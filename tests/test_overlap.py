"""Overlap engine: bucket construction, pipeline arithmetic, the exposed-comm
predictor, and the explicit-DP overlap schedule (jaxpr ordering + numerics)."""
import numpy as np
import pytest

from repro.core import overlap as ov
from repro.core.commplan import CommPlan
from repro.core.costmodel import (exposed_comm_time, make_comm_model,
                                  pipeline_params_at_scale)
from repro.core.scenarios import (PAPER_SYSTEMS, check_overlap_shapes,
                                  sweep_overlap, synthetic_grad_sizes)
from repro.core.topology import make_paper_systems, make_tpu_multipod

from .helpers import run_devices


# ------------------------------------------------------------------- buckets
def test_buckets_reverse_layer_order():
    """Bucket 0 must hold the *last* tensor's elements — the gradients backward
    materializes first."""
    buckets = ov.make_buckets([2, 3], bucket_elems=5)
    assert len(buckets) == 1
    assert buckets[0].spans == ((1, 0, 3), (0, 0, 2))
    fwd = ov.make_buckets([2, 3], bucket_elems=5, reverse=False)
    assert fwd[0].spans == ((0, 0, 2), (1, 0, 3))


def test_buckets_smaller_than_one_element():
    """bucket_bytes below one element clamps to one element per bucket instead
    of looping or emitting empty buckets."""
    buckets = ov.make_buckets([3], bucket_elems=0)
    assert len(buckets) == 3
    assert all(b.n_elems == 1 for b in buckets)


def test_buckets_single_tensor_tree():
    buckets = ov.make_buckets([10], bucket_elems=4)
    assert [b.n_elems for b in buckets] == [4, 4, 2]
    # spans of one tensor, contiguous and covering all 10 elements
    covered = sorted((lo, hi) for b in buckets for i, lo, hi in b.spans)
    assert covered == [(0, 4), (4, 8), (8, 10)]


def test_buckets_boundary_exactly_at_tensor_edge():
    """A tensor ending exactly at a bucket boundary must not leak a zero-width
    span into the next bucket."""
    buckets = ov.make_buckets([4, 4], bucket_elems=4)
    assert len(buckets) == 2
    assert buckets[0].spans == ((1, 0, 4),)
    assert buckets[1].spans == ((0, 0, 4),)
    assert all(lo < hi for b in buckets for _, lo, hi in b.spans)


def test_zero_size_leaf_roundtrip():
    """A zero-size gradient leaf owns no span; unpack must return fp32 zeros
    of its shape instead of crashing (regression)."""
    import jax.numpy as jnp

    flat_g = [jnp.ones((2, 2), jnp.float32), jnp.zeros((0,), jnp.float32),
              jnp.full((3,), 2.0, jnp.float32)]
    buckets = ov.make_buckets([g.size for g in flat_g], bucket_elems=4)
    assert all(lo < hi for b in buckets for _, lo, hi in b.spans)
    back = ov.unpack_buckets(ov.pack_buckets(flat_g, buckets), buckets, flat_g)
    assert back[1].shape == (0,) and back[1].dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(back[0]), np.ones((2, 2)))
    np.testing.assert_allclose(np.asarray(back[2]), 2.0 * np.ones(3))


def test_pack_unpack_roundtrip():
    import jax.numpy as jnp

    rng = np.random.RandomState(0)
    flat_g = [jnp.asarray(rng.randn(*s).astype(np.float32))
              for s in [(3, 2), (5,), (1,)]]
    buckets = ov.make_buckets([g.size for g in flat_g], bucket_elems=4)
    stacked = ov.pack_buckets(flat_g, buckets, scale=2.0)
    assert stacked.shape == (len(buckets), 4)
    back = ov.unpack_buckets(stacked, buckets, flat_g)
    for g, b in zip(flat_g, back):
        np.testing.assert_allclose(np.asarray(b), 2.0 * np.asarray(g), rtol=1e-6)


# --------------------------------------------------------- pipeline schedule
def test_pipeline_time_unimodal_in_chunks():
    """More chunks shrink the fill until the per-chunk alphas dominate."""
    model = make_comm_model("leonardo")
    params = pipeline_params_at_scale(model, 4096)
    depths = [1, 2, 4, 8, 16, 32]
    times = [ov.pipeline_time(64 << 20, c, params) for c in depths]
    best = times.index(min(times))
    assert best > 0, "pipelining a 64 MiB bucket must beat store-and-forward"
    assert all(b <= a * (1 + 1e-9) for a, b in zip(times[:best + 1], times[1:best + 1]))
    assert all(b >= a * (1 - 1e-9) for a, b in zip(times[best:], times[best + 1:]))


def test_choose_chunks_alpha_dominated_payload_unchunked():
    model = make_comm_model("leonardo")
    params = pipeline_params_at_scale(model, 4096)
    assert ov.choose_chunks(256.0, params) == 1
    assert ov.choose_chunks(64 << 20, params) > 1


def test_bucket_schedule_serial_chain_and_readiness():
    tl = ov.bucket_schedule(compute_time=1.0, bucket_bytes=[1, 1, 1, 1],
                            bucket_comm_s=[0.5, 0.5, 0.5, 0.5])
    # bucket 0 ready a quarter of the way through backward
    assert tl[0].ready_s == pytest.approx(0.25)
    assert tl[0].start_s == pytest.approx(0.25)
    # serial stream: each next bucket waits for the wire
    for a, b in zip(tl, tl[1:]):
        assert b.start_s == pytest.approx(max(b.ready_s, a.end_s))
    assert tl[-1].end_s == pytest.approx(0.25 + 4 * 0.5)


# ---------------------------------------------------------------- predictor
def test_exposed_comm_time_hidden_grows_with_compute():
    plan = CommPlan.from_topology(make_paper_systems()["leonardo"])
    model = make_comm_model("leonardo")
    sizes = synthetic_grad_sizes(256 << 20)
    ests = [exposed_comm_time(t, plan, sizes, n_endpoints=512, model=model)
            for t in (0.0, 0.01, 0.1, 1.0)]
    hf = [e.hidden_fraction for e in ests]
    assert hf == sorted(hf)
    assert ests[0].exposed_s == pytest.approx(ests[0].total_comm_s)
    for e in ests:
        assert 0.0 <= e.exposed_s <= e.total_comm_s * (1 + 1e-9)
        assert e.step_s == pytest.approx(max(e.compute_s, e.compute_s + e.exposed_s))


def test_exposed_comm_time_empty_sizes():
    plan = CommPlan.from_topology(make_paper_systems()["alps"])
    est = exposed_comm_time(1.0, plan, [], n_endpoints=64)
    assert est.total_comm_s == 0.0 and est.exposed_s == 0.0
    assert est.step_s == 1.0


def test_overlap_shape_checks_all_paper_systems():
    for system in PAPER_SYSTEMS:
        checks = check_overlap_shapes(system)
        bad = [k for k, okv in checks.items() if not okv]
        assert not bad, f"{system}: {bad}"


def test_sweep_overlap_points_structured():
    pts = sweep_overlap("lumi", (8, 512), compute_intensity=1.0)
    assert [p.n_endpoints for p in pts] == [8, 512]
    for p in pts:
        assert 0.0 < p.hidden_fraction <= 1.0
        assert p.compute_s == pytest.approx(p.total_comm_s)


def test_plan_pipeline_persistence_and_chunks():
    """The per-tier pipeline constants survive the JSON round-trip and feed
    pipeline_chunks."""
    plan = CommPlan.from_topology(make_tpu_multipod())
    assert plan.hierarchical and plan.pipeline
    back = CommPlan.from_blob(plan.to_blob())
    assert back.pipeline == plan.pipeline
    assert back.pipeline_chunks(plan.bucket_bytes) == \
        plan.pipeline_chunks(plan.bucket_bytes)
    assert plan.pipeline_chunks(plan.bucket_bytes) >= 1
    # single-level plans never pipeline
    flat = CommPlan.from_topology(make_paper_systems()["lumi"].intra)
    assert flat.pipeline_chunks(64 << 20) == 1


def test_overlap_rejects_per_tensor_bucketing():
    """overlap=True with an explicit bucket_bytes=0 (documented per-tensor
    mode) must refuse, not silently re-bucket."""
    import jax
    import repro.compat  # noqa: F401
    from jax.sharding import AxisType
    from repro.optim import adamw
    from repro.runtime import steps as rsteps

    mesh = jax.make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
    with pytest.raises(ValueError, match="per-tensor"):
        rsteps.build_explicit_dp_step(object(), adamw.OptConfig(), mesh,
                                      "data", overlap=True, bucket_bytes=0)


# ------------------------------------------------------- runtime (multi-dev)
OVERLAP_STEP = r"""
import jax, jax.numpy as jnp, numpy as np
import repro.compat  # jax API shims before touching jax.sharding
from jax.sharding import AxisType
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core import overlap as ov
from repro.models import build_model
from repro.optim import adamw
from repro.runtime import steps as rsteps

COLL = {"ppermute", "psum", "all_gather", "all_to_all", "psum_scatter"}

def walk(jaxpr, fn):
    for eqn in jaxpr.eqns:
        fn(eqn)
        for v in eqn.params.values():
            vals = v if isinstance(v, (tuple, list)) else (v,)
            for u in vals:
                if isinstance(u, jax.core.ClosedJaxpr):
                    walk(u.jaxpr, fn)
                elif isinstance(u, jax.core.Jaxpr):
                    walk(u, fn)

def prims_of(closed):
    names = set()
    walk(closed.jaxpr if hasattr(closed, "jaxpr") else closed,
         lambda e: names.add(e.primitive.name))
    return names

def scans_of(closed):
    found = []
    def visit(eqn):
        if eqn.primitive.name == "scan":
            found.append((eqn.params["length"], prims_of(eqn.params["jaxpr"])))
    walk(closed.jaxpr, visit)
    return found

cfg = get_config("smollm-135m").reduced()
shape = ShapeConfig("t", 32, 8, "train")
mesh = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
model = build_model(cfg)
opt = adamw.OptConfig(peak_lr=1e-3, warmup_steps=1, decay_steps=20)
params = model.init(jax.random.PRNGKey(0))
ostate = adamw.init_opt_state(params)
batch = model.make_batch(shape)
err = rsteps.init_error_state(params)

base = rsteps.build_explicit_dp_step(model, opt, mesh, "data")
bp, bo, bm, _ = base(params, ostate, batch, err)

# --- overlap mb=1: scan-carried issue schedule over reverse-order buckets ---
bb = 1 << 20
n_buckets = len(ov.make_buckets(
    [p.size for p in jax.tree.leaves(params)], bb // 4))
step1 = rsteps.build_explicit_dp_step(model, opt, mesh, "data",
                                      overlap=True, bucket_bytes=bb)
jx1 = jax.make_jaxpr(lambda p, o, b, e: step1(p, o, b, e))(
    params, ostate, batch, err)
scans = scans_of(jx1)
bucket_scans = [(ln, ps) for ln, ps in scans if ps & COLL]
assert bucket_scans, f"no scan carries collectives: {scans}"
assert any(ln == n_buckets for ln, ps in bucket_scans), \
    f"no per-bucket issue scan of length {n_buckets}: {[ln for ln, _ in scans]}"
# the issue scan is comm-only: reductions are separated from the backward blob
assert any(ln == n_buckets and "dot_general" not in ps
           for ln, ps in bucket_scans)
op, oo, om, _ = step1(params, ostate, batch, err)
d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(bp), jax.tree.leaves(op)))
print("overlap mb=1 delta:", d)
assert d < 5e-2
print("ok mb1")

# --- overlap mb=2: bucket reductions issued inside the same scan step as the
# next microbatch's backward (interleaved, not post-hoc) ---
step2 = rsteps.build_explicit_dp_step(model, opt, mesh, "data",
                                      overlap=True, bucket_bytes=bb,
                                      microbatches=2)
jx2 = jax.make_jaxpr(lambda p, o, b, e: step2(p, o, b, e))(
    params, ostate, batch, err)
inter = [(ln, ps) for ln, ps in scans_of(jx2)
         if (ps & COLL) and "dot_general" in ps]
assert inter, "no scan interleaves collectives with backward matmuls"
op2, _, om2, _ = step2(params, ostate, batch, err)
d2 = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
         for a, b in zip(jax.tree.leaves(bp), jax.tree.leaves(op2)))
print("overlap mb=2 delta:", d2)
assert d2 < 5e-2
print("ok mb2")

# --- two-level mesh: buckets run the chunked hierarchical pipeline ---
mesh2 = jax.make_mesh((2, 2), ("pod", "data"), axis_types=(AxisType.Auto,)*2)
steph = rsteps.build_explicit_dp_step(model, opt, mesh2, "data",
                                      dcn_axis="pod", overlap=True,
                                      bucket_bytes=bb, chunks=3)
hp, _, hm, _ = steph(params, ostate, batch, err)
dh = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
         for a, b in zip(jax.tree.leaves(bp), jax.tree.leaves(hp)))
print("hier chunked delta:", dh)
assert dh < 5e-2
print("ALL_OK")
"""


@pytest.mark.slow
def test_overlap_step_schedule_and_numerics():
    assert "ALL_OK" in run_devices(OVERLAP_STEP, 4, timeout=560)


INT8_WIRE = r"""
import jax, jax.numpy as jnp, re
import repro.compat
from jax.sharding import AxisType
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.optim import adamw
from repro.runtime import steps as rsteps

cfg = get_config("smollm-135m").reduced()
shape = ShapeConfig("t", 32, 8, "train")
mesh = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
model = build_model(cfg)
opt = adamw.OptConfig()
params = model.init(jax.random.PRNGKey(0))
ostate = adamw.init_opt_state(params)
batch = model.make_batch(shape)
err = rsteps.init_error_state(params)

step = rsteps.build_explicit_dp_step(model, opt, mesh, "data", compress_bits=8)
txt = str(jax.make_jaxpr(lambda p, o, b, e: step(p, o, b, e))(
    params, ostate, batch, err))
n_leaves = len(jax.tree.leaves(params))
i8 = re.findall(r"i8\[[^\]]*\] = all_gather", txt)
# per-tensor fp32 scale gathers are scalars -> f32[4] after gather; the bug
# was a *tensor-sized* fp32 payload on the wire (all_gather of the dequant)
big_f32 = re.findall(r"f32\[\d{3,}[^\]]*\] = all_gather", txt)
assert len(i8) == n_leaves, (len(i8), n_leaves)
assert not big_f32, big_f32

# wire accounting: int8 payload + one fp32 scale per tensor, per peer
sizes = [p.size for p in jax.tree.leaves(params)]
wire = sum(s + 4 for s in sizes)
fp32_wire = sum(4 * s for s in sizes)
assert wire < fp32_wire / 3.9, (wire, fp32_wire)

# numerics: compression still trains (finite loss, params move)
cp, co, cm, ce = step(params, ostate, batch, err)
assert jnp.isfinite(cm["loss"])
moved = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(cp)))
assert moved > 0
print("ALL_OK")
"""


@pytest.mark.slow
def test_int8_compression_wire_bytes():
    assert "ALL_OK" in run_devices(INT8_WIRE, 4, timeout=560)
