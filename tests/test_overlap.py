"""Overlap engine: bucket construction, pipeline arithmetic, the exposed-comm
predictor, and the explicit-DP overlap schedule (jaxpr ordering + numerics)."""
import numpy as np
import pytest

from repro.core import overlap as ov
from repro.core.commplan import CommPlan
from repro.core.costmodel import (exposed_comm_time, make_comm_model,
                                  pipeline_params_at_scale)
from repro.core.scenarios import (PAPER_SYSTEMS, check_overlap_shapes,
                                  sweep_overlap, synthetic_grad_sizes)
from repro.core.topology import make_paper_systems, make_tpu_multipod

from .helpers import run_devices


# ------------------------------------------------------------------- buckets
def test_buckets_reverse_layer_order():
    """Bucket 0 must hold the *last* tensor's elements — the gradients backward
    materializes first."""
    buckets = ov.make_buckets([2, 3], bucket_elems=5)
    assert len(buckets) == 1
    assert buckets[0].spans == ((1, 0, 3), (0, 0, 2))
    fwd = ov.make_buckets([2, 3], bucket_elems=5, reverse=False)
    assert fwd[0].spans == ((0, 0, 2), (1, 0, 3))


def test_buckets_smaller_than_one_element():
    """bucket_bytes below one element clamps to one element per bucket instead
    of looping or emitting empty buckets."""
    buckets = ov.make_buckets([3], bucket_elems=0)
    assert len(buckets) == 3
    assert all(b.n_elems == 1 for b in buckets)


def test_buckets_single_tensor_tree():
    buckets = ov.make_buckets([10], bucket_elems=4)
    assert [b.n_elems for b in buckets] == [4, 4, 2]
    # spans of one tensor, contiguous and covering all 10 elements
    covered = sorted((lo, hi) for b in buckets for i, lo, hi in b.spans)
    assert covered == [(0, 4), (4, 8), (8, 10)]


def test_buckets_boundary_exactly_at_tensor_edge():
    """A tensor ending exactly at a bucket boundary must not leak a zero-width
    span into the next bucket."""
    buckets = ov.make_buckets([4, 4], bucket_elems=4)
    assert len(buckets) == 2
    assert buckets[0].spans == ((1, 0, 4),)
    assert buckets[1].spans == ((0, 0, 4),)
    assert all(lo < hi for b in buckets for _, lo, hi in b.spans)


def test_zero_size_leaf_roundtrip():
    """A zero-size gradient leaf owns no span; unpack must return fp32 zeros
    of its shape instead of crashing (regression)."""
    import jax.numpy as jnp

    flat_g = [jnp.ones((2, 2), jnp.float32), jnp.zeros((0,), jnp.float32),
              jnp.full((3,), 2.0, jnp.float32)]
    buckets = ov.make_buckets([g.size for g in flat_g], bucket_elems=4)
    assert all(lo < hi for b in buckets for _, lo, hi in b.spans)
    back = ov.unpack_buckets(ov.pack_buckets(flat_g, buckets), buckets, flat_g)
    assert back[1].shape == (0,) and back[1].dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(back[0]), np.ones((2, 2)))
    np.testing.assert_allclose(np.asarray(back[2]), 2.0 * np.ones(3))


def test_pack_unpack_roundtrip():
    import jax.numpy as jnp

    rng = np.random.RandomState(0)
    flat_g = [jnp.asarray(rng.randn(*s).astype(np.float32))
              for s in [(3, 2), (5,), (1,)]]
    buckets = ov.make_buckets([g.size for g in flat_g], bucket_elems=4)
    stacked = ov.pack_buckets(flat_g, buckets, scale=2.0)
    assert stacked.shape == (len(buckets), 4)
    back = ov.unpack_buckets(stacked, buckets, flat_g)
    for g, b in zip(flat_g, back):
        np.testing.assert_allclose(np.asarray(b), 2.0 * np.asarray(g), rtol=1e-6)


# --------------------------------------------------------- pipeline schedule
def test_pipeline_time_unimodal_in_chunks():
    """More chunks shrink the fill until the per-chunk alphas dominate."""
    model = make_comm_model("leonardo")
    params = pipeline_params_at_scale(model, 4096)
    depths = [1, 2, 4, 8, 16, 32]
    times = [ov.pipeline_time(64 << 20, c, params) for c in depths]
    best = times.index(min(times))
    assert best > 0, "pipelining a 64 MiB bucket must beat store-and-forward"
    assert all(b <= a * (1 + 1e-9) for a, b in zip(times[:best + 1], times[1:best + 1]))
    assert all(b >= a * (1 - 1e-9) for a, b in zip(times[best:], times[best + 1:]))


def test_choose_chunks_alpha_dominated_payload_unchunked():
    model = make_comm_model("leonardo")
    params = pipeline_params_at_scale(model, 4096)
    assert ov.choose_chunks(256.0, params) == 1
    assert ov.choose_chunks(64 << 20, params) > 1


def test_bucket_schedule_serial_chain_and_readiness():
    tl = ov.bucket_schedule(compute_time=1.0, bucket_bytes=[1, 1, 1, 1],
                            bucket_comm_s=[0.5, 0.5, 0.5, 0.5])
    # bucket 0 ready a quarter of the way through backward
    assert tl[0].ready_s == pytest.approx(0.25)
    assert tl[0].start_s == pytest.approx(0.25)
    # serial stream: each next bucket waits for the wire
    for a, b in zip(tl, tl[1:]):
        assert b.start_s == pytest.approx(max(b.ready_s, a.end_s))
    assert tl[-1].end_s == pytest.approx(0.25 + 4 * 0.5)


# ---------------------------------------------------------------- predictor
def test_exposed_comm_time_hidden_grows_with_compute():
    plan = CommPlan.from_topology(make_paper_systems()["leonardo"])
    model = make_comm_model("leonardo")
    sizes = synthetic_grad_sizes(256 << 20)
    ests = [exposed_comm_time(t, plan, sizes, n_endpoints=512, model=model)
            for t in (0.0, 0.01, 0.1, 1.0)]
    hf = [e.hidden_fraction for e in ests]
    assert hf == sorted(hf)
    assert ests[0].exposed_s == pytest.approx(ests[0].total_comm_s)
    for e in ests:
        assert 0.0 <= e.exposed_s <= e.total_comm_s * (1 + 1e-9)
        assert e.step_s == pytest.approx(max(e.compute_s, e.compute_s + e.exposed_s))


def test_exposed_comm_time_empty_sizes():
    plan = CommPlan.from_topology(make_paper_systems()["alps"])
    est = exposed_comm_time(1.0, plan, [], n_endpoints=64)
    assert est.total_comm_s == 0.0 and est.exposed_s == 0.0
    assert est.step_s == 1.0


def test_overlap_shape_checks_all_paper_systems():
    for system in PAPER_SYSTEMS:
        checks = check_overlap_shapes(system)
        bad = [k for k, okv in checks.items() if not okv]
        assert not bad, f"{system}: {bad}"


def test_sweep_overlap_points_structured():
    pts = sweep_overlap("lumi", (8, 512), compute_intensity=1.0)
    assert [p.n_endpoints for p in pts] == [8, 512]
    for p in pts:
        assert 0.0 < p.hidden_fraction <= 1.0
        assert p.compute_s == pytest.approx(p.total_comm_s)


def test_plan_pipeline_persistence_and_chunks():
    """The per-tier pipeline constants survive the JSON round-trip and feed
    pipeline_chunks."""
    plan = CommPlan.from_topology(make_tpu_multipod())
    assert plan.hierarchical and plan.pipeline
    back = CommPlan.from_blob(plan.to_blob())
    assert back.pipeline == plan.pipeline
    assert back.pipeline_chunks(plan.bucket_bytes) == \
        plan.pipeline_chunks(plan.bucket_bytes)
    assert plan.pipeline_chunks(plan.bucket_bytes) >= 1
    # single-level plans never pipeline
    flat = CommPlan.from_topology(make_paper_systems()["lumi"].intra)
    assert flat.pipeline_chunks(64 << 20) == 1


def test_overlap_rejects_per_tensor_bucketing():
    """overlap=True with an explicit bucket_bytes=0 (documented per-tensor
    mode) must refuse, not silently re-bucket."""
    import jax
    import repro.compat  # noqa: F401
    from jax.sharding import AxisType
    from repro.optim import adamw
    from repro.runtime import steps as rsteps

    mesh = jax.make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
    with pytest.raises(ValueError, match="per-tensor"):
        rsteps.build_explicit_dp_step(object(), adamw.OptConfig(), mesh,
                                      "data", overlap=True, bucket_bytes=0)


# ------------------------------------------------------- runtime (multi-dev)
OVERLAP_STEP = r"""
import jax, jax.numpy as jnp, numpy as np
import repro.compat  # jax API shims before touching jax.sharding
from jax.sharding import AxisType
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core import overlap as ov
from repro.models import build_model
from repro.optim import adamw
from repro.runtime import steps as rsteps

# the shared walker (analysis.trace) replaced this file's hand-rolled
# walk/prims_of/scans_of copies
from repro.analysis import COLLECTIVE_KINDS as COLL
from repro.analysis import expected_trace, lint_trace, prims_of, scans_of, \
    trace_jaxpr

cfg = get_config("smollm-135m").reduced()
shape = ShapeConfig("t", 32, 8, "train")
mesh = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
model = build_model(cfg)
opt = adamw.OptConfig(peak_lr=1e-3, warmup_steps=1, decay_steps=20)
params = model.init(jax.random.PRNGKey(0))
ostate = adamw.init_opt_state(params)
batch = model.make_batch(shape)
err = rsteps.init_error_state(params)

base = rsteps.build_explicit_dp_step(model, opt, mesh, "data")
bp, bo, bm, _ = base(params, ostate, batch, err)

# --- overlap mb=1: scan-carried issue schedule over reverse-order buckets ---
bb = 1 << 20
n_buckets = len(ov.make_buckets(
    [p.size for p in jax.tree.leaves(params)], bb // 4))
step1 = rsteps.build_explicit_dp_step(model, opt, mesh, "data",
                                      overlap=True, bucket_bytes=bb)
jx1 = jax.make_jaxpr(lambda p, o, b, e: step1(p, o, b, e))(
    params, ostate, batch, err)
scans = scans_of(jx1)
bucket_scans = [(ln, ps) for ln, ps in scans if ps & COLL]
assert bucket_scans, f"no scan carries collectives: {scans}"
assert any(ln == n_buckets for ln, ps in bucket_scans), \
    f"no per-bucket issue scan of length {n_buckets}: {[ln for ln, _ in scans]}"
# the issue scan is comm-only: reductions are separated from the backward blob
assert any(ln == n_buckets and "dot_general" not in ps
           for ln, ps in bucket_scans)
# CommLint: the compiled step matches the overlap program end to end (every
# tensor-sized collective inside the scan, wire bytes within budget)
grad_bytes = sum(p.size * 4 for p in jax.tree.leaves(params))
fs = lint_trace(trace_jaxpr(jx1, donate_argnums=step1.donate_argnums),
                expected_trace(step1.program, n_devices=4,
                               grad_bytes=grad_bytes))
assert not fs, [str(f) for f in fs]
op, oo, om, _ = step1(params, ostate, batch, err)
d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(bp), jax.tree.leaves(op)))
print("overlap mb=1 delta:", d)
assert d < 5e-2
print("ok mb1")

# --- overlap mb=2: bucket reductions issued inside the same scan step as the
# next microbatch's backward (interleaved, not post-hoc) ---
step2 = rsteps.build_explicit_dp_step(model, opt, mesh, "data",
                                      overlap=True, bucket_bytes=bb,
                                      microbatches=2)
jx2 = jax.make_jaxpr(lambda p, o, b, e: step2(p, o, b, e))(
    params, ostate, batch, err)
inter = [(ln, ps) for ln, ps in scans_of(jx2)
         if (ps & COLL) and "dot_general" in ps]
assert inter, "no scan interleaves collectives with backward matmuls"
op2, _, om2, _ = step2(params, ostate, batch, err)
d2 = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
         for a, b in zip(jax.tree.leaves(bp), jax.tree.leaves(op2)))
print("overlap mb=2 delta:", d2)
assert d2 < 5e-2
print("ok mb2")

# --- two-level mesh: buckets run the chunked hierarchical pipeline ---
mesh2 = jax.make_mesh((2, 2), ("pod", "data"), axis_types=(AxisType.Auto,)*2)
steph = rsteps.build_explicit_dp_step(model, opt, mesh2, "data",
                                      dcn_axis="pod", overlap=True,
                                      bucket_bytes=bb, chunks=3)
hp, _, hm, _ = steph(params, ostate, batch, err)
dh = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
         for a, b in zip(jax.tree.leaves(bp), jax.tree.leaves(hp)))
print("hier chunked delta:", dh)
assert dh < 5e-2
print("ALL_OK")
"""


@pytest.mark.slow
def test_overlap_step_schedule_and_numerics():
    assert "ALL_OK" in run_devices(OVERLAP_STEP, 4, timeout=560)


INT8_WIRE = r"""
import jax, jax.numpy as jnp
import repro.compat
from jax.sharding import AxisType
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.optim import adamw
from repro.runtime import steps as rsteps

cfg = get_config("smollm-135m").reduced()
shape = ShapeConfig("t", 32, 8, "train")
mesh = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
model = build_model(cfg)
opt = adamw.OptConfig()
params = model.init(jax.random.PRNGKey(0))
ostate = adamw.init_opt_state(params)
batch = model.make_batch(shape)
err = rsteps.init_error_state(params)

step = rsteps.build_explicit_dp_step(model, opt, mesh, "data", compress_bits=8)
from repro.analysis import expected_trace, lint_trace, trace_jaxpr
jx = jax.make_jaxpr(lambda p, o, b, e: step(p, o, b, e))(
    params, ostate, batch, err)
tr = trace_jaxpr(jx, donate_argnums=step.donate_argnums)
n_leaves = len(jax.tree.leaves(params))
gathers = tr.of_kind("all_gather")
i8 = [r for r in gathers if r.dtype == "int8"]
# per-tensor fp32 scale gathers are scalar payloads; the bug was a
# *tensor-sized* fp32 payload on the wire (all_gather of the dequant) —
# which is exactly CommLint's wire-dtype-widening rule
big_f32 = [r for r in gathers if r.dtype == "float32"
           and not r.scalar and r.payload_bytes >= 400]
assert len(i8) == n_leaves, (len(i8), n_leaves)
assert not big_f32, big_f32
grad_bytes = sum(p.size * 4 for p in jax.tree.leaves(params))
fs = lint_trace(tr, expected_trace(step.program, n_devices=4,
                                   grad_bytes=grad_bytes))
assert not fs, [str(f) for f in fs]

# wire accounting: int8 payload + one fp32 scale per tensor, per peer
sizes = [p.size for p in jax.tree.leaves(params)]
wire = sum(s + 4 for s in sizes)
fp32_wire = sum(4 * s for s in sizes)
assert wire < fp32_wire / 3.9, (wire, fp32_wire)

# numerics: compression still trains (finite loss, params move)
cp, co, cm, ce = step(params, ostate, batch, err)
assert jnp.isfinite(cm["loss"])
moved = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(cp)))
assert moved > 0
print("ALL_OK")
"""


@pytest.mark.slow
def test_int8_compression_wire_bytes():
    assert "ALL_OK" in run_devices(INT8_WIRE, 4, timeout=560)


# ------------------------------------------------------- ZeRO pipeline math
def test_zero_stage_times_and_pipeline_time():
    """Three-phase stage arithmetic: the chunked pipeline hides the shorter
    stages behind the longest, an int8 AG leg strictly shrinks the AG stage,
    and the degenerate 1-chunk time is the plain stage sum."""
    p = ov.PipelineParams(n_ici=8, alpha_ici=2e-6, bw_ici=100e9,
                          alpha_dcn=1e-5, bw_dcn=25e9)
    nbytes = 64 << 20
    t_rs, t_inter, t_ag = p.zero_stage_times(nbytes)
    assert t_rs > 0 and t_inter > 0 and t_ag > 0
    assert t_rs == pytest.approx(t_ag)  # fp32 both legs, same alpha-beta
    assert ov.zero_pipeline_time(nbytes, 1, p) == \
        pytest.approx(t_rs + t_inter + t_ag)
    # pipelining: n_chunks stages of 1/n the bytes, bottleneck-paced
    t1 = ov.zero_pipeline_time(nbytes, 1, p)
    t4 = ov.zero_pipeline_time(nbytes, 4, p)
    assert t4 < t1
    # int8 AG multipliers shrink only the AG-side terms
    t_rs8, t_inter8, t_ag8 = p.zero_stage_times(nbytes, ag_intra=0.25,
                                                ag_inter=0.25)
    assert t_rs8 == pytest.approx(t_rs)
    assert t_ag8 < t_ag and t_inter8 < t_inter


def test_exposed_comm_time_zero_schedule():
    """`schedule="zero"` pricing: reported on the estimate, cheaper than the
    fp32 allreduce path on the flat tier (half the legs move compressed
    bytes), int8 AG strictly cheaper than fp32 AG, and unknown schedules are
    rejected."""
    from repro.core.topology import make_tpu_pod

    plan = CommPlan.from_topology(make_tpu_pod())
    sizes = synthetic_grad_sizes(64 << 20)
    ar = exposed_comm_time(0.01, plan, sizes, n_endpoints=8)
    z = exposed_comm_time(0.01, plan, sizes, n_endpoints=8, schedule="zero")
    z8 = exposed_comm_time(0.01, plan, sizes, n_endpoints=8, schedule="zero",
                           wire={"intra": "int8", "inter": "int8"})
    assert ar.schedule == "allreduce" and z.schedule == "zero"
    assert z8.total_comm_s < z.total_comm_s
    # fp32 zero on a flat tier == the allreduce (ring AR *is* RS + AG)
    assert z.total_comm_s == pytest.approx(ar.total_comm_s)
    with pytest.raises(ValueError, match="schedule"):
        exposed_comm_time(0.01, plan, sizes, n_endpoints=8, schedule="ring")
    # hierarchical: zero pricing uses the three-phase pipeline and the int8
    # AG leg still pays off
    hplan = CommPlan.from_topology(make_tpu_multipod())
    hz = exposed_comm_time(0.01, hplan, sizes, n_endpoints=512,
                           schedule="zero")
    hz8 = exposed_comm_time(0.01, hplan, sizes, n_endpoints=512,
                            schedule="zero",
                            wire={"intra": "int8", "inter": "int8"})
    assert hz8.total_comm_s < hz.total_comm_s
    assert hz.schedule == "zero" and hz.chunks >= 1


# ------------------------------------------------------ ZeRO runtime (multi-dev)
ZERO_STEP = r"""
import jax, jax.numpy as jnp, numpy as np
import repro.compat
from jax.sharding import AxisType, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core import overlap as ov
from repro.models import build_model
from repro.optim import adamw
from repro.runtime import steps as rsteps

mesh = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))

# --- two-tier collectives: RS -> AG round trip restores row order ---
row = jnp.arange(4 * 6, dtype=jnp.float32)
def rt(x):
    shard = ov.two_tier_reduce_scatter(x, "data")
    return ov.two_tier_all_gather(shard, "data")
back = shard_map(rt, mesh=mesh, in_specs=P(), out_specs=P(),
                 check_rep=False)(row)
np.testing.assert_array_equal(np.asarray(back), 4.0 * np.asarray(row))
print("rt flat ok")

mesh2 = jax.make_mesh((2, 2), ("pod", "data"), axis_types=(AxisType.Auto,)*2)
row2 = jnp.arange(2 * 2 * 3 * 2, dtype=jnp.float32)  # 2 chunks * 4 dev * 3
def rt2(x):
    shard = ov.two_tier_reduce_scatter(x, "data", "pod", n_chunks=2)
    return ov.two_tier_all_gather(shard, "data", "pod", n_chunks=2)
back2 = shard_map(rt2, mesh=mesh2, in_specs=P(), out_specs=P(),
                  check_rep=False)(row2)
np.testing.assert_array_equal(np.asarray(back2), 4.0 * np.asarray(row2))
print("rt hier ok")

# --- quantized AG: every device gets identical dequantized values ---
def qag(x):
    shard = ov.two_tier_reduce_scatter(x, "data")
    s = jnp.maximum(jnp.max(jnp.abs(shard)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(shard / s), -127, 127).astype(jnp.int8)
    full = ov.quantized_all_gather(q, s, "data")
    return jax.lax.all_gather(full, "data")  # (4, N): one row per device
rows = shard_map(qag, mesh=mesh, in_specs=P(), out_specs=P(),
                 check_rep=False)(row)
for r in range(1, 4):
    np.testing.assert_array_equal(np.asarray(rows[0]), np.asarray(rows[r]))
print("qag replicated ok")

# --- real-model three-phase step vs replicated baseline ---
cfg = get_config("smollm-135m").reduced()
shape = ShapeConfig("t", 32, 8, "train")
model = build_model(cfg)
opt = adamw.OptConfig(peak_lr=1e-3, warmup_steps=1, decay_steps=20)
params = model.init(jax.random.PRNGKey(0))
batch = model.make_batch(shape)
delta = lambda a, b: max(
    float(jnp.max(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32))))
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

base = rsteps.build_explicit_dp_step(model, opt, mesh, "data")
bp, bo, bm, _ = base(params, adamw.init_opt_state(params), batch,
                     base.init_error_state(params))

bb = 1 << 20
z = rsteps.build_explicit_dp_step(model, opt, mesh, "data", zero=True,
                                  overlap=True, bucket_bytes=bb)
zo = z.init_opt_state(params)
zp, zo2, zm, ze = z(params, zo, batch, z.init_error_state(params))
d = delta(bp, zp)
print("zero fp32 vs baseline:", d)
assert d < 1e-5, d
# satellite: psum-combined global norm tracks the replicated one
assert abs(float(bm["grad_norm"]) - float(zm["grad_norm"])) \
    <= 1e-5 * float(bm["grad_norm"])

# optimizer memory: m/v live carrier-sharded -> per-device bytes = full / 4
m = zo2["m"]
assert m.sharding.spec == P(None, "data"), m.sharding.spec
assert m.addressable_shards[0].data.nbytes * 4 == m.nbytes
print("opt state sharded ok:", m.shape, m.addressable_shards[0].data.shape)

# --- int8 AG leg: close to baseline, params replicated bit-identically ---
z8 = rsteps.build_explicit_dp_step(model, opt, mesh, "data", zero=True,
                                   overlap=True, bucket_bytes=bb,
                                   compress_bits=8)
zp8, _, _, _ = z8(params, z8.init_opt_state(params), batch,
                  z8.init_error_state(params))
d8 = delta(bp, zp8)
print("zero int8 vs baseline:", d8)
assert d8 < 5e-2, d8
for leaf in jax.tree.leaves(zp8):
    shards = leaf.addressable_shards
    for s in shards[1:]:
        np.testing.assert_array_equal(
            np.asarray(shards[0].data, np.float32),
            np.asarray(s.data, np.float32))
print("int8 params replicated ok")

# --- microbatched + hierarchical variants track the baseline ---
base_mb = rsteps.build_explicit_dp_step(model, opt, mesh, "data",
                                        overlap=True, bucket_bytes=bb,
                                        microbatches=2)
bmp, _, _, _ = base_mb(params, adamw.init_opt_state(params), batch,
                       base_mb.init_error_state(params))
zm2 = rsteps.build_explicit_dp_step(model, opt, mesh, "data", zero=True,
                                    overlap=True, bucket_bytes=bb,
                                    microbatches=2)
mp, _, _, _ = zm2(params, zm2.init_opt_state(params), batch,
                  zm2.init_error_state(params))
assert delta(bmp, mp) < 1e-5  # same microbatch accumulation, RS+AG vs AR

zh = rsteps.build_explicit_dp_step(model, opt, mesh2, "data", dcn_axis="pod",
                                   zero=True, overlap=True, bucket_bytes=bb,
                                   chunks=3)
hp, ho, hm, _ = zh(params, zh.init_opt_state(params), batch,
                   zh.init_error_state(params))
dh = delta(bp, hp)
print("zero hier chunked vs baseline:", dh)
assert dh < 1e-5, dh
assert ho["m"].sharding.spec == P(None, ("data", "pod")), ho["m"].sharding.spec
assert ho["m"].addressable_shards[0].data.nbytes * 4 == ho["m"].nbytes

# second step exercises carried sharded m/v
bp2, bo2, bm2, _ = base(bp, bo, batch, base.init_error_state(params))
zp2, _, zm2_, _ = z(zp, zo2, batch, ze)
assert delta(bp2, zp2) < 1e-5
print("ALL_OK")
"""


@pytest.mark.slow
def test_zero_step_multidevice_parity():
    assert "ALL_OK" in run_devices(ZERO_STEP, 4, timeout=560)
