"""Distributed integration: explicit-DP shard_map trainer vs XLA SPMD trainer,
sharded checkpoint resharding, and a reduced-config dry-run compile."""
import pytest

from .helpers import run_devices

EXPLICIT_DP = r"""
import jax, jax.numpy as jnp, numpy as np
import repro.compat  # jax API shims before touching jax.sharding
from jax.sharding import AxisType
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.optim import adamw
from repro.runtime import steps as rsteps

cfg = get_config("smollm-135m").reduced()
shape = ShapeConfig("t", 32, 4, "train")
mesh = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
model = build_model(cfg)          # no constraints; replicated params
opt = adamw.OptConfig(peak_lr=1e-3, warmup_steps=1, decay_steps=20)
params = model.init(jax.random.PRNGKey(0))
ostate = adamw.init_opt_state(params)
batch = model.make_batch(shape)

# reference: single-program step (the *CCL/XLA analog)
ref_step = jax.jit(rsteps.build_train_step(model, opt))
rp, ro, rm = ref_step(params, ostate, batch)

# explicit shard_map DP with our ring collectives (the GPU-aware-MPI analog)
step = rsteps.build_explicit_dp_step(model, opt, mesh, "data")
err = rsteps.init_error_state(params)
ep, eo, em, err = step(params, ostate, batch, err)
print("ref loss", float(rm["loss"]), "explicit loss", float(em["loss"]))
assert abs(float(rm["loss"]) - float(em["loss"])) < 1e-3
# parameters after one step must agree (same grads modulo fp error)
d = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
        for a, b in zip(jax.tree.leaves(rp), jax.tree.leaves(ep)))
print("max param delta:", d)
assert d < 5e-2  # bf16 params, ring-sum reassociation

# compressed variant still trains (loss finite, params move)
step_c = rsteps.build_explicit_dp_step(model, opt, mesh, "data", compress_bits=8)
cp, co, cm, err = step_c(params, ostate, batch, rsteps.init_error_state(params))
assert np.isfinite(float(cm["loss"]))
moved = max(float(jnp.max(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32))))
            for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(cp)))
assert moved > 0
print("OK")
"""


@pytest.mark.slow
def test_explicit_dp_matches_xla_spmd():
    assert "OK" in run_devices(EXPLICIT_DP, 4, timeout=560)


RESHARD = r"""
import jax, jax.numpy as jnp, numpy as np, tempfile
import repro.compat  # jax API shims before touching jax.sharding
from jax.sharding import AxisType, NamedSharding, PartitionSpec as P
from repro.checkpoint import CheckpointManager

# save on a (4,) mesh, restore on a (2,2) mesh — the elastic-restart path
mesh_a = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
tree = {"w": jax.device_put(jnp.arange(64.0).reshape(8, 8),
                            NamedSharding(mesh_a, P("data", None)))}
d = tempfile.mkdtemp()
cm = CheckpointManager(d)
cm.save(3, tree)
mesh_b = jax.make_mesh((2, 2), ("data", "model"), axis_types=(AxisType.Auto,)*2)
target_sh = {"w": NamedSharding(mesh_b, P("data", "model"))}
got, _ = cm.restore({"w": jax.ShapeDtypeStruct((8, 8), jnp.float32)},
                    shardings=target_sh)
np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(64.0).reshape(8, 8))
assert got["w"].sharding.spec == P("data", "model")
print("OK")
"""


@pytest.mark.slow
def test_checkpoint_reshard_across_meshes():
    assert "OK" in run_devices(RESHARD, 4)


DRYRUN_SMOKE = r"""
import jax
from repro.launch.dryrun import run_cell, summarize
from pathlib import Path
import tempfile
out = Path(tempfile.mkdtemp())
# reduced configs through the full production-mesh lower+compile path
for arch, shape in [("smollm-135m-reduced", "train_4k"),
                    ("mamba2-2.7b-reduced", "decode_32k"),
                    ("deepseek-moe-16b-reduced", "train_4k")]:
    cell = run_cell(arch, shape, multi_pod=True, out_dir=out)
    print(summarize(cell))
    assert cell["status"] == "ok", cell.get("error")
    assert cell["roofline"]["step_time_bound_s"] > 0
print("OK")
"""


@pytest.mark.slow
def test_dryrun_compiles_reduced_configs_multipod():
    assert "OK" in run_devices(DRYRUN_SMOKE, 512, timeout=560)
