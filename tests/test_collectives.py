"""Explicit collective algorithms vs jnp oracles (8 forced host devices)."""
import pytest

from .helpers import run_devices

VALIDATE = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import collectives as C  # installs repro.compat jax shims
from jax.sharding import PartitionSpec as P, AxisType

mesh = jax.make_mesh((8,), ("x",), axis_types=(AxisType.Auto,))
rng = np.random.RandomState(0)
x = rng.randn(8, 37).astype(np.float32)
want = np.broadcast_to(x.sum(0), (8, 37))
for name, fn in C.ALL_REDUCE_ALGOS.items():
    out = jax.jit(jax.shard_map(lambda v, fn=fn: fn(v, 'x'), mesh=mesh,
                                in_specs=P('x'), out_specs=P('x')))(x)
    assert np.allclose(np.asarray(out), want, atol=1e-4), name
    print("ok", name)

xg = rng.randn(8, 8, 3).astype(np.float32)
def oracle(xg, n, k):
    return np.stack([np.concatenate([xg[s][r*k:(r+1)*k] for s in range(n)]) for r in range(n)])
for name, fn in C.ALL_TO_ALL_ALGOS.items():
    out = np.asarray(jax.jit(jax.shard_map(lambda v, fn=fn: fn(v, 'x'), mesh=mesh,
                     in_specs=P('x'), out_specs=P('x')))(xg.reshape(64, 3))).reshape(8, 8, 3)
    assert np.allclose(out, oracle(xg, 8, 1)), name
    print("ok a2a", name)

mesh2 = jax.make_mesh((2, 4), ("pod", "ici"), axis_types=(AxisType.Auto,)*2)
xh = rng.randn(8, 21).astype(np.float32)
out = jax.jit(jax.shard_map(lambda v: C.hierarchical_all_reduce(v, 'ici', 'pod'),
      mesh=mesh2, in_specs=P(('pod','ici')), out_specs=P(('pod','ici'))))(xh)
assert np.allclose(np.asarray(out), np.broadcast_to(xh.sum(0), (8, 21)), atol=1e-4)
print("ok hierarchical")

# dtype sweep for ring (the trainer's DP path)
for dt in (np.float32, np.float16, np.int32):
    xi = (rng.randn(8, 16) * 10).astype(dt)
    out = jax.jit(jax.shard_map(lambda v: C.ring_all_reduce(v, 'x'), mesh=mesh,
                                in_specs=P('x'), out_specs=P('x')))(xi)
    ref = np.broadcast_to(xi.sum(0), (8, 16)).astype(dt)
    tol = 1e-2 if dt == np.float16 else 1e-4
    assert np.allclose(np.asarray(out).astype(np.float64), ref.astype(np.float64),
                       atol=tol, rtol=tol), dt
    print("ok ring dtype", dt)

# odd sizes exercise padding paths
for size in (1, 7, 63, 129):
    xo = rng.randn(8, size).astype(np.float32)
    out = jax.jit(jax.shard_map(lambda v: C.bidir_ring_all_reduce(v, 'x'), mesh=mesh,
                                in_specs=P('x'), out_specs=P('x')))(xo)
    assert np.allclose(np.asarray(out), np.broadcast_to(xo.sum(0), (8, size)), atol=1e-4), size
    print("ok bidir size", size)
print("ALL_OK")
"""


@pytest.mark.slow
def test_collective_algorithms_8dev():
    out = run_devices(VALIDATE, 8)
    assert "ALL_OK" in out


NONPOW2 = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import collectives as C  # installs repro.compat jax shims
from jax.sharding import PartitionSpec as P, AxisType
mesh = jax.make_mesh((6,), ("x",), axis_types=(AxisType.Auto,))
rng = np.random.RandomState(1)
x = rng.randn(6, 11).astype(np.float32)
for name in ("ring", "bidir_ring", "one_shot", "xla"):
    fn = C.ALL_REDUCE_ALGOS[name]
    out = jax.jit(jax.shard_map(lambda v, fn=fn: fn(v, 'x'), mesh=mesh,
                                in_specs=P('x'), out_specs=P('x')))(x)
    assert np.allclose(np.asarray(out), np.broadcast_to(x.sum(0), (6, 11)), atol=1e-4), name
print("ALL_OK")
"""


@pytest.mark.slow
def test_ring_family_non_power_of_two():
    assert "ALL_OK" in run_devices(NONPOW2, 6)


CHUNKED = r"""
import jax, jax.numpy as jnp, numpy as np
from repro.core import collectives as C  # installs repro.compat jax shims
from repro.core.overlap import chunked_hierarchical_all_reduce
from jax.sharding import PartitionSpec as P, AxisType

mesh = jax.make_mesh((2, 4), ("pod", "ici"), axis_types=(AxisType.Auto,)*2)
rng = np.random.RandomState(3)

# integer-valued fp32: sums are exact regardless of association, so the
# chunked pipeline must match the psum oracle bit-for-bit
for size in (1, 7, 64, 129, 1000):
    x = rng.randint(-64, 64, (8, size)).astype(np.float32)
    want = np.asarray(jax.jit(jax.shard_map(
        lambda v: jax.lax.psum(v, ("pod", "ici")), mesh=mesh,
        in_specs=P(("pod", "ici")), out_specs=P(("pod", "ici"))))(x))
    for n_chunks in (1, 2, 3, 5):
        out = np.asarray(jax.jit(jax.shard_map(
            lambda v, c=n_chunks: chunked_hierarchical_all_reduce(
                v, "ici", "pod", n_chunks=c),
            mesh=mesh, in_specs=P(("pod", "ici")),
            out_specs=P(("pod", "ici"))))(x))
        assert np.array_equal(out, want), (size, n_chunks)
    print("ok chunked size", size)

# and the registry carries it as a multi-axis all-reduce
spec = C.get_collective("all_reduce", "hierarchical_chunked")
assert spec.multi_axis
print("ALL_OK")
"""


@pytest.mark.slow
def test_chunked_hierarchical_pipeline_matches_psum_oracle():
    assert "ALL_OK" in run_devices(CHUNKED, 8)
