"""Test helpers: spawn subprocesses with forced host device counts.

Multi-device tests must run in fresh processes because jax locks the device
count at first init (the dry-run forces 512 only inside its own process).
"""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SRC = str(REPO / "src")


def count_eqns(closed, name: str = None) -> int:
    """Count jaxpr equations (all of them, or those of primitive `name`) —
    thin shim; the shared walker lives in `repro.analysis.trace`."""
    from repro.analysis.trace import count_eqns as _count

    return _count(closed, name)


def run_devices(code: str, n_devices: int = 8, timeout: int = 420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", code], env=env, capture_output=True,
                         text=True, timeout=timeout, cwd=str(REPO))
    assert res.returncode == 0, f"subprocess failed:\nSTDOUT:\n{res.stdout}\nSTDERR:\n{res.stderr[-3000:]}"
    return res.stdout
