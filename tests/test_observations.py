"""The paper's eight observations, asserted against this framework's models and
mechanisms (the reproduction scorecard — one test per observation)."""
import numpy as np
import pytest

from repro.core.autotune import CollectivePolicy
from repro.core.costmodel import make_comm_model, crossover_bytes
from repro.core.noise import NoiseModel, ServiceLevelArbiter, TrafficClass
from repro.core.topology import make_paper_node_graphs
from repro.core.hw import gbit


def test_obs1_tuning_changes_choice():
    """Obs 1: achieving good performance requires non-trivial tuning that depends
    on system, size, library, and scale — i.e., the optimal algorithm choice is
    not constant across that grid."""
    policy = CollectivePolicy.from_model(make_comm_model("lumi"))
    choices = {policy.all_reduce_algo(nbytes, n)
               for nbytes in (1 << 10, 1 << 16, 1 << 22, 1 << 28)
               for n in (2, 8, 64, 512)}
    assert len(choices) > 1, "a single algorithm won everywhere — no tuning surface"


def test_obs2_staging_loses_goodput_direct_wins():
    """Obs 2: GPU-aware transfers beat trivial staging by up to an order of
    magnitude; best small-transfer mechanism is system-dependent."""
    gaps = {}
    small_best = {}
    for system in ("alps", "leonardo", "lumi"):
        m = make_comm_model(system)
        s = float(1 << 27)
        gaps[system] = m.p2p(s, "mpi").goodput(s) / m.p2p(s, "staging").goodput(s)
        lat = {mech: m.p2p(256.0, mech).seconds for mech in ("device_copy", "ccl", "mpi")}
        small_best[system] = min(lat, key=lat.get)
    assert all(g > 2 for g in gaps.values())
    assert len(set(small_best.values())) >= 2, "small-message optimum should differ across systems"


def test_obs3_hop_count_underestimates_lumi_bandwidth():
    """Obs 3: RCCL's hop-count bandwidth model underutilizes multi-path GCD pairs."""
    g = make_paper_node_graphs()["lumi"]
    # GPU 0 -> 7: nominal single-path 400 Gb/s over >=2 hops; a hop-count model
    # assumes bw/hops and lands below what the fabric supports.
    hops = len(g.shortest_path(0, 7)) - 1
    assert hops >= 2
    hopcount_bw = g.link_bw / hops
    assert hopcount_bw < g.pair_bw(0, 7)


def test_obs4_ccl_wins_large_collectives_mpi_wins_small_on_lumi():
    m = make_comm_model("lumi")
    big = float(1 << 28)
    small = 2048.0
    assert m.allreduce_intra(big, "ccl").seconds < m.allreduce_intra(big, "mpi").seconds
    assert m.allreduce_intra(small, "mpi").seconds < m.allreduce_intra(small, "ccl").seconds


def test_obs5_mpi_wins_internode_p2p():
    for system in ("alps", "leonardo", "lumi"):
        m = make_comm_model(system)
        for s in (512.0, float(1 << 26)):
            assert m.p2p(s, "mpi", inter_node=True).seconds <= \
                m.p2p(s, "ccl", inter_node=True).seconds


def test_obs6_distance_hurts_leonardo_most():
    lat_ratio = {}
    for system in ("alps", "leonardo", "lumi"):
        m = make_comm_model(system)
        lat_ratio[system] = m.p2p(1.0, "mpi", True, "diff_group").seconds / \
            m.p2p(1.0, "mpi", True, "same_switch").seconds
    assert lat_ratio["leonardo"] > 1.9          # ~2x (Obs 6)
    assert lat_ratio["alps"] < 1.5              # ~28%
    # goodput: Leonardo -17% across groups, others ~1%
    assert make_comm_model("leonardo").profile.noise_goodput_frac_diff_group < 0.9
    assert make_comm_model("alps").profile.noise_goodput_frac_diff_group > 0.95


def test_obs7_alltoall_connection_state_bounded():
    """Obs 7: *CCL alltoall stalls beyond 512 endpoints; our dispatch forces the
    pairwise (one-peer-in-flight) schedule there."""
    import jax.numpy as jnp
    p = CollectivePolicy.from_model()
    # dispatch path check without tracing: the guard in all_to_all()
    x = jnp.zeros((4, 2))
    # emulate the guard logic
    algo = p.all_to_all_algo(x.size * 4, 1024)
    forced = "pairwise" if 1024 > 512 else algo
    assert forced == "pairwise"


def test_obs8_noise_costs_20_to_50_percent_at_1k():
    nm = NoiseModel.leonardo_diff_group()
    drop_ar = 1 - nm.goodput_scaling(1024, 4, "allreduce")
    drop_a2a = 1 - nm.goodput_scaling(1024, 4, "alltoall")
    assert 0.35 <= drop_ar <= 0.65
    assert 0.1 <= drop_a2a <= 0.3
    # and isolation via a second service level restores most of it (Sec. VI-A)
    arb = ServiceLevelArbiter(link_bw=25e9)
    victim = TrafficClass("allreduce", 0, 10e9)
    noisy = arb.victim_goodput(victim, [TrafficClass("prod", 0, 50e9)])
    isolated = arb.victim_goodput(victim, [TrafficClass("prod", 1, 50e9)])
    assert isolated > noisy
