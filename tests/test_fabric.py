"""Inter-node fabric layer + at-scale scenario suite + at-scale bugfix
regressions (dragonfly/fat-tree/rail constructors, tier classification,
capped finite-size bounds, calibrated inter path, axis-cut bisection,
adjacency caching, ceil node counting)."""
import pytest

from repro.core.bench import BenchRecord, IterStats
from repro.core.calibrate import _key, fit_profile, split_key
from repro.core.commplan import CommPlan
from repro.core.costmodel import make_comm_model
from repro.core.hw import LEONARDO, LUMI, gbit
from repro.core.scenarios import (DEFAULT_ENDPOINTS, at_scale_suite,
                                  check_paper_shapes, sweep_collective)
from repro.core.topology import (Fabric, LinkGraph, TwoLevelTopology,
                                 make_paper_fabrics, make_paper_systems,
                                 make_tpu_multipod)


@pytest.fixture(scope="module")
def fabrics():
    return make_paper_fabrics()


@pytest.fixture(scope="module")
def systems():
    return make_paper_systems()


# ----------------------------------------------------------- constructors
def test_dragonfly_tier_classification(fabrics):
    f = fabrics["alps"]  # 4 GPUs/node, 16 nodes/switch, 16 switches/group
    assert f.kind == "dragonfly"
    assert f.distance(0, 1) == "same_node"
    assert f.distance(0, 4) == "same_switch"          # next node, same switch
    assert f.distance(0, 16 * 4) == "same_group"      # switch 1, group 0
    assert f.distance(0, 16 * 16 * 4) == "diff_group"  # first node of group 1
    # tier_for_scale boundaries follow the packed-placement geometry
    assert f.tier_for_scale(4) == "same_node"
    assert f.tier_for_scale(64) == "same_switch"
    assert f.tier_for_scale(65) == "same_group"
    assert f.tier_for_scale(1024) == "same_group"
    assert f.tier_for_scale(1025) == "diff_group"
    assert f.tier_for_scale(4096) == "diff_group"


def test_dragonfly_link_counts_and_graphs(fabrics):
    for name in ("alps", "lumi"):
        f = fabrics[name]
        counts = f.tier_link_counts()
        assert counts["same_switch"] == f.endpoints_per_switch
        assert counts["same_group"] > 0 and counts["diff_group"] > 0
        # fully-connected tier graphs: one path per pair (EFI = 1, Sec. IV-A)
        assert f.switch_graph.edge_forwarding_index(per_link=False) == 1
        assert f.group_graph.edge_forwarding_index(per_link=False) == 1
        # injection-balanced sizing: the global links of one group carry the
        # group's full injection, so the per-endpoint tier bound is the NIC
        assert f.tier_bw("diff_group") == pytest.approx(f.nic_bw)


def test_fat_tree_taper(fabrics):
    f = fabrics["leonardo"]
    assert f.kind == "fat_tree" and f.taper == 2.0
    assert f.tier_bw("same_switch") == pytest.approx(LEONARDO.nic_bw)
    assert f.tier_bw("same_group") == pytest.approx(LEONARDO.nic_bw)
    assert f.tier_bw("diff_group") == pytest.approx(LEONARDO.nic_bw / 2.0)
    counts = f.tier_link_counts()
    # pod spine non-blocking (uplinks == downlinks); 2:1 taper at the core
    assert counts["same_group"] == counts["same_switch"] * f.switches_per_group
    assert counts["diff_group"] == f.endpoints_per_group * f.n_groups // 2


def test_tier_bw_monotone_across_tiers(fabrics):
    for f in fabrics.values():
        assert f.tier_bw("same_switch") >= f.tier_bw("same_group") \
            >= f.tier_bw("diff_group") > 0
        assert f.bisection_bw() > 0


def test_rail_optimized_classification():
    f = Fabric.rail_optimized("rail8", endpoints_per_node=4, n_nodes=8,
                              nic_bw=gbit(200), taper=2.0)
    assert f.distance(0, 1) == "same_node"
    assert f.distance(0, 4) == "same_switch"   # endpoint 0 of node 1: same rail
    assert f.distance(1, 4) == "same_group"    # cross-rail: via the spine
    assert f.tier_bw("same_switch") == pytest.approx(gbit(200))
    assert f.tier_bw("same_group") == pytest.approx(gbit(100))


def test_flat_fabric_is_legacy_dcn():
    f = Fabric.flat("dcn", endpoints_per_node=256, n_nodes=4, nic_bw=gbit(25))
    assert f.distance(0, 1) == "same_node"
    assert f.distance(0, 256) == "diff_group"  # every inter pair is diff_group
    for tier in ("same_switch", "same_group", "diff_group"):
        assert f.tier_bw(tier) == pytest.approx(gbit(25))
    assert f.asymptotic_alltoall_goodput() == pytest.approx(gbit(25))


def test_two_level_scalar_construction_backward_compatible():
    mp = make_tpu_multipod()
    assert mp.fabric is not None and mp.fabric.kind == "flat"
    assert mp.dcn_bw == pytest.approx(gbit(25))
    assert mp.alltoall_asymptotic_goodput() == pytest.approx(gbit(25))
    # from_fabric round-trip: n_pods and the scalar view are derived
    f = Fabric.flat("dcn", mp.intra.n, 4, gbit(25))
    t = TwoLevelTopology.from_fabric(mp.intra, f)
    assert t.n_pods == 4 and t.dcn_bw == pytest.approx(gbit(25))


# ----------------------------------------------------- bugfix regressions
def test_finite_size_alltoall_capped_and_monotone(systems):
    """Regression: the finite-size correction was unbounded — at
    n = intra.n + 1 it returned ~n * dcn_bw, far beyond the intra bound."""
    for name, topo in systems.items():
        intra_bound = topo.intra.alltoall_expected_goodput()
        prev = None
        for n in (topo.intra.n, topo.intra.n + 1, topo.intra.n * 2, 1024, 4096):
            g = topo.alltoall_expected_goodput(n)
            assert g <= intra_bound * (1 + 1e-9), (name, n)
            if prev is not None:
                assert g <= prev * (1 + 1e-9), (name, n)
            prev = g
    # and on a legacy scalar-dcn construction
    mp = make_tpu_multipod()
    just_over = mp.alltoall_expected_goodput(mp.intra.n + 1)
    assert just_over <= mp.intra.alltoall_expected_goodput()
    assert mp.alltoall_expected_goodput(4096) >= mp.dcn_bw * 0.99


def test_bisection_axis_cut_minimum():
    """Regression: bisection was a contiguous index half-split, wrong for odd
    nx and for y-axis-limited tori."""
    assert LinkGraph.torus2d(3, 4, 1e9).bisection_bw() == pytest.approx(6e9)
    # 2x8: the y cut (4 links) is narrower than the x half-split (16 links)
    assert LinkGraph.torus2d(2, 8, 1e9).bisection_bw() == pytest.approx(4e9)
    # symmetric even torus unchanged (the v5e pod bound tests depend on it)
    assert LinkGraph.torus2d(16, 16, 1e9).bisection_bw() == pytest.approx(32e9)
    assert LinkGraph.torus3d(2, 2, 4, 1e9).bisection_bw() == pytest.approx(8e9)
    assert LinkGraph.ring(7, 1e9).bisection_bw() == pytest.approx(2e9)


def test_adjacency_cached_and_correct():
    """Regression (perf): neighbors() rescanned the whole edge dict per call;
    the adjacency list is now built once and reused by the BFS/ECMP paths."""
    g = LinkGraph.lumi_node(1.0)
    assert g.neighbors(0) == [1, 2, 4]
    assert g.degree_links(0) == 6
    assert g._adjacency() is g._adjacency()  # cached, not rebuilt
    # recompute from the edge dict: identical view
    for u in range(g.n):
        manual = sorted(b for (a, b) in g.links if a == u) + \
            sorted(a for (a, b) in g.links if b == u)
        assert sorted(manual) == g.neighbors(u)
    # routing results unchanged by the cache
    assert g.edge_forwarding_index() == pytest.approx(4.0)


def test_allreduce_at_scale_ceil_node_count():
    """Regression: n_nodes used floor division, so 12 endpoints on 8-GCD
    nodes counted 1 node and the inter phase vanished."""
    m = make_comm_model("lumi")
    s = float(1 << 26)
    nn = m.profile.endpoints_per_node
    assert nn == 8
    t8 = m.allreduce_at_scale(s, 8).seconds     # single node: intra only
    t12 = m.allreduce_at_scale(s, 12).seconds   # 2 nodes: inter phase exists
    t16 = m.allreduce_at_scale(s, 16).seconds
    assert t12 > t8
    assert t12 == pytest.approx(t16, rel=1e-6)  # both span ceil(12/8)=2 nodes


def test_calibration_reaches_inter_node_path():
    """Regression: CommModel._bw hard-coded MECH_EFFICIENCY_P2P_INTER even
    when a CalibrationProfile was supplied — measured fits never affected
    inter-node costs.  Now the untiered p2p fit overrides the inter
    efficiency, and tier-qualified fits (@tier) refine it per tier."""
    def rec(nbytes, t, tier=None):
        return BenchRecord("pingpong/x", "mpi", "p2p", nbytes, 4,
                           IterStats([t] * 3), nbytes / (t / 2), tier=tier)

    bw_flat, bw_dg = 2e9, 0.5e9
    records = []
    for s in (1 << 10, 1 << 14, 1 << 20, 1 << 24):
        records.append(rec(s, 2 * (20e-6 + s / bw_flat)))
        records.append(rec(s, 2 * (80e-6 + s / bw_dg), tier="diff_group"))
    prof = fit_profile(records, system="lumi", topology="lumi_node")
    assert _key("mpi", "p2p", "large", "diff_group") in prof.params
    assert prof.get("mpi", "p2p", "large") is not None  # untiered intact

    plain = make_comm_model("lumi")
    calib = make_comm_model("lumi", calibration=prof)
    s = float(1 << 22)
    # untiered measured 2e9 B/s replaces nic_bw * 0.90 = 11.25e9 B/s
    t_plain = plain.p2p(s, "mpi", inter_node=True).seconds
    t_calib = calib.p2p(s, "mpi", inter_node=True).seconds
    assert t_calib > t_plain * 2
    # the tier-qualified fit makes diff_group slower still, and its measured
    # small-message alpha (80us) replaces the profile constant
    t_dg = calib.p2p(s, "mpi", inter_node=True, distance="diff_group").seconds
    assert t_dg > t_calib
    assert calib.p2p(1.0, "mpi", inter_node=True, distance="diff_group").seconds \
        == pytest.approx(80e-6, rel=0.05)


# ------------------------------------------------------- calibrate tier keys
def test_tier_key_roundtrip():
    assert _key("mpi", "p2p", "small") == "mpi/p2p/small"
    assert _key("mpi", "p2p", "small", "same_group") == "mpi/p2p/small@same_group"
    assert split_key("ccl/alltoall/large") == ("ccl", "alltoall", "large", None)
    assert split_key("mpi/p2p/small@diff_group") == \
        ("mpi", "p2p", "small", "diff_group")


def test_fit_profile_groups_tiers_separately():
    def rec(mech, nbytes, t, tier):
        return BenchRecord("r", mech, "p2p", nbytes, 8, IterStats([t] * 3),
                           nbytes / (t / 2), tier=tier)

    records = [rec("mpi", 4096, 1e-5, None), rec("mpi", 4096, 4e-5, "same_group"),
               rec("mpi", 4096, 8e-5, "diff_group")]
    prof = fit_profile(records)
    assert set(prof.params) == {"mpi/p2p/small", "mpi/p2p/small@same_group",
                                "mpi/p2p/small@diff_group"}
    assert prof.get("mpi", "p2p", "small", tier="diff_group").alpha > \
        prof.get("mpi", "p2p", "small", tier="same_group").alpha
    # no silent fallback from tiered lookup to the intra fit
    assert prof.get("mpi", "p2p", "small", tier="same_switch") is None


# ------------------------------------------------------------ CommPlan tiers
def test_commplan_tables_carry_distance_tiers(tmp_path):
    plan = CommPlan.from_topology(make_tpu_multipod())
    assert plan.tiers, "two-level plan should record per-axis-size tiers"
    assert plan.distance_tier(4) == "intra"
    assert plan.distance_tier(512) == "diff_group"
    # group boundary forces the bounded-connection-state alltoall schedule
    assert plan.all_to_all_algo(1 << 20, 512) == "pairwise"
    f = tmp_path / "plan.json"
    plan.save(str(f))
    back = CommPlan.load(str(f))
    assert back.tiers == plan.tiers
    assert "fabric" in plan.meta


def test_commplan_paper_fabric_tiers(systems):
    plan = CommPlan.from_topology(systems["lumi"],
                                  axis_sizes=(8, 64, 512, 4096, 32768))
    assert plan.distance_tier(8) == "intra"
    assert plan.distance_tier(64) == "same_switch"
    assert plan.distance_tier(512) == "same_group"
    assert plan.distance_tier(32768) == "diff_group"
    assert plan.hierarchical


# ------------------------------------------------------------- scenario suite
@pytest.mark.parametrize("system", ["alps", "leonardo", "lumi", "tpu_v5e"])
def test_paper_shapes_hold(system):
    checks = check_paper_shapes(system)
    bad = [k for k, ok in checks.items() if not ok]
    assert not bad, f"{system}: {bad}"


def test_alltoall_weak_scaling_approaches_nic_asymptote(systems):
    """Sec. V-C: weak-scaling alltoall goodput decays monotonically and its
    topology bound converges to the fabric's per-endpoint asymptote."""
    topo = systems["alps"]
    pts = sweep_collective("alps", "alltoall", "weak", "ccl", topo=topo)
    gs = [p.goodput_bytes_s for p in pts]
    assert all(b <= a for a, b in zip(gs, gs[1:]))
    assert pts[-1].n_endpoints == 4096 and pts[-1].tier == "diff_group"
    assert pts[-1].bound_bytes_s == pytest.approx(
        topo.alltoall_asymptotic_goodput(), rel=0.01)
    assert 0 < pts[-1].goodput_bytes_s <= pts[-1].bound_bytes_s


def test_allreduce_hierarchical_min_of_phases(systems):
    """Sec. V-A: at-scale allreduce is bounded by min(intra phase, fabric
    phase) — goodput never exceeds the intra-node bound, and the fabric tier
    bound shrinks across group boundaries on the tapered fat-tree."""
    topo = systems["leonardo"]
    intra = topo.intra.allreduce_expected_goodput()
    pts = sweep_collective("leonardo", "allreduce", "weak", "ccl", topo=topo)
    assert all(p.goodput_bytes_s <= intra for p in pts if p.n_endpoints > 4)
    assert topo.allreduce_expected_goodput(4096) < \
        topo.allreduce_expected_goodput(512)


def test_strong_scaling_surfaces_latency():
    """Strong scaling shrinks per-endpoint payloads, so goodput collapses
    faster than weak scaling at the same endpoint count."""
    weak = sweep_collective("lumi", "alltoall", "weak", "ccl")
    strong = sweep_collective("lumi", "alltoall", "strong", "ccl")
    assert strong[-1].payload_bytes < weak[-1].payload_bytes
    assert strong[-1].goodput_bytes_s < weak[-1].goodput_bytes_s


def test_noise_ordering_matches_obs8():
    pts_ar = sweep_collective("leonardo", "allreduce", "weak", "ccl",
                              endpoints=(1024,))
    pts_a2a = sweep_collective("leonardo", "alltoall", "weak", "ccl",
                               endpoints=(1024,))
    drop = lambda p: 1 - p.noisy_goodput_bytes_s / p.goodput_bytes_s
    assert drop(pts_ar[0]) > drop(pts_a2a[0])


def test_at_scale_suite_covers_grid():
    pts = at_scale_suite(systems=("lumi",), endpoints=(8, 64, 512),
                         mechanisms=("ccl",))
    assert len(pts) == 2 * 2 * 3  # collectives x scalings x endpoint counts
    assert {p.tier for p in pts} >= {"same_switch", "same_group"}
    assert all(p.seconds > 0 and p.goodput_bytes_s > 0 for p in pts)
