"""Architecture smoke tests (all 10, reduced configs) + semantic equivalences."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, get_config, list_configs
from repro.configs.base import ShapeConfig, shape_applicable
from repro.models import build_model
from repro.models import transformer as T

ALL_ARCHS = list_configs()
TRAIN_SHAPE = ShapeConfig("t", 64, 4, "train")


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_train_step(arch):
    """Reduced config: one forward/loss on CPU — shapes + no NaNs."""
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = m.make_batch(TRAIN_SHAPE)
    loss = jax.jit(m.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch} loss not finite"
    assert 1.0 < float(loss) < 20.0  # ~ln(vocab) at init


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_arch_smoke_decode_step(arch):
    cfg = get_config(arch).reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    dshape = ShapeConfig("d", 32, 2, "decode")
    cache = m.init_cache(dshape, batch_size=2)
    tok = m.make_batch(dshape)["tokens"][:2]
    logits, cache2 = jax.jit(m.decode)(params, cache, tok, jnp.array(3))
    assert logits.shape[0] == 2 and logits.shape[-1] == cfg.vocab
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ["smollm-135m", "qwen1.5-4b", "musicgen-medium",
                                  "mamba2-2.7b", "zamba2-7b"])
def test_decode_matches_forward(arch):
    """prefill + incremental decode == full forward (the caching invariant)."""
    cfg = dataclasses.replace(get_config(arch).reduced(), remat="none")
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    S, P0 = 16, 8
    batch = m.make_batch(ShapeConfig("t", S, 2, "train"))
    x, positions = m._embed(params, batch)
    if cfg.family == "ssm":
        xh = m._ssm_forward(params, x)
    elif cfg.family == "hybrid":
        from repro.models import hybrid as H
        xh = H.hybrid_forward(params, x, cfg, m.shd, positions)
    else:
        xh, _ = T.forward(params, x, cfg, m.shd, positions)
    full = np.asarray(T.unembed(params, xh, cfg, m.shd).astype(jnp.float32))

    cache = m.init_cache(ShapeConfig("d", S, 2, "decode"), batch_size=2)
    toks = batch["tokens"]
    lg, cache = jax.jit(m.prefill)(params, {"tokens": toks[:, :P0]}, cache)
    errs = [np.abs(np.asarray(lg.astype(jnp.float32))[:, 0] - full[:, P0 - 1]).max()]
    dec = jax.jit(m.decode)
    for p in range(P0, S - 1):
        tok = toks[:, p] if toks.ndim == 2 else toks[:, p, :]
        lg, cache = dec(params, cache, tok, jnp.array(p, jnp.int32))
        errs.append(np.abs(np.asarray(lg.astype(jnp.float32))[:, 0] - full[:, p]).max())
    tol = 1e-4 if cfg.family in ("dense", "audio") else 0.08  # bf16 recurrences
    assert max(errs) < tol, f"{arch}: {max(errs)}"


def test_moe_decode_matches_forward_without_drops():
    cfg = dataclasses.replace(get_config("deepseek-moe-16b").reduced(),
                              remat="none", capacity_factor=16.0)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    S = 16
    batch = m.make_batch(ShapeConfig("t", S, 2, "train"))
    x, positions = m._embed(params, batch)
    xh, _ = T.forward(params, x, cfg, m.shd, positions)
    full = np.asarray(T.unembed(params, xh, cfg, m.shd).astype(jnp.float32))
    cache = m.init_cache(ShapeConfig("d", S, 2, "decode"), batch_size=2)
    lg, cache = jax.jit(m.prefill)(params, {"tokens": batch["tokens"][:, :8]}, cache)
    err = np.abs(np.asarray(lg.astype(jnp.float32))[:, 0] - full[:, 7]).max()
    assert err < 1e-4


def test_moe_capacity_drops_tokens():
    """Low capacity must change outputs (drops) but keep them finite."""
    base = get_config("deepseek-moe-16b").reduced()
    m_lo = build_model(dataclasses.replace(base, capacity_factor=0.5, remat="none"))
    m_hi = build_model(dataclasses.replace(base, capacity_factor=16.0, remat="none"))
    params = m_lo.init(jax.random.PRNGKey(0))
    batch = m_lo.make_batch(TRAIN_SHAPE)
    lo = jax.jit(m_lo.loss)(params, batch)
    hi = jax.jit(m_hi.loss)(params, batch)
    assert bool(jnp.isfinite(lo)) and bool(jnp.isfinite(hi))
    assert abs(float(lo) - float(hi)) > 1e-6


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_param_count_analytic_matches_tree(arch):
    """cfg.param_count() (used for MODEL_FLOPS) vs the actual parameter tree."""
    cfg = get_config(arch)
    m = build_model(cfg)
    tree = m.abstract_params()
    actual = sum(np.prod(l.shape) for l in jax.tree.leaves(tree))
    expected = cfg.param_count()
    assert abs(actual - expected) / expected < 0.05, (actual, expected)


def test_vlm_loss_ignores_image_positions():
    cfg = get_config("internvl2-26b").reduced()
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    b = m.make_batch(TRAIN_SHAPE)
    l1 = jax.jit(m.loss)(params, b)
    assert bool(jnp.isfinite(l1))


def test_long_500k_applicability():
    """The documented skip matrix: ssm/hybrid run long_500k, full-attention don't."""
    long = SHAPES["long_500k"]
    runnable = {a for a in ALL_ARCHS if shape_applicable(get_config(a), long)[0]}
    assert runnable == {"mamba2-2.7b", "zamba2-7b"}


def test_ssd_chunked_matches_reference():
    from repro.models.mamba2 import ssd_chunked, ssd_reference
    rng = np.random.RandomState(0)
    b, s, h, p, g, n = 2, 32, 4, 8, 1, 16
    x = jnp.array(rng.randn(b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jnp.array(rng.randn(b, s, h), jnp.float32))
    A = -jnp.exp(jnp.array(rng.randn(h), jnp.float32))
    B = jnp.array(rng.randn(b, s, g, n), jnp.float32)
    C = jnp.array(rng.randn(b, s, g, n), jnp.float32)
    y_ref, f_ref = ssd_reference(x, dt, A, B, C)
    for chunk in (8, 16, 32):
        y, f = ssd_chunked(x, dt, A, B, C, chunk)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-4)
        np.testing.assert_allclose(np.asarray(f), np.asarray(f_ref), atol=1e-4)


def test_blockwise_equals_naive_attention():
    from repro.models.layers import blockwise_attention, naive_attention
    rng = np.random.RandomState(0)
    q = jnp.array(rng.randn(2, 128, 4, 32), jnp.float32)
    k = jnp.array(rng.randn(2, 128, 4, 32), jnp.float32)
    v = jnp.array(rng.randn(2, 128, 4, 32), jnp.float32)
    np.testing.assert_allclose(
        np.asarray(blockwise_attention(q, k, v, q_block=32)),
        np.asarray(naive_attention(q, k, v)), atol=1e-5, rtol=1e-5)


def test_gqa_repeat_semantics():
    """GQA with K=H must equal MHA; K<H groups share kv."""
    from repro.models.layers import attention
    rng = np.random.RandomState(0)
    q = jnp.array(rng.randn(1, 32, 4, 16), jnp.float32)
    k4 = jnp.array(rng.randn(1, 32, 4, 16), jnp.float32)
    v4 = jnp.array(rng.randn(1, 32, 4, 16), jnp.float32)
    out = attention(q, k4, v4, impl="naive")
    # grouped: take 2 kv heads, repeat manually
    k2, v2 = k4[:, :, :2], v4[:, :, :2]
    out_g = attention(q, k2, v2, impl="naive")
    manual_k = jnp.repeat(k2, 2, axis=2)
    manual_v = jnp.repeat(v2, 2, axis=2)
    out_m = attention(q, manual_k, manual_v, impl="naive")
    np.testing.assert_allclose(np.asarray(out_g), np.asarray(out_m), atol=1e-6)
    assert not np.allclose(np.asarray(out_g), np.asarray(out))
