"""Expert-parallel MoE step: planned alltoall dispatch/combine through the
StepProgram IR — payload accounting, the at-scale sweep oracles, and the live
multi-device step (jaxpr + plan-stats + executed-path asserts)."""
import pytest

from repro.core import program as prg
from repro.core import scenarios as sc
from repro.core.topology import make_paper_systems

from .helpers import run_devices


# ------------------------------------------------------------ payload math
def test_expert_dims_rejects_dense_config():
    from repro.configs.base import get_config
    from repro.runtime.moe_step import expert_dims

    with pytest.raises(ValueError, match="not a MoE config"):
        expert_dims(get_config("smollm-135m"))


def test_dispatch_bytes_is_the_table_key():
    """The sweep, the oracle, and the runtime must consult the plan with the
    same number: one (E, b*C, D) fp32 buffer."""
    from repro.configs.base import get_config
    from repro.models.moe import _capacity
    from repro.runtime.moe_step import dispatch_bytes

    cfg = get_config("deepseek-moe-16b").reduced()
    b, S = 2, 16
    C = _capacity(S, cfg)
    assert dispatch_bytes(cfg, b, S) == cfg.n_experts * b * C * cfg.d_model * 4


# ------------------------------------------------------------- sweep oracles
@pytest.mark.parametrize("system", sc.PAPER_SYSTEMS)
def test_check_moe_shapes(system):
    shapes = sc.check_moe_shapes(system)
    bad = [k for k, v in shapes.items() if not v]
    assert not bad, (system, shapes)


def test_moe_sweep_forces_pairwise_at_scale():
    """Obs. 7 through the sweep: every point beyond 512 endpoints (or across a
    group boundary) dispatches the bounded-state pairwise schedule."""
    pts = sc.sweep_moe_alltoall("alps")
    assert pts[-1].n_endpoints == 4096
    assert all(p.algo == "pairwise" for p in pts if p.n_endpoints > 512)
    assert all(p.algo == "pairwise" for p in pts if p.tier == "diff_group")
    assert all(p.step_comm_s >= 4.0 * p.exchange_s * (1 - 1e-9) for p in pts)


def test_moe_expert_placement_confines_to_group():
    topo = make_paper_systems()["alps"]
    group, replicas = sc.moe_expert_placement(topo, 4096)
    assert group * replicas == 4096
    assert replicas > 1, "4096 endpoints span dragonfly groups: must replicate"
    assert topo.tier_for_scale(group) != "diff_group"
    # small jobs fit in one group: no replication
    g8, r8 = sc.moe_expert_placement(topo, 8)
    assert (g8, r8) == (8, 1)
    # confined sweep never leaves the group tier
    conf = sc.sweep_moe_alltoall("alps", confine=True)
    assert all(p.tier != "diff_group" for p in conf)
    assert all(p.ep_group * p.n_replicas == n
               for p, n in zip(conf, sc.DEFAULT_ENDPOINTS))


def test_moe_program_shape():
    p = prg.moe_step_program()
    roles = [nd.role for nd in p.nodes if nd.kind == "all_to_all"]
    assert roles == ["dispatch", "combine"]
    assert p.has("all_reduce") and p.schedule == "moe_alltoall"
    assert prg.moe_step_program(compress_bits=8).name == "moe_alltoall_int8"


# ------------------------------------------------------- runtime (multi-dev)
MOE_STEP = r"""
import dataclasses
import jax, jax.numpy as jnp, numpy as np
import repro.compat
from jax.sharding import AxisType
from repro.configs import get_config
from repro.core import scenarios as sc
from repro.core.autotune import CollectivePolicy
from repro.optim import adamw
from repro.runtime import moe_step as ms
from repro.runtime import steps as rsteps

# the shared walker (analysis.trace) replaced this file's hand-rolled copy
from repro.analysis import expected_trace, lint_trace, prims_of, trace_jaxpr

cfg = get_config("deepseek-moe-16b").reduced()
mesh = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
opt = adamw.OptConfig(peak_lr=1e-3, warmup_steps=1, decay_steps=20)
params = ms.moe_ep_params(cfg, jax.random.PRNGKey(0))
batch = ms.moe_ep_batch(cfg, jax.random.PRNGKey(1), 8, 16)
ostate = adamw.init_opt_state(params)

# --- planned alltoall in the jaxpr + per-algo plan stats (default: xla) ---
policy = CollectivePolicy.from_model()
plan = policy._as_plan()
plan.reset_stats()
step = ms.build_moe_ep_step(cfg, opt, mesh, policy=policy)
err = step.init_error_state(params)
jx = jax.make_jaxpr(lambda p, o, b, e: step(p, o, b, e))(
    params, ostate, batch, err)
prims = prims_of(jx)
assert "all_to_all" in prims, prims
assert plan.stats.get("all_to_all_calls") == 2, plan.stats
assert plan.stats.get("all_to_all_algo/xla") == 2, plan.stats
assert plan.stats.get("all_reduce_calls", 0) >= 1, plan.stats
# CommLint: the traced MoE step stays inside its program's collective set
# (dispatch + combine, plus the vjp's transposed exchanges)
tr = trace_jaxpr(jx)
assert len(tr.of_kind("all_to_all")) >= 2, tr.counts()
fs = lint_trace(tr, expected_trace(step.program, n_devices=4, plan=policy))
assert not fs, [str(f) for f in fs]
print("ok jaxpr xla", sorted(k for k in plan.stats))

# --- group boundary forces pairwise: ppermute rotations, no fused alltoall ---
plan_pw = dataclasses.replace(plan, tiers={4: "diff_group"})
plan_pw.reset_stats()
pol_pw = CollectivePolicy.from_plan(plan_pw)
step_pw = ms.build_moe_ep_step(cfg, opt, mesh, policy=pol_pw)
jx_pw = jax.make_jaxpr(lambda p, o, b, e: step_pw(p, o, b, e))(
    params, ostate, batch, err)
prims_pw = prims_of(jx_pw)
assert "ppermute" in prims_pw, prims_pw
assert "all_to_all" not in prims_pw, prims_pw
assert plan_pw.stats.get("all_to_all_algo/pairwise") == 2, plan_pw.stats
# pairwise lowers to ppermute rotations — still within the program's set
fs_pw = lint_trace(trace_jaxpr(jx_pw),
                   expected_trace(step_pw.program, n_devices=4, plan=pol_pw))
assert not fs_pw, [str(f) for f in fs_pw]
print("ok jaxpr pairwise")

# --- numerics: loss decreases, and n=4 matches n=1 (same global batch) ---
p1, o1, m1, _ = step(params, ostate, batch, err)
p2, o2, m2, _ = step(p1, o1, batch, err)
assert float(m2["loss"]) < float(m1["loss"]), (m1["loss"], m2["loss"])
assert np.isfinite(float(m1["aux_loss"]))
mesh1 = jax.make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
step1 = ms.build_moe_ep_step(cfg, opt, mesh1, policy=CollectivePolicy.from_model())
q1, _, n1, _ = step1(params, ostate, batch, err)
assert abs(float(n1["loss"]) - float(m1["loss"])) < 1e-5
d = max(float(np.max(np.abs(np.asarray(jax.device_get(a), np.float32)
                            - np.asarray(jax.device_get(b), np.float32))))
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(q1)))
assert d < 1e-5, d
print("ok numerics", float(m1["loss"]), "->", float(m2["loss"]), "d:", d)

# --- the program-first entry point routes AllToAll programs to this step ---
routed = rsteps.build_program_step(cfg, opt, mesh, ms.prg.moe_step_program(),
                                   axis="data",
                                   policy=CollectivePolicy.from_model())
rp, _, rm, _ = routed(params, ostate, batch, err)
assert abs(float(rm["loss"]) - float(m1["loss"])) < 1e-6
assert routed.program.name == "moe_alltoall"
assert step.program.schedule == "moe_alltoall"
print("ok routing")

# --- executed path matches the sweep's table ranking (satellite oracle) ---
out = sc.moe_executed_path_oracle(cfg, mesh)
assert out["match"], out
print("ok oracle", out)

# --- expert count must divide the EP axis ---
try:
    ms.build_moe_ep_step(dataclasses.replace(cfg, n_experts=6), opt, mesh)
except ValueError as e:
    assert "divide" in str(e)
else:
    raise AssertionError("n_experts=6 over 4 devices must be rejected")
print("ALL_OK")
"""


@pytest.mark.slow
def test_moe_ep_step_live():
    assert "ALL_OK" in run_devices(MOE_STEP, 4, timeout=560)
