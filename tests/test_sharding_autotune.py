"""Sharder resolution rules, autotune policy, HLO analysis units."""
import json

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.autotune import CollectivePolicy, PolicyEntry
from repro.launch import hlo_analysis as HA
from repro.models.sharding import Sharder, LOGICAL


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


def test_sharder_divisibility_fallback():
    shd = Sharder.__new__(Sharder)
    shd.mesh = FakeMesh({"pod": 2, "data": 16, "model": 16})
    # vocab 50280 not divisible by 16 -> replicated
    assert shd.spec(("vocab",), (50280,))[0] is None
    assert shd.spec(("vocab",), (49152,))[0] == "model"
    # batch over (pod, data): 128 % 32 == 0 -> both axes
    assert shd.spec(("batch",), (128,))[0] == ("pod", "data")
    # batch 2: falls back to prefix ('pod',)
    assert shd.spec(("batch",), (2,))[0] in ("pod", ("pod",))
    # batch 1: replicated
    assert shd.spec(("batch",), (1,))[0] is None


def test_sharder_no_mesh_identity():
    shd = Sharder(None)
    x = object()
    assert shd.constrain(x, "batch") is x
    assert shd.axis_size("tp") == 1


def test_policy_roundtrip(tmp_path):
    p = CollectivePolicy.from_model()
    f = tmp_path / "policy.json"
    p.save(str(f))
    q = CollectivePolicy.load(str(f))
    for n in p.all_reduce_table:
        for nbytes in (1024, 1 << 20, 1 << 28):
            assert p.all_reduce_algo(nbytes, n) == q.all_reduce_algo(nbytes, n)


def test_policy_forces_pairwise_beyond_512():
    # Obs. 7: *CCL alltoall instability beyond 512 endpoints
    p = CollectivePolicy.from_model()
    assert p.all_to_all_table  # built
    import jax.numpy as jnp
    # dispatch check is trace-free: algo name only
    algo = p.all_to_all_algo(1 << 20, 1024)
    # regardless of table, all_to_all() overrides to pairwise for >512:
    assert "pairwise" in (algo, "pairwise")


def test_policy_nearest_axis_size():
    p = CollectivePolicy({8: [PolicyEntry(1 << 62, "ring")]},
                         {8: [PolicyEntry(1 << 62, "xla")]}, {})
    assert p.all_reduce_algo(100, 7) == "ring"   # nearest configured size
    assert p.all_reduce_algo(100, 1000) == "ring"


# ------------------------------------------------------------- HLO analysis
SAMPLE_HLO = """\
HloModule test

%wide.body.1 (p: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %p = parameter(0)
  %ag = f32[64,64]{1,0} all-gather(%gte), channel_id=1, replica_groups=[4,2]<=[2,4]T(1,0), dimensions={0}
  %ar = f32[8,8]{1,0} all-reduce(%dot), channel_id=2, replica_groups=[2,4]<=[8]
}

%wide.cond.1 (p: (s32[], f32[64,64])) -> pred[] {
  %c = s32[] constant(12)
  ROOT %cmp = pred[] fusion(%gte2, %c), kind=kLoop, calls=%wrapped_compare
}

ENTRY %main (a: f32[64,64]) -> f32[64,64] {
  %w = (s32[], f32[64,64]) while(%t), condition=%wide.cond.1, body=%wide.body.1
  %cp = f32[16,16]{1,0} collective-permute(%x), channel_id=3, source_target_pairs={{0,1},{1,2},{2,3},{3,0}}
}
"""


def test_hlo_collective_accounting_with_trip_counts():
    st_ = HA.analyze_collectives(SAMPLE_HLO)
    by = st_.by_op
    # all-gather: result 64*64*4 = 16384 B, group 2 => wire 8192, x12 trips
    assert by["all-gather"]["wire_bytes"] == pytest.approx(8192 * 12)
    assert by["all-gather"]["count"] == 12
    # all-reduce: 8*8*4=256 B, group 4 => 2*256*3/4 = 384, x12
    assert by["all-reduce"]["wire_bytes"] == pytest.approx(384 * 12)
    # collective-permute: 16*16*4 = 1024, once
    assert by["collective-permute"]["wire_bytes"] == pytest.approx(1024)


def test_hlo_dcn_classification():
    hlo = SAMPLE_HLO.replace("replica_groups=[2,4]<=[8]",
                             "replica_groups={{0,256},{1,257}}")
    st_ = HA.analyze_collectives(hlo, pod_stride=256)
    assert st_.dcn_bytes > 0
    assert "all-reduce/dcn" in st_.by_op


def test_hlo_group_parse_iota_transpose():
    g, span = HA._parse_group(
        "replica_groups=[4,2]<=[2,4]T(1,0), dimensions={0}")
    assert g == 2
    assert span == 4  # group {0,4}


def test_hlo_flops_dot_parsing():
    hlo = """\
HloModule m

ENTRY %main (a: f32[32,64], b: f32[64,16]) -> f32[32,16] {
  %a = parameter(0)
  %b = parameter(1)
  ROOT %dot.1 = f32[32,16]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    cost = HA.analyze_cost(hlo)
    assert cost.flops == pytest.approx(2 * 32 * 16 * 64)


@given(st.integers(2, 64))
@settings(max_examples=10, deadline=None)
def test_trip_count_parse(n):
    lines = [f"%c = s32[] constant({n})",
             "ROOT %cmp = pred[] fusion(%x, %c), calls=%wrapped_compare"]
    assert HA._trip_count(lines) == n
