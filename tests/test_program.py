"""StepProgram IR: construction, validation, JSON round-trip, plan/policy
persistence, program-vs-schedule pricing parity, and the bit-parity matrix of
program-built vs legacy flag-built steps."""
import dataclasses
import json

import pytest

from repro.core import program as prg
from repro.core.commplan import CommPlan
from repro.core.costmodel import exposed_comm_time, make_comm_model
from repro.core.scenarios import synthetic_grad_sizes
from repro.core.topology import make_paper_systems, make_tpu_multipod, make_tpu_pod

from .helpers import run_devices


# ------------------------------------------------------------ construction
def test_named_programs_validate_and_roundtrip():
    for name in sorted(prg.NAMED_PROGRAMS):
        p = prg.named_program(name)
        assert p.validate() is p
        back = prg.StepProgram.from_dict(json.loads(json.dumps(p.to_dict())))
        assert back == p, name
    with pytest.raises(ValueError, match="unknown program"):
        prg.named_program("ring")


def test_schedule_strings():
    assert prg.train_step_program().schedule == "allreduce"
    assert prg.train_step_program(zero=True).schedule == "zero"
    assert prg.moe_step_program().schedule == "moe_alltoall"


def test_train_program_mirrors_engine_defaulting():
    """The flag->node defaulting the engine used is now pinned in the builder:
    compress-only stays per-tensor (no Bucketize node), everything else
    buckets at the plan crossover."""
    assert not prg.train_step_program(compress_bits=8).has("bucketize")
    assert prg.train_step_program().has("bucketize")
    assert prg.train_step_program(overlap=True, compress_bits=8).has("bucketize")
    assert not prg.train_step_program(bucket_bytes=0).has("bucketize")
    bz = prg.train_step_program(overlap=True, bucket_bytes=1 << 20).node("bucketize")
    assert bz.reverse and bz.bucket_bytes == 1 << 20
    assert prg.train_step_program(zero=True).schedule == "zero"


def test_step_kwargs_roundtrip():
    """train_step_program(**p.step_kwargs()) rebuilds the same program — the
    lowering the runtime shim relies on."""
    cases = [
        dict(),
        dict(bucket_bytes=0),
        dict(compress_bits=8),
        dict(overlap=True),
        dict(overlap=True, compress_bits=8, bucket_bytes=1 << 20),
        dict(overlap=True, microbatches=4, chunks=2),
        dict(zero=True),
        dict(zero=True, compress_bits=8),
    ]
    for case in cases:
        p = prg.train_step_program(**case)
        assert prg.train_step_program(**p.step_kwargs()) == p, case


# -------------------------------------------------------------- validation
def test_validate_rejections():
    with pytest.raises(ValueError, match="bits"):
        prg.StepProgram("p", (prg.QuantizeWire(4), prg.AllReduce())).validate()
    with pytest.raises(ValueError, match="overlap schedule"):
        prg.StepProgram("p", (prg.MicrobatchLoop(2), prg.AllReduce())).validate()
    with pytest.raises(ValueError, match="per-tensor"):
        prg.StepProgram("p", (prg.Bucketize(0, reverse=True),
                              prg.AllReduce())).validate()
    with pytest.raises(ValueError, match="ZeRO"):
        prg.StepProgram("p", (prg.Bucketize(), prg.ShardedOptimUpdate())).validate()
    with pytest.raises(ValueError, match="ZeRO"):
        prg.StepProgram("p", (prg.ReduceScatter(), prg.AllGather())).validate()
    with pytest.raises(ValueError, match="dispatch"):
        prg.StepProgram("p", (prg.AllToAll("dispatch"),
                              prg.AllReduce())).validate()
    with pytest.raises(ValueError, match="router"):
        prg.StepProgram("p", (prg.AllToAll("dispatch"),
                              prg.AllToAll("combine"))).validate()
    with pytest.raises(ValueError, match="reduction"):
        prg.StepProgram("p", (prg.Bucketize(),)).validate()
    with pytest.raises(ValueError, match="unknown"):
        prg.StepProgram.from_dict({"name": "p", "nodes": [{"kind": "warp"}]})


# -------------------------------------------------------- plan persistence
def test_commplan_carries_default_program():
    plan = CommPlan.from_topology(make_tpu_pod())
    p = plan.step_program()
    assert p is not None and p.has("all_reduce")
    blob = plan.to_blob()
    assert blob["program"] == p.to_dict()
    assert CommPlan.from_blob(blob).step_program() == p


def test_policy_program_roundtrip(tmp_path):
    """Programs persist in the policy JSON: save -> load returns the same
    StepProgram object value (satellite: one artifact for all consumers)."""
    from repro.core.autotune import CollectivePolicy

    pol = CollectivePolicy.from_model(make_comm_model("leonardo"))
    pol.set_program(prg.named_program("zero_int8"))
    path = tmp_path / "policy.json"
    pol.save(str(path))
    loaded = CollectivePolicy.load(str(path))
    assert loaded.program == prg.named_program("zero_int8")
    # legacy table-only policies stay program-less
    legacy = CollectivePolicy({2: []}, {2: []}, {"source": "measured"})
    assert legacy.program is None


# ---------------------------------------------------------------- pricing
def test_program_pricing_matches_schedule_shim():
    """One IR, two consumers: pricing a program must equal the legacy
    schedule-string branch it replaced, for both dense schedules, on flat and
    hierarchical plans."""
    sizes = synthetic_grad_sizes(64 << 20)
    for topo, n in ((make_tpu_pod(), 8), (make_tpu_multipod(), 512)):
        plan = CommPlan.from_topology(topo)
        for schedule, program in [
            ("allreduce", prg.train_step_program()),
            ("zero", prg.train_step_program(zero=True)),
        ]:
            a = exposed_comm_time(0.01, plan, sizes, n_endpoints=n,
                                  schedule=schedule)
            b = exposed_comm_time(0.01, plan, sizes, n_endpoints=n,
                                  program=program)
            assert a == b, (schedule, n)


def test_program_pricing_node_overrides():
    """Program nodes carry the knobs: an explicit Bucketize size overrides the
    plan's crossover, and QuantizeWire implies the int8 wire."""
    plan = CommPlan.from_topology(make_paper_systems()["leonardo"])
    sizes = synthetic_grad_sizes(64 << 20)
    base = exposed_comm_time(0.01, plan, sizes, n_endpoints=512)
    p8 = prg.train_step_program(compress_bits=8, bucket_bytes=1 << 20)
    est8 = exposed_comm_time(0.01, plan, sizes, n_endpoints=512, program=p8)
    # QuantizeWire implies the lossy intra wire; Bucketize(1 MiB) repacks the
    # 64 MiB gradient into 64 buckets instead of the plan's crossover
    assert est8.wire == "int8/fp32" and base.wire == "fp32/fp32"
    assert est8.n_buckets == 64 and base.n_buckets != est8.n_buckets
    with pytest.raises(ValueError, match="schedule"):
        exposed_comm_time(0.01, plan, sizes, n_endpoints=8, schedule="ring")


def test_moe_program_priced_finite_at_scale():
    plan = CommPlan.from_topology(make_paper_systems()["alps"])
    est = exposed_comm_time(0.0, plan, [4 << 20, 4 << 20, 1 << 20],
                            n_endpoints=4096, model=make_comm_model("alps"),
                            program=prg.moe_step_program())
    assert est.schedule == "moe_alltoall"
    assert 0.0 < est.total_comm_s < float("inf")
    assert est.exposed_s == est.total_comm_s  # token exchanges gate the forward


# ----------------------------------------------------- launcher resolution
def test_resolve_step_program_flags():
    """The consolidated launcher resolution: implications, error messages, and
    the XLA path returning no program."""
    import argparse

    from repro.launch.train import resolve_step_program

    def ns(**kw):
        base = dict(explicit_dp=False, overlap=False, zero=False,
                    compress_bits="0", chunks=None, microbatches=1,
                    bucket_bytes=None)
        base.update(kw)
        return argparse.Namespace(**base)

    assert resolve_step_program(ns(), None, None) == (None, None)
    with pytest.raises(SystemExit, match="multiple devices"):
        resolve_step_program(ns(overlap=True), None, None)
    with pytest.raises(SystemExit, match="want 0, 8, or auto"):
        resolve_step_program(ns(compress_bits="bf16"), None, None)
    with pytest.raises(SystemExit, match="needs --explicit-dp"):
        resolve_step_program(ns(compress_bits="8"), None, None)


# ----------------------------------------------- bit-parity matrix (multi-dev)
PARITY = r"""
import jax, jax.numpy as jnp, numpy as np
import repro.compat
from jax.sharding import AxisType
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core import program as prg
from repro.models import build_model
from repro.optim import adamw
from repro.runtime import steps as rsteps

from repro.analysis import expected_trace, lint_trace, trace_jaxpr

cfg = get_config("smollm-135m").reduced()
shape = ShapeConfig("t", 32, 8, "train")
mesh = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
mesh2 = jax.make_mesh((2, 2), ("pod", "data"), axis_types=(AxisType.Auto,)*2)
model = build_model(cfg)
opt = adamw.OptConfig(peak_lr=1e-3, warmup_steps=1, decay_steps=20)
params = model.init(jax.random.PRNGKey(0))
batch = model.make_batch(shape)

CASES = [
    (dict(), None),
    (dict(bucket_bytes=0), None),
    (dict(compress_bits=8), None),
    (dict(overlap=True, bucket_bytes=1 << 20), None),
    (dict(overlap=True, compress_bits=8, bucket_bytes=1 << 20), None),
    (dict(overlap=True, microbatches=2, bucket_bytes=1 << 20), None),
    (dict(zero=True, bucket_bytes=1 << 20), None),
    (dict(zero=True, compress_bits=8, bucket_bytes=1 << 20), None),
    (dict(overlap=True, chunks=2, bucket_bytes=1 << 20), "pod"),
]

for flags, dcn in CASES:
    m = mesh2 if dcn else mesh
    legacy = rsteps.build_explicit_dp_step(model, opt, m, "data",
                                           dcn_axis=dcn, **flags)
    program = prg.train_step_program(**flags)
    built = rsteps.build_program_step(model, opt, m, program, axis="data",
                                      dcn_axis=dcn)
    assert built.program == program and legacy.program == program, flags
    outs = []
    for step in (legacy, built):
        if getattr(step, "zero", False):
            ostate = step.init_opt_state(params)
        else:
            ostate = adamw.init_opt_state(params)
        err = step.init_error_state(params)
        # CommLint: both builds honour the shared program's collective contract
        jx = jax.make_jaxpr(lambda p, o, b, e: step(p, o, b, e))(
            params, ostate, batch, err)
        tr = trace_jaxpr(jx, donate_argnums=getattr(step, "donate_argnums", ()))
        fs = lint_trace(tr, expected_trace(program, n_devices=4, dcn_axis=dcn))
        assert not fs, (flags, [str(f) for f in fs])
        p2, _, metrics, _ = step(params, ostate, batch, err)
        outs.append((jax.device_get(p2), float(metrics["loss"])))
    (pa, la), (pb, lb) = outs
    assert la == lb, (flags, la, lb)
    la_, lb_ = jax.tree.leaves(pa), jax.tree.leaves(pb)
    if flags.get("compress_bits", 0) == 0:
        ok = all(np.array_equal(np.asarray(a), np.asarray(b))
                 for a, b in zip(la_, lb_))
        assert ok, ("fp32 wire must be bit-identical", flags)
    else:
        d = max(float(np.max(np.abs(np.asarray(a, np.float32)
                                    - np.asarray(b, np.float32))))
                for a, b in zip(la_, lb_))
        assert d < 5e-2, (flags, d)
    print("parity ok", flags, "dcn" if dcn else "flat")
print("ALL_OK")
"""


@pytest.mark.slow
def test_program_vs_flag_step_parity_matrix():
    """Program-built and legacy flag-built steps are the same step: bit-equal
    params on the fp32 wire across (overlap x zero x compress x chunks), and
    within codec tolerance at int8."""
    assert "ALL_OK" in run_devices(PARITY, 4, timeout=560)
