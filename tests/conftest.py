"""Test-suite bootstrap.

Two jobs:
  * make `repro` importable without external PYTHONPATH plumbing (the tier-1
    command sets PYTHONPATH=src, but IDEs / CI matrices may not);
  * provide a deterministic stand-in for `hypothesis` when it isn't installed
    (this container has no network access, and the property tests only use
    `given` / `settings` / `strategies.{integers,floats,sampled_from}`).
    The stub sweeps boundary values first, then a seeded random sample — not a
    shrinker, but it keeps the property tests meaningful and reproducible.
"""
from __future__ import annotations

import random
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

try:
    import hypothesis  # noqa: F401
except ImportError:
    import types

    class _Strategy:
        def __init__(self, examples_fn):
            self._examples_fn = examples_fn

        def examples(self, rng, k):
            return self._examples_fn(rng, k)

    def integers(min_value, max_value):
        def gen(rng, k):
            bounds = [min_value, max_value]
            rest = [rng.randint(min_value, max_value) for _ in range(max(k - 2, 0))]
            return (bounds + rest)[:k]
        return _Strategy(gen)

    def floats(min_value, max_value):
        def gen(rng, k):
            bounds = [float(min_value), float(max_value)]
            rest = [rng.uniform(min_value, max_value) for _ in range(max(k - 2, 0))]
            return (bounds + rest)[:k]
        return _Strategy(gen)

    def sampled_from(seq):
        seq = list(seq)

        def gen(rng, k):
            out = list(seq)[:k]
            while len(out) < k:
                out.append(rng.choice(seq))
            return out
        return _Strategy(gen)

    def settings(max_examples=10, **_kw):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn
        return deco

    def given(*arg_strats, **kw_strats):
        def deco(fn):
            def wrapper(*args, **kwargs):
                k = getattr(fn, "_stub_max_examples", 10)
                rng = random.Random(0)
                cols = [s.examples(rng, k) for s in arg_strats]
                kw_cols = {name: s.examples(rng, k) for name, s in kw_strats.items()}
                for i in range(k):
                    vals = [c[i] for c in cols]
                    kws = {name: c[i] for name, c in kw_cols.items()}
                    fn(*args, *vals, **kwargs, **kws)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco

    stub = types.ModuleType("hypothesis")
    stub.given = given
    stub.settings = settings
    strategies = types.ModuleType("hypothesis.strategies")
    strategies.integers = integers
    strategies.floats = floats
    strategies.sampled_from = sampled_from
    stub.strategies = strategies
    sys.modules["hypothesis"] = stub
    sys.modules["hypothesis.strategies"] = strategies
