"""Per-kernel allclose vs ref.py oracles: shape/dtype sweeps + hypothesis."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

RNG = np.random.RandomState(0)


def _attn_ref_4d(q, k, v, causal=True):
    b, s, h, hd = q.shape
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    out = ref.attention_ref(fold(q), fold(k), fold(v), causal=causal)
    return out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)


@pytest.mark.parametrize("b,s,h,hd", [(2, 256, 4, 64), (1, 128, 2, 128),
                                      (2, 512, 3, 64), (1, 64, 1, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, s, h, hd, dtype):
    q = jnp.array(RNG.randn(b, s, h, hd), dtype)
    k = jnp.array(RNG.randn(b, s, h, hd), dtype)
    v = jnp.array(RNG.randn(b, s, h, hd), dtype)
    out = ops.flash_attention(q, k, v, q_block=min(128, s), kv_block=min(128, s))
    want = _attn_ref_4d(q, k, v)
    tol = 5e-6 if dtype == jnp.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("qb,kb", [(64, 32), (128, 256), (32, 32)])
def test_flash_attention_block_shapes(qb, kb):
    b, s, h, hd = 1, 256, 2, 64
    q = jnp.array(RNG.randn(b, s, h, hd), jnp.float32)
    k = jnp.array(RNG.randn(b, s, h, hd), jnp.float32)
    v = jnp.array(RNG.randn(b, s, h, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, q_block=qb, kv_block=kb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(_attn_ref_4d(q, k, v)),
                               atol=1e-5, rtol=1e-5)


def test_flash_attention_non_causal():
    b, s, h, hd = 1, 128, 2, 64
    q = jnp.array(RNG.randn(b, s, h, hd), jnp.float32)
    k = jnp.array(RNG.randn(b, s, h, hd), jnp.float32)
    v = jnp.array(RNG.randn(b, s, h, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=False)
    want = _attn_ref_4d(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5, rtol=1e-5)


@given(st.integers(1, 3), st.sampled_from([64, 128]), st.sampled_from([1, 2]),
       st.sampled_from([32, 64]))
@settings(max_examples=8, deadline=None)
def test_flash_attention_property(b, s, h, hd):
    rng = np.random.RandomState(b * 1000 + s + h + hd)
    q = jnp.array(rng.randn(b, s, h, hd), jnp.float32)
    k = jnp.array(rng.randn(b, s, h, hd), jnp.float32)
    v = jnp.array(rng.randn(b, s, h, hd), jnp.float32)
    out = ops.flash_attention(q, k, v, q_block=32, kv_block=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(_attn_ref_4d(q, k, v)),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_softmax_invariance():
    """Property: shifting all logits by a constant (scaling q) changes nothing
    about the *uniform-value* case; softmax rows sum to one => output within the
    convex hull of v rows."""
    b, s, h, hd = 1, 128, 1, 64
    q = jnp.array(RNG.randn(b, s, h, hd), jnp.float32)
    k = jnp.array(RNG.randn(b, s, h, hd), jnp.float32)
    v = jnp.ones((b, s, h, hd), jnp.float32) * 3.5
    out = ops.flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out), 3.5, atol=1e-5)


@pytest.mark.parametrize("r,d", [(8, 128), (64, 576), (128, 2048), (5, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_sweep(r, d, dtype):
    x = jnp.array(RNG.randn(r, d), dtype)
    sc = jnp.array(RNG.randn(d), dtype)
    out = ops.rmsnorm(x, sc)
    want = ref.rmsnorm_ref(x, sc)
    np.testing.assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32),
                               atol=1e-5 if dtype == jnp.float32 else 2e-2)


def test_rmsnorm_3d():
    x = jnp.array(RNG.randn(2, 7, 96), jnp.float32)
    sc = jnp.array(RNG.randn(96), jnp.float32)
    np.testing.assert_allclose(np.asarray(ops.rmsnorm(x, sc)),
                               np.asarray(ref.rmsnorm_ref(x, sc)), atol=1e-5)


def _ssd_oracle(x, dt, A, B, C, chunk):
    b, s = x.shape[0], x.shape[1]
    ys = []
    for bi in range(b):
        h0 = jnp.zeros((x.shape[2], x.shape[3], B.shape[-1]), jnp.float32)
        outs = []
        for c in range(s // chunk):
            sl = slice(c * chunk, (c + 1) * chunk)
            yc, h0 = ref.ssd_chunk_ref(x[bi, sl], dt[bi, sl], A, B[bi, sl], C[bi, sl], h0)
            outs.append(yc)
        ys.append(jnp.concatenate(outs, 0))
    return jnp.stack(ys)


@pytest.mark.parametrize("b,s,h,p,n,chunk", [
    (2, 64, 4, 8, 16, 16), (1, 128, 2, 16, 8, 32), (1, 32, 8, 4, 4, 8),
])
def test_ssd_scan_sweep(b, s, h, p, n, chunk):
    rng = np.random.RandomState(7)
    x = jnp.array(rng.randn(b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jnp.array(rng.randn(b, s, h), jnp.float32))
    A = -jnp.exp(jnp.array(rng.randn(h), jnp.float32))
    B = jnp.array(rng.randn(b, s, n), jnp.float32)
    C = jnp.array(rng.randn(b, s, n), jnp.float32)
    out = ops.ssd_scan(x, dt, A, B, C, chunk=chunk)
    want = _ssd_oracle(x, dt, A, B, C, chunk)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-4, rtol=2e-3)


def test_ssd_kernel_matches_model_chunked():
    """Kernel vs models/mamba2.ssd_chunked (two independent implementations)."""
    from repro.models.mamba2 import ssd_chunked
    rng = np.random.RandomState(3)
    b, s, h, p, n, chunk = 2, 64, 4, 8, 16, 16
    x = jnp.array(rng.randn(b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jnp.array(rng.randn(b, s, h), jnp.float32))
    A = -jnp.exp(jnp.array(rng.randn(h), jnp.float32))
    B = jnp.array(rng.randn(b, s, 1, n), jnp.float32)
    C = jnp.array(rng.randn(b, s, 1, n), jnp.float32)
    out_kernel = ops.ssd_scan(x, dt, A, B, C, chunk=chunk)
    out_model, _ = ssd_chunked(x, dt, A, B, C, chunk)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_model),
                               atol=2e-4, rtol=2e-3)
