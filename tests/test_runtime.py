"""Runtime: optimizer math, train loop, checkpoint/restart, data, compression."""
import dataclasses
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.data import SyntheticLM, DataConfig, PrefetchIterator
from repro.optim import adamw
from repro.runtime.train import Trainer, TrainConfig
from repro.runtime.serve import BatchedServer, ServeConfig

SHAPE = ShapeConfig("t", 64, 4, "train")


def test_adamw_single_step_math():
    """One AdamW step vs hand-computed reference."""
    cfg = adamw.OptConfig(peak_lr=0.1, min_lr=0.1, warmup_steps=0, decay_steps=1,
                          b1=0.9, b2=0.99, eps=1e-8, weight_decay=0.0,
                          clip_norm=1e9)
    p = {"w": jnp.array([1.0, 2.0], jnp.float32)}
    g = {"w": jnp.array([0.5, -0.5], jnp.float32)}
    st = adamw.init_opt_state(p)
    new_p, new_st, _ = adamw.apply_updates(p, g, st, cfg)
    m = 0.1 * np.array([0.5, -0.5])
    v = 0.01 * np.array([0.25, 0.25])
    mhat = m / (1 - 0.9)
    vhat = v / (1 - 0.99)
    want = np.array([1.0, 2.0]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)
    assert int(new_st["step"]) == 1


def test_grad_clip_scales_update():
    cfg = adamw.OptConfig(clip_norm=0.1, warmup_steps=0, weight_decay=0.0)
    p = {"w": jnp.ones((4,), jnp.float32)}
    g = {"w": jnp.full((4,), 100.0, jnp.float32)}
    st = adamw.init_opt_state(p)
    _, _, metrics = adamw.apply_updates(p, g, st, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(200.0, rel=1e-3)


def test_schedule_warmup_and_decay():
    cfg = adamw.OptConfig(peak_lr=1.0, min_lr=0.1, warmup_steps=10, decay_steps=100)
    assert float(adamw.schedule(jnp.array(5), cfg)) == pytest.approx(0.5)
    assert float(adamw.schedule(jnp.array(10), cfg)) == pytest.approx(1.0)
    assert float(adamw.schedule(jnp.array(100), cfg)) == pytest.approx(0.1)


def test_loss_decreases_on_tiny_model(tmp_path):
    cfg = get_config("smollm-135m").reduced()
    # overfit one repeated batch => loss must fall
    class OneBatch(SyntheticLM):
        def batch_at(self, step):
            return super().batch_at(0)
    tr = Trainer(cfg, SHAPE, adamw.OptConfig(peak_lr=3e-3, warmup_steps=2, decay_steps=50),
                 TrainConfig(steps=12, ckpt_every=0, ckpt_dir=str(tmp_path), log_every=100),
                 data=OneBatch(cfg, SHAPE))
    res = tr.run()
    losses = [m["loss"] for m in res["metrics"]]
    assert losses[-1] < losses[0] - 0.2


def test_checkpoint_roundtrip(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6, dtype=jnp.bfloat16).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.float32)}}
    cm.save(10, tree, extra={"step": 10})
    got, extra = cm.restore(tree)
    assert extra["step"] == 10
    np.testing.assert_array_equal(np.asarray(got["a"], np.float32),
                                  np.asarray(tree["a"], np.float32))
    assert got["a"].dtype == jnp.bfloat16


def test_checkpoint_retention_and_latest(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        cm.save(s, tree)
    assert cm.latest_step() == 4
    steps = sorted(int(p.name.split("_")[1]) for p in tmp_path.glob("step_*"))
    assert steps == [3, 4]


def test_checkpoint_async_and_atomicity(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    tree = {"a": jnp.ones((64, 64))}
    cm.save(1, tree, blocking=False)
    cm.wait()
    assert cm.latest_step() == 1
    # a stale tmp dir must be ignored
    (tmp_path / "step_9.tmp").mkdir()
    assert cm.latest_step() == 1


def test_train_restart_replays_determinism(tmp_path):
    """Fault tolerance: run 8 steps straight vs 4 + crash + resume: same loss."""
    cfg = get_config("smollm-135m").reduced()
    opt = adamw.OptConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=50)
    t1 = Trainer(cfg, SHAPE, opt, TrainConfig(steps=8, ckpt_every=100,
                 ckpt_dir=str(tmp_path / "a"), log_every=100, ckpt_async=False))
    r1 = t1.run()
    t2 = Trainer(cfg, SHAPE, opt, TrainConfig(steps=8, ckpt_every=4,
                 ckpt_dir=str(tmp_path / "b"), log_every=100, ckpt_async=False))
    r2 = t2.run(inject_failure_at=6)   # crash at 6 -> restore from 4 -> replay
    l1 = {m["step"]: m["loss"] for m in r1["metrics"]}
    l2 = {m["step"]: m["loss"] for m in r2["metrics"]}
    for s in (6, 7):
        assert l2[s] == pytest.approx(l1[s], rel=1e-5), f"step {s} diverged after restart"


def test_data_determinism_and_host_slicing():
    cfg = get_config("smollm-135m").reduced()
    d1 = SyntheticLM(cfg, SHAPE, DataConfig(seed=7))
    d2 = SyntheticLM(cfg, SHAPE, DataConfig(seed=7))
    np.testing.assert_array_equal(d1.batch_at(5)["tokens"], d2.batch_at(5)["tokens"])
    assert not np.array_equal(d1.batch_at(5)["tokens"], d1.batch_at(6)["tokens"])
    h0 = SyntheticLM(cfg, SHAPE, DataConfig(seed=7, host_index=0, host_count=2))
    h1 = SyntheticLM(cfg, SHAPE, DataConfig(seed=7, host_index=1, host_count=2))
    full = d1.batch_at(3)["tokens"]
    np.testing.assert_array_equal(np.concatenate([h0.batch_at(3)["tokens"],
                                                  h1.batch_at(3)["tokens"]]), full)


def test_prefetch_iterator():
    cfg = get_config("smollm-135m").reduced()
    src = SyntheticLM(cfg, SHAPE)
    it = PrefetchIterator(src, start_step=2)
    s, b = next(it)
    assert s == 2
    np.testing.assert_array_equal(b["tokens"], src.batch_at(2)["tokens"])
    it.close()


def test_serve_greedy_deterministic():
    cfg = get_config("smollm-135m").reduced()
    srv = BatchedServer(cfg, max_seq=48, batch_size=2)
    prompts = np.random.RandomState(0).randint(0, cfg.vocab, (2, 8)).astype(np.int32)
    a = srv.generate(prompts, ServeConfig(max_new_tokens=4))
    b = srv.generate(prompts, ServeConfig(max_new_tokens=4))
    np.testing.assert_array_equal(a, b)
    assert a.shape == (2, 4)


def test_microbatch_indivisible_raises_named_error():
    """An indivisible microbatch split must name the batch size and count
    instead of surfacing an opaque reshape error."""
    from repro.runtime import steps as rsteps

    batch = {"tokens": np.zeros((10, 4), np.int32)}
    with pytest.raises(ValueError, match=r"10.*microbatches=3"):
        rsteps._microbatch(batch, 3)
    # divisible split unchanged
    out = rsteps._microbatch(batch, 2)
    assert out["tokens"].shape == (2, 5, 4)


def test_explicit_dp_jit_cache_keyed_on_tree_structure():
    """The jitted shard_map step must not reuse the first call's specs for a
    call with a different pytree structure (stale-spec regression)."""
    import jax
    import repro.compat  # noqa: F401  (AxisType shim)
    from jax.sharding import AxisType
    from repro.runtime import steps as rsteps

    class ToyModel:
        @staticmethod
        def loss(params, batch):
            s = sum(jnp.sum(p) for p in jax.tree.leaves(params))
            return (s - 1.0) ** 2 + 0.0 * jnp.mean(batch["x"])

    mesh = jax.make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
    opt = adamw.OptConfig(peak_lr=1e-2, warmup_steps=0, decay_steps=10)
    step = rsteps.build_explicit_dp_step(ToyModel(), opt, mesh, "data")

    p1 = {"w": jnp.ones((4,), jnp.float32)}
    b1 = {"x": jnp.ones((2,), jnp.float32)}
    out1 = step(p1, adamw.init_opt_state(p1), b1, rsteps.init_error_state(p1))
    assert np.isfinite(float(out1[2]["loss"]))
    assert len(step._cache) == 1

    # a different params structure must get fresh shard_map specs
    p2 = {"w": jnp.ones((4,), jnp.float32), "v": jnp.ones((3,), jnp.float32)}
    out2 = step(p2, adamw.init_opt_state(p2), b2 := {"x": jnp.ones((2,), jnp.float32)},
                rsteps.init_error_state(p2))
    assert np.isfinite(float(out2[2]["loss"]))
    assert set(out2[0]) == {"w", "v"}
    assert len(step._cache) == 2

    # repeat calls reuse the cached jit (no per-step retrace)
    step(p1, adamw.init_opt_state(p1), b1, rsteps.init_error_state(p1))
    assert len(step._cache) == 2


def test_gradient_compression_error_feedback():
    """int8 error-feedback quantization: accumulated error stays bounded and the
    running sum of dequantized grads tracks the true sum (convergence guarantee)."""
    rng = np.random.RandomState(0)
    true_sum = np.zeros(256, np.float32)
    deq_sum = np.zeros(256, np.float32)
    err = np.zeros(256, np.float32)
    for _ in range(200):
        g = rng.randn(256).astype(np.float32) * 0.01
        true_sum += g
        gq = g + err
        scale = max(np.abs(gq).max(), 1e-12) / 127.0
        q = np.clip(np.round(gq / scale), -127, 127)
        deq = q * scale
        err = gq - deq
        deq_sum += deq
    assert np.abs(deq_sum - true_sum).max() < 1e-3


def test_checkpoint_shard_spec_metadata_roundtrip(tmp_path):
    """ZeRO carrier-sharded leaves round-trip when save and restore agree on
    the shard spec, and every sharded<->replicated cross-restore fails loudly
    before any leaf is loaded."""
    spec = {"opt/m": "zero-carrier:data", "opt/v": "zero-carrier:data"}
    tree = {"opt": {"m": jnp.arange(8, dtype=jnp.float32).reshape(2, 4),
                    "v": jnp.ones((2, 4), jnp.float32)}}
    cm = CheckpointManager(str(tmp_path / "z"))
    cm.save(3, tree, extra={"step": 3}, specs=spec)
    got, extra = cm.restore(tree, specs=spec)
    assert extra["step"] == 3
    np.testing.assert_array_equal(np.asarray(got["opt"]["m"]),
                                  np.asarray(tree["opt"]["m"]))
    # sharded checkpoint -> replicated restore target
    with pytest.raises(ValueError, match="replicated trainer"):
        cm.restore(tree)
    # replicated checkpoint -> sharded restore target
    cm2 = CheckpointManager(str(tmp_path / "r"))
    cm2.save(3, tree, extra={"step": 3})
    with pytest.raises(ValueError, match="replicated checkpoint"):
        cm2.restore(tree, specs=spec)
    # both sharded, but under different carrier layouts
    other = {k: "zero-carrier:data,pod" for k in spec}
    with pytest.raises(ValueError, match="match exactly"):
        cm.restore(tree, specs=other)


def test_recovery_bounded_retry_exhaustion(tmp_path):
    """A fault that keeps firing exhausts max_retries and surfaces as a named
    persistent failure (the old loop retried forever)."""
    cfg = get_config("smollm-135m").reduced()
    tr = Trainer(cfg, SHAPE, adamw.OptConfig(),
                 TrainConfig(steps=8, ckpt_every=2, ckpt_async=False,
                             ckpt_dir=str(tmp_path), log_every=100,
                             max_retries=3, retry_backoff_s=0.0))
    with pytest.raises(RuntimeError, match="persistent failure"):
        tr.run(inject_failure_at=[4] * 10)
    assert [r["attempt"] for r in tr.retry_log] == [1, 2, 3, 4]


def test_recovery_repeated_transient_fault_completes(tmp_path):
    """Two distinct firings of the same fault step (a re-failure after the
    replay) both recover within the retry budget; the old cleared-before-raise
    bug made a repeated entry unreachable."""
    cfg = get_config("smollm-135m").reduced()
    tr = Trainer(cfg, SHAPE, adamw.OptConfig(),
                 TrainConfig(steps=8, ckpt_every=2, ckpt_async=False,
                             ckpt_dir=str(tmp_path), log_every=100,
                             max_retries=3, retry_backoff_s=0.0))
    res = tr.run(inject_failure_at=[4, 4])
    assert res["final_step"] == 8
    assert res["retries"] == 2


def test_recovery_without_checkpoint_surfaces_fault(tmp_path):
    """restored is None: nothing to restore into, the transient fault must
    propagate instead of looping on an unrecoverable state."""
    cfg = get_config("smollm-135m").reduced()
    tr = Trainer(cfg, SHAPE, adamw.OptConfig(),
                 TrainConfig(steps=8, ckpt_every=0, ckpt_async=False,
                             ckpt_dir=str(tmp_path), log_every=100))
    with pytest.raises(RuntimeError, match="injected device failure"):
        tr.run(inject_failure_at=2)
    assert tr.retry_log == []


def test_recovery_fatal_error_propagates_immediately(tmp_path):
    """A RuntimeError that does not look like a fabric/device fault is a bug:
    no restore, no retry (the old catch-all swallowed it)."""
    cfg = get_config("smollm-135m").reduced()
    tr = Trainer(cfg, SHAPE, adamw.OptConfig(),
                 TrainConfig(steps=8, ckpt_every=2, ckpt_async=False,
                             ckpt_dir=str(tmp_path), log_every=100))
    orig = tr.step_fn

    def buggy(params, opt_state, batch):
        if int(opt_state["step"]) == 4:
            raise RuntimeError("loss scaler misconfigured (a genuine bug)")
        return orig(params, opt_state, batch)

    tr.step_fn = buggy
    with pytest.raises(RuntimeError, match="genuine bug"):
        tr.run()
    assert tr.retry_log == []


def test_straggler_skip_reverts_step(tmp_path):
    """'skip' drops the straggler step's update: the run records the skips
    and the final state is reachable without them (loss stays finite)."""
    import repro.compat  # noqa: F401
    from jax.sharding import AxisType
    from repro.core.faults import FaultEvent, FaultPlan

    cfg = get_config("smollm-135m").reduced()
    mesh = jax.make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
    plan = FaultPlan(events=(FaultEvent(step=7, kind="straggler", severity=6.0),
                             FaultEvent(step=9, kind="straggler", severity=6.0)))
    tr = Trainer(cfg, SHAPE, adamw.OptConfig(),
                 TrainConfig(steps=12, ckpt_every=0, ckpt_async=False,
                             ckpt_dir=str(tmp_path), log_every=100,
                             explicit_dp=True, bucket_bytes=1 << 16,
                             straggler_threshold=2.0, straggler_action="skip",
                             faults=plan),
                 mesh=mesh)
    res = tr.run()
    assert res["final_step"] == 12
    # the two injected episodes must be caught, and every detected straggler
    # (injected or wall-clock) skipped — on CPU real timing jitter can add one
    assert res["straggler_events"] >= 2
    assert res["skipped_steps"] == res["straggler_events"]
    skipped = {m["step"] for m in res["metrics"] if m["straggler"]}
    assert {7, 9} <= skipped
    assert all(np.isfinite(m["loss"]) for m in res["metrics"])


def test_straggler_skip_rejected_under_zero(tmp_path):
    cfg = get_config("smollm-135m").reduced()
    with pytest.raises(ValueError, match="unsound with zero"):
        Trainer(cfg, SHAPE, adamw.OptConfig(),
                TrainConfig(steps=1, ckpt_dir=str(tmp_path), zero=True,
                            explicit_dp=True, straggler_action="skip"))


def test_mid_run_plan_swap_bit_parity(tmp_path):
    """_swap_policy on the fp32 wire is numerically transparent: checkpoint at
    6, swap the policy, resume to 12 — bitwise the same losses as an
    uninterrupted 12-step run."""
    import repro.compat  # noqa: F401
    from jax.sharding import AxisType
    from repro.core.autotune import CollectivePolicy

    cfg = get_config("smollm-135m").reduced()
    opt = adamw.OptConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=50)

    def make(ckpt_dir, steps):
        mesh = jax.make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
        return Trainer(cfg, SHAPE, opt,
                       TrainConfig(steps=steps, ckpt_every=6, ckpt_async=False,
                                   ckpt_dir=str(ckpt_dir), log_every=100,
                                   explicit_dp=True, bucket_bytes=1 << 16),
                       mesh=mesh)

    straight = make(tmp_path / "a", 12).run()
    tr = make(tmp_path / "b", 6)
    tr.run()
    tr._swap_policy(CollectivePolicy.from_model())   # what a replan commits
    tr.cfg.steps = 12
    tr.run(resume=True)
    l1 = {m["step"]: m["loss"] for m in straight["metrics"]}
    l2 = {m["step"]: m["loss"] for m in tr.metrics_log}
    for s in range(6, 12):
        assert l2[s] == l1[s], f"step {s}: {l2[s]} != {l1[s]} (bitwise)"


def test_trainer_zero_requires_explicit_dp():
    cfg = get_config("smollm-135m").reduced()
    with pytest.raises(ValueError, match="explicit-DP"):
        Trainer(cfg, SHAPE, adamw.OptConfig(),
                TrainConfig(steps=1, ckpt_every=0, zero=True))


def test_trainer_zero_save_restore_and_cross_mode(tmp_path):
    """End-to-end ZeRO trainer: carrier-shaped opt state, checkpoint carries
    the shard spec, resume replays deterministically, and restoring across
    zero<->replicated trainer modes raises instead of misreading m/v."""
    import repro.compat  # noqa: F401
    from jax.sharding import AxisType

    cfg = get_config("smollm-135m").reduced()
    opt = adamw.OptConfig(peak_lr=1e-3, warmup_steps=2, decay_steps=50)
    mesh = jax.make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))

    def make(ckpt_dir, steps, **kw):
        return Trainer(cfg, SHAPE, opt,
                       TrainConfig(steps=steps, ckpt_every=4,
                                   ckpt_dir=str(ckpt_dir), log_every=100,
                                   ckpt_async=False, explicit_dp=True,
                                   bucket_bytes=1 << 16, **kw),
                       mesh=mesh)

    r1 = make(tmp_path / "a", 8, zero=True).run()
    assert all(np.isfinite(m["loss"]) for m in r1["metrics"])
    # the opt state the trainer built is the carrier, not per-leaf moments
    t2 = make(tmp_path / "a", 8, zero=True)
    _, opt_state = t2.init_state()
    assert set(opt_state) == {"m", "v", "step"} and opt_state["m"].ndim == 2
    # resume from step 8's checkpoint and replay nothing (already done)
    r2 = t2.run(resume=True)
    assert r2["final_step"] == 8
    # crash/resume replay determinism through the sharded checkpoint
    t3 = make(tmp_path / "c", 8, zero=True)
    r3 = t3.run(inject_failure_at=6)
    l1 = {m["step"]: m["loss"] for m in r1["metrics"]}
    l3 = {m["step"]: m["loss"] for m in r3["metrics"]}
    assert l3[7] == pytest.approx(l1[7], rel=1e-5)
    # a replicated explicit-DP trainer must refuse the ZeRO checkpoint
    with pytest.raises(ValueError, match="replicated trainer"):
        make(tmp_path / "a", 8).restore()
    # and the ZeRO trainer must refuse a replicated checkpoint
    make(tmp_path / "r", 4).run()
    with pytest.raises(ValueError, match="replicated checkpoint"):
        make(tmp_path / "r", 4, zero=True).restore()
