"""FaultGuard: fault plans, the injector, the drift guard, degradation sweeps.

Unit layers run in-process; the live multi-device paths (drift-triggered
mid-run re-plan, node-loss elastic re-mesh) run in subprocesses with forced
host device counts (tests/helpers.py).
"""
import dataclasses

import numpy as np
import pytest

from repro.core.faults import (FaultEvent, FaultInjector, FaultPlan,
                               NodeLossFault, TransientFault)
from repro.runtime.guard import DriftGuard, GuardConfig

from .helpers import run_devices


# ---------------------------------------------------------------- fault plans
def test_fault_event_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(step=0, kind="gremlin")
    with pytest.raises(ValueError, match="timing"):
        FaultEvent(step=-1, kind="straggler")
    with pytest.raises(ValueError, match="timing"):
        FaultEvent(step=0, kind="straggler", duration=0)
    with pytest.raises(ValueError, match="severity"):
        FaultEvent(step=0, kind="straggler", severity=0.0)


def test_fault_event_windowing():
    win = FaultEvent(step=4, kind="link_degrade", duration=3)
    assert [s for s in range(10) if win.active_at(s)] == [4, 5, 6]
    pt = FaultEvent(step=4, kind="transient_fail")
    assert [s for s in range(10) if pt.active_at(s)] == [4]


def test_fault_plan_roundtrip_and_determinism(tmp_path):
    plan = FaultPlan.messy_fabric(seed=3, steps=24)
    # seeded builder is deterministic, and distinct across seeds
    assert plan == FaultPlan.messy_fabric(seed=3, steps=24)
    assert plan != FaultPlan.messy_fabric(seed=4, steps=24)
    # events come back sorted regardless of input order
    shuffled = FaultPlan(events=tuple(reversed(plan.events)), seed=3,
                         comm_fraction=plan.comm_fraction)
    assert shuffled == plan
    # JSON round-trip through dict and through disk
    assert FaultPlan.from_dict(plan.to_dict()) == plan
    path = tmp_path / "plan.json"
    plan.save(str(path))
    assert FaultPlan.load(str(path)) == plan
    with pytest.raises(ValueError, match="version"):
        FaultPlan.from_dict({"version": 99})


def test_fault_plan_resolve(tmp_path):
    assert FaultPlan.resolve("messy:5").seed == 5
    kinds = {e.kind for e in FaultPlan.resolve("nodeloss", steps=24).events}
    assert "node_loss" in kinds
    assert "node_loss" not in {e.kind for e in
                               FaultPlan.resolve("messy", steps=24).events}
    path = tmp_path / "p.json"
    FaultPlan.messy_fabric(seed=9).save(str(path))
    assert FaultPlan.resolve(str(path)).seed == 9
    with pytest.raises(ValueError, match="not a file and not a builtin"):
        FaultPlan.resolve("no_such_thing")


# ------------------------------------------------------------------ injector
def test_injector_deterministic_and_windowed():
    plan = FaultPlan(events=(
        FaultEvent(step=4, kind="link_degrade", duration=4, severity=3.0),
        FaultEvent(step=6, kind="latency_spike", duration=2, severity=3.0),
        FaultEvent(step=9, kind="straggler", severity=2.5),
    ), seed=7, comm_fraction=0.5)
    a, b = FaultInjector(plan), FaultInjector(plan)
    for step in range(12):
        assert a.slowdown(step) == b.slowdown(step)  # bit-identical replay
    assert a.slowdown(0) == 1.0                      # clean before any event
    assert a.slowdown(4) > 1.0                       # inside the window
    assert a.slowdown(8) == 1.0 or a.slowdown(8) > 1.0
    assert a.slowdown(20) == 1.0                     # clean after it
    # the latency spike compounds on top of the degrade where they overlap
    assert a.slowdown(6) > a.slowdown(5)
    # straggler factor is separate from the fabric factor
    fabric, straggler = a.factors(9)
    assert straggler == pytest.approx(2.5) and fabric == 1.0


def test_injector_mitigation_scales_fabric_not_straggler():
    plan = FaultPlan(events=(
        FaultEvent(step=0, kind="link_degrade", duration=4, severity=4.0),
        FaultEvent(step=2, kind="straggler", severity=3.0),
    ), comm_fraction=0.5)
    inj = FaultInjector(plan)
    before_fabric = inj.perturb(0, 1.0)
    before_both = inj.perturb(2, 1.0)
    inj.on_replan(recovered=0.6)
    # fabric excess shrinks by exactly the recovered fraction...
    assert inj.perturb(0, 1.0) == pytest.approx(1.0 + (before_fabric - 1.0) * 0.4)
    # ...while the straggler multiplier is untouched (a slow device is not a
    # routing problem)
    fabric, straggler = inj.factors(2)
    assert straggler == pytest.approx(3.0)
    assert inj.perturb(2, 1.0) < before_both
    # full recovery floors the fabric factor at 1
    inj.on_replan(recovered=1.0)
    assert inj.perturb(0, 1.0) == pytest.approx(1.0)


def test_injector_point_faults_fire_once():
    plan = FaultPlan(events=(FaultEvent(step=3, kind="transient_fail"),
                             FaultEvent(step=5, kind="node_loss", device=2)))
    inj = FaultInjector(plan)
    inj.before_step(0)
    with pytest.raises(TransientFault, match="step 3"):
        inj.before_step(3)
    inj.before_step(3)  # replayed step after restore: already fired
    with pytest.raises(NodeLossFault) as ei:
        inj.before_step(5)
    assert ei.value.lost == (2,)
    inj.before_step(5)
    assert [r["kind"] for r in inj.log] == ["transient_fail", "node_loss"]


# --------------------------------------------------------------- drift guard
def test_guard_in_band_stays_quiet():
    g = DriftGuard(GuardConfig(band=0.3, patience=2), reference_s=1.0)
    for step in range(20):
        assert g.observe(step, 1.0 + 0.1 * (step % 3)) is None
    assert g.report()["n_events"] == 0


def test_guard_self_calibrates_from_warmup_median():
    g = DriftGuard(GuardConfig(warmup=3))
    # compile-heavy first step must not inflate the reference
    for step, dt in enumerate((9.0, 1.0, 1.1)):
        g.observe(step, dt)
    assert g.reference == pytest.approx(1.1)


def test_guard_sustained_drift_replans_once_then_cools_down():
    calls = []

    def replanner(step):
        calls.append(step)
        return True, {"swapped": True}

    g = DriftGuard(GuardConfig(band=0.2, ewma=1.0, patience=3, cooldown=100,
                               warmup=1), reference_s=1.0, replanner=replanner)
    g.observe(0, 1.0)
    events = [g.observe(s, 2.0) for s in range(1, 12)]
    replans = [e for e in events if e is not None and e.kind == "replan"]
    assert len(replans) == 1 and calls == [replans[0].step]
    assert g.n_replans == 1
    # committed swap re-seeded the reference from the next warmup window:
    # the post-swap step time (2.0) is the new normal, so no further events
    assert g.reference == pytest.approx(2.0)
    assert [e for e in events if e is not None] == replans


def test_guard_rejected_swap_keeps_old_plan():
    g = DriftGuard(GuardConfig(band=0.2, ewma=1.0, patience=2, cooldown=3,
                               warmup=1),
                   reference_s=1.0,
                   replanner=lambda step: (False, {"lint": {"findings": ["x"]}}))
    events = [g.observe(s, 3.0) for s in range(10)]
    rejected = [e for e in events if e is not None and e.kind == "replan_rejected"]
    assert rejected and g.n_replans == 0
    assert g.reference == 1.0          # no rebaseline on a rejected swap
    rep = g.report()
    assert rep["n_rejected"] == len(rejected)
    assert rep["events"][0]["detail"]["lint"]["findings"] == ["x"]


def test_guard_without_replanner_emits_drift():
    g = DriftGuard(GuardConfig(band=0.2, ewma=1.0, patience=2, cooldown=1,
                               warmup=1), reference_s=1.0)
    events = [g.observe(s, 3.0) for s in range(4)]
    kinds = [e.kind for e in events if e is not None]
    assert kinds and set(kinds) == {"drift"}


def test_guard_max_replans_cap():
    g = DriftGuard(GuardConfig(band=0.2, ewma=1.0, patience=1, cooldown=1,
                               warmup=1, max_replans=1),
                   reference_s=1.0, replanner=lambda s: (True, {}))
    g.observe(0, 3.0)          # replan #1; reference re-seeds
    assert g.n_replans == 1
    for s in range(1, 8):
        g.observe(s, 3.0)      # warmup re-seed absorbs 3.0 as the new normal
    g.reference = 1.0          # force drift again against a clean reference
    events = [g.observe(s, 3.0) for s in range(8, 12)]
    assert g.n_replans == 1    # capped
    drifts = [e for e in events if e is not None]
    assert drifts and drifts[0].detail["suppressed"] == "max_replans"


# ------------------------------------------------------- degradation pricing
def test_degradation_oracles_all_pass():
    from repro.core.scenarios import check_degradation_shapes

    for system in ("leonardo", "alps"):
        oracles = check_degradation_shapes(system, endpoints=(8, 64, 1024))
        assert all(oracles.values()), (system, oracles)


def test_degradation_rejects_unknown_scenario():
    from repro.core.scenarios import sweep_degradation

    with pytest.raises(ValueError, match="unknown scenario"):
        sweep_degradation("leonardo", "solar_flare")


# ------------------------------------------------------------- live runtime
def test_guard_replan_live_multidevice():
    """Acceptance: under the canonical messy plan the guarded trainer commits
    a lint-clean mid-run re-plan and ends with strictly fewer straggler-
    exposed steps than the oblivious trainer on the same seeded fabric."""
    out = run_devices("""
import repro.compat  # noqa: F401
import tempfile
import jax
from jax.sharding import AxisType
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.faults import FaultPlan
from repro.runtime.guard import GuardConfig
from repro.runtime.train import Trainer, TrainConfig

cfg = get_config("smollm-135m").reduced()
shape = ShapeConfig("t", 64, 4, "train")

def run(guard):
    mesh = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
    tc = TrainConfig(steps=24, ckpt_every=8, ckpt_async=False,
                     ckpt_dir=tempfile.mkdtemp(), log_every=100,
                     explicit_dp=True, bucket_bytes=1 << 16,
                     straggler_threshold=2.0,
                     faults=FaultPlan.messy_fabric(seed=0, steps=24),
                     guard=guard,
                     guard_cfg=GuardConfig(patience=3, cooldown=6, lint=True,
                                           max_replans=2))
    return Trainer(cfg, shape, train_cfg=tc, mesh=mesh).run()

obl = run(False)
grd = run(True)
g = grd["guard"]
replans = [e for e in g["events"] if e["kind"] == "replan"]
assert g["n_replans"] >= 1, g
for e in replans:
    lint = e["detail"].get("lint", {})
    assert lint, e                       # the swap went through the lint gate
    assert not lint["findings"], e
    assert e["detail"].get("swapped"), e
    assert e["detail"]["probe"]["records"] > 0, e
assert grd["straggler_events"] < obl["straggler_events"], (
    grd["straggler_events"], obl["straggler_events"])
print("REPLAN_OK", g["n_replans"], grd["straggler_events"],
      obl["straggler_events"])
""", n_devices=8)
    assert "REPLAN_OK" in out


def test_node_loss_elastic_remesh_live():
    """A node-loss fault mid-run rebuilds the mesh on the survivors (DP
    degree shrinks to the largest batch divisor) and finishes from the last
    checkpoint."""
    out = run_devices("""
import repro.compat  # noqa: F401
import tempfile
import jax
from jax.sharding import AxisType
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.faults import FaultEvent, FaultPlan
from repro.runtime.train import Trainer, TrainConfig

cfg = get_config("smollm-135m").reduced()
shape = ShapeConfig("t", 64, 4, "train")
mesh = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
plan = FaultPlan(events=(FaultEvent(step=6, kind="node_loss", device=1),))
tc = TrainConfig(steps=10, ckpt_every=4, ckpt_async=False,
                 ckpt_dir=tempfile.mkdtemp(), log_every=100,
                 explicit_dp=True, bucket_bytes=1 << 16,
                 straggler_threshold=50.0, faults=plan)
res = Trainer(cfg, shape, train_cfg=tc, mesh=mesh).run()
assert res["final_step"] == 10, res["final_step"]
assert res["final_devices"] == 2, res["final_devices"]   # 3 survivors -> dp 2
assert [r["kind"] for r in res["fault_log"]] == ["node_loss"]
print("REMESH_OK", res["final_devices"])
""", n_devices=4)
    assert "REMESH_OK 2" in out


def test_node_loss_without_checkpoint_or_under_zero():
    """No checkpoint -> the loss surfaces; ZeRO -> the shrink refuses (the
    carrier layout depends on the DP degree)."""
    out = run_devices("""
import repro.compat  # noqa: F401
import tempfile
import jax
from jax.sharding import AxisType
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.core.faults import FaultEvent, FaultPlan, NodeLossFault
from repro.runtime.train import Trainer, TrainConfig

cfg = get_config("smollm-135m").reduced()
shape = ShapeConfig("t", 64, 4, "train")
plan = FaultPlan(events=(FaultEvent(step=2, kind="node_loss", device=1),))

def make(**kw):
    mesh = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
    return Trainer(cfg, shape,
                   train_cfg=TrainConfig(steps=8, ckpt_async=False,
                                         ckpt_dir=tempfile.mkdtemp(),
                                         log_every=100, explicit_dp=True,
                                         bucket_bytes=1 << 16,
                                         straggler_threshold=50.0,
                                         faults=plan, **kw),
                   mesh=mesh)

try:
    make(ckpt_every=0).run()     # nothing to restore into
    raise SystemExit("expected NodeLossFault")
except NodeLossFault:
    pass
try:
    make(ckpt_every=2, zero=True).run()
    raise SystemExit("expected RuntimeError")
except RuntimeError as e:
    assert "zero=True" in str(e), e
print("NODELOSS_GUARDRAILS_OK")
""", n_devices=4)
    assert "NODELOSS_GUARDRAILS_OK" in out
