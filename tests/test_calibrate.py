"""Calibration loop: alpha-beta fitting, artifact round-trip, plan re-ranking."""
import pytest

from repro.core.bench import BenchRecord, IterStats, write_csv
from repro.core.calibrate import (SCHEMA_VERSION, CalibrationProfile, FittedParams,
                                  compare_to_model, fit_alpha_beta, fit_profile,
                                  plan_table_deltas, size_regime)
from repro.core.characterize import congestion_sweep, p2p_pairs
from repro.core.commplan import CommPlan
from repro.core.costmodel import make_comm_model
from repro.core.topology import LinkGraph, make_tpu_pod

from .helpers import run_devices


def _rec(name, mech, pattern, nbytes, t, n=4, expected=None):
    st = IterStats([t * 0.95, t, t * 1.05])
    goodput = nbytes / (t / 2.0) if pattern == "p2p" else nbytes / t
    return BenchRecord(name, mech, pattern, nbytes, n, st, goodput,
                       expected_bytes_s=expected)


def _synthetic_records():
    """Records drawn from known alpha-beta ground truths (p2p stores RTT)."""
    recs = []
    for s in (1 << 10, 1 << 12, 1 << 14, 1 << 20, 1 << 22, 1 << 24):
        recs.append(_rec("pingpong/near_0-1", "device_copy", "p2p", s,
                         2 * (50e-6 + s / 2e9)))
        recs.append(_rec("allreduce/xla", "ccl", "allreduce", s, 120e-6 + s / 1e9))
        recs.append(_rec("allreduce/ring", "mpi", "allreduce", s, 40e-6 + s / 3e9))
        recs.append(_rec("alltoall/xla", "ccl", "alltoall", s, 100e-6 + s / 1.5e9))
        recs.append(_rec("alltoall/pairwise", "mpi", "alltoall", s, 60e-6 + s / 2e9))
    return recs


# ------------------------------------------------------------------- fitting
def test_fit_recovers_ground_truth():
    alpha, bw = 20e-6, 5e9
    fp = fit_alpha_beta([(s, alpha + s / bw) for s in (1 << 10, 1 << 14, 1 << 18)])
    assert fp.alpha == pytest.approx(alpha, rel=1e-6)
    assert fp.bandwidth == pytest.approx(bw, rel=1e-6)
    assert fp.r2 == pytest.approx(1.0)


def test_fit_degenerate_inputs():
    with pytest.raises(ValueError):
        fit_alpha_beta([])
    one = fit_alpha_beta([(4096, 10e-6)])
    assert one.alpha == pytest.approx(10e-6) and one.n_samples == 1
    # non-monotone noise (negative slope): keeps best goodput + fastest time
    noisy = fit_alpha_beta([(1 << 10, 20e-6), (1 << 20, 10e-6)])
    assert noisy.alpha == pytest.approx(10e-6)
    assert noisy.bandwidth == pytest.approx((1 << 20) / 10e-6)


def test_fit_profile_groups_by_mech_pattern_regime():
    prof = fit_profile(_synthetic_records(), system="tpu_v5e", topology="t")
    assert size_regime(64 * 1024) == "small" and size_regime(64 * 1024 + 1) == "large"
    assert set(prof.params) == {
        f"{m}/{p}/{g}" for m, p in (("device_copy", "p2p"), ("ccl", "allreduce"),
                                    ("mpi", "allreduce"), ("ccl", "alltoall"),
                                    ("mpi", "alltoall"))
        for g in ("small", "large")}
    # p2p medians are RTTs: the fit halves them back to one-way alpha
    fp = prof.get("device_copy", "p2p", "small")
    assert fp.alpha == pytest.approx(50e-6, rel=0.05)
    assert prof.get("ccl", "allreduce", "large").bandwidth == pytest.approx(1e9, rel=0.05)
    assert prof.n_endpoints == 4 and prof.version == SCHEMA_VERSION


# --------------------------------------------------------------- persistence
def test_profile_roundtrip_bit_identical(tmp_path):
    prof = fit_profile(_synthetic_records(), system="tpu_v5e", topology="t",
                       meta={"iters": "3"})
    p1 = tmp_path / "calib.json"
    prof.save(str(p1))
    back = CalibrationProfile.load(str(p1))
    assert back == prof
    p2 = tmp_path / "calib2.json"
    back.save(str(p2))
    assert p1.read_bytes() == p2.read_bytes()


def test_profile_rejects_unknown_schema(tmp_path):
    prof = fit_profile(_synthetic_records())
    blob = prof.to_blob()
    blob["schema_version"] = SCHEMA_VERSION + 1
    import json
    f = tmp_path / "bad.json"
    f.write_text(json.dumps(blob))
    with pytest.raises(ValueError, match="unsupported calibration schema"):
        CalibrationProfile.load(str(f))


# ----------------------------------------------------------------- re-ranking
def test_calibrated_plan_reranks_and_is_deterministic():
    prof = fit_profile(_synthetic_records(), system="tpu_v5e", topology="t")
    model = make_comm_model("tpu_v5e")
    topo = model.two_level or model.graph
    analytic = CommPlan.from_topology(topo, profile=model.profile)
    calibrated = CommPlan.from_topology(topo, profile=model.profile,
                                        calibration=prof)
    deltas = plan_table_deltas(analytic, calibrated)
    assert deltas, "measured profile should re-rank at least one table entry"
    assert calibrated.meta["source"] == "commplan+calibration"
    # fit -> save -> load -> identical CommPlan tables
    import json
    back = CalibrationProfile.from_blob(json.loads(json.dumps(prof.to_blob())))
    recal = CommPlan.from_topology(topo, profile=model.profile, calibration=back)
    assert recal.all_reduce_table == calibrated.all_reduce_table
    assert recal.all_to_all_table == calibrated.all_to_all_table
    assert recal.reduce_scatter_table == calibrated.reduce_scatter_table
    assert recal.all_gather_table == calibrated.all_gather_table
    assert recal.bucket_bytes == calibrated.bucket_bytes


def test_calibrated_comm_model_overrides():
    prof = fit_profile(_synthetic_records(), system="tpu_v5e", topology="t")
    plain = make_comm_model("tpu_v5e")
    calib = make_comm_model("tpu_v5e", calibration=prof)
    # measured 50us one-way alpha replaces the 1us analytic constant
    s = 4096.0
    assert calib.p2p(s, "device_copy").seconds > plain.p2p(s, "device_copy").seconds
    assert calib.p2p(s, "device_copy").seconds >= 50e-6
    rows = compare_to_model(prof, plain)
    assert rows and all(r["ratio"] > 0 for r in rows)


def test_policy_calibration_sidecar(tmp_path):
    from repro.core.autotune import CollectivePolicy, calibration_sidecar

    prof = fit_profile(_synthetic_records(), system="tpu_v5e", topology="t")
    pol = CollectivePolicy.from_model(calibration=prof)
    path = tmp_path / "policy.json"
    pol.save(str(path))
    sidecar = calibration_sidecar(str(path))
    assert sidecar.endswith("policy.calibration.json")
    assert (tmp_path / "policy.calibration.json").exists()
    back = CollectivePolicy.load(str(path))
    assert back.calibration == prof
    for n in pol.all_reduce_table:
        for nbytes in (1024, 1 << 20, 1 << 28):
            assert back.all_reduce_algo(nbytes, n) == pol.all_reduce_algo(nbytes, n)
    # policies without a sidecar load with calibration=None (legacy files)
    plain = CollectivePolicy.from_model()
    path2 = tmp_path / "plain.json"
    plain.save(str(path2))
    assert CollectivePolicy.load(str(path2)).calibration is None
    # a corrupt sidecar must not make the (valid) policy file unloadable
    (tmp_path / "policy.calibration.json").write_text("{not json")
    with pytest.warns(UserWarning, match="calibration sidecar"):
        degraded = CollectivePolicy.load(str(path))
    assert degraded.calibration is None
    assert degraded.all_reduce_table == pol.all_reduce_table
    # re-saving without a calibration removes the stale sidecar
    plain.save(str(path))
    assert not (tmp_path / "policy.calibration.json").exists()
    assert CollectivePolicy.load(str(path)).calibration is None


# ------------------------------------------------------------------- scenarios
def test_p2p_pairs_nearest_and_farthest():
    ring = LinkGraph.ring(8, 1.0)
    pairs = p2p_pairs(ring, 8)
    dist = lambda u, v: min((v - u) % 8, (u - v) % 8)
    assert dist(*pairs[0]) == 1        # nearest
    assert dist(*pairs[1]) == 4        # farthest on an 8-ring
    assert p2p_pairs(ring, 1) == []    # n < 2: no self-ping benchmark
    assert len(p2p_pairs(ring, 2)) >= 1
    # graph smaller than the mesh: ring fallback still yields valid pairs
    for a, b in p2p_pairs(LinkGraph.ring(4, 1.0), 8):
        assert 0 <= a < 8 and 0 <= b < 8 and a != b
    # torus: nearest is an adjacent chip, farthest spans the first row
    pairs = p2p_pairs(make_tpu_pod(), 8)
    assert dist(*pairs[0]) == 1


def test_congestion_sweep_through_arbiter():
    base = [_rec("pingpong/near_0-1", "device_copy", "p2p", 1 << 20,
                 2 * (50e-6 + (1 << 20) / 2e9))]
    out = congestion_sweep(base)
    assert {r.name.split("/")[1] for r in out} == {"same_sl", "incast"}
    for r in out:
        assert r.pattern == "p2p_congested"
        assert r.goodput_bytes_s < base[0].goodput_bytes_s   # contention costs
        assert r.expected_bytes_s == base[0].goodput_bytes_s  # clean baseline kept
        # ping-pong RTTs are emitted as one-way times (RTT/2), slowed by the
        # contention factor — always slower than the clean one-way time
        assert r.stats.median > base[0].stats.median / 2
    assert congestion_sweep([]) == []


def test_write_csv_unions_heterogeneous_fieldnames(tmp_path):
    """Regression: fieldnames come from the union of all rows, and an
    expected_bytes_s of exactly 0.0 must not be dropped as falsy."""
    import csv

    r1 = _rec("a", "mpi", "allreduce", 1024, 1e-5)
    r2 = _rec("b", "mpi", "p2p", 1024, 1e-5, expected=0.0)
    row = r2.row()
    assert row["expected_gbps"] == 0.0   # 0.0 expectation is a real value
    # simulate heterogeneous rows (e.g. records from different harness versions)
    r1.row = lambda base=r1: {k: v for k, v in BenchRecord.row(base).items()
                              if k != "expected_gbps"}
    path = tmp_path / "bench.csv"
    write_csv(str(path), [r1, r2])
    with open(path) as f:
        rows = list(csv.DictReader(f))
    assert "expected_gbps" in rows[0]
    assert rows[0]["expected_gbps"] == ""      # restval for the missing field
    assert float(rows[1]["expected_gbps"]) == 0.0


# ------------------------------------------------------------- live (slow)
CALIB_LIVE = r"""
import jax
import repro.compat
from jax.sharding import AxisType
from repro.core.calibrate import CalibrationProfile, plan_table_deltas, run_calibration
from repro.core.commplan import CommPlan
from repro.core.costmodel import make_comm_model

mesh = jax.make_mesh((4,), ("x",), axis_types=(AxisType.Auto,))
model = make_comm_model("tpu_v5e")
profile, records = run_calibration(mesh, "x", sizes=(1 << 10, 1 << 20), iters=3,
                                   model=model)
assert any(k.startswith("device_copy/p2p/") for k in profile.params), profile.params
assert any(k.startswith("device_copy/p2p_concurrent/") for k in profile.params)
assert any(k.startswith("device_copy/p2p_congested/") for k in profile.params)
# sizes split across the mesh: 1 MiB total -> 256 KiB per endpoint = 'large'
assert any(k.endswith("/large") for k in profile.params), profile.params

import os, pathlib, tempfile
d = tempfile.mkdtemp()
p1 = os.path.join(d, "calib.json"); profile.save(p1)
back = CalibrationProfile.load(p1)
p2 = os.path.join(d, "calib2.json"); back.save(p2)
assert pathlib.Path(p1).read_bytes() == pathlib.Path(p2).read_bytes()
assert back == profile

topo = model.two_level or model.graph
analytic = CommPlan.from_topology(topo, profile=model.profile)
calibrated = CommPlan.from_topology(topo, profile=model.profile, calibration=profile)
recal = CommPlan.from_topology(topo, profile=model.profile, calibration=back)
assert calibrated.all_reduce_table == recal.all_reduce_table
assert calibrated.all_to_all_table == recal.all_to_all_table
deltas = plan_table_deltas(analytic, calibrated)
assert deltas, "live calibration did not re-rank any table entry"
print("n_deltas", len(deltas))
print("CALIB_OK")
"""


@pytest.mark.slow
def test_live_calibration_reranks_4dev():
    out = run_devices(CALIB_LIVE, 4, timeout=560)
    assert "CALIB_OK" in out
