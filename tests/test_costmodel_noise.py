"""Cost model + noise model: paper-observation oracles (Obs. 1-8 analogs)."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.costmodel import CommModel, make_comm_model, crossover_bytes
from repro.core.noise import NoiseModel, ServiceLevelArbiter, StragglerMitigator, TrafficClass
from repro.core.hw import SYSTEMS, gbit


@pytest.mark.parametrize("system", ["alps", "leonardo", "lumi", "tpu_v5e"])
def test_p2p_monotone_in_size(system):
    m = make_comm_model(system)
    times = [m.p2p(float(1 << k)).seconds for k in range(8, 28, 2)]
    assert all(b >= a for a, b in zip(times, times[1:]))


def test_staging_order_of_magnitude_slower():
    # Obs. 2 / Fig. 3: trivial staging up to 10x below direct transfers
    m = make_comm_model("leonardo")
    s = float(1 << 26)
    direct = m.p2p(s, "mpi").goodput(s)
    staged = m.p2p(s, "staging").goodput(s)
    assert direct / staged > 3


def test_allreduce_intra_staging_pinned():
    """Regression: the staging mechanism must early-return its
    store-and-forward formula — the algorithm dispatch used to compute a time
    the staging line then silently discarded (and raised on algorithms it
    never ran)."""
    m = make_comm_model("lumi")
    s = float(1 << 20)
    n = m.graph.n
    expected = m._alpha("staging", False) \
        + 2 * n * s / (m.profile.host_staging_bw * 0.9)
    got = m.allreduce_intra(s, "staging")
    assert got.seconds == pytest.approx(expected, rel=1e-12)
    assert got.bytes_on_wire == pytest.approx(2 * s * (n - 1) / n)
    # the algorithm argument is irrelevant to staging — including algorithms
    # the dispatch below would reject
    for algo in ("auto", "ring", "one_shot", "not_an_algorithm"):
        assert m.allreduce_intra(s, "staging", algorithm=algo).seconds \
            == pytest.approx(expected, rel=1e-12)
    # non-staging mechanisms still validate the algorithm name
    with pytest.raises(ValueError):
        m.allreduce_intra(s, "ccl", algorithm="not_an_algorithm")


def test_make_comm_model_memoized():
    """Models (and the topology factories beneath them) are built once per
    (system, calibration identity): the scenario sweeps call them in loops."""
    from repro.core.topology import make_paper_systems

    assert make_comm_model("lumi") is make_comm_model("lumi")
    assert make_comm_model("lumi") is not make_comm_model("alps")
    assert make_paper_systems() is make_paper_systems()

    class FakeCal:
        version = 1
        system = "lumi"
        n_endpoints = 4

        def efficiency(self, *a, **k):
            return None

        def get(self, *a, **k):
            return None

    cal = FakeCal()
    m1 = make_comm_model("lumi", calibration=cal)
    assert m1 is make_comm_model("lumi", calibration=cal)
    assert m1 is not make_comm_model("lumi")
    assert make_comm_model("lumi", calibration=FakeCal()) is not m1


def test_mpi_beats_ccl_small_inter_node():
    # Obs. 5: MPI up to an order of magnitude faster on small inter-node transfers
    m = make_comm_model("lumi")
    small = 512.0
    assert m.p2p(small, "mpi", inter_node=True).seconds < \
        m.p2p(small, "ccl", inter_node=True).seconds


def test_ccl_beats_mpi_large_collectives():
    # Obs. 4/7: *CCL wins large collectives (topology-tuned)
    m = make_comm_model("lumi")
    big = float(1 << 28)
    assert m.allreduce_at_scale(big, 64, "ccl").seconds < \
        m.allreduce_at_scale(big, 64, "mpi").seconds


def test_crossover_exists_on_lumi():
    # Fig. 11: inversion of the RCCL/MPI ratio with size
    x = crossover_bytes(make_comm_model("lumi"), 64)
    assert x is not None and 4 * 1024 <= x <= 64 * 1024 * 1024


def test_crossover_alltoall_op():
    # the op="alltoall" path has its own cost functions; the inversion exists
    # there too, earlier than allreduce's (fewer serialized phases)
    m = make_comm_model("lumi")
    x = crossover_bytes(m, 64, op="alltoall")
    assert x is not None and 1024 <= x <= 16 * 1024 * 1024
    assert x <= crossover_bytes(m, 64, op="allreduce")


def test_crossover_none_when_one_mechanism_dominates():
    m = make_comm_model("lumi")
    # GPU-aware MPI beats host staging at every size: no inversion to find
    assert crossover_bytes(m, 64, "mpi", "staging") is None
    # degenerate: a mechanism never beats itself
    assert crossover_bytes(m, 64, "ccl", "ccl") is None


def test_crossover_within_search_range():
    # returned size is always one of the probed powers of two (64 B .. 2 GiB)
    for op in ("allreduce", "alltoall"):
        x = crossover_bytes(make_comm_model("leonardo"), 64, op=op)
        if x is not None:
            assert 64 <= x <= 2 << 30 and x & (x - 1) == 0


def test_alltoall_asymptote_injection_bw():
    # Sec. V-C: at-scale alltoall goodput -> per-endpoint inter-node bandwidth
    m = make_comm_model("leonardo")
    s = float(2 << 20)
    g = m.alltoall_at_scale(s, 1024, "ccl").goodput(s)
    assert g <= gbit(100)
    assert g >= gbit(100) * 0.3  # bounded below: alpha terms cost ~25% at 2 MiB


def test_distance_latency_ordering():
    m = make_comm_model("leonardo")
    t_sw = m.p2p(1.0, "mpi", True, "same_switch").seconds
    t_gr = m.p2p(1.0, "mpi", True, "same_group").seconds
    t_dg = m.p2p(1.0, "mpi", True, "diff_group").seconds
    assert t_sw < t_gr < t_dg
    # Obs. 6: Leonardo latency ~2x across groups
    assert t_dg / t_sw > 1.8


# ---------------------------------------------------------------- noise (Sec VI)
def test_noise_scaling_matches_obs8():
    nm = NoiseModel.leonardo_diff_group()
    ar = nm.goodput_scaling(1024, 4, "allreduce")
    a2a = nm.goodput_scaling(1024, 4, "alltoall")
    assert 0.35 <= ar <= 0.65          # ~50% drop
    assert 0.75 <= a2a <= 0.9          # ~20% drop
    assert nm.goodput_scaling(4, 4, "allreduce") == 1.0  # intra-node unaffected


def test_isolated_sl_low_variance():
    import numpy as np
    nm = NoiseModel.isolated()
    s = nm.sample_latency(np.random.default_rng(0), 4000)
    assert np.percentile(s, 95) / np.median(s) < 1.1


def test_noisy_sl_heavy_tail():
    import numpy as np
    nm = NoiseModel.leonardo_diff_group()
    s = nm.sample_latency(np.random.default_rng(0), 4000)
    assert np.percentile(s, 95) / np.median(s) > 1.5
    assert s.max() <= nm.max_latency + 1e-9


def test_service_level_isolation_fig12():
    arb = ServiceLevelArbiter(link_bw=25e9, endpoint_bw=12.5e9)
    victim = TrafficClass("allreduce", 0, 10e9)
    same = [TrafficClass("alltoall", 0, 20e9)]
    diff = [TrafficClass("alltoall", 1, 20e9)]
    incast_diff = [TrafficClass("incast", 1, 40e9)]
    g_same = arb.victim_goodput(victim, same)
    g_diff = arb.victim_goodput(victim, diff)
    g_incast = arb.victim_goodput(victim, incast_diff, "incast")
    g_disjoint = arb.victim_goodput(victim, same, shares_switches=False)
    assert g_diff > g_same                      # SL separation helps vs alltoall
    assert g_incast < g_diff                    # ...but NOT vs incast (Fig. 12)
    assert g_disjoint == pytest.approx(10e9)    # disjoint switches: no interference


def test_incast_goodput_invariant_under_sl(sl_count: int = 4):
    """Fig. 12 regression: moving the victim (or the aggressors) to any other
    service level leaves incast goodput unchanged — the congestion lives on
    the destination endpoint link, below the arbitration point."""
    arb = ServiceLevelArbiter(link_bw=25e9, endpoint_bw=12.5e9)
    victim = TrafficClass("allreduce", 0, 10e9)
    base = arb.victim_goodput(victim, [TrafficClass("incast", 0, 40e9)],
                              "incast")
    for sl in range(1, sl_count):
        g = arb.victim_goodput(victim, [TrafficClass("incast", sl, 40e9)],
                               "incast")
        assert g == pytest.approx(base, rel=1e-9), sl
    # cross-check: the same SL move DOES help against a non-incast aggressor
    a2a_same = arb.victim_goodput(victim, [TrafficClass("a", 0, 40e9)])
    a2a_diff = arb.victim_goodput(victim, [TrafficClass("a", 1, 40e9)])
    assert a2a_diff > a2a_same


def test_incast_cap_scales_with_sender_demand():
    """The endpoint-link share shrinks as more senders pile on, and is capped
    by endpoint_bw regardless of the (faster) switch link."""
    arb = ServiceLevelArbiter(link_bw=100e9, endpoint_bw=12.5e9)
    victim = TrafficClass("allreduce", 0, 10e9)
    goodputs = []
    for n_senders in (1, 2, 4, 8):
        aggr = [TrafficClass(f"s{i}", 1, 20e9) for i in range(n_senders)]
        goodputs.append(arb.victim_goodput(victim, aggr, "incast"))
    assert all(b < a for a, b in zip(goodputs, goodputs[1:]))
    assert goodputs[0] <= arb.endpoint_bw
    # closed form: endpoint_bw * demand / (demand + incast_demand)
    assert goodputs[1] == pytest.approx(12.5e9 * 10e9 / (10e9 + 40e9))


def test_straggler_mitigator():
    sm = StragglerMitigator(threshold=1.5, warmup_steps=3)
    times = [1.0] * 6 + [2.5] + [1.0] * 3
    for i, t in enumerate(times):
        sm.observe(i, t)
    assert len(sm.events) == 1 and sm.events[0].step == 6
    # baseline not polluted by the straggler
    assert sm.baseline == pytest.approx(1.0, rel=0.1)


def test_lognormal_mean_matches_base_latency():
    """Regression: base_latency is the *mean* the paper reports (4.23 us,
    Sec. V-B), not the median — mu must be log(base) - sigma^2/2."""
    import numpy as np
    for nm in (NoiseModel.leonardo_diff_group(), NoiseModel.tpu_dcn(),
               NoiseModel.isolated()):
        s = nm.sample_latency(np.random.default_rng(1), 200_000)
        assert abs(s.mean() - nm.base_latency) / nm.base_latency < 0.05


def test_straggler_baseline_seeded_from_warmup_median():
    """Regression: a compile-heavy step 0 must not inflate the baseline and
    mask early stragglers — the seed is the warmup-window median."""
    sm = StragglerMitigator(threshold=2.0, warmup_steps=3)
    times = [10.0, 1.0, 1.0, 1.0, 2.6, 1.0]
    for i, t in enumerate(times):
        sm.observe(i, t)
    assert [e.step for e in sm.events] == [4]
    assert sm.baseline == pytest.approx(1.0, rel=0.2)


@given(st.floats(1e3, 1e9))
@settings(max_examples=20, deadline=None)
def test_allreduce_cost_positive_and_finite(s):
    m = make_comm_model("tpu_v5e")
    c = m.allreduce_at_scale(s, 512, "ccl")
    assert 0 < c.seconds < 1e4
