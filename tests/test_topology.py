"""Topology / expected-goodput models validated against the paper's own numbers."""
import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.topology import LinkGraph, make_paper_node_graphs, make_tpu_pod, make_tpu_multipod
from repro.core.hw import gbit


@pytest.fixture(scope="module")
def graphs():
    return make_paper_node_graphs()


def test_alps_pair_bandwidth(graphs):
    # 6 x 200 Gb/s NVLink4 per pair (Table I)
    assert graphs["alps"].pair_bw(0, 1) == pytest.approx(gbit(1200))


def test_leonardo_pair_bandwidth(graphs):
    assert graphs["leonardo"].pair_bw(0, 1) == pytest.approx(gbit(800))


def test_fully_connected_efi_is_one(graphs):
    # Sec. IV-A: "each link is crossed by only one path"
    assert graphs["alps"].edge_forwarding_index(per_link=False) == 1
    assert graphs["leonardo"].edge_forwarding_index(per_link=False) == 1


def test_lumi_efi_is_four(graphs):
    # Sec. IV-A: most loaded links (1,5)/(3,7) carry four paths
    assert graphs["lumi"].edge_forwarding_index() == pytest.approx(4.0)
    loads = graphs["lumi"].edge_loads_ecmp()
    assert loads[(1, 5)] == pytest.approx(4.0)
    assert loads[(3, 7)] == pytest.approx(4.0)


def test_lumi_pair_goodput_100gbs(graphs):
    assert graphs["lumi"].bottleneck_pair_goodput() == pytest.approx(gbit(100))


def test_alltoall_expected_goodputs(graphs):
    # Alps 3.6 Tb/s, Leonardo 2.4 Tb/s, LUMI 600 Gb/s (Sec. IV-A)
    assert graphs["alps"].alltoall_expected_goodput() == pytest.approx(gbit(3600))
    assert graphs["leonardo"].alltoall_expected_goodput() == pytest.approx(gbit(2400))
    assert graphs["lumi"].alltoall_expected_goodput() == pytest.approx(gbit(600))


def test_allreduce_expected_goodputs(graphs):
    # Alps/Leonardo: pipelined trees => sum of outgoing links; LUMI: 4 rings
    # Rabenseifner => 800 Gb/s (Sec. IV-C)
    assert graphs["alps"].allreduce_expected_goodput() == pytest.approx(gbit(3600))
    assert graphs["leonardo"].allreduce_expected_goodput() == pytest.approx(gbit(2400))
    assert graphs["lumi"].allreduce_expected_goodput() == pytest.approx(gbit(800))


def test_lumi_degree_six_links(graphs):
    # "any GCD can send data on six different IF links simultaneously"
    for u in range(8):
        assert graphs["lumi"].degree_links(u) == 6


def test_tpu_pod_alltoall_matches_bisection_bound():
    pod = make_tpu_pod(16, 16)
    a2a = pod.alltoall_expected_goodput()
    # bisection bound: 4 * bisection / n
    bis = pod.bisection_bw()
    assert a2a == pytest.approx(4 * bis / 256, rel=0.05)


def test_tpu_pod_allreduce_half_injection():
    pod = make_tpu_pod(16, 16)
    # ring allreduce: injection/2 = 4 links * 50 GB/s / 2
    assert pod.allreduce_expected_goodput() == pytest.approx(100e9)


def test_multipod_asymptotic_is_dcn_bound():
    mp = make_tpu_multipod()
    assert mp.alltoall_asymptotic_goodput() == pytest.approx(gbit(25))
    assert mp.allreduce_expected_goodput(512) <= mp.intra.allreduce_expected_goodput()


@given(n=st.integers(3, 10), links=st.integers(1, 4))
@settings(max_examples=15, deadline=None)
def test_fully_connected_efi_property(n, links):
    g = LinkGraph.fully_connected(n, links, 1e9)
    assert g.edge_forwarding_index(per_link=False) == pytest.approx(1.0)
    # alltoall bound equals injection bandwidth
    assert g.alltoall_expected_goodput() == pytest.approx((n - 1) * links * 1e9)


@given(k=st.sampled_from([4, 6, 8]))
@settings(max_examples=6, deadline=None)
def test_ring_efi_known_formula(k):
    # bidirectional ring, ECMP: max directed load = k^2/8 (even k)
    g = LinkGraph.ring(k, 1e9)
    assert g.edge_forwarding_index() == pytest.approx(k * k / 8, rel=0.26)


def test_torus_symmetry():
    g = make_tpu_pod(4, 4)
    loads = g.edge_loads_ecmp().values()
    assert max(loads) == pytest.approx(min(loads), rel=1e-6)  # edge-transitive
