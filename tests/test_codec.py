"""Fused bucket wire codec + wire-format planning: round-trip properties vs
the unfused `overlap` pack/unpack, in-kernel quantization + error feedback,
per-tier wire selection/persistence/pricing, and the O(1)-concatenate jaxpr
regression on the packed explicit-DP step."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from repro.core import overlap as ov
from repro.core import wire as wr
from repro.core.commplan import CommPlan
from repro.core.costmodel import exposed_comm_time, make_comm_model
from repro.core.topology import make_paper_systems
from repro.kernels import bucket_codec as bc

from .helpers import run_devices


def _leaves(rng, shapes, dtype=np.float32):
    return [jnp.asarray(rng.randn(*s).astype(np.float32)).astype(dtype)
            for s in shapes]


# --------------------------------------------------------------- round trips
RAGGED_SHAPE_SETS = [
    [(3, 2), (5,), (1,)],              # ragged small leaves
    [(2, 2), (0,), (3,)],              # zero-size leaf in the middle
    [(0,), (0, 4)],                    # all leaves zero-size (no buckets)
    [(7, 3), (1000,), (13,)],          # bucket-spanning large leaf
    [(1,)],                            # single element
]


@pytest.mark.parametrize("shapes", RAGGED_SHAPE_SETS)
@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("reverse", [True, False])
def test_fp32_roundtrip_matches_unfused(shapes, impl, reverse):
    """Codec pack/unpack must be element-for-element identical to the unfused
    `overlap.pack_buckets`/`unpack_buckets` across ragged, zero-size, and
    bucket-spanning leaves, in both bucket orders and both implementations."""
    rng = np.random.RandomState(0)
    flat = _leaves(rng, shapes)
    sizes = [g.size for g in flat]
    for cap in (4, 1, 0, 10_000):  # incl. sub-element (0 -> clamps to 1)
        table = bc.make_table(sizes, cap, reverse=reverse)
        buckets = ov.make_buckets(sizes, cap, reverse=reverse)
        assert table.n_buckets == len(buckets)
        if impl == "pallas" and table.n_buckets > 40:
            # the interpret-mode kernel replays the unrolled per-bucket `when`
            # chain at every grid step (O(n_buckets^2)) — minutes at 1000+
            # buckets.  The xla impl covers the large-table cases; pallas
            # keeps the sub-element/ragged coverage on the small ones.
            continue
        if table.n_buckets == 0:
            with pytest.raises(ValueError, match="empty table"):
                bc.pack(table, flat, impl=impl)
            continue
        ref = ov.pack_buckets(flat, buckets, scale=2.0)
        carrier, scales, _ = bc.pack(table, flat, scale=2.0, impl=impl)
        assert scales is None
        assert carrier.shape == (table.n_buckets, table.bucket_elems)
        np.testing.assert_allclose(np.asarray(carrier), np.asarray(ref),
                                   rtol=1e-6)
        back = bc.unpack(table, carrier, flat, impl=impl)
        ref_back = ov.unpack_buckets(ref, buckets, flat)
        for a, b, g in zip(back, ref_back, flat):
            assert a.shape == g.shape and a.dtype == jnp.float32
            np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_roundtrip_input_dtypes(dtype):
    """bf16 gradient leaves round-trip through the fp32 carrier exactly (the
    pack casts up); the bf16 *wire* round-trips within bf16 resolution."""
    rng = np.random.RandomState(1)
    flat = _leaves(rng, [(17,), (4, 5)], dtype)
    table = bc.make_table([g.size for g in flat], 8)
    carrier, _, _ = bc.pack(table, flat, impl="xla")
    back = bc.unpack(table, carrier, flat, impl="xla")
    for a, g in zip(back, flat):
        np.testing.assert_array_equal(np.asarray(a),
                                      np.asarray(g.astype(jnp.float32)))
    c16, _, _ = bc.pack(table, flat, wire="bf16", impl="xla")
    assert c16.dtype == jnp.bfloat16
    for a, g in zip(bc.unpack(table, c16, flat), flat):
        np.testing.assert_allclose(np.asarray(a),
                                   np.asarray(g.astype(jnp.float32)),
                                   rtol=1e-2, atol=1e-2)


@settings(max_examples=10)
@given(st.integers(1, 6), st.integers(1, 64), st.integers(0, 1))
def test_roundtrip_property(n_leaves, cap, rev):
    """Property: for random leaf sets and bucket sizes, unpack(pack(x)) == x
    (fp32 wire) and the carrier layout matches the unfused reference."""
    rng = np.random.RandomState(n_leaves * 1000 + cap)
    shapes = [tuple(rng.randint(0, 9, size=rng.randint(1, 3)))
              for _ in range(n_leaves)]
    flat = _leaves(rng, shapes)
    sizes = [g.size for g in flat]
    table = bc.make_table(sizes, cap, reverse=bool(rev))
    if table.n_buckets == 0:
        return
    buckets = ov.make_buckets(sizes, cap, reverse=bool(rev))
    ref = ov.pack_buckets(flat, buckets, scale=0.5)
    carrier, _, _ = bc.pack(table, flat, scale=0.5, impl="xla")
    np.testing.assert_allclose(np.asarray(carrier), np.asarray(ref), rtol=1e-6)
    for a, g in zip(bc.unpack(table, carrier, flat, impl="xla"), flat):
        np.testing.assert_allclose(np.asarray(a), 0.5 * np.asarray(g),
                                   rtol=1e-5, atol=1e-7)


# ------------------------------------------------------------- int8 + errors
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_int8_pack_error_feedback_identity(impl):
    """The in-kernel quantization must satisfy the error-feedback identity
    q * scale + new_err == packed + err exactly (that is the convergence
    guarantee), and both implementations must agree bit-for-bit."""
    rng = np.random.RandomState(2)
    flat = _leaves(rng, [(33,), (5, 5), (0,), (7,)])
    table = bc.make_table([g.size for g in flat], 16)
    err = jnp.asarray(rng.randn(table.n_buckets, table.bucket_elems)
                      .astype(np.float32)) * 1e-3
    q, s, new_err = bc.pack(table, flat, scale=0.25, wire="int8", err=err,
                            impl=impl)
    assert q.dtype == jnp.int8 and s.shape == (table.n_buckets,)
    packed, _, _ = bc.pack(table, flat, scale=0.25, impl="xla")
    lhs = np.asarray(q).astype(np.float32) * np.asarray(s)[:, None] \
        + np.asarray(new_err)
    np.testing.assert_allclose(lhs, np.asarray(packed + err), rtol=1e-5,
                               atol=1e-7)
    # implementations agree exactly on the wire payload
    q2, s2, e2 = bc.pack(table, flat, scale=0.25, wire="int8", err=err,
                         impl="xla")
    np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s), np.asarray(s2), rtol=1e-7)
    np.testing.assert_allclose(np.asarray(new_err), np.asarray(e2), atol=1e-7)
    # dequantized unpack stays within one quantization step of the source
    deq = bc.unpack(table, q, flat, scales=s, impl=impl)
    for a, g in zip(deq, flat):
        if g.size:
            tol = float(np.asarray(s).max())
            np.testing.assert_allclose(np.asarray(a), 0.25 * np.asarray(g),
                                       atol=tol * 1.01)


def test_int8_all_zero_bucket_stable():
    """An all-zero bucket must quantize with the clamped scale, not divide by
    zero (NaN on the wire)."""
    flat = [jnp.zeros((8,), jnp.float32)]
    table = bc.make_table([8], 4)
    q, s, e = bc.pack(table, flat, wire="int8",
                      err=jnp.zeros((2, 4), jnp.float32), impl="xla")
    assert np.all(np.isfinite(np.asarray(s)))
    assert np.all(np.asarray(q) == 0) and np.all(np.asarray(e) == 0.0)


def test_wire_bytes_accounting():
    table = bc.make_table([100], 32)  # 4 buckets of 32 elems
    assert bc.wire_bytes(table, "fp32") == 4 * 32 * 4
    assert bc.wire_bytes(table, "bf16") == 4 * 32 * 2
    assert bc.wire_bytes(table, "int8") == 4 * 32 * 1 + 4 * 4
    assert wr.bytes_on_wire(1024.0, "int8", n_buckets=2) == 256.0 + 8.0
    assert wr.bytes_on_wire(1024.0, "fp32") == 1024.0


# --------------------------------------------------------- wire-format plans
def test_choose_format_thresholds():
    """Compress where bandwidth-bound, fp32 where alpha-bound."""
    assert wr.choose_format(1e-5, 1e-3) == "int8"     # beta >> alpha
    assert wr.choose_format(1e-5, 3e-5) == "bf16"     # middle regime
    assert wr.choose_format(1e-5, 1e-6) == "fp32"     # alpha-bound
    assert wr.choose_format(1e-5, 1e-3, allow_lossy=False) == "fp32"


def test_choose_wire_inter_compresses_intra_paced_stays_fp32():
    """The pacing rule: a bandwidth-bound inter tier compresses, and an intra
    tier that never paces the pipeline stays fp32 even if its own beta term
    dominates its alpha term."""
    p = ov.PipelineParams(n_ici=4, alpha_ici=2e-6, bw_ici=300e9,
                          alpha_dcn=1e-5, bw_dcn=25e9)
    spec = wr.choose_wire(p, float(16 << 20))
    assert spec.inter == "int8"
    assert spec.intra == "fp32"
    # a starved intra tier that paces the pipeline is allowed to compress...
    slow = ov.PipelineParams(n_ici=4, alpha_ici=2e-6, bw_ici=1e9,
                             alpha_dcn=1e-5, bw_dcn=25e9)
    assert wr.choose_wire(slow, float(16 << 20)).intra == "int8"
    # ...but only while the realized int8 gather wire ((n-1)/4 per peer) beats
    # the fp32 allreduce (2(n-1)/n): at n >= 8 the gather moves MORE bytes,
    # so the planner must not turn compression on where it slows the step
    slow8 = ov.PipelineParams(n_ici=8, alpha_ici=2e-6, bw_ici=1e9,
                              alpha_dcn=1e-5, bw_dcn=25e9)
    assert wr.choose_wire(slow8, float(16 << 20)).intra == "fp32"
    assert wr.gather_wins(4) and not wr.gather_wins(8)
    # pricing uses the realized gather multiplier, not the idealized 0.25
    assert wr.realized_multiplier("int8", 4) == pytest.approx(0.5)
    assert wr.realized_multiplier("int8", 32) == 1.0
    assert wr.realized_multiplier("bf16", 32) == pytest.approx(0.5)


def test_plan_wire_persisted_and_exposed():
    """plan.wire survives the JSON round-trip, reaches CollectivePolicy, and
    the paper systems land where the paper points (inter tier compresses)."""
    from repro.core.autotune import CollectivePolicy

    plan = CommPlan.from_topology(make_paper_systems()["leonardo"])
    assert plan.wire and plan.wire["inter"] == "int8"
    assert plan.wire["intra"] == "fp32"
    back = CommPlan.from_blob(plan.to_blob())
    assert back.wire == plan.wire
    assert back.wire_spec() == plan.wire_spec()
    pol = CollectivePolicy.from_plan(plan)
    assert pol.wire.inter == "int8" and pol.wire.compresses
    # legacy blobs (no wire key) mean fp32 everywhere
    legacy = CommPlan.from_blob({"all_reduce": {}, "all_to_all": {}})
    assert legacy.wire_spec() == wr.WireSpec()
    assert not legacy.wire_spec().compresses
    with pytest.raises(ValueError, match="unknown wire format"):
        wr.WireSpec(intra="fp7")


def test_exposed_comm_time_prices_wire():
    """Wire-aware pricing: a compressing plan strictly shrinks the predicted
    comm time vs the fp32 wire, and never increases it."""
    plan = CommPlan.from_topology(make_paper_systems()["leonardo"])
    model = make_comm_model("leonardo")
    from repro.core.scenarios import synthetic_grad_sizes

    sizes = synthetic_grad_sizes(256 << 20)
    fp = exposed_comm_time(0.05, plan, sizes, n_endpoints=512, model=model)
    priced = exposed_comm_time(0.05, plan, sizes, n_endpoints=512, model=model,
                               wire="plan")
    assert fp.wire == "fp32/fp32"
    assert priced.wire == "fp32/int8"
    assert priced.total_comm_s < fp.total_comm_s
    assert priced.exposed_s <= fp.exposed_s + 1e-12
    # explicit spec and dict forms are accepted
    byspec = exposed_comm_time(0.05, plan, sizes, n_endpoints=512, model=model,
                               wire=wr.WireSpec(inter="int8"))
    bydict = exposed_comm_time(0.05, plan, sizes, n_endpoints=512, model=model,
                               wire={"inter": "int8"})
    assert byspec.total_comm_s == pytest.approx(bydict.total_comm_s)


def test_sweep_overlap_wire_param():
    from repro.core.scenarios import sweep_overlap

    fp = sweep_overlap("leonardo", (512,))
    pr = sweep_overlap("leonardo", (512,), wire="plan")
    assert fp[0].wire == "fp32/fp32" and pr[0].wire == "fp32/int8"
    assert pr[0].total_comm_s < fp[0].total_comm_s


# --------------------------------------------------- jaxpr op-count regression
from .helpers import count_eqns as _count_eqns


def _count_prim(closed, name):
    return _count_eqns(closed, name)


class _ToyModel:
    @staticmethod
    def loss(params, batch):
        s = sum(jnp.sum(p) for p in jax.tree.leaves(params))
        return (s - 1.0) ** 2 + 0.0 * jnp.mean(batch["x"])


def _toy_step_jaxpr(n_leaves, **kw):
    import repro.compat  # noqa: F401
    from jax.sharding import AxisType
    from repro.optim import adamw
    from repro.runtime import steps as rsteps

    mesh = jax.make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
    opt = adamw.OptConfig(peak_lr=1e-2, warmup_steps=0, decay_steps=10)
    params = {f"w{i}": jnp.ones((65,), jnp.float32) for i in range(n_leaves)}
    batch = {"x": jnp.ones((2,), jnp.float32)}
    step = rsteps.build_explicit_dp_step(_ToyModel(), opt, mesh, "data", **kw)
    err = step.init_error_state(params)
    return jax.make_jaxpr(lambda p, o, b, e: step(p, o, b, e))(
        params, adamw.init_opt_state(params), batch, err)


@pytest.mark.parametrize("kw", [dict(overlap=True, bucket_bytes=4 * 128),
                                dict(overlap=True, bucket_bytes=4 * 128,
                                     compress_bits=8),
                                dict(bucket_bytes=4 * 128)])
def test_packed_step_has_o1_concatenates(kw):
    """The packed explicit-DP step must contain O(1) concatenate ops — not one
    per bucket and one per leaf like the unfused pack/unpack emitted.  Checked
    at two leaf counts: the count must not grow with the tree."""
    c_small = _count_prim(_toy_step_jaxpr(4, **kw), "concatenate")
    c_big = _count_prim(_toy_step_jaxpr(24, **kw), "concatenate")
    assert c_big <= 2, (c_small, c_big)
    assert c_big == c_small, "concatenate count grew with the leaf count"


def test_overlap_step_single_fused_pack_and_unpack():
    """Jaxpr-level acceptance: one fused pack (dynamic_update_slice chain into
    a single carrier) and one fused unpack (slice per leaf), with the
    reductions in a single scan over the carrier rows."""
    jx = _toy_step_jaxpr(8, overlap=True, bucket_bytes=4 * 128)
    assert _count_prim(jx, "concatenate") == 0
    # one dus per leaf (the fused pack), not per (leaf x bucket)
    assert _count_prim(jx, "dynamic_update_slice") == 8
    assert _count_prim(jx, "scan") >= 1


# ------------------------------------------------ runtime numerics (multi-dev)
INT8_OVERLAP = r"""
import jax, jax.numpy as jnp, numpy as np
import repro.compat
from jax.sharding import AxisType
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.optim import adamw
from repro.runtime import steps as rsteps

cfg = get_config("smollm-135m").reduced()
shape = ShapeConfig("t", 32, 8, "train")
mesh = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
model = build_model(cfg)
opt = adamw.OptConfig(peak_lr=1e-3, warmup_steps=1, decay_steps=20)
params = model.init(jax.random.PRNGKey(0))
ostate = adamw.init_opt_state(params)
batch = model.make_batch(shape)
delta = lambda a, b: max(
    float(jnp.max(jnp.abs(x.astype(jnp.float32) - y.astype(jnp.float32))))
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))

base = rsteps.build_explicit_dp_step(model, opt, mesh, "data")
bp, _, bm, _ = base(params, ostate, batch, base.init_error_state(params))

# unfused baseline: per-tensor int8 (the legacy wire)
pt = rsteps.build_explicit_dp_step(model, opt, mesh, "data", compress_bits=8)
pp, _, pm, _ = pt(params, ostate, batch, pt.init_error_state(params))

# int8 + overlap: previously raised ValueError by construction
bb = 1 << 20
ovl = rsteps.build_explicit_dp_step(model, opt, mesh, "data", compress_bits=8,
                                    overlap=True, bucket_bytes=bb)
err = ovl.init_error_state(params)
assert err.ndim == 2, err.shape  # carrier-shaped error state
from repro.analysis import expected_trace, lint_trace, trace_jaxpr
jx = jax.make_jaxpr(lambda p, o, b, e: ovl(p, o, b, e))(
    params, ostate, batch, err)
tr = trace_jaxpr(jx, donate_argnums=ovl.donate_argnums)
# the wire is per-bucket int8 inside a scan: i8 gathers appear once (in the
# scan body), not once per leaf like the per-tensor baseline
n_leaves = len(jax.tree.leaves(params))
i8 = [r for r in tr.records if r.kind == "all_gather" and r.dtype == "int8"]
assert 1 <= len(i8) < n_leaves, (len(i8), n_leaves)
assert all(r.scan_depth >= 1 for r in i8), i8
# and the full CommLint rule catalog agrees the step matches its program
grad_bytes = sum(p.size * 4 for p in jax.tree.leaves(params))
fs = lint_trace(tr, expected_trace(ovl.program, n_devices=4,
                                   grad_bytes=grad_bytes))
assert not fs, [str(f) for f in fs]
op, _, om, oe = ovl(params, ostate, batch, err)
assert oe.ndim == 2
d_fp = delta(bp, op); d_pt = delta(pp, op)
print("int8+overlap vs fp32:", d_fp, "vs unfused int8:", d_pt)
# documented error-feedback tolerance: one int8 quantization step of the
# bucket scale on top of the fp32 baseline after one optimizer step
assert d_fp < 5e-2 and d_pt < 5e-2

# microbatched: error feedback carried per bucket through the scan
mbs = rsteps.build_explicit_dp_step(model, opt, mesh, "data", compress_bits=8,
                                    overlap=True, bucket_bytes=bb,
                                    microbatches=2)
mp, _, mm, me = mbs(params, ostate, batch, mbs.init_error_state(params))
assert delta(bp, mp) < 5e-2

# two-level mesh: int8 intra gather + fp32 inter leg, chunked pipeline
mesh2 = jax.make_mesh((2, 2), ("pod", "data"), axis_types=(AxisType.Auto,)*2)
hier = rsteps.build_explicit_dp_step(model, opt, mesh2, "data",
                                     dcn_axis="pod", compress_bits=8,
                                     overlap=True, bucket_bytes=bb, chunks=3)
hp, _, hm, he = hier(params, ostate, batch, hier.init_error_state(params))
assert delta(bp, hp) < 5e-2

# error feedback converges: a second step with the carried error state stays
# finite and keeps tracking the fp32 trajectory
bp2, bo2, bm2, _ = base(bp, ostate, batch, base.init_error_state(params))
op2, _, om2, _ = ovl(op, ostate, batch, oe)
assert jnp.isfinite(om2["loss"]) and delta(bp2, op2) < 1e-1
print("ALL_OK")
"""


@pytest.mark.slow
def test_int8_composes_with_overlap_numerics():
    assert "ALL_OK" in run_devices(INT8_OVERLAP, 4, timeout=560)


def test_compress_no_longer_excludes_overlap():
    """The ValueError barring compress_bits + bucketing/overlap is gone; the
    remaining guards (bad bits, per-tensor overlap, mb without overlap) hold."""
    import repro.compat  # noqa: F401
    from jax.sharding import AxisType
    from repro.optim import adamw
    from repro.runtime import steps as rsteps

    mesh = jax.make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
    opt = adamw.OptConfig()
    # composes now: no raise at build time
    rsteps.build_explicit_dp_step(_ToyModel(), opt, mesh, "data",
                                  compress_bits=8, overlap=True)
    rsteps.build_explicit_dp_step(_ToyModel(), opt, mesh, "data",
                                  compress_bits=8, bucket_bytes=1 << 20)
    with pytest.raises(ValueError, match="compress_bits"):
        rsteps.build_explicit_dp_step(_ToyModel(), opt, mesh, "data",
                                      compress_bits=4)
    with pytest.raises(ValueError, match="per-tensor"):
        rsteps.build_explicit_dp_step(_ToyModel(), opt, mesh, "data",
                                      overlap=True, bucket_bytes=0)
    with pytest.raises(ValueError, match="overlap"):
        rsteps.build_explicit_dp_step(_ToyModel(), opt, mesh, "data",
                                      microbatches=2)


def test_init_error_state_shapes():
    """Carrier-shaped zeros when compression rides buckets; per-leaf zeros on
    the per-tensor wire."""
    import repro.compat  # noqa: F401
    from jax.sharding import AxisType
    from repro.optim import adamw
    from repro.runtime import steps as rsteps

    mesh = jax.make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
    opt = adamw.OptConfig()
    params = {"a": jnp.ones((100,)), "b": jnp.ones((30,))}
    bb = 4 * 64
    s = rsteps.build_explicit_dp_step(_ToyModel(), opt, mesh, "data",
                                      compress_bits=8, overlap=True,
                                      bucket_bytes=bb)
    err = s.init_error_state(params)
    assert err.shape == (3, 64) and err.dtype == jnp.float32  # ceil(130/64)
    s_pt = rsteps.build_explicit_dp_step(_ToyModel(), opt, mesh, "data",
                                         compress_bits=8)
    err_pt = s_pt.init_error_state(params)
    assert jax.tree.structure(err_pt) == jax.tree.structure(params)


# --------------------------------------------------- ZeRO fused shard update
def test_adamw_update_shard_matches_adamw_reference():
    """The fused dequant+AdamW+requantize shard kernel must reproduce
    `adamw.apply_updates` exactly (same op order) on a flat fp32 shard, for
    both implementations."""
    from repro.optim import adamw

    rng = np.random.RandomState(3)
    nb, sh = 3, 64
    g = jnp.asarray(rng.randn(nb, sh).astype(np.float32))
    p = jnp.asarray(rng.randn(nb, sh).astype(np.float32))
    m = jnp.asarray(rng.randn(nb, sh).astype(np.float32)) * 0.1
    v = jnp.abs(jnp.asarray(rng.randn(nb, sh).astype(np.float32))) * 0.01
    cfg = adamw.OptConfig(peak_lr=1e-2, warmup_steps=0, decay_steps=10)
    state = {"m": {"w": m}, "v": {"w": v}, "step": jnp.zeros((), jnp.int32)}
    ref_p, ref_s, ref_metrics = adamw.apply_updates({"w": p}, {"w": g}, state,
                                                    cfg)
    step = jnp.ones((), jnp.float32)
    clip = jnp.minimum(1.0, cfg.clip_norm / (adamw.global_norm({"w": g}) + 1e-9))
    kw = dict(clip=clip, lr=adamw.schedule(1, cfg),
              bc1=1 - cfg.b1 ** step, bc2=1 - cfg.b2 ** step,
              b1=cfg.b1, b2=cfg.b2, eps=cfg.eps,
              weight_decay=cfg.weight_decay)
    # the eager xla impl (what the CPU/GPU trainer runs) is bit-for-bit; the
    # pallas kernel body goes through jit, where XLA may fuse a*b+c into an
    # FMA — 1-ulp slack covers exactly that
    pw, ps, nm, nv = bc.adamw_update_shard(g, p, m, v, wire="fp32",
                                           impl="xla", **kw)
    assert ps is None
    np.testing.assert_array_equal(np.asarray(pw), np.asarray(ref_p["w"]))
    np.testing.assert_array_equal(np.asarray(nm), np.asarray(ref_s["m"]["w"]))
    np.testing.assert_array_equal(np.asarray(nv), np.asarray(ref_s["v"]["w"]))
    pw2, _, nm2, nv2 = bc.adamw_update_shard(g, p, m, v, wire="fp32",
                                             impl="pallas", **kw)
    np.testing.assert_allclose(np.asarray(pw2), np.asarray(ref_p["w"]),
                               rtol=3e-7, atol=1e-9)
    np.testing.assert_allclose(np.asarray(nm2), np.asarray(ref_s["m"]["w"]),
                               rtol=3e-7, atol=1e-9)
    np.testing.assert_allclose(np.asarray(nv2), np.asarray(ref_s["v"]["w"]),
                               rtol=3e-7, atol=1e-9)


def test_adamw_update_shard_int8_wire():
    """int8 wire: per-row scales, xla/pallas agree bit-for-bit on the payload,
    dequantized params land within one quantization step; an all-zero row
    quantizes with the clamped scale (no NaN)."""
    from repro.optim import adamw

    rng = np.random.RandomState(4)
    nb, sh = 2, 32
    g = jnp.asarray(rng.randn(nb, sh).astype(np.float32))
    p = jnp.asarray(rng.randn(nb, sh).astype(np.float32))
    m = jnp.zeros((nb, sh), jnp.float32)
    v = jnp.zeros((nb, sh), jnp.float32)
    kw = dict(clip=jnp.float32(1.0), lr=jnp.float32(1e-2),
              bc1=jnp.float32(0.1), bc2=jnp.float32(0.05),
              b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1)
    ref, _, _, _ = bc.adamw_update_shard(g, p, m, v, wire="fp32", impl="xla",
                                         **kw)
    outs = {}
    for impl in ("xla", "pallas"):
        q, s, nm, nv = bc.adamw_update_shard(g, p, m, v, wire="int8",
                                             impl=impl, **kw)
        assert q.dtype == jnp.int8 and s.shape == (nb,)
        deq = np.asarray(q, np.float32) * np.asarray(s)[:, None]
        np.testing.assert_allclose(deq, np.asarray(ref),
                                   atol=float(np.asarray(s).max()) * 1.01)
        outs[impl] = (np.asarray(q), np.asarray(s))
    np.testing.assert_array_equal(outs["xla"][0], outs["pallas"][0])
    np.testing.assert_allclose(outs["xla"][1], outs["pallas"][1], rtol=1e-7)
    # all-zero state at g=p=0 is an AdamW fixed point with wd=0: stays zero
    z = jnp.zeros((1, 8), jnp.float32)
    q0, s0, m0, v0 = bc.adamw_update_shard(z, z, z, z, wire="int8", impl="xla",
                                           clip=jnp.float32(1.0),
                                           lr=jnp.float32(1e-2),
                                           bc1=jnp.float32(0.1),
                                           bc2=jnp.float32(0.05),
                                           b1=0.9, b2=0.95, eps=1e-8,
                                           weight_decay=0.1)
    assert np.all(np.isfinite(np.asarray(s0)))
    assert np.all(np.asarray(q0) == 0)
    assert np.all(np.asarray(m0) == 0) and np.all(np.asarray(v0) == 0)


def _toy_zero_steps(shapes, **kw):
    """Baseline + zero step pair over a params tree with `shapes` leaves on a
    1-device mesh (collectives degenerate to identity, numerics stay real)."""
    import repro.compat  # noqa: F401
    from jax.sharding import AxisType
    from repro.optim import adamw
    from repro.runtime import steps as rsteps

    mesh = jax.make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
    # clip_norm high enough that clip == 1.0 exactly on both paths: the
    # sum-of-squares reduction order differs (per-leaf vs padded carrier
    # rows), so the norm itself can differ in the last ulp — which must not
    # leak into the update for the bit-parity claim.  An *active* clip with
    # exactly-representable norms is covered by
    # test_zero_step_bit_parity_active_clip.
    opt = adamw.OptConfig(peak_lr=1e-2, warmup_steps=0, decay_steps=10,
                          clip_norm=1e9)
    rng = np.random.RandomState(7)
    # dyadic values: every fp32 sum order is exact, so parity is bit-for-bit
    params = {f"w{i}": jnp.asarray(
        rng.randint(-8, 9, size=s).astype(np.float32) * 0.25)
        for i, s in enumerate(shapes)}
    batch = {"x": jnp.ones((2,), jnp.float32)}
    base = rsteps.build_explicit_dp_step(_ToyModel(), opt, mesh, "data")
    z = rsteps.build_explicit_dp_step(_ToyModel(), opt, mesh, "data",
                                      zero=True, **kw)
    return base, z, params, batch


@pytest.mark.parametrize("shapes", RAGGED_SHAPE_SETS)
@pytest.mark.parametrize("kw", [dict(bucket_bytes=4 * 64),
                                dict(bucket_bytes=4 * 64, overlap=True)])
def test_zero_step_bit_parity_fp32(shapes, kw):
    """fp32 ZeRO (RS -> sharded AdamW -> AG) must be bit-for-bit identical to
    the replicated baseline across ragged / zero-size / sub-element bucket
    layouts, for two consecutive steps (the second exercises carried m/v)."""
    from repro.optim import adamw

    base, z, params, batch = _toy_zero_steps(shapes, **kw)
    bo = adamw.init_opt_state(params)
    zo = z.init_opt_state(params)
    ze = z.init_error_state(params)
    bp, bo, bm = params, bo, None
    zp, zo, zm = params, zo, None
    for _ in range(2):
        bp, bo, bm, _ = base(bp, bo, batch, base.init_error_state(params))
        zp, zo, zm, ze = z(zp, zo, batch, ze)
        for k in bp:
            np.testing.assert_array_equal(np.asarray(bp[k]), np.asarray(zp[k]))
        # satellite: the psum-combined global norm equals the replicated one
        # (to reduction-order ulp; exact-bit equality is checked with
        # controlled values in test_zero_step_bit_parity_active_clip)
        np.testing.assert_allclose(np.asarray(bm["grad_norm"]),
                                   np.asarray(zm["grad_norm"]), rtol=1e-6)
        assert int(zo["step"]) == int(bo["step"])


def test_zero_step_bit_parity_active_clip():
    """Global-norm clipping regression (satellite): with exactly-representable
    sums of squares the psum-combined shard norm is bit-identical to the
    replicated norm, the clip factor *actively* rescales (gnorm >> clip_norm),
    and two steps of clipped updates stay bit-for-bit."""
    import repro.compat  # noqa: F401
    from jax.sharding import AxisType
    from repro.optim import adamw
    from repro.runtime import steps as rsteps

    mesh = jax.make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
    opt = adamw.OptConfig(peak_lr=1e-2, warmup_steps=0, decay_steps=10,
                          clip_norm=1.0)
    # s = 12 -> every grad element is 2*(s-1) = 22, gnorm = sqrt(4*484) = 44
    # exactly; all partial sums are small integers, so any reduction order
    # produces the same bits and the clip factor matches bitwise
    params = {"w0": jnp.full((4,), 3.0, jnp.float32)}
    batch = {"x": jnp.ones((2,), jnp.float32)}
    base = rsteps.build_explicit_dp_step(_ToyModel(), opt, mesh, "data")
    z = rsteps.build_explicit_dp_step(_ToyModel(), opt, mesh, "data",
                                      zero=True, bucket_bytes=4 * 8)
    bp, bo, bm = params, adamw.init_opt_state(params), None
    zp, zo, ze = params, z.init_opt_state(params), z.init_error_state(params)
    for i in range(2):
        bp, bo, bm, _ = base(bp, bo, batch, base.init_error_state(params))
        zp, zo, zm, ze = z(zp, zo, batch, ze)
        np.testing.assert_array_equal(np.asarray(bp["w0"]),
                                      np.asarray(zp["w0"]))
        np.testing.assert_array_equal(np.asarray(bm["grad_norm"]),
                                      np.asarray(zm["grad_norm"]))
        if i == 0:
            assert float(bm["grad_norm"]) == 44.0  # clip active: 44 >> 1.0


def test_zero_step_int8_ag_close():
    """int8 AG leg: params stay within one quantization step of the fp32
    baseline (<5e-2 on O(1) toy values)."""
    from repro.optim import adamw

    base, z, params, batch = _toy_zero_steps([(7, 3), (1000,), (13,)],
                                             bucket_bytes=4 * 64,
                                             overlap=True, compress_bits=8)
    bp, _, _, _ = base(params, adamw.init_opt_state(params), batch,
                       base.init_error_state(params))
    zp, _, _, _ = z(params, z.init_opt_state(params), batch,
                    z.init_error_state(params))
    d = max(float(jnp.max(jnp.abs(bp[k] - zp[k]))) for k in params
            if bp[k].size)
    assert d < 5e-2, d


def test_zero_rejects_per_tensor():
    import repro.compat  # noqa: F401
    from jax.sharding import AxisType
    from repro.optim import adamw
    from repro.runtime import steps as rsteps

    mesh = jax.make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
    with pytest.raises(ValueError, match="per-tensor"):
        rsteps.build_explicit_dp_step(_ToyModel(), adamw.OptConfig(), mesh,
                                      "data", zero=True, bucket_bytes=0)


def test_zero_opt_state_shapes_and_spec():
    """Carrier-sharded m/v geometry: (n_buckets, padded) fp32, padded to a
    multiple of the shard unit; the step advertises the shard spec tag and the
    abstract state mirrors the concrete one."""
    import repro.compat  # noqa: F401
    from jax.sharding import AxisType
    from repro.optim import adamw
    from repro.runtime import steps as rsteps

    mesh = jax.make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
    params = {"a": jnp.ones((100,)), "b": jnp.ones((30,))}
    s = rsteps.build_explicit_dp_step(_ToyModel(), adamw.OptConfig(), mesh,
                                      "data", zero=True, bucket_bytes=4 * 64)
    o = s.init_opt_state(params)
    assert o["m"].shape == (3, 64) and o["m"].dtype == jnp.float32
    assert o["v"].shape == o["m"].shape
    assert o["step"].shape == () and o["step"].dtype == jnp.int32
    a = s.abstract_opt_state(params)
    assert a["m"].shape == o["m"].shape and a["m"].dtype == o["m"].dtype
    assert s.zero and s.opt_shard_spec == "zero-carrier:data"
    # err is a placeholder scalar (no error feedback on the param leg)
    assert s.init_error_state(params).shape == ()
    # non-zero steps keep the replicated adamw state and no spec tag
    s0 = rsteps.build_explicit_dp_step(_ToyModel(), adamw.OptConfig(), mesh,
                                       "data")
    assert not s0.zero and s0.opt_shard_spec is None
    o0 = s0.init_opt_state(params)
    assert jax.tree.structure(o0["m"]) == jax.tree.structure(params)


def test_zero_step_dispatches_rs_ag_no_gradient_allreduce():
    """The acceptance jaxpr property, trace-time: a zero step dispatches
    reduce_scatter + all_gather through the plan and *no* gradient allreduce —
    every remaining psum in the jaxpr is scalar-only (the loss pmean and the
    clip-norm combine)."""
    import repro.compat  # noqa: F401
    from jax.sharding import AxisType
    from repro.core.autotune import CollectivePolicy
    from repro.optim import adamw
    from repro.runtime import steps as rsteps

    mesh = jax.make_mesh((1,), ("data",), axis_types=(AxisType.Auto,))
    policy = CollectivePolicy.from_model()
    plan = policy._as_plan()
    params = {f"w{i}": jnp.ones((65,), jnp.float32) for i in range(4)}
    batch = {"x": jnp.ones((2,), jnp.float32)}
    opt = adamw.OptConfig()
    step = rsteps.build_explicit_dp_step(_ToyModel(), opt, mesh, "data",
                                         zero=True, policy=policy,
                                         bucket_bytes=4 * 64)
    plan.reset_stats()
    jx = jax.make_jaxpr(lambda p, o, b, e: step(p, o, b, e))(
        params, step.init_opt_state(params), batch,
        step.init_error_state(params))
    assert plan.stats.get("reduce_scatter_calls", 0) > 0
    assert plan.stats.get("all_gather_calls", 0) > 0
    assert plan.stats.get("all_reduce_calls", 0) == 0

    # every psum operand is scalar: no full-gradient allreduce anywhere —
    # the CommLint non-scalar-psum / full-gradient-allreduce-under-zero rules
    # over the structured trace (analysis.trace replaces the hand-rolled walk)
    from repro.analysis import expected_trace, lint_trace, trace_jaxpr

    tr = trace_jaxpr(jx, donate_argnums=step.donate_argnums)
    assert all(r.scalar for r in tr.of_kind("psum"))
    findings = lint_trace(tr, expected_trace(step.program, plan=policy))
    assert not findings, [str(f) for f in findings]

    # the replicated baseline, for contrast, does allreduce gradients
    plan.reset_stats()
    base = rsteps.build_explicit_dp_step(_ToyModel(), opt, mesh, "data",
                                         policy=policy)
    jax.make_jaxpr(lambda p, o, b, e: base(p, o, b, e))(
        params, adamw.init_opt_state(params), batch,
        base.init_error_state(params))
    assert plan.stats.get("all_reduce_calls", 0) > 0


# ------------------------------------------------------ ZeRO wire accounting
def test_zero_wire_bytes_ratio():
    """Planned DP wire bytes of the three-phase schedule: fp32 legs land at
    (n-1)/n of the allreduce baseline, and the int8 AG leg at n=8 crosses the
    <=0.6x acceptance line (the asymmetry is documented: logical 2x baseline
    vs realized ring legs)."""
    acc = wr.zero_wire_bytes(1 << 30, 8, ag_fmt="fp32")
    assert acc["ratio"] == pytest.approx(7 / 8)
    assert acc["reduce_scatter"] == acc["all_gather"]
    acc8 = wr.zero_wire_bytes(1 << 30, 8, ag_fmt="int8", n_buckets=64)
    assert acc8["ratio"] <= 0.6
    assert acc8["ratio"] == pytest.approx(
        (7 / 8 + 7 / 8 * 0.25) / 2, rel=1e-3)
    assert acc8["total"] < acc["total"] < acc["allreduce_fp32"]


def test_choose_zero_ag_format_no_gather_gate():
    """The ZeRO AG leg realizes the idealized multiplier at any n, so a
    bandwidth-bound intra tier compresses even at n >= 8 — exactly where
    `choose_wire`'s realized-gather gate keeps the allreduce wire fp32."""
    slow8 = ov.PipelineParams(n_ici=8, alpha_ici=2e-6, bw_ici=1e9,
                              alpha_dcn=1e-5, bw_dcn=25e9)
    assert wr.choose_wire(slow8, float(16 << 20)).intra == "fp32"
    zspec = wr.choose_zero_ag_format(slow8, float(16 << 20))
    assert zspec.intra == "int8" and zspec.inter == "int8"
    assert wr.choose_zero_ag_format(slow8, float(16 << 20),
                                    allow_lossy=False) == wr.WireSpec()
