"""Launcher CLIs + HLO accounting end-to-end validation."""
import subprocess
import sys

import pytest

from .helpers import REPO, run_devices


def _run_cli(args, timeout=400):
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-m"] + args, env=env, cwd=str(REPO),
                         capture_output=True, text=True, timeout=timeout)
    assert res.returncode == 0, res.stderr[-1500:]
    return res.stdout


@pytest.mark.slow
def test_train_launcher_cli(tmp_path):
    out = _run_cli(["repro.launch.train", "--arch", "smollm-135m", "--shape",
                    "train_4k", "--steps", "4", "--reduced",
                    "--ckpt-dir", str(tmp_path), "--ckpt-every", "2"])
    assert "done: step 4" in out


@pytest.mark.slow
def test_serve_launcher_cli():
    out = _run_cli(["repro.launch.serve", "--arch", "smollm-135m", "--reduced",
                    "--batch", "2", "--prompt-len", "8", "--new-tokens", "2"])
    assert "tok/s" in out


@pytest.mark.slow
def test_train_launcher_rejects_decode_shape():
    import os
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    res = subprocess.run([sys.executable, "-m", "repro.launch.train", "--arch",
                          "smollm-135m", "--shape", "decode_32k", "--reduced"],
                         env=env, cwd=str(REPO), capture_output=True, text=True,
                         timeout=120)
    assert res.returncode != 0


MULTIPLIER_VALIDATION = r"""
import jax, jax.numpy as jnp
from repro.launch.hlo_analysis import analyze_cost

# The trip-weighted HLO pass must make scan == unroll (XLA's own cost_analysis
# counts while bodies once — the bug the pass exists to fix).
L, D = 7, 256
w = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
x = jax.ShapeDtypeStruct((D, D), jnp.float32)

def scanned(w, x):
    def body(c, wi):
        return jnp.tanh(c @ wi), None
    return jax.lax.scan(body, x, w)[0].sum()

def unrolled(w, x):
    for i in range(L):
        x = jnp.tanh(x @ w[i])
    return x.sum()

fs = analyze_cost(jax.jit(scanned).lower(w, x).compile().as_text()).flops
fu = analyze_cost(jax.jit(unrolled).lower(w, x).compile().as_text()).flops
ca = jax.jit(scanned).lower(w, x).compile().cost_analysis()
xla_s = (ca[0] if isinstance(ca, (list, tuple)) else ca)["flops"]
print("scan:", fs, "unrolled:", fu, "xla_scan:", xla_s)
assert abs(fs - fu) / fu < 0.05, (fs, fu)
assert abs(fs - L * 2 * D**3) / (L * 2 * D**3) < 0.05
assert xla_s < fs / 2  # demonstrates the XLA under-count the pass corrects
print("OK")
"""


@pytest.mark.slow
def test_hlo_cost_scan_equals_unrolled():
    assert "OK" in run_devices(MULTIPLIER_VALIDATION, 2)
