"""CommLint: the shared jaxpr walker, trace extraction, the StepProgram ->
ExpectedTrace compiler, golden (clean) traces for every named program, and one
negative test per finding code — each asserting the exact code, anchored on
individual collective records."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import pytest
from jax import lax

import repro.compat  # noqa: F401
from repro.analysis import (COLLECTIVE_KINDS, FINDING_CODES, Finding,
                            count_eqns, expected_trace, lint_trace, prims_of,
                            scans_of, trace_jaxpr, trace_step)
from repro.core import program as prg
from repro.core.autotune import CollectivePolicy
from repro.launch.lint import (_LintModel, _dense_fixture, _make_mesh,
                               lint_program_on_mesh)
from repro.launch.lint import main as lint_main
from repro.optim import adamw
from repro.runtime.steps import build_program_step

from .helpers import run_devices

BUCKET = 4 * 128  # tiny bucket: the 1.6 KiB toy gradient packs into 4 rows


# ---------------------------------------------------------------- the walker
def test_walker_counts_nested_eqns():
    def f(x):
        def body(c, _):
            return c + 1.0, c * 2.0
        c, ys = lax.scan(body, x, None, length=5)
        return c + jnp.sum(ys)

    jx = jax.make_jaxpr(f)(0.0)
    assert count_eqns(jx, "scan") == 1
    assert count_eqns(jx, "add") >= 1  # the body's add, found through the scan
    assert count_eqns(jx) > count_eqns(jx, "scan")
    assert "scan" in prims_of(jx) and "add" in prims_of(jx)
    scans = scans_of(jx)
    assert len(scans) == 1
    length, body_prims = scans[0]
    assert length == 5 and "add" in body_prims


def test_trace_record_fields():
    jx = jax.make_jaxpr(lambda x: lax.psum(x, "i"),
                        axis_env=[("i", 4)])(jnp.ones((8,), jnp.float32))
    tr = trace_jaxpr(jx, donate_argnums=(3,))
    assert tr.donate_argnums == (3,)
    (rec,) = tr.records
    assert rec.kind == "psum" and rec.axes == ("i",)
    assert rec.dtype == "float32" and rec.shape == (8,)
    assert rec.payload_bytes == 32 and not rec.scalar
    assert rec.scan_depth == 0 and rec.scan_trips == 1
    assert tr.wire_bytes() == 32 and tr.counts() == {"psum": 1}

    # scalar psums are flagged as such and excluded from wire accounting
    js = jax.make_jaxpr(lambda x: lax.psum(x, "i"),
                        axis_env=[("i", 4)])(jnp.float32(1.0))
    ts = trace_jaxpr(js)
    assert ts.records[0].scalar
    assert ts.wire_bytes() == 0 and ts.wire_bytes(include_scalar=True) == 4


def test_trace_canonicalizes_psum_scatter_and_gather():
    def f(x):
        return lax.all_gather(lax.psum_scatter(x, "i", tiled=True), "i")

    jx = jax.make_jaxpr(f, axis_env=[("i", 2)])(jnp.ones((4,), jnp.float32))
    tr = trace_jaxpr(jx)
    assert tr.kinds() == {"reduce_scatter", "all_gather"}
    assert tr.kinds() <= COLLECTIVE_KINDS


def test_trace_scan_nesting_multiplies_wire_bytes():
    def f(x):
        def body(c, _):
            return lax.psum(c, "i"), None
        c, _ = lax.scan(body, x, None, length=3)
        return c

    jx = jax.make_jaxpr(f, axis_env=[("i", 2)])(jnp.ones((4,), jnp.float32))
    (rec,) = trace_jaxpr(jx).records
    assert rec.scan_depth == 1 and rec.scan_trips == 3
    assert rec.payload_bytes == 16 and rec.wire_bytes == 48


# ------------------------------------------------- expect: budget resolution
def test_carrier_bytes_and_budget_resolution():
    from repro.analysis.expect import carrier_bytes

    assert carrier_bytes(1000, 512) == (1024, 2)   # pads to whole rows
    assert carrier_bytes(1000, None) == (1000, 64)  # per-tensor: no padding
    # a Bucketize node pinned to the plan crossover can't be priced without
    # the plan: the budget stays None rather than guess the cap
    p = prg.train_step_program()
    assert p.has("bucketize")
    assert expected_trace(p, grad_bytes=1 << 20).byte_budget is None
    pol = CollectivePolicy.from_model()
    e = expected_trace(p, grad_bytes=1 << 20, plan=pol)
    assert e.byte_budget is not None and e.byte_budget > 0
    # an explicit node cap needs no plan
    e2 = expected_trace(prg.train_step_program(bucket_bytes=BUCKET),
                        grad_bytes=1 << 20)
    assert e2.byte_budget is not None


def test_expected_collectives_per_schedule():
    ar = prg.train_step_program().expected_collectives()
    z = prg.train_step_program(zero=True).expected_collectives()
    moe = prg.moe_step_program().expected_collectives()
    assert ar <= COLLECTIVE_KINDS and "reduce_scatter" not in ar
    assert {"reduce_scatter", "all_gather"} <= z
    assert "all_to_all" in moe and "all_to_all" not in ar


def test_finding_code_catalog_is_closed():
    assert len(set(FINDING_CODES)) == 8
    with pytest.raises(ValueError, match="unknown finding code"):
        Finding("misaligned-warp", "not a real rule")


# ----------------------------------------------------------- hlo-text guards
def test_hlo_analysis_guards_empty_and_malformed():
    from repro.launch.hlo_analysis import (_parse_group, analyze_collectives,
                                           analyze_cost)

    for text in ("", "   \n  "):
        stats = analyze_collectives(text)
        assert stats.ici_bytes == 0.0 and stats.dcn_bytes == 0.0
        assert stats.by_op == {}
        cost = analyze_cost(text)
        assert cost.flops == 0.0 and cost.bytes == 0.0
    # truncated iota group annotations degrade to "no groups", not a raise
    assert _parse_group("replica_groups=[2,4]<=") == (1, 0)
    assert _parse_group("no groups here at all") == (1, 0)


# -------------------------------------------------- golden traces (1 device)
@pytest.mark.parametrize("name", sorted(prg.NAMED_PROGRAMS))
def test_named_program_lints_clean(name):
    rep = lint_program_on_mesh(prg.named_program(name), n_devices=1)
    assert rep["codes"] == [], rep["findings"]
    if rep["schedule"] != "moe_alltoall":
        # (the degenerate 1-device mesh traces the MoE exchange away; the
        # multi-device golden below pins its 2 all_to_alls)
        assert rep["records"] >= 1
    assert set(rep["kinds"]) <= COLLECTIVE_KINDS


def test_lint_cli_rejects_unknown_program():
    with pytest.raises(SystemExit, match="unknown program"):
        lint_main(["warp_speed"])


# ------------------------------------------------ negatives: one per code
# The xla-forcing legacy policy pins the dense wire to plain psum emission,
# so each mutation lands on a deterministic jaxpr.
def _xla_policy():
    return CollectivePolicy({2: []}, {2: []}, {"source": "measured"})


@functools.lru_cache(maxsize=None)
def _built_trace(**flags):
    """Trace a step built from train_step_program(**flags) on one device."""
    mesh = _make_mesh((1,), ("data",))
    params, batch = _dense_fixture(1)
    opt = adamw.OptConfig(peak_lr=1e-2, warmup_steps=0, decay_steps=10)
    step = build_program_step(_LintModel(), opt, mesh,
                              prg.train_step_program(**flags),
                              policy=_xla_policy())
    return trace_step(step, params, step.init_opt_state(params), batch,
                      step.init_error_state(params))


def _codes(findings):
    return sorted({f.code for f in findings})


def test_negative_gradient_allreduce_under_zero():
    """An allreduce-built step linted against the ZeRO program: the
    tensor-sized gradient psums violate both scalar-only rules."""
    tr = _built_trace(bucket_bytes=BUCKET)
    fs = lint_trace(tr, expected_trace(prg.train_step_program(zero=True)))
    assert _codes(fs) == ["full-gradient-allreduce-under-zero",
                          "non-scalar-psum"], [str(f) for f in fs]
    assert all(f.record is not None and not f.record.scalar for f in fs)


def test_negative_wire_dtype_widening():
    """An fp32-wire step against the int8 program: every gradient-sized fp32
    record is a widened leg (the scalar clip combines stay exempt)."""
    tr = _built_trace(bucket_bytes=BUCKET)
    fs = lint_trace(tr, expected_trace(
        prg.train_step_program(compress_bits=8)))
    assert "wire-dtype-widening" in _codes(fs), [str(f) for f in fs]
    wides = [f for f in fs if f.code == "wire-dtype-widening"]
    assert all(f.record.dtype == "float32" and
               f.record.payload_bytes >= 256 for f in wides)


def test_negative_collective_outside_overlap_scan():
    """A non-overlap step against the overlap program: the bucket reductions
    issue at scan depth 0 instead of riding the issue schedule."""
    tr = _built_trace(bucket_bytes=BUCKET)
    fs = lint_trace(tr, expected_trace(
        prg.train_step_program(overlap=True, bucket_bytes=BUCKET)))
    assert _codes(fs) == ["collective-outside-overlap-scan"], \
        [str(f) for f in fs]
    assert all(f.record.scan_depth == 0 for f in fs)


def test_negative_undonated_carrier():
    """The int8 overlap step is clean as built; stripping the donation of the
    error-feedback carrier (argnum 3) is the one finding introduced."""
    tr = _built_trace(overlap=True, compress_bits=8, bucket_bytes=BUCKET)
    exp = expected_trace(prg.train_step_program(
        overlap=True, compress_bits=8, bucket_bytes=BUCKET))
    assert exp.require_donation == 3
    assert lint_trace(tr, exp) == [], \
        [str(f) for f in lint_trace(tr, exp)]
    stripped = dataclasses.replace(tr, donate_argnums=())
    fs = lint_trace(stripped, exp)
    assert _codes(fs) == ["undonated-carrier"], [str(f) for f in fs]


def test_negative_unplanned_collective():
    """A ZeRO-built step against the allreduce program: reduce_scatter is a
    kind the program never declared — and a stray kind does not also trip
    the wire rules (it reports once, as itself)."""
    tr = _built_trace(zero=True)
    fs = lint_trace(tr, expected_trace(
        prg.train_step_program(bucket_bytes=0)))
    assert _codes(fs) == ["unplanned-collective"], [str(f) for f in fs]
    assert {f.record.kind for f in fs} == {"reduce_scatter"}


def test_negative_unbucketed_concat():
    """Per-leaf concatenation (O(leaves) concatenates) against a bucketized
    program's O(1) codec cap."""
    def pack(xs):
        return functools.reduce(
            lambda a, b: jnp.concatenate([a, b]), xs)

    jx = jax.make_jaxpr(pack)([jnp.ones((4,), jnp.float32)] * 12)
    tr = trace_jaxpr(jx)
    assert tr.n_concats == 11
    fs = lint_trace(tr, expected_trace(
        prg.train_step_program(bucket_bytes=BUCKET)))
    assert _codes(fs) == ["unbucketed-concat"], [str(f) for f in fs]


def test_negative_byte_budget_exceeded():
    """An explicit (absurdly small) budget: the clean allreduce step exceeds
    it through exact payload x scan-trip accounting, scalars excluded."""
    tr = _built_trace(bucket_bytes=BUCKET)
    fs = lint_trace(tr, expected_trace(
        prg.train_step_program(bucket_bytes=BUCKET), byte_budget=1.0))
    assert _codes(fs) == ["byte-budget-exceeded"], [str(f) for f in fs]
    # and the real derived budget clears the same trace
    grad = sum(p.size * p.dtype.itemsize
               for p in jax.tree.leaves(_dense_fixture(1)[0]))
    clean = lint_trace(tr, expected_trace(
        prg.train_step_program(bucket_bytes=BUCKET), grad_bytes=grad))
    assert clean == [], [str(f) for f in clean]


# --------------------------------------------- golden traces (multi-device)
LINT_CLI = r"""
import repro.compat
from repro.core import program as prg
from repro.launch.lint import lint_program_on_mesh, main

assert main(["--all-named-programs"]) == 0
# the hierarchical two-tier path: int8 chunked pipeline on a pod x data mesh
rep = lint_program_on_mesh(
    prg.train_step_program(overlap=True, compress_bits=8, chunks=2,
                           bucket_bytes=1 << 20),
    dcn=2)
assert rep["codes"] == [], rep["findings"]
print("ALL_OK")
"""


@pytest.mark.slow
@pytest.mark.parametrize("n", [4, 8])
def test_lint_cli_clean_multi_device(n):
    """`python -m repro.launch.lint --all-named-programs` exits 0 — every
    named program traces clean on real multi-device meshes."""
    assert "ALL_OK" in run_devices(LINT_CLI, n, timeout=560)
