"""CommLint: the shared jaxpr walker, trace extraction, the StepProgram ->
ExpectedTrace compiler, golden (clean) traces for every named program, and one
negative test per finding code — each asserting the exact code, anchored on
individual collective records.  The compiled-HLO level (ScheduleLint) is
covered the same way: HLO-parsing units, jaxpr<->HLO cross-check goldens for
every named program, and synthetic-HLO negatives for each of its codes."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import pytest
from jax import lax

import repro.compat  # noqa: F401
from repro.analysis import (COLLECTIVE_KINDS, FINDING_CODES, CollectiveRecord,
                            CollectiveTrace, Finding, count_eqns,
                            crosscheck_trace, expected_trace, lint_trace,
                            parse_hlo, prims_of, scans_of,
                            static_exposed_comm, trace_jaxpr, trace_step)
from repro.core import program as prg
from repro.core.autotune import CollectivePolicy
from repro.launch.lint import (_LintModel, _dense_fixture, _make_mesh,
                               lint_program_on_mesh)
from repro.launch.lint import main as lint_main
from repro.optim import adamw
from repro.runtime.steps import build_program_step

from .helpers import run_devices

BUCKET = 4 * 128  # tiny bucket: the 1.6 KiB toy gradient packs into 4 rows


# ---------------------------------------------------------------- the walker
def test_walker_counts_nested_eqns():
    def f(x):
        def body(c, _):
            return c + 1.0, c * 2.0
        c, ys = lax.scan(body, x, None, length=5)
        return c + jnp.sum(ys)

    jx = jax.make_jaxpr(f)(0.0)
    assert count_eqns(jx, "scan") == 1
    assert count_eqns(jx, "add") >= 1  # the body's add, found through the scan
    assert count_eqns(jx) > count_eqns(jx, "scan")
    assert "scan" in prims_of(jx) and "add" in prims_of(jx)
    scans = scans_of(jx)
    assert len(scans) == 1
    length, body_prims = scans[0]
    assert length == 5 and "add" in body_prims


def test_trace_record_fields():
    jx = jax.make_jaxpr(lambda x: lax.psum(x, "i"),
                        axis_env=[("i", 4)])(jnp.ones((8,), jnp.float32))
    tr = trace_jaxpr(jx, donate_argnums=(3,))
    assert tr.donate_argnums == (3,)
    (rec,) = tr.records
    assert rec.kind == "psum" and rec.axes == ("i",)
    assert rec.dtype == "float32" and rec.shape == (8,)
    assert rec.payload_bytes == 32 and not rec.scalar
    assert rec.scan_depth == 0 and rec.scan_trips == 1
    assert tr.wire_bytes() == 32 and tr.counts() == {"psum": 1}

    # scalar psums are flagged as such and excluded from wire accounting
    js = jax.make_jaxpr(lambda x: lax.psum(x, "i"),
                        axis_env=[("i", 4)])(jnp.float32(1.0))
    ts = trace_jaxpr(js)
    assert ts.records[0].scalar
    assert ts.wire_bytes() == 0 and ts.wire_bytes(include_scalar=True) == 4


def test_trace_canonicalizes_psum_scatter_and_gather():
    def f(x):
        return lax.all_gather(lax.psum_scatter(x, "i", tiled=True), "i")

    jx = jax.make_jaxpr(f, axis_env=[("i", 2)])(jnp.ones((4,), jnp.float32))
    tr = trace_jaxpr(jx)
    assert tr.kinds() == {"reduce_scatter", "all_gather"}
    assert tr.kinds() <= COLLECTIVE_KINDS


def test_trace_scan_nesting_multiplies_wire_bytes():
    def f(x):
        def body(c, _):
            return lax.psum(c, "i"), None
        c, _ = lax.scan(body, x, None, length=3)
        return c

    jx = jax.make_jaxpr(f, axis_env=[("i", 2)])(jnp.ones((4,), jnp.float32))
    (rec,) = trace_jaxpr(jx).records
    assert rec.scan_depth == 1 and rec.scan_trips == 3
    assert rec.payload_bytes == 16 and rec.wire_bytes == 48


# ------------------------------------------------- expect: budget resolution
def test_carrier_bytes_and_budget_resolution():
    from repro.analysis.expect import carrier_bytes

    assert carrier_bytes(1000, 512) == (1024, 2)   # pads to whole rows
    assert carrier_bytes(1000, None) == (1000, 64)  # per-tensor: no padding
    # a Bucketize node pinned to the plan crossover can't be priced without
    # the plan: the budget stays None rather than guess the cap
    p = prg.train_step_program()
    assert p.has("bucketize")
    assert expected_trace(p, grad_bytes=1 << 20).byte_budget is None
    pol = CollectivePolicy.from_model()
    e = expected_trace(p, grad_bytes=1 << 20, plan=pol)
    assert e.byte_budget is not None and e.byte_budget > 0
    # an explicit node cap needs no plan
    e2 = expected_trace(prg.train_step_program(bucket_bytes=BUCKET),
                        grad_bytes=1 << 20)
    assert e2.byte_budget is not None


def test_expected_collectives_per_schedule():
    ar = prg.train_step_program().expected_collectives()
    z = prg.train_step_program(zero=True).expected_collectives()
    moe = prg.moe_step_program().expected_collectives()
    assert ar <= COLLECTIVE_KINDS and "reduce_scatter" not in ar
    assert {"reduce_scatter", "all_gather"} <= z
    assert "all_to_all" in moe and "all_to_all" not in ar


def test_finding_code_catalog_is_closed():
    assert len(set(FINDING_CODES)) == 13
    with pytest.raises(ValueError, match="unknown finding code"):
        Finding("misaligned-warp", "not a real rule")


# ----------------------------------------------------------- hlo-text guards
def test_hlo_analysis_guards_empty_and_malformed():
    from repro.launch.hlo_analysis import (_parse_group, analyze_collectives,
                                           analyze_cost)

    for text in ("", "   \n  "):
        stats = analyze_collectives(text)
        assert stats.ici_bytes == 0.0 and stats.dcn_bytes == 0.0
        assert stats.by_op == {}
        cost = analyze_cost(text)
        assert cost.flops == 0.0 and cost.bytes == 0.0
        assert parse_hlo(text).records == ()
    # truncated iota group annotations degrade to "no groups", not a raise
    assert _parse_group("replica_groups=[2,4]<=") == (1, 0)
    assert _parse_group("no groups here at all") == (1, 0)


def test_parse_group_permute_cycle_length():
    """`source_target_pairs` derives the group from the pair graph — a
    4-ring is a group of 4, not the old hard-coded 2."""
    from repro.launch.hlo_analysis import _parse_group

    ring = ("%cp = f32[64] collective-permute(f32[64] %p), "
            "source_target_pairs={{0,1},{1,2},{2,3},{3,0}}")
    assert _parse_group(ring) == (4, 3)
    assert _parse_group("source_target_pairs={{0,1}}") == (2, 1)
    # two disjoint 2-cycles: the effective group is one component (size 2)
    assert _parse_group(
        "source_target_pairs={{0,1},{1,0},{2,3},{3,2}}") == (2, 1)


def test_trip_count_ignores_unreferenced_constants():
    """The fused-compare fallback only considers constants a compare/fusion
    line actually references — an unrelated scalar constant in the condition
    must not become the trip count."""
    from repro.launch.hlo_analysis import _trip_count

    fused = [
        "%threshold = s32[] constant(99)",  # unrelated (select threshold)
        "%constant.7 = s32[] constant(4)",
        "ROOT %wrapped_compare = pred[] fusion(s32[] %gte, "
        "s32[] %constant.7), kind=kLoop, calls=%cc",
    ]
    assert _trip_count(fused) == 4
    # a direct compare with inline-typed operands resolves exactly
    assert _trip_count([
        "%c.2 = s32[] constant(3)",
        "ROOT %cmp = pred[] compare(s32[] %iv, s32[] %c.2), direction=LT",
    ]) == 3
    # no compare-fed constant at all -> 1, never the stray max
    assert _trip_count(["%threshold = s32[] constant(99)"]) == 1


# ----------------------------------------------- hlo trace: structured parse
def _entry_hlo(body_lines, extra_comps=""):
    body = "\n".join("  " + ln for ln in body_lines)
    return (f"HloModule m\n\n{extra_comps}"
            f"ENTRY %main (p0: f32[1024]) -> f32[1024] {{\n{body}\n}}\n")


def test_parse_hlo_records_and_payload_normalization():
    """HLO result bytes normalize to input-side payloads (all-gather: the
    per-device shard; reduce-scatter: the full pre-scatter operand) so they
    are directly comparable with jaxpr operand accounting."""
    tr = parse_hlo(_entry_hlo([
        "%p0 = f32[1024] parameter(0)",
        "%ag = f32[2048] all-gather(f32[1024] %p0), replica_groups={{0,1}}, "
        "dimensions={0}",
        "%rs = f32[1024] reduce-scatter(f32[2048] %ag), "
        "replica_groups={{0,1}}, dimensions={0}, to_apply=%add",
        "ROOT %ar = f32[1024] all-reduce(f32[1024] %rs), "
        "replica_groups={{0,1}}, to_apply=%add",
    ]))
    ag, rs, ar = tr.records
    assert (ag.op, ag.kind, ag.group_size) == ("all-gather", "all_gather", 2)
    assert ag.result_bytes == 8192 and ag.payload_bytes == 4096
    assert (rs.op, rs.payload_bytes) == ("reduce-scatter", 8192)
    assert (ar.op, ar.payload_bytes) == ("all-reduce", 4096)
    assert all(not r.is_async and r.trips == 1 for r in tr.records)
    assert tr.wire_bytes() == 4096 + 8192 + 4096
    assert tr.counts() == {"all-gather": 1, "reduce-scatter": 1,
                           "all-reduce": 1}


def test_parse_hlo_folds_async_pairs_and_while_trips():
    """-start/-done fold into one async record; collectives inside a while
    body carry the loop's trip multiplier, recovered from the condition."""
    comps = (
        "%body (bp: (f32[1024], s32[])) -> (f32[1024], s32[]) {\n"
        "  %bp = (f32[1024], s32[]) parameter(0)\n"
        "  %gteb = f32[1024] get-tuple-element((f32[1024], s32[]) %bp), "
        "index=0\n"
        "  %arb = f32[1024] all-reduce(f32[1024] %gteb), "
        "replica_groups={{0,1}}, to_apply=%add\n"
        "  %iv = s32[] get-tuple-element((f32[1024], s32[]) %bp), index=1\n"
        "  ROOT %tup = (f32[1024], s32[]) tuple(f32[1024] %arb, s32[] %iv)\n"
        "}\n\n"
        "%cond (cp: (f32[1024], s32[])) -> pred[] {\n"
        "  %cp = (f32[1024], s32[]) parameter(0)\n"
        "  %iv2 = s32[] get-tuple-element((f32[1024], s32[]) %cp), index=1\n"
        "  %c3 = s32[] constant(3)\n"
        "  ROOT %cmp = pred[] compare(s32[] %iv2, s32[] %c3), direction=LT\n"
        "}\n\n")
    tr = parse_hlo(_entry_hlo([
        "%p0 = f32[1024] parameter(0)",
        "%ars = (f32[1024], f32[1024]) all-reduce-start(f32[1024] %p0), "
        "replica_groups={{0,1}}, to_apply=%add",
        "%mul = f32[1024] multiply(f32[1024] %p0, f32[1024] %p0)",
        "%ard = f32[1024] all-reduce-done((f32[1024], f32[1024]) %ars)",
        "%z = s32[] constant(0)",
        "%t0 = (f32[1024], s32[]) tuple(f32[1024] %ard, s32[] %z)",
        "%w = (f32[1024], s32[]) while((f32[1024], s32[]) %t0), "
        "condition=%cond, body=%body",
        "ROOT %res = f32[1024] get-tuple-element((f32[1024], s32[]) %w), "
        "index=0",
    ], extra_comps=comps))
    assert len(tr.records) == 2
    async_rec = next(r for r in tr.records if r.computation == "main")
    loop_rec = next(r for r in tr.records if r.computation == "body")
    assert async_rec.is_async and async_rec.done_index > async_rec.start_index
    assert async_rec.payload_bytes == 4096 and async_rec.trips == 1
    assert not loop_rec.is_async and loop_rec.trips == 3
    assert loop_rec.wire_bytes == 3 * 4096


# -------------------------------------------------- golden traces (1 device)
@pytest.mark.parametrize("name", sorted(prg.NAMED_PROGRAMS))
def test_named_program_lints_clean(name):
    """Both levels clean on the 1-device mesh: the jaxpr rules and the
    compiled-HLO cross-check (the 4/8-device goldens run via the CLI below)."""
    rep = lint_program_on_mesh(prg.named_program(name), n_devices=1, hlo=True)
    assert rep["codes"] == [], rep["findings"]
    if rep["schedule"] != "moe_alltoall":
        # (the degenerate 1-device mesh traces the MoE exchange away; the
        # multi-device golden below pins its 2 all_to_alls)
        assert rep["records"] >= 1
    assert set(rep["kinds"]) <= COLLECTIVE_KINDS
    h = rep["hlo"]
    assert h["records"] >= 0 and "static_overlap" in h
    for fam, d in h["byte_deltas"].items():
        assert d["rel_delta"] <= 0.05, (fam, d)


def test_lint_cli_rejects_unknown_program():
    with pytest.raises(SystemExit, match="unknown program"):
        lint_main(["warp_speed"])


# ------------------------------------------------ negatives: one per code
# The xla-forcing legacy policy pins the dense wire to plain psum emission,
# so each mutation lands on a deterministic jaxpr.
def _xla_policy():
    return CollectivePolicy({2: []}, {2: []}, {"source": "measured"})


@functools.lru_cache(maxsize=None)
def _built_trace(**flags):
    """Trace a step built from train_step_program(**flags) on one device."""
    mesh = _make_mesh((1,), ("data",))
    params, batch = _dense_fixture(1)
    opt = adamw.OptConfig(peak_lr=1e-2, warmup_steps=0, decay_steps=10)
    step = build_program_step(_LintModel(), opt, mesh,
                              prg.train_step_program(**flags),
                              policy=_xla_policy())
    return trace_step(step, params, step.init_opt_state(params), batch,
                      step.init_error_state(params))


def _codes(findings):
    return sorted({f.code for f in findings})


def test_negative_gradient_allreduce_under_zero():
    """An allreduce-built step linted against the ZeRO program: the
    tensor-sized gradient psums violate both scalar-only rules."""
    tr = _built_trace(bucket_bytes=BUCKET)
    fs = lint_trace(tr, expected_trace(prg.train_step_program(zero=True)))
    assert _codes(fs) == ["full-gradient-allreduce-under-zero",
                          "non-scalar-psum"], [str(f) for f in fs]
    assert all(f.record is not None and not f.record.scalar for f in fs)


def test_negative_wire_dtype_widening():
    """An fp32-wire step against the int8 program: every gradient-sized fp32
    record is a widened leg (the scalar clip combines stay exempt)."""
    tr = _built_trace(bucket_bytes=BUCKET)
    fs = lint_trace(tr, expected_trace(
        prg.train_step_program(compress_bits=8)))
    assert "wire-dtype-widening" in _codes(fs), [str(f) for f in fs]
    wides = [f for f in fs if f.code == "wire-dtype-widening"]
    assert all(f.record.dtype == "float32" and
               f.record.payload_bytes >= 256 for f in wides)


def test_negative_collective_outside_overlap_scan():
    """A non-overlap step against the overlap program: the bucket reductions
    issue at scan depth 0 instead of riding the issue schedule."""
    tr = _built_trace(bucket_bytes=BUCKET)
    fs = lint_trace(tr, expected_trace(
        prg.train_step_program(overlap=True, bucket_bytes=BUCKET)))
    assert _codes(fs) == ["collective-outside-overlap-scan"], \
        [str(f) for f in fs]
    assert all(f.record.scan_depth == 0 for f in fs)


def test_negative_undonated_carrier():
    """The int8 overlap step is clean as built; stripping the donation of the
    error-feedback carrier (argnum 3) is the one finding introduced."""
    tr = _built_trace(overlap=True, compress_bits=8, bucket_bytes=BUCKET)
    exp = expected_trace(prg.train_step_program(
        overlap=True, compress_bits=8, bucket_bytes=BUCKET))
    assert exp.require_donation == 3
    assert lint_trace(tr, exp) == [], \
        [str(f) for f in lint_trace(tr, exp)]
    stripped = dataclasses.replace(tr, donate_argnums=())
    fs = lint_trace(stripped, exp)
    assert _codes(fs) == ["undonated-carrier"], [str(f) for f in fs]


def test_negative_unplanned_collective():
    """A ZeRO-built step against the allreduce program: reduce_scatter is a
    kind the program never declared — and a stray kind does not also trip
    the wire rules (it reports once, as itself)."""
    tr = _built_trace(zero=True)
    fs = lint_trace(tr, expected_trace(
        prg.train_step_program(bucket_bytes=0)))
    assert _codes(fs) == ["unplanned-collective"], [str(f) for f in fs]
    assert {f.record.kind for f in fs} == {"reduce_scatter"}


def test_negative_unbucketed_concat():
    """Per-leaf concatenation (O(leaves) concatenates) against a bucketized
    program's O(1) codec cap."""
    def pack(xs):
        return functools.reduce(
            lambda a, b: jnp.concatenate([a, b]), xs)

    jx = jax.make_jaxpr(pack)([jnp.ones((4,), jnp.float32)] * 12)
    tr = trace_jaxpr(jx)
    assert tr.n_concats == 11
    fs = lint_trace(tr, expected_trace(
        prg.train_step_program(bucket_bytes=BUCKET)))
    assert _codes(fs) == ["unbucketed-concat"], [str(f) for f in fs]


def test_negative_byte_budget_exceeded():
    """An explicit (absurdly small) budget: the clean allreduce step exceeds
    it through exact payload x scan-trip accounting, scalars excluded."""
    tr = _built_trace(bucket_bytes=BUCKET)
    fs = lint_trace(tr, expected_trace(
        prg.train_step_program(bucket_bytes=BUCKET), byte_budget=1.0))
    assert _codes(fs) == ["byte-budget-exceeded"], [str(f) for f in fs]
    # and the real derived budget clears the same trace
    grad = sum(p.size * p.dtype.itemsize
               for p in jax.tree.leaves(_dense_fixture(1)[0]))
    clean = lint_trace(tr, expected_trace(
        prg.train_step_program(bucket_bytes=BUCKET), grad_bytes=grad))
    assert clean == [], [str(f) for f in clean]


# --------------------------- negatives: one per compiled-HLO finding code
# Synthetic post-SPMD modules (the CPU lowering never emits async pairs or
# rewrites, so the goldens above can't trip these) cross-checked against a
# hand-built jaxpr trace and the program expectation.
def _jx(*recs):
    return CollectiveTrace(records=tuple(recs))


def _jrec(kind, payload, trips=1, dtype="float32"):
    return CollectiveRecord(kind=kind, axes=("data",), dtype=dtype,
                            shape=(payload // 4,), payload_bytes=payload,
                            scalar=False, scan_depth=0, scan_trips=trips)


def _exp(n=2, **kw):
    return expected_trace(prg.train_step_program(bucket_bytes=BUCKET),
                          n_devices=n, **kw)


def test_negative_collective_rewritten():
    """The compiled module moves half the bytes the jaxpr issued: the
    partitioner changed what rides the wire."""
    htr = parse_hlo(_entry_hlo([
        "%p0 = f32[512] parameter(0)",
        "ROOT %ar = f32[512] all-reduce(f32[512] %p0), "
        "replica_groups={{0,1}}, to_apply=%add",
    ]))
    fs = crosscheck_trace(_jx(_jrec("psum", 4096)), htr, _exp())
    assert _codes(fs) == ["collective-rewritten"], [str(f) for f in fs]
    # ...and a psum legitimately lowered to a one-shot all-gather of the
    # same input payload stays clean (family matching, not kind matching)
    htr_ag = parse_hlo(_entry_hlo([
        "%p0 = f32[1024] parameter(0)",
        "ROOT %ag = f32[2048] all-gather(f32[1024] %p0), "
        "replica_groups={{0,1}}, dimensions={0}",
    ]))
    assert crosscheck_trace(_jx(_jrec("psum", 4096)), htr_ag, _exp()) == []


def test_negative_trip_count_mismatch():
    """Per-issue payloads agree but the HLO while runs 2 trips against the
    jaxpr's 4-trip scan: only the execution multiplier diverged."""
    comps = (
        "%body (bp: (f32[1024], s32[])) -> (f32[1024], s32[]) {\n"
        "  %bp = (f32[1024], s32[]) parameter(0)\n"
        "  %gteb = f32[1024] get-tuple-element((f32[1024], s32[]) %bp), "
        "index=0\n"
        "  %arb = f32[1024] all-reduce(f32[1024] %gteb), "
        "replica_groups={{0,1}}, to_apply=%add\n"
        "  %iv = s32[] get-tuple-element((f32[1024], s32[]) %bp), index=1\n"
        "  ROOT %tup = (f32[1024], s32[]) tuple(f32[1024] %arb, s32[] %iv)\n"
        "}\n\n"
        "%cond (cp: (f32[1024], s32[])) -> pred[] {\n"
        "  %cp = (f32[1024], s32[]) parameter(0)\n"
        "  %iv2 = s32[] get-tuple-element((f32[1024], s32[]) %cp), index=1\n"
        "  %c2 = s32[] constant(2)\n"
        "  ROOT %cmp = pred[] compare(s32[] %iv2, s32[] %c2), direction=LT\n"
        "}\n\n")
    htr = parse_hlo(_entry_hlo([
        "%p0 = f32[1024] parameter(0)",
        "%z = s32[] constant(0)",
        "%t0 = (f32[1024], s32[]) tuple(f32[1024] %p0, s32[] %z)",
        "%w = (f32[1024], s32[]) while((f32[1024], s32[]) %t0), "
        "condition=%cond, body=%body",
        "ROOT %res = f32[1024] get-tuple-element((f32[1024], s32[]) %w), "
        "index=0",
    ], extra_comps=comps))
    (rec,) = htr.records
    assert rec.trips == 2
    fs = crosscheck_trace(_jx(_jrec("psum", 4096, trips=4)), htr, _exp())
    assert _codes(fs) == ["trip-count-mismatch"], [str(f) for f in fs]
    # the matching trip count is clean
    assert crosscheck_trace(_jx(_jrec("psum", 4096, trips=2)), htr,
                            _exp()) == []


def test_negative_wire_widened_post_spmd():
    """A convert from int8 feeding an fp32 collective: the wire format was
    widened after partitioning (dequantize-then-communicate)."""
    htr = parse_hlo(_entry_hlo([
        "%p0 = s8[1024] parameter(0)",
        "%cv = f32[1024] convert(s8[1024] %p0)",
        "ROOT %ar = f32[1024] all-reduce(f32[1024] %cv), "
        "replica_groups={{0,1}}, to_apply=%add",
    ]))
    (rec,) = htr.records
    assert rec.fed_by_convert == "int8"
    fs = crosscheck_trace(_jx(_jrec("psum", 4096)), htr, _exp())
    assert _codes(fs) == ["wire-widened-post-spmd"], [str(f) for f in fs]
    # a narrowing convert (quantize before the wire) is healthy
    htr_n = parse_hlo(_entry_hlo([
        "%p0 = f32[4096] parameter(0)",
        "%cv = s8[4096] convert(f32[4096] %p0)",
        "ROOT %ar = s8[4096] all-reduce(s8[4096] %cv), "
        "replica_groups={{0,1}}, to_apply=%add",
    ]))
    assert crosscheck_trace(_jx(_jrec("psum", 4096, dtype="int8")), htr_n,
                            _exp()) == []


def test_negative_dcn_misrouted():
    """A replica group spanning the pod stride in a single-tier program —
    and, the other direction, a hierarchical program whose compiled groups
    never span it (the two-tier plan was flattened)."""
    spanning = _entry_hlo([
        "%p0 = f32[256] parameter(0)",
        "ROOT %ag = f32[512] all-gather(f32[256] %p0), "
        "replica_groups={{0,2}}, dimensions={0}",
    ])
    htr = parse_hlo(spanning, pod_stride=2)
    fs = crosscheck_trace(_jx(_jrec("all_gather", 1024)), htr, _exp(n=4))
    assert _codes(fs) == ["dcn-misrouted"], [str(f) for f in fs]
    # hierarchical expectation, intra-only groups -> flattened hierarchy
    intra = _entry_hlo([
        "%p0 = f32[256] parameter(0)",
        "ROOT %ag = f32[512] all-gather(f32[256] %p0), "
        "replica_groups={{0,1}}, dimensions={0}",
    ])
    htr2 = parse_hlo(intra, pod_stride=2)
    fs2 = crosscheck_trace(_jx(_jrec("all_gather", 1024)), htr2,
                           _exp(n=4, dcn_axis="pod"))
    assert _codes(fs2) == ["dcn-misrouted"], [str(f) for f in fs2]
    # and the intra-tier group in a single-tier program is clean
    assert crosscheck_trace(_jx(_jrec("all_gather", 1024)),
                            parse_hlo(intra, pod_stride=2), _exp(n=4)) == []


def test_negative_overlap_lost_in_compilation():
    """An async start/done pair with nothing scheduled inside the window
    hides no compute; the same pair with a real op between stays clean."""
    empty = parse_hlo(_entry_hlo([
        "%p0 = f32[1024] parameter(0)",
        "%ars = (f32[1024], f32[1024]) all-reduce-start(f32[1024] %p0), "
        "replica_groups={{0,1}}, to_apply=%add",
        "ROOT %ard = f32[1024] all-reduce-done((f32[1024], f32[1024]) %ars)",
    ]))
    fs = crosscheck_trace(_jx(_jrec("psum", 4096)), empty, _exp())
    assert _codes(fs) == ["overlap-lost-in-compilation"], [str(f) for f in fs]
    filled = parse_hlo(_entry_hlo([
        "%p0 = f32[1024] parameter(0)",
        "%ars = (f32[1024], f32[1024]) all-reduce-start(f32[1024] %p0), "
        "replica_groups={{0,1}}, to_apply=%add",
        "%mul = f32[1024] multiply(f32[1024] %p0, f32[1024] %p0)",
        "ROOT %ard = f32[1024] all-reduce-done((f32[1024], f32[1024]) %ars)",
    ]))
    assert crosscheck_trace(_jx(_jrec("psum", 4096)), filled, _exp()) == []
    # the static scheduler sees the same distinction: the empty window
    # exposes all wire time, the filled one hides some of it
    so_empty, so_filled = static_exposed_comm(empty), static_exposed_comm(filled)
    assert so_empty.n_async == 1 and so_empty.hidden_fraction == 0.0
    assert so_empty.exposed_s == so_empty.comm_s > 0.0
    assert so_filled.overlapped_s > 0.0
    assert so_filled.exposed_s < so_filled.comm_s


# --------------------------------------------- golden traces (multi-device)
LINT_CLI = r"""
import json
import os
import tempfile

import repro.compat
from repro.core import program as prg
from repro.launch.lint import lint_program_on_mesh, main

path = os.path.join(tempfile.mkdtemp(), "lint_report.json")
assert main(["--hlo", "--all-named-programs", "--json", path]) == 0
data = json.load(open(path))
assert data["clean"] and data["hlo"]
for rep in data["reports"]:
    assert rep["codes"] == [], rep["findings"]
    # jaxpr-vs-HLO per-collective wire bytes agree within 5 percent
    for fam, d in rep["hlo"]["byte_deltas"].items():
        assert d["rel_delta"] <= 0.05, (rep["program"], fam, d)
# the hierarchical two-tier path: int8 chunked pipeline on a pod x data mesh
rep = lint_program_on_mesh(
    prg.train_step_program(overlap=True, compress_bits=8, chunks=2,
                           bucket_bytes=1 << 20),
    dcn=2, hlo=True)
assert rep["codes"] == [], rep["findings"]
print("ALL_OK")
"""


@pytest.mark.slow
@pytest.mark.parametrize("n", [4, 8])
def test_lint_cli_clean_multi_device(n):
    """`python -m repro.launch.lint --hlo --all-named-programs` exits 0 —
    every named program is clean at BOTH levels (jaxpr rules and the
    compiled-HLO cross-check) on real multi-device meshes, with jaxpr-vs-HLO
    wire bytes within the 5% tolerance, and `--json` round-trips."""
    assert "ALL_OK" in run_devices(LINT_CLI, n, timeout=560)


def test_lint_cli_json_report(tmp_path):
    """`--json` writes the machine-readable report (single program, one
    device: fast enough for tier-1)."""
    path = tmp_path / "report.json"
    import json

    assert lint_main(["allreduce", "--devices", "1", "--hlo",
                      "--json", str(path)]) == 0
    data = json.loads(path.read_text())
    assert data["clean"] and data["hlo"]
    (rep,) = data["reports"]
    assert rep["program"] == "allreduce" and rep["codes"] == []
    assert {"records", "ops", "byte_deltas", "static_overlap"} \
        <= set(rep["hlo"])
