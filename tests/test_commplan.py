"""CommPlan: topology-derived tables, persistence compat, bucketed explicit DP."""
import json
import math

import pytest

from repro.core import collectives as coll
from repro.core.autotune import CollectivePolicy, PolicyEntry
from repro.core.commplan import (CommPlan, MAX_BUCKET_BYTES, MIN_BUCKET_BYTES,
                                 PlanEntry)
from repro.core.topology import make_paper_node_graphs, make_tpu_multipod, make_tpu_pod

from .helpers import run_devices


# ------------------------------------------------------------------- registry
def test_registry_has_all_algorithms():
    ar = coll.registered("all_reduce")
    assert {"ring", "bidir_ring", "rabenseifner", "recursive_doubling", "tree",
            "one_shot", "xla", "hierarchical"} <= set(ar)
    assert ar["hierarchical"].multi_axis
    assert ar["rabenseifner"].pow2_only
    # single-axis views exclude multi-axis variants
    assert "hierarchical" not in coll.ALL_REDUCE_ALGOS
    assert "bidir_ring" in coll.ALL_REDUCE_ALGOS
    assert set(coll.REDUCE_SCATTER_ALGOS) == {"ring", "xla"}
    assert set(coll.ALL_GATHER_ALGOS) == {"ring", "xla"}


def test_registry_unknown_name_raises():
    with pytest.raises(KeyError, match="no 'all_reduce' collective"):
        coll.get_collective("all_reduce", "nope")


# ------------------------------------------------------- topology -> tables
def test_plans_distinct_across_topologies():
    lumi = CommPlan.from_topology(make_paper_node_graphs()["lumi"])
    mp = CommPlan.from_topology(make_tpu_multipod())
    assert lumi.all_reduce_table != mp.all_reduce_table
    assert not lumi.hierarchical and mp.hierarchical
    assert lumi.meta["topology"] == "lumi_node"
    assert mp.meta["topology"].startswith("v5e_pod")


def test_tables_shaped_like_obs1():
    """Latency-optimal small, bandwidth-optimal large, for every axis size."""
    plan = CommPlan.from_topology(make_tpu_pod())
    for n, entries in plan.all_reduce_table.items():
        assert entries[-1].max_bytes == 1 << 62
        if n >= 8:
            small = plan.all_reduce_algo(256, n)
            large = plan.all_reduce_algo(1 << 28, n)
            assert small in ("one_shot", "recursive_doubling", "tree")
            assert large in ("ring", "bidir_ring", "rabenseifner")


def test_hierarchical_dispatch_selection():
    mp = CommPlan.from_topology(make_tpu_multipod())
    assert mp.all_reduce_algo(1 << 20, 256, dcn=True) == "hierarchical"
    # single-level plans never pick it, even when asked about the dcn path
    lumi = CommPlan.from_topology(make_paper_node_graphs()["lumi"])
    assert lumi.all_reduce_algo(1 << 20, 8, dcn=True) != "hierarchical"


def test_pow2_fallback_on_odd_axis():
    plan = CommPlan.from_topology(make_tpu_pod())
    algo = plan.all_reduce_algo(1 << 28, 6)
    spec = coll.registered("all_reduce")[algo]
    assert not spec.pow2_only


def test_alltoall_forced_pairwise_beyond_512():
    plan = CommPlan.from_topology(make_tpu_multipod())
    assert plan.all_to_all_algo(1 << 20, 1024) == "pairwise"


def test_bucket_bytes_from_crossover():
    for topo in (make_paper_node_graphs()["lumi"], make_tpu_multipod()):
        plan = CommPlan.from_topology(topo)
        assert MIN_BUCKET_BYTES <= plan.bucket_bytes <= MAX_BUCKET_BYTES
        assert plan.bucket_bytes & (plan.bucket_bytes - 1) == 0  # power of two


# ---------------------------------------------------------------- persistence
def test_plan_json_roundtrip(tmp_path):
    plan = CommPlan.from_topology(make_tpu_multipod())
    f = tmp_path / "plan.json"
    plan.save(str(f))
    back = CommPlan.load(str(f))
    assert back.all_reduce_table == plan.all_reduce_table
    assert back.reduce_scatter_table == plan.reduce_scatter_table
    assert back.bucket_bytes == plan.bucket_bytes
    assert back.hierarchical == plan.hierarchical


def test_policy_roundtrip_new_format(tmp_path):
    p = CollectivePolicy.from_model()
    f = tmp_path / "policy.json"
    p.save(str(f))
    q = CollectivePolicy.load(str(f))
    for n in p.all_reduce_table:
        for nbytes in (1024, 1 << 20, 1 << 28):
            assert p.all_reduce_algo(nbytes, n) == q.all_reduce_algo(nbytes, n)
    assert q.bucket_bytes == p.bucket_bytes
    assert q.plan.hierarchical == p.plan.hierarchical


def test_policy_load_legacy_format(tmp_path):
    """Old (pre-CommPlan) policy files: all_reduce/all_to_all/meta only."""
    legacy = {
        "meta": {"source": "model"},
        "all_reduce": {"8": [{"max_bytes": 65536, "algorithm": "recursive_doubling"},
                             {"max_bytes": 1 << 62, "algorithm": "ring"}]},
        "all_to_all": {"8": [{"max_bytes": 1 << 62, "algorithm": "xla"}]},
    }
    f = tmp_path / "legacy.json"
    f.write_text(json.dumps(legacy))
    p = CollectivePolicy.load(str(f))
    assert p.all_reduce_algo(1024, 8) == "recursive_doubling"
    assert p.all_reduce_algo(1 << 28, 8) == "ring"
    assert p.all_to_all_algo(1024, 8) == "xla"
    # plan-only fields come back as safe defaults
    assert not p.plan.hierarchical
    assert p.bucket_bytes > 0
    assert isinstance(p.all_reduce_table[8][0], PolicyEntry)


def test_legacy_entry_alias():
    # PolicyEntry must remain the same dataclass as PlanEntry (shared tables)
    assert PolicyEntry is PlanEntry


# -------------------------------------------------- bucketing + dispatch e2e
BUCKETED_DP = r"""
import math
import jax, jax.numpy as jnp, numpy as np
import repro.compat
from jax.sharding import AxisType
from repro.configs import get_config
from repro.configs.base import ShapeConfig
from repro.models import build_model
from repro.optim import adamw
from repro.runtime import steps as rsteps
from repro.core.autotune import CollectivePolicy
from repro.core.commplan import CommPlan
from repro.core.topology import make_tpu_multipod

cfg = get_config("smollm-135m").reduced()
shape = ShapeConfig("t", 32, 8, "train")
model = build_model(cfg)
opt = adamw.OptConfig(peak_lr=1e-3, warmup_steps=1, decay_steps=20)
params = model.init(jax.random.PRNGKey(0))
ostate = adamw.init_opt_state(params)
batch = model.make_batch(shape)
err = rsteps.init_error_state(params)
tonp = lambda t: [np.asarray(jax.device_get(a)).astype(np.float32)
                  for a in jax.tree.leaves(t)]

mesh = jax.make_mesh((4,), ("data",), axis_types=(AxisType.Auto,))
pol = CollectivePolicy.from_model()
total_bytes = sum(p.size for p in jax.tree.leaves(params)) * 4
bucket = 1 << 20

step0 = rsteps.build_explicit_dp_step(model, opt, mesh, "data", policy=pol,
                                      bucket_bytes=0)
p0, o0, m0, _ = step0(params, ostate, batch, err)
pol.plan.reset_stats()
step1 = rsteps.build_explicit_dp_step(model, opt, mesh, "data", policy=pol,
                                      bucket_bytes=bucket)
p1, o1, m1, _ = step1(params, ostate, batch, err)

# bucketing is a pure re-chunking: identical numerics
assert abs(float(m0["loss"]) - float(m1["loss"])) < 1e-6
d = max(np.max(np.abs(a - b)) for a, b in zip(tonp(p0), tonp(p1)))
assert d < 1e-6, d
# and <= ceil(total/bucket) + 1 all-reduces (trace-time counter)
calls = pol.plan.stats["all_reduce_calls"]
assert calls <= math.ceil(total_bytes / bucket) + 1, calls
print("bucketed ok", calls)

# hierarchical dispatch on a (pod, data) mesh with a two-level plan
mesh2 = jax.make_mesh((2, 4), ("pod", "data"), axis_types=(AxisType.Auto,)*2)
plan2 = CommPlan.from_topology(make_tpu_multipod())
plan2.reset_stats()
step2 = rsteps.build_explicit_dp_step(model, opt, mesh2, "data",
                                      policy=CollectivePolicy.from_plan(plan2),
                                      bucket_bytes=bucket, dcn_axis="pod")
p2, o2, m2, _ = step2(params, ostate, batch, err)
assert plan2.stats["hierarchical_calls"] > 0
assert np.isfinite(float(m2["loss"]))
# same global batch, 8-way vs 4-way mean: grads agree modulo reassociation
d2 = max(np.max(np.abs(a - b)) for a, b in zip(tonp(p0), tonp(p2)))
assert d2 < 5e-2, d2
print("ALL_OK")
"""


@pytest.mark.slow
def test_bucketed_explicit_dp_8dev():
    assert "ALL_OK" in run_devices(BUCKETED_DP, 8, timeout=560)
