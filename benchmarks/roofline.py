"""Roofline table from the dry-run artifacts (EXPERIMENTS.md §Roofline)."""
from __future__ import annotations

import json
import sys
from pathlib import Path

ARTIFACTS = Path("artifacts/dryrun")


def load_cells(variant: str = "baseline", mesh: str = "pod16x16"):
    cells = []
    for f in sorted(ARTIFACTS.glob(f"*__{mesh}__{variant}.json")):
        cells.append(json.loads(f.read_text()))
    return cells


def table(variant: str = "baseline", mesh: str = "pod16x16", out=sys.stdout) -> list:
    cells = load_cells(variant, mesh)
    rows = []
    hdr = (f"{'arch':>20s} {'shape':<12s} {'dom':<8s} {'compute':>9s} {'memory':>9s} "
           f"{'ici':>8s} {'dcn':>8s} {'bound':>9s} {'useful':>6s} {'mfu<=':>6s} "
           f"{'mem/dev':>8s} fits")
    print(hdr, file=out)
    for c in cells:
        if c["status"] == "skipped":
            print(f"{c['arch']:>20s} {c['shape']:<12s} SKIPPED ({c['reason'][:58]})", file=out)
            rows.append(c)
            continue
        if c["status"] != "ok":
            print(f"{c['arch']:>20s} {c['shape']:<12s} ERROR {c.get('error','')[:70]}", file=out)
            rows.append(c)
            continue
        r = c["roofline"]
        m = c["memory"]
        print(f"{c['arch']:>20s} {c['shape']:<12s} {r['dominant'][:-2]:<8s} "
              f"{r['compute_s']*1e3:8.1f}m {r['memory_s']*1e3:8.1f}m "
              f"{r['ici_s']*1e3:7.1f}m {r['dcn_s']*1e3:7.1f}m "
              f"{r['step_time_bound_s']*1e3:8.1f}m {r['useful_compute_ratio']:6.2f} "
              f"{r['mfu_bound']:6.3f} {m['peak_per_device']/1e9:7.2f}G "
              f"{'Y' if m['fits_16g'] else 'N'}", file=out)
        rows.append(c)
    return rows


def pick_hillclimb_cells(variant: str = "baseline"):
    """The three most interesting cells: worst roofline fraction (mfu_bound),
    most collective-bound, most representative of the technique (seq-sharded
    long-context decode)."""
    cells = [c for c in load_cells(variant) if c["status"] == "ok"]
    train = [c for c in cells if c["shape"] == "train_4k"]
    worst = min(train, key=lambda c: c["roofline"]["mfu_bound"])
    coll = max(cells, key=lambda c: c["roofline"]["ici_s"] + c["roofline"]["dcn_s"])
    rep = next((c for c in cells if c["shape"] == "long_500k"), None)
    return {"worst_mfu": worst, "most_collective_bound": coll, "technique_representative": rep}


def main():
    mesh = sys.argv[1] if len(sys.argv) > 1 else "pod16x16"
    variant = sys.argv[2] if len(sys.argv) > 2 else "baseline"
    table(variant, mesh)
    print()
    picks = pick_hillclimb_cells(variant)
    for k, c in picks.items():
        if c:
            print(f"hillclimb[{k}]: {c['arch']} / {c['shape']} "
                  f"(dom={c['roofline']['dominant']}, mfu<={c['roofline']['mfu_bound']:.3f})")


if __name__ == "__main__":
    main()
