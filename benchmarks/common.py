"""Shared benchmark plumbing: subprocess multi-device runs + CSV artifacts."""
from __future__ import annotations

import os
import subprocess
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
SRC = str(REPO / "src")
OUT = REPO / "artifacts" / "bench"


def run_devices(code: str, n_devices: int = 8, timeout: int = 560) -> str:
    """Run `code` in a fresh process with forced host devices; return stdout."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    res = subprocess.run([sys.executable, "-c", code], env=env, capture_output=True,
                         text=True, timeout=timeout, cwd=str(REPO))
    if res.returncode != 0:
        raise RuntimeError(f"bench subprocess failed:\n{res.stderr[-2000:]}")
    return res.stdout


def out_path(name: str) -> Path:
    OUT.mkdir(parents=True, exist_ok=True)
    return OUT / name


def emit(name: str, rows: list, cols: list) -> None:
    """Print `name,us_per_call,derived` style CSV rows + save full CSV artifact."""
    import csv

    p = out_path(name + ".csv")
    with open(p, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=cols)
        w.writeheader()
        w.writerows(rows)
    for r in rows[: min(len(rows), 100)]:
        print(",".join(str(r.get(c, "")) for c in cols))
