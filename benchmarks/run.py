"""Benchmark driver: one section per paper figure + kernel/system benches.

  PYTHONPATH=src python -m benchmarks.run            # everything
  PYTHONPATH=src python -m benchmarks.run fig05 ...  # name filters

Prints `name,metric,value` style rows; full CSVs land in artifacts/bench/.
"""
from __future__ import annotations

import sys
import time
import traceback


def bench_kernels():
    """Interpret-mode kernel sanity timings + allclose (not perf — CPU)."""
    import numpy as np
    import jax.numpy as jnp
    from repro.kernels import ops, ref
    from .common import emit

    rows = []
    rng = np.random.RandomState(0)
    q = jnp.array(rng.randn(2, 256, 4, 64), jnp.float32)
    t0 = time.perf_counter()
    out = ops.flash_attention(q, q, q)
    dt = time.perf_counter() - t0
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(8, 256, 64)
    err = float(np.abs(np.asarray(out) -
                       np.asarray(ref.attention_ref(fold(q), fold(q), fold(q))
                                  .reshape(2, 4, 256, 64).transpose(0, 2, 1, 3))).max())
    rows.append({"name": "flash_attention_interpret", "us_per_call": dt * 1e6,
                 "derived": f"maxerr={err:.2e}"})
    x = jnp.array(rng.randn(64, 2048), jnp.bfloat16)
    sc = jnp.ones((2048,), jnp.bfloat16)
    t0 = time.perf_counter()
    ops.rmsnorm(x, sc)
    rows.append({"name": "rmsnorm_interpret", "us_per_call": (time.perf_counter() - t0) * 1e6,
                 "derived": ""})
    emit("kernels", rows, ["name", "us_per_call", "derived"])
    return rows


def bench_train_step():
    """Wall-time of a reduced-config train step per family (CPU reference)."""
    import jax
    from repro.configs import get_config
    from repro.configs.base import ShapeConfig
    from repro.models import build_model
    from repro.optim import adamw
    from repro.runtime import steps as rsteps
    from .common import emit

    rows = []
    shape = ShapeConfig("bench", 64, 4, "train")
    for arch in ("smollm-135m", "deepseek-moe-16b", "mamba2-2.7b", "zamba2-7b"):
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        fn = jax.jit(rsteps.build_train_step(model, adamw.OptConfig()))
        params = model.init(jax.random.PRNGKey(0))
        opt = adamw.init_opt_state(params)
        batch = model.make_batch(shape)
        out = fn(params, opt, batch)
        jax.block_until_ready(out[2]["loss"])
        t0 = time.perf_counter()
        for _ in range(3):
            params, opt, m = fn(params, opt, batch)
            jax.block_until_ready(m["loss"])
        dt = (time.perf_counter() - t0) / 3
        rows.append({"name": f"train_step/{arch}-reduced", "us_per_call": dt * 1e6,
                     "derived": f"loss={float(m['loss']):.3f}"})
    emit("train_step", rows, ["name", "us_per_call", "derived"])
    return rows


def bench_roofline():
    from . import roofline
    print("== roofline (single pod, baseline) ==")
    roofline.table()
    return []


def bench_commplan():
    """CommPlan tables per topology: algorithm crossovers + bucket sizes.

    The planner's answer to paper Obs. 1/Fig. 11 — print where the chosen
    algorithm flips per (topology, axis size), and the gradient bucket size the
    latency/bandwidth crossover implies."""
    from repro.core.commplan import CommPlan
    from repro.core.topology import (make_paper_node_graphs, make_tpu_multipod,
                                     make_tpu_pod)
    from .common import emit

    topos = dict(make_paper_node_graphs())
    topos["tpu_pod"] = make_tpu_pod()
    topos["tpu_multipod"] = make_tpu_multipod()
    rows = []
    for tname, topo in topos.items():
        plan = CommPlan.from_topology(topo)
        for n, entries in sorted(plan.all_reduce_table.items()):
            desc = " | ".join(
                f"<=2^{e.max_bytes.bit_length()-1}:{e.algorithm}" if e.max_bytes < 1 << 62
                else f"rest:{e.algorithm}" for e in entries)
            rows.append({"name": f"commplan/{tname}/allreduce/n{n}",
                         "us_per_call": 0.0, "derived": desc})
        rows.append({"name": f"commplan/{tname}/bucket",
                     "us_per_call": 0.0,
                     "derived": f"{plan.bucket_bytes >> 20} MiB"
                                f" hier={plan.hierarchical}"})
    emit("commplan", rows, ["name", "us_per_call", "derived"])
    return rows


def bench_calibrate():
    """Measured calibration loop: live sweep -> alpha-beta fit -> versioned
    artifact -> plan re-ranked from measured goodput (the paper's
    measure-then-model workflow, Sec. III-A feeding Secs. IV-VI)."""
    import jax
    import repro.compat  # noqa: F401  (AxisType shim on older jax)
    from jax.sharding import AxisType
    from repro.core.calibrate import (CalibrationProfile, compare_to_model,
                                      plan_table_deltas, run_calibration)
    from repro.core.commplan import CommPlan
    from repro.core.costmodel import make_comm_model
    from .common import emit, out_path

    from repro.core.bench import SMALL_MAX_BYTES

    n = jax.device_count()
    mesh = jax.make_mesh((n,), ("x",), axis_types=(AxisType.Auto,))
    model = make_comm_model("tpu_v5e")
    # largest size must clear SMALL_MAX_BYTES *per endpoint* (sizes are split
    # across the mesh) or no 'large'-regime fits exist to re-rank from
    sizes = (1 << 10, 1 << 14, max(1 << 20, 2 * SMALL_MAX_BYTES * n))
    # emulate 2-endpoint nodes on the host mesh so the inter-tier sweep has
    # same_switch and diff_group pairs to classify (the TPU fabric's 256-chip
    # pods would make every host-device pair same_node)
    from repro.core.topology import Fabric
    bench_fabric = (Fabric("bench_df", "dragonfly", 2, 2, 1, max(n // 4, 2),
                           model.profile.nic_bw, model.profile.nic_bw)
                    if n >= 4 else None)
    profile, _records = run_calibration(mesh, "x", sizes=sizes, iters=5,
                                        model=model, fabric=bench_fabric)
    assert any(k.endswith("/large") for k in profile.params), \
        "sweep produced no bandwidth-regime fits"
    if bench_fabric is not None:
        assert any("@" in k for k in profile.params), \
            "fabric tier sweep produced no tier-qualified fits"
    path = out_path("calibration.json")
    profile.save(str(path))
    back = CalibrationProfile.load(str(path))
    assert back == profile, "calibration artifact failed save/load round-trip"
    topo = model.two_level or model.graph
    analytic = CommPlan.from_topology(topo, profile=model.profile)
    calibrated = CommPlan.from_topology(topo, profile=model.profile,
                                        calibration=back)
    deltas = plan_table_deltas(analytic, calibrated)
    rows = [{"name": f"calibrate/{r['key']}", "us_per_call": r["measured_us"],
             "derived": f"analytic={r['analytic_us']:.1f}us "
                        f"ratio={r['ratio']:.2f} r2={r['r2']:.2f}"}
            for r in compare_to_model(back, model)]
    rows.append({"name": "calibrate/bucket", "us_per_call": 0.0,
                 "derived": f"{analytic.bucket_bytes >> 10} -> "
                            f"{calibrated.bucket_bytes >> 10} KiB"})
    rows.append({"name": "calibrate/table_deltas", "us_per_call": 0.0,
                 "derived": f"{len(deltas)} entries re-ranked"
                            + (f"; e.g. {deltas[0]}" if deltas else "")})
    emit("calibrate", rows, ["name", "us_per_call", "derived"])
    return rows


def bench_at_scale():
    """At-scale scenario suite (paper Secs. V-VI): weak/strong scaling of
    allreduce/alltoall from 8 to 4096 endpoints over the three paper fabrics
    plus the TPU multipod, with the qualitative paper-shape self-checks.

    Closed-form over the Fabric layer — runs in seconds, so CI sweeps the
    full endpoint range."""
    from repro.core.bench import gbps
    from repro.core.scenarios import (PAPER_SYSTEMS, at_scale_suite,
                                      check_paper_shapes)
    from .common import emit

    rows = []
    for system in PAPER_SYSTEMS:
        checks = check_paper_shapes(system)
        bad = [k for k, ok in checks.items() if not ok]
        assert not bad, f"{system}: paper-shape checks failed: {bad}"
        rows.append({"name": f"at_scale/{system}/shape_checks",
                     "us_per_call": 0.0,
                     "derived": f"{len(checks)} ok"})
    for p in at_scale_suite(mechanisms=("ccl",)):
        if p.scaling == "weak":
            rows.append({
                "name": f"at_scale/{p.system}/{p.collective}/n{p.n_endpoints}",
                "us_per_call": p.seconds * 1e6,
                "derived": f"goodput={gbps(p.goodput_bytes_s):.1f}Gbps "
                           f"noisy={gbps(p.noisy_goodput_bytes_s):.1f} "
                           f"bound={gbps(p.bound_bytes_s):.1f} tier={p.tier}"})
    emit("at_scale", rows, ["name", "us_per_call", "derived"])
    return rows


def bench_overlap():
    """Overlap engine (paper Sec. VI / Obs. 1): predicted hidden fraction
    across the paper fabrics 8..4096 endpoints, the predictor's shape
    self-checks, and — when the process has >= 2 devices — a live explicit-DP
    overlap step on a small mesh (smoke for the scan-carried issue schedule +
    chunked hierarchical pipeline)."""
    import jax
    from repro.core.scenarios import (PAPER_SYSTEMS, check_overlap_shapes,
                                      sweep_overlap)
    from .common import emit

    rows = []
    for system in PAPER_SYSTEMS:
        checks = check_overlap_shapes(system)
        bad = [k for k, ok in checks.items() if not ok]
        assert not bad, f"{system}: overlap-shape checks failed: {bad}"
        rows.append({"name": f"overlap/{system}/shape_checks",
                     "us_per_call": 0.0, "derived": f"{len(checks)} ok"})
        for p in sweep_overlap(system, (8, 64, 512, 4096)):
            assert p.hidden_fraction > 0.0, \
                f"{system} n={p.n_endpoints}: no comm hidden"
            rows.append({
                "name": f"overlap/{system}/n{p.n_endpoints}",
                "us_per_call": p.exposed_s * 1e6,
                "derived": f"hidden={p.hidden_fraction:.2f} "
                           f"comm={p.total_comm_s*1e3:.1f}ms "
                           f"chunks={p.chunks} bucket={p.bucket_bytes >> 20}MiB"})
    if jax.device_count() >= 2:
        import time as _time
        import repro.compat  # noqa: F401
        from jax.sharding import AxisType
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.models import build_model
        from repro.optim import adamw
        from repro.runtime import steps as rsteps

        n = jax.device_count()
        mesh = jax.make_mesh((n,), ("data",), axis_types=(AxisType.Auto,))
        cfg = get_config("smollm-135m").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        ostate = adamw.init_opt_state(params)
        batch = model.make_batch(ShapeConfig("b", 32, 2 * n, "train"))
        err = rsteps.init_error_state(params)
        step = rsteps.build_explicit_dp_step(
            model, adamw.OptConfig(), mesh, "data", overlap=True,
            bucket_bytes=1 << 20, microbatches=2)
        out = step(params, ostate, batch, err)
        jax.block_until_ready(out[2]["loss"])
        t0 = _time.perf_counter()
        out = step(*out[:2], batch, out[3])
        jax.block_until_ready(out[2]["loss"])
        rows.append({"name": f"overlap/live/{n}dev_mb2",
                     "us_per_call": (_time.perf_counter() - t0) * 1e6,
                     "derived": f"loss={float(out[2]['loss']):.3f}"})
    emit("overlap", rows, ["name", "us_per_call", "derived"])
    return rows


def bench_wire():
    """Fused wire codec vs the unfused pack/unpack (paper Obs. 1/4/5: the
    software wastes the wire, not the fabric): wall time and jaxpr op counts
    of the two gradient wire paths, the packed step's O(1)-concatenate
    property, per-tier wire decisions + wire bytes per step, and the
    scenario-suite wall time under the memoized factories.  Also writes a
    machine-readable BENCH_5.json at the repo root so the perf trajectory
    accumulates across PRs."""
    import json
    from pathlib import Path

    import numpy as np
    import jax
    import jax.numpy as jnp
    import repro.compat  # noqa: F401
    from repro.core import overlap as ov
    from repro.core import wire as wr
    from repro.core.commplan import CommPlan
    from repro.core.scenarios import (PAPER_SYSTEMS, at_scale_suite,
                                      sweep_overlap)
    from repro.core.topology import make_paper_systems
    from repro.kernels import bucket_codec as bc
    from .common import emit

    rows = []
    bench = {"pr": 5, "section": "wire"}

    # ---- pack/unpack: unfused (concat-per-bucket) vs codec (fused dus/slice)
    rng = np.random.RandomState(0)
    shapes = [(1024, 64)] + [(64, 64)] * 40 + [(64,)] * 41  # transformer-ish
    flat = [jnp.asarray(rng.randn(*s).astype(np.float32)) for s in shapes]
    sizes = [g.size for g in flat]
    cap = (64 << 10) // 4
    buckets = ov.make_buckets(sizes, cap)
    table = bc.make_table(sizes, cap)

    # the carrier crosses a collective in the real step — an optimization
    # barrier models that boundary (without it XLA elides the unfused
    # pack+unpack round-trip entirely and the comparison is fiction)
    def unfused(flat):
        stacked = ov.pack_buckets(flat, buckets, 0.5)
        stacked = jax.lax.optimization_barrier(stacked)
        return ov.unpack_buckets(stacked, buckets, flat)

    def codec(flat):
        carrier, _, _ = bc.pack(table, flat, scale=0.5, impl="xla")
        carrier = jax.lax.optimization_barrier(carrier)
        return bc.unpack(table, carrier, flat, impl="xla")

    from repro.launch.hlo_analysis import count_jaxpr_eqns as count

    def timeit(fn, *args, iters=10):
        out = fn(*args)
        jax.block_until_ready(out)
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            out = fn(*args)
            jax.block_until_ready(out)
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    f_old, f_new = jax.jit(unfused), jax.jit(codec)
    t_old, t_new = timeit(f_old, flat), timeit(f_new, flat)
    jx_old = jax.make_jaxpr(unfused)(flat)
    jx_new = jax.make_jaxpr(codec)(flat)
    ops_old, ops_new = count(jx_old), count(jx_new)
    cat_old, cat_new = (count(jx_old, "concatenate"),
                        count(jx_new, "concatenate"))
    assert ops_new < ops_old, (ops_new, ops_old)
    assert cat_new <= 1 < cat_old, (cat_new, cat_old)
    # gross-regression tripwire only: the deterministic guarantees are the
    # op-count asserts above; wall clock on shared CI runners is noisy, so
    # the slack is wide (the codec measures 2-6x faster here — it would have
    # to become genuinely slower than the unfused path to trip this)
    assert t_new <= t_old * 2.0, (t_new, t_old)
    rows.append({"name": "wire/pack_unpack/unfused", "us_per_call": t_old * 1e6,
                 "derived": f"ops={ops_old} concats={cat_old}"})
    rows.append({"name": "wire/pack_unpack/codec", "us_per_call": t_new * 1e6,
                 "derived": f"ops={ops_new} concats={cat_new} "
                            f"speedup={t_old / t_new:.2f}x"})
    bench["pack_unpack"] = {
        "leaves": len(flat), "buckets": table.n_buckets,
        "unfused_us": t_old * 1e6, "codec_us": t_new * 1e6,
        "unfused_ops": ops_old, "codec_ops": ops_new,
        "unfused_concats": cat_old, "codec_concats": cat_new,
    }

    # ---- per-tier wire decisions + wire bytes per step across paper fabrics
    bench["wire_plans"] = {}
    grad_bytes = float(sum(sizes) * 4)
    for system in PAPER_SYSTEMS:
        plan = CommPlan.from_topology(make_paper_systems()[system])
        spec = plan.wire_spec()
        nb = max(-(-int(grad_bytes) // plan.bucket_bytes), 1)
        wired = wr.bytes_on_wire(grad_bytes, spec.inter, nb)
        pr = sweep_overlap(system, (4096,), wire="plan")[0]
        fp = sweep_overlap(system, (4096,))[0]
        rows.append({"name": f"wire/plan/{system}", "us_per_call": 0.0,
                     "derived": f"{spec.intra}/{spec.inter} "
                                f"inter_bytes={wired / grad_bytes:.2f}x "
                                f"comm={pr.total_comm_s / fp.total_comm_s:.2f}x"})
        bench["wire_plans"][system] = {
            "intra": spec.intra, "inter": spec.inter,
            "inter_bytes_ratio": wired / grad_bytes,
            "comm_time_ratio_at_4096": pr.total_comm_s / fp.total_comm_s,
        }

    # ---- live overlapped explicit-DP step: fp32 wire vs composed int8 wire
    if jax.device_count() >= 2:
        from jax.sharding import AxisType
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.models import build_model
        from repro.optim import adamw
        from repro.runtime import steps as rsteps

        n = jax.device_count()
        mesh = jax.make_mesh((n,), ("data",), axis_types=(AxisType.Auto,))
        cfg = get_config("smollm-135m").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        ostate = adamw.init_opt_state(params)
        batch = model.make_batch(ShapeConfig("b", 32, 2 * n, "train"))
        step_times = {}
        for label, kw in (("fp32", {}), ("int8", {"compress_bits": 8})):
            step = rsteps.build_explicit_dp_step(
                model, adamw.OptConfig(), mesh, "data", overlap=True,
                bucket_bytes=1 << 20, **kw)
            err = step.init_error_state(params)
            out = step(params, ostate, batch, err)
            jax.block_until_ready(out[2]["loss"])
            t0 = time.perf_counter()
            out = step(params, ostate, batch, out[3])
            jax.block_until_ready(out[2]["loss"])
            dt = time.perf_counter() - t0
            step_times[label] = dt
            rows.append({"name": f"wire/live_step/{label}_{n}dev",
                         "us_per_call": dt * 1e6,
                         "derived": f"loss={float(out[2]['loss']):.3f}"})
        bench["live_step"] = {f"{k}_us": v * 1e6 for k, v in step_times.items()}
        bench["live_step"]["devices"] = n

    # ---- scenario-suite wall time (memoized topology/model factories)
    t0 = time.perf_counter()
    pts = at_scale_suite(mechanisms=("ccl",))
    suite_s = time.perf_counter() - t0
    rows.append({"name": "wire/scenario_suite", "us_per_call": suite_s * 1e6,
                 "derived": f"{len(pts)} points (memoized factories)"})
    bench["scenario_suite_s"] = suite_s

    path = Path(__file__).resolve().parent.parent / "BENCH_5.json"
    path.write_text(json.dumps(bench, indent=2))
    rows.append({"name": "wire/bench_artifact", "us_per_call": 0.0,
                 "derived": str(path)})
    emit("wire", rows, ["name", "us_per_call", "derived"])
    return rows


def bench_zero():
    """ZeRO three-phase wire path (RS -> sharded AdamW -> AG): planned wire
    bytes vs the allreduce schedule, optimizer-state memory / DP degree, and a
    live zero-vs-replicated step on the host devices.  Writes BENCH_6.json at
    the repo root so the perf trajectory accumulates across PRs."""
    import json
    from pathlib import Path

    import numpy as np
    import jax
    import repro.compat  # noqa: F401
    from repro.core import wire as wr
    from repro.core.commplan import CommPlan
    from repro.core.costmodel import exposed_comm_time
    from repro.core.scenarios import synthetic_grad_sizes
    from repro.core.topology import make_tpu_pod
    from .common import emit

    rows = []
    bench = {"pr": 6, "section": "zero"}

    # ---- planned wire bytes: RS + int8 AG vs 2x allreduce at n=8
    grad_bytes = 64 << 20
    nb = max(grad_bytes // (4 << 20), 1)
    zwb = wr.zero_wire_bytes(grad_bytes, 8, ag_fmt="int8", n_buckets=nb)
    assert zwb["ratio"] <= 0.6, zwb   # the PR's planning target
    zwb_fp = wr.zero_wire_bytes(grad_bytes, 8, ag_fmt="fp32", n_buckets=nb)
    rows.append({"name": "zero/wire_bytes/int8_ag_8dev", "us_per_call": 0.0,
                 "derived": f"ratio={zwb['ratio']:.3f} vs allreduce "
                            f"(fp32 ratio={zwb_fp['ratio']:.3f})"})
    bench["wire_bytes"] = {"grad_bytes": grad_bytes, "n": 8,
                           "int8_ag": zwb, "fp32_ag": zwb_fp}

    # ---- predicted exposed comm: zero vs allreduce schedule on the pod
    plan = CommPlan.from_topology(make_tpu_pod())
    sizes = synthetic_grad_sizes(grad_bytes)
    ar = exposed_comm_time(0.01, plan, sizes, n_endpoints=8)
    z8 = exposed_comm_time(0.01, plan, sizes, n_endpoints=8, schedule="zero",
                           wire={"intra": "int8", "inter": "int8"})
    rows.append({"name": "zero/predicted_comm/pod8", "us_per_call": 0.0,
                 "derived": f"zero_int8={z8.total_comm_s * 1e3:.2f}ms vs "
                            f"allreduce={ar.total_comm_s * 1e3:.2f}ms"})
    bench["predicted"] = {"allreduce_comm_s": ar.total_comm_s,
                          "zero_int8_comm_s": z8.total_comm_s}

    # ---- live step: replicated allreduce vs three-phase zero
    if jax.device_count() >= 2:
        from jax.sharding import AxisType
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.models import build_model
        from repro.optim import adamw
        from repro.runtime import steps as rsteps

        n = jax.device_count()
        mesh = jax.make_mesh((n,), ("data",), axis_types=(AxisType.Auto,))
        cfg = get_config("smollm-135m").reduced()
        model = build_model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = model.make_batch(ShapeConfig("b", 32, 2 * n, "train"))
        step_times = {}
        for label, kw in (("replicated", {}),
                          ("zero", {"zero": True}),
                          ("zero_int8", {"zero": True, "compress_bits": 8})):
            step = rsteps.build_explicit_dp_step(
                model, adamw.OptConfig(), mesh, "data", overlap=True,
                bucket_bytes=1 << 20, **kw)
            ostate = step.init_opt_state(params) if kw.get("zero") \
                else adamw.init_opt_state(params)
            err = step.init_error_state(params)
            out = step(params, ostate, batch, err)
            jax.block_until_ready(out[2]["loss"])
            ts = []
            for _ in range(3):
                t0 = time.perf_counter()
                out = step(params, ostate, batch, out[3])
                jax.block_until_ready(out[2]["loss"])
                ts.append(time.perf_counter() - t0)
            step_times[label] = float(np.median(ts))
            rows.append({"name": f"zero/live_step/{label}_{n}dev",
                         "us_per_call": step_times[label] * 1e6,
                         "derived": f"loss={float(out[2]['loss']):.3f}"})
            if kw.get("zero"):
                # optimizer memory: carrier-sharded m/v really is full / n
                m = out[1]["m"]
                shard_b = m.addressable_shards[0].data.nbytes
                assert shard_b * n == m.nbytes, (shard_b, n, m.nbytes)
                bench.setdefault("opt_state", {})[label] = {
                    "full_bytes": int(m.nbytes) * 2,
                    "per_device_bytes": int(shard_b) * 2}
        # gross-regression tripwire only: on a host-device CPU "fabric" the
        # collectives are memcpys, so zero's win is memory, not time — it
        # just must not be genuinely slower than the replicated step
        assert step_times["zero"] <= step_times["replicated"] * 2.0, step_times
        bench["live_step"] = {f"{k}_us": v * 1e6 for k, v in step_times.items()}
        bench["live_step"]["devices"] = n

    path = Path(__file__).resolve().parent.parent / "BENCH_6.json"
    path.write_text(json.dumps(bench, indent=2))
    rows.append({"name": "zero/bench_artifact", "us_per_call": 0.0,
                 "derived": str(path)})
    emit("zero", rows, ["name", "us_per_call", "derived"])
    return rows


def bench_moe():
    """StepProgram MoE section: planned alltoall step comm per system from 8
    to 4096 endpoints (the program pricer walking `moe_step_program()`), a
    live small-mesh expert-parallel step vs the dense explicit-DP baseline,
    and the program-vs-schedule pricing parity assert.  Writes BENCH_7.json
    at the repo root so the perf trajectory accumulates across PRs."""
    import json
    from pathlib import Path

    import numpy as np
    import jax
    import repro.compat  # noqa: F401
    from repro.core import program as prg
    from repro.core import scenarios as sc
    from repro.core.commplan import CommPlan
    from repro.core.costmodel import exposed_comm_time, make_comm_model
    from repro.core.scenarios import synthetic_grad_sizes
    from repro.core.topology import make_tpu_pod
    from .common import emit

    rows = []
    bench = {"pr": 7, "section": "moe"}

    # ---- one IR, two consumers: program pricing must equal the schedule
    # string it replaced (the refactor's no-regression contract)
    plan = CommPlan.from_topology(make_tpu_pod())
    sizes = synthetic_grad_sizes(64 << 20)
    for schedule, program in (("allreduce", prg.train_step_program()),
                              ("zero", prg.train_step_program(zero=True))):
        a = exposed_comm_time(0.01, plan, sizes, n_endpoints=8,
                              schedule=schedule)
        b = exposed_comm_time(0.01, plan, sizes, n_endpoints=8,
                              program=program)
        assert a == b, (schedule, a, b)
    rows.append({"name": "moe/program_pricer_parity", "us_per_call": 0.0,
                 "derived": "program== schedule for allreduce+zero"})

    # ---- planned MoE alltoall across the paper systems, 8 -> 4096 endpoints
    bench["sweep"] = {}
    for system in sc.PAPER_SYSTEMS:
        pts = sc.sweep_moe_alltoall(system, model=make_comm_model(system))
        shapes = sc.check_moe_shapes(system)
        assert all(shapes.values()), (system, shapes)
        bench["sweep"][system] = [
            {"n": p.n_endpoints, "algo": p.algo, "tier": p.tier,
             "step_comm_s": p.step_comm_s,
             "goodput_bytes_s": p.goodput_bytes_s} for p in pts]
        last = pts[-1]
        rows.append({"name": f"moe/planned_step/{system}_4096",
                     "us_per_call": last.step_comm_s * 1e6,
                     "derived": f"algo={last.algo} tier={last.tier}"})
        group, replicas = sc.moe_expert_placement(
            sc.make_paper_systems()[system], 4096)
        bench["sweep"][system + "_placement"] = {"ep_group": group,
                                                 "n_replicas": replicas}

    # ---- live small-mesh MoE step vs the dense explicit-DP baseline
    if jax.device_count() >= 2:
        from jax.sharding import AxisType
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.core.autotune import CollectivePolicy
        from repro.models import build_model
        from repro.optim import adamw
        from repro.runtime import moe_step as ms
        from repro.runtime import steps as rsteps

        n = jax.device_count()
        opt = adamw.OptConfig()
        step_times = {}

        cfg = get_config("deepseek-moe-16b").reduced()
        # EP axis must divide the expert count (E=4 reduced): on wider hosts
        # the MoE mesh uses the first E devices; the dense baseline uses all
        n_ep = min(n, cfg.n_experts)
        mesh_ep = jax.make_mesh((n_ep,), ("data",),
                                axis_types=(AxisType.Auto,),
                                devices=jax.devices()[:n_ep])
        policy = CollectivePolicy.from_model()
        pl = policy._as_plan()
        pl.reset_stats()
        step = rsteps.build_program_step(cfg, opt, mesh_ep,
                                         prg.moe_step_program(),
                                         policy=policy)
        params = ms.moe_ep_params(cfg, jax.random.PRNGKey(0))
        batch = ms.moe_ep_batch(cfg, jax.random.PRNGKey(1), 2 * n_ep, 32)
        ostate = adamw.init_opt_state(params)
        err = step.init_error_state(params)
        out = step(params, ostate, batch, err)
        jax.block_until_ready(out[2]["loss"])
        assert pl.stats.get("all_to_all_calls") == 2, pl.stats
        ts = []
        for _ in range(3):
            t0 = time.perf_counter()
            out = step(params, ostate, batch, out[3])
            jax.block_until_ready(out[2]["loss"])
            ts.append(time.perf_counter() - t0)
        step_times["moe_alltoall"] = float(np.median(ts))
        rows.append({"name": f"moe/live_step/moe_alltoall_{n_ep}dev",
                     "us_per_call": step_times["moe_alltoall"] * 1e6,
                     "derived": f"loss={float(out[2]['loss']):.3f} "
                                f"stats={pl.stats.get('all_to_all_algo/xla', 0)}x-xla"})

        dense_cfg = get_config("smollm-135m").reduced()
        model = build_model(dense_cfg)
        dparams = model.init(jax.random.PRNGKey(0))
        dbatch = model.make_batch(ShapeConfig("b", 32, 2 * n, "train"))
        mesh = jax.make_mesh((n,), ("data",), axis_types=(AxisType.Auto,))
        dstep = rsteps.build_program_step(model, opt, mesh,
                                          prg.named_program("allreduce"))
        dout = dstep(dparams, adamw.init_opt_state(dparams), dbatch,
                     dstep.init_error_state(dparams))
        jax.block_until_ready(dout[2]["loss"])
        ts = []
        for _ in range(3):
            t0 = time.perf_counter()
            dout = dstep(dparams, adamw.init_opt_state(dparams), dbatch,
                         dout[3])
            jax.block_until_ready(dout[2]["loss"])
            ts.append(time.perf_counter() - t0)
        step_times["dense_allreduce"] = float(np.median(ts))
        rows.append({"name": f"moe/live_step/dense_allreduce_{n}dev",
                     "us_per_call": step_times["dense_allreduce"] * 1e6,
                     "derived": f"loss={float(dout[2]['loss']):.3f}"})
        bench["live_step"] = {f"{k}_us": v * 1e6 for k, v in step_times.items()}
        bench["live_step"]["devices"] = n

        oracle = sc.moe_executed_path_oracle(cfg, mesh_ep)
        assert oracle["match"], oracle
        bench["executed_path"] = oracle
        rows.append({"name": "moe/executed_path_oracle", "us_per_call": 0.0,
                     "derived": f"modeled={oracle['modeled']} "
                                f"executed={oracle['executed']}"})

    path = Path(__file__).resolve().parent.parent / "BENCH_7.json"
    path.write_text(json.dumps(bench, indent=2))
    rows.append({"name": "moe/bench_artifact", "us_per_call": 0.0,
                 "derived": str(path)})
    emit("moe", rows, ["name", "us_per_call", "derived"])
    return rows


def bench_lint():
    """CommLint static-analysis section (PR 8): every named StepProgram is
    built on the host devices, its jaxpr traced into a CollectiveTrace, and
    linted against the ExpectedTrace compiled from its IR — all clean, by
    assert — plus the hierarchical two-tier chunked-int8 path on a pod x data
    mesh.  Tracing only, no execution; the per-program wall time is the cost
    of the CI gate itself.  Writes BENCH_8.json at the repo root so the
    trajectory accumulates across PRs."""
    import json
    from pathlib import Path

    import jax
    import repro.compat  # noqa: F401
    from repro.core import program as prg
    from repro.launch.lint import lint_named_programs, lint_program_on_mesh
    from .common import emit

    rows = []
    bench = {"pr": 8, "section": "lint", "devices": jax.device_count(),
             "programs": {}}
    reports = lint_named_programs()
    for rep in reports:
        assert not rep["findings"], (rep["program"], rep["findings"])
        rows.append({"name": f"lint/{rep['program']}",
                     "us_per_call": rep["seconds"] * 1e6,
                     "derived": f"records={rep['records']} "
                                f"kinds={','.join(rep['kinds'])} "
                                f"wire={rep['wire_bytes']}B clean"})
        bench["programs"][rep["program"]] = {
            k: rep[k] for k in ("n_devices", "records", "kinds",
                                "wire_bytes", "byte_budget", "seconds")}

    if jax.device_count() >= 4:
        rep = lint_program_on_mesh(
            prg.train_step_program(overlap=True, compress_bits=8, chunks=2,
                                   bucket_bytes=1 << 20), dcn=2)
        assert not rep["findings"], rep["findings"]
        rows.append({"name": "lint/hierarchical_int8_chunked",
                     "us_per_call": rep["seconds"] * 1e6,
                     "derived": f"records={rep['records']} "
                                f"kinds={','.join(rep['kinds'])} "
                                f"wire={rep['wire_bytes']}B clean (dcn=2)"})
        bench["hierarchical"] = {
            k: rep[k] for k in ("n_devices", "records", "kinds",
                                "wire_bytes", "byte_budget", "seconds")}

    bench["total_seconds"] = sum(r["seconds"] for r in reports)
    path = Path(__file__).resolve().parent.parent / "BENCH_8.json"
    path.write_text(json.dumps(bench, indent=2))
    rows.append({"name": "lint/bench_artifact", "us_per_call": 0.0,
                 "derived": str(path)})
    emit("lint", rows, ["name", "us_per_call", "derived"])
    return rows


def bench_hlolint():
    """ScheduleLint compiled-HLO section (PR 9): every named StepProgram is
    compiled, its post-SPMD module parsed into an HloTrace and cross-checked
    against the jaxpr CollectiveTrace and the program IR — all clean, by
    assert, with jaxpr-vs-HLO per-family wire bytes within the 5% tolerance
    — plus the hierarchical two-tier chunked-int8 path.  The per-program
    wall time now includes real XLA compilation (the cost of the `--hlo` CI
    gate).  Writes BENCH_9.json at the repo root so the trajectory
    accumulates across PRs."""
    import json
    from pathlib import Path

    import jax
    import repro.compat  # noqa: F401
    from repro.core import program as prg
    from repro.launch.lint import lint_named_programs, lint_program_on_mesh
    from .common import emit

    rows = []
    bench = {"pr": 9, "section": "hlolint", "devices": jax.device_count(),
             "programs": {}}
    reports = lint_named_programs(hlo=True)
    for rep in reports:
        assert not rep["findings"], (rep["program"], rep["findings"])
        h = rep["hlo"]
        worst = max((d["rel_delta"] for d in h["byte_deltas"].values()),
                    default=0.0)
        assert worst <= 0.05, (rep["program"], h["byte_deltas"])
        rows.append({"name": f"hlolint/{rep['program']}",
                     "us_per_call": rep["seconds"] * 1e6,
                     "derived": f"jaxpr={rep['records']} hlo={h['records']} "
                                f"async={h['n_async']} "
                                f"max_delta={worst:.1%} clean"})
        bench["programs"][rep["program"]] = {
            "n_devices": rep["n_devices"], "seconds": rep["seconds"],
            "jaxpr_records": rep["records"], "hlo_records": h["records"],
            "hlo_ops": h["ops"], "n_async": h["n_async"],
            "byte_deltas": h["byte_deltas"],
            "static_overlap": h["static_overlap"],
        }

    if jax.device_count() >= 4:
        rep = lint_program_on_mesh(
            prg.train_step_program(overlap=True, compress_bits=8, chunks=2,
                                   bucket_bytes=1 << 20), dcn=2, hlo=True)
        assert not rep["findings"], rep["findings"]
        h = rep["hlo"]
        rows.append({"name": "hlolint/hierarchical_int8_chunked",
                     "us_per_call": rep["seconds"] * 1e6,
                     "derived": f"jaxpr={rep['records']} hlo={h['records']} "
                                f"ops={h['ops']} clean (dcn=2)"})
        bench["hierarchical"] = {
            "n_devices": rep["n_devices"], "seconds": rep["seconds"],
            "hlo_records": h["records"], "hlo_ops": h["ops"],
            "byte_deltas": h["byte_deltas"],
        }

    bench["total_seconds"] = sum(r["seconds"] for r in reports)
    path = Path(__file__).resolve().parent.parent / "BENCH_9.json"
    path.write_text(json.dumps(bench, indent=2))
    rows.append({"name": "hlolint/bench_artifact", "us_per_call": 0.0,
                 "derived": str(path)})
    emit("hlolint", rows, ["name", "us_per_call", "derived"])
    return rows


def bench_faults():
    """FaultGuard messy-fabric section (PR 10): the modeled degradation family
    (core.scenarios.sweep_degradation) over the paper systems — guarded mean
    step time strictly below oblivious on every mitigable scenario, incast
    immune by Fig. 12 — plus a live guarded-vs-oblivious run on the host
    devices under the canonical seeded FaultPlan: same fabric perturbations,
    the guarded trainer detects drift, re-probes, lint-gates and swaps the
    plan mid-run, and ends with strictly fewer straggler-exposed steps.
    Writes BENCH_10.json at the repo root."""
    import json
    import tempfile
    from pathlib import Path

    import jax
    import repro.compat  # noqa: F401
    from repro.core.scenarios import (MESSY_SCENARIOS, check_degradation_shapes,
                                      sweep_degradation)
    from .common import emit

    rows = []
    bench = {"pr": 10, "section": "faults", "devices": jax.device_count(),
             "modeled": {}, "oracles": {}}

    # ---- modeled: guarded vs oblivious across scenarios and scale
    endpoints = (8, 64, 512, 4096)
    for system in ("leonardo", "alps"):
        for scen in MESSY_SCENARIOS:
            pts = sweep_degradation(system, scen, endpoints=endpoints)
            for p in pts:
                bench["modeled"][f"{system}/{scen}/n{p.n_endpoints}"] = {
                    "degradation_oblivious": round(p.degradation_oblivious, 4),
                    "degradation_guarded": round(p.degradation_guarded, 4),
                    "guarded_wins": p.guarded_wins}
            worst = max(pts, key=lambda p: p.degradation_oblivious)
            rows.append({"name": f"faults/{system}/{scen}",
                         "us_per_call": 0.0,
                         "derived": f"obl={worst.degradation_oblivious:.2f}x "
                                    f"grd={worst.degradation_guarded:.2f}x "
                                    f"@n{worst.n_endpoints} "
                                    f"wins={sum(p.guarded_wins for p in pts)}"
                                    f"/{len(pts)}"})
        oracles = check_degradation_shapes(system, endpoints=endpoints)
        # the two BENCH_10 acceptance gates, plus the full shape family
        assert oracles["congestion_strict_win"], (system, oracles)
        assert oracles["straggler_strict_win"], (system, oracles)
        assert all(oracles.values()), (system, oracles)
        bench["oracles"][system] = oracles
        rows.append({"name": f"faults/{system}/oracles", "us_per_call": 0.0,
                     "derived": f"{sum(oracles.values())}/{len(oracles)} pass"})

    # ---- live: guarded vs oblivious trainer under the same seeded plan
    if jax.device_count() >= 4:
        from jax.sharding import AxisType
        from repro.configs import get_config
        from repro.configs.base import ShapeConfig
        from repro.core.faults import FaultPlan
        from repro.runtime.guard import GuardConfig
        from repro.runtime.train import Trainer, TrainConfig

        cfg = get_config("smollm-135m").reduced()
        shape = ShapeConfig("t", 64, 4, "train")

        def live(guard):
            mesh = jax.make_mesh((4,), ("data",),
                                 axis_types=(AxisType.Auto,))
            tc = TrainConfig(
                steps=24, ckpt_every=8, ckpt_async=False,
                ckpt_dir=tempfile.mkdtemp(), log_every=100,
                explicit_dp=True, bucket_bytes=1 << 16,
                straggler_threshold=2.0,
                faults=FaultPlan.messy_fabric(seed=0, steps=24),
                guard=guard,
                guard_cfg=GuardConfig(patience=3, cooldown=6, lint=True,
                                      max_replans=2))
            t0 = time.perf_counter()
            out = Trainer(cfg, shape, train_cfg=tc, mesh=mesh).run()
            out["wall_s"] = time.perf_counter() - t0
            return out

        obl = live(False)
        grd = live(True)
        g = grd["guard"]
        replans = [e for e in g["events"] if e["kind"] == "replan"]
        # acceptance: guarded strictly beats oblivious under the identical
        # fault plan, via at least one committed, lint-clean mid-run replan
        assert grd["straggler_events"] < obl["straggler_events"], (
            grd["straggler_events"], obl["straggler_events"])
        assert g["n_replans"] >= 1, g
        for e in replans:
            assert not e["detail"].get("lint", {}).get("findings"), e
        rows.append({"name": "faults/live/oblivious_4dev",
                     "us_per_call": obl["wall_s"] * 1e6,
                     "derived": f"stragglers={obl['straggler_events']} "
                                f"retries={obl['retries']}"})
        rows.append({"name": "faults/live/guarded_4dev",
                     "us_per_call": grd["wall_s"] * 1e6,
                     "derived": f"stragglers={grd['straggler_events']} "
                                f"retries={grd['retries']} "
                                f"replans={g['n_replans']} lint=clean"})
        bench["live"] = {
            "steps": 24, "fault_plan": "messy:0",
            "oblivious": {"straggler_events": obl["straggler_events"],
                          "retries": obl["retries"],
                          "wall_s": round(obl["wall_s"], 2)},
            "guarded": {"straggler_events": grd["straggler_events"],
                        "retries": grd["retries"],
                        "n_replans": g["n_replans"],
                        "replan_steps": [e["step"] for e in replans],
                        "wall_s": round(grd["wall_s"], 2)},
            "fault_log": grd.get("fault_log", []),
        }

    path = Path(__file__).resolve().parent.parent / "BENCH_10.json"
    path.write_text(json.dumps(bench, indent=2))
    rows.append({"name": "faults/bench_artifact", "us_per_call": 0.0,
                 "derived": str(path)})
    emit("faults", rows, ["name", "us_per_call", "derived"])
    return rows


def main() -> None:
    from .figures import ALL_FIGURES

    filters = [a for a in sys.argv[1:] if not a.startswith("-")]
    sections = dict(ALL_FIGURES)
    sections["kernels"] = bench_kernels
    sections["train_step"] = bench_train_step
    sections["roofline"] = bench_roofline
    sections["commplan"] = bench_commplan
    sections["calibrate"] = bench_calibrate
    sections["at_scale"] = bench_at_scale
    sections["overlap"] = bench_overlap
    sections["wire"] = bench_wire
    sections["zero"] = bench_zero
    sections["moe"] = bench_moe
    sections["lint"] = bench_lint
    sections["hlolint"] = bench_hlolint
    sections["faults"] = bench_faults
    failures = []
    for name, fn in sections.items():
        if filters and not any(f in name for f in filters):
            continue
        print(f"\n===== {name} =====", flush=True)
        t0 = time.time()
        try:
            fn()
            print(f"[{name}: {time.time()-t0:.1f}s]", flush=True)
        except Exception:
            failures.append(name)
            traceback.print_exc()
    if failures:
        print("FAILED sections:", failures)
        sys.exit(1)
    print("\nall benchmark sections completed")


if __name__ == "__main__":
    main()
