"""Paper-figure analog benchmarks (Figs. 3-13), one function per figure.

Measured parts run on forced host devices (the container's "intra-node" fabric);
at-scale parts come from the calibrated cost models (CPU-only container — see
DESIGN.md Sec. 3).  Each emits a CSV artifact under artifacts/bench/ and prints
`name,metric,...` rows (the benchmarks/run.py contract).
"""
from __future__ import annotations

import numpy as np

from .common import emit, run_devices

MEASURE_CODE_TEMPLATE = r"""
import jax, jax.numpy as jnp, numpy as np, json
from repro.core import collectives as C  # installs repro.compat jax shims
from jax.sharding import PartitionSpec as P, AxisType
from repro.core.bench import time_fn, p2p_goodput, collective_goodput

mesh = jax.make_mesh((8,), ("x",), axis_types=(AxisType.Auto,))
sizes = {sizes}
rows = []
for nbytes in sizes:
    per = max(nbytes // 4 // 8, 1)
    x = np.random.randn(8, per).astype(np.float32)
    payload = per * 4
    {body}
print(json.dumps(rows))
"""


def _measure(body: str, sizes, n_devices: int = 8):
    import json

    code = MEASURE_CODE_TEMPLATE.format(sizes=list(sizes), body=body)
    out = run_devices(code, n_devices)
    return json.loads(out.strip().splitlines()[-1])


# ---------------------------------------------------------------- Fig. 3
def fig03_p2p_intranode():
    """Intra-node p2p goodput/latency across mechanisms.  Measured: ppermute
    ping-pong + staged host bounce on host devices; modeled: the three paper
    systems' dashed nominal lines."""
    body = r"""
    f = jax.jit(jax.shard_map(lambda v: C.ping_pong(v, 'x', 0, 1), mesh=mesh,
                              in_specs=P('x'), out_specs=P('x')))
    st = time_fn(f, x, iters=30, warmup=3)
    rows.append({"mechanism": "device_copy", "nbytes": payload,
                 "rtt_us": st.median * 1e6,
                 "goodput_gbps": p2p_goodput(payload, st.median) * 8 / 1e9})
    shards = [jax.device_put(x[i], d) for i, d in enumerate(mesh.devices.flat)]
    st = time_fn(lambda: C.staged_host_all_reduce(shards[:2]), iters=10, warmup=1)
    rows.append({"mechanism": "staging", "nbytes": payload,
                 "rtt_us": st.median * 1e6,
                 "goodput_gbps": p2p_goodput(payload, st.median) * 8 / 1e9})
"""
    rows = _measure(body, [1 << k for k in (10, 14, 18, 22)])
    from repro.core.costmodel import make_comm_model
    for sysname in ("alps", "leonardo", "lumi", "tpu_v5e"):
        m = make_comm_model(sysname)
        for nbytes in (1 << 14, 1 << 22, 1 << 26):
            for mech in ("staging", "device_copy", "ccl", "mpi"):
                c = m.p2p(float(nbytes), mech)
                rows.append({"mechanism": f"model/{sysname}/{mech}", "nbytes": nbytes,
                             "rtt_us": 2 * c.seconds * 1e6,
                             "goodput_gbps": c.goodput(nbytes) * 8 / 1e9})
    emit("fig03_p2p_intranode", rows, ["mechanism", "nbytes", "rtt_us", "goodput_gbps"])
    return rows


# ---------------------------------------------------------------- Fig. 4
def fig04_pair_heterogeneity():
    """LUMI GPU-pair goodput heterogeneity: expected (nominal best-path) vs the
    EFI-balanced model, incl. the RCCL misestimate analog (hop-count vs path
    capacity — Obs. 3)."""
    from repro.core.topology import make_paper_node_graphs
    g = make_paper_node_graphs()["lumi"]
    rows = []
    for peer in range(1, 8):
        nominal = g.pair_bw(0, peer) * 8 / 1e9
        # 70% of nominal achieved by device-copy/MPI (Sec. III-D)
        measured_like = 0.70 * nominal
        # RCCL hop-count model: bandwidth ~ link_bw / hops (underestimates
        # multi-path pairs => roughly half throughput on e.g. GPU 5/7)
        hops = len(g.shortest_path(0, peer)) - 1
        rccl_like = min(nominal, (g.link_bw * 8 / 1e9) / max(hops, 1)) * 0.7
        rows.append({"peer": peer, "nominal_gbps": nominal,
                     "devcopy_mpi_gbps": measured_like, "rccl_gbps": rccl_like,
                     "hops": hops})
    emit("fig04_pair_heterogeneity", rows,
         ["peer", "nominal_gbps", "devcopy_mpi_gbps", "rccl_gbps", "hops"])
    return rows


# ------------------------------------------------------------- Figs. 5/6
def fig05_alltoall_intranode():
    body = r"""
    rows_per_rank = 8 * max(per // 8, 1)
    xa = np.random.randn(8 * rows_per_rank, 1).astype(np.float32)  # local: (rpr, 1)
    pay = rows_per_rank * 4
    for name, fn in C.ALL_TO_ALL_ALGOS.items():
        f = jax.jit(jax.shard_map(lambda v, fn=fn: fn(v, 'x'), mesh=mesh,
                                  in_specs=P('x'), out_specs=P('x')))
        st = time_fn(f, xa, iters=30, warmup=3)
        rows.append({"algorithm": name, "nbytes": pay,
                     "goodput_gbps": collective_goodput(pay, st.median) * 8 / 1e9,
                     "median_us": st.median * 1e6})
"""
    rows = _measure(body, [1 << k for k in (12, 16, 20, 22)])
    from repro.core.topology import make_paper_node_graphs, make_tpu_pod
    for name, g in {**make_paper_node_graphs(), "v5e_pod": make_tpu_pod()}.items():
        rows.append({"algorithm": f"expected/{name}", "nbytes": 0,
                     "goodput_gbps": g.alltoall_expected_goodput() * 8 / 1e9,
                     "median_us": ""})
    emit("fig05_alltoall_intranode", rows, ["algorithm", "nbytes", "goodput_gbps", "median_us"])
    return rows


def fig06_allreduce_intranode():
    body = r"""
    for name, fn in C.ALL_REDUCE_ALGOS.items():
        f = jax.jit(jax.shard_map(lambda v, fn=fn: fn(v, 'x'), mesh=mesh,
                                  in_specs=P('x'), out_specs=P('x')))
        st = time_fn(f, x, iters=30, warmup=3)
        rows.append({"algorithm": name, "nbytes": payload,
                     "goodput_gbps": collective_goodput(payload, st.median) * 8 / 1e9,
                     "median_us": st.median * 1e6})
"""
    rows = _measure(body, [1 << k for k in (12, 16, 20, 22)])
    from repro.core.topology import make_paper_node_graphs, make_tpu_pod
    for name, g in {**make_paper_node_graphs(), "v5e_pod": make_tpu_pod()}.items():
        rows.append({"algorithm": f"expected/{name}", "nbytes": 0,
                     "goodput_gbps": g.allreduce_expected_goodput() * 8 / 1e9,
                     "median_us": ""})
    emit("fig06_allreduce_intranode", rows, ["algorithm", "nbytes", "goodput_gbps", "median_us"])
    return rows


# ------------------------------------------------------------- Figs. 7/8
def fig07_p2p_internode():
    """Inter-node (pod-to-pod) p2p: modeled over the paper systems + measured
    cross-'pod' ppermute on a (2,4) host mesh."""
    from repro.core.costmodel import make_comm_model
    rows = []
    for sysname in ("alps", "leonardo", "lumi", "tpu_v5e"):
        m = make_comm_model(sysname)
        for nbytes in (1, 1 << 14, 1 << 22, 1 << 28):
            for mech in ("ccl", "mpi"):
                for where in ("host", "gpu"):
                    c = m.p2p(float(max(nbytes, 1)), mech, inter_node=True)
                    lat = c.seconds if where == "gpu" else c.seconds * 0.8
                    rows.append({"system": sysname, "mechanism": mech,
                                 "buffer": where, "nbytes": nbytes,
                                 "latency_us": lat * 1e6,
                                 "goodput_gbps": nbytes / lat * 8 / 1e9})
    emit("fig07_p2p_internode", rows,
         ["system", "mechanism", "buffer", "nbytes", "latency_us", "goodput_gbps"])
    return rows


def fig08_distance():
    """Latency/goodput vs network distance with noise distributions (box-plot
    stats: median/IQR/p95/min/max per the paper's methodology)."""
    from repro.core.costmodel import make_comm_model
    from repro.core.noise import NoiseModel
    rng = np.random.default_rng(0)
    rows = []
    for sysname in ("alps", "leonardo", "lumi"):
        m = make_comm_model(sysname)
        for dist in ("same_switch", "same_group", "diff_group"):
            base = m.p2p(1.0, "mpi", True, dist).seconds
            nm = NoiseModel.leonardo_diff_group() if (sysname == "leonardo" and
                                                      dist != "same_switch") else \
                NoiseModel(base, m.profile.noise_lognorm_sigma, 0.99, base * 1.2, base * 10)
            lat = nm.sample_latency(rng, 2000) + (base - nm.base_latency)
            g = m.p2p(float(1 << 30), "mpi", True, dist)
            gp = (1 << 30) / g.seconds * 8 / 1e9
            if sysname == "leonardo" and dist == "diff_group":
                gp *= nm.goodput_fraction
            rows.append({"system": sysname, "distance": dist,
                         "lat_median_us": float(np.median(lat)) * 1e6,
                         "lat_p95_us": float(np.percentile(lat, 95)) * 1e6,
                         "lat_max_us": float(lat.max()) * 1e6,
                         "goodput_gbps": gp})
    emit("fig08_distance", rows, ["system", "distance", "lat_median_us",
                                  "lat_p95_us", "lat_max_us", "goodput_gbps"])
    return rows


# ----------------------------------------------------------- Figs. 9/10/11
def fig09_alltoall_scaling():
    from repro.core.characterize import project_at_scale
    rows = project_at_scale("tpu_v5e", alltoall_bytes=2 << 20)
    rows += project_at_scale("leonardo", alltoall_bytes=2 << 20)
    emit("fig09_alltoall_scaling", rows, list(rows[0].keys()))
    return rows


def fig10_allreduce_scaling():
    from repro.core.characterize import project_at_scale
    rows = project_at_scale("tpu_v5e", allreduce_bytes=1 << 30)
    rows += project_at_scale("lumi", allreduce_bytes=1 << 30)
    emit("fig10_allreduce_scaling", rows, list(rows[0].keys()))
    return rows


def fig11_crossover():
    """RCCL/MPI goodput ratio grid (sizes x node counts) + measured algorithm
    crossover on host devices (xla vs explicit latency-optimal)."""
    from repro.core.costmodel import make_comm_model
    m = make_comm_model("lumi")
    rows = []
    for n in (16, 64, 256, 1024):
        for k in range(10, 31, 4):
            s = float(1 << k)
            ratio = m.allreduce_at_scale(s, n, "mpi").seconds / \
                m.allreduce_at_scale(s, n, "ccl").seconds
            rows.append({"endpoints": n, "nbytes": 1 << k,
                         "ccl_speedup_over_mpi": round(ratio, 3)})
    body = r"""
    best = None
    for name in ("xla", "recursive_doubling", "ring"):
        fn = C.ALL_REDUCE_ALGOS[name]
        f = jax.jit(jax.shard_map(lambda v, fn=fn: fn(v, 'x'), mesh=mesh,
                                  in_specs=P('x'), out_specs=P('x')))
        st = time_fn(f, x, iters=30, warmup=3)
        rows.append({"endpoints": 8, "nbytes": payload,
                     "ccl_speedup_over_mpi": name + f":{st.median*1e6:.0f}us"})
"""
    rows += _measure(body, [1 << 12, 1 << 20])
    emit("fig11_crossover", rows, ["endpoints", "nbytes", "ccl_speedup_over_mpi"])
    return rows


# ------------------------------------------------------------- Figs. 12/13
def fig12_service_levels():
    from repro.core.noise import ServiceLevelArbiter, TrafficClass
    arb = ServiceLevelArbiter(link_bw=25e9, endpoint_bw=12.5e9)
    victim = TrafficClass("allreduce", 0, 10e9)
    rows = []
    for aggr_pattern in ("alltoall", "incast"):
        for sl in (0, 1):
            agg = [TrafficClass(aggr_pattern, sl, 30e9)]
            for shares in (True, False):
                g = arb.victim_goodput(victim, agg, aggr_pattern, shares)
                rows.append({"aggressor": aggr_pattern, "aggressor_sl": sl,
                             "shares_switches": shares,
                             "victim_goodput_gbps": g * 8 / 1e9})
    rows.append({"aggressor": "none", "aggressor_sl": "",
                 "shares_switches": "", "victim_goodput_gbps": 10e9 * 8 / 1e9})
    emit("fig12_service_levels", rows,
         ["aggressor", "aggressor_sl", "shares_switches", "victim_goodput_gbps"])
    return rows


def fig13_noise_scaling():
    from repro.core.characterize import project_at_scale
    from repro.core.noise import NoiseModel
    rows = project_at_scale("leonardo", noise=NoiseModel.leonardo_diff_group())
    emit("fig13_noise_scaling", rows, list(rows[0].keys()))
    return rows


ALL_FIGURES = {
    "fig03_p2p_intranode": fig03_p2p_intranode,
    "fig04_pair_heterogeneity": fig04_pair_heterogeneity,
    "fig05_alltoall_intranode": fig05_alltoall_intranode,
    "fig06_allreduce_intranode": fig06_allreduce_intranode,
    "fig07_p2p_internode": fig07_p2p_internode,
    "fig08_distance": fig08_distance,
    "fig09_alltoall_scaling": fig09_alltoall_scaling,
    "fig10_allreduce_scaling": fig10_allreduce_scaling,
    "fig11_crossover": fig11_crossover,
    "fig12_service_levels": fig12_service_levels,
    "fig13_noise_scaling": fig13_noise_scaling,
}
