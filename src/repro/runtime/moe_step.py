"""Expert-parallel MoE step: token dispatch/combine as *planned* alltoall.

The first non-allreduce pattern through the StepProgram IR (ROADMAP item 2).
The data-parallel axis doubles as the expert-parallel axis: each device owns
``E / n`` experts (global expert ``e`` lives on device ``e // e_loc``), routes
its local batch rows with the capacity-factor/token-drop machinery from
``models.moe`` (per-row routing, so indices never cross the sharding), and
exchanges token buffers with two planned alltoalls dispatched through the
plan's per-(size, distance-tier) tables:

  dispatch  (E, b*C, D) local buffer, row block j -> expert owner j
  compute   (e_loc, n*b*C, D) batched swiglu over every rank's tokens
  combine   the inverse exchange, back to token space, weighted top-k sum

Gradient completion mirrors the traffic: expert-weight gradients arrive
*through the alltoall backward* (each expert's tokens all live on its owner —
no further reduction), while the replicated router gradient is a dense
all-reduce over the EP axis — the program's ``AllReduce`` node.  Global-norm
clipping stays exact: the sharded expert sum-of-squares is psum-combined with
the (identical-everywhere) router term before the clip factor forms.

Obs. 7 shows up here for real: when the plan's tier tables mark the axis
``diff_group`` (or it spans >512 endpoints), ``plan.all_to_all_algo`` forces
the bounded-state pairwise schedule and the traced step lowers to ppermute
rotations instead of one fused alltoall — asserted by the jaxpr tests and the
``all_to_all_algo/*`` plan stats.
"""
from __future__ import annotations

from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..core import program as prg
from ..core.autotune import CollectivePolicy
from ..models.moe import _capacity, route_row
from ..optim import adamw


def expert_dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    """(E, top_k, D, F_expert) of a MoE config."""
    if not cfg.n_experts or not cfg.top_k:
        raise ValueError(f"{cfg.name}: not a MoE config "
                         f"(n_experts={cfg.n_experts}, top_k={cfg.top_k})")
    return cfg.n_experts, cfg.top_k, cfg.d_model, (cfg.d_expert or cfg.d_ff)


def dispatch_bytes(cfg: ModelConfig, batch_per_device: int, seq: int,
                   dtype_bytes: int = 4) -> int:
    """Local alltoall payload bytes — the size the plan's dispatch sees.

    One (E, b*C, D) buffer per exchange; this is the ``nbytes`` key the
    per-tier table is consulted with, so scenarios and the executed-path
    oracle price/assert the same number the runtime dispatches.
    """
    E, _, D, _ = expert_dims(cfg)
    C = _capacity(seq, cfg)
    return E * batch_per_device * C * D * dtype_bytes


def moe_ep_params(cfg: ModelConfig, key, dtype=jnp.float32) -> Dict:
    """Global-shape MoE layer params: replicated router, expert-sharded FFN."""
    E, _, D, F = expert_dims(cfg)
    kr, k1, k2, k3 = jax.random.split(key, 4)
    s_in, s_out = (2.0 / (D + F)) ** 0.5, (2.0 / (F + D)) ** 0.5
    return {
        "router": jax.random.normal(kr, (D, E), dtype) * (D ** -0.5),
        "experts": {
            "w1": jax.random.normal(k1, (E, D, F), dtype) * s_in,
            "w3": jax.random.normal(k3, (E, D, F), dtype) * s_in,
            "w2": jax.random.normal(k2, (E, F, D), dtype) * s_out,
        },
    }


def moe_ep_batch(cfg: ModelConfig, key, batch: int, seq: int,
                 dtype=jnp.float32) -> Dict:
    """Synthetic hidden-state regression batch (global shapes)."""
    kx, ky = jax.random.split(key)
    D = cfg.d_model
    x = jax.random.normal(kx, (batch, seq, D), dtype)
    y = jax.random.normal(ky, (batch, seq, D), dtype) * 0.1
    return {"x": x, "y": y}


def moe_ep_forward(params: Dict, x: jnp.ndarray, cfg: ModelConfig,
                   axis: str, n: int,
                   a2a: Callable) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-device EP forward.  x: (b, S, D) local rows; a2a: the planned
    exchange (identity when n == 1).  Returns (out (b, S, D) fp32, aux)."""
    b, S, D = x.shape
    E, k, _, _ = expert_dims(cfg)
    e_loc = E // n
    C = _capacity(S, cfg)
    r = jax.vmap(lambda xr: route_row(xr, params["router"], cfg, C))(x)

    # dispatch buffer, destination-major: row block j holds the e_loc global
    # experts device j owns, so the (E, b*C, D) buffer is already in alltoall
    # row-block layout
    xb = jax.vmap(lambda xr, tok: xr[tok])(x, r["tok"])      # (b, E, C, D)
    xb = xb * r["valid"][..., None].astype(x.dtype)
    buf = xb.transpose(1, 0, 2, 3).reshape(E, b * C, D)
    recv = a2a(buf)                                          # planned dispatch
    # recv block j = rank j's tokens for my experts
    toks = recv.reshape(n, e_loc, b * C, D).transpose(1, 0, 2, 3) \
               .reshape(e_loc, n * b * C, D)

    w = params["experts"]
    h = jnp.einsum("etd,edf->etf", toks, w["w1"])
    g = jnp.einsum("etd,edf->etf", toks, w["w3"])
    y_e = jnp.einsum("etf,efd->etd", jax.nn.silu(h) * g, w["w2"])

    # inverse exchange: block j of the send buffer = my experts' outputs for
    # rank j's tokens; the receive concatenates to global expert order again
    back = y_e.reshape(e_loc, n, b * C, D).transpose(1, 0, 2, 3) \
               .reshape(E, b * C, D)
    comb = a2a(back)                                         # planned combine
    yb = comb.reshape(E, b, C, D).transpose(1, 0, 2, 3)      # (b, E, C, D)

    def combine_row(yr, e_of, c_of, keep, w_of):
        vals = yr[e_of, jnp.clip(c_of, 0, C - 1)]            # (S*k, D)
        vals = vals * keep[:, None] * w_of[:, None]
        return vals.reshape(S, k, -1).sum(axis=1)

    out = jax.vmap(combine_row)(yb.astype(jnp.float32), r["e_of_slot"],
                                r["c_of_slot"], r["keep"], r["w"])
    return out, jnp.mean(r["aux"])


def build_moe_ep_step(cfg: ModelConfig, opt: adamw.OptConfig, mesh,
                      axis: str = "data",
                      policy: Optional[CollectivePolicy] = None,
                      program: Optional[prg.StepProgram] = None,
                      aux_weight: float = 0.01) -> Callable:
    """(params, opt_state, batch, err) -> (params, opt_state, metrics, err).

    Same calling convention as ``build_explicit_dp_step``; ``err`` is a
    placeholder scalar (no wire compression on the MoE path yet).  Params from
    ``moe_ep_params`` (global shapes: shard_map's in_specs shard the expert
    leaves over `axis`); batch from ``moe_ep_batch``.
    """
    from jax.sharding import PartitionSpec as P

    policy = policy or CollectivePolicy.from_model()
    program = (program or prg.moe_step_program()).validate()
    if not program.has("all_to_all"):
        raise ValueError(f"program {program.name!r} has no AllToAll node; "
                         "use build_explicit_dp_step / build_program_step")
    n = mesh.shape[axis]
    E, _, _, _ = expert_dims(cfg)
    if E % n:
        raise ValueError(f"n_experts={E} must divide over the expert-parallel "
                         f"axis {axis!r} (size {n})")

    def a2a(v):
        if n == 1:
            return v
        return policy.all_to_all(v, axis, n)

    def local_step(params, opt_state, batch, err):
        def loss_fn(p):
            out, aux = moe_ep_forward(p, batch["x"], cfg, axis, n, a2a)
            mse = jnp.mean(jnp.square(out - batch["y"].astype(jnp.float32)))
            return mse + aux_weight * aux, aux

        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        loss = jax.lax.pmean(loss, axis) if n > 1 else loss

        # the global objective is the mean of per-device losses: every grad
        # picks up 1/n, then the replicated router finishes with the planned
        # dense reduction (the program's AllReduce node); expert grads arrived
        # complete through the alltoall backward
        inv = 1.0 / n
        g_experts = jax.tree.map(lambda g: g.astype(jnp.float32) * inv,
                                 grads["experts"])
        g_router = grads["router"].astype(jnp.float32) * inv
        if n > 1:
            g_router = policy.all_reduce(g_router, axis, n)

        # exact global-norm clip across the mixed sharding: expert shards are
        # disjoint (psum sums them); the reduced router term is identical on
        # every device (added once outside the psum)
        e_sq = sum(jnp.sum(jnp.square(g))
                   for g in jax.tree.leaves(g_experts))
        gsq = (jax.lax.psum(e_sq, axis) if n > 1 else e_sq) \
            + jnp.sum(jnp.square(g_router))
        gnorm = jnp.sqrt(gsq)
        scale = jnp.minimum(1.0, opt.clip_norm / (gnorm + 1e-9))

        step_no = opt_state["step"] + 1
        lr = adamw.schedule(step_no, opt)
        b1, b2 = opt.b1, opt.b2
        bc1 = 1 - b1 ** step_no.astype(jnp.float32)
        bc2 = 1 - b2 ** step_no.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g * scale
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            delta = (m / bc1) / (jnp.sqrt(v / bc2) + opt.eps) \
                + opt.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        grads32 = {"router": g_router, "experts": g_experts}
        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads32)
        flat_m = tdef.flatten_up_to(opt_state["m"])
        flat_v = tdef.flatten_up_to(opt_state["v"])
        out = [upd(p, g, m, v)
               for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
        new_p = tdef.unflatten([o[0] for o in out])
        new_m = tdef.unflatten([o[1] for o in out])
        new_v = tdef.unflatten([o[2] for o in out])
        metrics = {"grad_norm": gnorm, "lr": lr, "loss": loss,
                   "aux_loss": aux}
        return new_p, {"m": new_m, "v": new_v, "step": step_no}, metrics, err

    def make(params, opt_state, batch, err):
        from jax import shard_map
        ex_spec = jax.tree.map(lambda _: P(axis), params["experts"])
        p_spec = {"router": P(), "experts": ex_spec}
        o_spec = {"m": p_spec, "v": p_spec, "step": P()}
        b_spec = jax.tree.map(lambda _: P(axis), batch)
        m_spec = {"grad_norm": P(), "lr": P(), "loss": P(), "aux_loss": P()}
        return shard_map(local_step, mesh=mesh,
                         in_specs=(p_spec, o_spec, b_spec, P()),
                         out_specs=(p_spec, o_spec, m_spec, P()),
                         check_vma=False)

    cache: Dict = {}

    def step(params, opt_state, batch, err):
        key = tuple(jax.tree.structure(t)
                    for t in (params, opt_state, batch, err))
        fn = cache.get(key)
        if fn is None:
            fn = cache[key] = jax.jit(make(params, opt_state, batch, err))
        return fn(params, opt_state, batch, err)

    def lower(params, opt_state, batch, err):
        """Lowered (pre-compile) artifact of this step's jit (the cached one
        the step itself runs) — what `launch.lint --hlo` compiles to HLO."""
        key = tuple(jax.tree.structure(t)
                    for t in (params, opt_state, batch, err))
        fn = cache.get(key)
        if fn is None:
            fn = cache[key] = jax.jit(make(params, opt_state, batch, err))
        return fn.lower(params, opt_state, batch, err)

    step.lower = lower
    step._cache = cache
    step.program = program
    step.zero = False
    step.opt_shard_spec = None
    step.init_error_state = lambda params: jnp.zeros((), jnp.float32)
    step.init_opt_state = adamw.init_opt_state
    step.abstract_opt_state = adamw.abstract_opt_state
    return step
