"""Batched serving: prefill + decode loop with greedy/temperature sampling.

The decode step attends over the sequence-sharded KV cache (DESIGN.md Sec. 5);
requests are served in fixed-size batches with left-padded prompts (continuous
batching reduces to swapping retired rows — `generate` retires rows on EOS by
masking).  The collective policy applies through the model's sharding
constraints; this loop adds the serving-level bookkeeping.
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import ModelConfig, ShapeConfig
from ..models.model import build_model
from . import steps as rsteps


@dataclasses.dataclass
class ServeConfig:
    max_new_tokens: int = 32
    temperature: float = 0.0     # 0 => greedy
    eos_id: int = -1             # -1 => never stop early
    seed: int = 0


class BatchedServer:
    def __init__(self, model_cfg: ModelConfig, max_seq: int, batch_size: int,
                 mesh=None, params=None):
        self.cfg = model_cfg
        self.shape = ShapeConfig("serve", max_seq, batch_size, "decode")
        self.model = build_model(model_cfg, mesh)
        self.params = params if params is not None else \
            self.model.init(jax.random.PRNGKey(0))
        self._prefill = jax.jit(self.model.prefill)
        self._decode = jax.jit(self.model.decode)

    def generate(self, prompts: np.ndarray, serve: Optional[ServeConfig] = None) -> np.ndarray:
        """prompts: (B, P) int32 (audio: (B, P, nq)).  Returns generated ids
        (B, max_new) (audio: (B, max_new, nq))."""
        serve = serve or ServeConfig()
        B = prompts.shape[0]
        P = prompts.shape[1]
        cache = self.model.init_cache(self.shape, batch_size=B)
        logits, cache = self._prefill(self.params, {"tokens": jnp.asarray(prompts)}, cache)
        key = jax.random.PRNGKey(serve.seed)
        outs = []
        done = np.zeros((B,), bool)
        tok = self._sample(logits, serve, key)
        for t in range(serve.max_new_tokens):
            outs.append(np.asarray(tok))
            if serve.eos_id >= 0:
                done |= (np.asarray(tok).reshape(B, -1)[:, 0] == serve.eos_id)
                if done.all():
                    break
            logits, cache = self._decode(self.params, cache, tok,
                                         jnp.array(P + t, jnp.int32))
            key, sub = jax.random.split(key)
            tok = self._sample(logits, serve, sub)
        return np.stack(outs, axis=1)

    def _sample(self, logits, serve: ServeConfig, key):
        lg = logits[:, -1] if logits.ndim == 3 else logits[:, -1, :, :]
        if serve.temperature <= 0.0:
            return jnp.argmax(lg, axis=-1).astype(jnp.int32)
        return jax.random.categorical(key, lg / serve.temperature, axis=-1).astype(jnp.int32)


def throughput_report(server: BatchedServer, prompt_len: int = 32,
                      new_tokens: int = 16) -> dict:
    """Tokens/s for one batch (benchmark harness hook)."""
    import time
    B = server.shape.global_batch
    rng = np.random.RandomState(0)
    if server.cfg.n_codebooks:
        prompts = rng.randint(0, server.cfg.vocab, (B, prompt_len, server.cfg.n_codebooks)).astype(np.int32)
    else:
        prompts = rng.randint(0, server.cfg.vocab, (B, prompt_len)).astype(np.int32)
    t0 = time.perf_counter()
    out = server.generate(prompts, ServeConfig(max_new_tokens=new_tokens))
    dt = time.perf_counter() - t0
    return {"batch": B, "new_tokens": int(out.shape[1]),
            "tokens_per_s": B * out.shape[1] / dt, "wall_s": dt}
