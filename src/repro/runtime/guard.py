"""Drift detection + online re-planning: the FaultGuard loop (ROADMAP item 4).

The planning stack prices every step before it runs (`exposed_comm_time`
over the calibrated plan), but until now nothing checked the fabric kept its
side of the bargain: congestion, link flap, and per-pair heterogeneity erode
the alpha-beta fits mid-run and the oblivious runtime just keeps paying.

`DriftGuard` closes the loop:

  * every step's measured time is compared against a reference (the
    calibrated `exposed_comm_time` prediction when the caller has one, else
    a warmup-median self-calibration — the live rebaseline of the same
    quantity) through an EWMA of the measured/reference ratio;
  * when the EWMA leaves the band for `patience` consecutive steps the guard
    declares drift and invokes the re-planner: a cheap
    `characterize.inter_tier_p2p_sweep` re-probe of the live mesh, a
    `calibrate.fit_profile` refit of the affected tiers, a plan re-rank
    through `CommPlan.from_topology(calibration=)` (rebucketing + wire
    re-decision ride along), and a `lint_program_on_mesh` gate before the
    swapped step is allowed to run (the replanner callable lives on the
    Trainer, which owns the mesh and the step builder);
  * after a committed swap the guard rebaselines: the post-replan step time
    is a new population.

Every decision is recorded as a `GuardEvent` (drift / replan /
replan_rejected) with the probe fit and the lint report in `detail`, so the
run's resilience history is auditable next to its lint artifacts.
"""
from __future__ import annotations

import dataclasses
import statistics
from typing import Callable, Dict, List, Optional, Tuple


@dataclasses.dataclass
class GuardConfig:
    band: float = 0.3           # relative band around the reference
    ewma: float = 0.25          # smoothing of the measured/reference ratio
    patience: int = 3           # consecutive out-of-band steps before replan
    cooldown: int = 8           # min steps between replans
    warmup: int = 3             # steps of median self-calibration
    max_replans: int = 3
    probe_sizes: Tuple[int, ...] = (1 << 10, 1 << 14, 1 << 18)
    probe_iters: int = 2
    lint: bool = True           # gate swapped plans through lint_program_on_mesh
    # modeled recovery a committed re-plan claims on *simulated* fabrics
    # (CPU host meshes): routing/rebucketing around the degraded tier
    # recovers this fraction of the fabric excess (core.faults.FaultInjector)
    recovered_fraction: float = 0.6


@dataclasses.dataclass
class GuardEvent:
    step: int
    kind: str                   # "drift" | "replan" | "replan_rejected"
    measured_s: float
    reference_s: float
    ratio: float
    detail: Dict = dataclasses.field(default_factory=dict)

    def to_dict(self) -> Dict:
        return {"step": self.step, "kind": self.kind,
                "measured_s": self.measured_s,
                "reference_s": self.reference_s,
                "ratio": round(self.ratio, 4), "detail": self.detail}


class DriftGuard:
    """EWMA drift band around a reference step time.

    `replanner(step)` is supplied by the owner (the Trainer): it runs the
    probe → refit → re-rank → lint pipeline and returns ``(committed,
    detail)``.  The guard decides *when*; the replanner decides *what*.
    """

    def __init__(self, cfg: Optional[GuardConfig] = None,
                 reference_s: Optional[float] = None,
                 replanner: Optional[Callable[[int], Tuple[bool, Dict]]] = None):
        self.cfg = cfg or GuardConfig()
        self.reference = reference_s
        self.replanner = replanner
        self.events: List[GuardEvent] = []
        self._warmup: List[float] = []
        self._ratio = 1.0
        self._hot = 0
        self._last_replan = -(10 ** 9)
        self.n_replans = 0

    # ------------------------------------------------------------- observe
    def observe(self, step: int, dt: float) -> Optional[GuardEvent]:
        """Feed one measured step time; returns the event it triggered (the
        caller reacts to kind == "replan" by resetting its own baselines)."""
        c = self.cfg
        if self.reference is None:
            # self-calibrate: median of the warmup window (a compile-heavy
            # first step must not inflate the reference)
            self._warmup.append(dt)
            if len(self._warmup) >= max(c.warmup, 1):
                self.reference = float(statistics.median(self._warmup))
                self._warmup = []
                self._ratio = 1.0
            return None
        ratio = dt / self.reference
        self._ratio = (1 - c.ewma) * self._ratio + c.ewma * ratio
        if self._ratio <= 1.0 + c.band:
            self._hot = 0
            return None
        self._hot += 1
        if self._hot < c.patience:
            return None
        if step - self._last_replan < c.cooldown or \
                self.n_replans >= c.max_replans:
            if self._hot == c.patience:  # one drift record per episode
                return self._emit(step, "drift", dt,
                                  {"suppressed": "cooldown"
                                   if step - self._last_replan < c.cooldown
                                   else "max_replans"})
            return None
        self._hot = 0
        self._last_replan = step
        if self.replanner is None:
            return self._emit(step, "drift", dt, {})
        committed, detail = self.replanner(step)
        kind = "replan" if committed else "replan_rejected"
        if committed:
            self.n_replans += 1
            # new plan, new population: re-seed the reference from the next
            # warmup window instead of judging it against the drifted one
            self.reference = None
        return self._emit(step, kind, dt, detail)

    def _emit(self, step: int, kind: str, dt: float, detail: Dict) -> GuardEvent:
        ref = self.reference if self.reference is not None else dt
        ev = GuardEvent(step, kind, dt, ref, self._ratio, detail)
        self.events.append(ev)
        return ev

    # -------------------------------------------------------------- report
    def report(self) -> Dict:
        """Machine-readable guard history — written alongside lint reports
        (each committed/rejected replan embeds its lint verdict in detail)."""
        return {
            "n_events": len(self.events),
            "n_replans": self.n_replans,
            "n_rejected": sum(1 for e in self.events
                              if e.kind == "replan_rejected"),
            "events": [e.to_dict() for e in self.events],
        }
