"""Training loop: checkpoint/restart, straggler mitigation, elastic re-meshing,
fault injection, and drift-guarded online re-planning.

The loop composes:
  * steps.train_step_bundle       — jitted step with FSDP+TP shardings
  * checkpoint.CheckpointManager  — async atomic saves, reshard-on-restore
  * data.SyntheticLM/TokenFile    — step-keyed deterministic batches (replay)
  * core.noise.StragglerMitigator — per-step time tracking + action (Sec. VI):
                                    'log', 'sync' (barrier), 'skip' (drop the
                                    step's update — rejected under ZeRO, where
                                    sharded optimizer state makes it unsound)
  * core.faults.FaultInjector     — seeded fault schedule wrapped around the
                                    step: transient failures / node loss raise,
                                    degradation windows perturb the measured
                                    step time (the simulated messy fabric)
  * guard.DriftGuard              — EWMA drift band around the calibrated
                                    step-time reference; sustained drift runs
                                    the probe -> refit -> re-rank -> lint-gate
                                    -> swap pipeline (`_replan`) mid-run
  * recovery                      — classified errors (transient vs fatal),
                                    bounded retry with exponential backoff,
                                    elastic re-mesh on node loss rebuilding on
                                    the surviving device set

On failure injection (tests) or real XlaRuntimeError, `run()` re-enters through
`_build()`; data replays from the restored step.  Fatal errors (anything that
does not look like a fabric/device fault) propagate immediately — the old
catch-all that swallowed genuine bugs is gone.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..configs.base import ModelConfig, ShapeConfig
from ..core.faults import FaultInjector, NodeLossFault, TransientFault
from ..core.noise import StragglerMitigator
from ..data.pipeline import SyntheticLM, DataConfig
from ..models.model import build_model
from ..models.sharding import tree_shardings_shaped
from ..optim import adamw
from . import steps as rsteps
from .guard import DriftGuard, GuardConfig

# substrings that mark a RuntimeError as a fabric/device fault worth the
# restore-and-retry path; anything else is a genuine bug and propagates
_TRANSIENT_MARKERS = ("injected device failure", "injected transient",
                      "device", "communicator", "nccl", "collective",
                      "data_loss", "unavailable", "deadline", "xla runtime")


def _is_transient(e: BaseException) -> bool:
    if isinstance(e, (TransientFault, NodeLossFault,
                      jax.errors.JaxRuntimeError)):
        return True
    if isinstance(e, RuntimeError):
        msg = str(e).lower()
        return any(m in msg for m in _TRANSIENT_MARKERS)
    return False


@dataclasses.dataclass
class TrainConfig:
    steps: int = 50
    microbatches: int = 1
    ckpt_every: int = 20
    ckpt_dir: str = "artifacts/ckpt"
    ckpt_async: bool = True
    log_every: int = 10
    straggler_threshold: float = 2.5
    straggler_action: str = "log"
    # explicit-DP path (shard_map + our collectives, paper Obs. 1/4): params
    # replicated, batch sharded on dp_axis (and dcn_axis on a two-pod mesh)
    explicit_dp: bool = False
    dp_axis: str = "data"
    dcn_axis: Optional[str] = None
    policy: Optional[object] = None       # core.autotune.CollectivePolicy
    bucket_bytes: Optional[int] = None    # None = plan crossover, 0 = per-tensor
    # int8 error-feedback wire compression (0 = fp32 wire).  Composes with
    # bucketing and overlap: the codec quantizes per bucket and the error
    # state becomes the carrier-shaped buffer (see runtime.steps)
    compress_bits: int = 0
    # overlap-aware execution (core.overlap): reverse-layer-order buckets on a
    # scan-carried issue schedule; with microbatches > 1 each bucket's
    # reduction overlaps the next microbatch's backward, and on a two-level
    # mesh buckets run the chunked hierarchical pipeline
    overlap: bool = False
    chunks: Optional[int] = None          # None = plan's per-tier alpha-beta fit
    # ZeRO-style sharded optimizer (runtime.steps): reduce-scatter the packed
    # carrier, AdamW over each device's shard (fp32 m/v carrier-sharded, so
    # optimizer memory drops by the DP degree), all-gather updated params at
    # the wire dtype.  Implies explicit_dp + bucketed carrier.
    zero: bool = False
    # StepProgram (core.program): the declarative schedule the step compiles
    # from.  When set it supersedes the boolean knobs above (which become a
    # legacy shim — launch.train.resolve_step_program builds the program from
    # the flags); its name is stamped into checkpoint metadata.
    program: Optional[object] = None
    # fault injection (core.faults): a FaultPlan (or prebuilt FaultInjector)
    # replayed deterministically around the step loop
    faults: Optional[object] = None
    # drift guard (runtime.guard): watch measured step time against the
    # reference band; sustained drift probes, refits, re-ranks, and lint-gates
    # a plan swap mid-run
    guard: bool = False
    guard_cfg: Optional[object] = None    # runtime.guard.GuardConfig
    # recovery: classified transient errors get at most max_retries
    # consecutive restore-and-replay attempts with exponential backoff
    max_retries: int = 3
    retry_backoff_s: float = 0.05


class Trainer:
    def __init__(self, model_cfg: ModelConfig, shape: ShapeConfig,
                 opt: Optional[adamw.OptConfig] = None,
                 train_cfg: Optional[TrainConfig] = None,
                 mesh=None, data=None):
        self.model_cfg = model_cfg
        self.shape = shape
        self.opt = opt or adamw.OptConfig()
        self.cfg = train_cfg or TrainConfig()
        self.mesh = mesh
        self.data = data or SyntheticLM(model_cfg, shape)
        if self.cfg.straggler_action == "skip" and self.cfg.zero:
            raise ValueError(
                "straggler_action='skip' is unsound with zero=True: dropping "
                "a step after the reduce-scatter leaves the carrier-sharded "
                "optimizer moments half-advanced across devices; use 'sync' "
                "or 'log' under ZeRO")
        self.ckpt = CheckpointManager(self.cfg.ckpt_dir)
        self.straggler = StragglerMitigator(threshold=self.cfg.straggler_threshold,
                                            action=self.cfg.straggler_action)
        self.metrics_log: list = []
        self.injector: Optional[FaultInjector] = None
        if self.cfg.faults is not None:
            self.injector = (self.cfg.faults
                             if isinstance(self.cfg.faults, FaultInjector)
                             else FaultInjector(self.cfg.faults))
        self.guard: Optional[DriftGuard] = None
        if self.cfg.guard:
            gcfg = self.cfg.guard_cfg or GuardConfig()
            self.guard = DriftGuard(gcfg, replanner=self._replan)
        self.skipped_steps = 0
        self.retry_log: list = []
        self._build(self.mesh)

    # ----------------------------------------------------------------- build
    def _build(self, mesh):
        if self.cfg.explicit_dp:
            if mesh is None:
                raise ValueError("explicit_dp requires a multi-device mesh; "
                                 "got mesh=None (single-device host?)")
            self._build_explicit_dp(mesh)
            return
        if self.cfg.zero:
            raise ValueError("zero=True requires the explicit-DP path "
                             "(explicit_dp=True / launch.train --zero)")
        self._dp_step = None
        self.model = build_model(self.model_cfg, mesh)
        self.bundle = rsteps.train_step_bundle(self.model, self.shape, self.opt,
                                               microbatches=self.cfg.microbatches)
        if mesh is not None:
            self.step_fn = jax.jit(self.bundle.fn, in_shardings=self.bundle.in_shardings,
                                   out_shardings=self.bundle.out_shardings,
                                   donate_argnums=self.bundle.donate_argnums)
        else:
            self.step_fn = jax.jit(self.bundle.fn, donate_argnums=self.bundle.donate_argnums)

    def _build_explicit_dp(self, mesh):
        """Explicit-DP: replicated params (model built without mesh constraints),
        gradients reduced by our CommPlan-dispatched collectives with bucketing.
        Error-feedback state lives on the trainer, initialized at first step."""
        c = self.cfg
        for ax, size in mesh.shape.items():
            if ax not in (c.dp_axis, c.dcn_axis) and size > 1:
                raise ValueError(f"explicit_dp needs a pure-DP mesh; axis {ax!r} "
                                 f"has size {size}")
        if c.microbatches > 1 and not c.overlap:
            raise ValueError("explicit-DP gradient accumulation is implemented "
                             "by the overlap schedule; pass overlap=True "
                             "(launch.train --overlap) with microbatches "
                             f"({c.microbatches} requested)")
        self.model = build_model(self.model_cfg)
        if c.program is not None:
            dp_step = rsteps.build_program_step(
                self.model, self.opt, mesh, c.program, axis=c.dp_axis,
                policy=c.policy, dcn_axis=c.dcn_axis)
        else:
            dp_step = rsteps.build_explicit_dp_step(
                self.model, self.opt, mesh, c.dp_axis, policy=c.policy,
                bucket_bytes=c.bucket_bytes, dcn_axis=c.dcn_axis,
                overlap=c.overlap, chunks=c.chunks,
                microbatches=c.microbatches, compress_bits=c.compress_bits,
                zero=c.zero)
        self._dp_step = dp_step
        self._dp_err = None

        def step_fn(params, opt_state, batch):
            if self._dp_err is None:
                # carrier-shaped under bucketed compression, per-leaf otherwise
                self._dp_err = dp_step.init_error_state(params)
            params, opt_state, metrics, self._dp_err = dp_step(
                params, opt_state, batch, self._dp_err)
            return params, opt_state, metrics

        self.step_fn = step_fn

    def init_state(self, seed: int = 0):
        params = self.model.init(jax.random.PRNGKey(seed))
        if self._dp_step is not None and getattr(self._dp_step, "zero", False):
            # carrier-sharded m/v: (n_buckets, padded_elems) fp32, laid out by
            # the step's codec table (runtime.steps.make_opt_state)
            opt_state = self._dp_step.init_opt_state(params)
        else:
            opt_state = adamw.init_opt_state(params)
        if self.model.shd.mesh is not None:
            p_sh = tree_shardings_shaped(self.model.shd, self.model.param_logical(),
                                         params)
            params = jax.tree.map(jax.device_put, params, p_sh)
        return params, opt_state

    # ------------------------------------------------------------------ run
    def run(self, params=None, opt_state=None, start_step: int = 0,
            resume: bool = False,
            inject_failure_at: Union[int, Sequence[int], None] = None) -> Dict:
        """Run the training loop with the recovery/guard machinery.

        `inject_failure_at` takes a step index or a sequence of them; each
        entry raises one recoverable failure at that step (a repeated entry
        exercises a repeated fault — each firing consumes one entry, so the
        replayed steps after a restore do not re-raise an already-fired one).
        """
        if resume and self.ckpt.latest_step() is not None:
            params, opt_state, start_step = self.restore()
        if params is None:
            params, opt_state = self.init_state()
        if inject_failure_at is None:
            pending_inject = []
        elif isinstance(inject_failure_at, (list, tuple)):
            pending_inject = sorted(inject_failure_at)
        else:
            pending_inject = [inject_failure_at]
        step = start_step
        retries = 0
        skip = self.cfg.straggler_action == "skip"
        while step < self.cfg.steps:
            batch = {k: jax.numpy.asarray(v) for k, v in self.data.batch_at(step).items()}
            # 'skip' reverts to the pre-step state after the fact, so it needs
            # copies taken before the step (the step may donate its inputs)
            prev = None
            if skip:
                prev = (jax.tree.map(jax.numpy.copy, params),
                        jax.tree.map(jax.numpy.copy, opt_state))
            t0 = time.perf_counter()
            try:
                if pending_inject and step == pending_inject[0]:
                    pending_inject.pop(0)
                    raise RuntimeError("injected device failure (test)")
                if self.injector is not None:
                    self.injector.before_step(step)
                params, opt_state, metrics = self.step_fn(params, opt_state, batch)
                jax.block_until_ready(metrics["loss"])
            except NodeLossFault as e:
                # elastic re-mesh: rebuild on the surviving device set, then
                # restore the last checkpoint onto the shrunk mesh
                self.ckpt.wait()
                if self.ckpt.latest_step() is None:
                    raise
                self.mesh = self._surviving_mesh(e.lost)
                self._build(self.mesh)
                params, opt_state, step = self.restore()
                self.straggler.reset_baseline()
                retries = 0
                continue
            except (RuntimeError, jax.errors.JaxRuntimeError) as e:
                if not _is_transient(e):
                    raise  # a genuine bug, not a fabric fault: propagate
                self.ckpt.wait()
                restored = self.ckpt.latest_step()
                if restored is None:
                    raise  # nothing to restore into: surface the fault
                retries += 1
                self.retry_log.append({"step": step, "attempt": retries,
                                       "error": str(e)[:200]})
                if retries > self.cfg.max_retries:
                    raise RuntimeError(
                        f"persistent failure: {retries - 1} consecutive "
                        f"restore-and-replay attempts failed at step {step} "
                        f"(last error: {e})") from e
                time.sleep(self.cfg.retry_backoff_s * 2 ** (retries - 1))
                self._build(self.mesh)
                params, opt_state, step = self.restore()
                continue
            retries = 0
            dt = time.perf_counter() - t0
            if self.injector is not None:
                # the simulated messy fabric: degradation windows perturb the
                # measured step time (deterministically, per the FaultPlan)
                dt = self.injector.perturb(step, dt)
            ev = self.straggler.observe(step, dt)
            if ev is not None:
                if self.cfg.straggler_action == "sync":
                    jax.block_until_ready(params)
                elif skip:
                    # drop the straggler step's update entirely (and its
                    # error-feedback contribution): the replicated state
                    # reverts to the pre-step snapshot
                    params, opt_state = prev
                    self._dp_err = None
                    self.skipped_steps += 1
            if self.guard is not None:
                gev = self.guard.observe(step, dt)
                if gev is not None and gev.kind == "replan":
                    # the swap changed the step-time population on both
                    # trackers; the injector models the re-ranked plan's
                    # partial recovery on simulated fabrics
                    self.straggler.reset_baseline()
                    if self.injector is not None:
                        self.injector.on_replan(
                            self.guard.cfg.recovered_fraction)
            row = {"step": step, "loss": float(metrics["loss"]),
                   "grad_norm": float(metrics["grad_norm"]),
                   "lr": float(metrics["lr"]), "time_s": dt,
                   "straggler": ev is not None}
            self.metrics_log.append(row)
            if step % self.cfg.log_every == 0:
                print(f"step {step:5d} loss {row['loss']:.4f} "
                      f"gnorm {row['grad_norm']:.3f} {dt*1e3:.0f}ms", flush=True)
            step += 1
            if self.cfg.ckpt_every and step % self.cfg.ckpt_every == 0:
                self.save(step, params, opt_state)
        self.save(step, params, opt_state)
        self.ckpt.wait()
        out = {"final_step": step, "metrics": self.metrics_log,
               "straggler_events": len(self.straggler.events),
               "skipped_steps": self.skipped_steps,
               "retries": len(self.retry_log),
               "final_devices": (int(np.prod(list(self.mesh.shape.values())))
                                 if self.mesh is not None else 1)}
        if self.guard is not None:
            out["guard"] = self.guard.report()
        if self.injector is not None:
            out["fault_log"] = list(self.injector.log)
        return out

    # ----------------------------------------------------------- re-planning
    def _replan(self, step: int):
        """The guard's probe -> refit -> re-rank -> lint-gate -> swap pipeline.

        Returns ``(committed, detail)``.  The probe is the cheap per-tier p2p
        sweep on the live mesh; its records refit the affected tiers and the
        re-ranked plan (new tables, bucket size, chunk depth, wire decision)
        comes back through the same `CommPlan.from_topology(calibration=)`
        path a launch-time --calibration run uses.  The swapped plan must
        lint clean against the step's program before it is allowed to run.
        """
        from ..core.autotune import CollectivePolicy
        from ..core.calibrate import fit_profile
        from ..core.characterize import inter_tier_p2p_sweep, pairwise_p2p_sweep
        from ..core.costmodel import make_comm_model
        from ..core.topology import make_paper_fabrics

        gcfg = self.guard.cfg if self.guard is not None else GuardConfig()
        detail: Dict = {"step": step}
        n_dev = (int(np.prod(list(self.mesh.shape.values())))
                 if self.mesh is not None else 1)
        axis = self.cfg.dp_axis if self.cfg.explicit_dp else None
        profile = None
        if (self.mesh is not None and axis in self.mesh.shape
                and self.mesh.shape[axis] >= 2):
            records = inter_tier_p2p_sweep(self.mesh, axis=axis,
                                           fabric=make_paper_fabrics()["tpu_v5e"],
                                           sizes=gcfg.probe_sizes,
                                           iters=gcfg.probe_iters)
            if not records:
                # the mesh fits inside one tier: fall back to the concurrent
                # pairwise exchange (congestion-aware, untier-qualified fits)
                records = pairwise_p2p_sweep(self.mesh, axis=axis,
                                             sizes=gcfg.probe_sizes,
                                             iters=gcfg.probe_iters)
            profile = fit_profile(records, system="tpu_v5e",
                                  n_endpoints=n_dev,
                                  meta={"source": "guard_replan",
                                        "step": step})
            detail["probe"] = {"records": len(records),
                               "fitted_keys": len(profile.params)}
        policy = CollectivePolicy.from_model(
            make_comm_model("tpu_v5e", calibration=profile),
            calibration=profile)
        detail["bucket_bytes"] = policy.bucket_bytes
        detail["wire"] = policy.wire.to_dict()
        program = self.cfg.program if self.cfg.program is not None \
            else policy.program
        if gcfg.lint and program is not None:
            from ..launch.lint import lint_program_on_mesh
            n_pod = self.mesh.shape.get("pod", 1) if self.mesh is not None else 1
            rep = lint_program_on_mesh(program, n_devices=n_dev,
                                       policy=policy, dcn=n_pod)
            detail["lint"] = {"program": rep["program"],
                              "findings": rep["findings"],
                              "records": rep["records"],
                              "seconds": round(rep["seconds"], 3)}
            if rep["findings"]:
                return False, detail  # keep the old plan: swap rejected
        self._swap_policy(policy)
        detail["swapped"] = True
        return True, detail

    def _swap_policy(self, policy) -> None:
        """Rebuild the compiled step under a new collective policy mid-run.

        Params/opt state are untouched (the swap is a dispatch-table change,
        not a state change); the error-feedback carrier is re-initialized by
        the rebuilt step.  On the fp32 wire the swap is numerically
        transparent — bit parity with an uninterrupted run (tested)."""
        self.cfg.policy = policy
        self._build(self.mesh)

    # --------------------------------------------------------- elastic mesh
    def _surviving_mesh(self, lost: Sequence[int]):
        """Rebuild the mesh on the devices that survived a node loss.

        The DP degree shrinks to the largest survivor count that divides the
        global batch (explicit-DP shards the batch over the dp axis); a
        two-level (pod) mesh collapses to single-level — the lost node broke
        the pod symmetry.  ZeRO state is carrier-sharded by the DP degree, so
        a shrink under zero=True cannot reinterpret the checkpoint and raises.
        """
        from jax.sharding import Mesh

        gone = set(int(d) for d in lost)
        survivors = [d for d in self.mesh.devices.flat if d.id not in gone]
        if not survivors:
            raise RuntimeError("node loss left no surviving devices")
        model_dim = self.mesh.shape.get("model", 1)
        n = max(len(survivors) // model_dim, 1)
        batch = self.shape.global_batch
        while n > 1 and batch % n:
            n -= 1
        old_dp = self.mesh.shape.get(self.cfg.dp_axis, 1)
        if self.cfg.zero and n != old_dp:
            raise RuntimeError(
                f"elastic re-mesh {old_dp} -> {n} devices with zero=True: the "
                f"carrier-sharded optimizer moments are laid out by the DP "
                f"degree; restore the ZeRO checkpoint on an equal-size mesh "
                f"or re-save replicated before shrinking")
        if model_dim > 1:
            devs = np.array(survivors[: n * model_dim]).reshape(n, model_dim)
            return Mesh(devs, ("data", "model"))
        self.cfg.dcn_axis = None  # a lost node collapses the two-level mesh
        return Mesh(np.array(survivors[:n]), (self.cfg.dp_axis,))

    # ------------------------------------------------------------ checkpoint
    def _zero_specs(self) -> Optional[Dict[str, str]]:
        """Per-leaf shard-spec metadata for the ZeRO carrier-sharded m/v (the
        checkpoint refuses a sharded<->replicated cross-restore on them)."""
        if self._dp_step is None or not getattr(self._dp_step, "zero", False):
            return None
        spec = self._dp_step.opt_shard_spec
        return {"opt/m": spec, "opt/v": spec}

    def save(self, step: int, params, opt_state):
        extra = {"step": step}
        program = getattr(self._dp_step, "program", None)
        if program is not None:
            # the schedule that produced this state, auditable from the
            # checkpoint alone (and the ZeRO shard specs below it)
            extra["program"] = program.to_dict()
        self.ckpt.save(step, {"params": params, "opt": opt_state},
                       extra=extra, specs=self._zero_specs(),
                       blocking=not self.cfg.ckpt_async)

    def restore(self, step: Optional[int] = None):
        specs = self._zero_specs()
        if specs is not None:
            abs_p = self.model.abstract_params()
            like = {"params": abs_p,
                    "opt": self._dp_step.abstract_opt_state(abs_p)}
            state, extra = self.ckpt.restore(like, step=step, specs=specs)
            return state["params"], state["opt"], int(extra["step"])
        like = {"params": self.model.abstract_params(),
                "opt": adamw.abstract_opt_state(self.model.abstract_params())}
        shardings = None
        if self.model.shd.mesh is not None:
            p_log = self.model.param_logical()
            shardings = {"params": tree_shardings_shaped(self.model.shd, p_log, like["params"]),
                         "opt": tree_shardings_shaped(self.model.shd,
                                                      adamw.opt_state_logical(p_log),
                                                      like["opt"])}
        state, extra = self.ckpt.restore(like, step=step, shardings=shardings)
        return state["params"], state["opt"], int(extra["step"])
