"""Training loop: checkpoint/restart, straggler mitigation, elastic re-meshing.

The loop composes:
  * steps.train_step_bundle       — jitted step with FSDP+TP shardings
  * checkpoint.CheckpointManager  — async atomic saves, reshard-on-restore
  * data.SyntheticLM/TokenFile    — step-keyed deterministic batches (replay)
  * core.noise.StragglerMitigator — per-step time tracking + action (Sec. VI)
  * elastic restart               — on device failure, rebuild the mesh from the
                                    surviving device set and restore the last
                                    checkpoint with the new shardings

On failure injection (tests) or real XlaRuntimeError, `run()` re-enters through
`_build()` with a fresh mesh; data replays from the restored step.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, Optional

import jax
import numpy as np

from ..checkpoint.manager import CheckpointManager
from ..configs.base import ModelConfig, ShapeConfig
from ..core.noise import StragglerMitigator
from ..data.pipeline import SyntheticLM, DataConfig
from ..models.model import build_model
from ..models.sharding import tree_shardings_shaped
from ..optim import adamw
from . import steps as rsteps


@dataclasses.dataclass
class TrainConfig:
    steps: int = 50
    microbatches: int = 1
    ckpt_every: int = 20
    ckpt_dir: str = "artifacts/ckpt"
    ckpt_async: bool = True
    log_every: int = 10
    straggler_threshold: float = 2.5
    straggler_action: str = "log"
    # explicit-DP path (shard_map + our collectives, paper Obs. 1/4): params
    # replicated, batch sharded on dp_axis (and dcn_axis on a two-pod mesh)
    explicit_dp: bool = False
    dp_axis: str = "data"
    dcn_axis: Optional[str] = None
    policy: Optional[object] = None       # core.autotune.CollectivePolicy
    bucket_bytes: Optional[int] = None    # None = plan crossover, 0 = per-tensor
    # int8 error-feedback wire compression (0 = fp32 wire).  Composes with
    # bucketing and overlap: the codec quantizes per bucket and the error
    # state becomes the carrier-shaped buffer (see runtime.steps)
    compress_bits: int = 0
    # overlap-aware execution (core.overlap): reverse-layer-order buckets on a
    # scan-carried issue schedule; with microbatches > 1 each bucket's
    # reduction overlaps the next microbatch's backward, and on a two-level
    # mesh buckets run the chunked hierarchical pipeline
    overlap: bool = False
    chunks: Optional[int] = None          # None = plan's per-tier alpha-beta fit
    # ZeRO-style sharded optimizer (runtime.steps): reduce-scatter the packed
    # carrier, AdamW over each device's shard (fp32 m/v carrier-sharded, so
    # optimizer memory drops by the DP degree), all-gather updated params at
    # the wire dtype.  Implies explicit_dp + bucketed carrier.
    zero: bool = False
    # StepProgram (core.program): the declarative schedule the step compiles
    # from.  When set it supersedes the boolean knobs above (which become a
    # legacy shim — launch.train.resolve_step_program builds the program from
    # the flags); its name is stamped into checkpoint metadata.
    program: Optional[object] = None


class Trainer:
    def __init__(self, model_cfg: ModelConfig, shape: ShapeConfig,
                 opt: Optional[adamw.OptConfig] = None,
                 train_cfg: Optional[TrainConfig] = None,
                 mesh=None, data=None):
        self.model_cfg = model_cfg
        self.shape = shape
        self.opt = opt or adamw.OptConfig()
        self.cfg = train_cfg or TrainConfig()
        self.mesh = mesh
        self.data = data or SyntheticLM(model_cfg, shape)
        self.ckpt = CheckpointManager(self.cfg.ckpt_dir)
        self.straggler = StragglerMitigator(threshold=self.cfg.straggler_threshold,
                                            action=self.cfg.straggler_action)
        self.metrics_log: list = []
        self._build(self.mesh)

    # ----------------------------------------------------------------- build
    def _build(self, mesh):
        if self.cfg.explicit_dp:
            if mesh is None:
                raise ValueError("explicit_dp requires a multi-device mesh; "
                                 "got mesh=None (single-device host?)")
            self._build_explicit_dp(mesh)
            return
        if self.cfg.zero:
            raise ValueError("zero=True requires the explicit-DP path "
                             "(explicit_dp=True / launch.train --zero)")
        self._dp_step = None
        self.model = build_model(self.model_cfg, mesh)
        self.bundle = rsteps.train_step_bundle(self.model, self.shape, self.opt,
                                               microbatches=self.cfg.microbatches)
        if mesh is not None:
            self.step_fn = jax.jit(self.bundle.fn, in_shardings=self.bundle.in_shardings,
                                   out_shardings=self.bundle.out_shardings,
                                   donate_argnums=self.bundle.donate_argnums)
        else:
            self.step_fn = jax.jit(self.bundle.fn, donate_argnums=self.bundle.donate_argnums)

    def _build_explicit_dp(self, mesh):
        """Explicit-DP: replicated params (model built without mesh constraints),
        gradients reduced by our CommPlan-dispatched collectives with bucketing.
        Error-feedback state lives on the trainer, initialized at first step."""
        c = self.cfg
        for ax, size in mesh.shape.items():
            if ax not in (c.dp_axis, c.dcn_axis) and size > 1:
                raise ValueError(f"explicit_dp needs a pure-DP mesh; axis {ax!r} "
                                 f"has size {size}")
        if c.microbatches > 1 and not c.overlap:
            raise ValueError("explicit-DP gradient accumulation is implemented "
                             "by the overlap schedule; pass overlap=True "
                             "(launch.train --overlap) with microbatches "
                             f"({c.microbatches} requested)")
        self.model = build_model(self.model_cfg)
        if c.program is not None:
            dp_step = rsteps.build_program_step(
                self.model, self.opt, mesh, c.program, axis=c.dp_axis,
                policy=c.policy, dcn_axis=c.dcn_axis)
        else:
            dp_step = rsteps.build_explicit_dp_step(
                self.model, self.opt, mesh, c.dp_axis, policy=c.policy,
                bucket_bytes=c.bucket_bytes, dcn_axis=c.dcn_axis,
                overlap=c.overlap, chunks=c.chunks,
                microbatches=c.microbatches, compress_bits=c.compress_bits,
                zero=c.zero)
        self._dp_step = dp_step
        self._dp_err = None

        def step_fn(params, opt_state, batch):
            if self._dp_err is None:
                # carrier-shaped under bucketed compression, per-leaf otherwise
                self._dp_err = dp_step.init_error_state(params)
            params, opt_state, metrics, self._dp_err = dp_step(
                params, opt_state, batch, self._dp_err)
            return params, opt_state, metrics

        self.step_fn = step_fn

    def init_state(self, seed: int = 0):
        params = self.model.init(jax.random.PRNGKey(seed))
        if self._dp_step is not None and getattr(self._dp_step, "zero", False):
            # carrier-sharded m/v: (n_buckets, padded_elems) fp32, laid out by
            # the step's codec table (runtime.steps.make_opt_state)
            opt_state = self._dp_step.init_opt_state(params)
        else:
            opt_state = adamw.init_opt_state(params)
        if self.model.shd.mesh is not None:
            p_sh = tree_shardings_shaped(self.model.shd, self.model.param_logical(),
                                         params)
            params = jax.tree.map(jax.device_put, params, p_sh)
        return params, opt_state

    # ------------------------------------------------------------------ run
    def run(self, params=None, opt_state=None, start_step: int = 0,
            resume: bool = False, inject_failure_at: Optional[int] = None) -> Dict:
        if resume and self.ckpt.latest_step() is not None:
            params, opt_state, start_step = self.restore()
        if params is None:
            params, opt_state = self.init_state()
        step = start_step
        while step < self.cfg.steps:
            batch = {k: jax.numpy.asarray(v) for k, v in self.data.batch_at(step).items()}
            t0 = time.perf_counter()
            try:
                if inject_failure_at is not None and step == inject_failure_at:
                    inject_failure_at = None
                    raise RuntimeError("injected device failure (test)")
                params, opt_state, metrics = self.step_fn(params, opt_state, batch)
                jax.block_until_ready(metrics["loss"])
            except (RuntimeError, jax.errors.JaxRuntimeError) as e:
                # elastic restart path: rebuild on surviving devices + restore
                self.ckpt.wait()
                restored = self.ckpt.latest_step()
                if restored is None:
                    raise
                self._build(self.mesh)
                params, opt_state, step = self.restore()
                continue
            dt = time.perf_counter() - t0
            ev = self.straggler.observe(step, dt)
            if ev is not None and self.cfg.straggler_action == "sync":
                jax.block_until_ready(params)
            row = {"step": step, "loss": float(metrics["loss"]),
                   "grad_norm": float(metrics["grad_norm"]),
                   "lr": float(metrics["lr"]), "time_s": dt,
                   "straggler": ev is not None}
            self.metrics_log.append(row)
            if step % self.cfg.log_every == 0:
                print(f"step {step:5d} loss {row['loss']:.4f} "
                      f"gnorm {row['grad_norm']:.3f} {dt*1e3:.0f}ms", flush=True)
            step += 1
            if self.cfg.ckpt_every and step % self.cfg.ckpt_every == 0:
                self.save(step, params, opt_state)
        self.save(step, params, opt_state)
        self.ckpt.wait()
        return {"final_step": step, "metrics": self.metrics_log,
                "straggler_events": len(self.straggler.events)}

    # ------------------------------------------------------------ checkpoint
    def _zero_specs(self) -> Optional[Dict[str, str]]:
        """Per-leaf shard-spec metadata for the ZeRO carrier-sharded m/v (the
        checkpoint refuses a sharded<->replicated cross-restore on them)."""
        if self._dp_step is None or not getattr(self._dp_step, "zero", False):
            return None
        spec = self._dp_step.opt_shard_spec
        return {"opt/m": spec, "opt/v": spec}

    def save(self, step: int, params, opt_state):
        extra = {"step": step}
        program = getattr(self._dp_step, "program", None)
        if program is not None:
            # the schedule that produced this state, auditable from the
            # checkpoint alone (and the ZeRO shard specs below it)
            extra["program"] = program.to_dict()
        self.ckpt.save(step, {"params": params, "opt": opt_state},
                       extra=extra, specs=self._zero_specs(),
                       blocking=not self.cfg.ckpt_async)

    def restore(self, step: Optional[int] = None):
        specs = self._zero_specs()
        if specs is not None:
            abs_p = self.model.abstract_params()
            like = {"params": abs_p,
                    "opt": self._dp_step.abstract_opt_state(abs_p)}
            state, extra = self.ckpt.restore(like, step=step, specs=specs)
            return state["params"], state["opt"], int(extra["step"])
        like = {"params": self.model.abstract_params(),
                "opt": adamw.abstract_opt_state(self.model.abstract_params())}
        shardings = None
        if self.model.shd.mesh is not None:
            p_log = self.model.param_logical()
            shardings = {"params": tree_shardings_shaped(self.model.shd, p_log, like["params"]),
                         "opt": tree_shardings_shaped(self.model.shd,
                                                      adamw.opt_state_logical(p_log),
                                                      like["opt"])}
        state, extra = self.ckpt.restore(like, step=step, shardings=shardings)
        return state["params"], state["opt"], int(extra["step"])
