from . import steps
from .train import Trainer, TrainConfig
from .serve import BatchedServer, ServeConfig
