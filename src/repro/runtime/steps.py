"""Step builders: jit-ready train/prefill/decode steps with shardings.

Two trainer mechanisms, mirroring the paper's software-layer axis (DESIGN.md §2):
  * `build_train_step`      — XLA SPMD chooses every collective (the *CCL analog);
  * `build_explicit_dp_step`— pure data parallelism under shard_map with *our*
    collective algorithms from core/ (the GPU-aware-MPI analog), with optional
    int8 gradient compression (error feedback) on the wire.

`build_train_step` supports gradient accumulation (microbatching): the batch is
split on the leading axis and grads are accumulated in fp32 by a lax.scan —
bounding activation memory and letting XLA overlap the per-microbatch
reduce-scatters with the next microbatch's backward (compute/comm overlap).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..core import program as prg
from ..core.autotune import CollectivePolicy
from ..models.model import Model
from ..models.sharding import Sharder, tree_shardings, tree_shardings_shaped
from ..optim import adamw


@dataclasses.dataclass
class StepBundle:
    """A jit-able step function plus its sharding pytrees."""
    fn: Callable
    in_shardings: Any
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()


def _microbatch(batch, n: int):
    def split(a):
        if a.shape[0] % n:
            raise ValueError(
                f"batch leading axis {a.shape[0]} is not divisible by "
                f"microbatches={n}; choose a microbatch count that divides "
                f"the (per-shard) batch size")
        return a.reshape((n, a.shape[0] // n) + a.shape[1:])

    return jax.tree.map(split, batch)


def build_train_step(model: Model, opt: adamw.OptConfig,
                     microbatches: int = 1) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            mb = _microbatch(batch, microbatches)

            def acc_body(carry, b):
                gsum, lsum = carry
                loss, grads = jax.value_and_grad(model.loss)(params, b)
                gsum = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                return (gsum, lsum + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(acc_body, (zeros, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
        else:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state, metrics = adamw.apply_updates(params, grads, opt_state, opt)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def train_step_bundle(model: Model, shape: ShapeConfig, opt: adamw.OptConfig,
                      microbatches: int = 1) -> StepBundle:
    shd = model.shd
    p_log = model.param_logical()
    p_sh = tree_shardings_shaped(shd, p_log, model.abstract_params())
    o_log = adamw.opt_state_logical(p_log)
    o_abs = adamw.abstract_opt_state(model.abstract_params())
    o_sh = tree_shardings_shaped(shd, o_log, o_abs)
    b_sh = tree_shardings_shaped(shd, model.batch_logical(shape), model.input_specs(shape))
    none_sh = shd.sharding((), ()) if shd.mesh is not None else None
    m_sh = {"grad_norm": none_sh, "lr": none_sh, "loss": none_sh} if shd.mesh is not None else None
    fn = build_train_step(model, opt, microbatches)
    return StepBundle(fn, (p_sh, o_sh, b_sh), (p_sh, o_sh, m_sh), donate_argnums=(0, 1))


def _logits_sharding(model: Model, shape: ShapeConfig):
    """Last-position logits sharding with vocab-divisibility checked against the
    actual shape (mamba2's 50280 / internvl2's 92553 don't divide 16)."""
    shd = model.shd
    if shd.mesh is None:
        return None
    c = model.cfg
    if c.n_codebooks:
        dims = ("batch", None, None, "tp")
        lshape = (shape.global_batch, 1, c.n_codebooks, c.vocab)
    else:
        dims = ("batch", None, "tp")
        lshape = (shape.global_batch, 1, c.vocab)
    return shd.sharding(dims, lshape)


def decode_step_bundle(model: Model, shape: ShapeConfig) -> StepBundle:
    shd = model.shd
    p_sh = tree_shardings_shaped(shd, model.param_logical(), model.abstract_params())
    c_sh = tree_shardings_shaped(shd, model.cache_logical(shape), model.abstract_cache(shape))
    b_log = model.batch_logical(shape)
    b_abs = model.input_specs(shape)
    tok_sh = tree_shardings_shaped(shd, {"tokens": b_log["tokens"]}, {"tokens": b_abs["tokens"]})["tokens"] \
        if shd.mesh is not None else None
    pos_sh = shd.sharding((), ()) if shd.mesh is not None else None
    logits_sh = _logits_sharding(model, shape)

    def decode_step(params, cache, tokens, pos):
        return model.decode(params, cache, tokens, pos)

    return StepBundle(decode_step, (p_sh, c_sh, tok_sh, pos_sh), (logits_sh, c_sh),
                      donate_argnums=(1,))


def prefill_step_bundle(model: Model, shape: ShapeConfig) -> StepBundle:
    shd = model.shd
    p_sh = tree_shardings_shaped(shd, model.param_logical(), model.abstract_params())
    c_sh = tree_shardings_shaped(shd, model.cache_logical(shape), model.abstract_cache(shape))
    b_sh = tree_shardings_shaped(shd, model.batch_logical(shape), model.input_specs(shape))
    logits_sh = _logits_sharding(model, shape)

    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)

    return StepBundle(prefill_step, (p_sh, b_sh, c_sh), (logits_sh, c_sh),
                      donate_argnums=(2,))


# --------------------------------------------------------------- explicit DP
def build_explicit_dp_step(model: Model, opt: adamw.OptConfig, mesh, axis: str = "data",
                           policy: Optional[CollectivePolicy] = None,
                           compress_bits: int = 0,
                           bucket_bytes: Optional[int] = None,
                           dcn_axis: Optional[str] = None,
                           overlap: bool = False,
                           microbatches: int = 1,
                           chunks: Optional[int] = None,
                           zero: bool = False,
                           step_program: Optional[prg.StepProgram] = None) \
        -> Callable:
    """Pure-DP train step under shard_map with explicit gradient collectives.

    Params/opt state replicated; batch sharded on `axis` (and `dcn_axis` when
    given).  Gradients are reduced with the CommPlan/CollectivePolicy algorithm
    choice (paper Obs. 1/4 applied), with optional int8 error-feedback
    compression on the wire (4x fewer DP bytes).

    Bucketing (the paper's message-aggregation optimization): the flat gradient
    list is packed by the fused wire codec (`kernels.bucket_codec`) into fixed
    `bucket_bytes` rows before reduction, so small tensors stop paying
    per-message latency — one fused pack and one fused unpack per step, O(1)
    concatenate ops regardless of leaf count (the old path emitted one
    concatenate per bucket and per leaf).  The default bucket size comes from
    the plan's latency/bandwidth crossover; pass `bucket_bytes=0` to reduce
    per-tensor.  `dcn_axis` on a two-pod mesh routes every bucket through the
    hierarchical intra-RS / inter-AR / intra-AG schedule (selected whenever
    the plan was built from a two-level topology).

    Compression (`compress_bits=8`) now *composes* with bucketing and overlap:
    the codec quantizes to the int8 wire inside the pack kernel with
    per-bucket scales, and the error-feedback state is a carrier-shaped
    `(n_buckets, bucket_elems)` fp32 buffer carried per bucket through the
    scan schedule (donated through the jit, so steady-state steps reuse the
    buffer).  Reduction of a quantized bucket all-gathers the int8 payload +
    scales and sums after dequant (`overlap.quantized_all_reduce`); on a
    two-level mesh the inter leg stays fp32 (requantizing partial sums would
    add error outside the error-feedback loop) and `chunks > 1` pipelines the
    intra gather of chunk t against the inter psum of chunk t-1.  Without
    bucketing/overlap, `compress_bits=8` keeps the legacy per-tensor wire
    (per-tensor scales).

    Overlap (`overlap=True`, paper Sec. VI / Obs. 1): buckets are built in
    *reverse layer order* (the order backward materializes gradients) and
    reduced through `core.overlap`'s scan-carried issue schedule — one bucket
    in flight at a time instead of one post-hoc blob.  With `microbatches > 1`
    the scan carries the previous microbatch's unreduced buckets, so each
    bucket's all-reduce is issued *inside the same scan step* as the next
    microbatch's backward and overlaps it.  With `dcn_axis`, each bucket runs
    the chunked double-buffered hierarchical pipeline; `chunks=None` takes the
    pipeline depth from the plan's per-tier alpha-beta fits
    (`plan.pipeline_chunks`).

    ZeRO (`zero=True`): the bucket schedule switches from all-reduce +
    replicated AdamW to the three-phase **reduce-scatter of the packed carrier
    -> sharded AdamW over each device's carrier shard -> all-gather of updated
    params** — the reduce leg moves each gradient byte once per shard instead
    of twice, and the fp32 moments live carrier-sharded (optimizer memory
    divided by the DP degree; the returned step exposes
    `step.init_opt_state(params)` / `step.abstract_opt_state(params)` for the
    sharded state).  Global-norm clipping stays exact: the per-shard sum of
    squares is psum-combined over the dp axes before the clip factor forms —
    which also makes all-RS-before-any-update a semantic barrier, so the
    overlap the schedule can legally express is the RS stream against the
    backward (scan-carried, microbatch-pipelined) and the chunked two-tier
    interleave inside each leg, not AG(k) against RS(k+1).  The update itself
    is the fused dequant+AdamW+requantize shard kernel
    (`bucket_codec.adamw_update_shard`); with `compress_bits=8` the AG leg
    carries int8 + one scale per bucket-shard and every device (including the
    shard owner) uses the dequantized values, keeping params bit-identically
    replicated.  The codec is the single gradient *and* parameter
    materialization point; `err` passes through untouched (no error feedback
    on the param leg — the same payload rides every tier, so the only error
    is the single quantization step).

    The returned step exposes `step.init_error_state(params)` — carrier-shaped
    zeros when compression rides buckets, per-leaf zeros otherwise.
    """
    from jax.sharding import PartitionSpec as P
    from ..core import overlap as ov
    from ..kernels import bucket_codec as codec

    policy = policy or CollectivePolicy.from_model()
    n = mesh.shape[axis]
    n_total = n * (mesh.shape[dcn_axis] if dcn_axis is not None else 1)
    if compress_bits not in (0, 8):
        raise ValueError(f"compress_bits must be 0 or 8, got {compress_bits}")
    if microbatches > 1 and not overlap:
        raise ValueError("explicit-DP microbatching is implemented by the "
                         "overlap schedule; pass overlap=True")
    if overlap and bucket_bytes == 0:
        # the overlap scan needs equal-size packed buckets — refuse the
        # documented per-tensor mode instead of silently re-bucketing
        raise ValueError("overlap=True requires bucketing; per-tensor "
                         "reduction (bucket_bytes=0) is not supported — omit "
                         "bucket_bytes to use the plan's crossover")
    if zero and bucket_bytes == 0:
        raise ValueError("zero=True shards the packed carrier; per-tensor "
                         "reduction (bucket_bytes=0) is not supported — omit "
                         "bucket_bytes to use the plan's crossover")
    # normalize through the StepProgram IR: the program (given directly or
    # built from the legacy flag combination) is the single description of
    # this step — the knobs below are *lowered* from it, and the same object
    # is what the cost model prices (exposed_comm_time(program=)) and the
    # plan persists.  The boolean kwargs are retained as a shim.
    if step_program is None:
        step_program = prg.train_step_program(
            overlap=overlap, zero=zero, compress_bits=compress_bits,
            chunks=chunks, microbatches=microbatches,
            bucket_bytes=bucket_bytes)
    kw = step_program.validate().step_kwargs()
    overlap, zero = kw["overlap"], kw["zero"]
    compress_bits, chunks = kw["compress_bits"], kw["chunks"]
    microbatches, bucket_bytes = kw["microbatches"], kw["bucket_bytes"]
    if bucket_bytes is None:
        # plain compress_bits (no overlap, no explicit bucket size) keeps the
        # legacy per-tensor wire; bucketed compression opts in via
        # bucket_bytes/overlap (zero is always bucketed: the carrier is the
        # thing being sharded)
        bucket_bytes = 0 if (compress_bits and not overlap and not zero) \
            else getattr(policy, "bucket_bytes", 0)
    if (overlap or zero) and not bucket_bytes:
        bucket_bytes = 4 << 20  # policy carried no crossover (legacy tables)
    bucketed = bucket_bytes > 0
    loss_axes = (dcn_axis, axis) if dcn_axis is not None else axis
    plan_hier = bool(getattr(policy, "hierarchical", False))
    if chunks is None:
        chunks_fn = getattr(policy, "pipeline_chunks", None)
        chunks = chunks_fn(bucket_bytes) if (chunks_fn is not None and
                                             dcn_axis is not None) else 1
    chunks = max(int(chunks), 1)
    bucket_elems = max(bucket_bytes // 4, 1)

    # ----------------------------------------------------------------- zero
    # carrier geometry of the three-phase schedule: rows are column-padded so
    # every bucket splits evenly into n_chunks chunks of n_ici * n_dcn shard
    # blocks (zeros are the reduction identity AND an AdamW fixed point, so
    # the pad stays zero forever).  The device at (axis=i, dcn=j) owns block
    # i * n_dcn + j of each chunk; its shard is the concatenation of its
    # per-chunk blocks (shard-major layout, mirrored exactly by the AG).
    n_dcn = mesh.shape[dcn_axis] if dcn_axis is not None else 1
    zero_chunks = chunks if dcn_axis is not None else 1
    shard_unit = zero_chunks * n * n_dcn
    shard_axes = (axis,) if dcn_axis is None else (axis, dcn_axis)
    zero_wire = "int8" if compress_bits == 8 else "fp32"

    def zero_geometry(sizes):
        table = codec.make_table(sizes, bucket_elems, reverse=bool(overlap))
        padded = -(-table.bucket_elems // shard_unit) * shard_unit
        return table, padded

    def pad_cols(carrier, padded):
        if padded > carrier.shape[1]:
            carrier = jnp.concatenate(
                [carrier, jnp.zeros((carrier.shape[0],
                                     padded - carrier.shape[1]),
                                    carrier.dtype)], axis=1)
        return carrier

    def zero_rs(row):
        return ov.two_tier_reduce_scatter(
            row, axis, dcn_axis, n_chunks=zero_chunks,
            rs=lambda v, ax: policy.reduce_scatter(v, ax, mesh.shape[ax]))

    def zero_ag(shard):
        return ov.two_tier_all_gather(
            shard, axis, dcn_axis, n_chunks=zero_chunks,
            ag=lambda v, ax: policy.all_gather(v, ax, mesh.shape[ax]))

    def zero_ag_q(shard_and_scale):
        q_row, s_row = shard_and_scale
        return ov.quantized_all_gather(q_row, s_row, axis, dcn_axis=dcn_axis,
                                       n_chunks=zero_chunks)

    def zero_step(params, opt_state, batch, err):
        flat_p, tdef = jax.tree.flatten(params)
        table, padded = zero_geometry([p.size for p in flat_p])
        nb = table.n_buckets
        step_no = opt_state["step"] + 1
        lr = adamw.schedule(step_no, opt)
        if nb == 0:  # every parameter leaf is zero-size: nothing on the wire
            loss = jax.lax.pmean(model.loss(params, batch), loss_axes)
            metrics = {"grad_norm": jnp.zeros((), jnp.float32), "lr": lr,
                       "loss": loss}
            return params, {"m": opt_state["m"], "v": opt_state["v"],
                            "step": step_no}, metrics, err
        cap = table.bucket_elems
        shard_elems = padded // (n * n_dcn)
        inv = 1.0 / (n_total * microbatches)

        def grads_of(b):
            loss, grads = jax.value_and_grad(model.loss)(params, b)
            flat, _ = jax.tree.flatten(grads)
            # same canonical-materialization barrier as the allreduce paths
            return loss, jax.lax.optimization_barrier(flat)

        def pack_pad(flat):
            carrier, _, _ = codec.pack(table, flat, scale=inv)
            return pad_cols(carrier, padded)

        if microbatches == 1:
            loss, flat_g = grads_of(batch)
            carrier = pack_pad(flat_g)
            if overlap:
                # scan-carried RS stream: one bucket's reduce-scatter in
                # flight at a time, in backward materialization order
                g_shard = ov.scan_bucket_reduce(carrier, zero_rs)
            else:
                g_shard = jnp.stack([zero_rs(carrier[k]) for k in range(nb)])
        else:
            mb = _microbatch(batch, microbatches)
            mb0 = jax.tree.map(lambda a: a[0], mb)
            rest = jax.tree.map(lambda a: a[1:], mb)
            loss0, flat0 = grads_of(mb0)
            pending0 = pack_pad(flat0)

            def body(carry, b):
                acc, pending, lsum = carry
                # previous microbatch's reduce-scatters are issued FIRST (no
                # dependency on this backward) so they overlap it; shards are
                # accumulated — 1/n of the accumulator an all-reduce carries
                red = jnp.stack([zero_rs(pending[k]) for k in range(nb)])
                loss, flat = grads_of(b)
                return (acc + red, pack_pad(flat), lsum + loss), None

            init = (jnp.zeros((nb, shard_elems), jnp.float32), pending0,
                    loss0)
            (acc, pending, lsum), _ = jax.lax.scan(body, init, rest)
            final = jnp.stack([zero_rs(pending[k]) for k in range(nb)])
            g_shard = acc + final
            loss = lsum / microbatches
        loss = jax.lax.pmean(loss, loss_axes)

        # exact global-norm clipping: the per-shard sum of squares is
        # psum-combined over the dp axes before the clip factor forms.  This
        # is also the schedule's semantic barrier — no shard may update until
        # every bucket's reduce-scatter has landed.
        gsq = jax.lax.psum(jnp.sum(jnp.square(g_shard)), loss_axes)
        gnorm = jnp.sqrt(gsq)
        clip = jnp.minimum(1.0, opt.clip_norm / (gnorm + 1e-9))
        bc1 = 1 - opt.b1 ** step_no.astype(jnp.float32)
        bc2 = 1 - opt.b2 ** step_no.astype(jnp.float32)

        # params ride the same codec: pack (casts to fp32), pad, slice this
        # device's shard-major blocks out of each chunk
        p_carrier = pad_cols(codec.pack(table, flat_p)[0], padded)
        ix = jax.lax.axis_index(axis) * n_dcn + (
            jax.lax.axis_index(dcn_axis) if dcn_axis is not None else 0)
        sub = shard_elems // zero_chunks
        p_shard = jax.lax.dynamic_slice(
            p_carrier.reshape(nb, zero_chunks, padded // zero_chunks),
            (0, 0, ix * sub), (nb, zero_chunks, sub)).reshape(nb, shard_elems)

        p_wire, p_scales, new_m, new_v = codec.adamw_update_shard(
            g_shard, p_shard, opt_state["m"], opt_state["v"],
            clip=clip, lr=lr, bc1=bc1, bc2=bc2, b1=opt.b1, b2=opt.b2,
            eps=opt.eps, weight_decay=opt.weight_decay, wire=zero_wire)

        if zero_wire == "int8":
            if overlap:
                full = ov.scan_bucket_reduce((p_wire, p_scales), zero_ag_q)
            else:
                full = jnp.stack([zero_ag_q((p_wire[k], p_scales[k]))
                                  for k in range(nb)])
        elif overlap:
            full = ov.scan_bucket_reduce(p_wire, zero_ag)
        else:
            full = jnp.stack([zero_ag(p_wire[k]) for k in range(nb)])
        new_flat = codec.unpack(table, full[:, :cap], flat_p)
        new_params = tdef.unflatten(
            [r.astype(p.dtype) for r, p in zip(new_flat, flat_p)])
        metrics = {"grad_norm": gnorm, "lr": lr, "loss": loss}
        return new_params, {"m": new_m, "v": new_v, "step": step_no}, \
            metrics, err

    def reduce_bucket(buf):
        """One packed fp32 bucket through the planned reduction: the chunked
        hierarchical pipeline on a two-level mesh, else the plan's algorithm."""
        if dcn_axis is not None and plan_hier and chunks > 1:
            return ov.chunked_hierarchical_all_reduce(buf, axis, dcn_axis,
                                                      n_chunks=chunks)
        return policy.all_reduce(buf, axis, n, dcn_axis=dcn_axis)

    def reduce_q(row_and_scale, n_chunks=1):
        """One int8 bucket (row + per-bucket scale) over the wire: intra
        all-gather of the payload + scales, local dequant-sum, fp32 inter leg;
        chunked double-buffered across the two tiers when `n_chunks > 1`."""
        q_row, s_row = row_and_scale
        return ov.quantized_all_reduce(q_row, s_row, axis, dcn_axis=dcn_axis,
                                       n_chunks=n_chunks)

    def reduce_bucketed(flat_g, err):
        """Pack the flat gradient stream into bucket_bytes rows (tensors split
        at bucket boundaries, forward order) and reduce each eagerly — exactly
        ceil(total_bytes / bucket_bytes) all-reduce calls, post-backward.  The
        codec shares span construction with the overlap engine; only the issue
        schedule differs.  With compression, quantization (and the per-bucket
        error feedback) happens in the pack."""
        table = codec.make_table([g.size for g in flat_g], bucket_elems,
                                 reverse=False)
        if table.n_buckets == 0:
            return [g.astype(jnp.float32) for g in flat_g], err
        cap = table.bucket_elems
        tail = table.total_elems - (table.n_buckets - 1) * cap

        def reduce_row(k, row, fn):
            # the final partial bucket keeps its exact wire size: the zero pad
            # is never sent (and the size-dependent algorithm dispatch sees
            # the true payload)
            if k == table.n_buckets - 1 and tail < cap:
                red = fn(row[:tail])
                return jnp.concatenate(
                    [red, jnp.zeros((cap - tail,), red.dtype)])
            return fn(row)

        if compress_bits == 8:
            q, s, new_err = codec.pack(table, flat_g, scale=1.0 / n_total,
                                       wire="int8", err=err)
            rows = [reduce_row(k, q[k],
                               lambda r, kk=k: reduce_q((r, s[kk]),
                                                        n_chunks=chunks))
                    for k in range(table.n_buckets)]
            return codec.unpack(table, rows, flat_g), new_err
        carrier, _, _ = codec.pack(table, flat_g, scale=1.0 / n_total)
        rows = [reduce_row(k, carrier[k],
                           lambda r: policy.all_reduce(r, axis, n,
                                                       dcn_axis=dcn_axis))
                for k in range(table.n_buckets)]
        return codec.unpack(table, rows, flat_g), err

    def overlap_grads(params, batch, err):
        """Reverse-layer-order bucketed gradients under the overlap issue
        schedule.  Returns (mean loss over microbatches, reduced flat grads in
        fp32, tree def, new error state)."""
        inv = 1.0 / (n_total * microbatches)

        def grads_of(b):
            loss, grads = jax.value_and_grad(model.loss)(params, b)
            flat, tdef = jax.tree.flatten(grads)
            # pin one canonical materialization of the (rematted) backward:
            # without the barrier XLA re-fuses the grad computation per wire
            # consumer graph, so different wire paths see bf16-ulp-different
            # gradient bits and step numerics depend on the wire configuration
            return loss, jax.lax.optimization_barrier(flat), tdef

        if microbatches == 1:
            loss, flat_g, tdef = grads_of(batch)
            table = codec.make_table([g.size for g in flat_g], bucket_elems)
            if table.n_buckets == 0:  # every gradient leaf is zero-size
                return loss, [g.astype(jnp.float32) for g in flat_g], tdef, err
            if compress_bits == 8:
                q, s, new_err = codec.pack(table, flat_g, scale=inv,
                                           wire="int8", err=err)
                # scan-carried issue schedule over the quantized carrier: one
                # int8 bucket (+ scale) in flight at a time
                reduced = ov.scan_bucket_reduce(
                    (q, s), partial(reduce_q, n_chunks=chunks))
                return loss, codec.unpack(table, reduced, flat_g), tdef, new_err
            carrier, _, _ = codec.pack(table, flat_g, scale=inv)
            # scan-carried issue schedule: one bucket in flight at a time, in
            # the order backward materializes them
            reduced = ov.scan_bucket_reduce(carrier, reduce_bucket)
            return loss, codec.unpack(table, reduced, flat_g), tdef, err

        mb = _microbatch(batch, microbatches)
        mb0 = jax.tree.map(lambda a: a[0], mb)
        rest = jax.tree.map(lambda a: a[1:], mb)
        loss0, flat0, tdef = grads_of(mb0)
        table = codec.make_table([g.size for g in flat0], bucket_elems)
        nb = table.n_buckets
        if nb == 0:
            raise ValueError("overlap microbatching found no gradient "
                             "elements to reduce (all leaves zero-size)")

        if compress_bits == 8:
            q0, s0, err1 = codec.pack(table, flat0, scale=inv, wire="int8",
                                      err=err)

            def body_q(carry, b):
                acc, q_p, s_p, err_c, lsum = carry
                # issue the previous microbatch's quantized bucket reductions
                # FIRST: no data dependency on this microbatch's backward
                reduced = jnp.stack([reduce_q((q_p[k], s_p[k]),
                                              n_chunks=chunks)
                                     for k in range(nb)])
                loss, flat, _ = grads_of(b)
                # per-bucket error feedback carried through the scan
                q_n, s_n, err_c = codec.pack(table, flat, scale=inv,
                                             wire="int8", err=err_c)
                return (acc + reduced, q_n, s_n, err_c, lsum + loss), None

            init = (jnp.zeros((nb, table.bucket_elems), jnp.float32),
                    q0, s0, err1, loss0)
            (acc, q_p, s_p, err_c, lsum), _ = jax.lax.scan(body_q, init, rest)
            final = jnp.stack([reduce_q((q_p[k], s_p[k]), n_chunks=chunks)
                               for k in range(nb)])
            loss = lsum / microbatches
            return (loss, codec.unpack(table, acc + final, flat0), tdef, err_c)

        pending0, _, _ = codec.pack(table, flat0, scale=inv)

        def body(carry, b):
            acc, pending, lsum = carry
            # issue the previous microbatch's bucket reductions FIRST: they
            # have no data dependency on this microbatch's backward, so the
            # scheduler overlaps the reduction stream with the backward compute
            reduced = jnp.stack([reduce_bucket(pending[k])
                                 for k in range(nb)])
            loss, flat, _ = grads_of(b)
            nxt, _, _ = codec.pack(table, flat, scale=inv)
            return (acc + reduced, nxt, lsum + loss), None

        init = (jnp.zeros_like(pending0), pending0, loss0)
        (acc, pending, lsum), _ = jax.lax.scan(body, init, rest)
        # flush: the last microbatch's buckets have no backward left to hide
        # behind — this is the exposed tail the predictor charges for
        final = jnp.stack([reduce_bucket(pending[k]) for k in range(nb)])
        reduced = acc + final
        loss = lsum / microbatches
        return loss, codec.unpack(table, reduced, flat0), tdef, err

    def local_step(params, opt_state, batch, err):
        if overlap:
            loss, red_flat, tdef, new_err = overlap_grads(params, batch, err)
            loss = jax.lax.pmean(loss, loss_axes)
            grads = tdef.unflatten(red_flat)
            params, opt_state, metrics = adamw.apply_updates(params, grads,
                                                             opt_state, opt)
            metrics["loss"] = loss
            return params, opt_state, metrics, new_err
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        loss = jax.lax.pmean(loss, loss_axes)

        def reduce_one(g, e):
            g32 = g.astype(jnp.float32) / n_total
            if compress_bits == 8:
                g32 = g32 + e
                scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
                q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
                deq = q.astype(jnp.float32) * scale
                new_e = g32 - deq
                # wire format: int8 payload + per-tensor fp32 scale, summed
                # after dequant — the all-gather moves s/4 + 4 bytes per peer,
                # not the 4x dequantized fp32 tensor
                qg = jax.lax.all_gather(q, axis)          # (n, ...) int8 wire
                sg = jax.lax.all_gather(scale, axis)      # (n,) fp32 scales
                summed = jnp.tensordot(sg, qg.astype(jnp.float32),
                                       axes=((0,), (0,)))
                if dcn_axis is not None:
                    # DCN leg stays fp32: re-quantizing the partial sum would
                    # add error outside the error-feedback loop
                    summed = jax.lax.psum(summed, dcn_axis)
                return summed, new_e
            return policy.all_reduce(g32, axis, n, dcn_axis=dcn_axis), e

        flat_g, tdef = jax.tree.flatten(grads)
        # same canonical-materialization barrier as the overlap path: the
        # reduced gradients must not depend on which wire path consumes them
        flat_g = jax.lax.optimization_barrier(flat_g)
        if bucketed:
            # err is carrier-shaped (compression) or passed through (fp32)
            reduced, new_err = reduce_bucketed(flat_g, err)
            grads = tdef.unflatten(reduced)
        else:
            flat_e = tdef.flatten_up_to(err)
            out = [reduce_one(g, e) for g, e in zip(flat_g, flat_e)]
            grads = tdef.unflatten([o[0] for o in out])
            new_err = tdef.unflatten([o[1] for o in out])
        params, opt_state, metrics = adamw.apply_updates(params, grads, opt_state, opt)
        metrics["loss"] = loss
        return params, opt_state, metrics, new_err

    def specs_like(tree, spec):
        return jax.tree.map(lambda _: spec, tree)

    def make(params, opt_state, batch, err):
        from jax import shard_map
        batch_axes = (dcn_axis, axis) if dcn_axis is not None else axis
        p_spec = specs_like(params, P())
        if zero:
            # fp32 moments are carrier-sharded on their column axis: each
            # device holds (n_buckets, padded / (n * n_dcn)) — optimizer
            # memory divided by the DP degree — and steady-state steps pass
            # the sharded arrays straight back in (no resharding)
            mv_spec = P(None, shard_axes)
            o_spec = {"m": mv_spec, "v": mv_spec, "step": P()}
        else:
            o_spec = specs_like(opt_state, P())
        b_spec = specs_like(batch, P(batch_axes))
        e_spec = specs_like(err, P())
        m_spec = {"grad_norm": P(), "lr": P(), "loss": P()}
        return shard_map(zero_step if zero else local_step, mesh=mesh,
                         in_specs=(p_spec, o_spec, b_spec, e_spec),
                         out_specs=(p_spec, o_spec, m_spec, e_spec),
                         check_vma=False)

    # remat inside the loss emits closed_call, which shard_map can't evaluate
    # eagerly — jit around the shard_map is required.  The shard_map specs
    # depend only on the pytree structures, so cache the built jit per
    # flattened tree-structure tuple: repeat calls with the same structures
    # reuse one jit (no per-step retrace), while a call with a different
    # batch/params structure gets fresh specs instead of silently reusing the
    # first call's stale shard_map specs.  Under compression the error state
    # (the codec's carrier-shaped buffer) is donated through the jit: it is
    # consumed and replaced every step, so steady-state training reuses the
    # carrier buffer instead of allocating a fresh one each step.  The fp32
    # paths pass err through untouched, where donation would only forbid
    # callers from reusing it for no win — so it is gated on compress_bits.
    cache: Dict[Tuple, Callable] = {}
    # zero mode never donates: err passes through untouched (no error
    # feedback on the param leg), and the parity tests legitimately reuse one
    # opt_state across several step builders
    donate = (3,) if (compress_bits and not zero) else ()

    def step(params, opt_state, batch, err):
        key = tuple(jax.tree.structure(t)
                    for t in (params, opt_state, batch, err))
        fn = cache.get(key)
        if fn is None:
            fn = cache[key] = jax.jit(make(params, opt_state, batch, err),
                                      donate_argnums=donate)
        return fn(params, opt_state, batch, err)

    def make_error_state(params):
        """Zeros of this step's error-feedback state: a carrier-shaped
        (n_buckets, bucket_elems) fp32 buffer when compression rides buckets,
        per-leaf zeros otherwise (the per-tensor legacy wire).  The zero path
        carries no error feedback (the param leg's payload rides every tier
        unchanged), so its state is a placeholder scalar."""
        if zero:
            return jnp.zeros((), jnp.float32)
        if compress_bits == 8 and bucketed:
            sizes = [p.size for p in jax.tree.leaves(params)]
            table = codec.make_table(sizes, bucket_elems,
                                     reverse=bool(overlap))
            return jnp.zeros((max(table.n_buckets, 1), table.bucket_elems),
                             jnp.float32)
        return init_error_state(params)

    def _param_sizes(params):
        import math as _math
        return [int(_math.prod(p.shape)) for p in jax.tree.leaves(params)]

    def make_opt_state(params):
        """Carrier-sharded optimizer state of the zero path: fp32 moments of
        shape (n_buckets, padded_bucket_elems) whose columns the step's
        in_specs shard over the dp axes (memory per device = full / DP)."""
        if not zero:
            return adamw.init_opt_state(params)
        table, padded = zero_geometry(_param_sizes(params))
        nb = max(table.n_buckets, 1)
        return {"m": jnp.zeros((nb, padded), jnp.float32),
                "v": jnp.zeros((nb, padded), jnp.float32),
                "step": jnp.zeros((), jnp.int32)}

    def make_abstract_opt_state(params):
        """ShapeDtypeStructs of `make_opt_state` (checkpoint restore target).
        `params` may be abstract or concrete."""
        if not zero:
            return adamw.abstract_opt_state(params)
        table, padded = zero_geometry(_param_sizes(params))
        mv = jax.ShapeDtypeStruct((max(table.n_buckets, 1), padded),
                                  jnp.float32)
        return {"m": mv, "v": mv,
                "step": jax.ShapeDtypeStruct((), jnp.int32)}

    def lower(params, opt_state, batch, err):
        """Lowered (pre-compile) artifact of this step's jit — the same
        cached jit the step itself runs, donation included, so the post-SPMD
        HLO `launch.lint --hlo` analyzes is exactly what executes."""
        key = tuple(jax.tree.structure(t)
                    for t in (params, opt_state, batch, err))
        fn = cache.get(key)
        if fn is None:
            fn = cache[key] = jax.jit(make(params, opt_state, batch, err),
                                      donate_argnums=donate)
        return fn.lower(params, opt_state, batch, err)

    step.lower = lower
    step._cache = cache  # introspectable by tests
    step.program = step_program
    step.donate_argnums = donate  # read by analysis.trace.trace_step
    step.init_error_state = make_error_state
    step.init_opt_state = make_opt_state
    step.abstract_opt_state = make_abstract_opt_state
    step.zero = zero
    # checkpoint shard-spec tag of the carrier-sharded moments: records the
    # sharded layout in the manifest so a replicated restore fails loudly
    step.opt_shard_spec = "zero-carrier:" + ",".join(shard_axes) if zero \
        else None
    return step


def build_program_step(model: Model, opt: adamw.OptConfig, mesh,
                       program: prg.StepProgram, axis: str = "data",
                       policy: Optional[CollectivePolicy] = None,
                       dcn_axis: Optional[str] = None) -> Callable:
    """Compile a StepProgram to the shard_map step.

    The program-first entry point: dense-gradient programs (AllReduce or the
    ZeRO sequence) lower onto the explicit-DP engine via
    ``program.step_kwargs()``; an AllToAll-bearing program compiles to the
    expert-parallel MoE step (`runtime.moe_step`), whose token
    dispatch/combine routes through the plan's per-tier alltoall tables.
    Either way ``step.program`` is the object that built the step — the same
    one ``exposed_comm_time(program=...)`` prices.
    """
    program.validate()
    if program.has("all_to_all"):
        from .moe_step import build_moe_ep_step
        return build_moe_ep_step(model, opt, mesh, axis=axis, policy=policy,
                                 program=program)
    return build_explicit_dp_step(model, opt, mesh, axis, policy=policy,
                                  dcn_axis=dcn_axis, step_program=program,
                                  **program.step_kwargs())


def init_error_state(params):
    """Per-leaf error-feedback zeros (the per-tensor wire's state shape).
    Steps built by `build_explicit_dp_step` expose `step.init_error_state`,
    which returns the carrier-shaped buffer when compression is bucketed."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
