"""Step builders: jit-ready train/prefill/decode steps with shardings.

Two trainer mechanisms, mirroring the paper's software-layer axis (DESIGN.md §2):
  * `build_train_step`      — XLA SPMD chooses every collective (the *CCL analog);
  * `build_explicit_dp_step`— pure data parallelism under shard_map with *our*
    collective algorithms from core/ (the GPU-aware-MPI analog), with optional
    int8 gradient compression (error feedback) on the wire.

`build_train_step` supports gradient accumulation (microbatching): the batch is
split on the leading axis and grads are accumulated in fp32 by a lax.scan —
bounding activation memory and letting XLA overlap the per-microbatch
reduce-scatters with the next microbatch's backward (compute/comm overlap).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..core.autotune import CollectivePolicy
from ..models.model import Model
from ..models.sharding import Sharder, tree_shardings, tree_shardings_shaped
from ..optim import adamw


@dataclasses.dataclass
class StepBundle:
    """A jit-able step function plus its sharding pytrees."""
    fn: Callable
    in_shardings: Any
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()


def _microbatch(batch, n: int):
    def split(a):
        if a.shape[0] % n:
            raise ValueError(
                f"batch leading axis {a.shape[0]} is not divisible by "
                f"microbatches={n}; choose a microbatch count that divides "
                f"the (per-shard) batch size")
        return a.reshape((n, a.shape[0] // n) + a.shape[1:])

    return jax.tree.map(split, batch)


def build_train_step(model: Model, opt: adamw.OptConfig,
                     microbatches: int = 1) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            mb = _microbatch(batch, microbatches)

            def acc_body(carry, b):
                gsum, lsum = carry
                loss, grads = jax.value_and_grad(model.loss)(params, b)
                gsum = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                return (gsum, lsum + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(acc_body, (zeros, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
        else:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state, metrics = adamw.apply_updates(params, grads, opt_state, opt)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def train_step_bundle(model: Model, shape: ShapeConfig, opt: adamw.OptConfig,
                      microbatches: int = 1) -> StepBundle:
    shd = model.shd
    p_log = model.param_logical()
    p_sh = tree_shardings_shaped(shd, p_log, model.abstract_params())
    o_log = adamw.opt_state_logical(p_log)
    o_abs = adamw.abstract_opt_state(model.abstract_params())
    o_sh = tree_shardings_shaped(shd, o_log, o_abs)
    b_sh = tree_shardings_shaped(shd, model.batch_logical(shape), model.input_specs(shape))
    none_sh = shd.sharding((), ()) if shd.mesh is not None else None
    m_sh = {"grad_norm": none_sh, "lr": none_sh, "loss": none_sh} if shd.mesh is not None else None
    fn = build_train_step(model, opt, microbatches)
    return StepBundle(fn, (p_sh, o_sh, b_sh), (p_sh, o_sh, m_sh), donate_argnums=(0, 1))


def _logits_sharding(model: Model, shape: ShapeConfig):
    """Last-position logits sharding with vocab-divisibility checked against the
    actual shape (mamba2's 50280 / internvl2's 92553 don't divide 16)."""
    shd = model.shd
    if shd.mesh is None:
        return None
    c = model.cfg
    if c.n_codebooks:
        dims = ("batch", None, None, "tp")
        lshape = (shape.global_batch, 1, c.n_codebooks, c.vocab)
    else:
        dims = ("batch", None, "tp")
        lshape = (shape.global_batch, 1, c.vocab)
    return shd.sharding(dims, lshape)


def decode_step_bundle(model: Model, shape: ShapeConfig) -> StepBundle:
    shd = model.shd
    p_sh = tree_shardings_shaped(shd, model.param_logical(), model.abstract_params())
    c_sh = tree_shardings_shaped(shd, model.cache_logical(shape), model.abstract_cache(shape))
    b_log = model.batch_logical(shape)
    b_abs = model.input_specs(shape)
    tok_sh = tree_shardings_shaped(shd, {"tokens": b_log["tokens"]}, {"tokens": b_abs["tokens"]})["tokens"] \
        if shd.mesh is not None else None
    pos_sh = shd.sharding((), ()) if shd.mesh is not None else None
    logits_sh = _logits_sharding(model, shape)

    def decode_step(params, cache, tokens, pos):
        return model.decode(params, cache, tokens, pos)

    return StepBundle(decode_step, (p_sh, c_sh, tok_sh, pos_sh), (logits_sh, c_sh),
                      donate_argnums=(1,))


def prefill_step_bundle(model: Model, shape: ShapeConfig) -> StepBundle:
    shd = model.shd
    p_sh = tree_shardings_shaped(shd, model.param_logical(), model.abstract_params())
    c_sh = tree_shardings_shaped(shd, model.cache_logical(shape), model.abstract_cache(shape))
    b_sh = tree_shardings_shaped(shd, model.batch_logical(shape), model.input_specs(shape))
    logits_sh = _logits_sharding(model, shape)

    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)

    return StepBundle(prefill_step, (p_sh, b_sh, c_sh), (logits_sh, c_sh),
                      donate_argnums=(2,))


# --------------------------------------------------------------- explicit DP
def build_explicit_dp_step(model: Model, opt: adamw.OptConfig, mesh, axis: str = "data",
                           policy: Optional[CollectivePolicy] = None,
                           compress_bits: int = 0,
                           bucket_bytes: Optional[int] = None,
                           dcn_axis: Optional[str] = None,
                           overlap: bool = False,
                           microbatches: int = 1,
                           chunks: Optional[int] = None) -> Callable:
    """Pure-DP train step under shard_map with explicit gradient collectives.

    Params/opt state replicated; batch sharded on `axis` (and `dcn_axis` when
    given).  Gradients are reduced with the CommPlan/CollectivePolicy algorithm
    choice (paper Obs. 1/4 applied), with optional int8 error-feedback
    compression on the wire (4x fewer DP bytes).

    Bucketing (the paper's message-aggregation optimization): the flat gradient
    list is concatenated and split into fixed `bucket_bytes` chunks before
    reduction, so small tensors stop paying per-message latency.  The default
    bucket size comes from the plan's latency/bandwidth crossover; pass
    `bucket_bytes=0` to reduce per-tensor.  Bucketing is mutually exclusive
    with `compress_bits` (compression uses per-tensor scales); requesting both
    raises.  `dcn_axis` on a two-pod mesh routes
    every bucket through the hierarchical intra-RS / inter-AR / intra-AG
    schedule (selected whenever the plan was built from a two-level topology).

    Overlap (`overlap=True`, paper Sec. VI / Obs. 1): buckets are built in
    *reverse layer order* (the order backward materializes gradients) and
    reduced through `core.overlap`'s scan-carried issue schedule — one bucket
    in flight at a time instead of one post-hoc blob.  With `microbatches > 1`
    the scan carries the previous microbatch's unreduced buckets, so each
    bucket's all-reduce is issued *inside the same scan step* as the next
    microbatch's backward and overlaps it.  With `dcn_axis`, each bucket runs
    the chunked double-buffered hierarchical pipeline; `chunks=None` takes the
    pipeline depth from the plan's per-tier alpha-beta fits
    (`plan.pipeline_chunks`).  Overlap implies bucketing and therefore
    excludes `compress_bits`.
    """
    from jax.sharding import PartitionSpec as P
    from ..core import overlap as ov

    policy = policy or CollectivePolicy.from_model()
    n = mesh.shape[axis]
    n_total = n * (mesh.shape[dcn_axis] if dcn_axis is not None else 1)
    if compress_bits and (bucket_bytes or overlap):
        raise ValueError("gradient bucketing/overlap does not compose with "
                         "int8 compression (per-tensor scales); pass "
                         "bucket_bytes=0 and overlap=False")
    if microbatches > 1 and not overlap:
        raise ValueError("explicit-DP microbatching is implemented by the "
                         "overlap schedule; pass overlap=True")
    if overlap and bucket_bytes == 0:
        # the overlap scan needs equal-size packed buckets — refuse the
        # documented per-tensor mode instead of silently re-bucketing
        raise ValueError("overlap=True requires bucketing; per-tensor "
                         "reduction (bucket_bytes=0) is not supported — omit "
                         "bucket_bytes to use the plan's crossover")
    if bucket_bytes is None:
        bucket_bytes = 0 if compress_bits else getattr(policy, "bucket_bytes", 0)
    if overlap and not bucket_bytes:
        bucket_bytes = 4 << 20  # policy carried no crossover (legacy tables)
    loss_axes = (dcn_axis, axis) if dcn_axis is not None else axis
    plan_hier = bool(getattr(policy, "hierarchical", False))
    if chunks is None:
        chunks_fn = getattr(policy, "pipeline_chunks", None)
        chunks = chunks_fn(bucket_bytes) if (chunks_fn is not None and
                                             dcn_axis is not None) else 1
    chunks = max(int(chunks), 1)

    def reduce_bucket(buf):
        """One packed fp32 bucket through the planned reduction: the chunked
        hierarchical pipeline on a two-level mesh, else the plan's algorithm."""
        if dcn_axis is not None and plan_hier and chunks > 1:
            return ov.chunked_hierarchical_all_reduce(buf, axis, dcn_axis,
                                                      n_chunks=chunks)
        return policy.all_reduce(buf, axis, n, dcn_axis=dcn_axis)

    def reduce_bucketed(flat_g):
        """Pack the flat gradient stream into exact bucket_bytes chunks (tensors
        split at bucket boundaries, forward order) and reduce each — exactly
        ceil(total_bytes / bucket_bytes) all-reduce calls, with transient memory
        bounded by ~one bucket rather than a full concatenated gradient copy.
        Span construction and scatter-back are shared with the overlap engine
        (`core.overlap`); only the issue schedule differs (eager, post-backward)."""
        elems = max(bucket_bytes // 4, 1)  # fp32 on the wire
        buckets = ov.make_buckets([g.size for g in flat_g], elems, reverse=False)
        rows = [policy.all_reduce(
                    ov.pack_buckets(flat_g, [b], 1.0 / n_total, pad=False)[0],
                    axis, n, dcn_axis=dcn_axis)
                for b in buckets]
        return ov.unpack_buckets(rows, buckets, flat_g)

    def overlap_grads(params, batch):
        """Reverse-layer-order bucketed gradients under the overlap issue
        schedule.  Returns (mean loss over microbatches, reduced flat grads in
        fp32, tree def)."""
        inv = 1.0 / (n_total * microbatches)

        def grads_of(b):
            loss, grads = jax.value_and_grad(model.loss)(params, b)
            flat, tdef = jax.tree.flatten(grads)
            return loss, flat, tdef

        if microbatches == 1:
            loss, flat_g, tdef = grads_of(batch)
            buckets = ov.make_buckets([g.size for g in flat_g],
                                      max(bucket_bytes // 4, 1))
            if not buckets:  # every gradient leaf is zero-size
                return loss, [g.astype(jnp.float32) for g in flat_g], tdef
            stacked = ov.pack_buckets(flat_g, buckets, inv)
            # scan-carried issue schedule: one bucket in flight at a time, in
            # the order backward materializes them
            reduced = ov.scan_bucket_reduce(stacked, reduce_bucket)
            return loss, ov.unpack_buckets(reduced, buckets, flat_g), tdef

        mb = _microbatch(batch, microbatches)
        mb0 = jax.tree.map(lambda a: a[0], mb)
        rest = jax.tree.map(lambda a: a[1:], mb)
        loss0, flat0, tdef = grads_of(mb0)
        buckets = ov.make_buckets([g.size for g in flat0],
                                  max(bucket_bytes // 4, 1))
        if not buckets:
            raise ValueError("overlap microbatching found no gradient "
                             "elements to reduce (all leaves zero-size)")
        pending0 = ov.pack_buckets(flat0, buckets, inv)

        def body(carry, b):
            acc, pending, lsum = carry
            # issue the previous microbatch's bucket reductions FIRST: they
            # have no data dependency on this microbatch's backward, so the
            # scheduler overlaps the reduction stream with the backward compute
            reduced = jnp.stack([reduce_bucket(pending[k])
                                 for k in range(len(buckets))])
            loss, flat, _ = grads_of(b)
            nxt = ov.pack_buckets(flat, buckets, inv)
            return (acc + reduced, nxt, lsum + loss), None

        init = (jnp.zeros_like(pending0), pending0, loss0)
        (acc, pending, lsum), _ = jax.lax.scan(body, init, rest)
        # flush: the last microbatch's buckets have no backward left to hide
        # behind — this is the exposed tail the predictor charges for
        final = jnp.stack([reduce_bucket(pending[k])
                           for k in range(len(buckets))])
        reduced = acc + final
        loss = lsum / microbatches
        return loss, ov.unpack_buckets(reduced, buckets, flat0), tdef

    def local_step(params, opt_state, batch, err):
        if overlap:
            loss, red_flat, tdef = overlap_grads(params, batch)
            loss = jax.lax.pmean(loss, loss_axes)
            grads = tdef.unflatten(red_flat)
            params, opt_state, metrics = adamw.apply_updates(params, grads,
                                                             opt_state, opt)
            metrics["loss"] = loss
            return params, opt_state, metrics, err
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        loss = jax.lax.pmean(loss, loss_axes)

        def reduce_one(g, e):
            g32 = g.astype(jnp.float32) / n_total
            if compress_bits == 8:
                g32 = g32 + e
                scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
                q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
                deq = q.astype(jnp.float32) * scale
                new_e = g32 - deq
                # wire format: int8 payload + per-tensor fp32 scale, summed
                # after dequant — the all-gather moves s/4 + 4 bytes per peer,
                # not the 4x dequantized fp32 tensor
                qg = jax.lax.all_gather(q, axis)          # (n, ...) int8 wire
                sg = jax.lax.all_gather(scale, axis)      # (n,) fp32 scales
                summed = jnp.tensordot(sg, qg.astype(jnp.float32),
                                       axes=((0,), (0,)))
                if dcn_axis is not None:
                    # DCN leg stays fp32: re-quantizing the partial sum would
                    # add error outside the error-feedback loop
                    summed = jax.lax.psum(summed, dcn_axis)
                return summed, new_e
            return policy.all_reduce(g32, axis, n, dcn_axis=dcn_axis), e

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(err)
        if compress_bits == 0 and bucket_bytes > 0:
            reduced = reduce_bucketed(flat_g)
            new_err_flat = flat_e
        else:
            out = [reduce_one(g, e) for g, e in zip(flat_g, flat_e)]
            reduced = [o[0] for o in out]
            new_err_flat = [o[1] for o in out]
        grads = tdef.unflatten(reduced)
        new_err = tdef.unflatten(new_err_flat)
        params, opt_state, metrics = adamw.apply_updates(params, grads, opt_state, opt)
        metrics["loss"] = loss
        return params, opt_state, metrics, new_err

    def specs_like(tree, spec):
        return jax.tree.map(lambda _: spec, tree)

    def make(params, opt_state, batch, err):
        from jax import shard_map
        batch_axes = (dcn_axis, axis) if dcn_axis is not None else axis
        p_spec = specs_like(params, P())
        o_spec = specs_like(opt_state, P())
        b_spec = specs_like(batch, P(batch_axes))
        e_spec = specs_like(err, P())
        m_spec = {"grad_norm": P(), "lr": P(), "loss": P()}
        return shard_map(local_step, mesh=mesh,
                         in_specs=(p_spec, o_spec, b_spec, e_spec),
                         out_specs=(p_spec, o_spec, m_spec, e_spec),
                         check_vma=False)

    # remat inside the loss emits closed_call, which shard_map can't evaluate
    # eagerly — jit around the shard_map is required.  The shard_map specs
    # depend only on the pytree structures, so cache the built jit per
    # flattened tree-structure tuple: repeat calls with the same structures
    # reuse one jit (no per-step retrace), while a call with a different
    # batch/params structure gets fresh specs instead of silently reusing the
    # first call's stale shard_map specs.
    cache: Dict[Tuple, Callable] = {}

    def step(params, opt_state, batch, err):
        key = tuple(jax.tree.structure(t)
                    for t in (params, opt_state, batch, err))
        fn = cache.get(key)
        if fn is None:
            fn = cache[key] = jax.jit(make(params, opt_state, batch, err))
        return fn(params, opt_state, batch, err)

    step._cache = cache  # introspectable by tests
    return step


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
