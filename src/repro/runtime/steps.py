"""Step builders: jit-ready train/prefill/decode steps with shardings.

Two trainer mechanisms, mirroring the paper's software-layer axis (DESIGN.md §2):
  * `build_train_step`      — XLA SPMD chooses every collective (the *CCL analog);
  * `build_explicit_dp_step`— pure data parallelism under shard_map with *our*
    collective algorithms from core/ (the GPU-aware-MPI analog), with optional
    int8 gradient compression (error feedback) on the wire.

`build_train_step` supports gradient accumulation (microbatching): the batch is
split on the leading axis and grads are accumulated in fp32 by a lax.scan —
bounding activation memory and letting XLA overlap the per-microbatch
reduce-scatters with the next microbatch's backward (compute/comm overlap).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from ..core.autotune import CollectivePolicy
from ..models.model import Model
from ..models.sharding import Sharder, tree_shardings, tree_shardings_shaped
from ..optim import adamw


@dataclasses.dataclass
class StepBundle:
    """A jit-able step function plus its sharding pytrees."""
    fn: Callable
    in_shardings: Any
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()


def _microbatch(batch, n: int):
    return jax.tree.map(lambda a: a.reshape((n, a.shape[0] // n) + a.shape[1:]), batch)


def build_train_step(model: Model, opt: adamw.OptConfig,
                     microbatches: int = 1) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics)."""

    def train_step(params, opt_state, batch):
        if microbatches > 1:
            mb = _microbatch(batch, microbatches)

            def acc_body(carry, b):
                gsum, lsum = carry
                loss, grads = jax.value_and_grad(model.loss)(params, b)
                gsum = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), gsum, grads)
                return (gsum, lsum + loss), None

            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), _ = jax.lax.scan(acc_body, (zeros, 0.0), mb)
            grads = jax.tree.map(lambda g: g / microbatches, gsum)
            loss = lsum / microbatches
        else:
            loss, grads = jax.value_and_grad(model.loss)(params, batch)
        params, opt_state, metrics = adamw.apply_updates(params, grads, opt_state, opt)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def train_step_bundle(model: Model, shape: ShapeConfig, opt: adamw.OptConfig,
                      microbatches: int = 1) -> StepBundle:
    shd = model.shd
    p_log = model.param_logical()
    p_sh = tree_shardings_shaped(shd, p_log, model.abstract_params())
    o_log = adamw.opt_state_logical(p_log)
    o_abs = adamw.abstract_opt_state(model.abstract_params())
    o_sh = tree_shardings_shaped(shd, o_log, o_abs)
    b_sh = tree_shardings_shaped(shd, model.batch_logical(shape), model.input_specs(shape))
    none_sh = shd.sharding((), ()) if shd.mesh is not None else None
    m_sh = {"grad_norm": none_sh, "lr": none_sh, "loss": none_sh} if shd.mesh is not None else None
    fn = build_train_step(model, opt, microbatches)
    return StepBundle(fn, (p_sh, o_sh, b_sh), (p_sh, o_sh, m_sh), donate_argnums=(0, 1))


def _logits_sharding(model: Model, shape: ShapeConfig):
    """Last-position logits sharding with vocab-divisibility checked against the
    actual shape (mamba2's 50280 / internvl2's 92553 don't divide 16)."""
    shd = model.shd
    if shd.mesh is None:
        return None
    c = model.cfg
    if c.n_codebooks:
        dims = ("batch", None, None, "tp")
        lshape = (shape.global_batch, 1, c.n_codebooks, c.vocab)
    else:
        dims = ("batch", None, "tp")
        lshape = (shape.global_batch, 1, c.vocab)
    return shd.sharding(dims, lshape)


def decode_step_bundle(model: Model, shape: ShapeConfig) -> StepBundle:
    shd = model.shd
    p_sh = tree_shardings_shaped(shd, model.param_logical(), model.abstract_params())
    c_sh = tree_shardings_shaped(shd, model.cache_logical(shape), model.abstract_cache(shape))
    b_log = model.batch_logical(shape)
    b_abs = model.input_specs(shape)
    tok_sh = tree_shardings_shaped(shd, {"tokens": b_log["tokens"]}, {"tokens": b_abs["tokens"]})["tokens"] \
        if shd.mesh is not None else None
    pos_sh = shd.sharding((), ()) if shd.mesh is not None else None
    logits_sh = _logits_sharding(model, shape)

    def decode_step(params, cache, tokens, pos):
        return model.decode(params, cache, tokens, pos)

    return StepBundle(decode_step, (p_sh, c_sh, tok_sh, pos_sh), (logits_sh, c_sh),
                      donate_argnums=(1,))


def prefill_step_bundle(model: Model, shape: ShapeConfig) -> StepBundle:
    shd = model.shd
    p_sh = tree_shardings_shaped(shd, model.param_logical(), model.abstract_params())
    c_sh = tree_shardings_shaped(shd, model.cache_logical(shape), model.abstract_cache(shape))
    b_sh = tree_shardings_shaped(shd, model.batch_logical(shape), model.input_specs(shape))
    logits_sh = _logits_sharding(model, shape)

    def prefill_step(params, batch, cache):
        return model.prefill(params, batch, cache)

    return StepBundle(prefill_step, (p_sh, b_sh, c_sh), (logits_sh, c_sh),
                      donate_argnums=(2,))


# --------------------------------------------------------------- explicit DP
def build_explicit_dp_step(model: Model, opt: adamw.OptConfig, mesh, axis: str = "data",
                           policy: Optional[CollectivePolicy] = None,
                           compress_bits: int = 0,
                           bucket_bytes: Optional[int] = None,
                           dcn_axis: Optional[str] = None) -> Callable:
    """Pure-DP train step under shard_map with explicit gradient collectives.

    Params/opt state replicated; batch sharded on `axis` (and `dcn_axis` when
    given).  Gradients are reduced with the CommPlan/CollectivePolicy algorithm
    choice (paper Obs. 1/4 applied), with optional int8 error-feedback
    compression on the wire (4x fewer DP bytes).

    Bucketing (the paper's message-aggregation optimization): the flat gradient
    list is concatenated and split into fixed `bucket_bytes` chunks before
    reduction, so small tensors stop paying per-message latency.  The default
    bucket size comes from the plan's latency/bandwidth crossover; pass
    `bucket_bytes=0` to reduce per-tensor.  Bucketing is mutually exclusive
    with `compress_bits` (compression uses per-tensor scales); requesting both
    raises.  `dcn_axis` on a two-pod mesh routes
    every bucket through the hierarchical intra-RS / inter-AR / intra-AG
    schedule (selected whenever the plan was built from a two-level topology).
    """
    from jax.sharding import PartitionSpec as P
    from ..core import collectives as coll

    policy = policy or CollectivePolicy.from_model()
    n = mesh.shape[axis]
    n_total = n * (mesh.shape[dcn_axis] if dcn_axis is not None else 1)
    if compress_bits and bucket_bytes:
        raise ValueError("gradient bucketing does not compose with int8 "
                         "compression (per-tensor scales); pass bucket_bytes=0")
    if bucket_bytes is None:
        bucket_bytes = 0 if compress_bits else getattr(policy, "bucket_bytes", 0)
    loss_axes = (dcn_axis, axis) if dcn_axis is not None else axis

    def reduce_bucketed(flat_g):
        """Pack the flat gradient stream into exact bucket_bytes chunks (tensors
        split at bucket boundaries) and reduce each — exactly
        ceil(total_bytes / bucket_bytes) all-reduce calls, with transient memory
        bounded by ~one bucket rather than a full concatenated gradient copy."""
        elems = max(bucket_bytes // 4, 1)  # fp32 on the wire
        segs = [[] for _ in flat_g]        # reduced pieces per tensor, in order
        cur, cur_n = [], 0                 # (tensor idx, lo, hi) in this bucket

        def flush():
            nonlocal cur, cur_n
            if not cur:
                return
            parts = [flat_g[i].astype(jnp.float32).reshape(-1)[lo:hi] / n_total
                     for i, lo, hi in cur]
            buf = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
            red = policy.all_reduce(buf, axis, n, dcn_axis=dcn_axis)
            off = 0
            for i, lo, hi in cur:
                segs[i].append(red[off: off + hi - lo])
                off += hi - lo
            cur, cur_n = [], 0

        for i, g in enumerate(flat_g):
            pos = 0
            while pos < g.size:
                take = min(g.size - pos, elems - cur_n)
                cur.append((i, pos, pos + take))
                cur_n += take
                pos += take
                if cur_n == elems:
                    flush()
        flush()
        return [
            (jnp.concatenate(ps) if len(ps) > 1 else ps[0]).reshape(g.shape)
            for g, ps in zip(flat_g, segs)
        ]

    def local_step(params, opt_state, batch, err):
        loss, grads = jax.value_and_grad(model.loss)(params, batch)
        loss = jax.lax.pmean(loss, loss_axes)

        def reduce_one(g, e):
            g32 = g.astype(jnp.float32) / n_total
            if compress_bits == 8:
                g32 = g32 + e
                scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
                q = jnp.clip(jnp.round(g32 / scale), -127, 127)
                deq = q * scale
                new_e = g32 - deq
                # wire format: int8 payload + per-tensor scale (summed after dequant)
                summed = coll.one_shot_all_reduce(deq, axis)
                if dcn_axis is not None:
                    summed = jax.lax.psum(summed, dcn_axis)
                return summed, new_e
            return policy.all_reduce(g32, axis, n, dcn_axis=dcn_axis), e

        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(err)
        if compress_bits == 0 and bucket_bytes > 0:
            reduced = reduce_bucketed(flat_g)
            new_err_flat = flat_e
        else:
            out = [reduce_one(g, e) for g, e in zip(flat_g, flat_e)]
            reduced = [o[0] for o in out]
            new_err_flat = [o[1] for o in out]
        grads = tdef.unflatten(reduced)
        new_err = tdef.unflatten(new_err_flat)
        params, opt_state, metrics = adamw.apply_updates(params, grads, opt_state, opt)
        metrics["loss"] = loss
        return params, opt_state, metrics, new_err

    def specs_like(tree, spec):
        return jax.tree.map(lambda _: spec, tree)

    def make(params, opt_state, batch, err):
        from jax import shard_map
        batch_axes = (dcn_axis, axis) if dcn_axis is not None else axis
        p_spec = specs_like(params, P())
        o_spec = specs_like(opt_state, P())
        b_spec = specs_like(batch, P(batch_axes))
        e_spec = specs_like(err, P())
        m_spec = {"grad_norm": P(), "lr": P(), "loss": P()}
        return shard_map(local_step, mesh=mesh,
                         in_specs=(p_spec, o_spec, b_spec, e_spec),
                         out_specs=(p_spec, o_spec, m_spec, e_spec),
                         check_vma=False)

    # remat inside the loss emits closed_call, which shard_map can't evaluate
    # eagerly — jit around the shard_map is required.  The specs only depend on
    # the pytree structures, which are fixed across steps, so build + jit once
    # on first call (a fresh jit(make(...)) per step would retrace every step).
    cache: Dict[str, Callable] = {}

    def step(params, opt_state, batch, err):
        if "fn" not in cache:
            cache["fn"] = jax.jit(make(params, opt_state, batch, err))
        return cache["fn"](params, opt_state, batch, err)

    return step


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
