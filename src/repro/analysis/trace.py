"""Collective trace extraction: the shared jaxpr walker.

One recursive walk over a ClosedJaxpr (absorbing the walker that used to live
in `launch.hlo_analysis.count_jaxpr_eqns` and the per-test copies in
`tests/test_overlap.py` / `tests/test_moe_step.py` / `tests/test_codec.py`)
that yields a structured **CollectiveTrace**: one ordered record per
`psum` / `ppermute` / `all_gather` / `reduce_scatter` / `all_to_all` equation,
carrying the mesh axes it runs over, its wire dtype, payload bytes, the
scan-nesting depth it was issued at, and the scan trip multiplier (product of
enclosing `lax.scan` lengths — the number of times the collective fires per
step, which is what exact per-collective wire-byte accounting needs).

The walk descends into every sub-jaxpr a primitive carries (shard_map bodies,
scan/while bodies, cond branches, custom-vjp calls), so records come out in
issue order regardless of how deeply the step nests.

Note on naming: `lax.psum_scatter` lowers to a primitive called
``reduce_scatter`` on current jax; both spellings canonicalize to
``reduce_scatter`` here so rules and tests never care which one the tracer
emitted.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, FrozenSet, List, Optional, Sequence, Tuple

import jax

#: canonical collective kinds a CollectiveRecord can carry
COLLECTIVE_KINDS: FrozenSet[str] = frozenset(
    {"psum", "ppermute", "all_gather", "reduce_scatter", "all_to_all"})

#: primitive-name -> canonical kind (psum_scatter is reduce_scatter's old name)
_PRIM_TO_KIND: Dict[str, str] = {k: k for k in COLLECTIVE_KINDS}
_PRIM_TO_KIND["psum_scatter"] = "reduce_scatter"

#: primitives that multiply the issue count of their body's equations
_LOOP_PRIMS = frozenset({"scan", "while"})


def _sub_jaxprs(eqn):
    """Every Jaxpr reachable through one equation's params."""
    for val in eqn.params.values():
        vals = val if isinstance(val, (tuple, list)) else (val,)
        for u in vals:
            if isinstance(u, jax.core.ClosedJaxpr):
                yield u.jaxpr
            elif isinstance(u, jax.core.Jaxpr):
                yield u


def _as_jaxpr(closed):
    """Accept a ClosedJaxpr, a bare Jaxpr, or anything with `.jaxpr`."""
    return closed.jaxpr if hasattr(closed, "jaxpr") else closed


def walk_eqns(closed, visit: Callable) -> None:
    """Depth-first walk calling ``visit(eqn, scan_depth, scan_trips)`` on
    every equation.  `scan_depth` counts enclosing scan/while bodies and
    `scan_trips` is the product of their static lengths (1 when a loop's
    length is unknown, e.g. `while`)."""

    def walk(jaxpr, depth, trips):
        for eqn in jaxpr.eqns:
            visit(eqn, depth, trips)
            if eqn.primitive.name in _LOOP_PRIMS:
                length = eqn.params.get("length", 1)
                sub_depth = depth + 1
                sub_trips = trips * max(int(length or 1), 1)
            else:
                sub_depth, sub_trips = depth, trips
            for sub in _sub_jaxprs(eqn):
                walk(sub, sub_depth, sub_trips)

    walk(_as_jaxpr(closed), 0, 1)


def count_eqns(closed, name: Optional[str] = None) -> int:
    """Count equations (of primitive `name`, or all) across nested jaxprs.
    The walker formerly known as `hlo_analysis.count_jaxpr_eqns`."""
    cnt = 0

    def visit(eqn, depth, trips):
        nonlocal cnt
        if name is None or eqn.primitive.name == name:
            cnt += 1

    walk_eqns(closed, visit)
    return cnt


def prims_of(closed) -> FrozenSet[str]:
    """Set of primitive names appearing anywhere in the (nested) jaxpr."""
    prims = set()
    walk_eqns(closed, lambda eqn, d, t: prims.add(eqn.primitive.name))
    return frozenset(prims)


def scans_of(closed) -> List[Tuple[int, FrozenSet[str]]]:
    """Every `lax.scan` in the jaxpr as ``(length, body primitive set)``,
    in walk order (nested scans appear after their parent)."""
    out: List[Tuple[int, FrozenSet[str]]] = []

    def visit(eqn, depth, trips):
        if eqn.primitive.name == "scan":
            body = eqn.params.get("jaxpr")
            out.append((int(eqn.params.get("length") or 0),
                        prims_of(body) if body is not None else frozenset()))

    walk_eqns(closed, visit)
    return out


def _axes_of(eqn) -> Tuple[str, ...]:
    """Mesh axis names of a collective eqn — psum spells them `axes`, the
    rest `axis_name`; either may be a bare name or a tuple."""
    raw = eqn.params.get("axes", eqn.params.get("axis_name", ()))
    if not isinstance(raw, (tuple, list)):
        raw = (raw,)
    return tuple(str(a) for a in raw)


@dataclasses.dataclass(frozen=True)
class CollectiveRecord:
    """One collective equation as issued by the compiled step."""
    kind: str                      # canonical name (COLLECTIVE_KINDS)
    axes: Tuple[str, ...]          # mesh axes it communicates over
    dtype: str                     # wire dtype of the largest operand
    shape: Tuple[int, ...]         # shape of the largest operand
    payload_bytes: int             # total bytes of all array operands
    scalar: bool                   # every operand is rank-0 (clip/loss psums)
    scan_depth: int                # number of enclosing scan/while bodies
    scan_trips: int                # product of enclosing scan lengths

    @property
    def wire_bytes(self) -> int:
        """Bytes this record puts on the wire per step (payload x trips)."""
        return self.payload_bytes * self.scan_trips

    def __str__(self) -> str:
        loc = f" depth={self.scan_depth}x{self.scan_trips}" \
            if self.scan_depth else ""
        return (f"{self.kind}[{','.join(self.axes)}] "
                f"{self.dtype}{list(self.shape)}{loc}")


def _record(eqn, depth, trips) -> CollectiveRecord:
    avals = [v.aval for v in eqn.invars
             if hasattr(v.aval, "shape") and hasattr(v.aval, "dtype")]
    payload = sum(int(a.size) * a.dtype.itemsize for a in avals)
    big = max(avals, key=lambda a: int(a.size) * a.dtype.itemsize,
              default=None)
    return CollectiveRecord(
        kind=_PRIM_TO_KIND[eqn.primitive.name],
        axes=_axes_of(eqn),
        dtype=str(big.dtype) if big is not None else "float32",
        shape=tuple(big.shape) if big is not None else (),
        payload_bytes=payload,
        scalar=all(a.ndim == 0 for a in avals),
        scan_depth=depth,
        scan_trips=trips,
    )


@dataclasses.dataclass(frozen=True)
class CollectiveTrace:
    """Ordered collective records of one traced step + the jaxpr-level
    facts the lint rules consume (donation, concatenate pressure)."""
    records: Tuple[CollectiveRecord, ...]
    donate_argnums: Tuple[int, ...] = ()
    n_eqns: int = 0                # total equations (nested)
    n_concats: int = 0             # concatenate equations (nested, unweighted)

    def of_kind(self, kind: str) -> Tuple[CollectiveRecord, ...]:
        return tuple(r for r in self.records if r.kind == kind)

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self.records:
            out[r.kind] = out.get(r.kind, 0) + 1
        return out

    def kinds(self) -> FrozenSet[str]:
        return frozenset(r.kind for r in self.records)

    def wire_bytes(self, kind: Optional[str] = None,
                   include_scalar: bool = False) -> int:
        """Per-step bytes over the wire — each record's payload times its
        scan trip count, the exact (not aggregate) accounting."""
        return sum(r.wire_bytes for r in self.records
                   if (kind is None or r.kind == kind)
                   and (include_scalar or not r.scalar))


def trace_jaxpr(closed, donate_argnums: Sequence[int] = ()) -> CollectiveTrace:
    """Extract the CollectiveTrace of a (Closed)Jaxpr."""
    records: List[CollectiveRecord] = []
    n_eqns = 0
    n_concats = 0

    def visit(eqn, depth, trips):
        nonlocal n_eqns, n_concats
        n_eqns += 1
        name = eqn.primitive.name
        if name == "concatenate":
            n_concats += 1
        elif name in _PRIM_TO_KIND:
            records.append(_record(eqn, depth, trips))

    walk_eqns(closed, visit)
    return CollectiveTrace(records=tuple(records),
                           donate_argnums=tuple(donate_argnums),
                           n_eqns=n_eqns, n_concats=n_concats)


def trace_step(step: Callable, *example_args) -> CollectiveTrace:
    """Trace a compiled step function on example (abstract-ok) arguments.
    Donation is read off the step's advertised `donate_argnums` (steps built
    by `runtime.steps` expose it)."""
    closed = jax.make_jaxpr(lambda *a: step(*a))(*example_args)
    return trace_jaxpr(closed,
                       donate_argnums=getattr(step, "donate_argnums", ()))
