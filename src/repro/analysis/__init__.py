"""Static analysis of compiled steps against the StepProgram IR (CommLint).

Two levels, one rule engine:

  * jaxpr level — `trace` extracts a structured CollectiveTrace from a
    jaxpr, `expect` compiles a StepProgram into the trace it should
    produce, and `lint` diffs the two into typed findings.
  * compiled-HLO level (ScheduleLint) — `hlo_trace` parses the post-SPMD
    module into an ordered HloTrace, and `schedule` cross-checks it against
    the jaxpr trace and the program (collective rewrites, wire widening,
    tier misrouting, lost overlap windows, trip-count drift) plus a static
    exposed-comm estimate read straight off the scheduled op stream.

`python -m repro.launch.lint [--hlo]` runs the pass over every named
program; `launch.train --lint` gates a run on it.
"""
from .expect import ExpectedTrace, expected_trace
from .hlo_trace import (HLO_TO_KIND, KIND_FAMILY, HloCollectiveRecord,
                        HloTrace, parse_hlo)
from .lint import FINDING_CODES, Finding, lint_step, lint_trace
from .schedule import (StaticOverlap, byte_deltas, crosscheck_trace,
                       static_exposed_comm)
from .trace import (COLLECTIVE_KINDS, CollectiveRecord, CollectiveTrace,
                    count_eqns, prims_of, scans_of, trace_jaxpr, trace_step)

__all__ = [
    "COLLECTIVE_KINDS", "CollectiveRecord", "CollectiveTrace",
    "ExpectedTrace", "FINDING_CODES", "Finding",
    "HLO_TO_KIND", "HloCollectiveRecord", "HloTrace", "KIND_FAMILY",
    "StaticOverlap", "byte_deltas", "count_eqns", "crosscheck_trace",
    "expected_trace", "lint_step", "lint_trace", "parse_hlo", "prims_of",
    "scans_of", "static_exposed_comm", "trace_jaxpr", "trace_step",
]
