"""Static analysis of compiled steps against the StepProgram IR (CommLint).

`trace` extracts a structured CollectiveTrace from a jaxpr, `expect` compiles
a StepProgram into the trace it should produce, and `lint` diffs the two into
typed findings.  `python -m repro.launch.lint` runs the pass over every named
program; `launch.train --lint` gates a run on it.
"""
from .expect import ExpectedTrace, expected_trace
from .lint import FINDING_CODES, Finding, lint_step, lint_trace
from .trace import (COLLECTIVE_KINDS, CollectiveRecord, CollectiveTrace,
                    count_eqns, prims_of, scans_of, trace_jaxpr, trace_step)

__all__ = [
    "COLLECTIVE_KINDS", "CollectiveRecord", "CollectiveTrace",
    "ExpectedTrace", "FINDING_CODES", "Finding",
    "count_eqns", "expected_trace", "lint_step", "lint_trace",
    "prims_of", "scans_of", "trace_jaxpr", "trace_step",
]
