"""Structured post-SPMD HLO parsing: the compiled-artifact side of CommLint.

This module is the one home of the HLO-text machinery that used to be buried
in ``launch.hlo_analysis`` (dtype table, shape/replica-group parsing,
computation splitting, while-trip recovery, per-line cost accounting).
``launch.hlo_analysis.analyze_collectives`` / ``analyze_cost`` are now thin
consumers of it, and ``analysis.schedule`` builds the jaxpr<->HLO cross-check
on top of it.

``parse_hlo`` turns a compiled module's text into an ordered **HloTrace**:
one ``HloCollectiveRecord`` per scheduled collective op (async ``-start`` /
``-done`` pairs fold into one record), carrying

  * the HLO op (``all-reduce`` ...) and its canonical jaxpr kind (``psum``);
  * replica-group size and the device-id span of the first group (the
    pod-stride DCN classifier the roofline uses);
  * the wire dtype and the **input-side payload bytes** — normalized so an
    ``all-gather`` counts its per-device shard and a ``reduce-scatter`` the
    full pre-scatter operand, i.e. the same quantity a jaxpr
    ``CollectiveRecord.payload_bytes`` reports for the op that lowered to it;
  * the while-body execution multiplier (``trips``) recovered from the loop
    conditions, so ``payload x trips`` is exact per-step wire accounting;
  * async scheduling facts (start/done line indices) and, when the operand
    chain shows it, the dtype a feeding ``convert`` widened from.

Input-side normalization is what makes the cross-check possible at all: the
SPMD partitioner legitimately lowers a ``psum`` to ``all-gather`` + local
reduce (one-shot) or a ``reduce_scatter`` to ``all-reduce`` + slice, and only
the input-side payload survives those rewrites unchanged.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, FrozenSet, List, Optional, Tuple

# ------------------------------------------------------------------- tables

#: bytes per element of every HLO dtype the dumps use (one definition —
#: ``launch.hlo_analysis`` imports it from here)
DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

#: HLO dtype -> numpy-style name, so rules can compare against jaxpr records
DTYPE_NP = {
    "pred": "bool", "s8": "int8", "u8": "uint8", "s16": "int16",
    "u16": "uint16", "bf16": "bfloat16", "f16": "float16", "s32": "int32",
    "u32": "uint32", "f32": "float32", "s64": "int64", "u64": "uint64",
    "f64": "float64", "c64": "complex64",
    "f8e4m3fn": "float8_e4m3fn", "f8e5m2": "float8_e5m2",
}

#: HLO collective op -> the canonical jaxpr kind that lowers to it
HLO_TO_KIND = {
    "all-reduce": "psum",
    "all-gather": "all_gather",
    "reduce-scatter": "reduce_scatter",
    "all-to-all": "all_to_all",
    "collective-permute": "ppermute",
}

#: reduction-equivalent families: the SPMD partitioner may rewrite within a
#: family (psum -> one-shot all-gather + reduce, reduce_scatter ->
#: all-reduce + slice) without changing the input-side payload; a byte that
#: leaves its family is a genuine rewrite
KIND_FAMILY = {
    "psum": "reduce", "all_gather": "reduce", "reduce_scatter": "reduce",
    "ppermute": "permute", "all_to_all": "alltoall",
}

SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
GROUPS_RE = re.compile(
    r"replica_groups=(\{\{[^=]*?\}\}|\[[^\]]*\]<=\[[^\]]*\](?:T\([\d,]+\))?)")
# lazy up to the closing "}}" so every pair is captured, not just the first
SOURCE_TARGET_RE = re.compile(r"source_target_pairs=\{(.*?)\}\}")
COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s+\(")
WHILE_RE = re.compile(r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
CALL_RE = re.compile(r"\b(?:call|fusion)\(.*?\).*?(?:to_apply|calls)=%?([\w.\-]+)")
CONST_RE = re.compile(r"%?([\w.\-]+)\s*=\s*\w+\[\]\s*constant\((\d+)\)")
# operands may carry inline scalar types (`compare(s32[] %iv, s32[] %c)`)
COMPARE_RE = re.compile(
    r"compare\((?:\w+\[\]\s+)?%?([\w.\-]+),\s*(?:\w+\[\]\s+)?%?([\w.\-]+)\),?"
    r".*direction=(LT|LE|GT|GE)")
DEF_RE = re.compile(r"%([\w.\-]+)\s*=\s*((?:\([^)]*\))|\w+\[[\d,]*\](?:\{[^}]*\})?)")
PARAM_ANNOT_RE = re.compile(r"([\w.\-]+):\s*((?:\([^)]*\))|\w+\[[\d,]*\](?:\{[^}]*\})?)")
# operands may carry an inline type (`dot(f32[8,8]{1,0} %a, ...)`) depending
# on the XLA version's dump style
DOT_RE = re.compile(
    r"=\s*(\w+\[[\d,]*\])[^ ]*\s+dot\("
    r"(?:\w+\[[\d,]*\](?:\{[^}]*\})?\s+)?%([\w.\-]+),\s*"
    r"(?:\w+\[[\d,]*\](?:\{[^}]*\})?\s+)?%([\w.\-]+)\)"
    r".*?lhs_contracting_dims=\{([\d,]*)\}")
FUSED_PREFIXES = ("fused_computation", "wrapped_", "add.", "add_", "max.",
                  "min.", "region_", "and.", "or.")

#: payloads below this are sideband/control traffic (mirrors
#: ``analysis.expect.WIDE_BYTES``; duplicated literal avoided via import there)


# ---------------------------------------------------------------- primitives


def shape_bytes(type_str: str) -> int:
    """Total bytes of every shape in an HLO type string (tuples sum)."""
    total = 0
    for dtype, dims in SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dtype]
    return total


def dominant_dtype(type_str: str) -> str:
    """Numpy-style dtype of the largest shape in an HLO type string."""
    best, best_bytes = "float32", -1
    for dtype, dims in SHAPE_RE.findall(type_str):
        if dtype not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        b = n * DTYPE_BYTES[dtype]
        if b > best_bytes:
            best, best_bytes = DTYPE_NP.get(dtype, dtype), b
    return best


def dims_of(type_str: str):
    m = SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dtype, dims = m.group(1), m.group(2)
    return dtype, [int(d) for d in dims.split(",")] if dims else []


def parse_group(line: str) -> Tuple[int, int]:
    """Returns (group_size, id_span_within_first_group) of a collective line.

    ``source_target_pairs`` (collective-permute) derives the group size from
    the pair graph: a ppermute-lowered alltoall or ring shift is a set of
    cycles/paths over the device ids, and the effective group is the largest
    connected component — it used to be hard-coded to 2, which misclassified
    every >2-device permute's DCN span share and per-op accounting.
    """
    m = GROUPS_RE.search(line)
    if not m:
        st = SOURCE_TARGET_RE.search(line)
        if st:
            ids = [int(x) for x in re.findall(r"\d+", st.group(1))]
            pairs = list(zip(ids[::2], ids[1::2]))
            if not pairs:
                return 1, 0
            span = max(abs(a - b) for a, b in pairs)
            # union-find over the undirected pair graph; group size = the
            # largest component's node count (a ring of n is one n-cycle)
            parent: Dict[int, int] = {}

            def find(x):
                parent.setdefault(x, x)
                while parent[x] != x:
                    parent[x] = parent[parent[x]]
                    x = parent[x]
                return x

            for a, b in pairs:
                ra, rb = find(a), find(b)
                if ra != rb:
                    parent[ra] = rb
            sizes: Dict[int, int] = defaultdict(int)
            for node in parent:
                sizes[find(node)] += 1
            return max(sizes.values()), span
        return 1, 0
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:].split("}")[0]
        ids = [int(x) for x in first.split(",") if x.strip()]
        return max(len(ids), 1), (max(ids) - min(ids)) if ids else 0
    # iota form: [G,S]<=[N...] with optional T(perm); malformed or truncated
    # group annotations (hand-written / trivial HLO) degrade to "no groups"
    # instead of raising out of the whole analysis
    import numpy as np
    try:
        left = [int(x) for x in re.findall(r"\d+", g.split("<=")[0])]
        right_part = g.split("<=")[1]
        reshape = [int(x) for x in re.findall(r"\d+", right_part.split("T")[0].strip("[] "))]
        tperm = re.search(r"T\(([\d,]+)\)", right_part)
        ngroups, gsize = (left + [1, 1])[:2] if len(left) >= 2 else (1, left[0] if left else 1)
        n = int(np.prod(reshape)) if reshape else ngroups * gsize
        ids = np.arange(n).reshape(reshape if reshape else (n,))
        if tperm:
            ids = ids.transpose([int(x) for x in tperm.group(1).split(",")])
        ids = ids.reshape(ngroups, gsize)
        span = int(ids[0].max() - ids[0].min()) if ids.size else 0
        return gsize, span
    except (IndexError, ValueError):
        return 1, 0


def split_computations(hlo_text: str) -> Dict[str, List[str]]:
    """Computation headers may wrap across lines; a computation starts at a
    non-indented `%name (`/`ENTRY %name (` line and ends at a bare `}`."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    entry_name = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if not raw.startswith((" ", "\t")):
            m = COMP_START_RE.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.startswith("ENTRY") or raw.startswith("ENTRY"):
                    entry_name = cur
                continue
        if line == "}":
            continue
        if cur is not None:
            comps[cur].append(line)
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def trip_count(cond_lines: List[str]) -> int:
    """Trip count of a while loop from its condition computation's lines."""
    consts = {}
    for ln in cond_lines:
        for name, val in CONST_RE.findall(ln):
            consts[name] = int(val)
    for ln in cond_lines:
        m = COMPARE_RE.search(ln)
        if m:
            a, b, d = m.groups()
            if b in consts:
                return consts[b] + (1 if d in ("LE",) else 0)
            if a in consts:
                return consts[a] + (1 if d in ("GE",) else 0)
    # XLA usually fuses the compare (`ROOT %wrapped_compare = pred[]
    # fusion(%gte, %constant.N), ...`): the bound constant still lives in the
    # cond computation.  Only constants actually *referenced by* a
    # compare/fusion/call line qualify — an unrelated scalar constant in the
    # condition (a select threshold, say) must not become the trip count.
    fed: set = set()
    for ln in cond_lines:
        if "compare" in ln or "fusion" in ln or "call(" in ln:
            fed.update(re.findall(r"[\w.\-]+", ln))
    referenced = [v for k, v in consts.items() if k in fed]
    if referenced:
        return max(referenced)
    return 1


def multipliers(comps: Dict[str, List[str]]) -> Dict[str, float]:
    """Execution multiplier per computation (entry=1; while bodies x trips)."""
    children: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    for name, lines in comps.items():
        for ln in lines:
            w = WHILE_RE.search(ln)
            if w:
                cond, body = w.groups()
                trips = trip_count(comps.get(cond, []))
                children[name].append((body, float(max(trips, 1))))
                children[name].append((cond, float(max(trips, 1))))
                continue
            c = CALL_RE.search(ln)
            if c:
                children[name].append((c.group(1), 1.0))
    mult: Dict[str, float] = defaultdict(float)

    def visit(name: str, m: float, depth=0):
        if depth > 64:
            return
        mult[name] += m
        for k, w in children.get(name, []):
            if k in comps:
                visit(k, m * w, depth + 1)

    # "__entry__" aliases the real entry computation's lines, so its children
    # are the real entry's children; the real entry itself is fixed to x1 by
    # the consumers' alias check.
    visit("__entry__", 1.0)
    return dict(mult)


def collect_trip_counts(comps: Dict[str, List[str]]) -> set:
    """All >1 while trip counts in the module (the loop-carry slicing set)."""
    trips = set()
    for lines in comps.values():
        for ln in lines:
            w = WHILE_RE.search(ln)
            if w:
                trips.add(trip_count(comps.get(w.group(1), [])))
    return {t for t in trips if t > 1}


def build_type_map(hlo_text: str) -> Dict[str, str]:
    types: Dict[str, str] = {}
    for m in PARAM_ANNOT_RE.finditer(hlo_text):
        types.setdefault(m.group(1), m.group(2))
    for m in DEF_RE.finditer(hlo_text):
        types[m.group(1)] = m.group(2)
    return types


def comp_multiplier(name: str, lines, mult: Dict[str, float],
                    entry_lines) -> float:
    """Resolve one computation's execution multiplier against the walk.

    The walk only ever visits the ``__entry__`` alias, so the real entry
    computation resolves through identity with the alias's lines; anything
    genuinely unreachable through while/call edges (custom-call targets and
    the like) conservatively executes once.
    """
    m_exec = mult.get(name, 0.0)
    if m_exec == 0.0:
        m_exec = mult.get("__entry__", 1.0) if lines is entry_lines else 1.0
    return m_exec


# ---------------------------------------------------------------- the trace


@dataclasses.dataclass(frozen=True, eq=False)
class HloCollectiveRecord:
    """One scheduled collective of a compiled module (async pairs fold)."""
    op: str                    # HLO op name ("all-reduce", ...)
    kind: str                  # canonical jaxpr kind it corresponds to
    computation: str           # computation whose stream it is scheduled in
    start_index: int           # line index of the op (or its -start)
    done_index: Optional[int]  # line index of the -done, None when sync
    group_size: int            # replica-group size
    span: int                  # device-id span within the first group
    dtype: str                 # numpy-style wire dtype of the payload
    result_bytes: int          # bytes of the result type as written
    payload_bytes: int         # input-side payload (jaxpr-comparable)
    scalar: bool               # every shape in the type is rank-0
    trips: float               # while-body execution multiplier
    is_dcn: bool               # first group spans the pod stride
    fed_by_convert: Optional[str] = None  # source dtype of a feeding convert

    @property
    def wire_bytes(self) -> float:
        """Input-side payload x trips — the jaxpr-comparable accounting."""
        return self.payload_bytes * self.trips

    @property
    def is_async(self) -> bool:
        return self.done_index is not None

    @property
    def algo_wire_bytes(self) -> float:
        """Per-device bytes on the wire with the standard ring factors
        (``analyze_collectives``'s accounting), before the trip multiplier."""
        g = max(self.group_size, 1)
        s = float(self.result_bytes)
        if self.op == "all-reduce":
            return 2.0 * s * (g - 1) / g
        if self.op == "all-gather":
            return s * (g - 1) / g
        if self.op == "reduce-scatter":
            return s * (g - 1)
        if self.op == "all-to-all":
            return s * (g - 1) / g
        return s  # collective-permute

    def __str__(self) -> str:
        tag = " async" if self.is_async else ""
        loc = f"x{self.trips:g}" if self.trips != 1 else ""
        dcn = " dcn" if self.is_dcn else ""
        return (f"{self.op}[g={self.group_size}] {self.dtype} "
                f"{self.payload_bytes}B{loc}{tag}{dcn}")


@dataclasses.dataclass(frozen=True, eq=False)
class HloTrace:
    """Ordered collective records of one compiled module plus the parsed
    context (`comps`/`types`/`loop_trips`) the static scheduler reuses."""
    records: Tuple[HloCollectiveRecord, ...]
    pod_stride: int = 0
    comps: Dict[str, List[str]] = dataclasses.field(default_factory=dict)
    types: Dict[str, str] = dataclasses.field(default_factory=dict)
    loop_trips: FrozenSet[int] = frozenset()

    def of_kind(self, kind: str) -> Tuple[HloCollectiveRecord, ...]:
        return tuple(r for r in self.records if r.kind == kind)

    def of_op(self, op: str) -> Tuple[HloCollectiveRecord, ...]:
        return tuple(r for r in self.records if r.op == op)

    def kinds(self) -> FrozenSet[str]:
        return frozenset(r.kind for r in self.records)

    def counts(self) -> Dict[str, int]:
        out: Dict[str, int] = {}
        for r in self.records:
            out[r.op] = out.get(r.op, 0) + 1
        return out

    def wire_bytes(self, kind: Optional[str] = None,
                   include_scalar: bool = False) -> float:
        """Input-side payload x trips (the jaxpr-comparable accounting)."""
        return sum(r.wire_bytes for r in self.records
                   if (kind is None or r.kind == kind)
                   and (include_scalar or not r.scalar))

    def coster(self) -> "LineCoster":
        return LineCoster(self.types, self.loop_trips)


def _operand_names(call_part: str) -> List[str]:
    """%-prefixed operand names inside one op's first balanced paren span."""
    paren = call_part.find("(")
    if paren < 0:
        return []
    depth, end = 0, len(call_part)
    for i in range(paren, len(call_part)):
        if call_part[i] == "(":
            depth += 1
        elif call_part[i] == ")":
            depth -= 1
            if depth == 0:
                end = i + 1
                break
    return re.findall(r"%([\w.\-]+)", call_part[paren:end])


def _convert_source(operands: List[str], defs: Dict[str, str],
                    types: Dict[str, str]) -> Optional[str]:
    """Numpy dtype a `convert` feeding the collective converts *from*, if
    any operand is one (dequantize-then-communicate shows up here)."""
    for name in operands:
        line = defs.get(name, "")
        if " convert(" not in line:
            continue
        paren = line.find(" convert(") + len(" convert")
        inner = line[paren:]
        m = SHAPE_RE.search(inner)
        if m and m.group(1) in DTYPE_BYTES:
            return DTYPE_NP.get(m.group(1), m.group(1))
        src = re.findall(r"%([\w.\-]+)", inner)
        if src:
            dt, _ = dims_of(types.get(src[0], ""))
            if dt in DTYPE_BYTES:
                return DTYPE_NP.get(dt, dt)
    return None


def _input_payload(op: str, result_bytes: int, g: int) -> int:
    """Input-side payload from the written result type: what the lowering's
    *source* jaxpr op carried as operand bytes."""
    g = max(g, 1)
    if op == "all-gather":
        return result_bytes // g
    if op == "reduce-scatter":
        return result_bytes * g
    return result_bytes


def parse_hlo(hlo_text: str, pod_stride: int = 0) -> HloTrace:
    """Parse a compiled module's text into an ordered HloTrace.

    ``pod_stride`` is the device-id stride of the pod (DCN) axis; groups whose
    first-group span reaches it are classified ``is_dcn``.  ``-start`` lines
    open an async record that the matching ``-done`` closes (payload then
    comes from the done's result type — the start's tuple double-counts);
    a start with no done degrades to half the tuple bytes.
    """
    if not hlo_text or not hlo_text.strip():
        return HloTrace(records=())
    comps = split_computations(hlo_text)
    if not comps:
        return HloTrace(records=())
    mult = multipliers(comps)
    types = build_type_map(hlo_text)
    loop_trips = frozenset(collect_trip_counts(comps))
    entry_lines = comps.get("__entry__")
    records: List[HloCollectiveRecord] = []
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        m_exec = comp_multiplier(name, lines, mult, entry_lines)
        defs = {m.group(1): ln for ln in lines for m in [DEF_RE.match(
            ln[5:] if ln.startswith("ROOT ") else ln)] if m}
        pending: Dict[str, int] = {}  # start var name -> records index
        for idx, line in enumerate(lines):
            om = OP_RE.search(line)
            if not om:
                continue
            type_str, op, suffix = om.group(1), om.group(2), om.group(3)
            clean = line[5:] if line.startswith("ROOT ") else line
            dm = DEF_RE.match(clean)
            var = dm.group(1) if dm else f"__anon{idx}"
            operands = _operand_names(line[om.end() - 1:])
            if suffix == "-done":
                src = next((o for o in operands if o in pending), None)
                if src is not None:
                    ri = pending.pop(src)
                    rec = records[ri]
                    rb = shape_bytes(type_str)
                    pb = _input_payload(op, rb, rec.group_size)
                    records[ri] = dataclasses.replace(
                        rec, done_index=idx, result_bytes=rb,
                        payload_bytes=pb,
                        dtype=dominant_dtype(type_str),
                        scalar=shape_scalar(type_str))
                continue
            g, span = parse_group(line)
            rb = shape_bytes(type_str)
            if suffix == "-start":
                # the start's tuple is (operand, result[, sync flags]):
                # halve until the -done supplies the real result type
                rb = rb // 2
            rec = HloCollectiveRecord(
                op=op, kind=HLO_TO_KIND[op], computation=name,
                start_index=idx, done_index=None, group_size=g, span=span,
                dtype=dominant_dtype(type_str), result_bytes=rb,
                payload_bytes=_input_payload(op, rb, g),
                scalar=shape_scalar(type_str), trips=m_exec,
                is_dcn=(pod_stride > 0 and span >= pod_stride),
                fed_by_convert=_convert_source(operands, defs, types))
            records.append(rec)
            if suffix == "-start":
                pending[var] = len(records) - 1
    return HloTrace(records=tuple(records), pod_stride=pod_stride,
                    comps=comps, types=types, loop_trips=loop_trips)


def shape_scalar(type_str: str) -> bool:
    """True when every shape in the type string is rank-0."""
    found = SHAPE_RE.findall(type_str)
    return bool(found) and all(not dims for _, dims in found)


# ------------------------------------------------------------ per-line cost
# XLA's HloCostAnalysis counts a while body ONCE, so scanned layer stacks
# under-report flops/bytes by a factor of L.  The per-line accounting below
# (moved verbatim from `launch.hlo_analysis.analyze_cost`'s loop body) is what
# both the module-level cost pass and the static overlap scheduler
# (`analysis.schedule`) price compute with:
#   flops  = 2 * result_elems * prod(contracting dims)   over `dot` ops
#   bytes  = result + operand bytes per scheduled line (post-fusion HLO: one
#            line ~ one kernel), with slicing ops touching only the slice and
#            stacked loop carries touching one slice per iteration.


class LineCoster:
    """Prices one scheduled HLO line: matmul flops and HBM traffic."""

    _SKIP = ("tuple", "get-tuple-element", "bitcast", "parameter", "constant",
             "iota", "after-all", "partition-id", "replica-id", "reshape",
             # control flow: carries alias in place; the bodies' real traffic
             # is counted via their own multipliers
             "while", "conditional", "call", "custom-call")

    def __init__(self, types: Dict[str, str], loop_trips):
        self.types = types
        self.loop_trips = set(loop_trips)

    def _operand_bytes(self, name: str) -> float:
        """Bytes actually read from one operand.  Stacked loop carries —
        arrays whose leading dim equals a loop trip count, e.g. the (88, D, F)
        parameter stacks sliced inside fused dynamic-slice/update — are
        touched one slice per iteration, not in full."""
        t = self.types.get(name, "")
        b = shape_bytes(t)
        _, dims = dims_of(t)
        if len(dims) >= 2 and dims[0] in self.loop_trips:
            return b / dims[0]
        return b

    def dot_flops(self, line: str) -> float:
        dm = DOT_RE.search(line)
        if not dm:
            return 0.0
        res_t, lhs, _, cdims = dm.group(1), dm.group(2), dm.group(3), dm.group(4)
        _, res_dims = dims_of(res_t)
        res_elems = 1
        for d in res_dims:
            res_elems *= d
        _, lhs_dims = dims_of(self.types.get(lhs, ""))
        contract = 1
        for ci in ([int(x) for x in cdims.split(",")] if cdims else []):
            if ci < len(lhs_dims):
                contract *= lhs_dims[ci]
        return 2.0 * res_elems * contract

    def hbm_bytes(self, line: str) -> Optional[Tuple[str, float]]:
        """(op_kind, bytes) of one scheduled line, or None when it moves no
        HBM traffic of its own (control flow, aliases, metadata ops)."""
        clean = line[5:] if line.startswith("ROOT ") else line
        dfm = DEF_RE.match(clean)
        if not dfm:
            return None
        res_bytes = shape_bytes(dfm.group(2))
        op_part = clean[dfm.end():].lstrip()
        opm = re.match(r"([\w\-]+)\(", op_part)
        op_kind = opm.group(1) if opm else ""
        paren = op_part.find("(")
        close = op_part.find(")", paren)
        operands = []
        if paren >= 0 and close > paren:
            operands = re.findall(r"%([\w.\-]+)", op_part[paren:close + 1])
        # Data-movement rules: slicing ops touch only the slice, not the full
        # operand (critical inside layer scans: dynamic-slice reads of the
        # stacked (L, ...) parameter arrays would otherwise count L times
        # L-full).
        if op_kind in self._SKIP:
            return None
        if op_kind in ("dynamic-slice", "gather", "slice"):
            return op_kind, 2.0 * res_bytes
        if op_kind in ("dynamic-update-slice", "scatter"):
            upd_idx = 1 if op_kind == "dynamic-update-slice" else 2
            upd = shape_bytes(self.types.get(operands[upd_idx], "")) \
                if len(operands) > upd_idx else res_bytes
            return op_kind, 3.0 * min(upd, res_bytes)
        if op_kind in ("copy", "convert", "transpose", "broadcast"):
            return op_kind, 2.0 * res_bytes
        # results that are themselves stacked carries (fused DUS into an
        # (L, ...) accumulator) also only write one slice per iteration
        _, res_dims = dims_of(dfm.group(2))
        if len(res_dims) >= 2 and res_dims and res_dims[0] in self.loop_trips:
            res_bytes = res_bytes / res_dims[0]
        operand_bytes = sum(self._operand_bytes(on) for on in operands)
        return op_kind, res_bytes + operand_bytes
