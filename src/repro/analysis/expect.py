"""Compile a StepProgram into the ExpectedTrace the lint rules check against.

The StepProgram IR (`core.program`) is the declared intent; the
CollectiveTrace (`analysis.trace`) is what the compiled step actually does.
This module derives, from the program alone plus a little mesh/model context,
everything a rule needs to diff the two:

  * the allowed collective kinds (`StepProgram.expected_collectives`);
  * the expected wire dtype (QuantizeWire -> int8 payload on the wire);
  * whether non-scalar psums are forbidden (ZeRO: only the loss pmean and
    the global-norm combine may psum, and both are scalar);
  * whether the reduction stream must live inside the overlap scan
    (Bucketize(reverse=True) at microbatches == 1 — with microbatch
    accumulation the flush legitimately runs after the interleaved scan);
  * which jit argnum must be donated (the error-feedback carrier of the
    int8 dense wire — argnum 3, mirroring `build_explicit_dp_step`);
  * the concatenate cap (the PR 5 codec packs in O(1) concatenates);
  * a per-step wire-byte budget from `core.wire.bytes_on_wire` over the
    padded carrier, with a documented tolerance for realized algorithm
    overheads (ring round-trips, hierarchical three-phase legs).

The byte budget is None when the caller gives no gradient size (the trace
then simply isn't byte-checked) and for AllToAll programs, whose payload is
activation- not gradient-shaped; pass `byte_budget=` explicitly to check
those.
"""
from __future__ import annotations

import dataclasses
import math
from typing import FrozenSet, Optional, Tuple

from ..core import wire as wr
from ..core.program import StepProgram

#: payloads below this many bytes are sideband/control traffic (scalar
#: clip combines, per-bucket scale stacks), never "the gradient"
WIDE_BYTES = 256

#: default concatenate cap under a bucketized program: the fused codec packs
#: with O(1) concatenates; the chunked pipeline adds a few per chunk and the
#: microbatch accumulation loop a couple per extra microbatch
CONCAT_CAP = 8

#: headroom multiplier on the logical byte budget — covers realized ring
#: round-trips (2(n-1)/n), the hierarchical intra/inter/intra legs, and the
#: one-shot gather, all of which stay within ~2x of the two-leg logical wire
BYTE_TOLERANCE = 2.5


@dataclasses.dataclass(frozen=True)
class ExpectedTrace:
    """What the jaxpr of a step compiled from `program` must look like."""
    program: StepProgram
    n_devices: int = 1
    allowed_kinds: FrozenSet[str] = frozenset({"psum"})
    wire: str = "fp32"                     # expected payload wire dtype
    forbid_nonscalar_psum: bool = False    # ZeRO: scalar psums only
    require_reduction_in_scan: bool = False
    require_donation: Optional[int] = None  # argnum that must be donated
    max_concats: Optional[int] = None
    byte_budget: Optional[float] = None    # per-step wire bytes, or None
    fp32_exempt_axes: Tuple[str, ...] = () # axes whose fp32 leg is planned
    wide_bytes: int = WIDE_BYTES

    @property
    def schedule(self) -> str:
        return self.program.schedule


def carrier_bytes(grad_bytes: int, bucket_bytes: Optional[int]) -> Tuple[int, int]:
    """(padded carrier bytes, bucket count) of a gradient packed at
    `bucket_bytes` per row — what actually rides the wire.  Per-tensor wire
    (no Bucketize) pays no padding; the bucket count then only sizes the
    int8 scale sideband and a leaf-count guess is accurate enough."""
    if not bucket_bytes:
        return int(grad_bytes), 64
    nb = max(math.ceil(grad_bytes / bucket_bytes), 1)
    return nb * int(bucket_bytes), nb


def expected_trace(program: StepProgram, *,
                   n_devices: int = 1,
                   grad_bytes: Optional[int] = None,
                   bucket_bytes: Optional[int] = None,
                   plan=None,
                   dcn_axis: Optional[str] = None,
                   byte_budget: Optional[float] = None,
                   byte_tolerance: float = BYTE_TOLERANCE) -> ExpectedTrace:
    """Compile `program` into the ExpectedTrace the linter diffs against.

    `bucket_bytes` defaults to the program's Bucketize node; a node pinned to
    the plan's crossover (bucket_bytes=None) resolves through `plan` (a
    CommPlan or CollectivePolicy — anything with `.bucket_bytes`).  The byte
    budget needs the resolved cap (the carrier pads to whole buckets); with a
    bucketized program and no way to resolve the cap it stays None rather
    than guess.  `dcn_axis` names the inter-tier axis on two-level meshes:
    its fp32 leg is part of the hierarchical plan (the int8 payload rides the
    intra tier), so fp32 records on it are exempt from the widening rule.
    """
    program.validate()
    kw = program.step_kwargs() if program.schedule != "moe_alltoall" else {}
    bz = program.node("bucketize")
    qw = program.node("quantize_wire")
    cp = program.node("chunked_pipeline")
    zero = program.schedule == "zero"
    overlap = bool(bz is not None and bz.reverse)
    microbatches = int(kw.get("microbatches", 1) or 1)
    chunks = cp.chunks if cp is not None and cp.chunks else 1

    if bucket_bytes is None and bz is not None:
        bucket_bytes = bz.bucket_bytes
    if bucket_bytes is None and plan is not None:
        bucket_bytes = getattr(plan, "bucket_bytes", None)

    budget = byte_budget
    if budget is None and grad_bytes is not None \
            and not (bz is not None and bucket_bytes is None) \
            and program.schedule != "moe_alltoall":
        padded, nb = carrier_bytes(grad_bytes, bucket_bytes)
        fmt = "int8" if qw is not None else "fp32"
        # two fp32-leg equivalents (RS+AG / psum in and out) plus the
        # compressed payload leg; microbatching re-issues the stream per
        # microbatch, a dcn axis adds the inter-tier legs
        logical = 2.0 * wr.bytes_on_wire(padded, "fp32", nb) \
            + wr.bytes_on_wire(padded, fmt, nb)
        budget = byte_tolerance * logical * max(microbatches, 1)
        if dcn_axis:
            budget *= 2.0

    return ExpectedTrace(
        program=program,
        n_devices=n_devices,
        allowed_kinds=program.expected_collectives(),
        wire="int8" if qw is not None else "fp32",
        forbid_nonscalar_psum=zero,
        require_reduction_in_scan=(overlap and microbatches == 1
                                   and not zero),
        require_donation=(3 if (qw is not None and not zero
                                and program.schedule == "allreduce")
                          else None),
        max_concats=(CONCAT_CAP + 8 * (chunks - 1)
                     + 4 * (max(microbatches, 1) - 1)
                     if bz is not None else None),
        byte_budget=budget,
        fp32_exempt_axes=(dcn_axis,) if dcn_axis else (),
    )
