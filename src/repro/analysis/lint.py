"""CommLint rule engine: diff a CollectiveTrace against an ExpectedTrace.

Every rule anchors on *individual* records — kind, dtype, payload, scan depth,
axes — never on aggregate counts alone, so a finding always names the exact
collective that violated the program (the per-collective accounting the
interconnect papers call for: achieved wire traffic diverges from plan one
collective at a time, not on average).

Finding codes (the full catalog — stable strings, asserted by tests):

  unplanned-collective              a kind the program never declared
  wire-dtype-widening               fp32 payload on a leg planned at int8
  full-gradient-allreduce-under-zero  tensor-sized psum in a ZeRO step
  collective-outside-overlap-scan   reduction issued outside the scan stream
  non-scalar-psum                   ZeRO allows only scalar psums (loss/clip)
  undonated-carrier                 error-feedback carrier not donated
  unbucketed-concat                 O(leaves) concatenates defeat the codec
  byte-budget-exceeded              per-step wire bytes above the plan budget

Compiled-HLO codes (emitted by `analysis.schedule.crosscheck_trace`, which
diffs the post-SPMD `HloTrace` against the jaxpr trace and the program —
the layer where XLA's partitioner/scheduler can silently change the wire):

  overlap-lost-in-compilation       async start/done pair with no compute
                                    scheduled inside the window
  collective-rewritten              jaxpr vs HLO wire bytes diverge beyond
                                    the combining tolerance (per family)
  wire-widened-post-spmd            a convert widens the payload right
                                    before it rides the wire
  dcn-misrouted                     replica groups span (or fail to span)
                                    the pod stride against the program's tier
                                    expectation
  trip-count-mismatch               HLO while trips disagree with the jaxpr
                                    scan multiplier (payloads agree)
"""
from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from .expect import ExpectedTrace
from .trace import CollectiveRecord, CollectiveTrace

FINDING_CODES = (
    "unplanned-collective",
    "wire-dtype-widening",
    "full-gradient-allreduce-under-zero",
    "collective-outside-overlap-scan",
    "non-scalar-psum",
    "undonated-carrier",
    "unbucketed-concat",
    "byte-budget-exceeded",
    # compiled-HLO level (analysis.schedule.crosscheck_trace)
    "overlap-lost-in-compilation",
    "collective-rewritten",
    "wire-widened-post-spmd",
    "dcn-misrouted",
    "trip-count-mismatch",
)

_WIDE_DTYPES = ("float32", "float64")


@dataclasses.dataclass(frozen=True)
class Finding:
    code: str
    message: str
    # the anchoring record: a jaxpr CollectiveRecord, an
    # hlo_trace.HloCollectiveRecord (compiled-HLO rules), or None for
    # whole-trace rules
    record: Optional[object] = None

    def __post_init__(self):
        if self.code not in FINDING_CODES:
            raise ValueError(f"unknown finding code {self.code!r}")

    def __str__(self) -> str:
        where = f" [{self.record}]" if self.record is not None else ""
        return f"{self.code}: {self.message}{where}"


def lint_trace(trace: CollectiveTrace, exp: ExpectedTrace) -> List[Finding]:
    """All findings of `trace` against `exp`, in record order then
    whole-trace rules.  An empty list is a clean step."""
    out: List[Finding] = []
    prog = exp.program.name
    # the scale sideband of a healthy int8 wire is fp32 but strictly smaller
    # than the int8 payload it escorts; anything fp32 *larger* than every
    # int8 record is gradient-shaped, not sideband
    max_i8 = max((r.payload_bytes for r in trace.records
                  if r.dtype == "int8"), default=0)

    for rec in trace.records:
        if rec.kind not in exp.allowed_kinds:
            out.append(Finding(
                "unplanned-collective",
                f"{rec.kind} is not part of program {prog!r} "
                f"(allowed: {sorted(exp.allowed_kinds)})", rec))
            continue  # a stray kind shouldn't also trip the wire rules
        big = (not rec.scalar) and rec.payload_bytes >= exp.wide_bytes
        exempt = bool(rec.axes) and \
            set(rec.axes) <= set(exp.fp32_exempt_axes)

        if rec.kind == "psum" and not rec.scalar and exp.forbid_nonscalar_psum:
            out.append(Finding(
                "non-scalar-psum",
                f"psum of {rec.dtype}{list(rec.shape)} under the ZeRO "
                "schedule; only the loss pmean and the global-norm combine "
                "may psum, and both are scalar", rec))
            if big:
                out.append(Finding(
                    "full-gradient-allreduce-under-zero",
                    f"tensor-sized psum ({rec.payload_bytes} B) in program "
                    f"{prog!r}: the gradient must reduce-scatter, not "
                    "allreduce", rec))

        if exp.wire == "int8" and big and not exempt \
                and rec.dtype in _WIDE_DTYPES \
                and rec.payload_bytes > max_i8 \
                and (rec.kind == "all_gather" or exp.schedule != "zero"):
            # ZeRO's RS leg is fp32 by design (error feedback needs exact
            # sums); only its AG return leg carries the int8 wire
            out.append(Finding(
                "wire-dtype-widening",
                f"{rec.dtype} payload ({rec.payload_bytes} B) on a "
                f"{rec.kind} leg program {prog!r} plans at int8", rec))

        if exp.require_reduction_in_scan and big and rec.scan_depth == 0:
            out.append(Finding(
                "collective-outside-overlap-scan",
                f"tensor-sized {rec.kind} at scan depth 0 in overlap "
                f"program {prog!r}: the reduction stream must ride the "
                "scan-carried issue schedule", rec))

    if exp.require_donation is not None \
            and exp.require_donation not in trace.donate_argnums:
        out.append(Finding(
            "undonated-carrier",
            f"program {prog!r} carries int8 error feedback but argnum "
            f"{exp.require_donation} is not donated "
            f"(donate_argnums={list(trace.donate_argnums)}): the carrier "
            "buffer is reallocated every step"))

    if exp.max_concats is not None and trace.n_concats > exp.max_concats:
        out.append(Finding(
            "unbucketed-concat",
            f"{trace.n_concats} concatenate ops (cap {exp.max_concats}) in "
            f"program {prog!r}: the fused codec packs in O(1) concatenates; "
            "per-leaf concatenation defeats it"))

    if exp.byte_budget is not None:
        actual = trace.wire_bytes()
        if actual > exp.byte_budget:
            out.append(Finding(
                "byte-budget-exceeded",
                f"{actual} wire bytes per step vs a budget of "
                f"{exp.byte_budget:.0f} for program {prog!r} "
                "(payload x scan trips, scalars excluded)"))
    return out


def lint_step(step, *example_args,
              expected: Optional[ExpectedTrace] = None,
              **expect_kw) -> Tuple[CollectiveTrace, List[Finding]]:
    """Trace a compiled step and lint it in one call.

    With no `expected`, the ExpectedTrace is compiled from `step.program`
    (set by `runtime.steps.build_program_step` / `build_explicit_dp_step`)
    and any `expect_kw` forwarded to `analysis.expect.expected_trace`.
    """
    from .expect import expected_trace
    from .trace import trace_step

    trace = trace_step(step, *example_args)
    if expected is None:
        program = getattr(step, "program", None)
        if program is None:
            raise ValueError("step has no .program attribute; pass expected=")
        expected = expected_trace(program, **expect_kw)
    return trace, lint_trace(trace, expected)
