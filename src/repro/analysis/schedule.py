"""ScheduleLint: diff the compiled-HLO schedule against the jaxpr and the IR.

PR 8's CommLint verifies a step at the jaxpr level — what the framework
*intends*.  XLA's SPMD partitioner and latency-hiding scheduler sit between
that intent and the wire: they combine collectives, rewrite a psum into a
one-shot all-gather + local reduce, insert converts, decompose permutes,
unroll scans, and (on accelerators) split collectives into async
``-start``/``-done`` pairs whose window is the only place overlap can
actually happen.  This module closes that gap:

  * ``crosscheck_trace`` diffs an `HloTrace` (`analysis.hlo_trace`) against
    the jaxpr `CollectiveTrace` and the program's `ExpectedTrace`, emitting
    the five compiled-HLO finding codes (`analysis.lint.FINDING_CODES`);
  * ``static_exposed_comm`` prices the scheduled op stream: wire time per
    collective vs the roofline compute scheduled inside its async window —
    a *static* overlap/exposed-comm estimate straight from the artifact,
    reported by dryrun next to the calibrated ``exposed_comm_time``.

Byte matching is per **family**, not per op: the partitioner may lower a
``psum`` as all-reduce *or* as all-gather + reduce (and a ``reduce_scatter``
as all-reduce + slice) without changing the input-side payload, so
reduction-kind bytes are pooled ({psum, all_gather, reduce_scatter} vs
{all-reduce, all-gather, reduce-scatter}) and only bytes that *leave* the
family — or change magnitude — are a rewrite.  Records below the sideband
threshold (`expect.WIDE_BYTES`) are control traffic and never byte-checked.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from .expect import ExpectedTrace
from .hlo_trace import (DTYPE_BYTES, DTYPE_NP, KIND_FAMILY,
                        HloCollectiveRecord, HloTrace, OP_RE)
from .lint import Finding
from .trace import CollectiveTrace

#: jaxpr↔HLO byte agreement tolerance: ring/one-shot rewrites preserve the
#: input-side payload exactly; 5% absorbs padding and combined sidebands
BYTE_TOL = 0.05

_NP_BYTES = {np_name: DTYPE_BYTES[hlo] for hlo, np_name in DTYPE_NP.items()}


def _np_bytes(dtype: str) -> int:
    return _NP_BYTES.get(str(dtype), 4)


def _family_sums(records, wide_bytes: int, weighted: bool):
    """Per-family byte sums over non-sideband records.  `records` yields
    (family, payload_bytes, trips)."""
    out: Dict[str, float] = {}
    for fam, payload, trips in records:
        if payload < wide_bytes:
            continue
        out[fam] = out.get(fam, 0.0) + payload * (trips if weighted else 1.0)
    return out


def _jaxpr_rows(jtrace: CollectiveTrace):
    for r in jtrace.records:
        yield KIND_FAMILY.get(r.kind, r.kind), float(r.payload_bytes), \
            float(getattr(r, "scan_trips", 1) or 1)


def _hlo_rows(htrace: HloTrace):
    for r in htrace.records:
        yield KIND_FAMILY.get(r.kind, r.kind), float(r.payload_bytes), \
            float(r.trips)


def byte_deltas(jtrace: CollectiveTrace, htrace: HloTrace,
                wide_bytes: int = 256) -> Dict[str, Dict[str, float]]:
    """Per-family trip-weighted wire-byte comparison (the benchmark metric):
    {family: {jaxpr, hlo, rel_delta}} over non-sideband records."""
    jw = _family_sums(_jaxpr_rows(jtrace), wide_bytes, weighted=True)
    hw_ = _family_sums(_hlo_rows(htrace), wide_bytes, weighted=True)
    out = {}
    for fam in sorted(set(jw) | set(hw_)):
        a, b = jw.get(fam, 0.0), hw_.get(fam, 0.0)
        denom = max(a, b)
        out[fam] = {"jaxpr": a, "hlo": b,
                    "rel_delta": (abs(a - b) / denom) if denom else 0.0}
    return out


def _window_cost(coster, lines: List[str], lo: int, hi: int) -> Tuple[float, float]:
    """(flops, hbm_bytes) of the compute scheduled strictly between two line
    indices of one computation — collective lines themselves excluded."""
    flops = bytes_ = 0.0
    for ln in lines[lo + 1:hi]:
        if OP_RE.search(ln):
            continue
        flops += coster.dot_flops(ln)
        priced = coster.hbm_bytes(ln)
        if priced is not None:
            bytes_ += priced[1]
    return flops, bytes_


def crosscheck_trace(jtrace: CollectiveTrace, htrace: HloTrace,
                     exp: ExpectedTrace, *,
                     tol: float = BYTE_TOL) -> List[Finding]:
    """All compiled-HLO findings of `htrace` against the jaxpr trace and the
    program expectation.  Empty list = compilation preserved the schedule."""
    out: List[Finding] = []
    prog = exp.program.name
    wide = exp.wide_bytes
    big = [r for r in htrace.records
           if not r.scalar and r.payload_bytes >= wide]

    # --- collective-rewritten / trip-count-mismatch: family byte agreement.
    # Skipped on 1-device meshes: XLA elides single-replica collectives
    # entirely, so there is nothing on the HLO side to match.
    if exp.n_devices > 1:
        jw = _family_sums(_jaxpr_rows(jtrace), wide, weighted=True)
        hw_ = _family_sums(_hlo_rows(htrace), wide, weighted=True)
        jp = _family_sums(_jaxpr_rows(jtrace), wide, weighted=False)
        hp = _family_sums(_hlo_rows(htrace), wide, weighted=False)
        for fam in sorted(set(jw) | set(hw_)):
            a, b = jw.get(fam, 0.0), hw_.get(fam, 0.0)
            denom = max(a, b)
            if denom == 0.0 or abs(a - b) / denom <= tol:
                continue
            anchor = next((r for r in big
                           if KIND_FAMILY.get(r.kind, r.kind) == fam), None)
            pa, pb = jp.get(fam, 0.0), hp.get(fam, 0.0)
            pden = max(pa, pb)
            if pden > 0.0 and abs(pa - pb) / pden <= tol:
                # per-issue payloads agree — only the execution multiplier
                # moved (a while loop unrolled differently than the jaxpr
                # scan, or a trip count was misparsed)
                out.append(Finding(
                    "trip-count-mismatch",
                    f"{fam} family of program {prog!r}: per-issue payloads "
                    f"agree ({pa:.0f} B jaxpr vs {pb:.0f} B HLO) but "
                    f"trip-weighted wire bytes diverge "
                    f"({a:.0f} B vs {b:.0f} B): HLO while trips != jaxpr "
                    "scan multiplier", anchor))
            else:
                out.append(Finding(
                    "collective-rewritten",
                    f"{fam} family of program {prog!r}: jaxpr wire bytes "
                    f"{a:.0f} B vs compiled HLO {b:.0f} B "
                    f"({abs(a - b) / denom:.0%} apart, tolerance {tol:.0%}): "
                    "the SPMD partitioner changed what rides the wire",
                    anchor))

    # --- wire-widened-post-spmd: a convert feeding a collective at a wider
    # dtype than it converts from means the payload was widened right before
    # the wire (dequantize-then-communicate).  The hierarchical inter-tier
    # fp32 leg is planned (fp32_exempt_axes), so DCN records are exempt when
    # the program declares one.
    for r in big:
        if r.fed_by_convert is None:
            continue
        if _np_bytes(r.fed_by_convert) >= _np_bytes(r.dtype):
            continue  # narrowing (quantize) or same width: healthy
        if r.is_dcn and exp.fp32_exempt_axes:
            continue
        out.append(Finding(
            "wire-widened-post-spmd",
            f"{r.op} payload ({r.payload_bytes} B {r.dtype}) is fed by a "
            f"convert from {r.fed_by_convert} in program {prog!r}: the "
            "wire format was widened after SPMD partitioning", r))

    # --- dcn-misrouted: tier routing vs the pod stride.  Only meaningful
    # when the caller classified groups against a pod stride.
    if htrace.pod_stride > 0 and big:
        expect_dcn = bool(exp.fp32_exempt_axes)
        spanning = [r for r in big if r.is_dcn]
        if expect_dcn and not spanning:
            out.append(Finding(
                "dcn-misrouted",
                f"program {prog!r} plans a hierarchical schedule (inter-tier "
                f"axes {list(exp.fp32_exempt_axes)}) but no compiled "
                f"collective spans the pod stride {htrace.pod_stride}: the "
                "two-tier plan was flattened into single-tier groups",
                big[0]))
        elif expect_dcn and len(spanning) == len(big):
            out.append(Finding(
                "dcn-misrouted",
                f"every compiled collective of program {prog!r} spans the "
                f"pod stride {htrace.pod_stride}: the intra-tier leg of the "
                "hierarchical schedule is missing (all traffic rides DCN)",
                spanning[0]))
        elif not expect_dcn:
            for r in spanning:
                out.append(Finding(
                    "dcn-misrouted",
                    f"{r.op} replica group spans the pod stride "
                    f"{htrace.pod_stride} (span {r.span}) but program "
                    f"{prog!r} plans a single-tier schedule: this leg rides "
                    "DCN unplanned", r))

    # --- overlap-lost-in-compilation: an async start/done pair with no
    # compute scheduled inside the window hides nothing — the latency-hiding
    # scheduler serialized what the program overlapped.  Sync collectives
    # (CPU lowering) have no window and can't trip this; the rule reads the
    # actual compiled schedule, not the program's intent.
    coster = htrace.coster()
    for r in htrace.records:
        if not r.is_async or r.scalar or r.payload_bytes < wide:
            continue
        lines = htrace.comps.get(r.computation, [])
        flops, bytes_ = _window_cost(coster, lines, r.start_index,
                                     r.done_index)
        if flops <= 0.0 and bytes_ <= 0.0:
            out.append(Finding(
                "overlap-lost-in-compilation",
                f"async {r.op} ({r.payload_bytes} B) in program {prog!r} "
                "has no compute scheduled between its start and done: the "
                "overlap window is empty, the collective is fully exposed",
                r))
    return out


# ------------------------------------------------- static overlap estimate


@dataclasses.dataclass(frozen=True)
class StaticOverlap:
    """Overlap/exposed-comm accounting read straight off the compiled
    schedule (wire time per collective vs roofline compute inside its async
    window) — the artifact-side counterpart of `costmodel.OverlapEstimate`."""
    comm_s: float        # total collective wire time in the scheduled stream
    overlapped_s: float  # comm hidden behind compute inside async windows
    exposed_s: float     # comm_s - overlapped_s
    compute_s: float     # roofline compute time of the whole module
    n_async: int         # collectives compiled as start/done pairs
    n_sync: int          # collectives compiled synchronous (no window)

    @property
    def hidden_fraction(self) -> float:
        return 0.0 if self.comm_s <= 0.0 else self.overlapped_s / self.comm_s

    def row(self) -> Dict[str, float]:
        return {"comm_s": self.comm_s, "overlapped_s": self.overlapped_s,
                "exposed_s": self.exposed_s, "compute_s": self.compute_s,
                "n_async": self.n_async, "n_sync": self.n_sync,
                "hidden_fraction": self.hidden_fraction}


def _roofline_seconds(flops: float, bytes_: float) -> float:
    from ..core import hw
    return max(flops / hw.PEAK_FLOPS_BF16, bytes_ / hw.HBM_BW)


def static_exposed_comm(htrace: HloTrace, *,
                        include_scalar: bool = False,
                        wide_bytes: int = 0) -> StaticOverlap:
    """Price the compiled op stream: per-collective wire time
    (`costmodel.wire_seconds` over the algorithm wire bytes, ICI vs DCN by
    pod span) against the roofline compute scheduled inside its async
    window.  A synchronous collective has no window — all of its wire time
    is exposed, which is exactly what the CPU lowering's fully-serial
    schedule should report."""
    from ..core.costmodel import wire_seconds

    coster = htrace.coster()
    comm = overlapped = 0.0
    n_async = n_sync = 0
    for r in htrace.records:
        if (r.scalar and not include_scalar) or r.payload_bytes < wide_bytes:
            continue
        wire = r.algo_wire_bytes * r.trips
        t_comm = wire_seconds(0.0, wire) if r.is_dcn else wire_seconds(wire)
        comm += t_comm
        if r.is_async:
            n_async += 1
            lines = htrace.comps.get(r.computation, [])
            flops, bytes_ = _window_cost(coster, lines, r.start_index,
                                         r.done_index)
            t_window = _roofline_seconds(flops, bytes_) * r.trips
            overlapped += min(t_comm, t_window)
        else:
            n_sync += 1
    # whole-module roofline compute (collective lines excluded; fused
    # computations' bytes counted once, at the fusion call site — the same
    # convention as `launch.hlo_analysis.analyze_cost`), for scale
    flops = bytes_ = 0.0
    entry_lines = htrace.comps.get("__entry__")
    from .hlo_trace import FUSED_PREFIXES, comp_multiplier, multipliers
    mult = multipliers(htrace.comps) if htrace.comps else {}
    for name, lines in htrace.comps.items():
        if name == "__entry__":
            continue
        m = comp_multiplier(name, lines, mult, entry_lines)
        fusion_like = name.startswith(FUSED_PREFIXES) or \
            ".clone" in name and "region" not in name
        for ln in lines:
            if OP_RE.search(ln):
                continue
            flops += coster.dot_flops(ln) * m
            if fusion_like:
                continue
            priced = coster.hbm_bytes(ln)
            if priced is not None:
                bytes_ += priced[1] * m
    return StaticOverlap(
        comm_s=comm, overlapped_s=overlapped, exposed_s=comm - overlapped,
        compute_s=_roofline_seconds(flops, bytes_),
        n_async=n_async, n_sync=n_sync)
