"""AdamW with cosine schedule, global-norm clipping, ZeRO-sharded state.

Optimizer moments are fp32 and inherit the parameter sharding (params carry
"fsdp"/"tp" logical axes => m/v are sharded the same way = ZeRO-3).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    peak_lr: float = 3e-4
    min_lr: float = 3e-5
    warmup_steps: int = 100
    decay_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0


def schedule(step: jnp.ndarray, cfg: OptConfig) -> jnp.ndarray:
    warm = cfg.peak_lr * jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps) / jnp.maximum(cfg.decay_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr + 0.5 * (cfg.peak_lr - cfg.min_lr) * (1 + jnp.cos(jnp.pi * t))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params) -> Dict[str, Any]:
    zeros32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros32, params),
        "v": jax.tree.map(zeros32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def abstract_opt_state(abstract_params):
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(f32, abstract_params),
        "v": jax.tree.map(f32, abstract_params),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }


def opt_state_logical(param_logical):
    ident = lambda l: l
    return {
        "m": jax.tree.map(ident, param_logical, is_leaf=lambda x: isinstance(x, tuple)),
        "v": jax.tree.map(ident, param_logical, is_leaf=lambda x: isinstance(x, tuple)),
        "step": (),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def apply_updates(params, grads, state, cfg: OptConfig) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(step, cfg)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state["m"])
    flat_v = tdef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
