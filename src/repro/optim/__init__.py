from .adamw import OptConfig, init_opt_state, abstract_opt_state, opt_state_logical, apply_updates, schedule
