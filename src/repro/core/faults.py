"""Deterministic fault injection: the messy-fabric harness (ROADMAP item 4).

The paper's core finding is that shared fabrics are *messy*: production noise
erodes allreduce goodput by up to 50% at 1k endpoints (Obs. 8), the 95th-pct
latency doubles the mean with a 132us max tail (Sec. V-B), incast saturates
endpoint links no service level can protect (Fig. 12), and the MI250x study
(arXiv:2302.14827) shows per-pair bandwidth heterogeneity is the norm.  The
models for all of that live in `core.noise`; this module turns them into a
*seeded, replayable schedule of faults* the live `Trainer.run` loop consumes:

  * `FaultEvent` — one timed event: a per-tier link degradation or latency
    spike window (priced through `ServiceLevelArbiter` / `NoiseModel`), a
    straggler episode, a transient step failure, or a node loss.
  * `FaultPlan` — an ordered, JSON-round-trippable set of events plus the
    seed; `messy_fabric()` builds the canonical seeded family used by tests,
    `benchmarks.run faults`, and the CI smoke.
  * `FaultInjector` — the step-wrapping hook: `before_step` raises the point
    faults (`TransientFault` / `NodeLossFault`) and `perturb` applies the
    windowed degradations to the measured step time.  On a CPU host mesh the
    fabric itself is simulated, so the injector is where the messy fabric
    *exists*: the same seeded plan perturbs the guarded and the oblivious
    runtime identically, which is what makes the guarded-vs-oblivious
    degradation comparison meaningful.

Determinism: every random draw is keyed on `(plan.seed, event.step, step)`,
so a plan replays bit-identically across runs, processes, and the
guarded/oblivious pair.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .noise import NoiseModel, ServiceLevelArbiter, TrafficClass

# windowed kinds degrade every step of [step, step + duration); point kinds
# fire exactly once at their step
KINDS = ("link_degrade", "latency_spike", "straggler",
         "transient_fail", "node_loss")
WINDOWED = ("link_degrade", "latency_spike", "straggler")
POINT = ("transient_fail", "node_loss")


class TransientFault(RuntimeError):
    """A recoverable step failure (the injected analog of a comm timeout or a
    device reset): the trainer's bounded-retry path restores and replays."""


class NodeLossFault(RuntimeError):
    """A device (node) left the job: the trainer's elastic path rebuilds the
    mesh on the surviving device set and restores onto it."""

    def __init__(self, message: str, lost: Sequence[int] = ()):
        super().__init__(message)
        self.lost = tuple(int(d) for d in lost)


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One timed fault.  `severity` is kind-specific:

      * link_degrade — aggressor demand as a multiple of the victim's (the
        arbiter turns it into a goodput fraction);
      * latency_spike — multiplier on the noise model's lognormal sigma (the
        queueing tail widens, the mean holds);
      * straggler — whole-step slowdown of the afflicted device (synchronous
        collectives make it everyone's slowdown);
      * transient_fail / node_loss — unused (point events).
    """

    step: int
    kind: str
    duration: int = 1
    tier: str = "inter"          # fabric tier the event hits ("intra"/"inter")
    severity: float = 2.0
    device: int = -1             # straggler / node-loss target (-1 = any)

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; one of {KINDS}")
        if self.step < 0 or self.duration < 1:
            raise ValueError(f"bad fault timing step={self.step} "
                             f"duration={self.duration}")
        if self.severity <= 0:
            raise ValueError(f"severity must be > 0, got {self.severity}")

    def active_at(self, step: int) -> bool:
        if self.kind in POINT:
            return step == self.step
        return self.step <= step < self.step + self.duration

    def to_dict(self) -> Dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: Dict) -> "FaultEvent":
        return cls(**{k: d[k] for k in
                      ("step", "kind", "duration", "tier", "severity", "device")
                      if k in d})


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """A seeded, ordered schedule of fault events.

    `comm_fraction` is the share of a clean step the fabric transfers occupy
    — the lever that converts a goodput fraction into a step-time factor
    (`(1 - f) + f / goodput`).  It is part of the plan (not the injector)
    because the same plan must degrade the guarded and oblivious runs
    identically.
    """

    events: Tuple[FaultEvent, ...] = ()
    seed: int = 0
    comm_fraction: float = 0.5

    def __post_init__(self):
        object.__setattr__(self, "events",
                           tuple(sorted(self.events, key=lambda e: e.step)))
        if not 0.0 < self.comm_fraction <= 1.0:
            raise ValueError(f"comm_fraction in (0, 1], got {self.comm_fraction}")

    def active(self, step: int) -> List[FaultEvent]:
        return [e for e in self.events if e.active_at(step)]

    def point_events(self, step: int) -> List[FaultEvent]:
        return [e for e in self.events if e.kind in POINT and e.step == step]

    # --------------------------------------------------------- persistence
    def to_dict(self) -> Dict:
        return {"version": 1, "seed": self.seed,
                "comm_fraction": self.comm_fraction,
                "events": [e.to_dict() for e in self.events]}

    @classmethod
    def from_dict(cls, d: Dict) -> "FaultPlan":
        if d.get("version", 1) != 1:
            raise ValueError(f"unknown FaultPlan version {d.get('version')!r}")
        return cls(events=tuple(FaultEvent.from_dict(e)
                                for e in d.get("events", ())),
                   seed=int(d.get("seed", 0)),
                   comm_fraction=float(d.get("comm_fraction", 0.5)))

    def save(self, path: str) -> None:
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(json.dumps(self.to_dict(), indent=2))

    @classmethod
    def load(cls, path: str) -> "FaultPlan":
        return cls.from_dict(json.loads(Path(path).read_text()))

    # ------------------------------------------------------------ builders
    @staticmethod
    def messy_fabric(seed: int = 0, steps: int = 32,
                     node_loss: bool = False) -> "FaultPlan":
        """The canonical seeded messy-fabric plan: a persistent inter-tier
        link degradation (the drift the guard must catch), a latency-spike
        window, a couple of straggler episodes, and one transient failure
        after the first checkpoint window.  `node_loss=True` adds a node loss
        near the end (off by default: it shrinks the mesh, which makes the
        guarded-vs-oblivious step-time comparison apples-to-oranges)."""
        rng = np.random.default_rng(seed)
        t_degrade = max(6, steps // 3)
        events = [
            # persistent congestion: an aggressor tenant arrives and stays
            FaultEvent(step=t_degrade, kind="link_degrade",
                       duration=max(steps - t_degrade, 1), tier="inter",
                       severity=float(rng.uniform(3.0, 5.0))),
            # a queueing-tail widening window (Sec. V-B shape)
            FaultEvent(step=max(2, steps // 6), kind="latency_spike",
                       duration=3, tier="inter",
                       severity=float(rng.uniform(2.0, 4.0))),
            # one recoverable step failure, late enough that a checkpoint
            # cadence of <= steps//3 has committed at least one snapshot
            FaultEvent(step=max(8, steps // 2), kind="transient_fail"),
        ]
        for _ in range(2):
            events.append(FaultEvent(
                step=int(rng.integers(2, max(steps - 1, 3))), kind="straggler",
                duration=1, severity=float(rng.uniform(2.5, 4.0)),
                device=int(rng.integers(0, 8))))
        if node_loss:
            events.append(FaultEvent(step=max(steps - 6, t_degrade + 2),
                                     kind="node_loss", device=1))
        return FaultPlan(events=tuple(events), seed=seed, comm_fraction=0.6)

    @classmethod
    def resolve(cls, spec: str, steps: int = 32) -> "FaultPlan":
        """CLI resolution: a JSON file path, or a named builtin —
        ``messy[:seed]`` / ``nodeloss[:seed]``."""
        name, _, seed_s = spec.partition(":")
        seed = int(seed_s) if seed_s else 0
        if name == "messy":
            return cls.messy_fabric(seed=seed, steps=steps)
        if name == "nodeloss":
            return cls.messy_fabric(seed=seed, steps=steps, node_loss=True)
        if Path(spec).exists():
            return cls.load(spec)
        raise ValueError(f"--faults {spec!r}: not a file and not a builtin "
                         f"('messy[:seed]' / 'nodeloss[:seed]')")


class FaultInjector:
    """The step-wrapping hook `Trainer.run` drives.

    `before_step` raises the plan's point faults; `perturb` converts the
    active windowed events into a step-time factor through the arbiter/noise
    models and applies it to the measured step time.  `on_replan` models the
    re-ranked plan's recovery on simulated fabrics: a replan cannot repair
    the physical link, but routing/rebucketing around the degraded tier
    recovers part of the *excess* — straggler excess is exempt (a slow
    device is not a routing problem)."""

    def __init__(self, plan: FaultPlan,
                 noise: Optional[NoiseModel] = None,
                 arbiter: Optional[ServiceLevelArbiter] = None):
        self.plan = plan
        self.noise = noise or NoiseModel.leonardo_diff_group()
        self.arbiter = arbiter or ServiceLevelArbiter(link_bw=25e9,
                                                      endpoint_bw=12.5e9)
        self.mitigation = 1.0   # scales the fabric excess; 1.0 = oblivious
        self._fired: set = set()
        self.log: List[Dict] = []

    # ------------------------------------------------------------- hooks
    def before_step(self, step: int) -> None:
        for ev in self.plan.point_events(step):
            key = (ev.step, ev.kind, ev.device)
            if key in self._fired:
                continue
            self._fired.add(key)
            self.log.append({"step": step, "kind": ev.kind,
                             "device": ev.device})
            if ev.kind == "transient_fail":
                raise TransientFault(
                    f"injected transient step failure at step {step}")
            raise NodeLossFault(
                f"injected node loss at step {step} (device {ev.device})",
                lost=(ev.device,) if ev.device >= 0 else (0,))

    def perturb(self, step: int, dt: float) -> float:
        fabric, straggler = self.factors(step)
        return dt * (1.0 + (fabric - 1.0) * self.mitigation) * straggler

    def on_replan(self, recovered: float = 0.6) -> None:
        self.mitigation *= max(0.0, 1.0 - recovered)
        self.log.append({"kind": "replan_mitigation",
                         "mitigation": self.mitigation})

    # ------------------------------------------------------------ pricing
    def factors(self, step: int) -> Tuple[float, float]:
        """(fabric_factor, straggler_factor) at `step` — both >= 1, both
        deterministic in (plan.seed, step)."""
        f = self.plan.comm_fraction
        fabric = 1.0
        straggler = 1.0
        for ev in self.plan.active(step):
            if ev.kind == "link_degrade":
                g = self.degraded_goodput(ev)
                fabric *= (1.0 - f) + f / max(g, 1e-6)
            elif ev.kind == "latency_spike":
                widened = dataclasses.replace(
                    self.noise, sigma=self.noise.sigma * ev.severity)
                rng = np.random.default_rng((self.plan.seed, ev.step, step))
                lat = float(widened.sample_latency(rng, 64).mean())
                # the tail's mean over the base: extra serialized latency on
                # every bucket of the comm fraction
                fabric *= 1.0 + f * max(lat / self.noise.base_latency - 1.0,
                                        0.0)
            elif ev.kind == "straggler":
                straggler *= ev.severity
        return fabric, straggler

    def degraded_goodput(self, ev: FaultEvent) -> float:
        """Goodput fraction under a link_degrade event: the victim shares its
        service level (the paper's production default) with an aggressor
        offering `severity` times its demand."""
        demand = self.arbiter.link_bw
        victim = TrafficClass("victim", 0, demand)
        aggr = [TrafficClass("aggressor", 0, ev.severity * demand)]
        return self.arbiter.victim_goodput(victim, aggr) / demand

    def slowdown(self, step: int) -> float:
        """Combined oblivious step-time factor at `step` (mitigation not
        applied) — what the degradation scenarios price."""
        fabric, straggler = self.factors(step)
        return fabric * straggler
