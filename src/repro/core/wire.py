"""Wire-format planning: per-tier gradient wire dtype as a calibrated decision.

The paper's software-layer observations (Obs. 1/4/5) say the interconnect is
rarely the problem — the bytes the software decides to move are.  Compression
is the bluntest instrument for that: int8 moves 4x fewer DP bytes.  But it
only pays where the transfer is *bandwidth-bound*; on an alpha-bound tier
(small per-step payloads, high per-message latency) shrinking the payload
saves nothing and costs quantization error.

This module turns that tradeoff into a planned decision from the same
alpha-beta fits the rest of the planner uses (`CommPlan.pipeline`, measured by
`core.calibrate` when a profile is attached):

  * `WireFormat` — the three wire dtypes the codec implements (fp32 / bf16 /
    int8 + per-bucket scales) with their bytes-per-element and sideband.
  * `choose_format(alpha, beta_seconds)` — one tier's decision: compress when
    the bandwidth term dominates the latency term at the bucket size.
  * `choose_wire(params, bucket_bytes)` — the per-tier `WireSpec` for a
    hierarchical plan: the intra tier and the inter (fabric) tier decided
    independently.  On the modeled systems this lands where the paper points:
    the inter tier is bandwidth-bound and compresses; the intra tier is
    alpha-bound at bucket granularity and stays fp32.
  * `bytes_on_wire(nbytes, fmt, n_buckets)` — wire-aware byte accounting used
    by `costmodel.exposed_comm_time`, `scenarios.sweep_overlap`, and the
    dry-run rooflines to price compression.

The chosen spec is persisted as `plan.wire` (see `commplan.CommPlan`) and
exposed through `autotune.CollectivePolicy.wire`; `runtime.steps` realizes
fp32/int8 via `--compress-bits` (bf16 exists for planning/pricing and the
codec round-trips it, but the trainer's lossy wire is the error-feedback int8
path).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

# bandwidth-term / latency-term thresholds: below BF16_RATIO the tier is
# alpha-bound (compression saves nothing), above INT8_RATIO it is clearly
# bandwidth-bound (take the 4x), in between bf16 halves the bytes at
# negligible accuracy cost
BF16_RATIO = 2.0
INT8_RATIO = 8.0


@dataclasses.dataclass(frozen=True)
class WireFormat:
    """One wire dtype the bucket codec can put on the fabric."""

    name: str
    bytes_per_elem: float
    scale_bytes: int        # per-bucket sideband (int8 carries fp32 scales)
    lossless: bool


WIRE_FORMATS: Dict[str, WireFormat] = {
    "fp32": WireFormat("fp32", 4.0, 0, True),
    "bf16": WireFormat("bf16", 2.0, 0, False),
    "int8": WireFormat("int8", 1.0, 4, False),
}


@dataclasses.dataclass(frozen=True)
class WireSpec:
    """Per-tier wire formats of a plan: `intra` covers the node/pod graph,
    `inter` the fabric tiers beyond it."""

    intra: str = "fp32"
    inter: str = "fp32"

    def __post_init__(self):
        for fmt in (self.intra, self.inter):
            if fmt not in WIRE_FORMATS:
                raise ValueError(f"unknown wire format {fmt!r}; "
                                 f"one of {sorted(WIRE_FORMATS)}")

    def fmt(self, tier: str) -> str:
        """Format for a fabric distance tier ("intra" or any inter tier)."""
        return self.intra if tier == "intra" else self.inter

    def multiplier(self, tier: str) -> float:
        """Bytes-on-wire multiplier vs fp32 for a tier (0.25 for int8)."""
        return WIRE_FORMATS[self.fmt(tier)].bytes_per_elem / 4.0

    @property
    def compresses(self) -> bool:
        return self.intra != "fp32" or self.inter != "fp32"

    def to_dict(self) -> Dict[str, str]:
        return {"intra": self.intra, "inter": self.inter}

    @classmethod
    def from_dict(cls, d: Optional[Dict[str, str]]) -> "WireSpec":
        d = d or {}
        return cls(intra=d.get("intra", "fp32"), inter=d.get("inter", "fp32"))


def bytes_on_wire(nbytes: float, fmt: str, n_buckets: int = 1) -> float:
    """Bytes an `nbytes` fp32 payload occupies on the wire in format `fmt`,
    including the per-bucket scale sideband for int8."""
    f = WIRE_FORMATS[fmt]
    return (nbytes / 4.0) * f.bytes_per_elem + n_buckets * f.scale_bytes


def wire_time(nbytes: float, fmt: str, alpha: float, bw: float,
              n_buckets: int = 1) -> float:
    """Alpha-beta transfer time of an fp32 payload sent in format `fmt`."""
    return alpha + bytes_on_wire(nbytes, fmt, n_buckets) / bw


def choose_format(alpha_s: float, beta_s: float,
                  allow_lossy: bool = True) -> str:
    """One tier's wire decision from its latency term (`alpha_s`, seconds per
    bucket of per-message latency) and bandwidth term (`beta_s`, seconds per
    bucket on the wire at fp32): compress where bandwidth-bound, stay fp32
    where alpha-bound."""
    if not allow_lossy:
        return "fp32"
    if alpha_s <= 0:
        # a zero-latency fit describes a purely bandwidth-bound tier (ratio
        # -> infinity): that is the case compression helps most
        return "int8" if beta_s > 0 else "fp32"
    ratio = beta_s / alpha_s
    if ratio >= INT8_RATIO:
        return "int8"
    if ratio >= BF16_RATIO:
        return "bf16"
    return "fp32"


def choose_wire(params, bucket_bytes: float,
                allow_lossy: bool = True) -> WireSpec:
    """Per-tier wire formats from a plan's `overlap.PipelineParams` alpha-beta
    constants, evaluated at the plan's bucket size (the unit the runtime
    actually puts on the wire).

    Inter tier: `alpha_dcn` against the hierarchical share
    `(bucket / n_ici) / bw_dcn` — compress when bandwidth-bound.  Intra tier:
    compression is considered only when (a) the fp32 intra phase would *pace*
    the pipeline (exceed the inter stage at its chosen wire) — when the inter
    tier is the bottleneck, shrinking the intra bytes cannot shorten the
    critical path, so the lossy format is all cost and no win — and (b) the
    *realized* wire actually moves fewer bytes: the runtime implements the
    lossy intra tier as the int8 gather wire ((n-1)/4 bytes per peer vs the
    fp32 ring's 2(n-1)/n), which only beats fp32 below n = 8 endpoints.  A
    planner that ignores (b) turns compression on exactly where it makes the
    step slower.  The intra decision is therefore int8-or-fp32 (bf16 has no
    realized intra wire); bf16 remains available to the inter (planning)
    tier.
    """
    n = max(int(params.n_ici), 2)
    frac = (n - 1) / n
    a_inter = params.alpha_dcn
    b_inter = (bucket_bytes / n) / params.bw_dcn
    inter = choose_format(a_inter, b_inter, allow_lossy)
    a_intra = (n - 1) * params.alpha_ici
    b_intra = bucket_bytes * frac / params.bw_ici
    t_inter = a_inter + b_inter * (WIRE_FORMATS[inter].bytes_per_elem / 4.0)
    intra = "fp32"
    if (a_intra + b_intra > t_inter and gather_wins(n)
            and choose_format(a_intra, b_intra, allow_lossy) != "fp32"):
        intra = "int8"
    return WireSpec(intra=intra, inter=inter)


def gather_wins(n: int) -> bool:
    """Whether the realized int8 gather wire ((n-1)/4 bytes per peer + scales)
    moves strictly fewer bytes than the fp32 bandwidth-optimal allreduce
    (2(n-1)/n per peer) over an n-endpoint axis: true iff n < 8."""
    return 2 <= n < 8


def realized_multiplier(fmt: str, n: int) -> float:
    """Bytes-on-wire multiplier of the *realized* wire vs the fp32 allreduce
    for an n-endpoint gather tier: int8 is the gather wire ((n-1)/4 per peer
    vs 2(n-1)/n), so its ratio is n/8, not the idealized 0.25 — above n = 8 it
    is clamped to 1.0 (no win).  Other formats keep the idealized ratio (they
    exist for planning/pricing, not as runtime wires)."""
    if fmt == "int8":
        return min(1.0, max(int(n), 2) / 8.0)
    return WIRE_FORMATS[fmt].bytes_per_elem / 4.0


def zero_wire_bytes(grad_bytes: float, n: int, ag_fmt: str = "fp32",
                    n_buckets: int = 1) -> Dict[str, float]:
    """Per-device DP wire-byte accounting of the three-phase ZeRO schedule
    (reduce-scatter of gradients -> sharded update -> all-gather of params)
    against the fp32 allreduce baseline.

    The baseline counts the paper's framing — allreduce moves every gradient
    byte twice (a reduce leg and a broadcast leg), so `2 * grad_bytes`.  The
    three-phase legs count *realized* ring bytes: the reduce-scatter moves
    `(n-1)/n` of the fp32 payload once, and the all-gather moves `(n-1)/n`
    of the payload at the AG leg's wire format (`bytes_on_wire`, so the int8
    scale sideband is included).  With an int8 AG leg at n = 8 the total
    lands at ~0.55x the baseline — the "wire bytes drop ~2x" headline.
    """
    n = max(int(n), 2)
    frac = (n - 1.0) / n
    ar = 2.0 * float(grad_bytes)
    rs = float(grad_bytes) * frac
    ag = bytes_on_wire(float(grad_bytes), ag_fmt, n_buckets) * frac
    total = rs + ag
    return {"allreduce_fp32": ar, "reduce_scatter": rs, "all_gather": ag,
            "total": total, "ratio": total / ar if ar else 0.0}


def choose_zero_ag_format(params, bucket_bytes: float,
                          allow_lossy: bool = True) -> WireSpec:
    """Wire formats of the ZeRO all-gather (param return) leg per tier.

    Unlike the gradient gather wire (`choose_wire`), the shard all-gather
    realizes the *idealized* multiplier at any endpoint count — each device
    contributes its 1/n shard exactly once, so an int8 AG leg always moves
    1/4 the fp32 AG bytes regardless of n.  There is therefore no
    `gather_wins` gate and no pacing gate: each tier is a pure
    `choose_format` decision at the bucket size.  (The runtime realizes any
    lossy decision as the int8 + per-shard-scale wire; bf16 remains a
    planning/pricing format.)
    """
    n = max(int(params.n_ici), 2)
    frac = (n - 1.0) / n
    intra = choose_format((n - 1) * params.alpha_ici,
                          bucket_bytes * frac / params.bw_ici, allow_lossy)
    inter = choose_format(params.alpha_dcn,
                          (bucket_bytes / n) / params.bw_dcn, allow_lossy)
    return WireSpec(intra=intra, inter=inter)


def choose_wire_single(alpha: float, bw: float, n: int, bucket_bytes: float,
                       allow_lossy: bool = True) -> WireSpec:
    """Wire decision for a single-level plan: only the intra tier exists, and
    the whole axis is the gather domain — the lossy wire is chosen only where
    the realized int8 gather beats the fp32 allreduce (`gather_wins`)."""
    n = max(int(n), 2)
    frac = (n - 1) / n
    intra = "fp32"
    if gather_wins(n) and choose_format((n - 1) * alpha,
                                        bucket_bytes * frac / bw,
                                        allow_lossy) != "fp32":
        intra = "int8"
    return WireSpec(intra=intra, inter=intra)
