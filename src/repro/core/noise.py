"""Network noise models, service-level isolation, straggler mitigation (paper Sec. VI).

The paper's unique position: Leonardo maps all production traffic to one InfiniBand
service level, so comparing default vs non-default SL measures *real* production
noise — -20% alltoall / -50% allreduce goodput at 1,024 GPUs (Obs. 8), 95th-pct
latency >8us vs 4.2us mean, max 132us (Sec. V-B).

Here:
  * `NoiseModel` — lognormal queueing-delay + goodput-degradation model calibrated
    to those measurements; composable with the cost models for the at-scale figures;
  * `ServiceLevelArbiter` — a virtual-lane simulator: classes share a link with
    round-robin arbitration; reproduces Fig. 12 (victim allreduce vs aggressor
    alltoall/incast on the same vs different SL, and the incast case where SL
    separation does not help because the endpoint link itself saturates);
  * `StragglerMitigator` — the runtime-facing piece: per-step time EWMA + deviation
    tracking with configurable actions, used by the train loop.  On TPU, ICI is
    single-tenant (no intra-slice noise) but DCN and host effects remain — see
    DESIGN.md Sec. 3.
"""
from __future__ import annotations

import dataclasses
import math
import statistics
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np


@dataclasses.dataclass
class NoiseModel:
    """Queueing-delay noise for one network tier."""

    base_latency: float          # s, uncongested
    sigma: float                 # lognormal shape of the queueing tail
    goodput_fraction: float      # mean goodput multiplier under production noise
    p95_latency: float           # s, calibration target
    max_latency: float           # s, calibration target

    @staticmethod
    def leonardo_diff_group() -> "NoiseModel":
        # Sec. V-B: mean 4.23us, p95 > 8us, max 132us; goodput 395->328 Gb/s mean,
        # min 216 Gb/s.
        return NoiseModel(base_latency=4.23e-6, sigma=0.45, goodput_fraction=328.0 / 395.0,
                          p95_latency=8e-6, max_latency=132e-6)

    @staticmethod
    def isolated() -> "NoiseModel":
        """Non-default service level: <1% min-max spread (Sec. VI-A)."""
        return NoiseModel(base_latency=4.23e-6, sigma=0.01, goodput_fraction=0.995,
                          p95_latency=4.4e-6, max_latency=5e-6)

    @staticmethod
    def tpu_dcn() -> "NoiseModel":
        """Inter-pod DCN: shared with other jobs, moderate tails; ICI itself is
        single-tenant per slice (structurally same-switch, see DESIGN.md)."""
        return NoiseModel(base_latency=25e-6, sigma=0.30, goodput_fraction=0.90,
                          p95_latency=60e-6, max_latency=500e-6)

    def sample_latency(self, rng: np.random.Generator, n: int) -> np.ndarray:
        """Per-message one-way latencies (s).

        `base_latency` is the *mean* the paper reports (4.23 us, Sec. V-B), so
        the lognormal location must be shifted: E[lognormal(mu, sigma)] =
        exp(mu + sigma^2/2), hence mu = log(base) - sigma^2/2.  (log(base)
        alone would make `base_latency` the median.)"""
        mu = math.log(self.base_latency) - self.sigma ** 2 / 2.0
        samples = rng.lognormal(mean=mu, sigma=self.sigma, size=n)
        return np.minimum(samples, self.max_latency)

    def goodput_scaling(self, n_endpoints: int, n_node: int, collective: str) -> float:
        """Fraction of noise-free goodput retained at scale (Fig. 13 model): noise
        applies to the inter-switch traffic fraction; allreduce's serialized
        dependency chains amplify it ~2x vs alltoall (Obs. 8: -50% vs -20%)."""
        if n_endpoints <= n_node:
            return 1.0
        frac_inter = (n_endpoints - n_node) / (n_endpoints - 1)
        amplification = 2.5 if collective == "allreduce" else 1.0
        loss = (1.0 - self.goodput_fraction) * frac_inter * amplification
        # saturate: the paper observes at most ~50% loss at 1k endpoints
        return max(0.35, 1.0 - loss)


@dataclasses.dataclass
class TrafficClass:
    name: str
    service_level: int
    demand_bytes_s: float   # offered load on the shared resource


class ServiceLevelArbiter:
    """Round-robin virtual-lane arbitration over a shared link (Sec. VI-A).

    Within one SL, flows share FIFO queues (head-of-line blocking: a victim's
    goodput degrades with the aggressor's demand).  Across SLs, arbitration is
    round-robin: each busy SL gets an equal share of link time.  Incast traffic
    congests the *destination endpoint* link, which no SL separation can fix —
    reproducing Fig. 12.
    """

    def __init__(self, link_bw: float, endpoint_bw: Optional[float] = None):
        self.link_bw = link_bw
        self.endpoint_bw = endpoint_bw or link_bw

    def victim_goodput(self, victim: TrafficClass, aggressors: Sequence[TrafficClass],
                       aggressor_pattern: str = "alltoall",
                       shares_switches: bool = True) -> float:
        """Achieved goodput (bytes/s) of the victim's flow."""
        if not shares_switches:
            # disjoint allocation: no shared switches => no interference (Sec. VI-A
            # final experiment)
            return min(victim.demand_bytes_s, self.link_bw)
        same_sl = [a for a in aggressors if a.service_level == victim.service_level]
        busy_sls = {victim.service_level} | {a.service_level for a in aggressors}
        sl_share = self.link_bw / len(busy_sls)
        # within the victim's SL: FIFO sharing with same-SL aggressor demand
        demand = victim.demand_bytes_s + sum(a.demand_bytes_s for a in same_sl)
        fifo = sl_share * victim.demand_bytes_s / demand if demand > 0 else sl_share
        g = min(victim.demand_bytes_s, fifo if same_sl else sl_share)
        if aggressor_pattern == "incast" and aggressors:
            # incast saturates the receiver endpoint link regardless of SL (Fig. 12)
            incast_demand = sum(a.demand_bytes_s for a in aggressors)
            endpoint_share = self.endpoint_bw * victim.demand_bytes_s / (
                victim.demand_bytes_s + incast_demand)
            g = min(g, endpoint_share)
        return g


@dataclasses.dataclass
class StragglerEvent:
    step: int
    step_time: float
    median_time: float
    ratio: float


class StragglerMitigator:
    """Per-step time tracker: EWMA baseline (seeded from the warmup-window
    median) and deviation threshold.

    Actions (paper Sec. VI applied to training): 'log' (record), 'sync' (insert a
    barrier to resynchronize pipelines), 'skip' (drop the step's gradient — only
    sound with replicated optimizer state), or a custom callback.
    """

    def __init__(self, threshold: float = 2.0, ewma: float = 0.1,
                 warmup_steps: int = 5, action: str = "log",
                 callback: Optional[Callable[[StragglerEvent], None]] = None):
        self.threshold = threshold
        self.ewma = ewma
        self.warmup_steps = warmup_steps
        self.action = action
        self.callback = callback
        self._baseline: Optional[float] = None
        self._warmup: List[float] = []
        self.events: List[StragglerEvent] = []

    def observe(self, step: int, step_time: float) -> Optional[StragglerEvent]:
        if len(self._warmup) < max(self.warmup_steps, 1):
            # Seed the baseline from the *median* of the warmup window, not the
            # first observation: step 0 is typically compile-heavy, and seeding
            # from it inflates the baseline enough to mask early stragglers.
            self._warmup.append(step_time)
            self._baseline = float(statistics.median(self._warmup))
            return None
        is_straggler = step_time > self.threshold * self._baseline
        ev = None
        if is_straggler:
            ev = StragglerEvent(step, step_time, self._baseline, step_time / self._baseline)
            self.events.append(ev)
            if self.callback is not None:
                self.callback(ev)
        else:
            # only fold non-straggler steps into the baseline
            self._baseline = (1 - self.ewma) * self._baseline + self.ewma * step_time
        return ev

    def reset_baseline(self) -> None:
        """Forget the EWMA baseline and re-seed from the next warmup window.

        The drift guard calls this after a mid-run re-plan: the step time
        under the new plan is a different population, and judging it against
        the pre-drift baseline would flag every healthy step as a straggler
        (the stale-baseline failure mode the oblivious runtime exhibits)."""
        self._baseline = None
        self._warmup = []

    @property
    def baseline(self) -> Optional[float]:
        return self._baseline
