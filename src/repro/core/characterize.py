"""End-to-end interconnect characterization driver — the paper's artifact, TPU-native.

Runs the full matrix {mechanism} x {pattern} x {size} x {scale} on the live device
set (host devices in this container; ICI on a real slice), plus the analytical
at-scale projections, and emits the eight observations with the local evidence.

Also provides the calibration-facing scenarios: the nearest/farthest p2p pair
selection (`p2p_pairs`), the concurrent pairwise-p2p sweep, and the
ServiceLevelArbiter congestion/incast projections (`core.calibrate` fits
alpha-beta parameters from all of them).

Used by examples/characterize_comm.py, core/calibrate.py, and the figure
benchmarks.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import PartitionSpec as P

from . import collectives as coll
from .bench import BenchRecord, IterStats, collective_goodput, iters_for_size, p2p_goodput, time_fn
from .costmodel import CommModel, make_comm_model
from .noise import NoiseModel, ServiceLevelArbiter, TrafficClass
from .topology import LinkGraph


def _shard_map(fn, mesh, axis):
    return jax.jit(jax.shard_map(fn, mesh=mesh, in_specs=P(axis), out_specs=P(axis)))


@dataclasses.dataclass
class CharacterizationReport:
    records: List[BenchRecord]
    observations: Dict[str, str]


def p2p_pairs(graph: Optional[LinkGraph], n: int) -> List[Tuple[int, int]]:
    """Nearest and farthest endpoint pairs (hop distance) among the first `n`
    endpoints of `graph` — the paper's p2p sweep covers both extremes of the
    link graph, not just rank 0's neighbor.  Falls back to a ring assumption
    when the graph doesn't cover the mesh.  Empty for n < 2."""
    if n < 2:
        return []
    if graph is None or graph.n < n:
        graph = LinkGraph.ring(n, 1.0)
    sources = range(n) if n <= 16 else (0,)  # all-pairs is quadratic; cap it
    best = worst = None
    for u in sources:
        dist, _ = graph._bfs_counts(u)
        for v in range(u + 1, n):
            d = dist[v]
            if d == float("inf"):
                continue
            if best is None or d < best[0]:
                best = (d, u, v)
            if worst is None or d > worst[0]:
                worst = (d, u, v)
    if best is None:
        return [(0, n - 1)]
    pairs = [(best[1], best[2])]
    if (worst[1], worst[2]) != pairs[0]:
        pairs.append((worst[1], worst[2]))
    return pairs


def characterize_mesh(mesh, axis: str = "x",
                      sizes: Sequence[int] = (1 << 10, 1 << 14, 1 << 18, 1 << 22),
                      iters: int = 30,
                      model: Optional[CommModel] = None) -> CharacterizationReport:
    """Measure p2p / allreduce / alltoall across mechanisms on a live mesh."""
    n = mesh.shape[axis]
    model = model or make_comm_model("tpu_v5e")
    records: List[BenchRecord] = []

    for nbytes in sizes:
        elems = max(nbytes // 4, n)
        per = elems // n + (1 if elems % n else 0)
        x = np.random.randn(n, per).astype(np.float32)
        payload = x.nbytes // n

        # --- p2p ping-pong (Fig. 3 analog): explicit ppermute path, nearest AND
        # farthest pair from the link graph (skipped entirely when n < 2 — a
        # single endpoint would only ping itself)
        for tag, (a, b) in zip(("near", "far"), p2p_pairs(model.graph, n)):
            f = _shard_map(lambda v, a=a, b=b: coll.ping_pong(v, axis, a, b),
                           mesh, axis)
            st = time_fn(f, x, iters=iters, warmup=3)
            records.append(BenchRecord(f"pingpong/{tag}_{a}-{b}", "device_copy",
                                       "p2p", payload, n, st,
                                       p2p_goodput(payload, st.median)))

        # --- allreduce across algorithms (Figs. 5-6 analog)
        for name in ("xla", "ring", "bidir_ring", "rabenseifner", "recursive_doubling",
                     "tree", "one_shot"):
            if n & (n - 1) and name in ("rabenseifner", "recursive_doubling", "tree"):
                continue
            fn = coll.ALL_REDUCE_ALGOS[name]
            f = _shard_map(lambda v, fn=fn: fn(v, axis), mesh, axis)
            st = time_fn(f, x, iters=iters, warmup=3)
            mech = "ccl" if name == "xla" else "mpi"
            records.append(BenchRecord(f"allreduce/{name}", mech, "allreduce",
                                       payload, n, st,
                                       collective_goodput(payload, st.median)))

        # --- alltoall (Fig. 5/9 analog): local view must be (n*k, ...) rows
        if per >= 1:
            rows_per_rank = n * max(per // n, 1)
            xa = np.random.randn(n * rows_per_rank, 4).astype(np.float32)
            pay = rows_per_rank * 4 * 4
            for name, fn in coll.ALL_TO_ALL_ALGOS.items():
                f = _shard_map(lambda v, fn=fn: fn(v, axis), mesh, axis)
                st = time_fn(f, xa, iters=iters, warmup=3)
                records.append(BenchRecord(f"alltoall/{name}",
                                           "ccl" if name == "xla" else "mpi",
                                           "alltoall", pay, n, st,
                                           collective_goodput(pay, st.median)))

        # --- trivial staging baseline (host bounce; not jitted by design)
        shards = [jax.device_put(x[i], d) for i, d in enumerate(mesh.devices.flat[:n])]
        st = time_fn(lambda: coll.staged_host_all_reduce(shards), iters=max(iters // 3, 5),
                     warmup=1)
        records.append(BenchRecord("allreduce/staging", "staging", "allreduce",
                                   payload, n, st, collective_goodput(payload, st.median)))

    observations = derive_observations(records)
    return CharacterizationReport(records, observations)


def pairwise_p2p_sweep(mesh, axis: str = "x",
                       sizes: Sequence[int] = (1 << 10, 1 << 14, 1 << 18),
                       iters: int = 20) -> List[BenchRecord]:
    """Concurrent pairwise exchange: all n endpoints send simultaneously to
    their (i + shift) peer, one shift per ring distance class.  The congestion-
    aware complement of the idle-network ping-pong — every link carries traffic
    at once, so the measured goodput reflects link sharing (EFI, Sec. IV-A)
    rather than the single-flow best case."""
    n = mesh.shape[axis]
    records: List[BenchRecord] = []
    if n < 2:
        return records
    shifts = sorted({1, n // 2, n - 1} - {0})
    for nbytes in sizes:
        # `sizes` are total buffer bytes, split across the mesh — the same
        # convention as characterize_mesh, so fits group comparable payloads
        per = max(nbytes // 4 // n, 1)
        x = np.random.randn(n, per).astype(np.float32)
        payload = per * 4
        for shift in shifts:
            perm = [(i, (i + shift) % n) for i in range(n)]
            f = _shard_map(lambda v, p=perm: jax.lax.ppermute(v, axis, p), mesh, axis)
            st = time_fn(f, x, iters=iters, warmup=3)
            records.append(BenchRecord(f"p2p_shift/{shift}", "device_copy",
                                       "p2p_concurrent", payload, n, st,
                                       collective_goodput(payload, st.median)))
    return records


def inter_tier_p2p_sweep(mesh, axis: str = "x", fabric=None,
                         sizes: Sequence[int] = (1 << 10, 1 << 14, 1 << 18),
                         iters: int = 20) -> List[BenchRecord]:
    """Per-distance-tier p2p sweep: one ping-pong pair per fabric tier
    (same_switch / same_group / diff_group), endpoints classified by
    `fabric.distance`.  On the host-device container every tier measures the
    same physical path — the value of the sweep is the tier-qualified fit
    keys (`mech/p2p/*@tier`) it feeds `calibrate.fit_profile`, which a real
    multi-node deployment fills with genuinely different numbers."""
    n = mesh.shape[axis]
    records: List[BenchRecord] = []
    if fabric is None or n < 2:
        return records
    # first endpoint pair observed at each inter tier under packed placement
    pair_by_tier = {}
    for b in range(1, n):
        tier = fabric.distance(0, b)
        if tier != "same_node" and tier not in pair_by_tier:
            pair_by_tier[tier] = (0, b)
    for nbytes in sizes:
        per = max(nbytes // 4 // n, 1)
        x = np.random.randn(n, per).astype(np.float32)
        payload = per * 4
        for tier, (a, b) in sorted(pair_by_tier.items()):
            f = _shard_map(lambda v, a=a, b=b: coll.ping_pong(v, axis, a, b),
                           mesh, axis)
            st = time_fn(f, x, iters=iters, warmup=3)
            records.append(BenchRecord(f"pingpong/{tier}_{a}-{b}", "device_copy",
                                       "p2p", payload, n, st,
                                       p2p_goodput(payload, st.median),
                                       tier=tier))
    return records


def congestion_sweep(p2p_records: Sequence[BenchRecord],
                     aggressor_factor: float = 2.0,
                     arbiter: Optional[ServiceLevelArbiter] = None) -> List[BenchRecord]:
    """Project measured p2p flows through the ServiceLevelArbiter contention
    model (Sec. VI-A / Fig. 12): a same-SL alltoall aggressor (FIFO sharing)
    and a cross-SL incast (endpoint-link saturation that SL separation cannot
    fix).  Emits synthetic BenchRecords whose goodput is the arbiter's victim
    share — the calibration fit learns a 'congested' effective bandwidth
    alongside the clean one; `expected_bytes_s` records the uncongested
    measurement."""
    base = [r for r in p2p_records if r.pattern in ("p2p", "p2p_concurrent")]
    out: List[BenchRecord] = []
    if not base:
        return out
    link_bw = max(r.goodput_bytes_s for r in base)
    arb = arbiter or ServiceLevelArbiter(link_bw=link_bw, endpoint_bw=link_bw / 2.0)
    for r in base:
        victim = TrafficClass("victim", 0, r.goodput_bytes_s)
        same_sl = [TrafficClass("aggressor", 0, aggressor_factor * link_bw)]
        incast = [TrafficClass("incast", 1, aggressor_factor * link_bw)]
        scenarios = (
            ("same_sl", arb.victim_goodput(victim, same_sl, "alltoall")),
            ("incast", arb.victim_goodput(victim, incast, "incast")),
        )
        # ping-pong stats are RTTs; p2p_concurrent stats are one-way.  Emit
        # uniformly one-way times so the p2p_congested fit is not a 2x mix.
        one_way = 0.5 if r.pattern == "p2p" else 1.0
        for tag, goodput in scenarios:
            scale = one_way * r.goodput_bytes_s / max(goodput, 1e-9)
            st = IterStats([t * scale for t in r.stats.times])
            out.append(BenchRecord(f"congestion/{tag}/{r.name}", r.mechanism,
                                   "p2p_congested", r.nbytes, r.n_endpoints, st,
                                   goodput, expected_bytes_s=r.goodput_bytes_s))
    return out


def derive_observations(records: List[BenchRecord]) -> Dict[str, str]:
    """Re-derive the paper's observations from local measurements where possible."""
    obs: Dict[str, str] = {}
    by = lambda pred: [r for r in records if pred(r)]

    staged = by(lambda r: r.mechanism == "staging")
    direct = by(lambda r: r.pattern == "allreduce" and r.mechanism != "staging")
    if staged and direct:
        ratio = max(d.goodput_bytes_s for d in direct) / max(s.goodput_bytes_s for s in staged)
        obs["obs2_staging_gap"] = (
            f"direct transfers beat trivial staging by {ratio:.1f}x at the largest size "
            "(paper: up to one order of magnitude)")

    small = by(lambda r: r.pattern == "allreduce" and r.nbytes <= 4096 and r.mechanism != "staging")
    big = by(lambda r: r.pattern == "allreduce" and r.nbytes >= (1 << 20) and r.mechanism != "staging")
    if small and big:
        best_small = min(small, key=lambda r: r.stats.median)
        best_big = max(big, key=lambda r: r.goodput_bytes_s)
        obs["obs4_crossover"] = (
            f"best small-message algorithm: {best_small.name}; best large-message: "
            f"{best_big.name} (paper Obs. 4/Fig. 11: the optimum flips with size)")

    a2a_x = by(lambda r: r.name == "alltoall/xla")
    a2a_p = by(lambda r: r.name == "alltoall/pairwise")
    if a2a_x and a2a_p:
        rx = max(r.goodput_bytes_s for r in a2a_x)
        rp = max(r.goodput_bytes_s for r in a2a_p)
        obs["obs7_alltoall"] = (
            f"platform alltoall {rx/max(rp,1e-9):.2f}x the pairwise schedule at peak; "
            "pairwise bounds connection state (the Obs. 7 instability fix)")
    return obs


def project_at_scale(system: str = "tpu_v5e",
                     endpoints: Sequence[int] = (8, 32, 128, 512, 1024, 4096),
                     alltoall_bytes: int = 2 << 20,
                     allreduce_bytes: int = 1 << 30,
                     noise: Optional[NoiseModel] = None) -> List[Dict]:
    """Figs. 9/10/13 analog: model-projected goodput vs endpoint count."""
    model = make_comm_model(system)
    nn = model.profile.endpoints_per_node
    rows = []
    for n in endpoints:
        for mech in ("ccl", "mpi"):
            a2a = model.alltoall_at_scale(alltoall_bytes, n, mech)
            ar = model.allreduce_at_scale(allreduce_bytes, n, mech)
            row = {
                "system": system, "endpoints": n, "mechanism": mech,
                "tier": model.fabric.tier_for_scale(n) if model.fabric else "",
                "alltoall_goodput_gbps": alltoall_bytes / a2a.seconds * 8 / 1e9,
                "allreduce_goodput_gbps": allreduce_bytes / ar.seconds * 8 / 1e9,
            }
            if noise is not None:
                row["alltoall_noisy_gbps"] = row["alltoall_goodput_gbps"] * \
                    noise.goodput_scaling(n, nn, "alltoall")
                row["allreduce_noisy_gbps"] = row["allreduce_goodput_gbps"] * \
                    noise.goodput_scaling(n, nn, "allreduce")
            rows.append(row)
    return rows
