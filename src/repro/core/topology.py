"""Link-graph topology models + edge forwarding index (paper Sec. IV-A).

The paper bounds collective goodput from the *edge forwarding index* (EFI) of the
connectivity graph: the maximum number of routes crossing any link under a routing.
We implement:

  * multigraph link topologies (capacity = #links x link_bw per edge),
  * the three paper node graphs (Alps / Leonardo fully-connected, LUMI's GCD graph),
  * TPU ICI tori (1-D ring, 2-D/3-D torus) and a two-level pod/DCN topology,
  * EFI under (a) deterministic single shortest-path routing (the paper's model —
    reproduces LUMI EFI = 4) and (b) ECMP fractional splitting,
  * the paper's expected-goodput formulas:
      alltoall  <= aggregate injection bandwidth / EFI          (Sec. IV-A)
      allreduce <= sum of outgoing links (fully connected, pipelined trees)
                   or n_disjoint_rings * link_bw / 2 (Rabenseifner)  (Sec. IV-C)
"""
from __future__ import annotations

import dataclasses
import itertools
from collections import defaultdict, deque
from typing import Dict, Iterable, List, Sequence, Tuple

Edge = Tuple[int, int]


def _key(u: int, v: int) -> Edge:
    return (u, v) if u <= v else (v, u)


@dataclasses.dataclass
class LinkGraph:
    """Undirected multigraph: edge (u,v) -> number of physical links."""

    n: int
    links: Dict[Edge, int]
    link_bw: float  # bytes/s per physical link, unidirectional
    name: str = "graph"

    # -- constructors -------------------------------------------------------
    @staticmethod
    def fully_connected(n: int, links_per_pair: int, link_bw: float, name: str = "fc") -> "LinkGraph":
        links = {_key(u, v): links_per_pair for u, v in itertools.combinations(range(n), 2)}
        return LinkGraph(n, links, link_bw, name)

    @staticmethod
    def lumi_node(link_bw: float) -> "LinkGraph":
        """LUMI/Frontier 8-GCD graph (paper Fig. 2): in-package pairs get 4 IF links,
        each GCD has 2 single external links (6 usable links per GCD)."""
        links: Dict[Edge, int] = {}
        for m in range(4):  # modules: (0,1) (2,3) (4,5) (6,7)
            links[_key(2 * m, 2 * m + 1)] = 4
        for u, v in [(0, 2), (0, 4), (1, 3), (1, 5), (2, 6), (3, 7), (4, 6), (5, 7)]:
            links[_key(u, v)] = 1
        return LinkGraph(8, links, link_bw, "lumi_node")

    @staticmethod
    def ring(n: int, link_bw: float, links_per_edge: int = 1, name: str = "ring") -> "LinkGraph":
        links = {_key(i, (i + 1) % n): links_per_edge for i in range(n)}
        return LinkGraph(n, links, link_bw, name)

    @staticmethod
    def torus2d(nx: int, ny: int, link_bw: float, name: str = "torus2d") -> "LinkGraph":
        """TPU v5e-style 2-D torus (wraparound in both dims)."""
        links: Dict[Edge, int] = defaultdict(int)
        idx = lambda x, y: x * ny + y
        for x in range(nx):
            for y in range(ny):
                links[_key(idx(x, y), idx((x + 1) % nx, y))] += 1
                links[_key(idx(x, y), idx(x, (y + 1) % ny))] += 1
        return LinkGraph(nx * ny, dict(links), link_bw, name)

    @staticmethod
    def torus3d(nx: int, ny: int, nz: int, link_bw: float, name: str = "torus3d") -> "LinkGraph":
        links: Dict[Edge, int] = defaultdict(int)
        idx = lambda x, y, z: (x * ny + y) * nz + z
        for x in range(nx):
            for y in range(ny):
                for z in range(nz):
                    links[_key(idx(x, y, z), idx((x + 1) % nx, y, z))] += 1
                    links[_key(idx(x, y, z), idx(x, (y + 1) % ny, z))] += 1
                    links[_key(idx(x, y, z), idx(x, y, (z + 1) % nz))] += 1
        return LinkGraph(nx * ny * nz, dict(links), link_bw, name)

    # -- basic properties ----------------------------------------------------
    def neighbors(self, u: int) -> List[int]:
        out = []
        for (a, b) in self.links:
            if a == u:
                out.append(b)
            elif b == u:
                out.append(a)
        return sorted(out)

    def degree_links(self, u: int) -> int:
        """Number of physical links incident to u (simultaneously usable)."""
        return sum(c for (a, b), c in self.links.items() if a == u or b == u)

    def injection_bw(self, u: int) -> float:
        return self.degree_links(u) * self.link_bw

    def pair_links(self, u: int, v: int) -> int:
        return self.links.get(_key(u, v), 0)

    def pair_bw(self, u: int, v: int) -> float:
        """Nominal single-best-path bandwidth between u,v (paper Fig. 4 dashed lines):
        the max over paths of the bottleneck capacity, not summed across paths."""
        # max-bottleneck path via binary search over capacities
        caps = sorted({c for c in self.links.values()})
        best = 0
        for cap in caps:
            if self._connected_with_min_cap(u, v, cap):
                best = cap
        return best * self.link_bw

    def _connected_with_min_cap(self, u: int, v: int, cap: int) -> bool:
        seen = {u}
        q = deque([u])
        while q:
            x = q.popleft()
            if x == v:
                return True
            for (a, b), c in self.links.items():
                if c < cap:
                    continue
                if a == x and b not in seen:
                    seen.add(b); q.append(b)
                elif b == x and a not in seen:
                    seen.add(a); q.append(a)
        return v in seen

    # -- routing / EFI -------------------------------------------------------
    def shortest_path(self, u: int, v: int) -> List[int]:
        """Deterministic BFS shortest path, lowest-neighbor-index tie-break —
        mirrors hop-count routing as in the paper's LUMI analysis."""
        prev = {u: None}
        q = deque([u])
        while q:
            x = q.popleft()
            if x == v:
                break
            for y in self.neighbors(x):
                if y not in prev:
                    prev[y] = x
                    q.append(y)
        path = [v]
        while prev[path[-1]] is not None:
            path.append(prev[path[-1]])
        return list(reversed(path))

    def edge_loads_single_path(self) -> Dict[Edge, float]:
        """Directed-path count per *directed* edge bundle (links are full duplex, so
        the two directions have independent capacity — paper Sec. IV-A) with one
        deterministic shortest path per ordered pair."""
        loads: Dict[Edge, float] = defaultdict(float)
        for u in range(self.n):
            for v in range(self.n):
                if u == v:
                    continue
                p = self.shortest_path(u, v)
                for a, b in zip(p, p[1:]):
                    loads[(a, b)] += 1.0  # directed
        return dict(loads)

    def edge_loads_ecmp(self) -> Dict[Edge, float]:
        """Directed-path load per directed edge bundle with fractional splitting over
        *all* shortest paths (balanced routing — matches the paper's LUMI analysis:
        max load 4 on the (1,5)/(3,7) single links)."""
        loads: Dict[Edge, float] = defaultdict(float)
        for src in range(self.n):
            dist, nsp = self._bfs_counts(src)
            # forward fractional flow: flow into node v from src is split backwards
            # over predecessor edges proportional to path counts.
            order = sorted(range(self.n), key=lambda v: -dist[v])
            flow = {v: 1.0 for v in range(self.n) if v != src}
            for v in order:
                if v == src or dist[v] == float("inf"):
                    continue
                f = flow.get(v, 0.0)
                preds = [u for u in self.neighbors(v) if dist[u] + 1 == dist[v]]
                tot = sum(nsp[u] for u in preds)
                for u in preds:
                    share = f * nsp[u] / tot
                    loads[(u, v)] += share  # directed src->...->u->v
                    if u != src:
                        flow[u] = flow.get(u, 0.0) + share
        return dict(loads)

    def _bfs_counts(self, src: int):
        dist = {v: float("inf") for v in range(self.n)}
        nsp = {v: 0 for v in range(self.n)}
        dist[src] = 0
        nsp[src] = 1
        q = deque([src])
        while q:
            x = q.popleft()
            for y in self.neighbors(x):
                if dist[y] == float("inf"):
                    dist[y] = dist[x] + 1
                    q.append(y)
                if dist[y] == dist[x] + 1:
                    nsp[y] += nsp[x]
        return dist, nsp

    def edge_forwarding_index(self, routing: str = "ecmp", per_link: bool = True) -> float:
        """Max directed-path count over any edge, normalized by the number of parallel
        links in the bundle when per_link=True (paper Sec. IV-A: LUMI = 4).  With
        per_link=False the bundle is treated as one fat link (paper's 'EFI = 1' for
        the fully-connected Alps/Leonardo nodes)."""
        loads = self.edge_loads_single_path() if routing == "single" else self.edge_loads_ecmp()
        if per_link:
            norm = [load / self.links[_key(a, b)] for (a, b), load in loads.items()]
        else:
            norm = list(loads.values())
        return max(norm) if norm else 0.0

    def bottleneck_pair_goodput(self, routing: str = "ecmp") -> float:
        """Max per-pair goodput g (bytes/s) sustainable by *all* pairs concurrently:
        for every directed edge e, g * paths(e) <= links(e) * link_bw.
        LUMI: min(400 Gb/s / 4) = 100 Gb/s per GCD pair (paper Sec. IV-A)."""
        loads = self.edge_loads_single_path() if routing == "single" else self.edge_loads_ecmp()
        return min(
            self.links[_key(a, b)] * self.link_bw / load for (a, b), load in loads.items()
        )

    # -- expected goodput (paper Secs. IV-A / IV-C) ---------------------------
    def alltoall_expected_goodput(self, routing: str = "ecmp", forwarding: bool | None = None) -> float:
        """Per-endpoint expected alltoall goodput (bytes/s), paper Sec. IV-A model:
        per-pair bottleneck goodput x number of concurrent flows, capped by the
        injection bandwidth.

        For GPU-node graphs (forwarding=False) a source drives at most
        `links-per-endpoint` concurrent flows — the paper's LUMI model:
        6 links x 100 Gb/s = 600 Gb/s; fully-connected nodes hit the injection
        bound (Alps 3.6 Tb/s, Leonardo 2.4 Tb/s).  For routed fabrics like the ICI
        torus (forwarding=True) intermediate chips forward, so all n-1 flows run
        concurrently and the bound coincides with the bisection bound
        (16x16 v5e torus: ~25 GB/s per chip)."""
        if self._is_fully_connected():
            return min(self.degree_links(u) for u in range(self.n)) * self.link_bw
        if forwarding is None:
            forwarding = self.name.startswith(("torus", "v5e", "ring"))
        g = self.bottleneck_pair_goodput(routing)
        inj = min(self.degree_links(u) for u in range(self.n)) * self.link_bw
        flows = self.n - 1 if forwarding else min(
            min(self.degree_links(u) for u in range(self.n)), self.n - 1
        )
        return min(inj, flows * g)

    def count_edge_disjoint_rings(self) -> int:
        """Number of edge-disjoint Hamiltonian-ring link sets, lower-bounded by
        min over nodes of (links incident / 2). For LUMI this gives 3... the paper
        (and AMD's CDNA2 doc) state 4 bidirectional rings using each physical link
        once per direction — i.e. links are full duplex, so a bidirectional ring
        consumes one link.  We therefore use min_degree_links // 2 * 2 capped by
        physical structure; for known graphs see KNOWN_RINGS."""
        if self.name in KNOWN_RINGS:
            return KNOWN_RINGS[self.name]
        if self.name.startswith(("torus", "v5e")):
            # a k-ary n-cube supports one unidirectional Hamiltonian ring per
            # outgoing link (2 per dimension): ring allreduce goodput = inj/2.
            return min(self.degree_links(u) for u in range(self.n))
        return max(1, min(self.degree_links(u) for u in range(self.n)) // 2)

    def allreduce_expected_goodput(self) -> float:
        """Per-endpoint expected allreduce goodput (bytes/s), paper Sec. IV-C:
          - fully connected: pipelined ternary-tree reduce+bcast => sum of outgoing
            link bandwidth;
          - otherwise: ring Rabenseifner over edge-disjoint bidirectional rings,
            sending 2x the buffer => rings * link_bw / 2."""
        if self._is_fully_connected():
            return min(self.degree_links(u) for u in range(self.n)) * self.link_bw
        rings = self.count_edge_disjoint_rings()
        # Rabenseifner moves 2S bytes through each ring link => goodput = rings*bw/2.
        # LUMI: 4 rings x 400 Gb/s / 2 = 800 Gb/s (paper Sec. IV-C).
        return rings * self.link_bw / 2.0

    def _is_fully_connected(self) -> bool:
        return all(self.pair_links(u, v) > 0 for u, v in itertools.combinations(range(self.n), 2))

    def bisection_bw(self) -> float:
        """Approximate bisection bandwidth: min over axis-aligned cuts for tori,
        else half-split cut."""
        half = self.n // 2
        cut = sum(c for (a, b), c in self.links.items() if (a < half) != (b < half))
        return cut * self.link_bw


# Edge-disjoint bidirectional ring counts for known graphs (paper Sec. IV-C cites 4
# for the MI250X GCD graph [AMD CDNA2 whitepaper]).
KNOWN_RINGS = {"lumi_node": 4}


@dataclasses.dataclass
class TwoLevelTopology:
    """Pod (ICI torus) x DCN — the TPU analog of node/Dragonfly (paper Sec. V).

    `intra` is the per-pod link graph; pods are connected over DCN with
    `dcn_bw` bytes/s per endpoint.
    """
    intra: LinkGraph
    n_pods: int
    dcn_bw: float

    @property
    def n(self) -> int:
        return self.intra.n * self.n_pods

    def alltoall_asymptotic_goodput(self) -> float:
        """Paper Sec. V-C: for large scale, alltoall goodput per endpoint approaches
        the inter-node (here DCN) bandwidth available to each endpoint."""
        return self.dcn_bw

    def alltoall_expected_goodput(self, n_endpoints: int) -> float:
        """Finite-size correction (Sec. V-C): only the fraction of traffic crossing
        the inter-pod network is limited by DCN."""
        if n_endpoints <= self.intra.n:
            # fall back to intra model on a sub-slice (approximate: full-pod EFI)
            return self.intra.alltoall_expected_goodput()
        frac_inter = (n_endpoints - self.intra.n) / max(n_endpoints - 1, 1)
        return self.dcn_bw / max(frac_inter, 1e-9) if frac_inter < 1 else self.dcn_bw

    def allreduce_expected_goodput(self, n_endpoints: int) -> float:
        """Hierarchical allreduce: intra-pod RS -> inter-pod AR -> intra-pod AG.
        The DCN phase moves bytes/n_intra per endpoint; goodput is min of phases."""
        intra = self.intra.allreduce_expected_goodput()
        if n_endpoints <= self.intra.n:
            return intra
        dcn_phase = self.dcn_bw * self.intra.n / 2.0  # reduced-scatter shards cross DCN
        return min(intra, dcn_phase)


def make_paper_node_graphs() -> Dict[str, LinkGraph]:
    from .hw import ALPS, LEONARDO, LUMI

    return {
        "alps": LinkGraph.fully_connected(4, 6, ALPS.link_bw, "alps_node"),
        "leonardo": LinkGraph.fully_connected(4, 4, LEONARDO.link_bw, "leonardo_node"),
        "lumi": LinkGraph.lumi_node(LUMI.link_bw),
    }


def make_tpu_pod(nx: int = 16, ny: int = 16) -> LinkGraph:
    from .hw import ICI_LINK_BW

    return LinkGraph.torus2d(nx, ny, ICI_LINK_BW, f"v5e_pod_{nx}x{ny}")


def make_tpu_multipod(n_pods: int = 2, nx: int = 16, ny: int = 16) -> TwoLevelTopology:
    from .hw import DCN_BW_PER_CHIP

    return TwoLevelTopology(make_tpu_pod(nx, ny), n_pods, DCN_BW_PER_CHIP)
