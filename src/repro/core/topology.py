"""Link-graph topology models + edge forwarding index (paper Sec. IV-A).

The paper bounds collective goodput from the *edge forwarding index* (EFI) of the
connectivity graph: the maximum number of routes crossing any link under a routing.
We implement:

  * multigraph link topologies (capacity = #links x link_bw per edge),
  * the three paper node graphs (Alps / Leonardo fully-connected, LUMI's GCD graph),
  * TPU ICI tori (1-D ring, 2-D/3-D torus) and a two-level pod/DCN topology,
  * EFI under (a) deterministic single shortest-path routing (the paper's model —
    reproduces LUMI EFI = 4) and (b) ECMP fractional splitting,
  * the paper's expected-goodput formulas:
      alltoall  <= aggregate injection bandwidth / EFI          (Sec. IV-A)
      allreduce <= sum of outgoing links (fully connected, pipelined trees)
                   or n_disjoint_rings * link_bw / 2 (Rabenseifner)  (Sec. IV-C)
  * the inter-node `Fabric` layer (Secs. V-VI): dragonfly (Slingshot groups +
    global links), fat-tree (Leonardo's 2:1 taper), and rail-optimized shapes,
    each classifying endpoint pairs into distance tiers (same_switch /
    same_group / diff_group) and bounding per-tier goodput by reusing the
    LinkGraph machinery one level up (switch graphs, group graphs).
"""
from __future__ import annotations

import dataclasses
import functools
import itertools
import math
from collections import defaultdict, deque
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

Edge = Tuple[int, int]


def _key(u: int, v: int) -> Edge:
    return (u, v) if u <= v else (v, u)


@dataclasses.dataclass
class LinkGraph:
    """Undirected multigraph: edge (u,v) -> number of physical links.

    Treated as immutable after construction: routing helpers cache an
    adjacency list on first use (mutating `links` afterwards is undefined).
    `dims` records the grid shape for torus constructors so bisection can
    take the minimum over axis-aligned cuts.
    """

    n: int
    links: Dict[Edge, int]
    link_bw: float  # bytes/s per physical link, unidirectional
    name: str = "graph"
    dims: Optional[Tuple[int, ...]] = None

    # -- constructors -------------------------------------------------------
    @staticmethod
    def fully_connected(n: int, links_per_pair: int, link_bw: float, name: str = "fc") -> "LinkGraph":
        links = {_key(u, v): links_per_pair for u, v in itertools.combinations(range(n), 2)}
        return LinkGraph(n, links, link_bw, name)

    @staticmethod
    def lumi_node(link_bw: float) -> "LinkGraph":
        """LUMI/Frontier 8-GCD graph (paper Fig. 2): in-package pairs get 4 IF links,
        each GCD has 2 single external links (6 usable links per GCD)."""
        links: Dict[Edge, int] = {}
        for m in range(4):  # modules: (0,1) (2,3) (4,5) (6,7)
            links[_key(2 * m, 2 * m + 1)] = 4
        for u, v in [(0, 2), (0, 4), (1, 3), (1, 5), (2, 6), (3, 7), (4, 6), (5, 7)]:
            links[_key(u, v)] = 1
        return LinkGraph(8, links, link_bw, "lumi_node")

    @staticmethod
    def ring(n: int, link_bw: float, links_per_edge: int = 1, name: str = "ring") -> "LinkGraph":
        links = {_key(i, (i + 1) % n): links_per_edge for i in range(n)}
        return LinkGraph(n, links, link_bw, name, dims=(n,))

    @staticmethod
    def torus2d(nx: int, ny: int, link_bw: float, name: str = "torus2d") -> "LinkGraph":
        """TPU v5e-style 2-D torus (wraparound in both dims)."""
        links: Dict[Edge, int] = defaultdict(int)
        idx = lambda x, y: x * ny + y
        for x in range(nx):
            for y in range(ny):
                links[_key(idx(x, y), idx((x + 1) % nx, y))] += 1
                links[_key(idx(x, y), idx(x, (y + 1) % ny))] += 1
        return LinkGraph(nx * ny, dict(links), link_bw, name, dims=(nx, ny))

    @staticmethod
    def torus3d(nx: int, ny: int, nz: int, link_bw: float, name: str = "torus3d") -> "LinkGraph":
        links: Dict[Edge, int] = defaultdict(int)
        idx = lambda x, y, z: (x * ny + y) * nz + z
        for x in range(nx):
            for y in range(ny):
                for z in range(nz):
                    links[_key(idx(x, y, z), idx((x + 1) % nx, y, z))] += 1
                    links[_key(idx(x, y, z), idx(x, (y + 1) % ny, z))] += 1
                    links[_key(idx(x, y, z), idx(x, y, (z + 1) % nz))] += 1
        return LinkGraph(nx * ny * nz, dict(links), link_bw, name, dims=(nx, ny, nz))

    # -- basic properties ----------------------------------------------------
    def _adjacency(self) -> Dict[int, List[Tuple[int, int]]]:
        """u -> sorted [(neighbor, link_count)], built once and cached.

        The graph is treated as immutable after construction, so the cache is
        never invalidated.  Without it every `neighbors` call rescans the whole
        edge dict, making the BFS-heavy EFI/ECMP paths quadratic in edges —
        intractable for 4096-endpoint fabrics."""
        adj = self.__dict__.get("_adj_cache")
        if adj is None:
            tmp: Dict[int, List[Tuple[int, int]]] = defaultdict(list)
            for (a, b), c in self.links.items():
                tmp[a].append((b, c))
                if a != b:
                    tmp[b].append((a, c))
            adj = {u: sorted(nbrs) for u, nbrs in tmp.items()}
            self.__dict__["_adj_cache"] = adj
        return adj

    def neighbors(self, u: int) -> List[int]:
        return [v for v, _ in self._adjacency().get(u, [])]

    def degree_links(self, u: int) -> int:
        """Number of physical links incident to u (simultaneously usable)."""
        return sum(c for _, c in self._adjacency().get(u, []))

    def injection_bw(self, u: int) -> float:
        return self.degree_links(u) * self.link_bw

    def pair_links(self, u: int, v: int) -> int:
        return self.links.get(_key(u, v), 0)

    def pair_bw(self, u: int, v: int) -> float:
        """Nominal single-best-path bandwidth between u,v (paper Fig. 4 dashed lines):
        the max over paths of the bottleneck capacity, not summed across paths.
        Implemented as a linear scan over the distinct link-bundle capacities,
        keeping the largest one that still connects u to v."""
        caps = sorted({c for c in self.links.values()})
        best = 0
        for cap in caps:
            if self._connected_with_min_cap(u, v, cap):
                best = cap
        return best * self.link_bw

    def _connected_with_min_cap(self, u: int, v: int, cap: int) -> bool:
        adj = self._adjacency()
        seen = {u}
        q = deque([u])
        while q:
            x = q.popleft()
            if x == v:
                return True
            for y, c in adj.get(x, []):
                if c >= cap and y not in seen:
                    seen.add(y); q.append(y)
        return v in seen

    # -- routing / EFI -------------------------------------------------------
    def shortest_path(self, u: int, v: int) -> List[int]:
        """Deterministic BFS shortest path, lowest-neighbor-index tie-break —
        mirrors hop-count routing as in the paper's LUMI analysis."""
        prev = {u: None}
        q = deque([u])
        while q:
            x = q.popleft()
            if x == v:
                break
            for y in self.neighbors(x):
                if y not in prev:
                    prev[y] = x
                    q.append(y)
        path = [v]
        while prev[path[-1]] is not None:
            path.append(prev[path[-1]])
        return list(reversed(path))

    def edge_loads_single_path(self) -> Dict[Edge, float]:
        """Directed-path count per *directed* edge bundle (links are full duplex, so
        the two directions have independent capacity — paper Sec. IV-A) with one
        deterministic shortest path per ordered pair."""
        loads: Dict[Edge, float] = defaultdict(float)
        for u in range(self.n):
            for v in range(self.n):
                if u == v:
                    continue
                p = self.shortest_path(u, v)
                for a, b in zip(p, p[1:]):
                    loads[(a, b)] += 1.0  # directed
        return dict(loads)

    def edge_loads_ecmp(self) -> Dict[Edge, float]:
        """Directed-path load per directed edge bundle with fractional splitting over
        *all* shortest paths (balanced routing — matches the paper's LUMI analysis:
        max load 4 on the (1,5)/(3,7) single links)."""
        loads: Dict[Edge, float] = defaultdict(float)
        for src in range(self.n):
            dist, nsp = self._bfs_counts(src)
            # forward fractional flow: flow into node v from src is split backwards
            # over predecessor edges proportional to path counts.
            order = sorted(range(self.n), key=lambda v: -dist[v])
            flow = {v: 1.0 for v in range(self.n) if v != src}
            for v in order:
                if v == src or dist[v] == float("inf"):
                    continue
                f = flow.get(v, 0.0)
                preds = [u for u in self.neighbors(v) if dist[u] + 1 == dist[v]]
                tot = sum(nsp[u] for u in preds)
                for u in preds:
                    share = f * nsp[u] / tot
                    loads[(u, v)] += share  # directed src->...->u->v
                    if u != src:
                        flow[u] = flow.get(u, 0.0) + share
        return dict(loads)

    def _bfs_counts(self, src: int):
        dist = {v: float("inf") for v in range(self.n)}
        nsp = {v: 0 for v in range(self.n)}
        dist[src] = 0
        nsp[src] = 1
        q = deque([src])
        while q:
            x = q.popleft()
            for y in self.neighbors(x):
                if dist[y] == float("inf"):
                    dist[y] = dist[x] + 1
                    q.append(y)
                if dist[y] == dist[x] + 1:
                    nsp[y] += nsp[x]
        return dist, nsp

    def edge_forwarding_index(self, routing: str = "ecmp", per_link: bool = True) -> float:
        """Max directed-path count over any edge, normalized by the number of parallel
        links in the bundle when per_link=True (paper Sec. IV-A: LUMI = 4).  With
        per_link=False the bundle is treated as one fat link (paper's 'EFI = 1' for
        the fully-connected Alps/Leonardo nodes)."""
        loads = self.edge_loads_single_path() if routing == "single" else self.edge_loads_ecmp()
        if per_link:
            norm = [load / self.links[_key(a, b)] for (a, b), load in loads.items()]
        else:
            norm = list(loads.values())
        return max(norm) if norm else 0.0

    def _memo(self, key, compute):
        """Result cache for the routing-heavy bounds (the graph is immutable,
        see the class docstring) — the all-pairs ECMP enumeration behind them
        is seconds on a 256-node torus, and the at-scale sweeps would
        otherwise pay it per evaluated endpoint count."""
        cache = self.__dict__.setdefault("_bound_cache", {})
        if key not in cache:
            cache[key] = compute()
        return cache[key]

    def bottleneck_pair_goodput(self, routing: str = "ecmp") -> float:
        """Max per-pair goodput g (bytes/s) sustainable by *all* pairs concurrently:
        for every directed edge e, g * paths(e) <= links(e) * link_bw.
        LUMI: min(400 Gb/s / 4) = 100 Gb/s per GCD pair (paper Sec. IV-A)."""
        def compute():
            loads = (self.edge_loads_single_path() if routing == "single"
                     else self.edge_loads_ecmp())
            return min(self.links[_key(a, b)] * self.link_bw / load
                       for (a, b), load in loads.items())
        return self._memo(("bottleneck", routing), compute)

    # -- expected goodput (paper Secs. IV-A / IV-C) ---------------------------
    def alltoall_expected_goodput(self, routing: str = "ecmp", forwarding: bool | None = None) -> float:
        """Per-endpoint expected alltoall goodput (bytes/s), paper Sec. IV-A model:
        per-pair bottleneck goodput x number of concurrent flows, capped by the
        injection bandwidth.

        For GPU-node graphs (forwarding=False) a source drives at most
        `links-per-endpoint` concurrent flows — the paper's LUMI model:
        6 links x 100 Gb/s = 600 Gb/s; fully-connected nodes hit the injection
        bound (Alps 3.6 Tb/s, Leonardo 2.4 Tb/s).  For routed fabrics like the ICI
        torus (forwarding=True) intermediate chips forward, so all n-1 flows run
        concurrently and the bound coincides with the bisection bound
        (16x16 v5e torus: ~25 GB/s per chip)."""
        def compute():
            if self._is_fully_connected():
                return min(self.degree_links(u) for u in range(self.n)) * self.link_bw
            fwd = forwarding
            if fwd is None:
                fwd = self.name.startswith(("torus", "v5e", "ring"))
            g = self.bottleneck_pair_goodput(routing)
            inj = min(self.degree_links(u) for u in range(self.n)) * self.link_bw
            flows = self.n - 1 if fwd else min(
                min(self.degree_links(u) for u in range(self.n)), self.n - 1
            )
            return min(inj, flows * g)
        return self._memo(("alltoall", routing, forwarding), compute)

    def count_edge_disjoint_rings(self) -> int:
        """Number of edge-disjoint Hamiltonian-ring link sets, lower-bounded by
        min over nodes of (links incident / 2). For LUMI this gives 3... the paper
        (and AMD's CDNA2 doc) state 4 bidirectional rings using each physical link
        once per direction — i.e. links are full duplex, so a bidirectional ring
        consumes one link.  We therefore use min_degree_links // 2 * 2 capped by
        physical structure; for known graphs see KNOWN_RINGS."""
        if self.name in KNOWN_RINGS:
            return KNOWN_RINGS[self.name]
        if self.name.startswith(("torus", "v5e")):
            # a k-ary n-cube supports one unidirectional Hamiltonian ring per
            # outgoing link (2 per dimension): ring allreduce goodput = inj/2.
            return min(self.degree_links(u) for u in range(self.n))
        return max(1, min(self.degree_links(u) for u in range(self.n)) // 2)

    def allreduce_expected_goodput(self) -> float:
        """Per-endpoint expected allreduce goodput (bytes/s), paper Sec. IV-C:
          - fully connected: pipelined ternary-tree reduce+bcast => sum of outgoing
            link bandwidth;
          - otherwise: ring Rabenseifner over edge-disjoint bidirectional rings,
            sending 2x the buffer => rings * link_bw / 2."""
        def compute():
            if self._is_fully_connected():
                return min(self.degree_links(u) for u in range(self.n)) * self.link_bw
            rings = self.count_edge_disjoint_rings()
            # Rabenseifner moves 2S bytes through each ring link => goodput =
            # rings*bw/2.  LUMI: 4 rings x 400 Gb/s / 2 = 800 Gb/s (Sec. IV-C).
            return rings * self.link_bw / 2.0
        return self._memo(("allreduce",), compute)

    def _is_fully_connected(self) -> bool:
        return self._memo(("fc",), lambda: all(
            self.pair_links(u, v) > 0
            for u, v in itertools.combinations(range(self.n), 2)))

    def bisection_bw(self) -> float:
        """Approximate bisection bandwidth: minimum over axis-aligned half cuts
        when the grid shape is known (tori/rings record `dims`), else the
        contiguous index half-split.  The axis minimum matters for asymmetric
        and odd-dimension tori, where the index half-split is not the narrowest
        cut (e.g. a 2x8 torus is y-axis-limited: 4 links, not 16)."""
        if self.dims and len(self.dims) >= 1 and any(d >= 2 for d in self.dims):
            return min(self._axis_cut_links(ax) for ax, d in enumerate(self.dims)
                       if d >= 2) * self.link_bw
        half = self.n // 2
        cut = sum(c for (a, b), c in self.links.items() if (a < half) != (b < half))
        return cut * self.link_bw

    def _coords(self, node: int) -> Tuple[int, ...]:
        cs = []
        for d in reversed(self.dims):
            cs.append(node % d)
            node //= d
        return tuple(reversed(cs))

    def _axis_cut_links(self, axis: int) -> int:
        """Links crossing the half cut perpendicular to `axis` (coord < d//2
        vs the rest); wraparound edges cross once more at the seam."""
        half = self.dims[axis] // 2
        return sum(c for (a, b), c in self.links.items()
                   if (self._coords(a)[axis] < half) != (self._coords(b)[axis] < half))


# Edge-disjoint bidirectional ring counts for known graphs (paper Sec. IV-C cites 4
# for the MI250X GCD graph [AMD CDNA2 whitepaper]).
KNOWN_RINGS = {"lumi_node": 4}


# Distance tiers of the inter-node fabric (paper Secs. V-VI: latency and noise
# are classified per pair as same switch / same group / different group).
INTER_TIERS = ("same_switch", "same_group", "diff_group")
TIERS = ("same_node",) + INTER_TIERS


@dataclasses.dataclass(frozen=True)
class Fabric:
    """Inter-node network fabric: endpoints -> nodes -> switches -> groups.

    Models the paper's three fabric shapes (Sec. II / Table I) above the
    intra-node `LinkGraph`:

      * ``dragonfly``  — Slingshot-style: switches within a group are fully
        connected (``switch_graph``), groups are fully connected over global
        links (``group_graph``); both tiers get EFI-style expected-goodput
        bounds by reusing the `LinkGraph` machinery.
      * ``fat_tree``   — Leonardo-style leaf/spine/core with a ``taper``
        (2:1 on Leonardo): full NIC bandwidth up to the group (pod) spine,
        1/taper of it through the core.
      * ``rail``       — rail-optimized: endpoint i of every node attaches to
        rail-switch i, so same-rail pairs are one switch hop away and
        cross-rail traffic pays the spine.
      * ``flat``       — backward-compatible scalar-DCN stand-in: every node
        is its own group, all inter traffic is `diff_group` at ``nic_bw``
        (exactly the old ``TwoLevelTopology.dcn_bw`` behavior).

    Endpoints are packed: node = ep // endpoints_per_node, switch =
    node // nodes_per_switch, group = switch // switches_per_group.
    """

    name: str
    kind: str                      # "dragonfly" | "fat_tree" | "rail" | "flat"
    endpoints_per_node: int
    nodes_per_switch: int
    switches_per_group: int
    n_groups: int
    nic_bw: float                  # per-endpoint injection, bytes/s
    link_bw: float = 0.0           # per fabric link (defaults to nic_bw)
    taper: float = 1.0             # leaf->core oversubscription (fat_tree/rail)
    switch_graph: Optional[LinkGraph] = None   # switches within one group
    group_graph: Optional[LinkGraph] = None    # groups over global links

    # ------------------------------------------------------------- geometry
    @property
    def nodes_per_group(self) -> int:
        return self.nodes_per_switch * self.switches_per_group

    @property
    def n_nodes(self) -> int:
        return self.nodes_per_group * self.n_groups

    @property
    def endpoints_per_switch(self) -> int:
        return self.nodes_per_switch * self.endpoints_per_node

    @property
    def endpoints_per_group(self) -> int:
        return self.nodes_per_group * self.endpoints_per_node

    @property
    def n_endpoints(self) -> int:
        return self.n_nodes * self.endpoints_per_node

    def node_of(self, endpoint: int) -> int:
        return endpoint // self.endpoints_per_node

    def switch_of(self, node: int) -> int:
        return node // self.nodes_per_switch

    def group_of(self, node: int) -> int:
        return self.switch_of(node) // self.switches_per_group

    # ------------------------------------------------- distance classification
    def distance(self, ep_a: int, ep_b: int) -> str:
        """Distance tier of an endpoint pair (paper Sec. V-B / Fig. 7)."""
        na, nb = self.node_of(ep_a), self.node_of(ep_b)
        if na == nb:
            return "same_node"
        if self.kind == "rail":
            # rail-optimized: same local index => one hop through the rail
            # switch; cross-rail traffic goes through the spine.
            same_rail = (ep_a % self.endpoints_per_node) == (ep_b % self.endpoints_per_node)
            return "same_switch" if same_rail else "same_group"
        if self.switch_of(na) == self.switch_of(nb):
            return "same_switch"
        if self.group_of(na) == self.group_of(nb):
            return "same_group"
        return "diff_group"

    def tier_for_scale(self, n_endpoints: int) -> str:
        """Widest tier spanned by a compact job of `n_endpoints` (endpoints
        [0, n) under packed placement) — the tier whose bounds govern an
        at-scale collective on that many endpoints."""
        if n_endpoints <= self.endpoints_per_node:
            return "same_node"
        if self.kind == "rail":
            return "same_group" if self.endpoints_per_node > 1 else "same_switch"
        if n_endpoints <= self.endpoints_per_switch:
            return "same_switch"
        if n_endpoints <= self.endpoints_per_group:
            return "same_group"
        return "diff_group"

    # ------------------------------------------------------- per-tier bounds
    def tier_bw(self, tier: str) -> float:
        """Per-endpoint expected-goodput bound (bytes/s) when traffic spans
        `tier` — the EFI-style bound of Sec. IV-A lifted one level up: the
        tier's link graph bounds the aggregate, divided by the endpoints
        sharing it, capped by the NIC.  Tiers are monotone: wider never beats
        narrower."""
        if tier == "same_node":
            return float("inf")  # governed by the intra-node graph, not the fabric
        if tier == "same_switch":
            return self.nic_bw
        if tier == "same_group":
            if self.kind == "dragonfly" and self.switch_graph is not None:
                agg = self.switch_graph.alltoall_expected_goodput()  # per switch
                return min(self.nic_bw, agg / max(self.endpoints_per_switch, 1))
            if self.kind == "rail":
                return self.nic_bw / max(self.taper, 1.0)
            return self.nic_bw  # fat-tree pod spine / flat: non-blocking
        if tier == "diff_group":
            same_group = self.tier_bw("same_group")
            if self.kind == "dragonfly" and self.group_graph is not None:
                agg = self.group_graph.alltoall_expected_goodput()  # per group
                return min(same_group, agg / max(self.endpoints_per_group, 1))
            if self.kind == "fat_tree":
                return min(same_group, self.nic_bw / max(self.taper, 1.0))
            return same_group
        raise ValueError(f"unknown tier {tier!r}")

    def tier_link_counts(self) -> Dict[str, int]:
        """Physical link counts per tier: switch downlinks (same_switch), the
        intra-group switch fabric (same_group, per group), and the global /
        core links (diff_group, whole fabric)."""
        counts = {"same_switch": self.endpoints_per_switch}
        if self.kind == "dragonfly":
            counts["same_group"] = (sum(self.switch_graph.links.values())
                                    if self.switch_graph is not None else 0)
            counts["diff_group"] = (sum(self.group_graph.links.values())
                                    if self.group_graph is not None else 0)
        elif self.kind == "fat_tree":
            # taper sits at the group->core boundary (matching tier_bw): the
            # pod spine is non-blocking, the core carries 1/taper of the
            # aggregate injection
            counts["same_group"] = self.endpoints_per_switch * self.switches_per_group
            counts["diff_group"] = max(
                int(round(self.endpoints_per_group * self.n_groups
                          / max(self.taper, 1.0))), 1) if self.n_groups > 1 else 0
        elif self.kind == "rail":
            counts["same_group"] = max(
                int(round(self.n_nodes * self.endpoints_per_node / max(self.taper, 1.0))), 1)
            counts["diff_group"] = 0
        else:  # flat
            counts["same_group"] = 0
            counts["diff_group"] = self.n_nodes
        return counts

    def bisection_bw(self) -> float:
        """Fabric bisection (bytes/s): the narrowest tier's cut over half the
        endpoints; dragonfly reuses the group/switch `LinkGraph` bisection."""
        if self.kind == "dragonfly":
            if self.n_groups > 1 and self.group_graph is not None:
                return self.group_graph.bisection_bw()
            if self.switch_graph is not None:
                return self.switch_graph.bisection_bw()
            return self.n_endpoints / 2.0 * self.nic_bw
        widest = "diff_group" if self.n_groups > 1 else "same_group"
        return self.n_endpoints / 2.0 * self.tier_bw(widest)

    def asymptotic_alltoall_goodput(self) -> float:
        """Sec. V-C: the per-endpoint goodput an at-scale alltoall approaches —
        the widest populated tier's bound."""
        if self.n_groups > 1:
            return self.tier_bw("diff_group")
        if self.switches_per_group > 1 or self.kind == "rail":
            return self.tier_bw("same_group")
        return self.tier_bw("same_switch")

    def alltoall_expected_goodput(self, n_endpoints: int) -> float:
        """Per-endpoint alltoall bound for a compact job of `n_endpoints`."""
        return self.tier_bw(self.tier_for_scale(max(n_endpoints, 1)))

    # ---------------------------------------------------------- constructors
    @staticmethod
    def dragonfly(name: str, endpoints_per_node: int, nic_bw: float,
                  nodes_per_switch: int = 16, switches_per_group: int = 16,
                  n_groups: int = 8, link_bw: Optional[float] = None,
                  group_links_per_pair: Optional[int] = None,
                  global_links_per_pair: Optional[int] = None) -> "Fabric":
        """Slingshot-style dragonfly: all-to-all switches inside a group,
        all-to-all groups over global links (paper Sec. II: Alps/LUMI).

        Link bundles default to injection-balanced sizing (Slingshot's design
        point, and why the paper's at-scale alltoall approaches the NIC
        bandwidth): enough links per switch/group pair to carry the attached
        endpoints' full injection.  Pass explicit counts to model a tapered
        dragonfly."""
        link_bw = nic_bw if link_bw is None else link_bw
        eps_switch = nodes_per_switch * endpoints_per_node
        eps_group = eps_switch * switches_per_group
        inj = lambda eps, peers: max(
            int(math.ceil(eps * nic_bw / (peers * link_bw))), 1)
        switch_graph = group_graph = None
        if switches_per_group > 1:
            glp = (group_links_per_pair if group_links_per_pair is not None
                   else inj(eps_switch, switches_per_group - 1))
            switch_graph = LinkGraph.fully_connected(
                switches_per_group, glp, link_bw, f"{name}_group")
        if n_groups > 1:
            glb = (global_links_per_pair if global_links_per_pair is not None
                   else inj(eps_group, n_groups - 1))
            group_graph = LinkGraph.fully_connected(
                n_groups, glb, link_bw, f"{name}_global")
        return Fabric(name, "dragonfly", endpoints_per_node, nodes_per_switch,
                      switches_per_group, n_groups, nic_bw, link_bw,
                      switch_graph=switch_graph, group_graph=group_graph)

    @staticmethod
    def fat_tree(name: str, endpoints_per_node: int, nic_bw: float,
                 nodes_per_switch: int = 16, switches_per_group: int = 18,
                 n_groups: int = 8, taper: float = 2.0) -> "Fabric":
        """Leaf/spine/core fat-tree with `taper`:1 oversubscription through the
        core (Leonardo's 2:1, paper Sec. II): full NIC bandwidth inside a pod,
        nic_bw/taper across pods."""
        return Fabric(name, "fat_tree", endpoints_per_node, nodes_per_switch,
                      switches_per_group, n_groups, nic_bw, nic_bw, taper=taper)

    @staticmethod
    def rail_optimized(name: str, endpoints_per_node: int, n_nodes: int,
                       nic_bw: float, taper: float = 1.0) -> "Fabric":
        """Rail-optimized: one switch plane (rail) per endpoint index; all
        nodes attach to every rail.  Same-rail pairs are same_switch; the rest
        cross the spine (same_group, tapered)."""
        return Fabric(name, "rail", endpoints_per_node, n_nodes, 1, 1, nic_bw,
                      nic_bw, taper=taper)

    @staticmethod
    def flat(name: str, endpoints_per_node: int, n_nodes: int,
             nic_bw: float) -> "Fabric":
        """Scalar-DCN stand-in: every node its own group, all inter traffic at
        `nic_bw` classified diff_group (the legacy `dcn_bw` behavior)."""
        return Fabric(name, "flat", endpoints_per_node, 1, 1, max(n_nodes, 1),
                      nic_bw, nic_bw)


@functools.lru_cache(maxsize=None)
def make_paper_fabrics() -> Dict[str, "Fabric"]:
    """The three paper inter-node fabrics + the TPU DCN, sized so a
    4096-endpoint job fits (paper Sec. V runs up to 4096 GPUs).

    Alps / LUMI: Slingshot dragonfly (Sec. II); Leonardo modeled as the
    2:1-tapered fat-tree of its NDR spine; TPU: flat DCN over pods."""
    from .hw import ALPS, LEONARDO, LUMI, DCN_BW_PER_CHIP

    return {
        "alps": Fabric.dragonfly("alps_slingshot", ALPS.endpoints_per_node,
                                 ALPS.nic_bw, nodes_per_switch=16,
                                 switches_per_group=16, n_groups=32),
        "leonardo": Fabric.fat_tree("leonardo_fattree", LEONARDO.endpoints_per_node,
                                    LEONARDO.nic_bw, nodes_per_switch=16,
                                    switches_per_group=18, n_groups=8, taper=2.0),
        "lumi": Fabric.dragonfly("lumi_slingshot", LUMI.endpoints_per_node,
                                 LUMI.nic_bw, nodes_per_switch=16,
                                 switches_per_group=16, n_groups=16),
        "tpu_v5e": Fabric.flat("tpu_dcn", 256, 16, DCN_BW_PER_CHIP),
    }


@dataclasses.dataclass
class TwoLevelTopology:
    """Pod (ICI torus) x inter-node fabric — node/Dragonfly of the paper, Sec. V.

    `intra` is the per-pod (per-node) link graph; pods are connected by
    `fabric`.  The legacy scalar construction `TwoLevelTopology(intra, n_pods,
    dcn_bw)` still works: it builds a flat `Fabric` where every inter pair is
    `diff_group` at `dcn_bw` bytes/s per endpoint.
    """
    intra: LinkGraph
    n_pods: int = 0
    dcn_bw: float = 0.0
    fabric: Optional[Fabric] = None

    def __post_init__(self):
        if self.fabric is None:
            self.fabric = Fabric.flat(f"{self.intra.name}_dcn", self.intra.n,
                                      max(self.n_pods, 1), self.dcn_bw)
        if not self.n_pods:
            self.n_pods = self.fabric.n_nodes
        if not self.dcn_bw:
            # scalar view for legacy callers: the widest tier's bound
            self.dcn_bw = self.fabric.asymptotic_alltoall_goodput()

    @classmethod
    def from_fabric(cls, intra: LinkGraph, fabric: Fabric) -> "TwoLevelTopology":
        return cls(intra, fabric.n_nodes, 0.0, fabric)

    @property
    def n(self) -> int:
        return self.intra.n * self.n_pods

    def tier_for_scale(self, n_endpoints: int) -> str:
        return self.fabric.tier_for_scale(n_endpoints)

    def alltoall_asymptotic_goodput(self) -> float:
        """Paper Sec. V-C: for large scale, alltoall goodput per endpoint approaches
        the inter-node (fabric) bandwidth available to each endpoint."""
        return self.fabric.asymptotic_alltoall_goodput()

    def alltoall_expected_goodput(self, n_endpoints: int) -> float:
        """Finite-size correction (Sec. V-C): only the fraction of traffic
        crossing the inter-node fabric is limited by it — capped by the
        intra-node bound, which the fabric correction can never exceed (an
        uncapped correction at n_endpoints = intra.n + 1 would claim
        ~n * dcn_bw, beyond what the node physically sustains)."""
        intra_bound = self.intra.alltoall_expected_goodput()
        if n_endpoints <= self.intra.n:
            # fall back to intra model on a sub-slice (approximate: full-pod EFI)
            return intra_bound
        frac_inter = (n_endpoints - self.intra.n) / max(n_endpoints - 1, 1)
        tier_bw = self.fabric.tier_bw(self.fabric.tier_for_scale(n_endpoints))
        return min(intra_bound, tier_bw / max(frac_inter, 1e-9))

    def allreduce_expected_goodput(self, n_endpoints: int) -> float:
        """Hierarchical allreduce: intra-pod RS -> inter-pod AR -> intra-pod AG.
        The fabric phase moves bytes/n_intra per endpoint; goodput is min of
        phases, with the inter phase at the spanned tier's bandwidth."""
        intra = self.intra.allreduce_expected_goodput()
        if n_endpoints <= self.intra.n:
            return intra
        tier_bw = self.fabric.tier_bw(self.fabric.tier_for_scale(n_endpoints))
        dcn_phase = tier_bw * self.intra.n / 2.0  # reduce-scatter shards cross the fabric
        return min(intra, dcn_phase)


@functools.lru_cache(maxsize=None)
def make_paper_node_graphs() -> Dict[str, LinkGraph]:
    from .hw import ALPS, LEONARDO, LUMI

    return {
        "alps": LinkGraph.fully_connected(4, 6, ALPS.link_bw, "alps_node"),
        "leonardo": LinkGraph.fully_connected(4, 4, LEONARDO.link_bw, "leonardo_node"),
        "lumi": LinkGraph.lumi_node(LUMI.link_bw),
    }


@functools.lru_cache(maxsize=None)
def make_tpu_pod(nx: int = 16, ny: int = 16) -> LinkGraph:
    from .hw import ICI_LINK_BW

    return LinkGraph.torus2d(nx, ny, ICI_LINK_BW, f"v5e_pod_{nx}x{ny}")


@functools.lru_cache(maxsize=None)
def make_tpu_multipod(n_pods: int = 2, nx: int = 16, ny: int = 16) -> TwoLevelTopology:
    from .hw import DCN_BW_PER_CHIP

    return TwoLevelTopology(make_tpu_pod(nx, ny), n_pods, DCN_BW_PER_CHIP)


@functools.lru_cache(maxsize=None)
def make_paper_systems() -> Dict[str, TwoLevelTopology]:
    """Full two-level system models: intra-node graph + inter-node fabric for
    the three paper machines and the TPU multipod — what the at-scale scenario
    suite (`core.scenarios`) sweeps from 8 to 4096 endpoints.

    Memoized (as are the factories above): the scenario sweeps call these in
    loops, and rebuilding the link graphs / fabrics per call dominated the CI
    smoke wall time.  Callers treat the returned topologies as immutable."""
    fabrics = make_paper_fabrics()
    systems = {name: TwoLevelTopology.from_fabric(graph, fabrics[name])
               for name, graph in make_paper_node_graphs().items()}
    systems["tpu_v5e"] = TwoLevelTopology.from_fabric(make_tpu_pod(),
                                                      fabrics["tpu_v5e"])
    return systems
