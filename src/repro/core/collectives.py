"""Explicit collective algorithms over jax.lax.ppermute / all_to_all (shard_map).

The paper's "mechanism axis" (trivial staging / device-device copy / *CCL /
GPU-aware MPI), TPU-native:

  * XLA built-in collectives (``psum``/``all_gather``/``all_to_all``) — the
    vendor-tuned path, the *CCL analog;
  * the explicit algorithms here — hand-scheduled point-to-point over
    ``ppermute``, the GPU-aware-MPI / device-copy analog.  Algorithm choice per
    message size is exactly the tuning surface of the paper's Obs. 1 / Fig. 11;
  * host staging — see ``staged_host_all_reduce`` (outside jit; benchmark only).

Every function operates on the *local shard view* inside ``jax.shard_map`` over a
named axis.  All are validated against jnp oracles in tests/test_collectives.py.

Algorithms:
  ring_reduce_scatter / ring_all_gather / ring_all_reduce      bandwidth-optimal
  bidir_ring_all_reduce                                        2 counter-rotating rings
  rabenseifner_all_reduce (recursive halving + doubling)       bw-optimal, log-latency
  recursive_doubling_all_reduce                                latency-optimal
  tree_all_reduce (binomial reduce + broadcast)                latency-optimal small n
  one_shot_all_reduce (all-gather + local reduce)              device-copy analog
  all_to_all_direct / all_to_all_pairwise                      XLA vs chunk-bounded
  hierarchical_all_reduce                                      ICI RS -> DCN AR -> ICI AG
  ping_pong                                                    p2p latency/goodput probe

Every algorithm self-registers in the collective registry (`register` /
`registered` / `get_collective`); `core.commplan` ranks registry entries with
topology-derived cost functions instead of hand-maintained candidate dicts.
`ALL_REDUCE_ALGOS` / `ALL_TO_ALL_ALGOS` remain as single-axis views for
backward compatibility.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Dict, Optional, Sequence

import jax
import jax.numpy as jnp
from jax import lax


# ------------------------------------------------------------------- registry
@dataclasses.dataclass(frozen=True)
class CollectiveSpec:
    """Registry entry: the callable plus the dispatch constraints the planner
    needs (power-of-two-only schedules, multi-axis hierarchical variants)."""

    name: str
    kind: str                 # all_reduce | all_to_all | reduce_scatter | all_gather
    fn: Callable
    pow2_only: bool = False   # schedule requires a power-of-two axis size
    multi_axis: bool = False  # fn(x, ici_axis, dcn_axis) instead of fn(x, axis)


_REGISTRY: Dict[str, Dict[str, CollectiveSpec]] = {}


def register(kind: str, name: str, *, pow2_only: bool = False, multi_axis: bool = False):
    """Decorator registering a collective implementation under (kind, name)."""

    def deco(fn: Callable) -> Callable:
        _REGISTRY.setdefault(kind, {})[name] = CollectiveSpec(
            name, kind, fn, pow2_only=pow2_only, multi_axis=multi_axis)
        return fn

    return deco


def registered(kind: str, *, multi_axis: Optional[bool] = None) -> Dict[str, CollectiveSpec]:
    specs = _REGISTRY.get(kind, {})
    if multi_axis is None:
        return dict(specs)
    return {n: s for n, s in specs.items() if s.multi_axis == multi_axis}


def get_collective(kind: str, name: str) -> CollectiveSpec:
    try:
        return _REGISTRY[kind][name]
    except KeyError:
        raise KeyError(f"no {kind!r} collective named {name!r}; "
                       f"registered: {sorted(_REGISTRY.get(kind, {}))}") from None


def _axis_n(axis: str) -> int:
    return lax.axis_size(axis)


def _ring_perm(n: int, shift: int = 1):
    return [(i, (i + shift) % n) for i in range(n)]


def _pad_to(x: jnp.ndarray, multiple: int):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % multiple
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat, pad


# --------------------------------------------------------------------------- ring
@register("reduce_scatter", "ring")
def ring_reduce_scatter(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Returns this rank's reduced chunk (flat, len = padded_size/n)."""
    n = _axis_n(axis)
    idx = lax.axis_index(axis)
    flat, _ = _pad_to(x, n)
    chunks = flat.reshape(n, -1)
    # Step s: every rank sends the chunk it currently accumulates for rank
    # (idx - s - 1) and receives+accumulates the one for (idx - s)... canonical:
    # start by sending chunk (idx+ n -1)%n? Use the textbook schedule:
    #   after n-1 steps rank r owns sum of chunk r.
    buf = jnp.take(chunks, (idx + n - 1) % n, axis=0)
    for s in range(n - 1):
        buf = lax.ppermute(buf, axis, _ring_perm(n, 1))
        take = (idx + n - 2 - s) % n
        if s < n - 2:
            buf = buf + jnp.take(chunks, take, axis=0)
        else:
            buf = buf + jnp.take(chunks, idx, axis=0)
    return buf


@register("all_gather", "ring")
def ring_all_gather(chunk: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Each rank contributes `chunk`; returns (n, chunk_shape) gathered in rank order."""
    n = _axis_n(axis)
    idx = lax.axis_index(axis)
    out = jnp.zeros((n,) + chunk.shape, chunk.dtype)
    out = lax.dynamic_update_index_in_dim(out, chunk, idx, 0)
    buf = chunk
    for s in range(n - 1):
        buf = lax.ppermute(buf, axis, _ring_perm(n, 1))
        src = (idx - s - 1) % n
        out = lax.dynamic_update_index_in_dim(out, buf, src, 0)
    return out


@register("all_reduce", "ring")
def ring_all_reduce(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Bandwidth-optimal ring: reduce-scatter + all-gather, 2(n-1)/n bytes/rank."""
    n = _axis_n(axis)
    if n == 1:
        return x
    chunk = ring_reduce_scatter(x, axis)
    full = ring_all_gather(chunk, axis).reshape(-1)
    return full[: x.size].reshape(x.shape).astype(x.dtype)


@register("all_reduce", "bidir_ring")
def bidir_ring_all_reduce(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Two counter-rotating rings, each carrying half the buffer — uses both link
    directions (the paper's LUMI bidirectional-ring observation, Sec. IV-C)."""
    n = _axis_n(axis)
    if n == 1:
        return x
    flat, pad = _pad_to(x, 2)
    half = flat.shape[0] // 2
    a, b = flat[:half], flat[half:]

    idx = lax.axis_index(axis)

    def one_ring(v, shift):
        nn = _axis_n(axis)
        fl, _ = _pad_to(v, nn)
        chunks = fl.reshape(nn, -1)
        buf = jnp.take(chunks, (idx + nn - 1) % nn if shift == 1 else (idx + 1) % nn, axis=0)
        for s in range(nn - 1):
            buf = lax.ppermute(buf, axis, _ring_perm(nn, shift))
            if shift == 1:
                take = (idx + nn - 2 - s) % nn if s < nn - 2 else idx
            else:
                take = (idx + 2 + s) % nn if s < nn - 2 else idx
            buf = buf + jnp.take(chunks, take, axis=0)
        gathered = ring_all_gather_dir(buf, axis, shift)
        return gathered.reshape(-1)[: v.size]

    ra = one_ring(a, 1)
    rb = one_ring(b, -1)
    out = jnp.concatenate([ra, rb])
    return out[: x.size].reshape(x.shape).astype(x.dtype)


def ring_all_gather_dir(chunk: jnp.ndarray, axis: str, shift: int) -> jnp.ndarray:
    n = _axis_n(axis)
    idx = lax.axis_index(axis)
    out = jnp.zeros((n,) + chunk.shape, chunk.dtype)
    out = lax.dynamic_update_index_in_dim(out, chunk, idx, 0)
    buf = chunk
    for s in range(n - 1):
        buf = lax.ppermute(buf, axis, _ring_perm(n, shift))
        src = (idx - shift * (s + 1)) % n
        out = lax.dynamic_update_index_in_dim(out, buf, src, 0)
    return out


# ----------------------------------------------------------------- rabenseifner
@register("all_reduce", "rabenseifner", pow2_only=True)
def rabenseifner_all_reduce(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Recursive halving reduce-scatter + recursive doubling all-gather
    (Rabenseifner [33]); n must be a power of two.  2(n-1)/n bytes, 2 log2 n steps."""
    n = _axis_n(axis)
    if n == 1:
        return x
    assert n & (n - 1) == 0, "rabenseifner requires power-of-two axis"
    idx = lax.axis_index(axis)
    flat, _ = _pad_to(x, n)
    m = flat.shape[0]
    work = flat
    lo = jnp.zeros((), jnp.int32)
    size = m
    dists = []
    d = n // 2
    while d >= 1:
        dists.append(d)
        d //= 2
    # reduce-scatter by recursive halving
    for d in dists:
        half = size // 2
        perm = [(i, i ^ d) for i in range(n)]
        keep_low = (idx & d) == 0
        send_start = lo + jnp.where(keep_low, half, 0)
        keep_start = lo + jnp.where(keep_low, 0, half)
        send = lax.dynamic_slice(work, (send_start,), (half,))
        recv = lax.ppermute(send, axis, perm)
        kept = lax.dynamic_slice(work, (keep_start,), (half,)) + recv
        work = lax.dynamic_update_slice(work, kept, (keep_start,))
        lo = keep_start
        size = half
    # all-gather by recursive doubling (reverse order)
    for d in reversed(dists):
        perm = [(i, i ^ d) for i in range(n)]
        send = lax.dynamic_slice(work, (lo,), (size,))
        recv = lax.ppermute(send, axis, perm)
        mine_high = (idx & d) != 0
        recv_start = lo + jnp.where(mine_high, -size, size)
        work = lax.dynamic_update_slice(work, recv, (recv_start,))
        lo = lo - jnp.where(mine_high, size, 0)
        size = size * 2
    return work[: x.size].reshape(x.shape).astype(x.dtype)


# ------------------------------------------------------- latency-optimal family
@register("all_reduce", "recursive_doubling", pow2_only=True)
def recursive_doubling_all_reduce(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """log2(n) full-buffer exchanges — latency-optimal for small messages."""
    n = _axis_n(axis)
    if n == 1:
        return x
    assert n & (n - 1) == 0, "recursive doubling requires power-of-two axis"
    acc = x
    d = 1
    while d < n:
        perm = [(i, i ^ d) for i in range(n)]
        acc = acc + lax.ppermute(acc, axis, perm)
        d *= 2
    return acc


@register("all_reduce", "tree", pow2_only=True)
def tree_all_reduce(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Binomial-tree reduce to rank 0 followed by binomial broadcast."""
    n = _axis_n(axis)
    if n == 1:
        return x
    assert n & (n - 1) == 0
    idx = lax.axis_index(axis)
    acc = x
    d = 1
    while d < n:  # reduce
        perm = [(i, i - d) for i in range(n) if i % (2 * d) == d]
        recv = lax.ppermute(acc, axis, perm)
        is_recv = (idx % (2 * d)) == 0
        acc = jnp.where(is_recv, acc + recv, acc)
        d *= 2
    d = n // 2
    while d >= 1:  # broadcast
        perm = [(i, i + d) for i in range(n) if i % (2 * d) == 0]
        recv = lax.ppermute(acc, axis, perm)
        is_recv = (idx % (2 * d)) == d
        acc = jnp.where(is_recv, recv, acc)
        d //= 2
    return acc


@register("all_reduce", "one_shot")
def one_shot_all_reduce(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """All-gather everything, reduce locally — the explicit device-device-copy
    analog (paper Sec. IV-D 'reduction on GPU 0 + broadcast' without pipelining)."""
    g = lax.all_gather(x, axis)  # (n, ...)
    return jnp.sum(g, axis=0).astype(x.dtype)


@register("all_reduce", "xla")
def xla_all_reduce(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """The *CCL analog: let the platform library schedule it."""
    return lax.psum(x, axis)


@register("reduce_scatter", "xla")
def xla_reduce_scatter(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Platform reduce-scatter; same contract as ring_reduce_scatter (flat chunk
    of the padded buffer, len = padded_size/n)."""
    n = _axis_n(axis)
    flat, _ = _pad_to(x, n)
    return lax.psum_scatter(flat, axis, scatter_dimension=0, tiled=True)


@register("all_gather", "xla")
def xla_all_gather(chunk: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Platform all-gather; same contract as ring_all_gather ((n,) + chunk.shape)."""
    return lax.all_gather(chunk, axis)


# ------------------------------------------------------------------- all-to-all
@register("all_to_all", "xla")
def all_to_all_direct(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """XLA all_to_all (the *CCL analog).  x: (n*k, ...) local rows; row block j
    goes to rank j; returns the n received blocks concatenated."""
    return lax.all_to_all(x, axis, split_axis=0, concat_axis=0, tiled=True)


@register("all_to_all", "pairwise")
def all_to_all_pairwise(x: jnp.ndarray, axis: str) -> jnp.ndarray:
    """Pairwise-exchange alltoall over ppermute rotations: n-1 steps, one peer in
    flight per step — the bounded-connection-state fix for the paper's Obs. 7
    (*CCL alltoall instability beyond 512 endpoints).  One-peer-in-flight is
    inherent to the rotation schedule, so no extra chunking knob is needed."""
    n = _axis_n(axis)
    idx = lax.axis_index(axis)
    rows = x.shape[0]
    assert rows % n == 0
    k = rows // n
    blocks = x.reshape(n, k, *x.shape[1:])
    out = jnp.zeros_like(blocks)
    own = jnp.take(blocks, idx, axis=0)
    out = lax.dynamic_update_index_in_dim(out, own, idx, 0)
    for s in range(1, n):
        # send the block destined to rank (idx + s); it travels s hops... use a
        # direct permutation instead: perm sending to (i+s) delivers in one step.
        perm = [(i, (i + s) % n) for i in range(n)]
        send = jnp.take(blocks, (idx + s) % n, axis=0)
        recv = lax.ppermute(send, axis, perm)  # from rank (idx - s)
        out = lax.dynamic_update_index_in_dim(out, recv, (idx - s) % n, 0)
    return out.reshape(x.shape)


# ------------------------------------------------------------------ hierarchical
@register("all_reduce", "hierarchical", multi_axis=True)
def hierarchical_all_reduce(x: jnp.ndarray, ici_axis: str, dcn_axis: str) -> jnp.ndarray:
    """Multi-pod allreduce: intra-pod reduce-scatter (ICI) -> inter-pod allreduce of
    the scattered shard (DCN, 1/n_ici of the bytes) -> intra-pod all-gather (ICI).
    This is the bandwidth-correct schedule when DCN << ICI (DESIGN.md Sec. 5)."""
    n = _axis_n(ici_axis)
    chunk = ring_reduce_scatter(x, ici_axis)
    chunk = lax.psum(chunk, dcn_axis)
    full = ring_all_gather(chunk, ici_axis).reshape(-1)
    return full[: x.size].reshape(x.shape).astype(x.dtype)


# ------------------------------------------------------------------------- p2p
def ping_pong(x: jnp.ndarray, axis: str, a: int = 0, b: int = 1, rounds: int = 1) -> jnp.ndarray:
    """Bounce a buffer a->b->a `rounds` times (the paper's p2p probe, Sec. III-C)."""
    n = _axis_n(axis)
    buf = x
    for _ in range(rounds):
        buf = lax.ppermute(buf, axis, [(a, b)])
        buf = lax.ppermute(buf, axis, [(b, a)])
    return buf


# ------------------------------------------------------------------- host path
def staged_host_all_reduce(shards: Sequence) -> list:
    """Trivial staging baseline (paper Sec. III-A): device->host copies, host-side
    reduction, host->device copies.  Store-and-forward, no pipelining; not jittable
    by design — used by benchmarks only."""
    import numpy as np

    host = [np.asarray(jax.device_get(s)) for s in shards]
    total = functools.reduce(lambda a_, b_: a_ + b_, host)
    return [jax.device_put(total, s.devices().pop() if hasattr(s, "devices") else None)
            for s in shards]


# Backward-compatible single-axis views over the registry (multi-axis variants
# like `hierarchical` dispatch through commplan/`registered` instead).
ALL_REDUCE_ALGOS = {n: s.fn for n, s in registered("all_reduce", multi_axis=False).items()}
ALL_TO_ALL_ALGOS = {n: s.fn for n, s in registered("all_to_all", multi_axis=False).items()}
REDUCE_SCATTER_ALGOS = {n: s.fn for n, s in registered("reduce_scatter", multi_axis=False).items()}
ALL_GATHER_ALGOS = {n: s.fn for n, s in registered("all_gather", multi_axis=False).items()}
