"""Hardware profiles: the paper's three systems + the TPU deployment target.

All bandwidths are stored in **bytes/second unidirectional** internally.  The paper
reports Gb/s (bits); helpers convert.  Latencies in seconds.

Paper sources (Table I, Figs. 1-2, Secs. II-V):
  - Alps:      4x GH200/node, NVLink4, 6x200 Gb/s links per GPU pair (1.2 Tb/s/pair),
               1x Cassini-1 200 Gb/s NIC per GPU, Slingshot-11 Dragonfly.
  - Leonardo:  4x A100/node, NVLink3, 4x200 Gb/s per pair (800 Gb/s/pair),
               4x100 Gb/s IB HDR ports per node (1 per GPU), Dragonfly+.
  - LUMI:      8 GCDs/node (4x MI250X), 1-4x 400 Gb/s IF links per GCD pair,
               1x Cassini-1 200 Gb/s NIC per module (100 Gb/s per GCD), Dragonfly.

TPU v5e target (per the roofline brief): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI, 4 ICI links per chip (2-D torus, 16x16 = 256-chip pod),
inter-pod DCN modeled at 25 Gb/s/chip (200 Gb/s host NIC shared by 8 chips).
"""
from __future__ import annotations

import dataclasses
from typing import Dict


def gbit(x: float) -> float:
    """Gigabits/s -> bytes/s."""
    return x * 1e9 / 8.0


def gbyte(x: float) -> float:
    """Gigabytes/s -> bytes/s."""
    return x * 1e9


@dataclasses.dataclass(frozen=True)
class MechanismLatency:
    """Small-message one-way latency (s) per data-movement mechanism (paper Fig. 3/7).

    The GDRCopy / CPU-load-store tier differences of Sec. III-C collapse into these
    constants on TPU (hosts cannot load/store HBM): see DESIGN.md 'what does not
    transfer'.
    """
    staging: float
    device_copy: float
    ccl: float
    mpi: float


@dataclasses.dataclass(frozen=True)
class SystemProfile:
    name: str
    endpoints_per_node: int
    # intra-node
    pair_bw: float                 # best-pair unidirectional bytes/s
    link_bw: float                 # single intra-node link, bytes/s
    links_per_endpoint: int        # simultaneously usable links
    host_staging_bw: float         # device<->host effective bytes/s (trivial staging)
    intra_latency: MechanismLatency
    # inter-node
    nic_bw: float                  # per-endpoint injection bytes/s
    inter_latency_same_switch: float
    inter_latency_same_group: float
    inter_latency_diff_group: float
    # noise (paper Sec. VI, Leonardo observations; 0 => structurally isolated)
    noise_goodput_frac_diff_group: float   # mean goodput multiplier across groups
    noise_lognorm_sigma: float             # latency tail heaviness
    compute_peak: float = 0.0              # FLOP/s (bf16) — 0 for paper systems
    hbm_bw: float = 0.0


ALPS = SystemProfile(
    name="alps",
    endpoints_per_node=4,
    pair_bw=gbit(1200.0),          # 6 x 200 Gb/s NVLink4
    link_bw=gbit(200.0),
    links_per_endpoint=18,         # 6 links x 3 peers
    host_staging_bw=gbit(300.0),
    intra_latency=MechanismLatency(staging=12e-6, device_copy=4e-6, ccl=5e-6, mpi=5e-6),
    nic_bw=gbit(200.0),
    inter_latency_same_switch=4.33e-6,
    inter_latency_same_group=4.9e-6,
    inter_latency_diff_group=5.56e-6,   # +28% (Obs. 6)
    noise_goodput_frac_diff_group=0.99,  # -1% goodput (Obs. 6)
    noise_lognorm_sigma=0.05,
)

LEONARDO = SystemProfile(
    name="leonardo",
    endpoints_per_node=4,
    pair_bw=gbit(800.0),           # 4 x 200 Gb/s NVLink3
    link_bw=gbit(200.0),
    links_per_endpoint=12,
    host_staging_bw=gbit(256.0),   # PCIe Gen4 x16
    intra_latency=MechanismLatency(staging=10e-6, device_copy=3e-6, ccl=6e-6, mpi=2.5e-6),
    nic_bw=gbit(100.0),
    inter_latency_same_switch=2.03e-6,
    inter_latency_same_group=3.0e-6,
    inter_latency_diff_group=4.23e-6,   # 2x (Obs. 6)
    noise_goodput_frac_diff_group=0.83,  # 395 -> 328 Gb/s (Obs. 6)
    noise_lognorm_sigma=0.45,            # p95 > 8us, max 132us tail
)

LUMI = SystemProfile(
    name="lumi",
    endpoints_per_node=8,          # 8 GCDs
    pair_bw=gbit(1600.0),          # GCD0<->1: 4 x 400 Gb/s IF
    link_bw=gbit(400.0),
    links_per_endpoint=6,          # 4 in-package + 2 external
    host_staging_bw=gbit(288.0),   # IF host link per GCD
    intra_latency=MechanismLatency(staging=9e-6, device_copy=4e-6, ccl=9e-6, mpi=3e-6),
    nic_bw=gbit(100.0),            # 200 Gb/s NIC shared by 2 GCDs
    inter_latency_same_switch=3.66e-6,
    inter_latency_same_group=4.2e-6,
    inter_latency_diff_group=4.7e-6,
    noise_goodput_frac_diff_group=0.99,
    noise_lognorm_sigma=0.05,
)

TPU_V5E = SystemProfile(
    name="tpu_v5e",
    endpoints_per_node=256,        # one pod slice = the "node" analog (single ICI domain)
    pair_bw=gbyte(50.0),           # one ICI link
    link_bw=gbyte(50.0),
    links_per_endpoint=4,          # 2-D torus: +x,-x,+y,-y
    host_staging_bw=gbyte(16.0),   # PCIe to host
    intra_latency=MechanismLatency(staging=20e-6, device_copy=1e-6, ccl=1e-6, mpi=1e-6),
    nic_bw=gbit(25.0),             # DCN: 200 Gb/s host NIC / 8 chips
    inter_latency_same_switch=10e-6,
    inter_latency_same_group=15e-6,
    inter_latency_diff_group=25e-6,
    noise_goodput_frac_diff_group=0.90,  # DCN is shared; ICI is single-tenant
    noise_lognorm_sigma=0.30,
    compute_peak=197e12,
    hbm_bw=819e9,
)

SYSTEMS: Dict[str, SystemProfile] = {p.name: p for p in (ALPS, LEONARDO, LUMI, TPU_V5E)}

# Roofline constants for the dry-run analysis (TPU v5e, per the brief).
PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_LINK_BW = 50e9
ICI_LINKS = 4
DCN_BW_PER_CHIP = gbit(25.0)
