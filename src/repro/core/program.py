"""StepProgram IR: one declarative description of a training step.

A StepProgram is a small typed sequence of schedule nodes that three layers
consume from the *same object*:

  * ``runtime.steps.build_program_step``     compiles it to the shard_map step
    (the legacy ``overlap=/zero=/compress_bits=/chunks=/microbatches=`` flag
    jungle is now a shim that normalizes through this IR);
  * ``core.costmodel.exposed_comm_time(program=...)``  prices it node-by-node
    (the stringly-typed ``schedule=`` branches are shimmed onto programs);
  * ``core.commplan.CommPlan.program``       persists it in plan JSON so
    dryrun, scenarios, and hillclimb all consume one artifact.

Node vocabulary (execution order within a program):

  MicrobatchLoop(n)        scan-carried gradient accumulation (needs overlap)
  Bucketize(bucket_bytes)  pack leaves into wire buckets; ``reverse=True`` is
                           the overlap engine's reverse-layer-order issue
                           schedule (bucket i reduces while bucket i+1's
                           backward still runs).  ``bucket_bytes=None`` means
                           the plan's latency/bandwidth crossover; a program
                           with no Bucketize node is the per-tensor wire.
  QuantizeWire(bits)       int8 error-feedback codec on the wire payload
  ChunkedPipeline(chunks)  double-buffered hierarchical pipeline depth
                           (``None`` = the plan's per-tier alpha-beta fit)
  AllReduce()              dense-gradient reduction (flat or hierarchical)
  ReduceScatter()          \
  ShardedOptimUpdate()      } the ZeRO three-phase schedule
  AllGather()              /
  AllToAll(role)           planned token dispatch/combine (expert parallelism)

Programs are plain frozen dataclasses with a JSON round-trip; no jax imports
here so commplan/costmodel can depend on this module freely.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

# --------------------------------------------------------------------- nodes


@dataclasses.dataclass(frozen=True)
class MicrobatchLoop:
    kind = "microbatch_loop"
    n: int = 1


@dataclasses.dataclass(frozen=True)
class Bucketize:
    kind = "bucketize"
    bucket_bytes: Optional[int] = None   # None = plan crossover
    reverse: bool = False                # True = overlap issue schedule


@dataclasses.dataclass(frozen=True)
class QuantizeWire:
    kind = "quantize_wire"
    bits: int = 8


@dataclasses.dataclass(frozen=True)
class ChunkedPipeline:
    kind = "chunked_pipeline"
    chunks: Optional[int] = None         # None = plan's per-tier fit


@dataclasses.dataclass(frozen=True)
class AllReduce:
    kind = "all_reduce"


@dataclasses.dataclass(frozen=True)
class ReduceScatter:
    kind = "reduce_scatter"


@dataclasses.dataclass(frozen=True)
class ShardedOptimUpdate:
    kind = "sharded_optim_update"


@dataclasses.dataclass(frozen=True)
class AllGather:
    kind = "all_gather"


@dataclasses.dataclass(frozen=True)
class AllToAll:
    kind = "all_to_all"
    role: str = "dispatch"               # "dispatch" | "combine"


NODE_TYPES = {
    cls.kind: cls
    for cls in (MicrobatchLoop, Bucketize, QuantizeWire, ChunkedPipeline,
                AllReduce, ReduceScatter, ShardedOptimUpdate, AllGather,
                AllToAll)
}


# ------------------------------------------------------------------- program


@dataclasses.dataclass(frozen=True)
class StepProgram:
    name: str
    nodes: Tuple[Any, ...] = ()

    # ------------------------------------------------------------- structure
    def node(self, kind: str):
        for nd in self.nodes:
            if nd.kind == kind:
                return nd
        return None

    def has(self, kind: str) -> bool:
        return self.node(kind) is not None

    @property
    def schedule(self) -> str:
        """Legacy schedule string this program corresponds to."""
        if self.has("all_to_all"):
            return "moe_alltoall"
        if self.has("sharded_optim_update"):
            return "zero"
        return "allreduce"

    def validate(self) -> "StepProgram":
        kinds = [nd.kind for nd in self.nodes]
        for k in kinds:
            if k not in NODE_TYPES:
                raise ValueError(f"unknown StepProgram node kind {k!r}")
        bz, qw = self.node("bucketize"), self.node("quantize_wire")
        mb = self.node("microbatch_loop")
        zero = self.has("sharded_optim_update")
        a2a = [nd for nd in self.nodes if nd.kind == "all_to_all"]
        if qw is not None and qw.bits != 8:
            raise ValueError(f"QuantizeWire.bits must be 8; got {qw.bits}")
        if mb is not None and mb.n > 1 and not (bz is not None and bz.reverse):
            raise ValueError(
                "MicrobatchLoop needs the overlap issue schedule "
                "(Bucketize(reverse=True)): explicit-DP microbatching is "
                "implemented by the overlap schedule")
        if (zero or (bz is not None and bz.reverse)) and \
                (bz is None or bz.bucket_bytes == 0):
            raise ValueError(
                "overlap/zero schedules need a bucketed carrier, not "
                "per-tensor wire (Bucketize with bucket_bytes != 0)")
        if zero:
            if not (self.has("reduce_scatter") and self.has("all_gather")):
                raise ValueError("ShardedOptimUpdate needs the full ZeRO "
                                 "phase sequence ReduceScatter -> "
                                 "ShardedOptimUpdate -> AllGather")
            if a2a:
                raise ValueError("AllToAll does not compose with the ZeRO "
                                 "schedule yet")
        elif self.has("reduce_scatter") or self.has("all_gather"):
            raise ValueError("ReduceScatter/AllGather outside the ZeRO "
                             "sequence (missing ShardedOptimUpdate)")
        if a2a:
            roles = sorted(nd.role for nd in a2a)
            if roles != ["combine", "dispatch"]:
                raise ValueError("an AllToAll program needs exactly one "
                                 f"dispatch and one combine node; got {roles}")
            if not self.has("all_reduce"):
                raise ValueError("an AllToAll program still needs an "
                                 "AllReduce node for the dense "
                                 "(router) gradients")
            if mb is not None and mb.n > 1:
                raise ValueError("MicrobatchLoop is not supported on the "
                                 "AllToAll (expert-parallel) path yet")
        elif not zero and not self.has("all_reduce"):
            raise ValueError("a training StepProgram needs a reduction: "
                             "AllReduce, the ZeRO sequence, or AllToAll")
        return self

    def expected_collectives(self) -> frozenset:
        """Jaxpr collective kinds a step compiled from this program may emit.

        The contract `analysis.expect` checks against: every schedule may
        psum (the loss pmean and the global-norm combine are psums), and the
        planned algorithm families add their wire primitives — ring/pairwise
        schedules lower to ppermute, the one-shot all-reduce to all_gather,
        the xla fallbacks to the direct primitive.  What is *absent* is the
        point: a reduce_scatter inside an allreduce program, or an all_to_all
        anywhere on the dense path, is an unplanned collective.
        """
        kinds = {"psum"}
        sched = self.schedule
        if sched == "zero":
            kinds |= {"reduce_scatter", "all_gather", "ppermute"}
        elif sched == "moe_alltoall":
            kinds |= {"all_to_all", "ppermute", "all_gather"}
        else:
            kinds |= {"ppermute", "all_gather"}
        return frozenset(kinds)

    # ----------------------------------------------------------------- JSON
    def to_dict(self) -> Dict[str, Any]:
        return {"name": self.name,
                "nodes": [{"kind": nd.kind, **dataclasses.asdict(nd)}
                          for nd in self.nodes]}

    @classmethod
    def from_dict(cls, blob: Dict[str, Any]) -> "StepProgram":
        nodes = []
        for nd in blob.get("nodes", ()):
            nd = dict(nd)
            kind = nd.pop("kind")
            if kind not in NODE_TYPES:
                raise ValueError(f"unknown StepProgram node kind {kind!r}")
            nodes.append(NODE_TYPES[kind](**nd))
        return cls(name=blob.get("name", "program"), nodes=tuple(nodes))

    # ------------------------------------------------------------- lowering
    def step_kwargs(self) -> Dict[str, Any]:
        """Lower to the explicit-DP engine's knobs.

        ``train_step_program(**program.step_kwargs())`` rebuilds an equivalent
        program — the round-trip the parity tests pin down.
        """
        mb, bz = self.node("microbatch_loop"), self.node("bucketize")
        qw, cp = self.node("quantize_wire"), self.node("chunked_pipeline")
        return dict(
            overlap=bool(bz is not None and bz.reverse),
            zero=self.has("sharded_optim_update"),
            compress_bits=qw.bits if qw is not None else 0,
            chunks=cp.chunks if cp is not None else None,
            microbatches=mb.n if mb is not None else 1,
            bucket_bytes=bz.bucket_bytes if bz is not None else 0,
        )


# ------------------------------------------------------------------ builders


def train_step_program(overlap: bool = False, zero: bool = False,
                       compress_bits: int = 0, chunks: Optional[int] = None,
                       microbatches: int = 1,
                       bucket_bytes: Optional[int] = None) -> StepProgram:
    """The dense-gradient training program for a legacy flag combination.

    Mirrors ``build_explicit_dp_step``'s defaulting exactly: with
    ``bucket_bytes=None`` the compress-only path stays per-tensor (legacy
    exact-tail wire) while every other mode buckets at the plan's crossover.
    """
    if compress_bits not in (0, 8):
        raise ValueError(f"compress_bits must be 0 or 8; got {compress_bits}")
    if bucket_bytes == 0:
        bucketed = False
    elif bucket_bytes is None:
        bucketed = not (compress_bits and not overlap and not zero)
    else:
        bucketed = True
    nodes = []
    if microbatches > 1:
        nodes.append(MicrobatchLoop(microbatches))
    if bucketed:
        nodes.append(Bucketize(bucket_bytes, reverse=bool(overlap)))
    if compress_bits:
        nodes.append(QuantizeWire(compress_bits))
    nodes.append(ChunkedPipeline(chunks))
    if zero:
        nodes += [ReduceScatter(), ShardedOptimUpdate(), AllGather()]
    else:
        nodes.append(AllReduce())
    name = "zero" if zero else ("overlap" if overlap else "allreduce")
    if compress_bits:
        name += "_int8"
    if microbatches > 1:
        name += f"_mb{microbatches}"
    if chunks is not None and chunks > 1:
        name += f"_chunked{chunks}"
    return StepProgram(name, tuple(nodes)).validate()


def moe_step_program(compress_bits: int = 0,
                     bucket_bytes: Optional[int] = None) -> StepProgram:
    """Expert-parallel MoE step: token dispatch/combine as planned AllToAll
    nodes, dense (router) gradients on the planned AllReduce."""
    nodes = [AllToAll("dispatch"), AllToAll("combine")]
    if bucket_bytes:
        nodes.append(Bucketize(bucket_bytes))
    if compress_bits:
        nodes.append(QuantizeWire(compress_bits))
    nodes.append(AllReduce())
    name = "moe_alltoall" + ("_int8" if compress_bits else "")
    return StepProgram(name, tuple(nodes)).validate()


NAMED_PROGRAMS = {
    "allreduce": lambda: train_step_program(),
    "overlap": lambda: train_step_program(overlap=True),
    "overlap_int8": lambda: train_step_program(overlap=True, compress_bits=8),
    "zero": lambda: train_step_program(zero=True),
    "zero_int8": lambda: train_step_program(zero=True, compress_bits=8),
    "moe_alltoall": lambda: moe_step_program(),
}


def named_program(name: str) -> StepProgram:
    if name not in NAMED_PROGRAMS:
        raise ValueError(f"unknown program {name!r} "
                         f"(have {sorted(NAMED_PROGRAMS)})")
    return NAMED_PROGRAMS[name]()
