"""Measured calibration loop: fit alpha-beta cost-model parameters from live sweeps.

The paper's workflow is measure-then-model (Sec. III-A feeds Secs. IV-VI): the
per-iteration benchmark distributions calibrate the alpha-beta models that
explain the at-scale figures.  This module closes that loop for the repo:

  1. **Sweep** — `run_calibration` drives the live characterization matrix
     (`characterize.characterize_mesh`) plus the pairwise-p2p concurrency sweep
     and the ServiceLevelArbiter congestion/incast scenarios on the current
     mesh;
  2. **Fit** — for every (mechanism, pattern, size-regime) group of
     `BenchRecord`s, least-squares-fit t(s) = alpha + s/B over the median
     per-iteration times (p2p medians are RTT, halved before fitting);
  3. **Persist** — the fits become a versioned `CalibrationProfile` JSON
     artifact (schema v1, sorted keys, exact float round-trip);
  4. **Apply** — `CommModel(..., calibration=profile)` replaces the
     `MECH_EFFICIENCY*` constants with measured efficiencies, and
     `CommPlan.from_topology(..., calibration=profile)` re-ranks the dispatch
     tables and the gradient bucket size from measured goodput.

Size regimes follow the harness's iteration-count boundary: `small` <= 64 KiB
(latency-dominated), `large` above it (bandwidth-dominated).
"""
from __future__ import annotations

import dataclasses
import json
import math
from collections import defaultdict
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .bench import SMALL_MAX_BYTES, BenchRecord, gbps
from .characterize import (characterize_mesh, congestion_sweep,
                           inter_tier_p2p_sweep, pairwise_p2p_sweep)
from .commplan import SIZE_CLASSES, CommPlan
from .costmodel import CommModel, make_comm_model

SCHEMA_VERSION = 1


def size_regime(nbytes: int) -> str:
    return "small" if nbytes <= SMALL_MAX_BYTES else "large"


def _key(mechanism: str, pattern: str, regime: str,
         tier: Optional[str] = None) -> str:
    """Fit-group key.  Tier-qualified keys (`mech/pattern/regime@tier`) hold
    inter-node fits per fabric distance class (same_switch / same_group /
    diff_group); untiered keys are the intra-node fits of schema v1."""
    base = f"{mechanism}/{pattern}/{regime}"
    return f"{base}@{tier}" if tier else base


def split_key(key: str) -> Tuple[str, str, str, Optional[str]]:
    """Inverse of `_key`: (mechanism, pattern, regime, tier-or-None)."""
    mechanism, pattern, rest = key.split("/", 2)
    regime, _, tier = rest.partition("@")
    return mechanism, pattern, regime, tier or None


@dataclasses.dataclass(frozen=True)
class FittedParams:
    """One alpha-beta fit: t(s) = alpha + s / bandwidth."""

    alpha: float        # seconds
    bandwidth: float    # bytes/s effective
    r2: float           # goodness of fit on the fitted points
    n_samples: int
    min_bytes: int
    max_bytes: int

    def predict(self, nbytes: float) -> float:
        return self.alpha + (nbytes / self.bandwidth if self.bandwidth > 0 else 0.0)


def fit_alpha_beta(points: Sequence[Tuple[float, float]]) -> FittedParams:
    """Least-squares fit of t = alpha + s/B over (bytes, seconds) points.

    Degenerate inputs get conservative fallbacks: a single point attributes the
    whole time to both terms (alpha = t, B = s/t); a non-positive slope (noise)
    keeps the best observed goodput as B and the fastest time as alpha.
    """
    pts = sorted((float(s), float(t)) for s, t in points)
    if not pts:
        raise ValueError("fit_alpha_beta needs at least one (bytes, seconds) point")
    s = np.array([p[0] for p in pts])
    t = np.array([p[1] for p in pts])
    if len(pts) == 1 or np.ptp(s) == 0:
        alpha = float(t.mean())
        bw = float(s[0] / t.mean()) if t.mean() > 0 else 0.0
        return FittedParams(alpha, bw, 0.0, len(pts), int(s.min()), int(s.max()))
    slope, intercept = np.polyfit(s, t, 1)
    if slope <= 0:
        alpha = float(t.min())
        bw = float((s / t).max())
    elif intercept < 0:
        # refit through the origin: all time is bandwidth
        alpha = 0.0
        bw = float((s * s).sum() / (s * t).sum())
    else:
        alpha = float(intercept)
        bw = float(1.0 / slope)
    pred = alpha + s / bw if bw > 0 else np.full_like(t, alpha)
    ss_res = float(((t - pred) ** 2).sum())
    ss_tot = float(((t - t.mean()) ** 2).sum())
    r2 = 1.0 - ss_res / ss_tot if ss_tot > 0 else 1.0
    return FittedParams(alpha, bw, max(r2, 0.0), len(pts), int(s.min()), int(s.max()))


@dataclasses.dataclass
class CalibrationProfile:
    """Versioned artifact of measured (mechanism, pattern, regime) fits."""

    version: int
    system: str
    topology: str
    n_endpoints: int
    params: Dict[str, FittedParams]
    meta: Dict[str, str] = dataclasses.field(default_factory=dict)

    def get(self, mechanism: str, pattern: str,
            regime: Optional[str] = None,
            tier: Optional[str] = None) -> Optional[FittedParams]:
        """Fit for (mechanism, pattern[, regime][, tier]); without a regime,
        prefer the bandwidth-dominated 'large' fit, falling back to 'small'.
        A tier asks for the tier-qualified inter-node fit only (no silent
        fallback to the intra fit — callers decide that)."""
        if regime is not None:
            return self.params.get(_key(mechanism, pattern, regime, tier))
        return (self.params.get(_key(mechanism, pattern, "large", tier))
                or self.params.get(_key(mechanism, pattern, "small", tier)))

    def efficiency(self, mechanism: str, pattern: str, nominal_bw: float,
                   regime: str = "large",
                   tier: Optional[str] = None) -> Optional[float]:
        """Measured effective bandwidth as a fraction of `nominal_bw`."""
        fp = self.get(mechanism, pattern, regime, tier)
        if fp is None or nominal_bw <= 0 or fp.bandwidth <= 0:
            return None
        return fp.bandwidth / nominal_bw

    # ---------------------------------------------------------- persistence
    def to_blob(self) -> Dict:
        return {
            "schema_version": self.version,
            "system": self.system,
            "topology": self.topology,
            "n_endpoints": self.n_endpoints,
            "params": {k: dataclasses.asdict(v) for k, v in sorted(self.params.items())},
            "meta": self.meta,
        }

    @classmethod
    def from_blob(cls, blob: Dict) -> "CalibrationProfile":
        version = int(blob.get("schema_version", 0))
        if version != SCHEMA_VERSION:
            raise ValueError(f"unsupported calibration schema v{version} "
                             f"(this build reads v{SCHEMA_VERSION})")
        params = {k: FittedParams(**p) for k, p in blob.get("params", {}).items()}
        return cls(version=version, system=str(blob.get("system", "")),
                   topology=str(blob.get("topology", "")),
                   n_endpoints=int(blob.get("n_endpoints", 0)),
                   params=params, meta=dict(blob.get("meta", {})))

    def save(self, path: str) -> None:
        # sorted keys + repr floats => byte-identical across save/load/save
        with open(path, "w") as f:
            json.dump(self.to_blob(), f, indent=2, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "CalibrationProfile":
        with open(path) as f:
            return cls.from_blob(json.load(f))


# ----------------------------------------------------------------------- fit
def fit_profile(records: Sequence[BenchRecord], system: str = "tpu_v5e",
                topology: str = "", n_endpoints: int = 0,
                meta: Optional[Dict[str, str]] = None) -> CalibrationProfile:
    """Group records by (mechanism, pattern, size regime) and fit each group.

    p2p records carry ping-pong RTTs; the one-way time (RTT/2) is what the
    alpha-beta model predicts, so they are halved before fitting.  Records
    tagged with a fabric `tier` fit into tier-qualified groups (the inter-node
    distance classes), separate from the untiered intra fits.
    """
    groups: Dict[Tuple[str, str, str, Optional[str]],
                 List[Tuple[float, float]]] = defaultdict(list)
    for r in records:
        if not r.stats.times:
            continue
        t = r.stats.median
        if r.pattern == "p2p":
            t /= 2.0
        if t <= 0:
            continue
        tier = getattr(r, "tier", None)
        groups[(r.mechanism, r.pattern, size_regime(r.nbytes), tier)].append(
            (float(r.nbytes), float(t)))
        n_endpoints = max(n_endpoints, r.n_endpoints)
    params = {_key(m, p, g, tier): fit_alpha_beta(pts)
              for (m, p, g, tier), pts in groups.items()}
    return CalibrationProfile(SCHEMA_VERSION, system, topology, n_endpoints,
                              params, dict(meta or {}))


# ---------------------------------------------------------------------- sweep
def run_calibration(mesh, axis: str = "x",
                    sizes: Sequence[int] = (1 << 10, 1 << 14, 1 << 18, 1 << 22),
                    iters: int = 10,
                    model: Optional[CommModel] = None,
                    system: str = "tpu_v5e",
                    base_records: Optional[Sequence[BenchRecord]] = None,
                    fabric: Optional[object] = None,
                    ) -> Tuple[CalibrationProfile, List[BenchRecord]]:
    """Run the full calibration sweep on a live mesh and fit a profile.

    `base_records` lets callers reuse an existing `characterize_mesh` run; the
    pairwise-p2p and congestion scenarios always run fresh.  With a `fabric`
    (a `topology.Fabric`; defaults to the model's), the per-distance-tier p2p
    sweep runs too, producing tier-qualified fit keys (`mech/p2p/*@tier`) so
    the measured loop covers the inter tiers, not just intra.
    Returns (profile, all records that fed the fit).
    """
    model = model or make_comm_model(system)
    if base_records is None:
        base_records = characterize_mesh(mesh, axis, sizes=sizes, iters=iters,
                                         model=model).records
    records = list(base_records)
    records += pairwise_p2p_sweep(mesh, axis, sizes=tuple(sizes), iters=iters)
    if fabric is not None:
        records += inter_tier_p2p_sweep(mesh, axis, fabric, sizes=tuple(sizes),
                                        iters=iters)
    records += congestion_sweep(records)
    profile = fit_profile(records, system=model.profile.name,
                          topology=model.graph.name,
                          n_endpoints=mesh.shape[axis],
                          meta={"axis": axis,
                                "sizes": ",".join(str(s) for s in sizes),
                                "iters": str(iters)})
    return profile, records


# ------------------------------------------------------------------ reporting
_PROBE_BYTES = {"small": 4096, "large": 1 << 22}


def compare_to_model(profile: CalibrationProfile, model: CommModel) -> List[Dict]:
    """Analytic-vs-measured delta per fitted key, at one probe size per regime.
    Tier-qualified keys compare against the model's inter-node path at that
    distance tier."""
    n = max(profile.n_endpoints, 2)
    rows: List[Dict] = []
    for key, fp in sorted(profile.params.items()):
        mech, pattern, regime, tier = split_key(key)
        s = float(_PROBE_BYTES[regime])
        try:
            if pattern in ("p2p", "p2p_concurrent", "p2p_congested"):
                analytic = (model.p2p(s, mech, inter_node=True, distance=tier)
                            if tier else model.p2p(s, mech)).seconds
            elif pattern == "allreduce":
                analytic = model.allreduce_intra(s, mech, n=n).seconds
            elif pattern == "alltoall":
                analytic = model.alltoall_intra(s, mech, n=n).seconds
            else:
                continue
        except (KeyError, AttributeError):
            continue
        measured = fp.predict(s)
        rows.append({
            "key": key, "alpha_us": fp.alpha * 1e6, "bw_gbps": gbps(fp.bandwidth),
            "r2": fp.r2, "n_samples": fp.n_samples,
            "measured_us": measured * 1e6, "analytic_us": analytic * 1e6,
            "ratio": measured / analytic if analytic > 0 else math.inf,
        })
    return rows


def plan_table_deltas(analytic: CommPlan, calibrated: CommPlan,
                      sizes: Sequence[int] = tuple(SIZE_CLASSES)) -> List[str]:
    """(op, axis-size, payload) entries where the calibrated plan disagrees
    with the analytic one — the observable effect of the measured profile."""
    tables = (
        ("all_reduce", analytic.all_reduce_table, calibrated.all_reduce_table),
        ("all_to_all", analytic.all_to_all_table, calibrated.all_to_all_table),
        ("reduce_scatter", analytic.reduce_scatter_table, calibrated.reduce_scatter_table),
        ("all_gather", analytic.all_gather_table, calibrated.all_gather_table),
    )
    diffs: List[str] = []
    for op, ta, tc in tables:
        for n in sorted(set(ta) & set(tc)):
            for s in sizes:
                a = CommPlan.lookup(ta, s, n)
                c = CommPlan.lookup(tc, s, n)
                if a != c:
                    diffs.append(f"{op}/n{n}/{s}B: {a} -> {c}")
    return diffs
