"""Alpha-beta collective cost models over system profiles (paper Secs. IV-VI).

Time models per algorithm (n endpoints, s bytes per endpoint, alpha latency,
B bytes/s effective bandwidth):

  p2p                 : alpha + s/B
  ring allreduce      : 2(n-1) alpha + 2 s (n-1)/n / B          (bw-optimal)
  rabenseifner        : 2 log2(n) alpha + 2 s (n-1)/n / B       (RS + AG)
  recursive doubling  : log2(n) alpha + s log2(n) / B           (latency-opt)
  binomial tree       : 2 log2(n) alpha + 2 s / B               (pipelined reduce+bcast)
  one-shot            : alpha + (n-1) s / B                     (all-gather + local)
  alltoall direct     : (n-1) alpha + (n-1) s_pp / B            (s_pp per peer)
  alltoall pairwise   : (n-1)(alpha + s_pp / B)                 (chunk-bounded)

Effective bandwidth B comes from `topology` (expected goodput given the link graph),
and the large-scale regime uses the asymptotic per-endpoint inter-node bandwidth
(paper Sec. V-C).  Mechanism-dependent constants (staging / device copy / *CCL / MPI)
come from `hw.SystemProfile` — they encode the software-layer observations (Obs. 2,
4, 5, 7): *CCL-like stacks pay a kernel-launch alpha but win on intra-node bandwidth;
MPI-like stacks win small-message latency; staging is store-and-forward.

The `MECH_EFFICIENCY*` tables below are paper-derived *defaults*: a measured
`calibrate.CalibrationProfile` passed to `CommModel(..., calibration=...)`
replaces them (and the intra-node alphas) with live fits from this machine.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Dict, Optional

from . import hw
from .topology import Fabric, INTER_TIERS, LinkGraph, TwoLevelTopology

LOG2 = lambda n: max(1, int(math.ceil(math.log2(max(n, 2)))))

# Mechanism-level bandwidth efficiency (fraction of nominal the software achieves),
# calibrated on the paper's Figs. 3-6: device-copy/MPI ~70% of nominal on LUMI
# (Sec. III-D), *CCL ~80-95% on large intra-node collectives, staging is
# store-and-forward limited by host bw.
MECH_EFFICIENCY = {
    "staging": 0.9,      # of host_staging_bw, store-and-forward counted separately
    "device_copy": 0.70,
    "ccl": 0.70,
    "mpi": 0.75,         # Obs 2: GPU-aware MPI has the best intra-node p2p goodput
}

# Inter-node point-to-point (Fig. 7 / Obs. 5): MPI outperforms *CCL at every
# size — up to 3x on large transfers (kernel-launch + channel chunking overheads).
MECH_EFFICIENCY_P2P_INTER = {
    "staging": 0.9,
    "device_copy": 0.60,
    "ccl": 0.35,
    "mpi": 0.90,
}

# Collective-pattern bandwidth efficiency (Obs. 4 / Fig. 11): *CCL collectives are
# topology-tuned; MPI collectives do not exploit the intra-node fabric (RCCL up to
# 4x faster on large vectors on LUMI).
MECH_EFFICIENCY_COLLECTIVE = {
    "staging": 0.9,
    "device_copy": 0.50,
    "ccl": 0.85,
    "mpi": 0.22,
}

# *CCL kernel management overhead per operation (paper Obs. 5: up to 10x on small
# inter-node transfers; kernel launch + channel setup floors).
CCL_KERNEL_ALPHA = 8e-6
CCL_SMALL_FLOOR = 25e-6


@dataclasses.dataclass
class CollectiveCost:
    seconds: float
    bytes_on_wire: float

    def goodput(self, payload_bytes: float) -> float:
        return payload_bytes / self.seconds if self.seconds > 0 else float("inf")


class CommModel:
    """Cost model for one system (intra 'node'/pod graph + inter fabric).

    With `calibration` (a `calibrate.CalibrationProfile`), the hard-coded
    `MECH_EFFICIENCY*` fractions and intra-node alpha constants are replaced by
    the measured fits wherever the profile covers them: per-mechanism p2p fits
    override pair bandwidth efficiency and intra latency; per-mechanism
    allreduce/alltoall fits override the collective efficiencies (clamped to
    <= 1.0 of the topology bound — the bound is physical)."""

    def __init__(self, profile: hw.SystemProfile, node_graph: LinkGraph,
                 two_level: Optional[TwoLevelTopology] = None,
                 calibration: Optional[object] = None,
                 fabric: Optional[Fabric] = None):
        self.profile = profile
        self.graph = node_graph
        self.two_level = two_level
        self.fabric = fabric if fabric is not None else (
            two_level.fabric if two_level is not None else None)
        self.calibration = calibration
        self._eff_pair = dict(MECH_EFFICIENCY)
        self._eff_inter = dict(MECH_EFFICIENCY_P2P_INTER)
        self._eff_coll_ar = dict(MECH_EFFICIENCY_COLLECTIVE)
        self._eff_coll_a2a = dict(MECH_EFFICIENCY_COLLECTIVE)
        self._alpha_intra: Dict[str, float] = {}
        self._alpha_inter: Dict[tuple, float] = {}      # (mech, tier) -> seconds
        self._eff_inter_tier: Dict[tuple, float] = {}   # (mech, tier) -> fraction
        if calibration is not None:
            self._apply_calibration(calibration)

    def _apply_calibration(self, cal) -> None:
        clamp = lambda x: min(max(x, 1e-4), 1.0)
        for mech in self._eff_pair:
            eff = cal.efficiency(mech, "p2p", self.profile.pair_bw)
            if eff is not None:
                self._eff_pair[mech] = clamp(eff)
            fa = cal.get(mech, "p2p", "small")
            if fa is not None and fa.alpha > 0:
                self._alpha_intra[mech] = fa.alpha
        # inter-node path: the measured p2p fit replaces the paper-derived
        # MECH_EFFICIENCY_P2P_INTER fraction (previously the profile was
        # silently ignored here), and tier-qualified fits (mech/p2p/*@tier,
        # from an inter-tier sweep) refine it per distance class.
        for mech in self._eff_inter:
            eff = cal.efficiency(mech, "p2p", self.profile.nic_bw)
            if eff is not None:
                self._eff_inter[mech] = clamp(eff)
            for tier in INTER_TIERS:
                eff_t = cal.efficiency(mech, "p2p", self.profile.nic_bw, tier=tier)
                if eff_t is not None:
                    self._eff_inter_tier[(mech, tier)] = clamp(eff_t)
                fa = cal.get(mech, "p2p", "small", tier=tier)
                if fa is not None and fa.alpha > 0:
                    self._alpha_inter[(mech, tier)] = fa.alpha
        ar_bound = self.graph.allreduce_expected_goodput()
        a2a_bound = self.graph.alltoall_expected_goodput()
        for mech in MECH_EFFICIENCY_COLLECTIVE:
            ar = cal.efficiency(mech, "allreduce", ar_bound)
            if ar is not None:
                self._eff_coll_ar[mech] = clamp(ar)
            a2a = cal.efficiency(mech, "alltoall", a2a_bound)
            if a2a is not None:
                self._eff_coll_a2a[mech] = clamp(a2a)

    # ----- mechanism plumbing ------------------------------------------------
    def _tier_for(self, n_endpoints: int) -> str:
        """Distance tier an n-endpoint job spans, from the fabric (falls back
        to the conservative diff_group when no fabric is attached)."""
        if self.fabric is not None:
            tier = self.fabric.tier_for_scale(n_endpoints)
            return "same_switch" if tier == "same_node" else tier
        return "diff_group"

    def _alpha(self, mechanism: str, inter_node: bool,
               distance: Optional[str] = "same_switch") -> float:
        p = self.profile
        if inter_node:
            if distance is None:
                distance = "diff_group"
            if (mechanism, distance) in self._alpha_inter:
                # measured end-to-end: the fit already pays kernel-launch /
                # staging overheads, so no adders on top
                return self._alpha_inter[(mechanism, distance)]
            base = {
                "same_switch": p.inter_latency_same_switch,
                "same_group": p.inter_latency_same_group,
                "diff_group": p.inter_latency_diff_group,
            }[distance]
            if mechanism == "ccl":
                base += CCL_KERNEL_ALPHA
            if mechanism == "staging":
                base += 10e-6
            return base
        if mechanism in self._alpha_intra:
            return self._alpha_intra[mechanism]
        lat = p.intra_latency
        return getattr(lat, mechanism)

    def _inter_nic_bw(self, distance: str) -> float:
        """Per-endpoint inter-node bandwidth at a distance tier: the NIC,
        capped by the fabric tier bound (fat-tree taper, dragonfly global
        links)."""
        if self.fabric is not None:
            return min(self.profile.nic_bw, self.fabric.tier_bw(distance))
        return self.profile.nic_bw

    def _bw(self, mechanism: str, inter_node: bool,
            distance: Optional[str] = None) -> float:
        p = self.profile
        if mechanism == "staging":
            return p.host_staging_bw * MECH_EFFICIENCY["staging"]
        if inter_node:
            tier = distance or "diff_group"
            eff = self._eff_inter_tier.get((mechanism, tier),
                                           self._eff_inter[mechanism])
            return self._inter_nic_bw(tier) * eff
        return p.pair_bw * self._eff_pair[mechanism]

    # ----- point-to-point (Figs. 3, 7, 8) ------------------------------------
    def p2p(self, s: float, mechanism: str = "mpi", inter_node: bool = False,
            distance: str = "same_switch",
            endpoints: Optional[tuple] = None) -> CollectiveCost:
        """Point-to-point cost.  `distance` names the tier explicitly; passing
        an `endpoints` pair instead classifies it on the attached fabric."""
        if endpoints is not None and self.fabric is not None:
            tier = self.fabric.distance(*endpoints)
            inter_node = tier != "same_node"
            distance = "same_switch" if tier == "same_node" else tier
        a = self._alpha(mechanism, inter_node, distance)
        if mechanism == "staging":
            # store-and-forward: dev->host, host->host (or NIC), host->dev
            t = a + s / (self.profile.host_staging_bw * 0.9) * 2 \
                + s / self._bw("mpi", inter_node, distance)
            return CollectiveCost(t, 3 * s)
        t = a + s / self._bw(mechanism, inter_node, distance)
        return CollectiveCost(t, s)

    # ----- intra-node collectives (Figs. 5, 6) --------------------------------
    def allreduce_intra(self, s: float, mechanism: str = "ccl", algorithm: str = "auto",
                        n: Optional[int] = None) -> CollectiveCost:
        n = n or self.graph.n
        a = self._alpha(mechanism, False)
        if mechanism == "staging":
            # store-and-forward through the host: the algorithm dispatch below
            # is irrelevant (and used to be computed then discarded) — return
            # the staging formula before consulting it
            t = a + 2 * n * s / (self.profile.host_staging_bw * 0.9)
            return CollectiveCost(t, 2 * s * (n - 1) / n)
        eff = self._eff_coll_ar.get(mechanism, 0.5)
        peak = self.graph.allreduce_expected_goodput() * eff
        floor = CCL_SMALL_FLOOR if mechanism == "ccl" else 0.0
        if algorithm == "auto":
            algorithm = "rabenseifner" if s >= 32 * 1024 else "recursive_doubling"
        if algorithm in ("ring", "rabenseifner"):
            steps = 2 * (n - 1) if algorithm == "ring" else 2 * LOG2(n)
            t = steps * a + 2.0 * s * (n - 1) / n / peak
        elif algorithm == "recursive_doubling":
            t = LOG2(n) * a + s * LOG2(n) / (self.graph.pair_bw(0, 1) * eff)
        elif algorithm == "tree":
            t = 2 * LOG2(n) * a + 2.0 * s / peak
        elif algorithm == "one_shot":
            t = a + (n - 1) * s / (self.graph.injection_bw(0) * eff)
        else:
            raise ValueError(algorithm)
        t = max(t, floor)
        return CollectiveCost(t, 2 * s * (n - 1) / n)

    def alltoall_intra(self, s_total: float, mechanism: str = "ccl",
                       n: Optional[int] = None) -> CollectiveCost:
        """s_total: bytes each endpoint sends in total (paper's 'buffer size')."""
        n = n or self.graph.n
        a = self._alpha(mechanism, False)
        eff = self._eff_coll_a2a.get(mechanism, 0.5)
        peak = self.graph.alltoall_expected_goodput() * eff
        if mechanism == "staging":
            return CollectiveCost(a + 2 * n * s_total / (self.profile.host_staging_bw * 0.9), 2 * n * s_total)
        t = (n - 1) * a + s_total / peak
        return CollectiveCost(t, s_total)

    # ----- at-scale collectives (Figs. 9, 10, 13) -----------------------------
    def alltoall_at_scale(self, s_total: float, n_endpoints: int, mechanism: str = "ccl",
                          noise: float = 0.0) -> CollectiveCost:
        """Asymptotic model of Sec. V-C: inter-node bandwidth per endpoint bounds the
        goodput; the intra-node fraction (n_node-1)/(n-1) is served at intra speed."""
        p = self.profile
        nn = p.endpoints_per_node
        tier = self._tier_for(n_endpoints)
        a = self._alpha(mechanism, True, tier)
        eff = self._eff_coll_a2a.get(mechanism, 0.5)
        if n_endpoints <= nn:
            return self.alltoall_intra(s_total, mechanism, n_endpoints)
        frac_inter = (n_endpoints - nn) / (n_endpoints - 1)
        bw_inter = self._inter_nic_bw(tier) * eff * (1.0 - noise)
        bw_intra = self.graph.alltoall_expected_goodput() * eff
        t = (n_endpoints - 1) * a / 50.0  # pipelined connection setup, amortized
        t += s_total * frac_inter / bw_inter + s_total * (1 - frac_inter) / bw_intra
        # *CCL instability (Obs. 7): connection state grows linearly with endpoints
        if mechanism == "ccl" and n_endpoints > 4096:
            t = float("inf")
        return CollectiveCost(t, s_total)

    def allreduce_at_scale(self, s: float, n_endpoints: int, mechanism: str = "ccl",
                           noise: float = 0.0) -> CollectiveCost:
        p = self.profile
        nn = p.endpoints_per_node
        if n_endpoints <= nn:
            return self.allreduce_intra(s, mechanism)
        eff = self._eff_coll_ar.get(mechanism, 0.5)
        tier = self._tier_for(n_endpoints)
        a = self._alpha(mechanism, True, tier)
        # hierarchical: intra reduce-scatter, inter ring over n_nodes, intra
        # allgather.  Nodes are counted with ceil division: 12 endpoints on
        # 8-GPU nodes span 2 nodes, so the inter phase exists (floor made it
        # vanish for any non-multiple endpoint count).
        n_nodes = -(-n_endpoints // nn)
        intra = self.allreduce_intra(s, mechanism).seconds
        bw_inter = self._inter_nic_bw(tier) * eff * (1.0 - noise)
        inter = 2 * (n_nodes - 1) * a / 10.0 + 2.0 * (s / nn) * (n_nodes - 1) / n_nodes / bw_inter
        if mechanism == "mpi" and self.profile.name == "leonardo":
            # Open MPI v4 runs the reduction on the host (Sec. IV-D)
            inter += 2 * n_endpoints / nn * s / (p.host_staging_bw * 0.9) / 10
        return CollectiveCost(intra + inter, 2 * s)


# ----- overlap-aware step-time prediction (Sec. VI, Obs. 1) -----------------
@dataclasses.dataclass(frozen=True)
class OverlapEstimate:
    """Prediction of how much gradient-reduction time a backward pass hides."""

    compute_s: float
    total_comm_s: float      # wire time of all buckets, unhidden
    exposed_s: float         # comm the step actually waits on
    step_s: float            # max(compute, last bucket drain)
    hidden_fraction: float   # 1 - exposed/total (0 = fully exposed blob)
    n_buckets: int
    chunks: int              # hierarchical pipeline depth used
    wire: str = "fp32/fp32"  # intra/inter wire formats the estimate priced
    schedule: str = "allreduce"  # "allreduce" or "zero" (RS / update / AG)


def pipeline_params_at_scale(model: CommModel, n_endpoints: int,
                             mechanism: str = "ccl"):
    """Per-tier alpha-beta constants of the hierarchical pipeline at a given
    scale, from the cost model (calibration-aware through `_alpha`/`_eff_*`)."""
    from .overlap import PipelineParams

    tier = model._tier_for(n_endpoints)
    eff = model._eff_coll_ar.get(mechanism, 0.5)
    return PipelineParams(
        n_ici=model.graph.n,
        alpha_ici=model._alpha(mechanism, False),
        bw_ici=model.graph.allreduce_expected_goodput() * eff,
        alpha_dcn=model._alpha(mechanism, True, tier),
        bw_dcn=model._inter_nic_bw(tier) * eff,
    )


def wire_seconds(ici_bytes: float, dcn_bytes: float = 0.0,
                 bw_ici: Optional[float] = None,
                 bw_dcn: Optional[float] = None) -> float:
    """Seconds to move per-device wire bytes at the flat roofline bandwidths.

    The pricing hook the static HLO scheduler (`analysis.schedule`) uses:
    ICI traffic at the full link budget (`hw.ICI_LINK_BW * hw.ICI_LINKS`),
    DCN traffic at the per-chip NIC share (`hw.DCN_BW_PER_CHIP`).  This is
    deliberately alpha-free — the static estimate prices the *schedule
    shape* (what the compiled stream can hide), not a latency-accurate
    step time; `exposed_comm_time` remains the calibrated predictor.
    """
    bw_ici = bw_ici or (hw.ICI_LINK_BW * hw.ICI_LINKS)
    bw_dcn = bw_dcn or hw.DCN_BW_PER_CHIP
    return ici_bytes / bw_ici + dcn_bytes / bw_dcn


def exposed_comm_time(compute_time: float, plan, sizes,
                      n_endpoints: Optional[int] = None,
                      model: Optional[CommModel] = None,
                      chunks: Optional[int] = None,
                      mechanism: str = "ccl",
                      wire=None,
                      schedule: str = "allreduce",
                      program=None) -> OverlapEstimate:
    """Overlap-aware step-time predictor for the explicit-DP gradient path.

    `sizes` are the per-tensor gradient byte counts in forward layer order;
    `plan` supplies the bucket size (and, when hierarchical, the pipeline
    depth).  Buckets are scheduled exactly like the runtime engine
    (`core.overlap`): reverse layer order, bucket i's gradients materialize at
    `compute_time * cum_frac_i` of the backward, and the comm stream is serial
    — exposed time is whatever drains past the end of backward.  Beyond the
    node/pod boundary each bucket pays the chunked hierarchical pipeline time
    (`overlap.pipeline_time`); inside it, the intra-node collective model.

    `wire` prices compression (core.wire): None keeps the fp32 wire (the
    uncompressed runtime default), ``"plan"`` takes the plan's persisted
    per-tier wire decision, or pass a `wire.WireSpec` / ``{"intra": ...,
    "inter": ...}`` dict directly.  The intra tier is priced at the *realized*
    wire cost (`wire.realized_multiplier`: int8 is the gather wire, n/8 of the
    fp32 allreduce bytes, not the idealized 0.25); the inter tier keeps the
    idealized format ratio — the runtime's inter leg stays fp32 today, so the
    inter figure is the planning bound, reported by dryrun next to the fp32
    realization.  Alpha terms stay put either way.

    `schedule="zero"` prices the three-phase ZeRO path (reduce-scatter ->
    sharded update -> all-gather) instead of the allreduce: the RS leg always
    moves fp32 gradients, and only the AG (param return) leg pays the wire
    format — at the *idealized* multiplier, because a shard all-gather moves
    each 1/n shard exactly once (`realized_multiplier` is an allreduce-vs-
    gather artifact and does not apply).  Hierarchical plans price it with
    `overlap.zero_pipeline_time` (per-stage alpha-beta with the inter hop
    carrying one RS and one AG share); flat plans as half an fp32 allreduce
    plus half an allreduce at the AG wire — a ring allreduce *is* RS + AG, so
    each leg costs half of it at its own format.

    `program=` prices a `core.program.StepProgram` node-by-node — the *same
    object* `runtime.steps.build_program_step` compiles, so the runtime and
    the roofline can no longer drift.  The legacy `schedule=` strings are a
    shim: internally they build the equivalent program.  A program's
    `QuantizeWire` node implies the runtime's realizable wire (intra int8,
    inter fp32 — except the ZeRO AG leg, which carries int8 on both tiers),
    its `ChunkedPipeline` node the pipeline depth, and an explicit
    `Bucketize.bucket_bytes` overrides the plan's crossover; an explicit
    `wire=` / `chunks=` argument still wins.  An `AllToAll`-bearing program
    (the expert-parallel MoE step) switches to the alltoall pricer: each
    AllToAll node pays one forward and one backward exchange at the algorithm
    the plan's per-tier table dispatches for that payload ("xla" -> the *CCL
    asymptotic model, "pairwise" -> the bounded-state MPI-style model — which
    is how Obs. 7's >4096-endpoint *CCL blow-up is avoided at scale), all of
    it exposed (token exchanges sit on the critical path); remaining `sizes`
    entries beyond the first two are dense (router) gradient bytes priced on
    the allreduce model.
    """
    import dataclasses as _dc

    from . import overlap as ov
    from . import program as prg
    from .wire import WireSpec, realized_multiplier

    if program is not None:
        program.validate()
        schedule = program.schedule
        cp = program.node("chunked_pipeline")
        if chunks is None and cp is not None:
            chunks = cp.chunks
        qw = program.node("quantize_wire")
        if wire is None and qw is not None:
            wire = WireSpec(intra="int8",
                            inter="int8" if program.has("sharded_optim_update")
                            else "fp32")
    elif schedule in ("allreduce", "zero"):
        program = prg.train_step_program(zero=(schedule == "zero"))

    if wire == "plan":
        wire = plan.wire_spec() if hasattr(plan, "wire_spec") else None
    elif isinstance(wire, dict):
        wire = WireSpec.from_dict(wire)
    wire = wire or WireSpec()
    model_given = model is not None
    model = model or make_comm_model(
        plan.meta.get("profile", "tpu_v5e") if plan.meta.get("profile")
        in hw.SYSTEMS else "tpu_v5e")
    if n_endpoints is None:
        n_endpoints = int(plan.meta.get("n_endpoints", 0) or 0) or model.graph.n
    if schedule not in ("allreduce", "zero", "moe_alltoall"):
        raise ValueError(f"unknown schedule {schedule!r}; "
                         f"one of ('allreduce', 'zero', 'moe_alltoall')")
    sizes = [int(s) for s in sizes if int(s) > 0]
    wire_str = f"{wire.intra}/{wire.inter}"
    if not sizes:
        return OverlapEstimate(compute_time, 0.0, 0.0, compute_time, 1.0, 0, 1,
                               wire_str, schedule)
    if schedule == "moe_alltoall":
        return _price_moe_program(compute_time, plan, sizes, n_endpoints,
                                  model, mechanism, wire_str)
    bz = program.node("bucketize") if program is not None else None
    bucket_cap = max(int(bz.bucket_bytes if (bz is not None and
                                             bz.bucket_bytes)
                         else plan.bucket_bytes), 1)
    buckets = ov.make_buckets(sizes, bucket_cap)  # byte-granular, reverse order
    b_bytes = [float(b.n_elems) for b in buckets]
    nn = model.profile.endpoints_per_node
    # full buckets all share one byte count: evaluate the per-bucket comm model
    # once per *unique* size instead of once per bucket (a 1 GiB gradient at a
    # 4 MiB bucket is ~256 identical evaluations otherwise — measurable at
    # 4096-endpoint sweep granularity)
    uniq = sorted(set(b_bytes))
    if n_endpoints > nn:
        # without an explicit model, a hierarchical plan's persisted per-tier
        # fits (calibrated when the plan was) drive the prediction — the same
        # constants plan.pipeline_chunks hands the runtime; an explicit model
        # re-derives them at this endpoint count instead
        params = None
        if not model_given and hasattr(plan, "pipeline_params"):
            params = plan.pipeline_params()
        if params is None:
            params = pipeline_params_at_scale(model, n_endpoints, mechanism)
        if schedule == "zero":
            # RS leg stays fp32 (wire_intra/wire_inter defaults); the AG leg
            # alone carries the wire format, at the idealized ratio
            c = chunks if chunks is not None else ov.choose_chunks(bucket_cap,
                                                                   params)
            c = max(int(c), 1)
            comm_by_size = {
                b: ov.zero_pipeline_time(b, c, params,
                                         ag_intra=wire.multiplier("intra"),
                                         ag_inter=wire.multiplier("inter"))
                for b in uniq}
        else:
            params = _dc.replace(
                params,
                wire_intra=realized_multiplier(wire.intra, params.n_ici),
                wire_inter=wire.multiplier("inter"))
            c = chunks if chunks is not None else ov.choose_chunks(bucket_cap,
                                                                   params)
            c = max(int(c), 1)
            comm_by_size = {b: ov.pipeline_time(b, c, params) for b in uniq}
    else:
        c = 1
        n_tier = min(n_endpoints, model.graph.n)
        if schedule == "zero":
            # ring allreduce = RS + AG: half at fp32, half at the AG wire
            comm_by_size = {
                b: 0.5 * (model.allreduce_intra(b, mechanism, n=n_tier).seconds
                          + model.allreduce_intra(b * wire.multiplier("intra"),
                                                  mechanism, n=n_tier).seconds)
                for b in uniq}
        else:
            m_intra = realized_multiplier(wire.intra, n_tier)
            comm_by_size = {
                b: model.allreduce_intra(b * m_intra, mechanism,
                                         n=n_tier).seconds
                for b in uniq}
    comm = [comm_by_size[b] for b in b_bytes]
    timeline = ov.bucket_schedule(compute_time, b_bytes, comm)
    total_comm = sum(comm)
    step = max(compute_time, timeline[-1].end_s)
    exposed = step - compute_time
    hidden = 1.0 - exposed / total_comm if total_comm > 0 else 1.0
    return OverlapEstimate(compute_time, total_comm, exposed, step,
                           min(max(hidden, 0.0), 1.0), len(buckets), c,
                           wire_str, schedule)


def _price_moe_program(compute_time: float, plan, sizes, n_endpoints: int,
                       model: CommModel, mechanism: str,
                       wire_str: str) -> OverlapEstimate:
    """Price an AllToAll-bearing (expert-parallel MoE) program.

    ``sizes[:2]`` are the dispatch/combine per-endpoint buffer bytes (see
    ``runtime.moe_step.dispatch_bytes``); anything after is dense (router)
    gradient bytes on the allreduce model.  Each exchange runs at whatever
    algorithm the plan's per-tier table ranks first for that (payload,
    endpoint count) — the executed-path oracle in ``core.scenarios`` asserts
    the live step dispatches the same one — and is charged twice (the
    backward of an alltoall is its transpose).  Token exchanges gate the
    forward, so nothing here hides behind compute: exposed == total.
    """
    a2a_sizes, dense = sizes[:2], sizes[2:]
    t_a2a = 0.0
    for s in a2a_sizes:
        algo = plan.all_to_all_algo(int(s), n_endpoints) \
            if hasattr(plan, "all_to_all_algo") else "pairwise"
        mech = mechanism if algo == "xla" else "mpi"
        t_a2a += 2.0 * model.alltoall_at_scale(float(s), n_endpoints,
                                               mechanism=mech).seconds
    t_dense = sum(model.allreduce_at_scale(float(s), n_endpoints,
                                           mechanism=mechanism).seconds
                  for s in dense)
    total = t_a2a + t_dense
    return OverlapEstimate(compute_time, total, total, compute_time + total,
                           0.0, len(sizes), 1, wire_str, "moe_alltoall")


# Memoized system models: the scenario sweeps (`at_scale_suite`,
# `check_paper_shapes`, `sweep_overlap`) used to rebuild the fabric + model per
# call inside their loops.  Models are immutable after construction, so one
# instance per (system, calibration identity) is shared.  The cache entry
# holds a strong reference to the calibration object, which keeps its id()
# from being recycled while the entry is alive; the identity check guards the
# (theoretical) mismatch anyway.
_MODEL_CACHE: Dict[tuple, CommModel] = {}


def make_comm_model(system: str = "tpu_v5e", calibration: Optional[object] = None) -> CommModel:
    from .topology import (make_paper_fabrics, make_paper_node_graphs,
                           make_tpu_pod, make_tpu_multipod)

    key = (system, id(calibration) if calibration is not None else None)
    hit = _MODEL_CACHE.get(key)
    if hit is not None and hit.calibration is calibration:
        return hit
    prof = hw.SYSTEMS[system]
    if system == "tpu_v5e":
        model = CommModel(prof, make_tpu_pod(), make_tpu_multipod(),
                          calibration=calibration,
                          fabric=make_paper_fabrics()[system])
    else:
        model = CommModel(prof, make_paper_node_graphs()[system],
                          calibration=calibration,
                          fabric=make_paper_fabrics()[system])
    _MODEL_CACHE[key] = model
    return model


def crossover_bytes(model: CommModel, n: int, mech_a: str = "ccl", mech_b: str = "mpi",
                    op: str = "allreduce") -> Optional[int]:
    """Find the message size where mech_a starts beating mech_b (the paper's Fig. 11
    ~32 KiB inversion on LUMI).  Returns None if one dominates everywhere."""
    fn = (lambda s, m: model.allreduce_at_scale(s, n, m).seconds) if op == "allreduce" \
        else (lambda s, m: model.alltoall_at_scale(s, n, m).seconds)
    prev = None
    for k in range(6, 32):  # 64 B .. 2 GiB
        s = float(2 ** k)
        a_wins = fn(s, mech_a) < fn(s, mech_b)
        if prev is not None and a_wins != prev:
            return 2 ** k
        prev = a_wins
    return None
