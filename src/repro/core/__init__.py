"""repro.core — the paper's contribution (interconnect characterization), TPU-native.

Public API:
  topology:    LinkGraph, Fabric, TwoLevelTopology, make_paper_systems, make_tpu_pod
  scenarios:   at_scale_suite, check_paper_shapes (Sec. V-VI sweeps, 8..4096 eps)
  costmodel:   CommModel, make_comm_model, crossover_bytes
  collectives: ALL_REDUCE_ALGOS, ALL_TO_ALL_ALGOS, hierarchical_all_reduce, ...
  bench:       time_fn, IterStats, BenchRecord, write_csv
  noise:       NoiseModel, ServiceLevelArbiter, StragglerMitigator
  overlap:     make_buckets, chunked_hierarchical_all_reduce, choose_chunks
               (overlap-aware execution engine over the plan)
  commplan:    CommPlan, PlanEntry (topology -> dispatch plan, the planning seam)
  autotune:    CollectivePolicy, default_policy (thin shim over commplan)
  characterize: characterize_mesh, project_at_scale
  calibrate:   CalibrationProfile, fit_profile, run_calibration (measured loop)
"""
from . import hw
from .topology import (Fabric, LinkGraph, TwoLevelTopology, make_paper_fabrics,
                       make_paper_node_graphs, make_paper_systems, make_tpu_pod,
                       make_tpu_multipod)
from .costmodel import (CommModel, OverlapEstimate, crossover_bytes,
                        exposed_comm_time, make_comm_model)
from .overlap import (Bucket, PipelineParams, choose_chunks,
                      chunked_hierarchical_all_reduce, make_buckets,
                      pipeline_time)
from .scenarios import (OverlapPoint, ScenarioPoint, at_scale_suite,
                        check_overlap_shapes, check_paper_shapes,
                        sweep_collective, sweep_overlap)
from .bench import IterStats, BenchRecord, time_fn, write_csv, gbps
from .noise import NoiseModel, ServiceLevelArbiter, StragglerMitigator
from .commplan import CommPlan, PlanEntry
from .autotune import CollectivePolicy, default_policy
from .calibrate import CalibrationProfile, FittedParams, fit_profile, run_calibration

__all__ = [
    "hw", "Fabric", "LinkGraph", "TwoLevelTopology", "make_paper_fabrics",
    "make_paper_node_graphs", "make_paper_systems", "make_tpu_pod",
    "make_tpu_multipod", "CommModel", "make_comm_model", "crossover_bytes",
    "ScenarioPoint", "at_scale_suite", "check_paper_shapes", "sweep_collective",
    "OverlapEstimate", "exposed_comm_time", "Bucket", "PipelineParams",
    "choose_chunks", "chunked_hierarchical_all_reduce", "make_buckets",
    "pipeline_time", "OverlapPoint", "check_overlap_shapes", "sweep_overlap",
    "IterStats", "BenchRecord", "time_fn", "write_csv", "gbps", "NoiseModel",
    "ServiceLevelArbiter", "StragglerMitigator", "CommPlan", "PlanEntry",
    "CollectivePolicy", "default_policy", "CalibrationProfile", "FittedParams",
    "fit_profile", "run_calibration",
]
