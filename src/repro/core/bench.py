"""Measurement harness (paper Sec. III-A methodology).

* per-iteration timings (never aggregate-only — needed for the noise analysis,
  Sec. VI), blocking on completion before stopping the clock;
* collective timings are inherently max-across-ranks in single-controller JAX
  (dispatch + block_until_ready covers all shards) — consistent with [23];
* statistics: mean, median, IQR, p5/p95, min/max — exactly the paper's box plots;
* goodput helpers using the paper's definitions:
    p2p unidirectional goodput = bytes / (rtt/2)         (Sec. III-C)
    collective goodput          = buffer bytes / runtime  (Sec. IV-A)
* CSV artifacts matching the paper-artifact format (name, size, per-iter times).
"""
from __future__ import annotations

import csv
import dataclasses
import statistics
import time
from typing import Callable, Dict, List, Optional, Sequence

import jax
import numpy as np


@dataclasses.dataclass
class IterStats:
    """Distribution summary of per-iteration runtimes (seconds)."""

    times: List[float]

    @property
    def mean(self) -> float:
        return statistics.fmean(self.times)

    @property
    def median(self) -> float:
        return statistics.median(self.times)

    def percentile(self, p: float) -> float:
        return float(np.percentile(self.times, p))

    @property
    def iqr(self) -> tuple:
        return (self.percentile(25), self.percentile(75))

    @property
    def p5(self) -> float:
        return self.percentile(5)

    @property
    def p95(self) -> float:
        return self.percentile(95)

    @property
    def min(self) -> float:
        return min(self.times)

    @property
    def max(self) -> float:
        return max(self.times)

    def summary(self) -> Dict[str, float]:
        q1, q3 = self.iqr
        return {
            "mean_s": self.mean, "median_s": self.median, "q1_s": q1, "q3_s": q3,
            "p5_s": self.p5, "p95_s": self.p95, "min_s": self.min, "max_s": self.max,
            "iters": len(self.times),
        }


# Latency-dominated vs bandwidth-dominated boundary: drives both the paper's
# iteration-count schedule and core.calibrate's size regimes.
SMALL_MAX_BYTES = 64 * 1024


def iters_for_size(nbytes: int, lo: int = 100, hi: int = 1000) -> int:
    """Paper: 100..1000 iterations depending on transfer size."""
    if nbytes <= SMALL_MAX_BYTES:
        return hi
    if nbytes >= 64 * 1024 * 1024:
        return lo
    return 300


def time_fn(fn: Callable, *args, iters: int = 100, warmup: int = 10) -> IterStats:
    """Per-iteration wall times of an already-jitted callable.

    Blocks on all outputs each iteration (the 'synchronize with the GPU before
    stopping the timer' rule of Sec. III-A).  One-time costs (compilation = the
    communicator-creation analog) are excluded via warmup.
    """
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        times.append(time.perf_counter() - t0)
    return IterStats(times)


def p2p_goodput(nbytes: int, rtt_seconds: float) -> float:
    """Unidirectional goodput: bytes / (rtt/2)  [bytes/s]."""
    return nbytes / (rtt_seconds / 2.0)


def collective_goodput(buffer_bytes: int, seconds: float) -> float:
    return buffer_bytes / seconds


def gbps(bytes_per_s: float) -> float:
    """bytes/s -> Gb/s (the paper's reporting unit)."""
    return bytes_per_s * 8.0 / 1e9


@dataclasses.dataclass
class BenchRecord:
    name: str
    mechanism: str
    pattern: str
    nbytes: int
    n_endpoints: int
    stats: IterStats
    goodput_bytes_s: float
    expected_bytes_s: Optional[float] = None
    tier: Optional[str] = None   # fabric distance tier (inter-node sweeps)

    def row(self) -> Dict[str, object]:
        r = {
            "name": self.name, "mechanism": self.mechanism, "pattern": self.pattern,
            "nbytes": self.nbytes, "n_endpoints": self.n_endpoints,
            "goodput_gbps": gbps(self.goodput_bytes_s),
            "expected_gbps": gbps(self.expected_bytes_s)
                             if self.expected_bytes_s is not None else "",
            "tier": self.tier or "",
        }
        r.update(self.stats.summary())
        return r


def write_csv(path: str, records: Sequence[BenchRecord]) -> None:
    if not records:
        return
    rows = [r.row() for r in records]
    fieldnames: List[str] = []
    for row in rows:
        for k in row:
            if k not in fieldnames:
                fieldnames.append(k)
    with open(path, "w", newline="") as f:
        w = csv.DictWriter(f, fieldnames=fieldnames, restval="")
        w.writeheader()
        w.writerows(rows)


def print_records(records: Sequence[BenchRecord]) -> None:
    for r in records:
        exp = f" expected={gbps(r.expected_bytes_s):8.1f}" \
            if r.expected_bytes_s is not None else ""
        print(
            f"{r.name:32s} {r.mechanism:12s} {r.pattern:10s} n={r.n_endpoints:<5d} "
            f"{r.nbytes:>12d}B  {r.stats.median*1e6:10.1f}us  "
            f"goodput={gbps(r.goodput_bytes_s):8.2f} Gb/s{exp}"
        )
