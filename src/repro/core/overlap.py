"""Overlap-aware collective execution: hide communication behind compute.

The paper's headline conclusion (Sec. VI, Obs. 1/4) is that measured
interconnects leave bandwidth untapped and the biggest wins are software-level:
issuing communication early enough that it overlaps the remaining compute, and
pipelining the phases of hierarchical schedules so the slow tier streams while
the fast tier works on the next chunk.  PRs 1-3 built the *planning* stack
(CommPlan tables, measured calibration, fabric tiers); this module is the
*execution* side that turns a plan into realized overlap:

  * **Reverse-layer-order gradient buckets** (`make_buckets`): during backward,
    the *last* layers' gradients materialize first, so bucket 0 holds the tail
    of the flat gradient list.  Issued in bucket order, reductions start while
    earlier layers' gradients are still being computed — instead of one
    post-hoc blob after the full backward pass.
  * **Scan-carried issue schedule** (`scan_bucket_reduce`): equal-size packed
    buckets are reduced inside a `lax.scan`, which serializes the collectives
    into an ordered comm stream (one bucket in flight at a time) that XLA's
    latency-hiding scheduler can slot around independent compute — and which
    is visible in the jaxpr as N per-bucket collectives, not one concatenation.
  * **Chunked double-buffered hierarchical pipeline**
    (`chunked_hierarchical_all_reduce`): each bucket is split into chunks so
    the intra-node reduce-scatter of chunk k+1 is issued concurrently with the
    inter-node all-reduce of chunk k and the intra-node all-gather of chunk
    k-1 — the three tiers stream simultaneously instead of executing
    store-and-forward.  Chunk count comes from the plan's per-tier alpha-beta
    fits (`choose_chunks`): more chunks shrink the pipeline fill until the
    per-chunk latency term dominates.

All schedule arithmetic (`pipeline_time`, `bucket_schedule`) is closed-form
alpha-beta and shared with `costmodel.exposed_comm_time`, so the predictor and
the runtime agree on the same model.  Numerics are exact re-chunking: every
path matches the unpipelined reduction bit-for-bit in fp32 when sums are
exactly representable (validated in tests/test_collectives.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, List, Optional, Sequence, Tuple

import jax.numpy as jnp
from jax import lax

from . import collectives as coll

MAX_PIPELINE_CHUNKS = 16


# ------------------------------------------------------------------- buckets
@dataclasses.dataclass(frozen=True)
class Bucket:
    """One reduction unit: contiguous spans of the flat gradient list.

    `spans` are (tensor_index, lo, hi) element ranges; tensors are split at
    bucket boundaries, so a bucket never exceeds `elems` (except when a single
    element already does — a bucket always holds at least one element)."""

    spans: Tuple[Tuple[int, int, int], ...]
    elems: int

    @property
    def n_elems(self) -> int:
        return sum(hi - lo for _, lo, hi in self.spans)


def make_buckets(sizes: Sequence[int], bucket_elems: int,
                 reverse: bool = True) -> List[Bucket]:
    """Assign per-tensor element counts to fixed-size buckets.

    With `reverse=True` (the overlap schedule), tensors are walked from the
    *end* of the list — reverse layer order, because backward produces the last
    layers' gradients first — so bucket 0 is ready earliest during backward.
    `bucket_elems` below one element is clamped to 1 (each element becomes its
    own bucket rather than an infinite loop / zero-size bucket).
    """
    cap = max(int(bucket_elems), 1)
    order = range(len(sizes) - 1, -1, -1) if reverse else range(len(sizes))
    buckets: List[Bucket] = []
    cur: List[Tuple[int, int, int]] = []
    cur_n = 0
    for i in order:
        pos = 0
        size = int(sizes[i])
        while pos < size:
            take = min(size - pos, cap - cur_n)
            cur.append((i, pos, pos + take))
            cur_n += take
            pos += take
            if cur_n == cap:
                buckets.append(Bucket(tuple(cur), cap))
                cur, cur_n = [], 0
    if cur:
        buckets.append(Bucket(tuple(cur), cap))
    return buckets


def pack_buckets(flat_g: Sequence[jnp.ndarray], buckets: Sequence[Bucket],
                 scale: float = 1.0, pad: bool = True):
    """Stack buckets into one (n_buckets, bucket_elems) fp32 array (the scan
    carrier).  The final partial bucket is zero-padded — zeros are the identity
    of the reduction, so padding never changes results.  With `pad=False` a
    single bucket keeps its exact wire size and the return is a one-element
    list (rows can be ragged, so no stacking)."""
    assert pad or len(buckets) == 1, "pad=False packs exactly one bucket"
    cap = buckets[0].elems
    rows = []
    for b in buckets:
        parts = [flat_g[i].astype(jnp.float32).reshape(-1)[lo:hi] * scale
                 for i, lo, hi in b.spans]
        row = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
        if pad and row.shape[0] < cap:
            row = jnp.concatenate([row, jnp.zeros((cap - row.shape[0],), jnp.float32)])
        rows.append(row)
    return jnp.stack(rows) if pad else rows


def unpack_buckets(reduced, buckets: Sequence[Bucket],
                   flat_g: Sequence[jnp.ndarray]) -> List[jnp.ndarray]:
    """Scatter reduced bucket rows (a stacked 2-D array or a list of 1-D rows)
    back into per-tensor fp32 arrays with the original shapes (inverse of
    `pack_buckets`).  Zero-size tensors — which own no bucket span — come back
    as fp32 zeros of their original shape."""
    # spans were appended in bucket construction order; collect per tensor in
    # ascending (lo, hi) order so concatenation restores the flat layout
    pieces: List[List[Tuple[int, jnp.ndarray]]] = [[] for _ in flat_g]
    for k, b in enumerate(buckets):
        row = reduced[k]
        off = 0
        for i, lo, hi in b.spans:
            pieces[i].append((lo, row[off: off + hi - lo]))
            off += hi - lo
    out = []
    for g, ps in zip(flat_g, pieces):
        if not ps:
            out.append(jnp.zeros(g.shape, jnp.float32))
            continue
        ps.sort(key=lambda t: t[0])
        parts = [p for _, p in ps]
        flat = jnp.concatenate(parts) if len(parts) > 1 else parts[0]
        out.append(flat.reshape(g.shape))
    return out


def scan_bucket_reduce(stacked: jnp.ndarray,
                       reduce_fn: Callable[[jnp.ndarray], jnp.ndarray]) -> jnp.ndarray:
    """Issue one bucket reduction per `lax.scan` step — the serialized comm
    stream.  The scan is the issue schedule: bucket k+1's reduction cannot be
    launched before bucket k's (one bucket in flight), matching the wire model
    in `bucket_schedule`, and the jaxpr shows a scan of per-bucket collectives
    instead of one monolithic post-hoc reduction."""

    def body(tok, bucket):
        return tok, reduce_fn(bucket)

    _, reduced = lax.scan(body, jnp.zeros((), jnp.float32), stacked)
    return reduced


# ------------------------------------------------- chunked hierarchical pipe
@coll.register("all_reduce", "hierarchical_chunked", multi_axis=True)
def chunked_hierarchical_all_reduce(x: jnp.ndarray, ici_axis: str, dcn_axis: str,
                                    n_chunks: int = 2) -> jnp.ndarray:
    """Software-pipelined hierarchical all-reduce: the buffer is split into
    `n_chunks` chunks and the three phases are issued stage-interleaved so

        stage t:  intra AG(chunk t-2) | inter AR(chunk t-1) | intra RS(chunk t)

    run concurrently (double buffering generalized to a 3-deep pipeline).  The
    three issues inside one stage have no data dependencies on each other, so
    the compiler may overlap the slow inter tier with both intra phases.
    Numerically identical to `hierarchical_all_reduce` (pure re-chunking).
    """
    n = lax.axis_size(ici_axis)
    n_chunks = max(int(n_chunks), 1)
    if n_chunks == 1:
        return coll.hierarchical_all_reduce(x, ici_axis, dcn_axis)
    flat = x.astype(jnp.float32).reshape(-1) if x.dtype != jnp.float32 \
        else x.reshape(-1)
    # chunk length must divide the ici axis so reduce-scatter needs no pad
    chunk_elems = -(-flat.shape[0] // n_chunks)
    chunk_elems = -(-chunk_elems // n) * n
    pad = n_chunks * chunk_elems - flat.shape[0]
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    chunks = flat.reshape(n_chunks, chunk_elems)
    rs: List[Optional[jnp.ndarray]] = [None] * n_chunks
    ar: List[Optional[jnp.ndarray]] = [None] * n_chunks
    ag: List[Optional[jnp.ndarray]] = [None] * n_chunks
    for t in range(n_chunks + 2):
        # issue order within a stage is oldest-first: drain the pipe tail
        # (AG of t-2), keep the inter tier busy (AR of t-1), then feed it
        # (RS of t).  All three are data-independent.
        if 0 <= t - 2 < n_chunks:
            ag[t - 2] = coll.ring_all_gather(ar[t - 2], ici_axis)
        if 0 <= t - 1 < n_chunks:
            ar[t - 1] = lax.psum(rs[t - 1], dcn_axis)
        if t < n_chunks:
            rs[t] = coll.ring_reduce_scatter(chunks[t], ici_axis)
    out = jnp.concatenate([a.reshape(-1) for a in ag])
    return out[: x.size].reshape(x.shape).astype(x.dtype)


def two_tier_reduce_scatter(x: jnp.ndarray, ici_axis: str,
                            dcn_axis: Optional[str] = None,
                            n_chunks: int = 1,
                            rs: Optional[Callable] = None) -> jnp.ndarray:
    """Reduce-scatter of a 1-D row over one or two tiers — the first phase of
    the ZeRO schedule (RS -> sharded update -> AG).

    Single tier: one reduce-scatter over `ici_axis`; rank r owns chunk r of
    the row.  Two tiers: an intra reduce-scatter feeds an inter one, so the
    device at (i, j) owns block `i * n_dcn + j` of the row split into
    `n_ici * n_dcn` blocks.  With `n_chunks > 1` the row is chunked and the
    inter RS of chunk t-1 is issued concurrently with the intra RS of chunk t
    (the two issues are data-independent) — the RS half of the chunked
    hierarchical pipeline.  The returned shard is the *concatenation of
    per-chunk blocks* (shard-major layout); `two_tier_all_gather` mirrors the
    chunking exactly, so the round trip restores row order.

    `rs(values, axis_name)` defaults to the ring algorithm; pass a plan
    dispatcher to route each leg through the planned per-size algorithm.  The
    caller guarantees `x.size` is divisible by `n_chunks * n_ici * n_dcn`.
    """
    rs = rs or (lambda v, ax: coll.ring_reduce_scatter(v, ax))
    if dcn_axis is None:
        return rs(x, ici_axis)
    n_chunks = max(int(n_chunks), 1)
    chunks = x.reshape(n_chunks, -1)
    intra: List[Optional[jnp.ndarray]] = [None] * n_chunks
    out: List[Optional[jnp.ndarray]] = [None] * n_chunks
    for t in range(n_chunks + 1):
        # oldest-first within a stage: the inter tier scatters chunk t-1
        # while the intra tier reduces chunk t
        if 0 <= t - 1 < n_chunks:
            out[t - 1] = rs(intra[t - 1], dcn_axis)
        if t < n_chunks:
            intra[t] = rs(chunks[t], ici_axis)
    return jnp.concatenate(out) if n_chunks > 1 else out[0]


def two_tier_all_gather(shard: jnp.ndarray, ici_axis: str,
                        dcn_axis: Optional[str] = None,
                        n_chunks: int = 1,
                        ag: Optional[Callable] = None) -> jnp.ndarray:
    """All-gather of a `two_tier_reduce_scatter` shard back into the full row
    — the return phase of the ZeRO schedule (updated params to every device).

    Gathers run in the inverse tier order of the RS (inter first, then intra)
    with the same chunking, so the concatenated output is in original row
    order.  With `n_chunks > 1` the intra gather of chunk t-1 drains while
    the inter tier gathers chunk t.  `ag(values, axis_name)` must return the
    (n, ...) rank-ordered stack (the ring/xla all-gather contract); it
    defaults to the ring algorithm.
    """
    ag = ag or coll.ring_all_gather
    if dcn_axis is None:
        return ag(shard, ici_axis).reshape(-1)
    n_chunks = max(int(n_chunks), 1)
    sub = shard.reshape(n_chunks, -1)
    inner: List[Optional[jnp.ndarray]] = [None] * n_chunks
    out: List[Optional[jnp.ndarray]] = [None] * n_chunks
    for t in range(n_chunks + 1):
        if 0 <= t - 1 < n_chunks:
            out[t - 1] = ag(inner[t - 1], ici_axis).reshape(-1)
        if t < n_chunks:
            inner[t] = ag(sub[t], dcn_axis).reshape(-1)
    return jnp.concatenate(out) if n_chunks > 1 else out[0]


def quantized_all_gather(q_shard: jnp.ndarray, scale: jnp.ndarray,
                         ici_axis: str, dcn_axis: Optional[str] = None,
                         n_chunks: int = 1) -> jnp.ndarray:
    """Wire-compressed return leg of the ZeRO schedule: gather the int8 param
    shards (+ one fp32 scale per shard) over one or two tiers and dequantize
    only after the full gather -> the fp32 full row.

    Every device — including each shard's owner — uses the *dequantized*
    values for every shard, so parameters stay bit-identically replicated
    across the mesh (an owner that kept its exact fp32 shard would silently
    diverge from its peers).  Unlike the gradient wire there is no error
    feedback: the same int8 payload rides both tiers unchanged, so the only
    error is the single quantization step.  With `n_chunks > 1` the intra
    gather of chunk t-1 overlaps the inter gather of chunk t; the per-shard
    scale covers all chunks of that shard.
    """
    if dcn_axis is None:
        qg = lax.all_gather(q_shard, ici_axis)            # (n, S) int8 wire
        sg = lax.all_gather(scale, ici_axis)              # (n,) fp32 scales
        return (qg.astype(jnp.float32) * sg[:, None]).reshape(-1)
    sg = lax.all_gather(lax.all_gather(scale, dcn_axis), ici_axis)  # (n, n_dcn)
    n_chunks = max(int(n_chunks), 1)
    sub = q_shard.reshape(n_chunks, -1)
    inner: List[Optional[jnp.ndarray]] = [None] * n_chunks
    out: List[Optional[jnp.ndarray]] = [None] * n_chunks
    for t in range(n_chunks + 1):
        if 0 <= t - 1 < n_chunks:
            g = lax.all_gather(inner[t - 1], ici_axis)    # (n, n_dcn, sc) i8
            out[t - 1] = (g.astype(jnp.float32) * sg[:, :, None]).reshape(-1)
        if t < n_chunks:
            inner[t] = lax.all_gather(sub[t], dcn_axis)   # (n_dcn, sc) int8
    return jnp.concatenate(out) if n_chunks > 1 else out[0]


def quantized_all_reduce(q: jnp.ndarray, scale: jnp.ndarray, ici_axis: str,
                         dcn_axis: Optional[str] = None,
                         n_chunks: int = 1) -> jnp.ndarray:
    """Wire-compressed all-reduce of one int8 bucket row (+ its fp32 scale).

    Intra tier: all-gather the int8 payload and the per-peer scales, then
    dequantize-and-sum locally — the wire moves s/4 + 4 bytes per peer instead
    of the 4x fp32 row.  The inter (DCN) leg stays fp32: requantizing partial
    sums would add error outside the error-feedback loop (the packing kernel
    only tracks the *local* quantization residual).

    With `n_chunks > 1` on a two-level mesh, the row is chunked and the intra
    gather of chunk t is issued concurrently with the inter psum of chunk t-1
    — the int8 analog of `chunked_hierarchical_all_reduce`'s double buffering
    (two stages instead of three: gather-sum feeds psum).  Numerically
    identical to the unchunked path (pure re-chunking of the same sums).
    """
    sg = lax.all_gather(scale, ici_axis)                  # (n,) fp32 scales
    n_chunks = max(int(n_chunks), 1) if dcn_axis is not None else 1
    flat = q.reshape(-1)
    chunk_elems = -(-flat.shape[0] // n_chunks)
    pad = n_chunks * chunk_elems - flat.shape[0]
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    chunks = flat.reshape(n_chunks, chunk_elems)
    deq: List[Optional[jnp.ndarray]] = [None] * n_chunks
    ar: List[Optional[jnp.ndarray]] = [None] * n_chunks
    for t in range(n_chunks + 1):
        # oldest-first within a stage: keep the inter tier draining while the
        # intra tier gathers the next chunk (the two issues are independent)
        if 0 <= t - 1 < n_chunks and dcn_axis is not None:
            ar[t - 1] = lax.psum(deq[t - 1], dcn_axis)
        if t < n_chunks:
            qg = lax.all_gather(chunks[t], ici_axis)      # (n, chunk) int8
            deq[t] = jnp.tensordot(sg, qg.astype(jnp.float32),
                                   axes=((0,), (0,)))
    rows = ar if dcn_axis is not None else deq
    out = jnp.concatenate(rows) if n_chunks > 1 else rows[0]
    return out[: q.size].reshape(q.shape)


# ------------------------------------------------------- closed-form schedule
@dataclasses.dataclass(frozen=True)
class PipelineParams:
    """Per-tier alpha-beta constants of the hierarchical pipeline, as persisted
    on a CommPlan (plan.pipeline) or derived from a CommModel."""

    n_ici: int
    alpha_ici: float
    bw_ici: float       # intra-tier effective bytes/s (allreduce-phase bound)
    alpha_dcn: float
    bw_dcn: float       # inter-tier effective bytes/s per endpoint
    # bytes-on-wire multipliers vs fp32 per tier (core.wire: 0.25 for int8):
    # the wire-format plan shrinks the bandwidth term of the tiers it
    # compresses while the alpha terms stay put
    wire_intra: float = 1.0
    wire_inter: float = 1.0

    def stage_times(self, chunk_bytes: float) -> Tuple[float, float, float]:
        """(reduce-scatter, inter all-reduce, all-gather) seconds per chunk."""
        n = max(self.n_ici, 2)
        frac = (n - 1) / n
        t_rs = (n - 1) * self.alpha_ici \
            + chunk_bytes * self.wire_intra * frac / self.bw_ici
        t_ag = t_rs
        t_ar = self.alpha_dcn + (chunk_bytes * self.wire_inter / n) / self.bw_dcn
        return t_rs, t_ar, t_ag

    def zero_stage_times(self, chunk_bytes: float, ag_intra: float = 1.0,
                         ag_inter: float = 1.0) -> Tuple[float, float, float]:
        """(intra RS, inter RS+AG, intra AG) seconds per chunk of the
        three-phase ZeRO schedule.  The reduce legs stay fp32 (partial sums
        must not be requantized); `ag_intra`/`ag_inter` are the bytes-on-wire
        multipliers of the param all-gather legs — *idealized* ratios, because
        the shard gather moves each shard exactly once (unlike the gradient
        gather wire, `wire.realized_multiplier` does not apply)."""
        n = max(self.n_ici, 2)
        frac = (n - 1) / n
        t_rs = (n - 1) * self.alpha_ici \
            + chunk_bytes * self.wire_intra * frac / self.bw_ici
        t_inter = 2 * self.alpha_dcn \
            + (chunk_bytes * (self.wire_inter + ag_inter) / n) / self.bw_dcn
        t_ag = (n - 1) * self.alpha_ici \
            + chunk_bytes * ag_intra * frac / self.bw_ici
        return t_rs, t_inter, t_ag


def zero_pipeline_time(nbytes: float, n_chunks: int, params: PipelineParams,
                       ag_intra: float = 1.0, ag_inter: float = 1.0) -> float:
    """Pipelined three-phase ZeRO schedule time for `nbytes` split into
    `n_chunks` chunks (fill + steady state paced by the slowest stage), the
    RS/update/AG analog of `pipeline_time`."""
    n_chunks = max(int(n_chunks), 1)
    ts = params.zero_stage_times(nbytes / n_chunks, ag_intra, ag_inter)
    return sum(ts) + (n_chunks - 1) * max(ts)


def pipeline_time(nbytes: float, n_chunks: int, params: PipelineParams) -> float:
    """Pipelined hierarchical all-reduce time for `nbytes` split into
    `n_chunks` chunks: fill (one chunk through all three stages) plus steady
    state paced by the slowest stage.  n_chunks=1 degenerates to the
    store-and-forward sum of phases."""
    n_chunks = max(int(n_chunks), 1)
    ts = params.stage_times(nbytes / n_chunks)
    return sum(ts) + (n_chunks - 1) * max(ts)


def choose_chunks(nbytes: float, params: PipelineParams,
                  max_chunks: int = MAX_PIPELINE_CHUNKS) -> int:
    """Chunk count minimizing the pipelined time: more chunks shrink the fill
    cost until the per-chunk alpha terms dominate (the paper's latency /
    bandwidth tension, applied to pipeline depth)."""
    best, best_t = 1, pipeline_time(nbytes, 1, params)
    c = 2
    while c <= max_chunks:
        t = pipeline_time(nbytes, c, params)
        if t < best_t:
            best, best_t = c, t
        c *= 2
    return best


@dataclasses.dataclass(frozen=True)
class BucketTimeline:
    """One bucket's life on the wire in the overlap schedule."""

    ready_s: float      # when its gradients have materialized during backward
    start_s: float      # when the serialized comm stream gets to it
    end_s: float
    comm_s: float


def bucket_schedule(compute_time: float, bucket_bytes: Sequence[float],
                    bucket_comm_s: Sequence[float]) -> List[BucketTimeline]:
    """The overlap wire model shared by predictor and runtime semantics.

    Buckets are in issue order (reverse layer order): bucket i's gradients
    materialize once the backward has produced the last `sum(bytes[:i+1])`
    bytes of gradient, i.e. at `compute_time * cum_frac_i` (backward progress
    modeled linear in gradient bytes).  The comm stream is serial: bucket i
    starts at `max(ready_i, end_{i-1})`.
    """
    total = sum(bucket_bytes) or 1.0
    out: List[BucketTimeline] = []
    cum = 0.0
    prev_end = 0.0
    for b, t in zip(bucket_bytes, bucket_comm_s):
        cum += b
        ready = compute_time * (cum / total)
        start = max(ready, prev_end)
        end = start + t
        out.append(BucketTimeline(ready, start, end, t))
        prev_end = end
    return out
