"""Collective-algorithm autotuning (paper Obs. 1 + Fig. 11, made automatic).

The paper's headline software finding: the best data-movement mechanism depends
on message size, endpoint count, and system — with order-of-magnitude stakes —
and the libraries' defaults get it wrong (NCCL_* env tuning, the ~32 KiB
RCCL/MPI inversion on LUMI, GDRCopy mispaths...).

This module is now a thin builder/persistence shim over `core.commplan`:
`CollectivePolicy` wraps a topology-derived `CommPlan` (built via `from_model`
from the cost model's link graph, or via `measure` from on-device timings) and
keeps the original (bytes, axis-size) -> algorithm JSON format loadable —
old policy files round-trip unchanged; new saves carry the extra
reduce-scatter/all-gather tables, bucket size, and hierarchical flag.
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import jax.numpy as jnp

from . import collectives as coll
from .commplan import SIZE_CLASSES, CommPlan, PlanEntry, _is_pow2
from .costmodel import CommModel, make_comm_model

# Backward-compatible name: policy tables are plan tables.
PolicyEntry = PlanEntry


def calibration_sidecar(policy_path: str) -> str:
    """Path of the CalibrationProfile artifact persisted alongside a policy
    JSON: policy.json -> policy.calibration.json."""
    p = Path(policy_path)
    return str(p.with_name(p.stem + ".calibration.json"))


@dataclasses.dataclass
class CollectivePolicy:
    """Size-threshold dispatch tables per collective op and axis size — the
    stable public facade; all ranking/dispatch logic lives in `CommPlan`."""

    all_reduce_table: Dict[int, List[PlanEntry]]
    all_to_all_table: Dict[int, List[PlanEntry]]
    meta: Dict[str, str]
    plan: Optional[CommPlan] = None
    calibration: Optional[object] = None  # calibrate.CalibrationProfile

    def _as_plan(self) -> CommPlan:
        """Tables-only policies (legacy JSON, `measure`) get a wrapping plan so
        every dispatch path is uniform."""
        if self.plan is None:
            self.plan = CommPlan(self.all_reduce_table, self.all_to_all_table,
                                 {}, {}, meta=dict(self.meta))
        return self.plan

    # ------------------------------------------------------------- dispatch
    def all_reduce_algo(self, nbytes: int, axis_size: int) -> str:
        return CommPlan.lookup(self.all_reduce_table, nbytes, axis_size, "xla")

    def all_to_all_algo(self, nbytes: int, axis_size: int) -> str:
        return CommPlan.lookup(self.all_to_all_table, nbytes, axis_size, "xla")

    @property
    def bucket_bytes(self) -> int:
        return self._as_plan().bucket_bytes

    @property
    def hierarchical(self) -> bool:
        return self._as_plan().hierarchical

    def pipeline_chunks(self, nbytes: int) -> int:
        """Chunk depth for the overlap engine's hierarchical pipeline (1 for
        single-level plans)."""
        return self._as_plan().pipeline_chunks(nbytes)

    @property
    def wire(self):
        """Per-tier wire formats (`wire.WireSpec`) the plan chose from its
        alpha-beta fits — fp32 everywhere for legacy table-only policies."""
        return self._as_plan().wire_spec()

    @property
    def program(self):
        """The plan's persisted StepProgram (`core.program`), or None for
        legacy table-only policies.  Round-trips through save/load with the
        rest of the plan blob."""
        return self._as_plan().step_program()

    def set_program(self, program) -> None:
        self._as_plan().set_program(program)

    def all_reduce(self, x: jnp.ndarray, axis: str, axis_size: int,
                   dcn_axis: Optional[str] = None) -> jnp.ndarray:
        """Trace-time dispatch (sizes are static under jit)."""
        return self._as_plan().all_reduce(x, axis, axis_size, dcn_axis=dcn_axis)

    def all_to_all(self, x: jnp.ndarray, axis: str, axis_size: int) -> jnp.ndarray:
        return self._as_plan().all_to_all(x, axis, axis_size)

    def reduce_scatter(self, x: jnp.ndarray, axis: str, axis_size: int) -> jnp.ndarray:
        """One leg of the ZeRO three-phase schedule (plan-dispatched algo)."""
        return self._as_plan().reduce_scatter(x, axis, axis_size)

    def all_gather(self, chunk: jnp.ndarray, axis: str, axis_size: int) -> jnp.ndarray:
        return self._as_plan().all_gather(chunk, axis, axis_size)

    # ------------------------------------------------------------ builders
    @staticmethod
    def from_plan(plan: CommPlan, calibration: Optional[object] = None) -> "CollectivePolicy":
        return CollectivePolicy(plan.all_reduce_table, plan.all_to_all_table,
                                dict(plan.meta), plan=plan, calibration=calibration)

    @staticmethod
    def from_model(model: Optional[CommModel] = None,
                   axis_sizes: Tuple[int, ...] = (2, 4, 8, 16, 64, 256, 512),
                   calibration: Optional[object] = None) -> "CollectivePolicy":
        """Topology-derived policy: rank algorithms from the model's link graph
        (and two-level topology when present) instead of flat constants.  With
        `calibration`, the plan is re-ranked from the measured fits and the
        profile is persisted alongside the policy JSON on save."""
        model = model or make_comm_model("tpu_v5e")
        topo = model.two_level or model.graph
        plan = CommPlan.from_topology(topo, profile=model.profile,
                                      axis_sizes=axis_sizes,
                                      calibration=calibration)
        return CollectivePolicy.from_plan(plan, calibration=calibration)

    @staticmethod
    def measure(mesh, axis: str, sizes: Optional[List[int]] = None,
                iters: int = 20) -> "CollectivePolicy":
        """Measured policy: times each algorithm on the live mesh (the tuning run
        the paper performed by hand, Sec. III-B)."""
        import numpy as np
        import jax
        from jax.sharding import PartitionSpec as P

        from .bench import time_fn

        sizes = sizes or [1 << k for k in range(10, 25, 2)]
        n = mesh.shape[axis]
        specs = coll.registered("all_reduce", multi_axis=False)
        entries: List[PlanEntry] = []
        results: Dict[int, str] = {}
        for s in sizes:
            elems = max(s // 4, n)
            x = np.random.randn(n, elems // n + 1).astype(np.float32)
            best, best_t = None, float("inf")
            for name, spec in specs.items():
                if spec.pow2_only and not _is_pow2(n):
                    continue
                f = jax.jit(jax.shard_map(lambda v, fn=spec.fn: fn(v, axis), mesh=mesh,
                                          in_specs=P(axis), out_specs=P(axis)))
                st = time_fn(f, x, iters=iters, warmup=3)
                if st.median < best_t:
                    best, best_t = name, st.median
            results[s] = best
        prev = None
        for s in sizes:
            if prev is not None and results[s] != prev:
                entries.append(PlanEntry(s // 2, prev))
            prev = results[s]
        entries.append(PlanEntry(1 << 62, prev or "xla"))
        return CollectivePolicy({n: entries}, {n: [PlanEntry(1 << 62, "xla")]},
                                {"source": "measured"})

    # --------------------------------------------------------- persistence
    def save(self, path: str) -> None:
        if self.plan is not None:
            blob = self.plan.to_blob()
            blob["meta"] = {**blob.get("meta", {}), **self.meta}
        else:
            blob = {
                "meta": self.meta,
                "all_reduce": {str(n): [dataclasses.asdict(e) for e in es]
                               for n, es in self.all_reduce_table.items()},
                "all_to_all": {str(n): [dataclasses.asdict(e) for e in es]
                               for n, es in self.all_to_all_table.items()},
            }
        with open(path, "w") as f:
            json.dump(blob, f, indent=2)
        sidecar = Path(calibration_sidecar(path))
        if self.calibration is not None:
            self.calibration.save(str(sidecar))
        elif sidecar.exists():
            # an uncalibrated save must not leave a previous run's profile
            # behind for load() to attach to tables it never produced
            sidecar.unlink()

    @staticmethod
    def load(path: str) -> "CollectivePolicy":
        with open(path) as f:
            blob = json.load(f)
        if "all_reduce" not in blob or "all_to_all" not in blob:
            # CommPlan.from_blob is lenient; the policy facade must keep
            # rejecting non-policy JSON (launchers rely on it for validation)
            raise KeyError(f"{path}: not a policy file (missing "
                           f"'all_reduce'/'all_to_all' tables)")
        calibration = None
        sidecar = calibration_sidecar(path)
        if Path(sidecar).exists():
            from .calibrate import CalibrationProfile
            try:
                calibration = CalibrationProfile.load(sidecar)
            except Exception as e:  # the policy tables are still fully usable
                import warnings
                warnings.warn(f"ignoring unreadable calibration sidecar "
                              f"{sidecar}: {e}")
        # legacy files carry no plan-only fields; from_blob defaults them
        return CollectivePolicy.from_plan(CommPlan.from_blob(blob),
                                          calibration=calibration)


def default_policy() -> CollectivePolicy:
    return CollectivePolicy.from_model()
