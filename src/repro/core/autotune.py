"""Collective-algorithm autotuning (paper Obs. 1 + Fig. 11, made automatic).

The paper's headline software finding: the best data-movement mechanism depends on
message size, endpoint count, and system — with order-of-magnitude stakes — and the
libraries' defaults get it wrong (NCCL_* env tuning, the ~32 KiB RCCL/MPI
inversion on LUMI, GDRCopy mispaths...).

`CollectivePolicy` is the framework's answer: a persisted (bytes, axis-size) ->
algorithm table, built either from the analytical cost model (`from_model`) or from
on-device measurements (`measure`).  The training/serving runtime asks the policy at
trace time (message sizes are static under jit), so the dispatch is free.
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Callable, Dict, List, Optional, Tuple

import jax.numpy as jnp

from . import collectives as coll
from .costmodel import CommModel, make_comm_model

SIZE_CLASSES = [1 << k for k in range(8, 31, 2)]  # 256 B .. 1 GiB


def _is_pow2(n: int) -> bool:
    return n & (n - 1) == 0


@dataclasses.dataclass
class PolicyEntry:
    max_bytes: int
    algorithm: str


@dataclasses.dataclass
class CollectivePolicy:
    """Size-threshold dispatch tables per collective op and axis size."""

    all_reduce_table: Dict[int, List[PolicyEntry]]
    all_to_all_table: Dict[int, List[PolicyEntry]]
    meta: Dict[str, str]

    # ------------------------------------------------------------- dispatch
    def all_reduce_algo(self, nbytes: int, axis_size: int) -> str:
        return self._lookup(self.all_reduce_table, nbytes, axis_size, "xla")

    def all_to_all_algo(self, nbytes: int, axis_size: int) -> str:
        return self._lookup(self.all_to_all_table, nbytes, axis_size, "xla")

    @staticmethod
    def _lookup(table: Dict[int, List[PolicyEntry]], nbytes: int, axis_size: int,
                default: str) -> str:
        if axis_size not in table:
            # nearest configured axis size (log distance)
            if not table:
                return default
            axis_size = min(table, key=lambda n: abs(math.log2(n) - math.log2(max(axis_size, 1))))
        for entry in table[axis_size]:
            if nbytes <= entry.max_bytes:
                return entry.algorithm
        return table[axis_size][-1].algorithm if table[axis_size] else default

    def all_reduce(self, x: jnp.ndarray, axis: str, axis_size: int) -> jnp.ndarray:
        """Trace-time dispatch (sizes are static under jit)."""
        algo = self.all_reduce_algo(x.size * x.dtype.itemsize, axis_size)
        if not _is_pow2(axis_size) and algo in ("rabenseifner", "recursive_doubling", "tree"):
            algo = "ring"
        return coll.ALL_REDUCE_ALGOS[algo](x, axis)

    def all_to_all(self, x: jnp.ndarray, axis: str, axis_size: int) -> jnp.ndarray:
        algo = self.all_to_all_algo(x.size * x.dtype.itemsize, axis_size)
        # Obs. 7: beyond 512 endpoints *CCL alltoall is unstable — force pairwise.
        if axis_size > 512:
            algo = "pairwise"
        return coll.ALL_TO_ALL_ALGOS[algo](x, axis)

    # ------------------------------------------------------------ builders
    @staticmethod
    def from_model(model: Optional[CommModel] = None,
                   axis_sizes: Tuple[int, ...] = (2, 4, 8, 16, 64, 256, 512)) -> "CollectivePolicy":
        """Analytical policy from the alpha-beta cost model."""
        model = model or make_comm_model("tpu_v5e")
        ar: Dict[int, List[PolicyEntry]] = {}
        a2a: Dict[int, List[PolicyEntry]] = {}
        for n in axis_sizes:
            entries: List[PolicyEntry] = []
            prev_algo = None
            for s in SIZE_CLASSES:
                algo = _best_ar_algo(model, s, n)
                if prev_algo is None:
                    prev_algo = algo
                elif algo != prev_algo:
                    entries.append(PolicyEntry(s // 2, prev_algo))
                    prev_algo = algo
            entries.append(PolicyEntry(1 << 62, prev_algo or "xla"))
            ar[n] = entries
            a2a[n] = [
                PolicyEntry(64 * 1024, "xla"),
                PolicyEntry(1 << 62, "xla" if n <= 512 else "pairwise"),
            ]
        return CollectivePolicy(ar, a2a, {"source": "model"})

    @staticmethod
    def measure(mesh, axis: str, sizes: Optional[List[int]] = None,
                iters: int = 20) -> "CollectivePolicy":
        """Measured policy: times each algorithm on the live mesh (the tuning run
        the paper performed by hand, Sec. III-B)."""
        import numpy as np
        import jax
        from jax.sharding import PartitionSpec as P

        from .bench import time_fn

        sizes = sizes or [1 << k for k in range(10, 25, 2)]
        n = mesh.shape[axis]
        entries: List[PolicyEntry] = []
        results: Dict[int, str] = {}
        for s in sizes:
            elems = max(s // 4, n)
            x = np.random.randn(n, elems // n + 1).astype(np.float32)
            best, best_t = None, float("inf")
            for name, fn in coll.ALL_REDUCE_ALGOS.items():
                if not _is_pow2(n) and name in ("rabenseifner", "recursive_doubling", "tree"):
                    continue
                f = jax.jit(jax.shard_map(lambda v, fn=fn: fn(v, axis), mesh=mesh,
                                          in_specs=P(axis), out_specs=P(axis)))
                st = time_fn(f, x, iters=iters, warmup=3)
                if st.median < best_t:
                    best, best_t = name, st.median
            results[s] = best
        prev = None
        for s in sizes:
            if prev is not None and results[s] != prev:
                entries.append(PolicyEntry(s // 2, prev))
            prev = results[s]
        entries.append(PolicyEntry(1 << 62, prev or "xla"))
        return CollectivePolicy({n: entries}, {n: [PolicyEntry(1 << 62, "xla")]},
                                {"source": "measured"})

    # --------------------------------------------------------- persistence
    def save(self, path: str) -> None:
        blob = {
            "meta": self.meta,
            "all_reduce": {str(n): [dataclasses.asdict(e) for e in es]
                           for n, es in self.all_reduce_table.items()},
            "all_to_all": {str(n): [dataclasses.asdict(e) for e in es]
                           for n, es in self.all_to_all_table.items()},
        }
        with open(path, "w") as f:
            json.dump(blob, f, indent=2)

    @staticmethod
    def load(path: str) -> "CollectivePolicy":
        with open(path) as f:
            blob = json.load(f)
        parse = lambda d: {int(n): [PolicyEntry(**e) for e in es] for n, es in d.items()}
        return CollectivePolicy(parse(blob["all_reduce"]), parse(blob["all_to_all"]),
                                blob.get("meta", {}))


def _best_ar_algo(model: CommModel, nbytes: int, n: int) -> str:
    candidates = {
        "recursive_doubling": model.allreduce_intra(nbytes, "mpi", "recursive_doubling", n).seconds,
        "rabenseifner": model.allreduce_intra(nbytes, "mpi", "rabenseifner", n).seconds,
        "ring": model.allreduce_intra(nbytes, "ccl", "ring", n).seconds,
        "xla": model.allreduce_intra(nbytes, "ccl", "auto", n).seconds,
    }
    return min(candidates, key=candidates.get)


def default_policy() -> CollectivePolicy:
    return CollectivePolicy.from_model()
