"""At-scale scenario suite: the paper's Sec. V-VI sweeps as assertable curves.

The headline results of the paper are *inter-node*: allreduce/alltoall behavior
from 8 to 4096 GPUs on Slingshot dragonfly (Alps, LUMI), a tapered fat-tree
(Leonardo), and — for this repo's deployment target — the TPU multipod DCN.
This module drives `CommModel` + the `Fabric` layer over that grid and returns
structured points that tests (and `benchmarks.run at_scale`) can assert
qualitative paper shapes on:

  * alltoall weak-scaling goodput per endpoint decays monotonically toward the
    fabric's asymptotic per-endpoint bound (Sec. V-C / Fig. 9);
  * allreduce is hierarchical min-of-phases: goodput never exceeds the
    intra-node bound and flattens at the fabric phase (Sec. V-A / Fig. 10);
  * network noise costs allreduce ~2x more than alltoall at 1k+ endpoints
    (Sec. VI / Obs. 8), applied to the inter-tier traffic fraction only;
  * the untapped-bandwidth gap: achieved goodput vs the fabric bound.

Everything here is model-driven (closed-form alpha-beta over the fabric), so
sweeping to 4096 endpoints costs microseconds per point and runs in CI.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

from .costmodel import CommModel, make_comm_model
from .noise import NoiseModel
from .topology import TwoLevelTopology, make_paper_systems

DEFAULT_ENDPOINTS = (8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)
PAPER_SYSTEMS = ("alps", "leonardo", "lumi", "tpu_v5e")
DEFAULT_BYTES = 4 << 20  # per-endpoint buffer, the paper's large-message regime


@dataclasses.dataclass(frozen=True)
class ScenarioPoint:
    """One (system, collective, scale) evaluation of the at-scale model."""

    system: str
    collective: str            # "allreduce" | "alltoall"
    scaling: str               # "weak" | "strong"
    mechanism: str
    n_endpoints: int
    payload_bytes: float       # per-endpoint bytes at this scale
    seconds: float
    goodput_bytes_s: float     # payload / seconds (paper Sec. IV-A definition)
    tier: str                  # fabric distance tier spanned at this scale
    bound_bytes_s: float       # topology expected-goodput bound at this scale
    noisy_goodput_bytes_s: float  # goodput under the system's noise model


def system_noise(system: str) -> NoiseModel:
    """Noise model per paper system, built from the profile's Sec. VI numbers."""
    if system == "leonardo":
        return NoiseModel.leonardo_diff_group()
    if system == "tpu_v5e":
        return NoiseModel.tpu_dcn()
    return NoiseModel.isolated()  # Alps/LUMI: ~1% production noise (Obs. 6)


def sweep_collective(system: str, collective: str = "alltoall",
                     scaling: str = "weak", mechanism: str = "ccl",
                     endpoints: Sequence[int] = DEFAULT_ENDPOINTS,
                     bytes_per_endpoint: int = DEFAULT_BYTES,
                     model: Optional[CommModel] = None,
                     topo: Optional[TwoLevelTopology] = None,
                     noise: Optional[NoiseModel] = None) -> List[ScenarioPoint]:
    """One scaling curve: goodput per endpoint vs endpoint count.

    Weak scaling keeps the per-endpoint buffer fixed (the paper's setup);
    strong scaling keeps the *global* bytes fixed at
    `bytes_per_endpoint * endpoints[0]`, so per-endpoint payload shrinks and
    the latency terms surface at scale.
    """
    model = model or make_comm_model(system)
    topo = topo or make_paper_systems()[system]
    noise = noise or system_noise(system)
    nn = model.profile.endpoints_per_node
    total = float(bytes_per_endpoint) * endpoints[0]
    # topology bounds are pure functions of n: evaluate once per scale
    points: List[ScenarioPoint] = []
    for n in endpoints:
        s = float(bytes_per_endpoint) if scaling == "weak" else total / n
        if collective == "alltoall":
            cost = model.alltoall_at_scale(s, n, mechanism)
            bound = topo.alltoall_expected_goodput(n)
        elif collective == "allreduce":
            cost = model.allreduce_at_scale(s, n, mechanism)
            bound = topo.allreduce_expected_goodput(n)
        else:
            raise ValueError(collective)
        goodput = cost.goodput(s)
        tier = topo.tier_for_scale(n)
        noisy = goodput * noise.goodput_scaling(n, nn, collective)
        points.append(ScenarioPoint(system, collective, scaling, mechanism, n,
                                    s, cost.seconds, goodput, tier, bound, noisy))
    return points


def at_scale_suite(systems: Sequence[str] = PAPER_SYSTEMS,
                   endpoints: Sequence[int] = DEFAULT_ENDPOINTS,
                   bytes_per_endpoint: int = DEFAULT_BYTES,
                   mechanisms: Sequence[str] = ("ccl", "mpi"),
                   ) -> List[ScenarioPoint]:
    """The full paper grid: {system} x {allreduce, alltoall} x {weak, strong}
    x {mechanism} over the endpoint sweep."""
    topos = make_paper_systems()
    points: List[ScenarioPoint] = []
    for system in systems:
        model = make_comm_model(system)
        noise = system_noise(system)
        for collective in ("alltoall", "allreduce"):
            for scaling in ("weak", "strong"):
                for mech in mechanisms:
                    points.extend(sweep_collective(
                        system, collective, scaling, mech, endpoints,
                        bytes_per_endpoint, model=model, topo=topos[system],
                        noise=noise))
    return points


# ------------------------------------------------------- curve-shape oracles
def asymptote(system: str, topo: Optional[TwoLevelTopology] = None) -> float:
    """The per-endpoint bound an at-scale alltoall approaches (Sec. V-C)."""
    topo = topo or make_paper_systems()[system]
    return topo.alltoall_asymptotic_goodput()


def is_monotone_non_increasing(points: Sequence[ScenarioPoint],
                               rel_tol: float = 1e-6) -> bool:
    """Weak-scaling goodput must never rise with endpoint count."""
    gs = [p.goodput_bytes_s for p in points]
    return all(b <= a * (1 + rel_tol) for a, b in zip(gs, gs[1:]))


def check_paper_shapes(system: str,
                       endpoints: Sequence[int] = DEFAULT_ENDPOINTS,
                       bytes_per_endpoint: int = DEFAULT_BYTES) -> Dict[str, bool]:
    """Sec. V/VI qualitative observations as named booleans — the scenario
    suite's self-check, asserted by tests and the at_scale benchmark section."""
    topo = make_paper_systems()[system]
    model = make_comm_model(system)
    noise = system_noise(system)
    a2a = sweep_collective(system, "alltoall", "weak", "ccl", endpoints,
                           bytes_per_endpoint, model=model, topo=topo, noise=noise)
    ar = sweep_collective(system, "allreduce", "weak", "ccl", endpoints,
                          bytes_per_endpoint, model=model, topo=topo, noise=noise)
    asym = asymptote(system, topo)
    last = a2a[-1]
    intra_ar = topo.intra.allreduce_expected_goodput()
    nn = model.profile.endpoints_per_node
    n_big = endpoints[-1]
    return {
        # alltoall goodput decays toward (and never beats) the fabric bound
        "alltoall_monotone": is_monotone_non_increasing(a2a),
        "alltoall_bounded": all(p.goodput_bytes_s <= p.bound_bytes_s * 1.001
                                for p in a2a if p.n_endpoints > nn),
        "alltoall_approaches_asymptote": 0.0 < last.goodput_bytes_s <= asym
                                         and last.bound_bytes_s <= asym * 1.2,
        # allreduce is min-of-phases: never above the intra-node bound
        "allreduce_hierarchical_min": all(
            p.goodput_bytes_s <= intra_ar * 1.001 for p in ar),
        # Obs. 8: noise costs allreduce more than alltoall at scale
        "noise_hits_allreduce_harder":
            noise.goodput_scaling(n_big, nn, "allreduce")
            <= noise.goodput_scaling(n_big, nn, "alltoall"),
        # untapped bandwidth: the achieved curve sits below the fabric bound
        "untapped_bandwidth_gap": last.goodput_bytes_s < last.bound_bytes_s,
    }
