"""At-scale scenario suite: the paper's Sec. V-VI sweeps as assertable curves.

The headline results of the paper are *inter-node*: allreduce/alltoall behavior
from 8 to 4096 GPUs on Slingshot dragonfly (Alps, LUMI), a tapered fat-tree
(Leonardo), and — for this repo's deployment target — the TPU multipod DCN.
This module drives `CommModel` + the `Fabric` layer over that grid and returns
structured points that tests (and `benchmarks.run at_scale`) can assert
qualitative paper shapes on:

  * alltoall weak-scaling goodput per endpoint decays monotonically toward the
    fabric's asymptotic per-endpoint bound (Sec. V-C / Fig. 9);
  * allreduce is hierarchical min-of-phases: goodput never exceeds the
    intra-node bound and flattens at the fabric phase (Sec. V-A / Fig. 10);
  * network noise costs allreduce ~2x more than alltoall at 1k+ endpoints
    (Sec. VI / Obs. 8), applied to the inter-tier traffic fraction only;
  * the untapped-bandwidth gap: achieved goodput vs the fabric bound.

Everything here is model-driven (closed-form alpha-beta over the fabric), so
sweeping to 4096 endpoints costs microseconds per point and runs in CI.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import numpy as np

from .costmodel import (CommModel, exposed_comm_time, make_comm_model,
                        pipeline_params_at_scale)
from .noise import NoiseModel, ServiceLevelArbiter, TrafficClass
from .topology import TwoLevelTopology, make_paper_systems

DEFAULT_ENDPOINTS = (8, 16, 32, 64, 128, 256, 512, 1024, 2048, 4096)
PAPER_SYSTEMS = ("alps", "leonardo", "lumi", "tpu_v5e")
DEFAULT_BYTES = 4 << 20  # per-endpoint buffer, the paper's large-message regime


@dataclasses.dataclass(frozen=True)
class ScenarioPoint:
    """One (system, collective, scale) evaluation of the at-scale model."""

    system: str
    collective: str            # "allreduce" | "alltoall"
    scaling: str               # "weak" | "strong"
    mechanism: str
    n_endpoints: int
    payload_bytes: float       # per-endpoint bytes at this scale
    seconds: float
    goodput_bytes_s: float     # payload / seconds (paper Sec. IV-A definition)
    tier: str                  # fabric distance tier spanned at this scale
    bound_bytes_s: float       # topology expected-goodput bound at this scale
    noisy_goodput_bytes_s: float  # goodput under the system's noise model


def system_noise(system: str) -> NoiseModel:
    """Noise model per paper system, built from the profile's Sec. VI numbers."""
    if system == "leonardo":
        return NoiseModel.leonardo_diff_group()
    if system == "tpu_v5e":
        return NoiseModel.tpu_dcn()
    return NoiseModel.isolated()  # Alps/LUMI: ~1% production noise (Obs. 6)


def sweep_collective(system: str, collective: str = "alltoall",
                     scaling: str = "weak", mechanism: str = "ccl",
                     endpoints: Sequence[int] = DEFAULT_ENDPOINTS,
                     bytes_per_endpoint: int = DEFAULT_BYTES,
                     model: Optional[CommModel] = None,
                     topo: Optional[TwoLevelTopology] = None,
                     noise: Optional[NoiseModel] = None) -> List[ScenarioPoint]:
    """One scaling curve: goodput per endpoint vs endpoint count.

    Weak scaling keeps the per-endpoint buffer fixed (the paper's setup);
    strong scaling keeps the *global* bytes fixed at
    `bytes_per_endpoint * endpoints[0]`, so per-endpoint payload shrinks and
    the latency terms surface at scale.
    """
    model = model or make_comm_model(system)
    topo = topo or make_paper_systems()[system]
    noise = noise or system_noise(system)
    nn = model.profile.endpoints_per_node
    total = float(bytes_per_endpoint) * endpoints[0]
    # topology bounds are pure functions of n: evaluate once per scale
    points: List[ScenarioPoint] = []
    for n in endpoints:
        s = float(bytes_per_endpoint) if scaling == "weak" else total / n
        if collective == "alltoall":
            cost = model.alltoall_at_scale(s, n, mechanism)
            bound = topo.alltoall_expected_goodput(n)
        elif collective == "allreduce":
            cost = model.allreduce_at_scale(s, n, mechanism)
            bound = topo.allreduce_expected_goodput(n)
        else:
            raise ValueError(collective)
        goodput = cost.goodput(s)
        tier = topo.tier_for_scale(n)
        noisy = goodput * noise.goodput_scaling(n, nn, collective)
        points.append(ScenarioPoint(system, collective, scaling, mechanism, n,
                                    s, cost.seconds, goodput, tier, bound, noisy))
    return points


def at_scale_suite(systems: Sequence[str] = PAPER_SYSTEMS,
                   endpoints: Sequence[int] = DEFAULT_ENDPOINTS,
                   bytes_per_endpoint: int = DEFAULT_BYTES,
                   mechanisms: Sequence[str] = ("ccl", "mpi"),
                   ) -> List[ScenarioPoint]:
    """The full paper grid: {system} x {allreduce, alltoall} x {weak, strong}
    x {mechanism} over the endpoint sweep."""
    topos = make_paper_systems()
    points: List[ScenarioPoint] = []
    for system in systems:
        model = make_comm_model(system)
        noise = system_noise(system)
        for collective in ("alltoall", "allreduce"):
            for scaling in ("weak", "strong"):
                for mech in mechanisms:
                    points.extend(sweep_collective(
                        system, collective, scaling, mech, endpoints,
                        bytes_per_endpoint, model=model, topo=topos[system],
                        noise=noise))
    return points


# ------------------------------------------------------- curve-shape oracles
def asymptote(system: str, topo: Optional[TwoLevelTopology] = None) -> float:
    """The per-endpoint bound an at-scale alltoall approaches (Sec. V-C)."""
    topo = topo or make_paper_systems()[system]
    return topo.alltoall_asymptotic_goodput()


def is_monotone_non_increasing(points: Sequence[ScenarioPoint],
                               rel_tol: float = 1e-6) -> bool:
    """Weak-scaling goodput must never rise with endpoint count."""
    gs = [p.goodput_bytes_s for p in points]
    return all(b <= a * (1 + rel_tol) for a, b in zip(gs, gs[1:]))


def check_paper_shapes(system: str,
                       endpoints: Sequence[int] = DEFAULT_ENDPOINTS,
                       bytes_per_endpoint: int = DEFAULT_BYTES) -> Dict[str, bool]:
    """Sec. V/VI qualitative observations as named booleans — the scenario
    suite's self-check, asserted by tests and the at_scale benchmark section."""
    topo = make_paper_systems()[system]
    model = make_comm_model(system)
    noise = system_noise(system)
    a2a = sweep_collective(system, "alltoall", "weak", "ccl", endpoints,
                           bytes_per_endpoint, model=model, topo=topo, noise=noise)
    ar = sweep_collective(system, "allreduce", "weak", "ccl", endpoints,
                          bytes_per_endpoint, model=model, topo=topo, noise=noise)
    asym = asymptote(system, topo)
    last = a2a[-1]
    intra_ar = topo.intra.allreduce_expected_goodput()
    nn = model.profile.endpoints_per_node
    n_big = endpoints[-1]
    return {
        # alltoall goodput decays toward (and never beats) the fabric bound
        "alltoall_monotone": is_monotone_non_increasing(a2a),
        "alltoall_bounded": all(p.goodput_bytes_s <= p.bound_bytes_s * 1.001
                                for p in a2a if p.n_endpoints > nn),
        "alltoall_approaches_asymptote": 0.0 < last.goodput_bytes_s <= asym
                                         and last.bound_bytes_s <= asym * 1.2,
        # allreduce is min-of-phases: never above the intra-node bound
        "allreduce_hierarchical_min": all(
            p.goodput_bytes_s <= intra_ar * 1.001 for p in ar),
        # Obs. 8: noise costs allreduce more than alltoall at scale
        "noise_hits_allreduce_harder":
            noise.goodput_scaling(n_big, nn, "allreduce")
            <= noise.goodput_scaling(n_big, nn, "alltoall"),
        # untapped bandwidth: the achieved curve sits below the fabric bound
        "untapped_bandwidth_gap": last.goodput_bytes_s < last.bound_bytes_s,
    }


# ----------------------------------------------------------- overlap sweeps
DEFAULT_GRAD_BYTES = 1 << 30   # ~256M-param fp32 gradient, the sweep payload


def synthetic_grad_sizes(total_bytes: int = DEFAULT_GRAD_BYTES,
                         n_layers: int = 32) -> List[int]:
    """A transformer-shaped gradient byte list: one large embedding first (20%
    of the bytes, the forward's first / backward's last gradient) followed by
    `n_layers` equal decoder layers."""
    emb = total_bytes // 5
    per_layer = (total_bytes - emb) // n_layers
    sizes = [emb] + [per_layer] * n_layers
    sizes[-1] += total_bytes - sum(sizes)  # exact total
    return sizes


@dataclasses.dataclass(frozen=True)
class OverlapPoint:
    """One (system, scale, schedule) evaluation of the overlap predictor."""

    system: str
    n_endpoints: int
    bucket_bytes: int
    chunks: int
    compute_s: float
    total_comm_s: float
    exposed_s: float
    hidden_fraction: float
    wire: str = "fp32/fp32"   # intra/inter wire formats the point priced


# plan per topology: topologies are memoized singletons (core.topology), so
# identity is a stable key.  Entries hold a strong reference to the topology
# (keeps its id() from being recycled) and verify identity on hit, so a
# caller-supplied transient topology can never collide with a cached one.
_PLAN_CACHE: Dict[int, tuple] = {}


def plan_for(topo):
    """The (cached) CommPlan for a topology (shared across sweep loops)."""
    from .commplan import CommPlan

    hit = _PLAN_CACHE.get(id(topo))
    if hit is not None and hit[0] is topo:
        return hit[1]
    plan = CommPlan.from_topology(topo)
    _PLAN_CACHE[id(topo)] = (topo, plan)
    return plan


def sweep_overlap(system: str,
                  endpoints: Sequence[int] = DEFAULT_ENDPOINTS,
                  grad_bytes: int = DEFAULT_GRAD_BYTES,
                  compute_intensity: float = 1.0,
                  bucket_bytes: Optional[int] = None,
                  chunks: Optional[int] = None,
                  mechanism: str = "ccl",
                  model: Optional[CommModel] = None,
                  wire=None) -> List[OverlapPoint]:
    """Fraction of gradient-reduction time hidden behind backward compute vs
    endpoint count (Sec. VI: the overlap win the measured fabrics leave on the
    table).  `compute_intensity` scales the backward time relative to the
    *unhidden* comm time at each scale: 1.0 means backward exactly as long as
    the full reduction, >1 compute-bound, <1 comm-bound.  `bucket_bytes` /
    `chunks` override the plan's own choices to sweep the schedule knobs.
    `wire` prices compression: None = fp32 wire, ``"plan"`` = the plan's
    per-tier wire decision, or an explicit `wire.WireSpec`."""
    model = model or make_comm_model(system)
    topo = make_paper_systems()[system]
    plan = plan_for(topo)
    if bucket_bytes:
        plan = dataclasses.replace(plan, bucket_bytes=int(bucket_bytes))
    sizes = synthetic_grad_sizes(grad_bytes)
    points: List[OverlapPoint] = []
    for n in endpoints:
        base = exposed_comm_time(0.0, plan, sizes, n_endpoints=n, model=model,
                                 chunks=chunks, mechanism=mechanism, wire=wire)
        compute_s = compute_intensity * base.total_comm_s
        est = exposed_comm_time(compute_s, plan, sizes, n_endpoints=n,
                                model=model, chunks=chunks, mechanism=mechanism,
                                wire=wire)
        points.append(OverlapPoint(system, n, plan.bucket_bytes, est.chunks,
                                   compute_s, est.total_comm_s, est.exposed_s,
                                   est.hidden_fraction, est.wire))
    return points


def check_overlap_shapes(system: str,
                         endpoints: Sequence[int] = DEFAULT_ENDPOINTS,
                         grad_bytes: int = DEFAULT_GRAD_BYTES) -> Dict[str, bool]:
    """Qualitative shape checks tying `exposed_comm_time` to the paper's
    overlap story — the acceptance oracles for the overlap engine."""
    from .overlap import pipeline_time

    model = make_comm_model(system)
    n_big = endpoints[-1]
    # 1) hidden fraction grows with compute intensity (more backward to hide
    #    behind) and a compute-bound step hides nearly everything
    by_intensity = [sweep_overlap(system, (n_big,), grad_bytes, ci,
                                  model=model)[0].hidden_fraction
                    for ci in (0.25, 1.0, 4.0)]
    grows = all(b >= a - 1e-9 for a, b in zip(by_intensity, by_intensity[1:]))
    # 2) sanity: exposed in [0, total]; some comm is hidden at intensity 1
    pts = sweep_overlap(system, endpoints, grad_bytes, 1.0, model=model)
    bounded = all(0.0 <= p.exposed_s <= p.total_comm_s * (1 + 1e-9) for p in pts)
    some_hidden = all(p.hidden_fraction > 0.0 for p in pts)
    # 3) pipeline time is monotone non-increasing in chunk count until the
    #    per-chunk alpha terms dominate, then non-decreasing (unimodal) — and
    #    a latency-dominated payload is best left unchunked
    params = pipeline_params_at_scale(model, n_big)
    plan = plan_for(make_paper_systems()[system])
    depths = [1, 2, 4, 8, 16]
    times = [pipeline_time(plan.bucket_bytes, c, params) for c in depths]
    best = times.index(min(times))
    unimodal = (all(b <= a * (1 + 1e-9) for a, b in zip(times[:best + 1],
                                                        times[1:best + 1]))
                and all(b >= a * (1 - 1e-9) for a, b in zip(times[best:],
                                                            times[best + 1:])))
    tiny = [pipeline_time(256.0, c, params) for c in depths]
    alpha_dominated = tiny.index(min(tiny)) == 0
    # 4) at fixed compute time, scaling out (more exposed wire time per byte)
    #    never hides a larger fraction
    compute_s = pts[0].compute_s
    hf = []
    plan_sizes = synthetic_grad_sizes(grad_bytes)
    for n in endpoints:
        est = exposed_comm_time(compute_s, plan, plan_sizes, n_endpoints=n,
                                model=model)
        hf.append(est.hidden_fraction)
    scale_monotone = all(b <= a + 1e-9 for a, b in zip(hf, hf[1:]))
    return {
        "hidden_grows_with_compute": grows,
        "compute_bound_hides_most": by_intensity[-1] >= 0.9,
        "exposed_bounded": bounded,
        "overlap_always_helps": some_hidden,
        "chunks_monotone_until_alpha": unimodal,
        "tiny_payload_unchunked": alpha_dominated,
        "scaling_out_exposes_more": scale_monotone,
    }


# ------------------------------------------------------- MoE alltoall sweeps
DEFAULT_ROUTER_BYTES = 1 << 20   # dense (router) gradient riding the allreduce


@dataclasses.dataclass(frozen=True)
class MoEPoint:
    """One (system, scale) evaluation of the planned MoE alltoall program."""

    system: str
    n_endpoints: int           # expert-parallel axis size the exchange spans
    payload_bytes: float       # per-endpoint dispatch buffer (= combine)
    algo: str                  # alltoall schedule the plan's tier table ranks
    tier: str                  # fabric distance tier at this scale
    exchange_s: float          # one token exchange at the dispatched algo
    step_comm_s: float         # program-priced step: 2x(dispatch+combine)+router
    goodput_bytes_s: float     # payload / exchange_s (Sec. IV-A definition)
    ep_group: int              # fabric-confined expert-group size at this scale
    n_replicas: int            # expert replicas tiling the remaining endpoints


def moe_expert_placement(topo: TwoLevelTopology, n_endpoints: int):
    """Fabric-tier-aware expert placement: the EP group is the largest
    power-of-two subset of the job whose packed span stays off the global
    links (``tier_for_scale`` at most ``same_group``), so dispatch/combine
    alltoalls never cross a dragonfly group boundary; the remaining factor
    tiles expert replicas (pure DP over identical groups).  Returns
    ``(ep_group, n_replicas)``; on fabrics with no ``diff_group`` tier at this
    scale the group is the whole job."""
    group = 1
    n = 1
    while n <= n_endpoints:
        if topo.tier_for_scale(n) != "diff_group":
            group = n
        n *= 2
    return group, max(n_endpoints // group, 1)


def sweep_moe_alltoall(system: str,
                       endpoints: Sequence[int] = DEFAULT_ENDPOINTS,
                       payload_bytes: int = DEFAULT_BYTES,
                       router_bytes: int = DEFAULT_ROUTER_BYTES,
                       mechanism: str = "ccl",
                       model: Optional[CommModel] = None,
                       confine: bool = False) -> List[MoEPoint]:
    """Planned MoE step comm vs endpoint count — the IR's first non-allreduce
    pattern swept to 4096 endpoints.  Every point prices the *same*
    ``moe_step_program()`` object the runtime compiles: `exposed_comm_time`
    walks its nodes (two alltoall exchanges, each charged forward+backward,
    plus the router's dense allreduce), and the recorded ``algo`` is what the
    plan's per-(size, tier) table dispatches at that scale — pairwise forced
    beyond 512 endpoints or at a group boundary (Obs. 7).  ``confine=True``
    shrinks the EP axis to the `moe_expert_placement` group (replicas tile the
    rest), the placement the tentpole plans on dragonfly fabrics."""
    from . import program as prg

    model = model or make_comm_model(system)
    topo = make_paper_systems()[system]
    plan = plan_for(topo)
    program = prg.moe_step_program()
    sizes = [float(payload_bytes), float(payload_bytes), float(router_bytes)]
    points: List[MoEPoint] = []
    for n in endpoints:
        group, replicas = moe_expert_placement(topo, n)
        ep = group if confine else n
        est = exposed_comm_time(0.0, plan, sizes, n_endpoints=ep, model=model,
                                mechanism=mechanism, program=program)
        algo = plan.all_to_all_algo(int(payload_bytes), ep)
        mech = mechanism if algo == "xla" else "mpi"
        exch = model.alltoall_at_scale(float(payload_bytes), ep,
                                       mechanism=mech).seconds
        points.append(MoEPoint(system, ep, float(payload_bytes), algo,
                               topo.tier_for_scale(ep), exch, est.total_comm_s,
                               float(payload_bytes) / exch if exch > 0
                               else float("inf"),
                               group, replicas if confine else 1))
    return points


def check_moe_shapes(system: str,
                     endpoints: Sequence[int] = DEFAULT_ENDPOINTS,
                     payload_bytes: int = DEFAULT_BYTES) -> Dict[str, bool]:
    """The MoE program's qualitative acceptance oracles, mirroring
    `check_paper_shapes`: the planned alltoall keeps the paper's at-scale
    behavior, Obs. 7's pairwise forcing actually fires, and the program pricer
    agrees with the ``schedule=`` tables it replaced."""
    topo = make_paper_systems()[system]
    model = make_comm_model(system)
    pts = sweep_moe_alltoall(system, endpoints, payload_bytes, model=model)
    confined = sweep_moe_alltoall(system, endpoints, payload_bytes,
                                  model=model, confine=True)
    forced = [p for p in pts
              if p.n_endpoints > 512 or p.tier == "diff_group"]
    return {
        # weak-scaling goodput never rises with endpoint count *at a fixed
        # schedule* — the dispatched-best curve may jump at an algorithm
        # switch (that discontinuity is the paper's point), so monotonicity is
        # asserted over the forced-pairwise tail where the schedule is pinned
        "alltoall_monotone": all(
            b.goodput_bytes_s <= a.goodput_bytes_s * (1 + 1e-6)
            for a, b in zip(forced, forced[1:])),
        # the program pricer charges each exchange forward+backward: the
        # node-walked step time can never undercut the four raw exchanges
        "pricer_prices_program_nodes": all(
            p.step_comm_s >= 4.0 * p.exchange_s * (1 - 1e-9) for p in pts),
        # the program is priceable at the paper's largest scale (pairwise is
        # the schedule that *stays* finite where CCL alltoall falls over)
        "finite_at_4096": all(
            p.step_comm_s < float("inf") for p in pts
            if p.n_endpoints == endpoints[-1]),
        # Obs. 7: pairwise forced beyond 512 endpoints / at group boundaries
        "pairwise_forced_at_scale": bool(forced) and all(
            p.algo == "pairwise" for p in forced),
        # fabric-confined placement never spans the global links, and the
        # confined exchange is never slower than the unconfined one
        "placement_stays_in_group": all(
            p.tier != "diff_group" for p in confined),
        "placement_never_hurts": all(
            c.exchange_s <= u.exchange_s * (1 + 1e-6)
            for c, u in zip(confined, pts)),
    }


def moe_executed_path_oracle(cfg=None, mesh=None, axis: str = "data",
                             plan=None, batch: int = 8,
                             seq: int = 16) -> Dict:
    """Executed-path oracle: a planned MoE step traced on the *live* mesh must
    dispatch the same alltoall algorithm the sweep's table ranks first for its
    (payload, axis size).  Builds `runtime.moe_step.build_moe_ep_step`, runs
    one step, and compares the plan's ``all_to_all_algo/*`` stats against the
    modeled `all_to_all_algo` lookup at `dispatch_bytes`.  Returns
    ``{"modeled", "executed", "match", "payload_bytes", "n"}``; on a
    single-device mesh the exchange is the identity and `match` is vacuous."""
    import jax

    from ..configs.base import get_config
    from ..optim import adamw
    from ..runtime import moe_step as ms
    from .autotune import CollectivePolicy

    cfg = cfg or get_config("deepseek-moe-16b").reduced()
    if mesh is None:
        from jax.sharding import AxisType
        n_dev = jax.device_count()
        mesh = jax.make_mesh((n_dev,), (axis,),
                             axis_types=(AxisType.Auto,))
    n = mesh.shape[axis]
    policy = (CollectivePolicy.from_plan(plan) if plan is not None
              else CollectivePolicy.from_model())
    pl = policy._as_plan()
    pl.reset_stats()
    step = ms.build_moe_ep_step(cfg, adamw.OptConfig(), mesh, axis=axis,
                                policy=policy)
    params = ms.moe_ep_params(cfg, jax.random.PRNGKey(0))
    data = ms.moe_ep_batch(cfg, jax.random.PRNGKey(1), batch, seq)
    opt_state = adamw.init_opt_state(params)
    step(params, opt_state, data, step.init_error_state(params))
    nbytes = ms.dispatch_bytes(cfg, batch // n, seq)
    modeled = pl.all_to_all_algo(nbytes, n)
    executed = sorted(k.split("/", 1)[1] for k, v in pl.stats.items()
                      if k.startswith("all_to_all_algo/") and v > 0)
    return {"modeled": modeled, "executed": executed,
            "match": executed == [modeled] if n > 1 else not executed,
            "payload_bytes": nbytes, "n": n}


# ---------------------------------- messy-fabric degradation (ROADMAP item 4)
# Guarded-vs-oblivious step-time degradation under the paper's interference
# modes, closed-form over the same cost model the runtime's DriftGuard trusts.
# "Oblivious" keeps paying the degraded fabric with the stale plan;
# "guarded" pays the detection window + a re-plan overhead, then runs with
# the mitigated cost (SL separation, re-ranked tables around the bad pairs,
# bounded straggler exposure, or an elastic re-mesh).  congestion_incast is
# the Fig. 12 control: endpoint-link saturation that no re-plan can fix —
# the guard's predicted win is ~0, the swap is rejected, and guarded pays
# only the probe.

MESSY_SCENARIOS = ("congestion", "congestion_incast", "link_flap",
                   "hetero_bw", "straggler", "node_loss")


@dataclasses.dataclass(frozen=True)
class DegradationPoint:
    """One (system, scenario, scale) guarded-vs-oblivious evaluation."""

    system: str
    scenario: str
    n_endpoints: int
    step_clean_s: float
    step_oblivious_s: float
    step_guarded_s: float
    degradation_oblivious: float   # step_oblivious / step_clean
    degradation_guarded: float
    guarded_wins: bool


def _degraded_step(c: float, e: float, T: float, k: float) -> float:
    """Step time when the fabric runs `k` x slower: the backward still hides
    its `T - e` of comm, the extra `(k - 1) T` all drains past it."""
    return c + e + max(k - 1.0, 0.0) * T


def _congestion_factors(incast: bool) -> tuple:
    """(k_oblivious, k_guarded) comm slowdowns for the multi-tenant scenario,
    from the SL arbiter (Sec. VI-A): the victim shares the production SL with
    a 3x-demand aggressor; the guarded runtime's re-plan moves it to its own
    SL.  Incast congests the destination endpoint link instead — SL
    separation cannot help (Fig. 12), so guarded == oblivious on the fabric."""
    arb = ServiceLevelArbiter(link_bw=1.0, endpoint_bw=0.5)
    victim = TrafficClass("allreduce", 0, 1.0)
    pattern = "incast" if incast else "alltoall"
    g_obl = arb.victim_goodput(victim, [TrafficClass("aggr", 0, 3.0)], pattern)
    g_grd = arb.victim_goodput(victim, [TrafficClass("aggr", 1, 3.0)], pattern)
    return 1.0 / max(g_obl, 1e-9), 1.0 / max(g_grd, 1e-9)


def sweep_degradation(system: str, scenario: str,
                      endpoints: Sequence[int] = DEFAULT_ENDPOINTS,
                      grad_bytes: int = DEFAULT_GRAD_BYTES,
                      compute_intensity: float = 1.0,
                      seed: int = 0,
                      detect_steps: int = 4,
                      replan_steps: int = 2,
                      horizon_steps: int = 64,
                      model: Optional[CommModel] = None
                      ) -> List[DegradationPoint]:
    """Guarded-vs-oblivious mean step time under one interference scenario.

    All quantities are per-step means over a `horizon_steps` window around
    the fault: the guarded runtime pays `detect_steps` of oblivious cost
    (the EWMA band's patience) plus `replan_steps` of clean-step time for the
    probe/refit/swap, amortized over the horizon.  Mitigation factors come
    from the models the guard actually consults — the SL arbiter for
    congestion, seeded per-pair bandwidth draws for hetero_bw
    (arXiv:2302.14827's MI250x spread), the straggler mitigator's bounded
    exposure, and a real re-priced `exposed_comm_time` at the surviving
    endpoint count for node_loss.
    """
    if scenario not in MESSY_SCENARIOS:
        raise ValueError(f"unknown scenario {scenario!r}; one of "
                         f"{MESSY_SCENARIOS}")
    model = model or make_comm_model(system)
    topo = make_paper_systems()[system]
    plan = plan_for(topo)
    sizes = synthetic_grad_sizes(grad_bytes)
    nn = model.profile.endpoints_per_node
    w_detect = detect_steps / horizon_steps
    overhead = replan_steps / horizon_steps   # in units of clean step time
    rng = np.random.default_rng(seed)
    points: List[DegradationPoint] = []
    for n in endpoints:
        base = exposed_comm_time(0.0, plan, sizes, n_endpoints=n, model=model)
        c = compute_intensity * base.total_comm_s
        est = exposed_comm_time(c, plan, sizes, n_endpoints=n, model=model)
        T, e = est.total_comm_s, est.exposed_s
        t_clean = c + e
        if scenario in ("congestion", "congestion_incast"):
            k_obl, k_grd = _congestion_factors(scenario == "congestion_incast")
            t_obl = _degraded_step(c, e, T, k_obl)
            if scenario == "congestion_incast":
                # predicted win ~0: the guard rejects the swap and pays only
                # the probe — guarded is the oblivious time plus overhead
                t_grd = t_obl + overhead * t_clean
            else:
                t_grd = (w_detect * t_obl
                         + (1 - w_detect) * _degraded_step(c, e, T, k_grd)
                         + overhead * t_clean)
        elif scenario == "link_flap":
            # bursty: one L-step flap episode per horizon at the congestion
            # factor; the guard detects within each episode, then mitigates
            k_obl, k_grd = _congestion_factors(False)
            L = 16
            p = L / horizon_steps
            w_ep = min(detect_steps / L, 1.0)
            t_deg_o = _degraded_step(c, e, T, k_obl)
            t_deg_g = _degraded_step(c, e, T, k_grd)
            t_obl = (1 - p) * t_clean + p * t_deg_o
            t_grd = ((1 - p) * t_clean
                     + p * (w_ep * t_deg_o + (1 - w_ep) * t_deg_g)
                     + overhead * t_clean)
        elif scenario == "hetero_bw":
            # seeded per-pair bandwidth spread (lognormal, mean 1): the
            # oblivious ring is bound by the worst pair it crosses; the
            # re-ranked plan routes/rebuckets around it (median-pair bound)
            m = int(min(max(n, 2), 64))
            mult = rng.lognormal(mean=-0.08, sigma=0.4, size=m)
            k_obl = max(1.0 / float(mult.min()), 1.0)
            k_grd = max(1.0 / float(np.median(mult)), 1.0)
            t_obl = _degraded_step(c, e, T, k_obl)
            t_grd = (w_detect * t_obl
                     + (1 - w_detect) * _degraded_step(c, e, T, k_grd)
                     + overhead * t_clean)
        elif scenario == "straggler":
            # a slow device drags every synchronous step it participates in;
            # the mitigator detects past the warmup and bounds the exposure
            # (sync resynchronization recovers most of the compounding)
            p, s = 0.15, 3.0
            s_grd = 1.0 + (s - 1.0) * 0.35
            t_obl = t_clean * ((1 - p) + p * s)
            t_grd = (t_clean * ((1 - p) + p * (w_detect * s
                                               + (1 - w_detect) * s_grd))
                     + overhead * t_clean)
        else:  # node_loss
            # mid-horizon loss of one node: the oblivious runtime stalls (its
            # mesh contains a dead device — every remaining step is lost);
            # the guarded runtime re-meshes on the survivors and re-prices
            n_surv = max(n - nn, 2)
            est_s = exposed_comm_time(c * n / n_surv, plan, sizes,
                                      n_endpoints=n_surv, model=model)
            t_surv = c * n / n_surv + est_s.exposed_s
            t_obl = 2.0 * t_clean          # half the horizon's work is lost
            t_grd = (0.5 * t_clean + 0.5 * t_surv
                     + 2 * overhead * t_clean)  # restore + replan
        points.append(DegradationPoint(
            system, scenario, n, t_clean, t_obl, t_grd,
            t_obl / t_clean, t_grd / t_clean,
            guarded_wins=t_grd < t_obl * (1 - 1e-9)))
    return points


def check_degradation_shapes(system: str,
                             endpoints: Sequence[int] = DEFAULT_ENDPOINTS
                             ) -> Dict[str, bool]:
    """Named oracles over the messy-fabric family (asserted by
    `benchmarks.run faults` and the scenario tests)."""
    by_scen = {s: sweep_degradation(system, s, endpoints)
               for s in MESSY_SCENARIOS}
    helped = [s for s in MESSY_SCENARIOS if s != "congestion_incast"]
    congestion = by_scen["congestion"]
    return {
        # the guard never loses where a mitigation exists
        "guarded_never_worse": all(
            p.step_guarded_s <= p.step_oblivious_s * (1 + 1e-9)
            for s in helped for p in by_scen[s]),
        # strict wins on the two scenarios BENCH_10 gates on
        "congestion_strict_win": all(p.guarded_wins for p in congestion),
        "straggler_strict_win": all(p.guarded_wins
                                    for p in by_scen["straggler"]),
        # Fig. 12: incast saturates the endpoint link — SL separation cannot
        # help, the swap is rejected, and the guard only pays its probe
        "incast_immune_to_sl": all(
            p.step_guarded_s >= p.step_oblivious_s
            for p in by_scen["congestion_incast"]),
        # congestion hurts more at scale (the comm share grows)
        "degradation_grows_with_scale":
            congestion[-1].degradation_oblivious
            >= congestion[0].degradation_oblivious - 1e-9,
        # the heterogeneity win exists at every scale (min-pair vs median)
        "hetero_win_everywhere": all(p.guarded_wins
                                     for p in by_scen["hetero_bw"]),
        # elastic re-mesh beats losing the rest of the run
        "node_loss_win": all(p.guarded_wins for p in by_scen["node_loss"]),
    }
