"""Topology-aware collective planning: topology -> cost model -> dispatch plan.

The paper's headline software finding (Obs. 1/4, Fig. 11) is that the best
collective algorithm depends on message size, endpoint count, *and the topology
underneath*.  `CommPlan` closes that loop: it is built *from* a `LinkGraph` or
`TwoLevelTopology` (not from flat alpha-beta constants), ranks every algorithm
registered in `core.collectives` with topology-derived bandwidths
(`allreduce_expected_goodput` / `alltoall_expected_goodput` / EFI, paper
Secs. IV-A/IV-C), and emits size-threshold dispatch tables for all-reduce,
all-to-all, reduce-scatter, and all-gather.

Two-level topologies (pod x DCN, paper Sec. V) additionally enable the
hierarchical multi-axis path: whenever the caller can name both an intra
(ici) and an inter (dcn) mesh axis, dispatch selects
`collectives.hierarchical_all_reduce` — intra RS, inter AR on 1/n_intra of the
bytes, intra AG — the bandwidth-correct schedule when DCN << ICI.

The plan also fixes the runtime's **gradient bucket size** from its own
latency/bandwidth crossover: the byte size where the chosen large-message
algorithm's per-message latency term drops below ~5% of its bandwidth term
(the paper's message-aggregation optimization).  `runtime.steps` coalesces the
flat gradient list into buckets of this size before reduction.

Persistence is JSON, a superset of the legacy `CollectivePolicy` format
(`core.autotune` is now a thin builder/persistence shim over this module).
"""
from __future__ import annotations

import dataclasses
import json
import math
from typing import Callable, Dict, List, Optional, Tuple, Union

from . import hw
from . import collectives as coll
from .costmodel import (CCL_KERNEL_ALPHA, CCL_SMALL_FLOOR,
                        MECH_EFFICIENCY_COLLECTIVE)
from .topology import LinkGraph, TwoLevelTopology

SIZE_CLASSES = [1 << k for k in range(8, 31, 2)]  # 256 B .. 1 GiB

# Schedule efficiency: explicit ppermute schedules are derived from the graph,
# so they achieve most of the topology bound; the vendor ("xla") path is the
# *CCL analog and uses the calibrated collective efficiency from costmodel.
EXPLICIT_EFF = 0.90
XLA_EFF = MECH_EFFICIENCY_COLLECTIVE["ccl"]

DEFAULT_BUCKET_BYTES = 4 << 20
MIN_BUCKET_BYTES = 256 << 10
MAX_BUCKET_BYTES = 64 << 20

LOG2 = lambda n: max(1, int(math.ceil(math.log2(max(n, 2)))))


def _is_pow2(n: int) -> bool:
    return n > 0 and n & (n - 1) == 0


@dataclasses.dataclass
class PlanEntry:
    """One size-threshold row: use `algorithm` for payloads <= max_bytes."""
    max_bytes: int
    algorithm: str


Table = Dict[int, List[PlanEntry]]


def _infer_profile(graph: LinkGraph) -> hw.SystemProfile:
    """Map a graph back to the system profile that owns its latency constants.
    Topology gives bandwidth structure; alpha terms must come from hw."""
    name = graph.name
    for prefix, system in (("lumi", "lumi"), ("alps", "alps"),
                           ("leonardo", "leonardo"), ("v5e", "tpu_v5e"),
                           ("torus", "tpu_v5e"), ("ring", "tpu_v5e")):
        if name.startswith(prefix):
            return hw.SYSTEMS[system]
    return hw.SYSTEMS["tpu_v5e"]


# --------------------------------------------------------------- cost ranking
@dataclasses.dataclass(frozen=True)
class _TopoBw:
    """Topology-derived effective bandwidths (bytes/s) feeding the rankers."""
    allreduce: float      # multi-ring / pipelined-tree capacity (Sec. IV-C)
    alltoall: float       # injection / EFI bound (Sec. IV-A)
    hop: float            # bottleneck single hop on a Hamiltonian ring
    pair: float           # best direct pair
    pair_bottleneck: float  # concurrent all-pairs goodput (EFI-limited)
    injection: float


def _topo_bw(graph: LinkGraph) -> _TopoBw:
    fc = graph._is_fully_connected()
    return _TopoBw(
        allreduce=graph.allreduce_expected_goodput(),
        alltoall=graph.alltoall_expected_goodput(),
        hop=graph.pair_bw(0, 1) if fc else graph.link_bw,
        pair=graph.pair_bw(0, 1),
        pair_bottleneck=graph.bottleneck_pair_goodput(),
        injection=graph.injection_bw(0),
    )


def _ar_costs(bw: _TopoBw, a_exp: float, a_xla: float, n: int, s: float,
              scale_bw: Optional[float] = None, eff_exp: float = EXPLICIT_EFF,
              eff_xla: float = XLA_EFF,
              floor_xla: float = CCL_SMALL_FLOOR) -> Dict[str, float]:
    """Seconds per registered all-reduce algorithm; topology enters through
    `bw`, scale (axis sizes beyond the graph) through `scale_bw`, measured
    calibration through the eff/floor overrides."""
    frac = (n - 1) / n
    b_ar = (scale_bw if scale_bw is not None else bw.allreduce) * eff_exp
    # beyond the graph, every schedule crosses the at-scale bottleneck: the
    # ring family's per-hop bandwidth degrades along with the aggregate bound
    b_hop = (min(bw.hop, scale_bw) if scale_bw is not None else bw.hop) * eff_exp
    return {
        "ring": 2 * (n - 1) * a_exp + 2 * s * frac / b_hop,
        "bidir_ring": 2 * (n - 1) * a_exp + s * frac / b_hop,
        "rabenseifner": 2 * LOG2(n) * a_exp + 2 * s * frac / b_ar,
        "recursive_doubling": LOG2(n) * a_exp + s * LOG2(n) / (bw.pair_bottleneck * eff_exp),
        "tree": 2 * LOG2(n) * a_exp + 2 * s / (bw.pair_bottleneck * eff_exp),
        # explicit one-shot lowers to an all-gather (log-depth) + local reduce
        "one_shot": LOG2(n) * a_exp + (n - 1) * s / (bw.injection * eff_exp),
        "xla": max(floor_xla,
                   2 * LOG2(n) * a_xla + 2 * s * frac
                   / ((scale_bw if scale_bw is not None else bw.allreduce) * eff_xla)),
    }


def _a2a_costs(bw: _TopoBw, a_exp: float, a_xla: float, n: int, s: float,
               scale_bw: Optional[float] = None, eff_exp: float = EXPLICIT_EFF,
               eff_xla: float = XLA_EFF,
               floor_xla: float = CCL_SMALL_FLOOR) -> Dict[str, float]:
    b_a2a = (scale_bw if scale_bw is not None else bw.alltoall)
    b_pair = (min(bw.pair_bottleneck, scale_bw) if scale_bw is not None
              else bw.pair_bottleneck)
    return {
        "pairwise": (n - 1) * (a_exp + (s / n) / (b_pair * eff_exp)),
        "xla": max(floor_xla,
                   min(n - 1, 8) * a_xla + s / (b_a2a * eff_xla)),
    }


def _rs_costs(bw: _TopoBw, a_exp: float, a_xla: float, n: int, s: float,
              eff_exp: float = EXPLICIT_EFF, eff_xla: float = XLA_EFF,
              floor_xla: float = CCL_SMALL_FLOOR) -> Dict[str, float]:
    frac = (n - 1) / n
    return {
        "ring": (n - 1) * a_exp + s * frac / (bw.hop * eff_exp),
        "xla": max(floor_xla,
                   LOG2(n) * a_xla + s * frac / (bw.allreduce * eff_xla)),
    }


_COSTS_BY_KIND: Dict[str, Callable[..., Dict[str, float]]] = {
    "all_reduce": _ar_costs,
    "all_to_all": _a2a_costs,
    "reduce_scatter": _rs_costs,
    "all_gather": _rs_costs,  # mirror of reduce-scatter (same wire pattern)
}


def _rank_entries(kind: str, bw: _TopoBw, a_exp: float, a_xla: float, n: int,
                  scale_bw: Optional[float] = None, eff_exp: float = EXPLICIT_EFF,
                  eff_xla: float = XLA_EFF,
                  floor_xla: float = CCL_SMALL_FLOOR) -> List[PlanEntry]:
    """Compress per-size-class winners into threshold entries, restricted to
    algorithms actually present in the registry (and pow2-legal for this n)."""
    specs = coll.registered(kind, multi_axis=False)
    cost_fn = _COSTS_BY_KIND[kind]
    extra = {"scale_bw": scale_bw} if kind in ("all_reduce", "all_to_all") else {}
    entries: List[PlanEntry] = []
    prev = None
    for s in SIZE_CLASSES:
        costs = cost_fn(bw, a_exp, a_xla, n, float(s), eff_exp=eff_exp,
                        eff_xla=eff_xla, floor_xla=floor_xla, **extra)
        legal = {name: t for name, t in costs.items()
                 if name in specs and (_is_pow2(n) or not specs[name].pow2_only)}
        algo = min(legal, key=legal.get)
        if prev is None:
            prev = algo
        elif algo != prev:
            entries.append(PlanEntry(s // 2, prev))
            prev = algo
    entries.append(PlanEntry(1 << 62, prev or "xla"))
    return entries


# -------------------------------------------------------------------- CommPlan
@dataclasses.dataclass
class CommPlan:
    """Complete topology-derived dispatch plan.

    Tables map axis_size -> threshold entries; lookups snap to the nearest
    configured axis size in log space.  `stats` counts trace-time dispatches
    (message sizes are static under jit, so this is free and exact)."""

    all_reduce_table: Table
    all_to_all_table: Table
    reduce_scatter_table: Table
    all_gather_table: Table
    bucket_bytes: int = DEFAULT_BUCKET_BYTES
    hierarchical: bool = False
    meta: Dict[str, str] = dataclasses.field(default_factory=dict)
    # axis_size -> fabric distance tier the tables were ranked at ("intra" for
    # sizes inside the node/pod graph); empty for single-level plans.
    tiers: Dict[int, str] = dataclasses.field(default_factory=dict)
    # per-tier alpha-beta constants of the hierarchical pipeline (n_ici,
    # alpha_ici, bw_ici, alpha_dcn, bw_dcn) — feeds `pipeline_chunks` and the
    # overlap predictor; empty for single-level plans.
    pipeline: Dict[str, float] = dataclasses.field(default_factory=dict)
    # per-tier gradient wire formats ({"intra": ..., "inter": ...}, see
    # core.wire): chosen from the same alpha-beta fits as `pipeline` — compress
    # where bandwidth-bound, fp32 where alpha-bound.  Empty = fp32 everywhere
    # (legacy plans).
    wire: Dict[str, str] = dataclasses.field(default_factory=dict)
    # the chosen StepProgram (core.program) as its JSON dict: the plan's
    # recommended schedule for a training step on this topology.  One
    # artifact feeds the runtime compiler, the program pricer, dryrun,
    # scenarios, and hillclimb; empty for legacy plans.
    program: Dict = dataclasses.field(default_factory=dict)
    stats: Dict[str, int] = dataclasses.field(default_factory=dict, compare=False)

    # ------------------------------------------------------------- builders
    @classmethod
    def from_topology(cls, topo: Union[LinkGraph, TwoLevelTopology],
                      profile: Optional[hw.SystemProfile] = None,
                      axis_sizes: Optional[Tuple[int, ...]] = None,
                      calibration: Optional[object] = None) -> "CommPlan":
        """Rank the registry from topology-derived bandwidths.  With
        `calibration` (a `calibrate.CalibrationProfile`), the analytic alpha
        constants and schedule efficiencies are replaced by the measured fits,
        so tables and bucket size reflect the machine the sweep ran on."""
        two_level = isinstance(topo, TwoLevelTopology)
        graph = topo.intra if two_level else topo
        profile = profile or _infer_profile(graph)
        a_exp = profile.intra_latency.mpi
        a_xla = profile.intra_latency.ccl + CCL_KERNEL_ALPHA
        bw = _topo_bw(graph)
        effs = {kind: (EXPLICIT_EFF, XLA_EFF) for kind in _COSTS_BY_KIND}
        floor_xla = CCL_SMALL_FLOOR
        if calibration is not None:
            a_exp, a_xla, effs, floor_xla = _calibrated_params(
                calibration, bw, a_exp, a_xla, floor_xla)
        if axis_sizes is None:
            axis_sizes = tuple(sorted({2, 4, 8, 16, 64, 256, 512, graph.n, topo.n}))
        fabric = topo.fabric if two_level else None
        ar: Table = {}
        a2a: Table = {}
        rs: Table = {}
        ag: Table = {}
        tiers: Dict[int, str] = {}
        for n in axis_sizes:
            if n < 2:
                continue
            # beyond the single-level graph, ring-family bandwidth degrades to
            # the topology's own at-scale model (Sec. V) when we have one, and
            # the step latency rises to the spanned distance tier's alpha —
            # tables are ranked per (endpoint count, distance tier)
            scale_ar = scale_a2a = None
            a_exp_n, a_xla_n = a_exp, a_xla
            if n > graph.n:
                if two_level:
                    scale_ar = topo.allreduce_expected_goodput(n)
                    scale_a2a = topo.alltoall_expected_goodput(n)
                    tier = fabric.tier_for_scale(n)
                    tiers[n] = tier
                    a_tier = getattr(profile, f"inter_latency_{tier}", None) \
                        if tier != "same_node" else None
                    if a_tier is not None:
                        a_exp_n = max(a_exp, a_tier)
                        a_xla_n = max(a_xla, a_tier + CCL_KERNEL_ALPHA)
                else:
                    scale_ar = bw.allreduce
                    scale_a2a = bw.alltoall
            elif two_level:
                tiers[n] = "intra"
            rank = lambda kind, scale=None: _rank_entries(
                kind, bw, a_exp_n, a_xla_n, n, scale, eff_exp=effs[kind][0],
                eff_xla=effs[kind][1], floor_xla=floor_xla)
            ar[n] = rank("all_reduce", scale_ar)
            a2a[n] = rank("all_to_all", scale_a2a)
            rs[n] = rank("reduce_scatter")
            ag[n] = rank("all_gather")
        n_full = max(topo.n, 2)
        slowest = (topo.allreduce_expected_goodput(n_full) if two_level
                   else bw.allreduce) * effs["all_reduce"][0]
        bucket = _bucket_from_crossover(a_exp, 2 * LOG2(n_full), slowest)
        pipeline: Dict[str, float] = {}
        wire_fmt: Dict[str, str] = {}
        if two_level:
            # per-tier alpha-beta for the chunked hierarchical pipeline: the
            # intra phases run at the graph's allreduce bound, the inter phase
            # at the fabric tier the full topology spans (capped by the NIC)
            tier = fabric.tier_for_scale(topo.n)
            a_dcn = getattr(profile, f"inter_latency_{tier}",
                            profile.inter_latency_diff_group) \
                if tier != "same_node" else profile.inter_latency_same_switch
            pipeline = {
                "n_ici": float(graph.n),
                "alpha_ici": a_exp,
                "bw_ici": bw.allreduce * effs["all_reduce"][0],
                "alpha_dcn": a_dcn,
                "bw_dcn": min(profile.nic_bw, fabric.tier_bw(tier))
                          * effs["all_reduce"][0],
            }
        # wire-format decision from the same (possibly calibrated) alpha-beta
        # constants, evaluated at the plan's own bucket size
        from . import overlap as _ov
        from . import wire as _wire
        if two_level:
            wire_fmt = _wire.choose_wire(
                _ov.PipelineParams(int(pipeline["n_ici"]),
                                   pipeline["alpha_ici"], pipeline["bw_ici"],
                                   pipeline["alpha_dcn"], pipeline["bw_dcn"]),
                float(bucket)).to_dict()
        else:
            wire_fmt = _wire.choose_wire_single(
                a_exp, bw.allreduce * effs["all_reduce"][0], graph.n,
                float(bucket)).to_dict()
        meta = {"source": "commplan", "topology": graph.name,
                "profile": profile.name, "n_endpoints": str(topo.n)}
        if two_level:
            meta["n_pods"] = str(topo.n_pods)
            meta["fabric"] = f"{fabric.name}/{fabric.kind}"
        if calibration is not None:
            meta["source"] = "commplan+calibration"
            meta["calibration"] = (f"v{getattr(calibration, 'version', '?')}/"
                                   f"{getattr(calibration, 'system', '?')}/"
                                   f"n{getattr(calibration, 'n_endpoints', '?')}")
        # the plan's recommended training program, derived from its own
        # decisions: overlap is strictly better than the post-hoc blob, and a
        # lossy intra wire decision rides the int8 error-feedback codec
        from . import program as prg
        compress = 8 if wire_fmt.get("intra", "fp32") != "fp32" else 0
        program = prg.train_step_program(overlap=True,
                                         compress_bits=compress).to_dict()
        return cls(ar, a2a, rs, ag, bucket_bytes=bucket, hierarchical=two_level,
                   meta=meta, tiers=tiers, pipeline=pipeline, wire=wire_fmt,
                   program=program)

    # -------------------------------------------------------------- lookups
    @staticmethod
    def lookup(table: Table, nbytes: int, axis_size: int, default: str = "xla") -> str:
        if axis_size not in table:
            if not table:
                return default
            axis_size = min(table, key=lambda n: abs(
                math.log2(n) - math.log2(max(axis_size, 1))))
        for entry in table[axis_size]:
            if nbytes <= entry.max_bytes:
                return entry.algorithm
        return table[axis_size][-1].algorithm if table[axis_size] else default

    def _algo(self, kind: str, table: Table, nbytes: int, axis_size: int,
              fallback: str) -> str:
        algo = self.lookup(table, nbytes, axis_size)
        spec = coll.registered(kind, multi_axis=False).get(algo)
        if spec is not None and spec.pow2_only and not _is_pow2(axis_size):
            algo = fallback
        return algo

    def distance_tier(self, axis_size: int) -> str:
        """Fabric distance tier the plan ranked this axis size at: "intra"
        inside the node/pod graph, else same_switch / same_group / diff_group.
        Snaps to the nearest configured size like table lookups do."""
        if not self.tiers:
            return "intra"
        if axis_size not in self.tiers:
            axis_size = min(self.tiers, key=lambda n: abs(
                math.log2(n) - math.log2(max(axis_size, 1))))
        return self.tiers[axis_size]

    def pipeline_params(self):
        """The hierarchical pipeline's per-tier alpha-beta constants as an
        `overlap.PipelineParams`, or None for single-level plans."""
        if not (self.hierarchical and self.pipeline):
            return None
        from . import overlap
        p = self.pipeline
        return overlap.PipelineParams(int(p["n_ici"]), p["alpha_ici"],
                                      p["bw_ici"], p["alpha_dcn"], p["bw_dcn"])

    def wire_spec(self):
        """The plan's per-tier wire formats as a `wire.WireSpec` (fp32
        everywhere for legacy plans with no persisted decision)."""
        from .wire import WireSpec
        return WireSpec.from_dict(self.wire)

    def step_program(self):
        """The persisted StepProgram, or None for legacy plans."""
        if not self.program:
            return None
        from . import program as prg
        return prg.StepProgram.from_dict(self.program)

    def set_program(self, program) -> None:
        """Persist a chosen StepProgram (stored as its JSON dict)."""
        self.program = program.to_dict()

    def pipeline_chunks(self, nbytes: int) -> int:
        """Chunk count for the double-buffered hierarchical pipeline on an
        `nbytes` bucket, chosen from the plan's per-tier alpha-beta fits
        (1 = unpipelined; also the answer for single-level plans)."""
        params = self.pipeline_params()
        if params is None:
            return 1
        from . import overlap
        return overlap.choose_chunks(float(max(nbytes, 1)), params)

    def all_reduce_algo(self, nbytes: int, axis_size: int, *, dcn: bool = False) -> str:
        if dcn and self.hierarchical:
            return "hierarchical"
        return self._algo("all_reduce", self.all_reduce_table, nbytes, axis_size, "ring")

    def all_to_all_algo(self, nbytes: int, axis_size: int) -> str:
        # Obs. 7: beyond 512 endpoints *CCL alltoall is unstable — force
        # pairwise.  Group boundaries count too: once the axis spans fabric
        # groups, connection state rides the noisy global links, so the
        # bounded-state schedule wins regardless of endpoint count.
        if axis_size > 512 or self.distance_tier(axis_size) == "diff_group":
            return "pairwise"
        return self._algo("all_to_all", self.all_to_all_table, nbytes, axis_size, "pairwise")

    def reduce_scatter_algo(self, nbytes: int, axis_size: int) -> str:
        return self._algo("reduce_scatter", self.reduce_scatter_table, nbytes,
                          axis_size, "ring")

    def all_gather_algo(self, nbytes: int, axis_size: int) -> str:
        return self._algo("all_gather", self.all_gather_table, nbytes, axis_size, "ring")

    # ------------------------------------------------------------- dispatch
    def _count(self, key: str) -> None:
        self.stats[key] = self.stats.get(key, 0) + 1

    def all_reduce(self, x, axis: str, axis_size: int, dcn_axis: Optional[str] = None):
        """Trace-time dispatch; with `dcn_axis` on a two-level plan this lowers
        to the hierarchical intra-RS / inter-AR / intra-AG schedule."""
        self._count("all_reduce_calls")
        if dcn_axis is not None and self.hierarchical:
            self._count("hierarchical_calls")
            return coll.hierarchical_all_reduce(x, axis, dcn_axis)
        algo = self.all_reduce_algo(x.size * x.dtype.itemsize, axis_size)
        out = coll.get_collective("all_reduce", algo).fn(x, axis)
        if dcn_axis is not None:
            # single-level plan on a two-axis mesh: finish over the outer axis
            out = coll.xla_all_reduce(out, dcn_axis)
        return out

    def all_to_all(self, x, axis: str, axis_size: int):
        self._count("all_to_all_calls")
        algo = self.all_to_all_algo(x.size * x.dtype.itemsize, axis_size)
        # per-algorithm counter: lets the executed path assert *which*
        # schedule the per-tier table dispatched (e.g. pairwise forced at a
        # group boundary), not just that an alltoall happened
        self._count(f"all_to_all_algo/{algo}")
        return coll.get_collective("all_to_all", algo).fn(x, axis)

    def reduce_scatter(self, x, axis: str, axis_size: int):
        self._count("reduce_scatter_calls")
        algo = self.reduce_scatter_algo(x.size * x.dtype.itemsize, axis_size)
        return coll.get_collective("reduce_scatter", algo).fn(x, axis)

    def all_gather(self, chunk, axis: str, axis_size: int):
        self._count("all_gather_calls")
        algo = self.all_gather_algo(chunk.size * chunk.dtype.itemsize * axis_size,
                                    axis_size)
        return coll.get_collective("all_gather", algo).fn(chunk, axis)

    def reset_stats(self) -> None:
        self.stats.clear()

    # ---------------------------------------------------------- persistence
    def to_blob(self) -> Dict:
        dump = lambda t: {str(n): [dataclasses.asdict(e) for e in es]
                          for n, es in t.items()}
        return {
            "meta": self.meta,
            "all_reduce": dump(self.all_reduce_table),
            "all_to_all": dump(self.all_to_all_table),
            "reduce_scatter": dump(self.reduce_scatter_table),
            "all_gather": dump(self.all_gather_table),
            "bucket_bytes": self.bucket_bytes,
            "hierarchical": self.hierarchical,
            "tiers": {str(n): t for n, t in self.tiers.items()},
            "pipeline": dict(self.pipeline),
            "wire": dict(self.wire),
            "program": dict(self.program),
        }

    @classmethod
    def from_blob(cls, blob: Dict) -> "CommPlan":
        """Accepts both the full commplan format and the legacy CollectivePolicy
        format (all_reduce/all_to_all/meta only)."""
        parse = lambda d: {int(n): [PlanEntry(**e) for e in es] for n, es in d.items()}
        return cls(
            parse(blob.get("all_reduce", {})),
            parse(blob.get("all_to_all", {})),
            parse(blob.get("reduce_scatter", {})),
            parse(blob.get("all_gather", {})),
            bucket_bytes=int(blob.get("bucket_bytes", DEFAULT_BUCKET_BYTES)),
            hierarchical=bool(blob.get("hierarchical", False)),
            meta=dict(blob.get("meta", {})),
            tiers={int(n): str(t) for n, t in blob.get("tiers", {}).items()},
            pipeline={k: float(v) for k, v in blob.get("pipeline", {}).items()},
            wire={k: str(v) for k, v in blob.get("wire", {}).items()},
            program=dict(blob.get("program", {})),
        )

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_blob(), f, indent=2)

    @classmethod
    def load(cls, path: str) -> "CommPlan":
        with open(path) as f:
            return cls.from_blob(json.load(f))


def _calibrated_params(cal, bw: _TopoBw, a_exp: float, a_xla: float,
                       floor_xla: float):
    """Map a CalibrationProfile's measured alpha-beta fits onto the ranker's
    parameters.

    * explicit-schedule alpha <- measured one-way small-message p2p latency
      (the ppermute hop every explicit algorithm pays per step);
    * xla alpha <- measured small-regime *CCL-analog allreduce latency divided
      by the model's 2*log2(n_meas) step count; the raw fit doubles as the
      small-message floor (the measured kernel-launch floor);
    * schedule efficiencies <- measured large-regime goodput relative to the
      topology bound, per (pattern, mechanism).  Deliberately NOT clamped to
      1.0: the plan ranks relative measured goodput, and on hosts whose real
      fabric differs from the modeled one the measurement is the truth.
    """
    n_meas = max(getattr(cal, "n_endpoints", 2), 2)
    fp = cal.get("device_copy", "p2p", "small") or cal.get("mpi", "p2p", "small")
    if fp is not None and fp.alpha > 0:
        a_exp = fp.alpha
    fx = cal.get("ccl", "allreduce", "small")
    if fx is not None and fx.alpha > 0:
        a_xla = fx.alpha / (2 * LOG2(n_meas))
        floor_xla = fx.alpha

    def eff(mech, pattern, bound, default):
        ratio = cal.efficiency(mech, pattern, bound)
        return max(ratio, 1e-6) if ratio is not None else default

    eff_ar = (eff("mpi", "allreduce", bw.allreduce, EXPLICIT_EFF),
              eff("ccl", "allreduce", bw.allreduce, XLA_EFF))
    eff_a2a = (eff("mpi", "alltoall", bw.alltoall, EXPLICIT_EFF),
               eff("ccl", "alltoall", bw.alltoall, XLA_EFF))
    effs = {"all_reduce": eff_ar, "all_to_all": eff_a2a,
            "reduce_scatter": eff_ar, "all_gather": eff_ar}
    return a_exp, a_xla, effs, floor_xla


def _bucket_from_crossover(alpha: float, steps: int, bandwidth: float) -> int:
    """Gradient bucket size from the latency/bandwidth crossover: the smallest
    power-of-two byte count where the per-bucket latency term (steps * alpha)
    is <= ~5% of the bandwidth term — below this, small tensors pay
    per-message latency; above it, coalescing stops helping (and delays the
    first reduction).  Clamped to [256 KiB, 64 MiB]."""
    target = 19.0 * steps * alpha * bandwidth
    bucket = 1 << max(int(math.ceil(math.log2(max(target, 1.0)))), 0)
    return min(max(bucket, MIN_BUCKET_BYTES), MAX_BUCKET_BYTES)
