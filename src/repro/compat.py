"""Version compatibility shims for the jax API surface this codebase targets.

The framework is written against the modern API (``jax.shard_map``,
``jax.sharding.AxisType``, ``jax.make_mesh(..., axis_types=...)``,
``check_vma=``).  Older jax releases (<= 0.4.x) spell these
``jax.experimental.shard_map.shard_map`` / ``check_rep=`` and have no axis
types.  ``install()`` fills the gaps in-place — attributes are only added when
missing, so on a modern jax this module is a no-op.  It runs from
``repro/__init__`` so any ``import repro.*`` makes the modern spellings
available everywhere (including test subprocesses).
"""
from __future__ import annotations

import enum
import functools
import inspect

import jax
import jax.sharding


def install() -> None:
    if not hasattr(jax.sharding, "AxisType"):
        class AxisType(enum.Enum):
            Auto = "auto"
            Explicit = "explicit"
            Manual = "manual"

        jax.sharding.AxisType = AxisType

    if "axis_types" not in inspect.signature(jax.make_mesh).parameters:
        _make_mesh = jax.make_mesh

        @functools.wraps(_make_mesh)
        def make_mesh(axis_shapes, axis_names, *, axis_types=None, **kw):
            return _make_mesh(axis_shapes, axis_names, **kw)

        jax.make_mesh = make_mesh

    if not hasattr(jax.lax, "axis_size"):
        def axis_size(axis_name):
            # psum of a Python literal is evaluated statically, so schedules can
            # still unroll Python loops over the result
            return jax.lax.psum(1, axis_name)

        jax.lax.axis_size = axis_size

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        @functools.wraps(_shard_map)
        def shard_map(f, mesh=None, in_specs=None, out_specs=None,
                      check_vma=None, **kw):
            if check_vma is not None:
                kw.setdefault("check_rep", check_vma)
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)

        jax.shard_map = shard_map

    try:
        from jax.experimental.pallas import tpu as pltpu
    except ImportError:
        pltpu = None
    if pltpu is not None and not hasattr(pltpu, "CompilerParams") \
            and hasattr(pltpu, "TPUCompilerParams"):
        pltpu.CompilerParams = pltpu.TPUCompilerParams


install()
