"""Data pipeline: deterministic synthetic LM tokens + binary token-file reader.

Restart semantics (fault tolerance): batches are a pure function of (seed, step),
so a restore-from-checkpoint replays the exact stream with zero bookkeeping.
Multi-host sharding: `host_slice` selects this host's rows; under the
single-controller container it is the identity.

`PrefetchIterator` double-buffers batch construction on a background thread (the
host-side input pipeline never blocks the device step).
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from pathlib import Path
from typing import Dict, Iterator, Optional

import numpy as np

from ..configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class DataConfig:
    seed: int = 1234
    host_index: int = 0
    host_count: int = 1


class SyntheticLM:
    """Deterministic random-token batches shaped per (model, shape) pair,
    including the stub modality frontends (VLM patch embeddings, audio
    codebooks) — see DESIGN.md Sec. 4."""

    def __init__(self, model_cfg: ModelConfig, shape: ShapeConfig,
                 cfg: Optional[DataConfig] = None):
        self.m = model_cfg
        self.shape = shape
        self.cfg = cfg or DataConfig()

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.Generator(np.random.PCG64(hash((self.cfg.seed, step)) % (2**63)))
        B, S = self.shape.global_batch, self.shape.seq_len
        B_local = B // self.cfg.host_count
        lo = self.cfg.host_index * B_local
        out: Dict[str, np.ndarray] = {}
        if self.m.family == "vlm":
            toks = rng.integers(0, self.m.vocab, (B, S - self.m.n_img_tokens), dtype=np.int32)
            img = rng.standard_normal((B, self.m.n_img_tokens, self.m.d_model), dtype=np.float32)
            out = {"tokens": toks[lo:lo + B_local],
                   "img_embeds": img[lo:lo + B_local].astype(np.float32)}
        elif self.m.n_codebooks:
            toks = rng.integers(0, self.m.vocab, (B, S, self.m.n_codebooks), dtype=np.int32)
            out = {"tokens": toks[lo:lo + B_local]}
        else:
            toks = rng.integers(0, self.m.vocab, (B, S), dtype=np.int32)
            out = {"tokens": toks[lo:lo + B_local]}
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class TokenFileDataset:
    """Binary uint16/uint32 token files (memmap) with epoch-deterministic
    shuffled windows — the 'real data' path."""

    def __init__(self, path: str, model_cfg: ModelConfig, shape: ShapeConfig,
                 dtype=np.uint16, cfg: Optional[DataConfig] = None):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.m = model_cfg
        self.shape = shape
        self.cfg = cfg or DataConfig()
        self.n_windows = (len(self.tokens) - 1) // shape.seq_len

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        B, S = self.shape.global_batch, self.shape.seq_len
        epoch = (step * B) // max(self.n_windows, 1)
        rng = np.random.Generator(np.random.PCG64(hash((self.cfg.seed, epoch)) % (2**63)))
        perm = rng.permutation(self.n_windows)
        idx = [(step * B + i) % self.n_windows for i in range(B)]
        starts = perm[idx] * S
        batch = np.stack([self.tokens[s:s + S].astype(np.int32) for s in starts])
        B_local = B // self.cfg.host_count
        lo = self.cfg.host_index * B_local
        return {"tokens": batch[lo:lo + B_local]}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class PrefetchIterator:
    """Background-thread prefetch (depth-bounded)."""

    def __init__(self, source, start_step: int = 0, depth: int = 2):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step

        def worker():
            s = start_step
            while not self._stop.is_set():
                try:
                    self.q.put((s, self.source.batch_at(s)), timeout=0.5)
                    s += 1
                except queue.Full:
                    continue

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __next__(self):
        step, batch = self.q.get()
        return step, batch

    def __iter__(self):
        return self

    def close(self):
        self._stop.set()
