from .pipeline import SyntheticLM, TokenFileDataset, PrefetchIterator, DataConfig
