"""CommLint launcher: statically verify compiled steps against their programs.

  PYTHONPATH=src python -m repro.launch.lint --all-named-programs
  PYTHONPATH=src python -m repro.launch.lint --hlo --json report.json \\
      zero_int8 moe_alltoall --devices 4

For every requested StepProgram this builds the step on a CPU mesh (a toy
multi-leaf model for the dense-gradient programs, the reduced MoE config for
the AllToAll program), extracts its CollectiveTrace (`analysis.trace`) from
the jaxpr — no compilation or execution, tracing only — compiles the program
into an ExpectedTrace (`analysis.expect`), and reports every lint finding
(`analysis.lint`).  `--hlo` adds the compiled-artifact level (ScheduleLint):
the step is actually compiled, its post-SPMD HLO parsed into an HloTrace
(`analysis.hlo_trace`) and cross-checked against the jaxpr trace and the
program (`analysis.schedule`), with the static exposed-comm estimate in the
report.  Exit status is the number of programs with findings, so CI can gate
on it; `--json PATH` writes the full reports machine-readably.
`launch.train --lint` and the dryrun roofline reuse `lint_program_on_mesh`.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Dict, List, Optional, Sequence, Tuple

from ..core import program as prg
from ..core.autotune import CollectivePolicy


class _LintModel:
    """Multi-leaf toy model: enough leaves to exercise packing, small enough
    that tracing is instant.  Loss touches every leaf and the batch."""

    @staticmethod
    def loss(params, batch):
        import jax
        import jax.numpy as jnp

        s = sum(jnp.sum(p) for p in jax.tree.leaves(params))
        return (s - 1.0) ** 2 + 0.0 * jnp.mean(batch["x"])


def _dense_fixture(n_devices: int, n_leaves: int = 6, leaf_elems: int = 65):
    import jax.numpy as jnp

    params = {f"w{i}": jnp.ones((leaf_elems + i,), jnp.float32)
              for i in range(n_leaves)}
    batch = {"x": jnp.ones((2 * n_devices,), jnp.float32)}
    return params, batch


def _make_mesh(shape: Tuple[int, ...], names: Tuple[str, ...]):
    import repro.compat  # noqa: F401 (make_mesh axis_types shim)
    import jax
    from jax.sharding import AxisType

    n = 1
    for s in shape:
        n *= s
    return jax.make_mesh(shape, names, devices=jax.devices()[:n],
                         axis_types=(AxisType.Auto,) * len(shape))


def lint_program_on_mesh(program: prg.StepProgram,
                         n_devices: Optional[int] = None,
                         policy: Optional[CollectivePolicy] = None,
                         dcn: int = 1,
                         hlo: bool = False) -> Dict:
    """Build `program`'s step on a CPU mesh, trace it, lint it.

    `n_devices` is the total mesh size (defaults to every visible device);
    `dcn > 1` splits off a leading "pod" axis of that size to lint the
    hierarchical two-tier path.  The MoE program clamps the mesh to the
    expert count (the EP axis must divide it).  `hlo=True` additionally
    compiles the step (`step.lower`), parses the post-SPMD module into an
    HloTrace, cross-checks it against the jaxpr trace
    (`analysis.schedule.crosscheck_trace`), and reports the static
    exposed-comm estimate under "hlo".  Returns a report dict with the
    findings as strings under "findings" and their codes under "codes";
    HLO-level findings are merged into the same lists.
    """
    import jax

    from ..analysis.expect import expected_trace
    from ..analysis.lint import lint_trace
    from ..analysis.trace import trace_step
    from ..optim import adamw

    t0 = time.perf_counter()
    program.validate()
    policy = policy or CollectivePolicy.from_model()
    n = n_devices or len(jax.devices())
    opt = adamw.OptConfig(peak_lr=1e-2, warmup_steps=0, decay_steps=10)

    if program.schedule == "moe_alltoall":
        from ..configs.base import get_config
        from ..runtime import moe_step as ms
        from ..runtime.steps import build_program_step

        cfg = get_config("deepseek-moe-16b").reduced()
        n = min(n, cfg.n_experts)  # EP axis must divide the expert count
        mesh = _make_mesh((n,), ("data",))
        params = ms.moe_ep_params(cfg, jax.random.PRNGKey(0))
        batch = ms.moe_ep_batch(cfg, jax.random.PRNGKey(1), 2 * n, 16)
        step = build_program_step(cfg, opt, mesh, program, policy=policy)
        import jax.numpy as jnp
        args = (params, adamw.init_opt_state(params), batch,
                jnp.zeros((), jnp.float32))
        expected = expected_trace(program, n_devices=n, plan=policy)
    else:
        from ..runtime.steps import build_program_step

        dcn = max(int(dcn), 1)
        if dcn > 1 and n // dcn >= 1 and n % dcn == 0:
            mesh = _make_mesh((dcn, n // dcn), ("pod", "data"))
            dcn_axis = "pod"
        else:
            mesh = _make_mesh((n,), ("data",))
            dcn_axis = None
        params, batch = _dense_fixture(n)
        step = build_program_step(_LintModel(), opt, mesh, program,
                                  policy=policy, dcn_axis=dcn_axis)
        args = (params, step.init_opt_state(params), batch,
                step.init_error_state(params))
        grad_bytes = sum(p.size * p.dtype.itemsize
                         for p in jax.tree.leaves(params))
        expected = expected_trace(program, n_devices=n, grad_bytes=grad_bytes,
                                  plan=policy, dcn_axis=dcn_axis)

    trace = trace_step(step, *args)
    findings = lint_trace(trace, expected)
    report = {
        "program": program.name,
        "schedule": program.schedule,
        "n_devices": n,
        "records": len(trace.records),
        "kinds": sorted(trace.kinds()),
        "wire_bytes": trace.wire_bytes(),
        "byte_budget": expected.byte_budget,
    }
    if hlo:
        from ..analysis.hlo_trace import parse_hlo
        from ..analysis.schedule import (byte_deltas, crosscheck_trace,
                                         static_exposed_comm)

        # pod axis is the leading mesh axis, so its device-id stride is the
        # size of everything under it (row-major device order)
        pod_stride = (n // dcn) if dcn > 1 and n % dcn == 0 else 0
        lowered = step.lower(*args) if hasattr(step, "lower") \
            else jax.jit(lambda *a: step(*a)).lower(*args)
        htrace = parse_hlo(lowered.compile().as_text(),
                           pod_stride=pod_stride)
        findings = findings + crosscheck_trace(trace, htrace, expected)
        static = static_exposed_comm(htrace)
        report["hlo"] = {
            "records": len(htrace.records),
            "ops": htrace.counts(),
            "wire_bytes": htrace.wire_bytes(),
            "n_async": sum(r.is_async for r in htrace.records),
            "byte_deltas": byte_deltas(trace, htrace,
                                       wide_bytes=expected.wide_bytes),
            "static_overlap": static.row(),
        }
    report.update(
        codes=sorted({f.code for f in findings}),
        findings=[str(f) for f in findings],
        seconds=time.perf_counter() - t0,
    )
    return report


def lint_named_programs(names: Optional[Sequence[str]] = None,
                        n_devices: Optional[int] = None,
                        policy: Optional[CollectivePolicy] = None,
                        hlo: bool = False) -> List[Dict]:
    """Lint reports for the requested named programs (default: all)."""
    names = list(names) if names else sorted(prg.NAMED_PROGRAMS)
    return [lint_program_on_mesh(prg.named_program(nm), n_devices=n_devices,
                                 policy=policy, hlo=hlo)
            for nm in names]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="repro.launch.lint",
        description="Lint compiled steps against their StepProgram IR")
    ap.add_argument("programs", nargs="*",
                    help=f"named programs (default: all of "
                         f"{sorted(prg.NAMED_PROGRAMS)})")
    ap.add_argument("--all-named-programs", action="store_true",
                    help="lint every named StepProgram")
    ap.add_argument("--devices", type=int, default=None,
                    help="mesh size (default: every visible device)")
    ap.add_argument("--policy", default=None,
                    help="CollectivePolicy JSON to dispatch through")
    ap.add_argument("--hlo", action="store_true",
                    help="add the compiled-HLO level: compile each step, "
                         "cross-check the post-SPMD schedule against the "
                         "jaxpr trace, report the static exposed-comm "
                         "estimate")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the full reports as JSON (machine-readable "
                         "findings; CI uploads this as an artifact)")
    args = ap.parse_args(argv)

    names = None if (args.all_named_programs or not args.programs) \
        else args.programs
    for nm in names or ():
        if nm not in prg.NAMED_PROGRAMS:
            raise SystemExit(f"unknown program {nm!r} "
                             f"(have {sorted(prg.NAMED_PROGRAMS)})")
    policy = CollectivePolicy.load(args.policy) if args.policy else None

    reports = lint_named_programs(names, n_devices=args.devices,
                                  policy=policy, hlo=args.hlo)
    bad = 0
    for rep in reports:
        status = "clean" if not rep["findings"] else \
            f"{len(rep['findings'])} finding(s)"
        print(f"{rep['program']:16s} n={rep['n_devices']} "
              f"records={rep['records']:2d} kinds={','.join(rep['kinds'])} "
              f"wire={rep['wire_bytes']}B "
              f"({rep['seconds']:.2f}s) {status}")
        if "hlo" in rep:
            h = rep["hlo"]
            so = h["static_overlap"]
            deltas = ", ".join(
                f"{fam}:{d['rel_delta']:.1%}"
                for fam, d in sorted(h["byte_deltas"].items())) or "-"
            print(f"    hlo: records={h['records']} "
                  f"async={h['n_async']} wire={h['wire_bytes']:.0f}B "
                  f"deltas[{deltas}] "
                  f"static exposed={so['exposed_s']:.2e}s "
                  f"hidden={so['hidden_fraction']:.0%}")
        for f in rep["findings"]:
            print(f"    {f}")
        bad += bool(rep["findings"])
    print(f"lint: {len(reports)} program(s), "
          f"{sum(len(r['findings']) for r in reports)} finding(s)")
    if args.json:
        with open(args.json, "w") as fh:
            json.dump({"reports": reports, "hlo": args.hlo,
                       "clean": bad == 0}, fh, indent=2, sort_keys=True)
        print(f"wrote {args.json}")
    return bad


if __name__ == "__main__":
    sys.exit(main())
