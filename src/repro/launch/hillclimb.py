import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""§Perf hillclimb driver: the three selected cells, one variant per iteration.

Each iteration is a (hypothesis, change) pair from EXPERIMENTS.md §Perf; this
script recompiles the cell and records the roofline deltas as variant JSONs
next to the baselines.

  PYTHONPATH=src python -m repro.launch.hillclimb [cellA|cellB|cellC ...]
"""
import dataclasses
import json
import sys

from ..configs.base import get_config
from .dryrun import run_cell, summarize


def cell_a():
    """mistral-large-123b / train_4k — most collective-bound.

    it1 mb16:    cap microbatches at DP degree (b_micro >= 1 per shard).
                 Hypothesis: baseline mb=64 replicates each microbatch 4x across
                 the batch shards => ~4x useless flops and 4x FSDP all-gather
                 traffic.  Predict flops/dev 1.28e16 -> ~3.5e15, ici ~4x down.
    it2 mb16+rs: shard the carried residual over `model` (residual_shard).
                 Hypothesis: saved activations 88*4096*12288*2 = 8.9 GB/dev
                 -> 0.56 GB/dev; adds per-layer all-gather of the residual
                 (~100 MB/layer/microbatch) — net memory win, small ici cost.
    it3 mb4+rs:  with activations 16x smaller, drop to 4 microbatches.
                 Hypothesis: FSDP param all-gathers scale with microbatch count:
                 ici ~4x down vs it2; activation memory x4 (still fits).
    """
    arch = "mistral-large-123b"
    cfg = get_config(arch)
    yield run_cell(arch, "train_4k", False, microbatches=16, variant="it1_mb16")
    rs = dataclasses.replace(cfg, residual_shard=True)
    yield run_cell(arch, "train_4k", False, microbatches=16, variant="it2_mb16_rs",
                   cfg_override=rs)
    yield run_cell(arch, "train_4k", False, microbatches=4, variant="it3_mb4_rs",
                   cfg_override=rs)


def cell_b():
    """mamba2-2.7b / train_4k — worst roofline fraction (memory-bound SSD).

    it1 mb16:     cap microbatches (same pathology as cell A at mb=32: the
                  8-sample microbatch replicates 2x over 16 batch shards).
    it2 +chunk64: SSD chunk 256 -> 64.  Hypothesis: intra-chunk L/M-matrix
                  traffic ~ S*l per head (l^2 per chunk x S/l chunks); state
                  traffic ~ (S/l)*P*N.  l* = sqrt(P*N) = sqrt(64*128) ~ 90 =>
                  chunk 64 cuts the dominant term ~4x at ~2x state cost.
    it3 +vpad:    pad vocab 50280 -> 50432 (divisible by 16).  Hypothesis: the
                  odd vocab forces replicated (B,S,V) fp32 logits per device
                  (16x the sharded size) — padding restores vocab sharding.
    """
    arch = "mamba2-2.7b"
    cfg = get_config(arch)
    yield run_cell(arch, "train_4k", False, microbatches=16, variant="it1_mb16")
    c64 = dataclasses.replace(cfg, ssm_chunk=64)
    yield run_cell(arch, "train_4k", False, microbatches=16, variant="it2_mb16_chunk64",
                   cfg_override=c64)
    vpad = dataclasses.replace(c64, vocab=50432)
    yield run_cell(arch, "train_4k", False, microbatches=16, variant="it3_mb16_chunk64_vpad",
                   cfg_override=vpad)


def cell_c():
    """zamba2-7b / long_500k — the technique-representative cell (sequence-
    sharded KV decode; currently 22 GB/dev, does NOT fit).

    it1 seqdata:  batch=1 leaves (data) idle; remap the "seq" logical axis to
                  ("model","data") => cache seq sharded 256-ways instead of 16.
                  Hypothesis: per-device KV 19.5 GB -> ~1.2 GB (fits), memory
                  term ~16x down; attention psum merges now span 256 devices
                  (latency, not bytes — stats are tiny).
    it2 +vpad:    zamba2 vocab 32000 = 16*2000 already divides — instead probe
                  the multi-pod mesh with the same remap incl. "pod"
                  (seq over model+data+pod = 512 shards).
    """
    arch = "zamba2-7b"
    yield run_cell(arch, "long_500k", False, variant="it1_seqdata",
                   seq_axes=("model", "data"))
    yield run_cell(arch, "long_500k", True, variant="it2_seqdatapod",
                   seq_axes=("model", "data", "pod"))


CELLS = {"cellA": cell_a, "cellB": cell_b, "cellC": cell_c}


def main():
    which = sys.argv[1:] or list(CELLS)
    for name in which:
        print(f"==== {name}: {CELLS[name].__doc__.splitlines()[0]} ====", flush=True)
        for cell in CELLS[name]():
            print(summarize(cell), flush=True)


if __name__ == "__main__":
    main()
