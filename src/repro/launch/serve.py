"""Production serving launcher (batched prefill + sequence-sharded decode).

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-135m [--reduced] \
      --batch 4 --prompt-len 16 --new-tokens 32 [--mesh 2x4] [--seq-axes model,data]
"""
from __future__ import annotations

import argparse
import sys

import numpy as np

from ..configs.base import get_config, list_configs
from ..models.model import build_model
from ..runtime.serve import BatchedServer, ServeConfig, throughput_report
from .mesh import make_host_mesh
from .train import parse_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_configs())
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--seq-axes", default=None,
                    help='comma list remapping the KV-cache "seq" sharding, '
                         'e.g. "model,data" for batch=1 long-context decode')
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = parse_mesh(args.mesh) if args.mesh else make_host_mesh()
    max_seq = args.prompt_len + args.new_tokens + 8
    server = BatchedServer(cfg, max_seq=max_seq, batch_size=args.batch, mesh=mesh)
    if args.seq_axes:
        server.model = build_model(cfg, mesh, seq_axes=tuple(args.seq_axes.split(",")))
    rep = throughput_report(server, prompt_len=args.prompt_len,
                            new_tokens=args.new_tokens)
    print(f"{cfg.name}: {rep['tokens_per_s']:.1f} tok/s "
          f"(batch {rep['batch']}, {rep['new_tokens']} new, {rep['wall_s']:.1f}s)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
