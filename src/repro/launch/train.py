"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --shape train_4k \
      --steps 100 [--reduced] [--mesh 2x4] [--microbatches 4] [--resume] \
      [--residual-shard] [--fused-qkv] [--policy artifacts/policy.json] \
      [--calibration artifacts/bench/calibration.json] \
      [--explicit-dp] [--bucket-bytes N] [--overlap] [--chunks C] \
      [--compress-bits {0,8,auto}] [--zero] \
      [--faults messy:0|PLAN.json] [--guard] [--straggler-action sync]

On this CPU container use --reduced (full configs are exercised via the dry-run).
The mesh string "DxM" builds (data=D, model=M) over the available devices;
"PxDxM" adds the pod axis. Without --mesh, a best-effort host mesh is used.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys

from ..configs.base import SHAPES, get_config, list_configs, shape_applicable
from ..core import program as prg
from ..core.autotune import CollectivePolicy
from ..optim import OptConfig
from ..runtime.train import Trainer, TrainConfig
from .mesh import make_host_mesh, make_mesh


def parse_mesh(spec: str):
    dims = [int(x) for x in spec.lower().split("x")]
    if len(dims) == 2:
        return make_mesh(tuple(dims), ("data", "model"))
    if len(dims) == 3:
        return make_mesh(tuple(dims), ("pod", "data", "model"))
    raise SystemExit(f"bad --mesh {spec!r} (want DxM or PxDxM)")


def resolve_step_program(args, mesh, plan):
    """One place for the explicit-DP flag implications, mesh validation, and
    wire resolution.  Returns ``(program, dcn_axis)``: the StepProgram the
    runtime compiles and the pricer prices, or ``(None, None)`` when the XLA
    SPMD path runs (it chooses its own collectives — no program to plan).
    """
    if args.overlap or args.zero:
        args.explicit_dp = True  # both are explicit-DP execution modes
    dcn_axis = None
    if args.explicit_dp:
        if mesh is None:
            raise SystemExit("--explicit-dp needs multiple devices (set "
                             "XLA_FLAGS=--xla_force_host_platform_device_count=N "
                             "on a single-device host)")
        if mesh.shape.get("model", 1) > 1:
            raise SystemExit("--explicit-dp needs a pure-DP mesh (model dim 1); "
                             f"got mesh {dict(mesh.shape)}")
        if mesh.shape.get("pod", 1) > 1:
            dcn_axis = "pod"  # hierarchical allreduce over DCN when two-level
    if args.compress_bits == "auto":
        # the plan's calibrated per-tier wire decision (core.wire), restricted
        # to what the runtime's wire can realize: int8 rides the gather over
        # the DP axis, so on a flat mesh that gather spans the whole fabric
        # (any planned lossy tier pays), while on a two-level mesh the inter
        # leg stays fp32 and only a lossy *intra* decision is realizable.  A
        # bf16-planned tier maps to the int8 error-feedback wire (the only
        # lossy format the trainer implements — strictly fewer bytes, and
        # error feedback where bf16 would round silently).
        from ..core.wire import gather_wins
        wire = (plan or CollectivePolicy.from_model()).wire
        if args.zero:
            # the ZeRO all-gather (param return) leg realizes the *idealized*
            # multiplier at any endpoint count — each device contributes its
            # 1/n shard exactly once — so there is no gather_wins gate: any
            # planned lossy tier is worth compressing.
            realizable = args.explicit_dp and wire.compresses
        else:
            realizable = args.explicit_dp and (
                (wire.intra != "fp32") if dcn_axis is not None
                else wire.compresses)
            # the realized int8 gather must also win at the mesh's actual
            # gather axis size — above 8 endpoints it moves more bytes than
            # fp32.  Without --explicit-dp there is no wire to compress: auto
            # resolves to 0 (only a literal 8 hard-errors below).
            n_gather = mesh.shape.get("data", 1) if mesh is not None else 1
            realizable = realizable and gather_wins(n_gather)
        compress_bits = 8 if realizable else 0
        print(f"wire: {wire.intra}/{wire.inter} -> compress_bits={compress_bits}")
    else:
        try:
            compress_bits = int(args.compress_bits)
        except ValueError:
            raise SystemExit(f"--compress-bits {args.compress_bits!r}: "
                             f"want 0, 8, or auto")
    if compress_bits and not args.explicit_dp:
        raise SystemExit("--compress-bits needs --explicit-dp (the XLA SPMD "
                         "path chooses its own collectives)")
    if not args.explicit_dp:
        return None, None
    program = prg.train_step_program(
        overlap=args.overlap, zero=args.zero, compress_bits=compress_bits,
        chunks=args.chunks, microbatches=args.microbatches,
        bucket_bytes=args.bucket_bytes)
    return program, dcn_axis


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list_configs())
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default=None)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="artifacts/train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--residual-shard", action="store_true")
    ap.add_argument("--fused-qkv", action="store_true")
    ap.add_argument("--fast-norm", action="store_true")
    ap.add_argument("--policy", default=None,
                    help="collective policy JSON (core.autotune); informational "
                         "for the XLA path, binding for explicit-DP runs")
    ap.add_argument("--calibration", default=None,
                    help="measured CalibrationProfile JSON (core.calibrate); "
                         "builds a policy re-ranked from the measured fits "
                         "(mutually exclusive with --policy)")
    ap.add_argument("--explicit-dp", action="store_true",
                    help="shard_map DP trainer with CommPlan-dispatched gradient "
                         "collectives (requires a pure-DP mesh: model dim 1)")
    ap.add_argument("--bucket-bytes", type=int, default=None,
                    help="gradient bucket size for --explicit-dp (default: the "
                         "plan's latency/bandwidth crossover; 0 = per-tensor)")
    ap.add_argument("--compress-bits", default="0",
                    help="int8 error-feedback wire compression for "
                         "--explicit-dp: 8 = on (composes with --overlap/"
                         "--chunks via the per-bucket codec), 0 = fp32 wire, "
                         "auto = compress iff the plan's calibrated wire "
                         "decision picks a lossy format on a tier the "
                         "runtime's int8 wire rides (the DP-axis gather; the "
                         "inter leg of a two-level mesh stays fp32)")
    ap.add_argument("--overlap", action="store_true",
                    help="overlap-aware explicit-DP execution (implies "
                         "--explicit-dp): reverse-layer-order gradient buckets "
                         "on a scan-carried issue schedule; with --microbatches "
                         "each bucket's reduction overlaps the next "
                         "microbatch's backward; on a PxDx1 mesh buckets run "
                         "the chunked hierarchical pipeline")
    ap.add_argument("--chunks", type=int, default=None,
                    help="hierarchical pipeline depth for --overlap (default: "
                         "chosen from the plan's per-tier alpha-beta fits)")
    ap.add_argument("--zero", action="store_true",
                    help="ZeRO-style sharded optimizer (implies --explicit-dp): "
                         "reduce-scatter the packed gradient carrier, AdamW "
                         "over each device's shard (fp32 m/v carrier-sharded, "
                         "optimizer memory / DP degree), all-gather updated "
                         "params at the wire dtype; --compress-bits 8 makes "
                         "the all-gather leg int8")
    ap.add_argument("--straggler-threshold", type=float, default=2.5)
    ap.add_argument("--straggler-action", default="log",
                    choices=["log", "sync", "skip"],
                    help="on a detected straggler step: log it, 'sync' (insert "
                         "a resynchronizing barrier), or 'skip' (revert the "
                         "step's update — rejected with --zero, where optimizer "
                         "state is sharded)")
    ap.add_argument("--faults", default=None,
                    help="fault-injection plan: 'messy[:SEED]' (canonical "
                         "messy-fabric plan, core.faults), 'nodeloss[:SEED]', "
                         "or a FaultPlan JSON path; faults perturb the "
                         "simulated fabric deterministically")
    ap.add_argument("--guard", action="store_true",
                    help="drift-aware execution (runtime.guard): watch step "
                         "times against an EWMA band, on sustained drift "
                         "re-probe/refit/re-rank the plan and lint-gate the "
                         "swap; guard events land in "
                         "artifacts/guard_report.json")
    ap.add_argument("--lint", action="store_true",
                    help="statically lint the compiled step against its "
                         "StepProgram before training (analysis.lint); any "
                         "finding refuses to start the run")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    cfg = dataclasses.replace(cfg, residual_shard=args.residual_shard,
                              fused_qkv=args.fused_qkv and not cfg.qkv_bias,
                              fast_norm=args.fast_norm)
    shape = SHAPES[args.shape]
    if args.reduced:
        shape = shape.reduced()
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        raise SystemExit(why)
    if shape.kind != "train":
        raise SystemExit(f"--shape {args.shape} is a {shape.kind} shape; use launch.serve")

    # explicit-DP wants a pure-DP default mesh (model dim 1); --overlap/--zero
    # imply explicit-DP (resolve_step_program re-asserts the implication)
    explicit = args.explicit_dp or args.overlap or args.zero
    mesh = parse_mesh(args.mesh) if args.mesh \
        else make_host_mesh(model=1 if explicit else 0)
    policy = None
    if args.policy and args.calibration:
        raise SystemExit("--policy and --calibration are mutually exclusive "
                         "(a policy file already carries its tables; "
                         "--calibration re-ranks them from the measured fits)")
    if args.policy:
        try:
            policy = CollectivePolicy.load(args.policy)
        except FileNotFoundError:
            raise SystemExit(f"--policy {args.policy}: file not found")
        except (KeyError, ValueError, TypeError) as e:
            raise SystemExit(f"--policy {args.policy}: not a policy file ({e})")
    if args.calibration:
        from ..core import hw
        from ..core.calibrate import CalibrationProfile
        from ..core.costmodel import make_comm_model
        try:
            profile = CalibrationProfile.load(args.calibration)
        except FileNotFoundError:
            raise SystemExit(f"--calibration {args.calibration}: file not found")
        except (KeyError, ValueError, TypeError) as e:
            raise SystemExit(f"--calibration {args.calibration}: "
                             f"not a calibration file ({e})")
        # re-rank the topology the profile was measured against, not a default
        system = profile.system if profile.system in hw.SYSTEMS else "tpu_v5e"
        policy = CollectivePolicy.from_model(make_comm_model(system),
                                             calibration=profile)
        print(f"calibration: {args.calibration} (schema v{profile.version}, "
              f"system={system}, {len(profile.params)} fitted keys) -> "
              f"re-ranked plan, bucket={policy.bucket_bytes} B")
    if policy is not None:
        src = policy.meta.get("source", "?")
        print(f"policy: {args.policy or args.calibration} (source={src}, "
              f"bucket={policy.bucket_bytes} B, "
              f"wire={policy.wire.intra}/{policy.wire.inter})")
    program, dcn_axis = resolve_step_program(args, mesh, policy)
    if program is not None:
        print(f"program: {program.name} "
              f"({' -> '.join(nd.kind for nd in program.nodes)})")
    if args.lint:
        if program is None:
            raise SystemExit("--lint needs a step program to lint against: "
                             "the XLA SPMD path (no --explicit-dp/--overlap/"
                             "--zero) chooses its own collectives")
        from .lint import lint_program_on_mesh
        n_data = mesh.shape.get("data", 1) if mesh is not None else 1
        n_pod = mesh.shape.get("pod", 1) if mesh is not None else 1
        # both levels: jaxpr rules plus the compiled-HLO cross-check — the
        # gate covers what the SPMD partitioner did, not just the intent
        rep = lint_program_on_mesh(program, n_devices=n_pod * n_data,
                                   policy=policy, dcn=n_pod, hlo=True)
        if rep["findings"]:
            for f in rep["findings"]:
                print(f"lint: {f}", file=sys.stderr)
            raise SystemExit(
                f"lint: {len(rep['findings'])} finding(s) on program "
                f"{program.name!r} — refusing to start the run")
        h = rep["hlo"]
        print(f"lint: program {program.name} clean "
              f"({rep['records']} collectives, {h['records']} compiled, "
              f"{h['n_async']} async, {rep['seconds']:.2f}s)")

    faults = None
    if args.faults:
        from ..core.faults import FaultPlan
        faults = FaultPlan.resolve(args.faults, steps=args.steps)
        print(f"faults: {args.faults} -> {len(faults.events)} events "
              f"(seed={faults.seed})")

    trainer = Trainer(
        cfg, shape,
        OptConfig(peak_lr=args.lr, warmup_steps=args.warmup, decay_steps=args.steps),
        TrainConfig(steps=args.steps, microbatches=args.microbatches,
                    ckpt_every=args.ckpt_every, ckpt_dir=args.ckpt_dir,
                    log_every=10, straggler_threshold=args.straggler_threshold,
                    straggler_action=args.straggler_action,
                    explicit_dp=args.explicit_dp, dcn_axis=dcn_axis,
                    policy=policy, program=program,
                    faults=faults, guard=args.guard),
        mesh=mesh,
    )
    result = trainer.run(resume=args.resume)
    losses = [m["loss"] for m in result["metrics"]]
    if losses:
        print(f"done: step {result['final_step']}, loss {losses[0]:.4f} -> "
              f"{losses[-1]:.4f}, stragglers {result['straggler_events']}")
    if result.get("retries") or result.get("skipped_steps"):
        print(f"recovery: {result['retries']} transient retr"
              f"{'y' if result['retries'] == 1 else 'ies'}, "
              f"{result.get('skipped_steps', 0)} skipped step(s)")
    if args.guard:
        import json
        import os
        rep = result.get("guard", {})
        os.makedirs("artifacts", exist_ok=True)
        path = os.path.join("artifacts", "guard_report.json")
        with open(path, "w") as f:
            json.dump(rep, f, indent=2, sort_keys=True)
        print(f"guard: {rep.get('n_replans', 0)} replan(s), "
              f"{rep.get('n_rejected', 0)} rejected, "
              f"{rep.get('n_events', 0)} event(s) -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
