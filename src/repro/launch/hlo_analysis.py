"""Post-SPMD HLO analysis: collective byte accounting for the roofline.

cost_analysis() has no collective term, so we parse the compiled HLO text and sum
wire bytes per collective op (per device), with the standard algorithm factors:

  all-reduce          2 * size * (g-1)/g        (ring RS+AG)
  all-gather          size * (g-1)/g            (size = gathered result)
  reduce-scatter      size * (g-1)               (size = scattered result)
  all-to-all          size * (g-1)/g
  collective-permute  size

`g` = replica-group size parsed from the op.  Groups that span the `pod` axis
(device-id span >= pod stride) are classified as DCN traffic and costed at DCN
bandwidth in the roofline; everything else is ICI.

Collectives inside `while` bodies (layer scans!) execute trip-count times: we
parse the computation graph, recover trip counts from the loop conditions'
`compare(iv, constant)` patterns, and weight each computation by its execution
multiplier (nested scans multiply).
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"=\s*((?:\([^)]*\))|(?:\w+\[[\d,]*\][^ ]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=(\{\{[^=]*?\}\}|\[[^\]]*\]<=\[[^\]]*\](?:T\([\d,]+\))?)")
_SOURCE_TARGET_RE = re.compile(r"source_target_pairs=\{([^}]*)\}")
_COMP_START_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s+\(")
_WHILE_RE = re.compile(r"while\(.*?\).*?condition=%?([\w.\-]+).*?body=%?([\w.\-]+)")
_CALL_RE = re.compile(r"\b(?:call|fusion)\(.*?\).*?(?:to_apply|calls)=%?([\w.\-]+)")
_COND_RE = re.compile(r"conditional\(")
_CONST_RE = re.compile(r"%?([\w.\-]+)\s*=\s*\w+\[\]\s*constant\((\d+)\)")
_COMPARE_RE = re.compile(r"compare\(%?([\w.\-]+),\s*%?([\w.\-]+)\),?.*direction=(LT|LE|GT|GE)")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _parse_group(line: str) -> Tuple[int, int]:
    """Returns (group_size, id_span_within_first_group)."""
    m = _GROUPS_RE.search(line)
    if not m:
        st = _SOURCE_TARGET_RE.search(line)
        if st:
            ids = [int(x) for x in re.findall(r"\d+", st.group(1))]
            span = max(abs(a - b) for a, b in zip(ids[::2], ids[1::2])) if ids else 0
            return 2, span
        return 1, 0
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:].split("}")[0]
        ids = [int(x) for x in first.split(",") if x.strip()]
        return max(len(ids), 1), (max(ids) - min(ids)) if ids else 0
    # iota form: [G,S]<=[N...] with optional T(perm); malformed or truncated
    # group annotations (hand-written / trivial HLO) degrade to "no groups"
    # instead of raising out of the whole analysis
    import numpy as np
    try:
        left = [int(x) for x in re.findall(r"\d+", g.split("<=")[0])]
        right_part = g.split("<=")[1]
        reshape = [int(x) for x in re.findall(r"\d+", right_part.split("T")[0].strip("[] "))]
        tperm = re.search(r"T\(([\d,]+)\)", right_part)
        ngroups, gsize = (left + [1, 1])[:2] if len(left) >= 2 else (1, left[0] if left else 1)
        n = int(np.prod(reshape)) if reshape else ngroups * gsize
        ids = np.arange(n).reshape(reshape if reshape else (n,))
        if tperm:
            ids = ids.transpose([int(x) for x in tperm.group(1).split(",")])
        ids = ids.reshape(ngroups, gsize)
        span = int(ids[0].max() - ids[0].min()) if ids.size else 0
        return gsize, span
    except (IndexError, ValueError):
        return 1, 0


def _split_computations(hlo_text: str) -> Dict[str, List[str]]:
    """Computation headers may wrap across lines; a computation starts at a
    non-indented `%name (`/`ENTRY %name (` line and ends at a bare `}`."""
    comps: Dict[str, List[str]] = {}
    cur: Optional[str] = None
    entry_name = None
    for raw in hlo_text.splitlines():
        line = raw.strip()
        if not raw.startswith((" ", "\t")):
            m = _COMP_START_RE.match(line)
            if m:
                cur = m.group(1)
                comps[cur] = []
                if line.startswith("ENTRY") or raw.startswith("ENTRY"):
                    entry_name = cur
                continue
        if line == "}":
            continue
        if cur is not None:
            comps[cur].append(line)
    if entry_name is not None:
        comps["__entry__"] = comps[entry_name]
    return comps


def _trip_count(cond_lines: List[str]) -> int:
    consts = {}
    for ln in cond_lines:
        for name, val in _CONST_RE.findall(ln):
            consts[name] = int(val)
    for ln in cond_lines:
        m = _COMPARE_RE.search(ln)
        if m:
            a, b, d = m.groups()
            if b in consts:
                return consts[b] + (1 if d in ("LE",) else 0)
            if a in consts:
                return consts[a] + (1 if d in ("GE",) else 0)
    # XLA usually fuses the compare (`ROOT %wrapped_compare = pred[] fusion(%gte,
    # %constant.N), ...`): the bound constant still lives in the cond computation.
    if consts:
        return max(consts.values())
    return 1


def _multipliers(comps: Dict[str, List[str]]) -> Dict[str, float]:
    """Execution multiplier per computation (entry=1; while bodies x trip count)."""
    children: Dict[str, List[Tuple[str, float]]] = defaultdict(list)
    for name, lines in comps.items():
        for ln in lines:
            w = _WHILE_RE.search(ln)
            if w:
                cond, body = w.groups()
                trips = _trip_count(comps.get(cond, []))
                children[name].append((body, float(max(trips, 1))))
                children[name].append((cond, float(max(trips, 1))))
                continue
            c = _CALL_RE.search(ln)
            if c:
                children[name].append((c.group(1), 1.0))
    mult: Dict[str, float] = defaultdict(float)

    def visit(name: str, m: float, depth=0):
        if depth > 64:
            return
        mult[name] += m
        for k, w in children.get(name, []):
            if k in comps:
                visit(k, m * w, depth + 1)

    # "__entry__" aliases the real entry computation's lines, so its children are
    # the real entry's children; the real entry itself is fixed to x1 in analyze.
    visit("__entry__", 1.0)
    return dict(mult)


@dataclasses.dataclass
class CollectiveStats:
    ici_bytes: float = 0.0
    dcn_bytes: float = 0.0
    by_op: Dict[str, Dict[str, float]] = dataclasses.field(default_factory=dict)

    def row(self):
        return {"ici_bytes": self.ici_bytes, "dcn_bytes": self.dcn_bytes,
                "by_op": self.by_op}


def analyze_collectives(hlo_text: str, pod_stride: int = 0) -> CollectiveStats:
    """pod_stride: device-id stride of the pod axis (data*model = 256 for the
    (2,16,16) mesh); 0 = single pod (everything ICI)."""
    if not hlo_text or not hlo_text.strip():
        return CollectiveStats()
    comps = _split_computations(hlo_text)
    if not comps:
        return CollectiveStats()
    mult = _multipliers(comps)
    # map the alias back: ops under the entry computation get multiplier of entry
    stats = CollectiveStats()
    agg = defaultdict(lambda: {"count": 0.0, "wire_bytes": 0.0})
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        m_exec = mult.get(name, 0.0)
        if m_exec == 0.0:
            m_exec = mult.get("__entry__", 1.0) if lines is comps.get("__entry__") else 1.0
        for line in lines:
            om = _OP_RE.search(line)
            if not om:
                continue
            type_str, op, start = om.group(1), om.group(2), om.group(3)
            size = _shape_bytes(type_str)
            g, span = _parse_group(line)
            if op == "all-reduce":
                wire = 2.0 * size * (g - 1) / max(g, 1)
            elif op == "all-gather":
                wire = size * (g - 1) / max(g, 1)
            elif op == "reduce-scatter":
                wire = size * (g - 1)
            elif op == "all-to-all":
                wire = size * (g - 1) / max(g, 1)
            else:
                wire = size
            wire *= m_exec
            is_dcn = pod_stride > 0 and span >= pod_stride
            key = f"{op}{'/dcn' if is_dcn else ''}"
            agg[key]["count"] += m_exec
            agg[key]["wire_bytes"] += wire
            if is_dcn:
                stats.dcn_bytes += wire
            else:
                stats.ici_bytes += wire
    stats.by_op = {k: dict(v) for k, v in agg.items()}
    return stats


# ------------------------------------------------------------------ cost pass
# XLA's HloCostAnalysis (and thus compiled.cost_analysis()) counts a while body
# ONCE, so scanned layer stacks under-report flops/bytes by a factor of L
# (verified empirically: scan ratio 1.0 vs unrolled 10.0 for a 10-layer stack).
# We therefore re-derive both from the HLO with the execution multipliers above:
#   flops  = sum over `dot` ops of 2 * result_elems * prod(contracting dims)
#            (matmul flops only — the standard MFU accounting convention)
#   bytes  = sum over scheduled op lines of (result + operand) bytes
#            (post-fusion HLO: one line ~ one kernel ~ operands read + result
#            written to HBM; fusion-internal computations are skipped, their
#            traffic is counted at the fusion call site)

_DEF_RE = re.compile(r"%([\w.\-]+)\s*=\s*((?:\([^)]*\))|\w+\[[\d,]*\](?:\{[^}]*\})?)")
_PARAM_ANNOT_RE = re.compile(r"([\w.\-]+):\s*((?:\([^)]*\))|\w+\[[\d,]*\](?:\{[^}]*\})?)")
# operands may carry an inline type (`dot(f32[8,8]{1,0} %a, ...)`) depending on
# the XLA version's dump style
_DOT_RE = re.compile(
    r"=\s*(\w+\[[\d,]*\])[^ ]*\s+dot\("
    r"(?:\w+\[[\d,]*\](?:\{[^}]*\})?\s+)?%([\w.\-]+),\s*"
    r"(?:\w+\[[\d,]*\](?:\{[^}]*\})?\s+)?%([\w.\-]+)\)"
    r".*?lhs_contracting_dims=\{([\d,]*)\}")
_FUSED_PREFIXES = ("fused_computation", "wrapped_", "add.", "add_", "max.", "min.",
                   "region_", "and.", "or.")


def _dims_of(type_str: str):
    m = _SHAPE_RE.search(type_str)
    if not m:
        return None, []
    dtype, dims = m.group(1), m.group(2)
    return dtype, [int(d) for d in dims.split(",")] if dims else []


def _build_type_map(hlo_text: str) -> Dict[str, str]:
    types: Dict[str, str] = {}
    for m in _PARAM_ANNOT_RE.finditer(hlo_text):
        types.setdefault(m.group(1), m.group(2))
    for m in _DEF_RE.finditer(hlo_text):
        types[m.group(1)] = m.group(2)
    return types


@dataclasses.dataclass
class ModuleCost:
    flops: float = 0.0
    bytes: float = 0.0
    bytes_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    top_lines: List[Tuple[float, str]] = dataclasses.field(default_factory=list)

    def _add(self, kind: str, b: float, line: str):
        self.bytes += b
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0.0) + b
        if b > 1e9:
            self.top_lines.append((b, line[:140]))
            if len(self.top_lines) > 400:
                self.top_lines.sort(key=lambda t: -t[0])
                del self.top_lines[40:]


def _collect_trip_counts(comps) -> set:
    trips = set()
    for lines in comps.values():
        for ln in lines:
            w = _WHILE_RE.search(ln)
            if w:
                trips.add(_trip_count(comps.get(w.group(1), [])))
    return {t for t in trips if t > 1}


def analyze_cost(hlo_text: str) -> ModuleCost:
    if not hlo_text or not hlo_text.strip():
        return ModuleCost()
    comps = _split_computations(hlo_text)
    if not comps:
        return ModuleCost()
    mult = _multipliers(comps)
    types = _build_type_map(hlo_text)
    trips = _collect_trip_counts(comps)
    cost = ModuleCost()

    def _operand_bytes(name: str) -> float:
        """Bytes actually read from one operand.  Stacked loop carries — arrays
        whose leading dim equals a loop trip count, e.g. the (88, D, F) parameter
        stacks sliced inside fused dynamic-slice/update — are touched one slice
        per iteration, not in full."""
        t = types.get(name, "")
        b = _shape_bytes(t)
        _, dims = _dims_of(t)
        if len(dims) >= 2 and dims[0] in trips:
            return b / dims[0]
        return b
    entry_lines = comps.get("__entry__")
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        m_exec = mult.get(name, 0.0)
        if m_exec == 0.0:
            m_exec = 1.0 if lines is entry_lines else 0.0
        if m_exec == 0.0:
            continue
        fusion_like = name.startswith(_FUSED_PREFIXES) or ".clone" in name and "region" not in name
        for line in lines:
            dm = _DOT_RE.search(line)
            if dm:
                res_t, lhs, _, cdims = dm.group(1), dm.group(2), dm.group(3), dm.group(4)
                _, res_dims = _dims_of(res_t)
                res_elems = 1
                for d in res_dims:
                    res_elems *= d
                lhs_t = types.get(lhs, "")
                _, lhs_dims = _dims_of(lhs_t)
                contract = 1
                for ci in ([int(x) for x in cdims.split(",")] if cdims else []):
                    if ci < len(lhs_dims):
                        contract *= lhs_dims[ci]
                cost.flops += 2.0 * res_elems * contract * m_exec
            if fusion_like:
                continue  # bytes counted at the call site
            clean = line[5:] if line.startswith("ROOT ") else line
            dfm = _DEF_RE.match(clean)
            if not dfm:
                continue
            res_bytes = _shape_bytes(dfm.group(2))
            op_part = clean[dfm.end():].lstrip()
            opm = re.match(r"([\w\-]+)\(", op_part)
            op_kind = opm.group(1) if opm else ""
            paren = op_part.find("(")
            close = op_part.find(")", paren)
            operands = []
            if paren >= 0 and close > paren:
                operands = re.findall(r"%([\w.\-]+)", op_part[paren:close + 1])
            # Data-movement rules: slicing ops touch only the slice, not the full
            # operand (critical inside layer scans: dynamic-slice reads of the
            # stacked (L, ...) parameter arrays would otherwise count L times L-full).
            if op_kind in ("tuple", "get-tuple-element", "bitcast", "parameter",
                           "constant", "iota", "after-all", "partition-id",
                           "replica-id", "reshape",
                           # control flow: carries alias in place; the bodies'
                           # real traffic is counted via their own multipliers
                           "while", "conditional", "call", "custom-call"):
                continue
            if op_kind in ("dynamic-slice", "gather", "slice"):
                cost._add(op_kind, 2.0 * res_bytes * m_exec, line)
                continue
            if op_kind in ("dynamic-update-slice", "scatter"):
                upd_idx = 1 if op_kind == "dynamic-update-slice" else 2
                upd = _shape_bytes(types.get(operands[upd_idx], "")) if len(operands) > upd_idx else res_bytes
                cost._add(op_kind, 3.0 * min(upd, res_bytes) * m_exec, line)
                continue
            if op_kind in ("copy", "convert", "transpose", "broadcast"):
                cost._add(op_kind, 2.0 * res_bytes * m_exec, line)
                continue
            # results that are themselves stacked carries (fused DUS into an
            # (L, ...) accumulator) also only write one slice per iteration
            _, res_dims = _dims_of(dfm.group(2))
            if len(res_dims) >= 2 and res_dims and res_dims[0] in trips:
                res_bytes = res_bytes / res_dims[0]
            operand_bytes = sum(_operand_bytes(on) for on in operands)
            cost._add(op_kind, (res_bytes + operand_bytes) * m_exec, line)
    return cost


# ------------------------------------------------------------- jaxpr counting
def count_jaxpr_eqns(closed, name: Optional[str] = None) -> int:
    """Count jaxpr equations (all, or those of primitive `name`), recursing
    into nested closed jaxprs (scan/cond/remat bodies).  Thin shim over the
    shared walker in `analysis.trace` (which absorbed this function's body);
    kept so the wire-codec op-count regressions and `benchmarks.run wire`
    don't churn."""
    from ..analysis.trace import count_eqns

    return count_eqns(closed, name)
