"""Post-SPMD HLO analysis: collective byte accounting for the roofline.

cost_analysis() has no collective term, so we parse the compiled HLO text and
sum wire bytes per collective op (per device), with the standard algorithm
factors:

  all-reduce          2 * size * (g-1)/g        (ring RS+AG)
  all-gather          size * (g-1)/g            (size = gathered result)
  reduce-scatter      size * (g-1)               (size = scattered result)
  all-to-all          size * (g-1)/g
  collective-permute  size

`g` = replica-group size parsed from the op.  Groups that span the `pod` axis
(device-id span >= pod stride) are classified as DCN traffic and costed at DCN
bandwidth in the roofline; everything else is ICI.

Collectives inside `while` bodies (layer scans!) execute trip-count times,
recovered from the loop conditions' `compare(iv, constant)` patterns; nested
scans multiply.

The parsing machinery itself (dtype table, shape/replica-group regexes,
computation splitting, trip recovery, the per-line cost rules) lives in
`analysis.hlo_trace` — this module is a thin consumer that aggregates its
structured records into the roofline's totals.  The private names below are
kept as aliases for back-compat with existing callers/tests.
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from ..analysis import hlo_trace as _ht
from ..analysis.hlo_trace import (  # noqa: F401  (re-exported aliases)
    DTYPE_BYTES as _DTYPE_BYTES,
    FUSED_PREFIXES as _FUSED_PREFIXES,
    LineCoster,
    build_type_map as _build_type_map,
    collect_trip_counts as _collect_trip_counts,
    dims_of as _dims_of,
    multipliers as _multipliers,
    parse_group as _parse_group,
    parse_hlo,
    shape_bytes as _shape_bytes,
    split_computations as _split_computations,
    trip_count as _trip_count,
)

_SHAPE_RE = _ht.SHAPE_RE
_OP_RE = _ht.OP_RE
_GROUPS_RE = _ht.GROUPS_RE
_SOURCE_TARGET_RE = _ht.SOURCE_TARGET_RE
_COMP_START_RE = _ht.COMP_START_RE
_WHILE_RE = _ht.WHILE_RE
_CALL_RE = _ht.CALL_RE
_CONST_RE = _ht.CONST_RE
_COMPARE_RE = _ht.COMPARE_RE
_DEF_RE = _ht.DEF_RE
_PARAM_ANNOT_RE = _ht.PARAM_ANNOT_RE
_DOT_RE = _ht.DOT_RE


@dataclasses.dataclass
class CollectiveStats:
    ici_bytes: float = 0.0
    dcn_bytes: float = 0.0
    by_op: Dict[str, Dict[str, float]] = dataclasses.field(default_factory=dict)

    def row(self):
        return {"ici_bytes": self.ici_bytes, "dcn_bytes": self.dcn_bytes,
                "by_op": self.by_op}


def analyze_collectives(hlo_text: str, pod_stride: int = 0) -> CollectiveStats:
    """pod_stride: device-id stride of the pod axis (data*model = 256 for the
    (2,16,16) mesh); 0 = single pod (everything ICI)."""
    trace = parse_hlo(hlo_text, pod_stride=pod_stride)
    stats = CollectiveStats()
    agg = defaultdict(lambda: {"count": 0.0, "wire_bytes": 0.0})
    for rec in trace.records:
        wire = rec.algo_wire_bytes * rec.trips
        key = f"{rec.op}{'/dcn' if rec.is_dcn else ''}"
        agg[key]["count"] += rec.trips
        agg[key]["wire_bytes"] += wire
        if rec.is_dcn:
            stats.dcn_bytes += wire
        else:
            stats.ici_bytes += wire
    stats.by_op = {k: dict(v) for k, v in agg.items()}
    return stats


# ------------------------------------------------------------------ cost pass
# XLA's HloCostAnalysis (and thus compiled.cost_analysis()) counts a while body
# ONCE, so scanned layer stacks under-report flops/bytes by a factor of L
# (verified empirically: scan ratio 1.0 vs unrolled 10.0 for a 10-layer stack).
# We therefore re-derive both from the HLO with the execution multipliers, via
# the per-line rules in `analysis.hlo_trace.LineCoster`:
#   flops  = sum over `dot` ops of 2 * result_elems * prod(contracting dims)
#            (matmul flops only — the standard MFU accounting convention)
#   bytes  = sum over scheduled op lines of (result + operand) bytes
#            (post-fusion HLO: one line ~ one kernel ~ operands read + result
#            written to HBM; fusion-internal computations are skipped, their
#            traffic is counted at the fusion call site)


@dataclasses.dataclass
class ModuleCost:
    flops: float = 0.0
    bytes: float = 0.0
    bytes_by_kind: Dict[str, float] = dataclasses.field(default_factory=dict)
    top_lines: List[Tuple[float, str]] = dataclasses.field(default_factory=list)

    def _add(self, kind: str, b: float, line: str):
        self.bytes += b
        self.bytes_by_kind[kind] = self.bytes_by_kind.get(kind, 0.0) + b
        if b > 1e9:
            self.top_lines.append((b, line[:140]))
            if len(self.top_lines) > 400:
                self.top_lines.sort(key=lambda t: -t[0])
                del self.top_lines[40:]


def analyze_cost(hlo_text: str) -> ModuleCost:
    if not hlo_text or not hlo_text.strip():
        return ModuleCost()
    comps = _split_computations(hlo_text)
    if not comps:
        return ModuleCost()
    mult = _multipliers(comps)
    coster = LineCoster(_build_type_map(hlo_text), _collect_trip_counts(comps))
    cost = ModuleCost()
    entry_lines = comps.get("__entry__")
    for name, lines in comps.items():
        if name == "__entry__":
            continue
        m_exec = mult.get(name, 0.0)
        if m_exec == 0.0:
            m_exec = 1.0 if lines is entry_lines else 0.0
        if m_exec == 0.0:
            continue
        fusion_like = name.startswith(_FUSED_PREFIXES) or \
            ".clone" in name and "region" not in name
        for line in lines:
            cost.flops += coster.dot_flops(line) * m_exec
            if fusion_like:
                continue  # bytes counted at the call site
            priced = coster.hbm_bytes(line)
            if priced is not None:
                op_kind, b = priced
                cost._add(op_kind, b * m_exec, line)
    return cost


# ------------------------------------------------------------- jaxpr counting
def count_jaxpr_eqns(closed, name: Optional[str] = None) -> int:
    """Count jaxpr equations (all, or those of primitive `name`), recursing
    into nested closed jaxprs (scan/cond/remat bodies).  Thin shim over the
    shared walker in `analysis.trace` (which absorbed this function's body);
    kept so the wire-codec op-count regressions and `benchmarks.run wire`
    don't churn."""
    from ..analysis.trace import count_eqns

    return count_eqns(closed, name)
