import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x mesh)
cell against the production meshes and extract the roofline terms.

  PYTHONPATH=src python -m repro.launch.dryrun --all --out artifacts/dryrun
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k --mesh both

Each cell writes a JSON artifact with memory_analysis, cost_analysis, collective
wire bytes (ICI vs DCN), roofline terms, and the dominant bottleneck.
The 512 forced host devices exist ONLY in this process (see the module's first
two lines); smoke tests and benchmarks see the real device count.
"""
import argparse
import dataclasses
import gc
import json
import math
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from ..configs.base import SHAPES, ModelConfig, ShapeConfig, get_config, list_configs, shape_applicable
from ..core import hw, topology
from ..models.model import build_model
from ..optim import adamw
from ..runtime import steps as rsteps
from . import hlo_analysis
from .mesh import make_production_mesh

ARTIFACTS = Path("artifacts/dryrun")


def auto_microbatches(cfg: ModelConfig, shape: ShapeConfig, n_devices: int,
                      target_bytes: float = 4e9) -> int:
    """Pick grad-accumulation depth so rematted activations fit (DESIGN.md Sec. 7).

    Activations are sharded over the batch axes only (model-axis dims are local),
    so the per-device estimate divides by batch shards = n_devices / 16.
    """
    if shape.kind != "train":
        return 1
    batch_shards = max(n_devices // 16, 1)
    # never split below one sample per batch shard: a microbatch smaller than the
    # batch axes replicates compute across them (measured: 4x useless flops on
    # mistral-large at mb=64)
    mb_max = max(shape.global_batch // batch_shards, 1)
    d_eff = cfg.d_model if cfg.family != "ssm" else cfg.d_inner + cfg.d_model
    for mb in (1, 2, 4, 8, 16, 32, 64):
        if mb > mb_max:
            break
        if shape.global_batch % mb:
            continue
        b_micro = shape.global_batch / mb / batch_shards
        act = cfg.n_layers * b_micro * shape.seq_len * d_eff * 2
        # logits of one microbatch (fp32, vocab/16 per device) live once
        logits = b_micro * shape.seq_len * (cfg.vocab / 16) * 4 * 3
        if act + logits <= target_bytes:
            return mb
    return mb_max


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6 N D (train) / 2 N D (inference), N = active params."""
    n_active = cfg.active_param_count()
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens


_LINT_CACHE: dict = {}


def _lint_report(program, hlo: bool = False) -> dict:
    """CommLint verdict for one StepProgram on an 8-device CPU submesh —
    reported next to the roofline so a priced program that would compile to
    off-plan collectives is visible in the same artifact.  With `hlo=True`
    the compiled-HLO level rides along: the jaxpr↔HLO cross-check findings
    plus the static overlap accounting of the compiled schedule (note: of
    the 8-device lint fixture — the schedule *shape*, not a production-mesh
    time).  Cached per (program name, level): every cell prices the same
    plan/zero programs."""
    if program is None:
        return None
    key = (program.name, hlo)
    if key not in _LINT_CACHE:
        from .lint import lint_program_on_mesh
        try:
            rep = lint_program_on_mesh(program, n_devices=8, hlo=hlo)
            out = dict(
                program=rep["program"], n_devices=rep["n_devices"],
                records=rep["records"], findings=rep["findings"],
                seconds=round(rep["seconds"], 3))
            if hlo:
                h = rep["hlo"]
                out["hlo"] = dict(
                    records=h["records"], n_async=h["n_async"],
                    byte_deltas=h["byte_deltas"],
                    static_overlap=h["static_overlap"])
            _LINT_CACHE[key] = out
        except Exception as e:  # noqa: BLE001 — lint must not sink the sweep
            _LINT_CACHE[key] = dict(program=program.name,
                                    error=f"{type(e).__name__}: {e}")
    return _LINT_CACHE[key]


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             microbatches: int = 0, out_dir: Path = ARTIFACTS,
             variant: str = "baseline", cfg_override=None, seq_axes=None,
             overrides=None) -> dict:
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    cell = {"arch": arch, "shape": shape_name, "mesh": mesh_name, "variant": variant}
    if not ok:
        cell.update(status="skipped", reason=why)
        return cell

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = math.prod(mesh.shape.values())
    model = build_model(cfg, mesh, seq_axes=seq_axes, overrides=overrides)
    mb = microbatches or auto_microbatches(cfg, shape, n_dev)
    t0 = time.time()
    try:
        if shape.kind == "train":
            bundle = rsteps.train_step_bundle(model, shape, adamw.OptConfig(), microbatches=mb)
            args = (model.abstract_params(), adamw.abstract_opt_state(model.abstract_params()),
                    model.input_specs(shape))
        elif shape.kind == "prefill":
            bundle = rsteps.prefill_step_bundle(model, shape)
            args = (model.abstract_params(), model.input_specs(shape),
                    model.abstract_cache(shape))
        else:
            bundle = rsteps.decode_step_bundle(model, shape)
            ins = model.input_specs(shape)
            args = (model.abstract_params(), model.abstract_cache(shape),
                    ins["tokens"], ins["pos"])
        with mesh:
            jitted = jax.jit(bundle.fn, in_shardings=bundle.in_shardings,
                             out_shardings=bundle.out_shardings,
                             donate_argnums=bundle.donate_argnums)
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            t0 = time.time()
            compiled = lowered.compile()
            t_compile = time.time() - t0

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # older jax returns [dict]
            ca = ca[0] if ca else {}
        hlo = compiled.as_text()
        pod_stride = mesh.shape["data"] * mesh.shape["model"] if multi_pod else 0
        colls = hlo_analysis.analyze_collectives(hlo, pod_stride=pod_stride)
        # XLA cost_analysis counts while bodies once (scan under-reporting):
        # use the trip-weighted HLO pass; keep XLA's numbers for reference.
        parsed = hlo_analysis.analyze_cost(hlo)
        flops = float(parsed.flops)
        bytes_acc = float(parsed.bytes)
        xla_flops = float(ca.get("flops", 0.0))
        xla_bytes = float(ca.get("bytes accessed", 0.0))
        mf = model_flops(cfg, shape) / n_dev
        t_comp = flops / hw.PEAK_FLOPS_BF16
        t_mem = bytes_acc / hw.HBM_BW
        t_ici = colls.ici_bytes / (hw.ICI_LINK_BW * hw.ICI_LINKS)
        # DCN time at the fabric tier the mesh spans (flat TPU DCN today, so
        # this equals DCN_BW_PER_CHIP — but a tapered fabric would shrink it)
        fabric = topology.make_paper_fabrics()["tpu_v5e"]
        dcn_tier = fabric.tier_for_scale(n_dev) if multi_pod else "same_switch"
        t_dcn = colls.dcn_bytes / min(hw.DCN_BW_PER_CHIP, fabric.tier_bw(dcn_tier))
        terms = {"compute_s": t_comp, "memory_s": t_mem, "ici_s": t_ici, "dcn_s": t_dcn}
        dominant = max(terms, key=terms.get)
        step_s = max(terms.values())
        # overlap-aware refinement (core.costmodel.exposed_comm_time): the
        # roofline's max(terms) assumes perfect overlap and sum(terms) none;
        # the predictor schedules the gradient buckets against the backward
        # and charges only the comm that drains past it.  Train cells only.
        overlap_terms = {}
        if shape.kind == "train":
            from ..core.commplan import CommPlan
            from ..core.costmodel import exposed_comm_time
            from ..core.wire import bytes_on_wire, zero_wire_bytes
            topo = topology.make_tpu_multipod() if multi_pod else topology.make_tpu_pod()
            plan = CommPlan.from_topology(topo)
            grad_sizes = [int(a.size) * 4 for a in
                          jax.tree.leaves(model.abstract_params())]
            est = exposed_comm_time(t_comp, plan, grad_sizes, n_endpoints=n_dev)
            # wire-priced variant: the plan's per-tier wire decision
            # (core.wire) shrinks the bandwidth terms of compressed tiers
            est_w = exposed_comm_time(t_comp, plan, grad_sizes,
                                      n_endpoints=n_dev, wire="plan")
            wspec = plan.wire_spec()
            grad_bytes = float(sum(grad_sizes))
            n_buckets = max(est.n_buckets, 1)
            # ZeRO (RS -> sharded AdamW -> AG) variant: the three-phase
            # schedule priced by the same predictor, plus the memory and
            # wire-byte headlines — fp32 m/v shrink by the DP degree, and
            # the AG leg's wire format sets the planned DP bytes.  Priced
            # from the StepProgram object (core.program) — the same artifact
            # the runtime compiles — not the legacy schedule= string.
            from ..core import program as prg
            est_z = exposed_comm_time(t_comp, plan, grad_sizes,
                                      n_endpoints=n_dev, wire="plan",
                                      program=prg.train_step_program(zero=True))
            ag_fmt = wspec.inter if multi_pod else wspec.intra
            zwb = zero_wire_bytes(grad_bytes, n_dev, ag_fmt=ag_fmt,
                                  n_buckets=n_buckets)
            overlap_terms_zero = dict(
                exposed_comm_zero_s=est_z.exposed_s,
                step_time_zero_s=t_comp + est_z.exposed_s,
                opt_state_bytes=2.0 * grad_bytes,
                opt_state_bytes_zero=2.0 * grad_bytes / n_dev,
                dp_wire_bytes_planned_zero=zwb["total"],
                dp_wire_ratio_zero=zwb["ratio"],
            )
            # messy-fabric pricing (core.scenarios.sweep_degradation): what
            # this cell's step time degrades to under congestion/stragglers
            # at its device count, oblivious vs drift-guarded (ROADMAP 4)
            from ..core.scenarios import sweep_degradation
            degradation = {}
            for scen in ("congestion", "straggler"):
                pt = sweep_degradation("tpu_v5e", scen,
                                       endpoints=(n_dev,))[0]
                degradation[scen] = dict(
                    oblivious=round(pt.degradation_oblivious, 4),
                    guarded=round(pt.degradation_guarded, 4),
                    guarded_wins=pt.guarded_wins)
            plan_prog = plan.step_program()
            lint_plan = _lint_report(plan_prog, hlo=True)
            lint_zero = _lint_report(prg.train_step_program(zero=True),
                                     hlo=True)

            def _static_exposed(rep):
                """HLO-derived static exposed-comm seconds of the compiled
                lint fixture, or None when the level errored out."""
                return ((rep or {}).get("hlo", {})
                        .get("static_overlap", {}).get("exposed_s"))

            overlap_terms = dict(
                exposed_comm_s=est.exposed_s,
                hidden_comm_fraction=est.hidden_fraction,
                overlap_chunks=est.chunks,
                plan_program=plan_prog.name if plan_prog else None,
                step_time_overlap_s=t_comp + est.exposed_s,
                wire=wspec.to_dict(),
                exposed_comm_wire_s=est_w.exposed_s,
                step_time_wire_s=t_comp + est_w.exposed_s,
                dp_wire_bytes_fp32=grad_bytes,
                dp_wire_bytes_planned=bytes_on_wire(
                    grad_bytes, wspec.inter if multi_pod else wspec.intra,
                    n_buckets),
                # the compiled schedule's own exposure accounting (the
                # artifact-level counterpart of exposed_comm_s above)
                exposed_comm_hlo_static_s=_static_exposed(lint_plan),
                exposed_comm_zero_hlo_static_s=_static_exposed(lint_zero),
                lint=dict(plan=lint_plan, zero=lint_zero),
                degradation=degradation,
                **overlap_terms_zero,
            )
        cell.update(
            status="ok",
            microbatches=mb,
            devices=n_dev,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory=dict(
                argument_bytes=ma.argument_size_in_bytes,
                output_bytes=ma.output_size_in_bytes,
                temp_bytes=ma.temp_size_in_bytes,
                alias_bytes=ma.alias_size_in_bytes,
                peak_per_device=ma.argument_size_in_bytes + ma.temp_size_in_bytes,
                fits_16g=(ma.argument_size_in_bytes + ma.temp_size_in_bytes) < 16e9,
            ),
            cost=dict(flops_per_device=flops, bytes_per_device=bytes_acc,
                      xla_flops_unweighted=xla_flops, xla_bytes_unweighted=xla_bytes,
                      bytes_by_kind=parsed.bytes_by_kind,
                      top_byte_lines=sorted(parsed.top_lines, key=lambda t: -t[0])[:25]),
            collectives=colls.row(),
            roofline=dict(
                **terms,
                **overlap_terms,
                dominant=dominant,
                step_time_bound_s=step_s,
                model_flops_per_device=mf,
                useful_compute_ratio=(mf / flops if flops else 0.0),
                mfu_bound=(mf / hw.PEAK_FLOPS_BF16) / step_s if step_s else 0.0,
            ),
        )
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        cell.update(status="error", error=f"{type(e).__name__}: {e}",
                    trace=traceback.format_exc()[-2000:])
    finally:
        gc.collect()

    out_dir.mkdir(parents=True, exist_ok=True)
    path = out_dir / f"{arch}__{shape_name}__{mesh_name}__{variant}.json"
    path.write_text(json.dumps(cell, indent=2, default=float))
    return cell


def summarize(cell: dict) -> str:
    if cell.get("status") == "skipped":
        return f"{cell['arch']:>20s} {cell['shape']:<12s} {cell['mesh']:<11s} SKIP  ({cell['reason'][:60]})"
    if cell.get("status") != "ok":
        return f"{cell['arch']:>20s} {cell['shape']:<12s} {cell['mesh']:<11s} ERROR {cell.get('error', '')[:90]}"
    r = cell["roofline"]
    m = cell["memory"]
    lint = r.get("lint") or {}
    lint_tag = ""
    if lint:
        n_findings = sum(len((rep or {}).get("findings", ()))
                         for rep in lint.values())
        lint_tag = f" lint={'clean' if not n_findings else n_findings}"
    return (f"{cell['arch']:>20s} {cell['shape']:<12s} {cell['mesh']:<11s} "
            f"mb={cell['microbatches']:<3d} mem={m['peak_per_device']/1e9:6.2f}GB "
            f"fits={str(m['fits_16g'])[0]} comp={r['compute_s']*1e3:9.2f}ms "
            f"memt={r['memory_s']*1e3:9.2f}ms ici={r['ici_s']*1e3:8.2f}ms "
            f"dcn={r['dcn_s']*1e3:8.2f}ms dom={r['dominant']:<9s} "
            f"useful={r['useful_compute_ratio']:5.2f} mfu<={r['mfu_bound']:5.2f} "
            f"[compile {cell['compile_s']:.0f}s]{lint_tag}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["pod", "multipod", "both"], default="both")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(ARTIFACTS))
    ap.add_argument("--microbatches", type=int, default=0)
    ap.add_argument("--variant", default="baseline")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    out_dir = Path(args.out)
    archs = [args.arch] if args.arch else list_configs()
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"pod": [False], "multipod": [True], "both": [False, True]}[args.mesh]
    if not (args.all or args.arch):
        ap.error("pass --all or --arch")

    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "pod2x16x16" if mp else "pod16x16"
                path = out_dir / f"{arch}__{shape}__{mesh_name}__{args.variant}.json"
                if args.skip_existing and path.exists():
                    cell = json.loads(path.read_text())
                    if cell.get("status") in ("ok", "skipped"):
                        print(summarize(cell), "(cached)", flush=True)
                        results.append(cell)
                        continue
                cell = run_cell(arch, shape, mp, args.microbatches, out_dir, args.variant)
                print(summarize(cell), flush=True)
                results.append(cell)
    n_ok = sum(1 for c in results if c["status"] == "ok")
    n_skip = sum(1 for c in results if c["status"] == "skipped")
    n_err = len(results) - n_ok - n_skip
    print(f"\ndry-run: {n_ok} ok, {n_skip} skipped (documented), {n_err} errors")
    sys.exit(1 if n_err else 0)


if __name__ == "__main__":
    main()
