"""Production mesh builders (DESIGN.md Sec. 5).

`make_production_mesh` is a FUNCTION so importing this module never touches jax
device state.  Single pod = one v5e 16x16 ICI torus (256 chips); multi-pod adds a
leading `pod` axis over DCN (2 x 256 = 512 chips).  A `pipeline` axis name is
reserved for larger deployments (unused at these scales — see DESIGN.md).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_mesh(shape: Tuple[int, ...], axes: Tuple[str, ...]) -> Mesh:
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 0) -> Optional[Mesh]:
    """Best-effort mesh over whatever devices exist (smoke tests, examples).
    Returns None when only one device is available (Sharder treats None as
    'no constraints')."""
    n = len(jax.devices())
    if n == 1:
        return None
    m = model or (2 if n % 2 == 0 else 1)
    return make_mesh((n // m, m), ("data", "model"))
