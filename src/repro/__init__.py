"""repro: communication-characterized multi-pod JAX training/serving framework.

Reproduction of "Exploring GPU-to-GPU Communication: Insights into Supercomputer
Interconnects" (SC'24), adapted to a TPU v5e multi-pod target.  See DESIGN.md.
"""
from . import compat  # installs jax API shims when running on older jax

__version__ = "1.0.0"
