"""mamba2-2.7b [ssm] — SSD (state-space duality), attention-free — arXiv:2405.21060
(unverified)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0, d_ff=0, vocab=50280,
    ssm_state=128, ssm_expand=2, ssm_headdim=64, ssm_conv=4, ssm_chunk=256,
    sub_quadratic=True,
))
