"""zamba2-7b [hybrid] — Mamba2 backbone + shared attention block — arXiv:2411.15242
(unverified).  Simplifications vs the released model (noted per DESIGN.md): one
shared transformer block applied every `attn_every` SSM layers with a concat
projection from [x, x_embed]; no per-application LoRA."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=81, d_model=3584, n_heads=32, n_kv_heads=32, d_ff=14336, vocab=32000,
    mlp="gelu", rope_theta=10000.0,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_conv=4, ssm_chunk=256,
    attn_every=6, sub_quadratic=True,
))
