"""stablelm-1.6b [dense] — hf:stabilityai/stablelm-2-1_6b (unverified)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="stablelm-1.6b", family="dense",
    n_layers=24, d_model=2048, n_heads=32, n_kv_heads=32, d_ff=5632, vocab=100352,
    mlp="swiglu", rope_theta=10000.0,
))
