"""musicgen-medium [audio] — decoder-only over EnCodec tokens (4 codebooks; frontend
STUB provides codebook token ids) — arXiv:2306.05284 (hf)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-medium", family="audio",
    n_layers=48, d_model=1536, n_heads=24, n_kv_heads=24, d_ff=6144, vocab=2048,
    mlp="gelu", rope_theta=10000.0, n_codebooks=4,
))
