"""internvl2-26b [vlm] — InternViT frontend (STUB: precomputed patch embeddings) +
InternLM2 backbone — arXiv:2404.16821 (hf)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="internvl2-26b", family="vlm",
    n_layers=48, d_model=6144, n_heads=48, n_kv_heads=8, d_ff=16384, vocab=92553,
    mlp="swiglu", rope_theta=1000000.0, n_img_tokens=256,
))
