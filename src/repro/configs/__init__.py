from .base import ModelConfig, ShapeConfig, SHAPES, get_config, list_configs, register, shape_applicable
