"""smollm-135m [dense] — llama-arch small — hf:HuggingFaceTB/SmolLM-135M (hf)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="smollm-135m", family="dense",
    n_layers=30, d_model=576, n_heads=9, n_kv_heads=3, d_ff=1536, vocab=49152,
    mlp="swiglu", rope_theta=10000.0, tie_embeddings=True,
))
