"""Config system: model configs, input-shape configs, mesh configs, registry."""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    qkv_bias: bool = False
    mlp: str = "swiglu"             # swiglu | gelu
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    d_expert: int = 0               # per-expert hidden dim (fine-grained MoE)
    capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    ssm_chunk: int = 256
    # --- hybrid (Zamba2-style: shared attention block every k SSM layers) ---
    attn_every: int = 0
    # --- modality frontends (stubs; see DESIGN.md Sec. 4) ---
    n_codebooks: int = 0            # audio: EnCodec codebooks
    n_img_tokens: int = 0           # vlm: precomputed patch embeddings per sample
    # --- implementation knobs (the tuning surface; paper Obs. 1) ---
    attn_impl: str = "blockwise"    # blockwise | naive | pallas
    q_block: int = 256
    use_scan: bool = True           # scan over layers (compile-time/HLO size)
    remat: str = "block"            # none | block  (activation checkpointing)
    sub_quadratic: bool = False     # set for ssm/hybrid: long_500k is runnable
    residual_shard: bool = False    # Megatron-SP-style: shard the residual
    #                                 stream's d_model over `model` between blocks
    #                                 (cuts saved-activation memory 16x; adds
    #                                 per-layer all-gathers — a §Perf knob)
    fused_qkv: bool = False         # single (D, (H+2K)*hd) projection: one dx
    #                                 all-reduce instead of three in backward
    fast_norm: bool = False         # rms_norm without fp32 materialization

    @property
    def head_dim(self) -> int:
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim if self.ssm_headdim else 0

    def param_count(self) -> int:
        """Analytic parameter count (for MODEL_FLOPS = 6*N*D in the roofline)."""
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        hd, H, K = self.head_dim, self.n_heads, self.n_kv_heads
        attn = d * H * hd + 2 * d * K * hd + H * hd * d      # q,k,v,o
        if self.mlp == "swiglu":
            mlp = 3 * d * f
        else:
            mlp = 2 * d * f
        if self.family == "moe":
            fe = self.d_expert or f
            mlp = self.n_experts * 3 * d * fe + self.n_shared_experts * 3 * d * fe \
                + d * self.n_experts
        per_layer = attn + mlp + 2 * d
        if self.family == "ssm":
            per_layer = self._ssm_layer_params()
        if self.family == "hybrid":
            n_attn = L // max(self.attn_every, 1)
            per_layer = self._ssm_layer_params()
            emb = V * d
            shared = attn + 3 * d * f + 2 * d + 2 * d * d  # one shared block + in-proj
            return L * per_layer + shared + emb + (0 if self.tie_embeddings else V * d)
        emb = V * d * (self.n_codebooks or 1)
        head = 0 if self.tie_embeddings else V * d * (self.n_codebooks or 1)
        return L * per_layer + emb + head

    def _ssm_layer_params(self) -> int:
        d, di, N = self.d_model, self.d_inner, self.ssm_state
        H = self.ssm_heads
        in_proj = d * (2 * di + 2 * N + H)
        conv = (di + 2 * N) * self.ssm_conv
        out = di * d
        return in_proj + conv + out + 2 * H + di + 2 * d

    def active_param_count(self) -> int:
        """Active params per token (MoE: only top-k + shared experts count)."""
        if self.family != "moe":
            return self.param_count()
        d, f, L, V = self.d_model, self.d_ff, self.n_layers, self.vocab
        hd, H, K = self.head_dim, self.n_heads, self.n_kv_heads
        attn = d * H * hd + 2 * d * K * hd + H * hd * d
        fe = self.d_expert or f
        mlp = (self.top_k + self.n_shared_experts) * 3 * d * fe + d * self.n_experts
        per_layer = attn + mlp + 2 * d
        return L * per_layer + 2 * V * d

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, 2 if self.family != "hybrid" else 4),
            d_model=128,
            n_heads=4,
            n_kv_heads=2 if 0 < self.n_kv_heads < self.n_heads else (4 if self.n_kv_heads else 0),
            d_ff=256,
            d_expert=64 if self.d_expert else 0,
            vocab=256,
            n_experts=min(self.n_experts, 4) if self.n_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=32 if self.ssm_state else 64,
            ssm_chunk=8,
            attn_every=2 if self.attn_every else 0,
            n_img_tokens=8 if self.n_img_tokens else 0,
            q_block=16,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                      # train | prefill | decode

    def reduced(self) -> "ShapeConfig":
        return dataclasses.replace(self, name=self.name + "-reduced",
                                   seq_len=min(self.seq_len, 64), global_batch=4)


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(model: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """long_500k needs sub-quadratic context handling: run for ssm/hybrid, skip for
    pure full-attention archs (DESIGN.md Sec. 4)."""
    if shape.name == "long_500k" and not model.sub_quadratic:
        return False, "long_500k skipped: pure full-attention arch (see DESIGN.md)"
    return True, ""


_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name.endswith("-reduced"):
        return get_config(name[: -len("-reduced")]).reduced()
    return _REGISTRY[name]


def list_configs():
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    if _REGISTRY:
        return
    from . import (  # noqa: F401
        stablelm_1_6b, mistral_large_123b, qwen1_5_4b, smollm_135m, internvl2_26b,
        dbrx_132b, deepseek_moe_16b, zamba2_7b, mamba2_2_7b, musicgen_medium,
    )
