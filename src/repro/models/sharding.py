"""Logical-axis sharding: one vocabulary, resolved against whatever mesh is live.

Logical axes:
  "batch"  -> ("pod", "data")     data parallel
  "fsdp"   -> ("pod", "data")     ZeRO-3 parameter/optimizer sharding
  "tp"     -> ("model",)          tensor parallel (heads / ff / experts / vocab)
  "seq"    -> ("model",)          sequence-sharded KV cache (flash-decode, DESIGN 5)
  None     -> replicated

`Sharder` resolves a logical spec to a PartitionSpec, dropping any axis that does
not divide the corresponding dimension (e.g. 8 KV heads on a 16-way model axis:
replicate instead of crash — the cost shows up in the roofline, which is the point).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

LOGICAL = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),
    "tp": ("model",),
    "seq": ("model",),
    "expert": ("model",),
    # Embedding/unembedding tables: vocab over `model` ONLY.  Sharding d_model
    # would make the logits contraction partial-sum over fsdp => an all-reduce of
    # the full (B,S,V) fp32 logits; sharding vocab over (model, data) too makes
    # the result sharding conflict with the batch axis and XLA materializes the
    # full-vocab logits per device (measured: 12.9 GB/dev on smollm train_4k).
    "vocab": ("model",),
}


@dataclasses.dataclass
class Sharder:
    mesh: Optional[Mesh] = None
    overrides: Optional[dict] = None   # logical-name -> axes tuple (e.g. remap
    #                                    "seq" to ("model","data") when batch=1
    #                                    leaves the data axis idle — DESIGN 5)

    def _axes(self, logical: str):
        if self.overrides and logical in self.overrides:
            return self.overrides[logical]
        return LOGICAL.get(logical, ())

    def axis_size(self, logical: Optional[str]) -> int:
        if self.mesh is None or logical is None:
            return 1
        size = 1
        for ax in self._axes(logical):
            size *= self.mesh.shape.get(ax, 1)
        return size

    def spec(self, dims: Sequence[Optional[str]], shape: Optional[Sequence[int]] = None) -> P:
        parts = []
        for i, name in enumerate(dims):
            if name is None or self.mesh is None:
                parts.append(None)
                continue
            axes = tuple(ax for ax in self._axes(name) if self.mesh.shape.get(ax, 1) > 1)
            if not axes:
                parts.append(None)
                continue
            size = math.prod(self.mesh.shape[ax] for ax in axes)
            if shape is not None and shape[i] % size != 0:
                # try a prefix of the axes that divides
                ok = None
                for j in range(len(axes) - 1, 0, -1):
                    sz = math.prod(self.mesh.shape[ax] for ax in axes[:j])
                    if shape[i] % sz == 0:
                        ok = axes[:j]
                        break
                parts.append(ok if ok else None)
                continue
            parts.append(axes if len(axes) > 1 else axes[0])
        return P(*parts)

    def sharding(self, dims: Sequence[Optional[str]], shape: Optional[Sequence[int]] = None) -> Optional[NamedSharding]:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(dims, shape))

    def constrain(self, x, *dims: Optional[str]):
        """with_sharding_constraint if a mesh is live, else identity."""
        if self.mesh is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, self.spec(dims, x.shape)))


def tree_shardings(sharder: Sharder, logical_tree):
    """Map a pytree of logical-dim tuples to NamedShardings (or None)."""
    if sharder.mesh is None:
        return None
    return jax.tree.map(lambda dims: NamedSharding(sharder.mesh, sharder.spec(dims)),
                        logical_tree, is_leaf=lambda x: isinstance(x, tuple))


def tree_shardings_shaped(sharder: Sharder, logical_tree, shaped_tree):
    """Same, but checks divisibility against actual shapes."""
    if sharder.mesh is None:
        return None
    return jax.tree.map(
        lambda dims, arr: NamedSharding(sharder.mesh, sharder.spec(dims, arr.shape)),
        logical_tree, shaped_tree, is_leaf=lambda x: isinstance(x, tuple))
