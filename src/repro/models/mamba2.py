"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) blocks.

Chunked SSD algorithm (training/prefill, sub-quadratic):
  within a chunk of length Q the recurrence is unrolled into an attention-like
  quadratic form (the "duality"); across chunks a linear recurrence carries the
  (H, P, N) state.  `ssd_chunked` is the jnp implementation (also the oracle for
  kernels/ssd_scan); `ssd_reference` is the naive sequential recurrence used to
  validate it.

Decode is O(1) per token: the state update h <- h*exp(dt*A) + dt * x B^T.

Sharding: d_inner (heads H) carries "tp"; the state dim N is replicated; the
recurrence is local to each (batch, head) shard — an SSM has *no* sequence-dim
collectives, which is exactly why the attention-centric parts of the paper's
technique do not bind here (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import rms_norm
from .sharding import Sharder

NGROUPS = 1  # B/C projection groups (Mamba2 default 1 group broadcast over heads)


def ssm_param_defs(cfg: ModelConfig, n_layers: Optional[int] = None) -> Dict:
    L = n_layers if n_layers is not None else cfg.n_layers
    D, Di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = Di + 2 * NGROUPS * N
    return {
        "ln": ((L, D), (None, None)),
        "in_proj": ((L, D, 2 * Di + 2 * NGROUPS * N + H), (None, "fsdp", "tp")),
        "conv_w": ((L, cfg.ssm_conv, conv_dim), (None, None, "tp")),
        "conv_b": ((L, conv_dim), (None, "tp")),
        "A_log": ((L, H), (None, "tp")),
        "dt_bias": ((L, H), (None, "tp")),
        "D_skip": ((L, H), (None, "tp")),
        "gate_ln": ((L, Di), (None, "tp")),
        "out_proj": ((L, Di, D), (None, "tp", "fsdp")),
    }


def _segsum(da: jnp.ndarray) -> jnp.ndarray:
    """Stable segment-sum: out[..., i, j] = sum_{j < t <= i} da[..., t] (lower-tri)."""
    Q = da.shape[-1]
    cs = jnp.cumsum(da, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]          # (..., i, j): sum_{j<t<=i}
    mask = jnp.tril(jnp.ones((Q, Q), bool), k=0)
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, chunk: int):
    """SSD over chunks.

    x: (b, s, h, p); dt: (b, s, h) (post-softplus); A: (h,) negative;
    B, C: (b, s, g, n).  Returns y: (b, s, h, p) and final state (b, h, p, n).
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    assert s % chunk == 0
    nc = s // chunk
    rep = h // g
    xb = x.reshape(b, nc, chunk, h, p)
    dtb = dt.reshape(b, nc, chunk, h)
    Bb = jnp.repeat(B.reshape(b, nc, chunk, g, n), rep, axis=3)   # (b,nc,l,h,n)
    Cb = jnp.repeat(C.reshape(b, nc, chunk, g, n), rep, axis=3)

    da = dtb * A                                                    # (b,nc,l,h)
    da_t = da.transpose(0, 1, 3, 2)                                 # (b,nc,h,l)
    da_cs = jnp.cumsum(da_t, axis=-1)                               # (b,nc,h,l)

    # ---- intra-chunk (the "attention-like" quadratic block) ----
    L = jnp.exp(_segsum(da_t))                                      # (b,nc,h,l,l)
    CB = jnp.einsum("bcihn,bcjhn->bchij", Cb, Bb)
    M = CB * L
    y_diag = jnp.einsum("bchij,bcjh,bcjhp->bcihp", M.astype(jnp.float32),
                        dtb.astype(jnp.float32), xb.astype(jnp.float32))

    # ---- chunk states ----
    decay_states = jnp.exp(da_cs[..., -1:] - da_cs)                 # (b,nc,h,l)
    states = jnp.einsum("bclhn,bchl,bclh,bclhp->bchpn",
                        Bb.astype(jnp.float32), decay_states.astype(jnp.float32),
                        dtb.astype(jnp.float32), xb.astype(jnp.float32))

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(da_cs[..., -1])                           # (b,nc,h)

    def scan_fn(prev, inp):
        st, dec = inp                                               # (b,h,p,n), (b,h)
        new = st + prev * dec[..., None, None]
        return new, prev                                            # emit state *entering* chunk

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final, prev_states = jax.lax.scan(
        scan_fn, init,
        (states.transpose(1, 0, 2, 3, 4), chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)              # (b,nc,h,p,n)

    # ---- inter-chunk output ----
    state_decay = jnp.exp(da_cs)                                    # (b,nc,h,l)
    y_off = jnp.einsum("bclhn,bchpn,bchl->bclhp", Cb.astype(jnp.float32),
                       prev_states, state_decay.astype(jnp.float32))

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final


def ssd_reference(x, dt, A, B, C):
    """Naive sequential recurrence (oracle): h_t = h_{t-1}*exp(dt_t A) + dt_t B_t x_t^T."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bf = jnp.repeat(B, rep, axis=2).astype(jnp.float32)
    Cf = jnp.repeat(C, rep, axis=2).astype(jnp.float32)
    xf, dtf = x.astype(jnp.float32), dt.astype(jnp.float32)

    def step(hstate, t):
        da = jnp.exp(dtf[:, t] * A)                                 # (b,h)
        upd = jnp.einsum("bh,bhp,bhn->bhpn", dtf[:, t], xf[:, t], Bf[:, t])
        hstate = hstate * da[..., None, None] + upd
        y = jnp.einsum("bhn,bhpn->bhp", Cf[:, t], hstate)
        return hstate, y

    init = jnp.zeros((b, h, p, n), jnp.float32)
    final, ys = jax.lax.scan(step, init, jnp.arange(s))
    return ys.transpose(1, 0, 2, 3).astype(x.dtype), final


def _causal_conv(xBC, w, bias, conv_state=None):
    """Depthwise causal conv1d, kernel (K, C).  xBC: (B, S, C).
    With conv_state (B, K-1, C) for decode (S=1), returns (out, new_state)."""
    K = w.shape[0]
    if conv_state is not None:
        window = jnp.concatenate([conv_state, xBC], axis=1)         # (B, K, C)
        out = jnp.einsum("bkc,kc->bc", window.astype(jnp.float32), w.astype(jnp.float32))
        out = out + bias
        return jax.nn.silu(out)[:, None, :].astype(xBC.dtype), window[:, 1:, :]
    pad = jnp.pad(xBC, ((0, 0), (K - 1, 0), (0, 0)))
    windows = jnp.stack([pad[:, i:i + xBC.shape[1], :] for i in range(K)], axis=2)  # (B,S,K,C)
    out = jnp.einsum("bskc,kc->bsc", windows.astype(jnp.float32), w.astype(jnp.float32)) + bias
    return jax.nn.silu(out).astype(xBC.dtype), pad[:, -(K - 1):, :] if K > 1 else None


def mamba_block(x, lp, cfg: ModelConfig, shd: Optional[Sharder],
                state: Optional[Dict] = None):
    """One Mamba2 block.  x: (B, S, D).  state (decode): {"conv": (B,K-1,Cdim),
    "ssm": (B,H,P,N)}.  Returns (out, new_state)."""
    Bsz, S, D = x.shape
    Di, N, H, P = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_headdim
    h = rms_norm(x, lp["ln"], fast=cfg.fast_norm)
    zxbcdt = jnp.einsum("bsd,de->bse", h, lp["in_proj"])
    z, xin, BC, dt = jnp.split(zxbcdt, [Di, 2 * Di, 2 * Di + 2 * NGROUPS * N], axis=-1)
    xBC = jnp.concatenate([xin, BC], axis=-1)                       # (B,S,Di+2gN)
    if shd is not None:
        xBC = shd.constrain(xBC, "batch", None, "tp")

    if state is None:
        xBC, new_conv = _causal_conv(xBC, lp["conv_w"], lp["conv_b"])
    else:
        xBC, new_conv = _causal_conv(xBC, lp["conv_w"], lp["conv_b"], state["conv"])

    xs, Bmat, Cmat = jnp.split(xBC, [Di, Di + NGROUPS * N], axis=-1)
    xs = xs.reshape(Bsz, S, H, P)
    Bmat = Bmat.reshape(Bsz, S, NGROUPS, N)
    Cmat = Cmat.reshape(Bsz, S, NGROUPS, N)
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))                   # (H,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"])    # (B,S,H)

    if state is None:
        chunk = min(cfg.ssm_chunk, S)
        if S % chunk != 0:
            y, final = ssd_reference(xs, dt, A, Bmat, Cmat)
        else:
            y, final = ssd_chunked(xs, dt, A, Bmat, Cmat, chunk)
        new_ssm = final
    else:
        # O(1) decode: single-step recurrence
        da = jnp.exp(dt[:, 0] * A)                                  # (B,H)
        rep = H // NGROUPS
        Bf = jnp.repeat(Bmat[:, 0], rep, axis=1).astype(jnp.float32)
        Cf = jnp.repeat(Cmat[:, 0], rep, axis=1).astype(jnp.float32)
        upd = jnp.einsum("bh,bhp,bhn->bhpn", dt[:, 0], xs[:, 0].astype(jnp.float32), Bf)
        new_ssm = state["ssm"] * da[..., None, None] + upd
        y = jnp.einsum("bhn,bhpn->bhp", Cf, new_ssm)[:, None].astype(x.dtype)

    y = (y.astype(jnp.float32) + xs.astype(jnp.float32) * lp["D_skip"][None, None, :, None])
    y = y.reshape(Bsz, S, Di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype), lp["gate_ln"],
                 fast=cfg.fast_norm)
    out = jnp.einsum("bse,ed->bsd", y, lp["out_proj"])
    # prefill also returns resumable states (conv tail + final ssm state)
    new_state = {"conv": new_conv, "ssm": new_ssm}
    return out, new_state
