from .model import Model, build_model
from .sharding import Sharder, tree_shardings, tree_shardings_shaped
