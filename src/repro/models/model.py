"""Model facade: one interface over all 10 architectures.

  model = build_model(cfg)
  params = model.init(key)                      # real arrays (smoke/small scale)
  aparams = model.abstract_params()             # ShapeDtypeStructs (dry-run)
  loss = model.loss(params, batch)              # train objective
  logits, cache = model.prefill(params, batch, cache_len)
  logits, cache = model.decode(params, cache, tokens, pos)
  batch = model.input_specs(shape)              # abstract inputs per ShapeConfig
  cache = model.abstract_cache(shape)           # abstract KV/SSM cache

Logical-axis trees (`param_logical`, `cache_logical`, `batch_logical`) feed the
Sharder to produce in/out shardings for pjit — see launch/dryrun.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import hybrid as H
from . import mamba2 as M
from . import transformer as T
from .sharding import Sharder

PARAM_DTYPE = T.PARAM_DTYPE


def _text_len(cfg: ModelConfig, seq_len: int) -> int:
    return seq_len - cfg.n_img_tokens if cfg.family == "vlm" else seq_len


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    shd: Sharder

    # ------------------------------------------------------------- params
    def param_defs(self):
        c = self.cfg
        if c.family == "ssm":
            D, V = c.d_model, c.vocab
            return {
                "emb": ((V, D), ("vocab", None)),
                "layers": M.ssm_param_defs(c),
                "ln_f": ((D,), (None,)),
                "head": ((V, D), ("vocab", None)),
            }
        if c.family == "hybrid":
            return H.hybrid_param_defs(c)
        return T.dense_param_defs(c)

    def init(self, key):
        return T.init_from_defs(self.param_defs(), key, self.cfg.d_model)

    def abstract_params(self):
        return T.abstract_from_defs(self.param_defs())

    def param_logical(self):
        return T.logical_from_defs(self.param_defs())

    # ------------------------------------------------------------- embed
    def _embed(self, params, batch) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """Returns (x, positions)."""
        c = self.cfg
        if c.family == "vlm":
            tx = params["emb"][batch["tokens"]]
            x = jnp.concatenate([batch["img_embeds"].astype(tx.dtype), tx], axis=1)
        else:
            x = T.embed_tokens(params, batch["tokens"], c)
        if self.shd.mesh is not None:
            x = self.shd.constrain(x, "batch", None, None)
        B, S = x.shape[0], x.shape[1]
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        return x, positions

    # --------------------------------------------------------------- loss
    def loss(self, params, batch):
        c = self.cfg
        x, positions = self._embed(params, batch)
        if c.family == "ssm":
            xh = self._ssm_forward(params, x)
        elif c.family == "hybrid":
            xh = H.hybrid_forward(params, x, c, self.shd, positions)
        else:
            xh, aux = T.forward(params, x, c, self.shd, positions)
        logits = T.unembed(params, xh[:, :-1], c, self.shd)
        if c.family == "vlm":
            targets = batch["tokens"][:, 1:]
            logits = logits[:, c.n_img_tokens:]
            loss = T.cross_entropy(logits, targets)
        elif c.n_codebooks:
            targets = batch["tokens"][:, 1:]          # (B, S-1, nq)
            loss = T.cross_entropy(logits.transpose(0, 1, 2, 3), targets)
        else:
            targets = batch["tokens"][:, 1:]
            loss = T.cross_entropy(logits, targets)
        if c.family == "moe":
            loss = loss + 0.01 * aux
        return loss

    # ------------------------------------------------------------- ssm fw
    def _ssm_forward(self, params, x):
        c = self.cfg

        def body(carry, lp):
            out, _ = M.mamba_block(carry, lp, c, self.shd)
            h = carry + out
            if self.shd.mesh is not None:
                h = self.shd.constrain(h, "batch", None, None)
            return h, None

        if c.remat == "block":
            body = jax.checkpoint(body)
        x, _ = jax.lax.scan(body, x, params["layers"])
        return T.rms_norm(x, params["ln_f"])

    # ------------------------------------------------------------ serving
    def abstract_cache(self, shape: ShapeConfig, batch_size: Optional[int] = None):
        c = self.cfg
        B = batch_size or shape.global_batch
        S = shape.seq_len
        K, hd = c.n_kv_heads, c.head_dim
        mk = lambda sh, dt=PARAM_DTYPE: jax.ShapeDtypeStruct(sh, dt)
        if c.family == "ssm":
            L = c.n_layers
            return {
                "conv": mk((L, B, c.ssm_conv - 1, c.d_inner + 2 * M.NGROUPS * c.ssm_state)),
                "ssm": mk((L, B, c.ssm_heads, c.ssm_headdim, c.ssm_state), jnp.float32),
            }
        if c.family == "hybrid":
            G = c.n_layers // c.attn_every
            R = c.n_layers - G * c.attn_every
            conv_dim = c.d_inner + 2 * M.NGROUPS * c.ssm_state
            d = {
                "mamba": {
                    "conv": mk((G * c.attn_every, B, c.ssm_conv - 1, conv_dim)),
                    "ssm": mk((G * c.attn_every, B, c.ssm_heads, c.ssm_headdim, c.ssm_state), jnp.float32),
                },
                "k": mk((G, B, S, K, hd)),
                "v": mk((G, B, S, K, hd)),
            }
            if R:
                d["extra"] = {
                    "conv": mk((R, B, c.ssm_conv - 1, conv_dim)),
                    "ssm": mk((R, B, c.ssm_heads, c.ssm_headdim, c.ssm_state), jnp.float32),
                }
            return d
        L = c.n_layers
        return {"k": mk((L, B, S, K, hd)), "v": mk((L, B, S, K, hd))}

    def cache_logical(self, shape: ShapeConfig):
        c = self.cfg
        if c.family == "ssm":
            return {"conv": (None, "batch", None, "tp"),
                    "ssm": (None, "batch", "tp", None, None)}
        if c.family == "hybrid":
            d = {
                "mamba": {"conv": (None, "batch", None, "tp"),
                          "ssm": (None, "batch", "tp", None, None)},
                "k": (None, "batch", "seq", None, None),
                "v": (None, "batch", "seq", None, None),
            }
            G = c.n_layers // c.attn_every
            if c.n_layers - G * c.attn_every:
                d["extra"] = {"conv": (None, "batch", None, "tp"),
                              "ssm": (None, "batch", "tp", None, None)}
            return d
        return {"k": (None, "batch", "seq", None, None),
                "v": (None, "batch", "seq", None, None)}

    def init_cache(self, shape: ShapeConfig, batch_size: Optional[int] = None):
        return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                            self.abstract_cache(shape, batch_size),
                            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))

    def prefill(self, params, batch, cache):
        """Forward over a prompt, filling `cache`.  Returns (last_logits, cache)."""
        c = self.cfg
        x, positions = self._embed(params, batch)
        if c.family == "ssm":
            xh, cache = self._ssm_cached(params, x, cache, pos=None)
        elif c.family == "hybrid":
            xh, cache = H.hybrid_forward_cached(params, x, c, self.shd, positions, cache)
        else:
            xh, cache = T.forward_with_cache(params, x, c, self.shd, positions, cache)
        logits = T.unembed(params, xh[:, -1:], c, self.shd)
        return logits, cache

    def decode(self, params, cache, tokens, pos):
        """One decode step.  tokens: (B,) int32 (audio: (B, nq)).  pos: scalar."""
        c = self.cfg
        if c.n_codebooks:
            x = T.embed_tokens(params, tokens[:, None, :], c)     # (B,1,D)
        elif c.family == "vlm":
            x = params["emb"][tokens[:, None]]
        else:
            x = T.embed_tokens(params, tokens[:, None], c)
        if self.shd.mesh is not None:
            x = self.shd.constrain(x, "batch", None, None)
        positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
        if c.family == "ssm":
            xh, cache = self._ssm_cached(params, x, cache, pos=pos)
        elif c.family == "hybrid":
            xh, cache = H.hybrid_forward_cached(params, x, c, self.shd, positions,
                                                cache, pos=pos)
        else:
            xh, cache = T.forward_with_cache(params, x, c, self.shd, positions,
                                             cache, pos=pos)
        logits = T.unembed(params, xh[:, -1:], c, self.shd)
        return logits, cache

    def _ssm_cached(self, params, x, cache, pos=None):
        c = self.cfg

        if pos is None:
            def body(carry, lp):
                out, st = M.mamba_block(carry, lp, c, self.shd)
                return carry + out, st
            x, states = jax.lax.scan(body, x, params["layers"])
        else:
            def body(carry, xs):
                lp, conv, ssm = xs
                out, st = M.mamba_block(carry, lp, c, self.shd,
                                        {"conv": conv, "ssm": ssm})
                return carry + out, st
            x, states = jax.lax.scan(body, x, (params["layers"], cache["conv"], cache["ssm"]))
        return T.rms_norm(x, params["ln_f"]), states

    # -------------------------------------------------------------- inputs
    def input_specs(self, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
        """Abstract model inputs for one ShapeConfig (modality frontends are stubs:
        VLM gets precomputed patch embeddings, audio gets codebook token ids)."""
        c = self.cfg
        B, S = shape.global_batch, shape.seq_len
        i32 = jnp.int32
        if shape.kind == "decode":
            tok = jax.ShapeDtypeStruct((B, c.n_codebooks), i32) if c.n_codebooks \
                else jax.ShapeDtypeStruct((B,), i32)
            return {"tokens": tok, "pos": jax.ShapeDtypeStruct((), i32)}
        if c.family == "vlm":
            return {
                "tokens": jax.ShapeDtypeStruct((B, _text_len(c, S)), i32),
                "img_embeds": jax.ShapeDtypeStruct((B, c.n_img_tokens, c.d_model), PARAM_DTYPE),
            }
        if c.n_codebooks:
            return {"tokens": jax.ShapeDtypeStruct((B, S, c.n_codebooks), i32)}
        return {"tokens": jax.ShapeDtypeStruct((B, S), i32)}

    def batch_logical(self, shape: ShapeConfig):
        c = self.cfg
        if shape.kind == "decode":
            tok = ("batch", None) if c.n_codebooks else ("batch",)
            return {"tokens": tok, "pos": ()}
        if c.family == "vlm":
            return {"tokens": ("batch", None), "img_embeds": ("batch", None, None)}
        if c.n_codebooks:
            return {"tokens": ("batch", None, None)}
        return {"tokens": ("batch", None)}

    def make_batch(self, shape: ShapeConfig, seed: int = 0):
        """Concrete random batch (smoke tests / examples)."""
        import numpy as np
        rng = np.random.RandomState(seed)
        specs = self.input_specs(shape)
        out = {}
        for k, sds in specs.items():
            if jnp.issubdtype(sds.dtype, jnp.integer):
                hi = self.cfg.vocab if k == "tokens" else 2
                if k == "pos":
                    out[k] = jnp.array(shape.seq_len // 2, jnp.int32)
                else:
                    out[k] = jnp.array(rng.randint(0, hi, sds.shape), jnp.int32)
            else:
                out[k] = jnp.array(rng.randn(*sds.shape), jnp.float32).astype(sds.dtype)
        return out


def build_model(cfg: ModelConfig, mesh=None, seq_axes=None, overrides=None) -> Model:
    """seq_axes: remap the "seq" logical axis (KV-cache sequence sharding), e.g.
    ("model", "data") for batch=1 long-context decode where the batch axes idle.
    overrides: full logical-axis remap dict, e.g. {"fsdp": ("data",)} to keep
    ZeRO sharding pod-local (params replicated across pods; gradients cross DCN
    once per step instead of param gathers per microbatch — EXPERIMENTS §Perf)."""
    ov = dict(overrides or {})
    if seq_axes:
        ov["seq"] = tuple(seq_axes)
    return Model(cfg, Sharder(mesh, ov or None))
