"""Decoder-only transformer family: dense (stablelm/mistral/qwen/smollm), VLM
backbone (internvl2), audio decoder (musicgen), MoE (dbrx/deepseek via moe.py).

Everything is pure-functional: params are nested dicts; layer params are stacked
along a leading L axis and consumed by lax.scan (keeps HLO size O(1) in depth —
essential for 88-layer x 512-device dry-runs); remat ("block") checkpoints each
layer body.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import apply_rope, attention, decode_attention, mlp, rms_norm
from .sharding import Sharder

PARAM_DTYPE = jnp.bfloat16


# --------------------------------------------------------------------- defs
def dense_param_defs(cfg: ModelConfig) -> Dict[str, Any]:
    """name -> (shape, logical dims).  Single source for init/abstract/specs."""
    L, D, F, V = cfg.n_layers, cfg.d_model, cfg.d_ff, cfg.vocab
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    defs: Dict[str, Any] = {}
    nq = max(cfg.n_codebooks, 1)
    emb_shape = (V, D) if nq == 1 else (nq, V, D)
    emb_logical = ("vocab", None) if nq == 1 else (None, "vocab", None)
    defs["emb"] = (emb_shape, emb_logical)
    lyr: Dict[str, Any] = {
        "ln1": ((L, D), (None, None)),
        "wo": ((L, H * hd, D), (None, "tp", "fsdp")),
        "ln2": ((L, D), (None, None)),
    }
    if cfg.fused_qkv and not cfg.qkv_bias:
        lyr["wqkv"] = ((L, D, (H + 2 * K) * hd), (None, "fsdp", "tp"))
    else:
        lyr["wq"] = ((L, D, H * hd), (None, "fsdp", "tp"))
        lyr["wk"] = ((L, D, K * hd), (None, "fsdp", "tp"))
        lyr["wv"] = ((L, D, K * hd), (None, "fsdp", "tp"))
    if cfg.qkv_bias:
        lyr["bq"] = ((L, H * hd), (None, "tp"))
        lyr["bk"] = ((L, K * hd), (None, "tp"))
        lyr["bv"] = ((L, K * hd), (None, "tp"))
    if cfg.family == "moe":
        E, Fe = cfg.n_experts, (cfg.d_expert or F)
        lyr["router"] = ((L, D, E), (None, "fsdp", None))
        lyr["experts"] = {
            "w1": ((L, E, D, Fe), (None, "expert", "fsdp", None)),
            "w3": ((L, E, D, Fe), (None, "expert", "fsdp", None)),
            "w2": ((L, E, Fe, D), (None, "expert", None, "fsdp")),
        }
        if cfg.n_shared_experts:
            Fs = cfg.n_shared_experts * Fe
            lyr["shared"] = {
                "w1": ((L, D, Fs), (None, "fsdp", "tp")),
                "w3": ((L, D, Fs), (None, "fsdp", "tp")),
                "w2": ((L, Fs, D), (None, "tp", "fsdp")),
            }
    else:
        m = {"w1": ((L, D, F), (None, "fsdp", "tp")),
             "w2": ((L, F, D), (None, "tp", "fsdp"))}
        if cfg.mlp == "swiglu":
            m["w3"] = ((L, D, F), (None, "fsdp", "tp"))
        lyr["mlp"] = m
    defs["layers"] = lyr
    defs["ln_f"] = ((D,), (None,))
    if not cfg.tie_embeddings:
        defs["head"] = (emb_shape, emb_logical)
    return defs


def init_from_defs(defs, key, d_model: int):
    flat = {}

    def walk(d, prefix=""):
        for k, v in d.items():
            if isinstance(v, dict):
                walk(v, prefix + k + "/")
            else:
                flat[prefix + k] = v

    walk(defs)
    keys = jax.random.split(key, len(flat))
    out_flat = {}
    for (name, (shape, _)), kk in zip(sorted(flat.items()), keys):
        if name.endswith(("ln1", "ln2", "ln_f", "norm", "ln")):
            out_flat[name] = jnp.ones(shape, PARAM_DTYPE)
        elif name.endswith(("bq", "bk", "bv", "dt_bias")):
            out_flat[name] = jnp.zeros(shape, PARAM_DTYPE)
        elif name.endswith("A_log"):
            out_flat[name] = jnp.log(jnp.linspace(1.0, 16.0, shape[-1], dtype=jnp.float32)
                                     * jnp.ones(shape)).astype(jnp.float32)
        elif name.endswith("D_skip"):
            out_flat[name] = jnp.ones(shape, jnp.float32)
        else:
            scale = 1.0 / (d_model ** 0.5)
            out_flat[name] = (jax.random.normal(kk, shape, jnp.float32) * scale).astype(PARAM_DTYPE)

    def rebuild(d, prefix=""):
        out = {}
        for k, v in d.items():
            if isinstance(v, dict):
                out[k] = rebuild(v, prefix + k + "/")
            else:
                out[k] = out_flat[prefix + k]
        return out

    return rebuild(defs)


def abstract_from_defs(defs):
    def walk(d):
        out = {}
        for k, v in d.items():
            if isinstance(v, dict):
                out[k] = walk(v)
            else:
                shape, _ = v
                dt = jnp.float32 if k in ("A_log", "D_skip") else PARAM_DTYPE
                out[k] = jax.ShapeDtypeStruct(shape, dt)
        return out
    return walk(defs)


def logical_from_defs(defs):
    def walk(d):
        out = {}
        for k, v in d.items():
            if isinstance(v, dict):
                out[k] = walk(v)
            else:
                out[k] = v[1]
        return out
    return walk(defs)


# ------------------------------------------------------------------ blocks
def _layer_slice(lyr, i):
    return jax.tree.map(lambda a: a[i], lyr)


def attn_block(x, lp, cfg: ModelConfig, shd: Sharder, positions,
               kv: Optional[Tuple] = None, pos=None):
    """Pre-norm attention block.  kv=(k_cache, v_cache) for decode (S-sharded)."""
    B, S, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = rms_norm(x, lp["ln1"], fast=cfg.fast_norm)
    if "wqkv" in lp:
        qkv = jnp.einsum("bsd,de->bse", h, lp["wqkv"])
        q, k, v = jnp.split(qkv, [H * hd, (H + K) * hd], axis=-1)
    else:
        q = jnp.einsum("bsd,de->bse", h, lp["wq"])
        k = jnp.einsum("bsd,de->bse", h, lp["wk"])
        v = jnp.einsum("bsd,de->bse", h, lp["wv"])
        if cfg.qkv_bias:
            q, k, v = q + lp["bq"], k + lp["bk"], v + lp["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, K, hd)
    v = v.reshape(B, S, K, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    new_kv = None
    if kv is not None:
        k_cache, v_cache = kv
        if pos is None:  # prefill: write the whole prefix
            k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype), (0, 0, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype), (0, 0, 0, 0))
            o = attention(q, k, v, impl=cfg.attn_impl, q_block=cfg.q_block, shd=shd)
        else:           # decode: write one token at `pos`, attend over the cache
            k_cache = jax.lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0))
            v_cache = jax.lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0))
            if shd is not None:
                k_cache = shd.constrain(k_cache, "batch", "seq", None, None)
                v_cache = shd.constrain(v_cache, "batch", "seq", None, None)
            o = decode_attention(q, k_cache, v_cache, pos, shd=shd)
        new_kv = (k_cache, v_cache)
    else:
        o = attention(q, k, v, impl=cfg.attn_impl, q_block=cfg.q_block, shd=shd)
    o = jnp.einsum("bse,ed->bsd", o.reshape(B, S, H * hd), lp["wo"])
    return o, new_kv


def ffn_block(x, lp, cfg: ModelConfig, shd: Sharder):
    h = rms_norm(x, lp["ln2"], fast=cfg.fast_norm)
    if cfg.family == "moe":
        from .moe import moe_ffn
        out, aux = moe_ffn(h, lp, cfg, shd)
        return out, aux
    return mlp(h, lp["mlp"], cfg.mlp, shd), 0.0


def transformer_layer(x, lp, cfg: ModelConfig, shd: Sharder, positions,
                      kv=None, pos=None):
    a, new_kv = attn_block(x, lp, cfg, shd, positions, kv, pos)
    x = x + a
    f, aux = ffn_block(x, lp, cfg, shd)
    x = x + f
    if shd is not None:
        # residual_shard: keep the carried residual d_model-sharded over `model`
        # between blocks (16x less saved-activation memory under remat; XLA
        # inserts the per-block all-gather at use — Megatron-SP adapted to FSDP+TP)
        x = shd.constrain(x, "batch", None, "tp" if cfg.residual_shard else None)
    return x, new_kv, aux


# ----------------------------------------------------------------- forward
def embed_tokens(params, tokens, cfg: ModelConfig):
    if cfg.n_codebooks:
        # tokens: (B, S, nq); sum codebook embeddings
        embs = params["emb"]                       # (nq, V, D)
        x = jnp.zeros(tokens.shape[:2] + (cfg.d_model,), PARAM_DTYPE)
        for i in range(cfg.n_codebooks):
            x = x + embs[i][tokens[..., i]]
        return x
    return params["emb"][tokens]                   # (B, S, D)


def unembed(params, x, cfg: ModelConfig, shd: Sharder):
    head = params["emb"] if cfg.tie_embeddings else params["head"]
    if cfg.n_codebooks:
        logits = jnp.einsum("bsd,qvd->bsqv", x, head)
    else:
        logits = jnp.einsum("bsd,vd->bsv", x, head)
    if shd is not None:
        logits = shd.constrain(logits, *(("batch",) + (None,) * (logits.ndim - 2) + ("tp",)))
    return logits


def forward(params, x, cfg: ModelConfig, shd: Sharder, positions):
    """Training/prefill trunk (no cache).  x: (B, S, D) embeddings."""
    lyr = params["layers"]

    def body(carry, lp):
        h, aux = carry
        h, _, a = transformer_layer(h, lp, cfg, shd, positions)
        return (h, aux + a), None

    if cfg.remat == "block":
        body = jax.checkpoint(body)
    if cfg.use_scan:
        (x, aux), _ = jax.lax.scan(body, (x, 0.0), lyr)
    else:
        aux = 0.0
        for i in range(cfg.n_layers):
            (x, aux), _ = body((x, aux), _layer_slice(lyr, i))
    return rms_norm(x, params["ln_f"]), aux


def forward_with_cache(params, x, cfg: ModelConfig, shd: Sharder, positions,
                       cache, pos=None):
    """Prefill (pos=None) or single-token decode (pos=scalar).  cache:
    {"k": (L,B,S,K,hd), "v": ...}."""
    lyr = params["layers"]

    def body(carry, xs):
        h = carry
        lp, kc, vc = xs
        h, new_kv, _ = transformer_layer(h, lp, cfg, shd, positions, (kc, vc), pos)
        return h, new_kv

    x, kvs = jax.lax.scan(body, x, (lyr, cache["k"], cache["v"]))
    new_cache = {"k": kvs[0], "v": kvs[1]}
    return rms_norm(x, params["ln_f"]), new_cache


# ------------------------------------------------------------------- losses
def cross_entropy(logits, targets, mask=None):
    """CE that stays sharded over the vocab dim: the gold logit is extracted with
    a masked reduction (partial + psum) instead of take_along_axis, which would
    all-gather the full (B,S,V) fp32 logits when V is sharded."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(iota == targets[..., None], logits, 0.0), axis=-1)
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
