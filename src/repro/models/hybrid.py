"""Zamba2-style hybrid: Mamba2 backbone + ONE shared transformer block applied
every `attn_every` SSM layers (weights shared across applications; the block input
is concat([x, x_embed]) projected 2D->D, following the Zamba design).

Layout: G = n_layers // attn_every groups of [attn_every mamba layers + shared
block], plus R = n_layers - G*attn_every trailing mamba layers (81 = 13*6 + 3 for
zamba2-7b).  The shared block's KV cache is (G, B, S, K, hd) — sequence-sharded
for long-context decode (the paper-aligned path; DESIGN.md Sec. 5).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .layers import apply_rope, attention, decode_attention, mlp, rms_norm
from .mamba2 import mamba_block, ssm_param_defs
from .sharding import Sharder


def hybrid_param_defs(cfg: ModelConfig) -> Dict:
    D, F, V = cfg.d_model, cfg.d_ff, cfg.vocab
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    G = cfg.n_layers // cfg.attn_every
    R = cfg.n_layers - G * cfg.attn_every
    defs = {
        "emb": ((V, D), ("vocab", None)),
        "mamba": ssm_param_defs(cfg, n_layers=G * cfg.attn_every),
        "shared": {
            "in_proj": ((2 * D, D), ("fsdp", None)),
            "ln_in": ((2 * D,), (None,)),
            "ln1": ((D,), (None,)),
            "wq": ((D, H * hd), ("fsdp", "tp")),
            "wk": ((D, K * hd), ("fsdp", "tp")),
            "wv": ((D, K * hd), ("fsdp", "tp")),
            "wo": ((H * hd, D), ("tp", "fsdp")),
            "ln2": ((D,), (None,)),
            "mlp": {"w1": ((D, F), ("fsdp", "tp")), "w2": ((F, D), ("tp", "fsdp"))},
            "out_proj": ((D, D), ("fsdp", None)),
        },
        "ln_f": ((D,), (None,)),
        "head": ((V, D), ("vocab", None)),
    }
    if R:
        defs["extra"] = ssm_param_defs(cfg, n_layers=R)
    return defs


def shared_block(x, x0, sp, cfg: ModelConfig, shd: Optional[Sharder], positions,
                 kv: Optional[Tuple] = None, pos=None):
    """The shared attention+MLP block.  x0: token embeddings (Zamba concat trick)."""
    B, S, D = x.shape
    H, K, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    h = rms_norm(jnp.concatenate([x, x0], axis=-1), sp["ln_in"])
    h = jnp.einsum("bse,ed->bsd", h, sp["in_proj"])
    a_in = rms_norm(h, sp["ln1"])
    q = jnp.einsum("bsd,de->bse", a_in, sp["wq"]).reshape(B, S, H, hd)
    k = jnp.einsum("bsd,de->bse", a_in, sp["wk"]).reshape(B, S, K, hd)
    v = jnp.einsum("bsd,de->bse", a_in, sp["wv"]).reshape(B, S, K, hd)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    new_kv = None
    if kv is not None:
        kc, vc = kv
        if pos is None:
            kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, 0, 0, 0))
            o = attention(q, k, v, impl=cfg.attn_impl, q_block=cfg.q_block, shd=shd)
        else:
            kc = jax.lax.dynamic_update_slice(kc, k.astype(kc.dtype), (0, pos, 0, 0))
            vc = jax.lax.dynamic_update_slice(vc, v.astype(vc.dtype), (0, pos, 0, 0))
            if shd is not None:
                kc = shd.constrain(kc, "batch", "seq", None, None)
                vc = shd.constrain(vc, "batch", "seq", None, None)
            o = decode_attention(q, kc, vc, pos, shd=shd)
        new_kv = (kc, vc)
    else:
        o = attention(q, k, v, impl=cfg.attn_impl, q_block=cfg.q_block, shd=shd)
    h = h + jnp.einsum("bse,ed->bsd", o.reshape(B, S, H * hd), sp["wo"])
    h = h + mlp(rms_norm(h, sp["ln2"]), sp["mlp"], cfg.mlp, shd)
    return x + jnp.einsum("bsd,de->bse", h, sp["out_proj"]), new_kv


def _group_tree(tree, G: int, M: int):
    return jax.tree.map(lambda a: a.reshape(G, M, *a.shape[1:]), tree)


def hybrid_forward(params, x0, cfg: ModelConfig, shd: Optional[Sharder], positions):
    """Training/scoring trunk.  x0: (B, S, D) embeddings."""
    G, M = cfg.n_layers // cfg.attn_every, cfg.attn_every
    grouped = _group_tree(params["mamba"], G, M)
    sp = params["shared"]

    def inner(c, lp):
        out, _ = mamba_block(c, lp, cfg, shd)
        return c + out, None

    def group_body(c, gp):
        h, _ = jax.lax.scan(inner, c, gp)
        h, _ = shared_block(h, x0, sp, cfg, shd, positions)
        if shd is not None:
            h = shd.constrain(h, "batch", None, None)
        return h, None

    if cfg.remat == "block":
        group_body = jax.checkpoint(group_body)
    x, _ = jax.lax.scan(group_body, x0, grouped)
    if "extra" in params:
        body = jax.checkpoint(inner) if cfg.remat == "block" else inner
        x, _ = jax.lax.scan(body, x, params["extra"])
    return rms_norm(x, params["ln_f"])


def hybrid_forward_cached(params, x0, cfg: ModelConfig, shd, positions, cache, pos=None):
    """Prefill (pos None) / decode (pos scalar) with states.

    cache = {"mamba": {"conv","ssm"} leading dim G*M, "extra": same (R),
             "k","v": (G, B, S, K, hd)}  (mamba states present only in decode).
    """
    G, M = cfg.n_layers // cfg.attn_every, cfg.attn_every
    grouped = _group_tree(params["mamba"], G, M)
    sp = params["shared"]
    decode = pos is not None

    def inner(c, xs):
        lp, st = xs
        out, new_st = mamba_block(c, lp, cfg, shd, st)
        return c + out, new_st

    def inner_prefill(c, lp):
        out, st = mamba_block(c, lp, cfg, shd)
        return c + out, st

    def group_body(c, xs):
        if decode:
            gp, gst, kc, vc = xs
            h, new_st = jax.lax.scan(inner, c, (gp, gst))
        else:
            gp, kc, vc = xs
            h, new_st = jax.lax.scan(inner_prefill, c, gp)
        h, (kc, vc) = shared_block(h, x0, sp, cfg, shd, positions, (kc, vc), pos)
        return h, (new_st, kc, vc)

    if decode:
        gstates = _group_tree(cache["mamba"], G, M)
        x, (new_states, kcs, vcs) = jax.lax.scan(
            group_body, x0, (grouped, gstates, cache["k"], cache["v"]))
    else:
        x, (new_states, kcs, vcs) = jax.lax.scan(
            group_body, x0, (grouped, cache["k"], cache["v"]))
    new_cache = {"mamba": jax.tree.map(lambda a: a.reshape(G * M, *a.shape[2:]), new_states),
                 "k": kcs, "v": vcs}

    if "extra" in params:
        if decode:
            x, new_extra = jax.lax.scan(inner, x, (params["extra"], cache["extra"]))
        else:
            x, new_extra = jax.lax.scan(inner_prefill, x, params["extra"])
        new_cache["extra"] = new_extra
    return rms_norm(x, params["ln_f"]), new_cache
