"""Shared neural building blocks: RMSNorm, RoPE, GQA attention (blockwise /
naive / sequence-sharded decode), MLPs.

Attention implementations:
  * naive      — full (S x S) scores; reference/oracle only.
  * blockwise  — lax.scan over query blocks with a bounded score tile; identical
                 math, memory O(q_block * S) instead of O(S^2).  This is also the
                 jnp twin of kernels/flash_attention (the Pallas TPU kernel).
  * decode     — one query position against a KV cache whose *sequence* dimension
                 is sharded over the `model` mesh axis ("seq" logical axis): XLA
                 partitions the contraction and inserts the psum — the TPU-native
                 flash-decode / sequence-parallel pattern of DESIGN.md Sec. 5,
                 which is what makes 500k-token decode representable.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .sharding import Sharder


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-5,
             fast: bool = False) -> jnp.ndarray:
    dt = x.dtype
    if fast:
        # beyond-paper §Perf knob: variance via a dot with fp32 accumulation —
        # no materialized fp32 copy of x (2x traffic) per norm; the scale
        # multiply stays in the input dtype (standard mixed-precision practice)
        var = jnp.einsum("...d,...d->...", x, x,
                         preferred_element_type=jnp.float32)[..., None] / x.shape[-1]
        inv = jax.lax.rsqrt(var + eps).astype(dt)
        return x * inv * scale.astype(dt)
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (..., S, 1, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _repeat_kv(k: jnp.ndarray, n_rep: int) -> jnp.ndarray:
    """(B, S, K, hd) -> (B, S, K*n_rep, hd)."""
    if n_rep == 1:
        return k
    b, s, kh, hd = k.shape
    return jnp.broadcast_to(k[:, :, :, None, :], (b, s, kh, n_rep, hd)).reshape(b, s, kh * n_rep, hd)


def naive_attention(q, k, v, *, causal: bool = True, q_offset: int = 0,
                    shd: Optional[Sharder] = None) -> jnp.ndarray:
    """q: (B, Sq, H, hd); k, v: (B, Sk, H, hd) (kv already repeated to H)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        qpos = jnp.arange(sq) + q_offset
        mask = qpos[:, None] >= jnp.arange(sk)[None, :]
        scores = jnp.where(mask[None, None], scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)


def blockwise_attention(q, k, v, *, causal: bool = True, q_block: int = 256,
                        q_offset: int = 0, shd: Optional[Sharder] = None,
                        context_parallel: bool = False) -> jnp.ndarray:
    """Memory-bounded attention: scan over query blocks (score tile q_block x Sk).

    context_parallel=True shards the *within-block* query dim over the `model`
    axis ("seq" logical) — the fallback when the head count does not divide the
    TP axis (smollm 9H, qwen 20H, musicgen 24H on a 16-way axis): compute still
    splits 16 ways, with kv replicated (the all-gathered kv of standard TP)."""
    b, sq, h, hd = q.shape
    sk = k.shape[1]
    qb = min(q_block, sq)
    if sq % qb != 0:
        return naive_attention(q, k, v, causal=causal, q_offset=q_offset, shd=shd)
    nb = sq // qb
    scale = 1.0 / math.sqrt(hd)
    qr = q.reshape(b, nb, qb, h, hd).transpose(1, 0, 2, 3, 4)   # (nb, B, qb, H, hd)
    kpos = jnp.arange(sk)

    def body(_, args):
        i, qi = args
        if shd is not None and context_parallel:
            qi = shd.constrain(qi, "batch", "seq", None, None)
        s = jnp.einsum("bqhd,bkhd->bhqk", qi, k,
                       preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = i * qb + jnp.arange(qb) + q_offset
            mask = qpos[:, None] >= kpos[None, :]
            s = jnp.where(mask[None, None], s, -1e30)
        p = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v)
        if shd is not None and context_parallel:
            o = shd.constrain(o, "batch", "seq", None, None)  # (B, qb, H, hd)
        return None, o

    # Flash semantics: never materialize the (nb, B, H, qb, Sk) probability stack
    # for backward — recompute each block's scores in the backward pass.
    body = jax.checkpoint(body)
    _, out = jax.lax.scan(body, None, (jnp.arange(nb), qr))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, hd)


def decode_attention(q, k_cache, v_cache, pos, *, shd: Optional[Sharder] = None) -> jnp.ndarray:
    """One-token attention against a (possibly sequence-sharded) KV cache.

    q: (B, 1, H, hd); caches: (B, S, K, hd); pos: scalar index of the current token
    (caches already contain it).  The cache's S dim carries the "seq" logical axis;
    the softmax/contraction over S is partitioned by XLA (partial max/sum + psum).

    GQA stays *grouped*: q is reshaped to (B, 1, K, G, hd) and contracted against
    the K-head cache directly — no materialized H-head repeat (12x for
    mistral-large), and `preferred_element_type` keeps the cache operand bf16
    with fp32 accumulation instead of upcasting the whole cache slice (measured:
    -0.9 GB/layer fused f32 transpose-copies on the 123B decode cell)."""
    b, s, kh, hd = k_cache.shape
    h = q.shape[2]
    g = h // kh
    qg = q.reshape(b, 1, kh, g, hd)
    if shd is not None:
        k_cache = shd.constrain(k_cache, "batch", "seq", None, None)
        v_cache = shd.constrain(v_cache, "batch", "seq", None, None)
    scale = 1.0 / math.sqrt(hd)
    scores = jnp.einsum("bqkgd,bskd->bkgqs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale  # (B,K,G,1,S)
    mask = (jnp.arange(s) <= pos)[None, None, None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def attention(q, k, v, *, impl: str = "blockwise", causal: bool = True,
              q_block: int = 256, q_offset: int = 0,
              shd: Optional[Sharder] = None) -> jnp.ndarray:
    """Dispatch over implementations; kv is (B, S, K, hd) with K | H.

    Sharding: heads over `model` when the head count divides the TP axis
    (Megatron-style); otherwise context-parallel query sharding inside the
    blockwise scan (see blockwise_attention)."""
    h = q.shape[2]
    kh = k.shape[2]
    k = _repeat_kv(k, h // kh)
    v = _repeat_kv(v, h // kh)
    tp = shd.axis_size("tp") if shd is not None else 1
    head_sharded = tp > 1 and h % tp == 0
    context_parallel = tp > 1 and not head_sharded
    if shd is not None and head_sharded:
        q = shd.constrain(q, "batch", None, "tp", None)
        k = shd.constrain(k, "batch", None, "tp", None)
        v = shd.constrain(v, "batch", None, "tp", None)
    elif shd is not None and context_parallel:
        # KV-sequence sharding: softmax stats and the output block are psum-merged
        # (tiny + one (B,qb,H,hd) block per layer); dk/dv gradients stay local —
        # unlike query sharding, whose backward all-reduces dk/dv per block.
        k = shd.constrain(k, "batch", "seq", None, None)
        v = shd.constrain(v, "batch", "seq", None, None)
    if impl == "pallas":
        from ..kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal)
    if impl == "naive":
        return naive_attention(q, k, v, causal=causal, q_offset=q_offset, shd=shd)
    return blockwise_attention(q, k, v, causal=causal, q_block=q_block,
                               q_offset=q_offset, shd=shd,
                               context_parallel=False)


def mlp(x: jnp.ndarray, params: dict, kind: str = "swiglu",
        shd: Optional[Sharder] = None) -> jnp.ndarray:
    """swiglu: silu(x@w1) * (x@w3) @ w2;  gelu: gelu(x@w1) @ w2."""
    h = jnp.einsum("...d,df->...f", x, params["w1"])
    if kind == "swiglu":
        g = jnp.einsum("...d,df->...f", x, params["w3"])
        h = jax.nn.silu(h) * g
    else:
        h = jax.nn.gelu(h)
    if shd is not None:
        h = shd.constrain(h, *(("batch",) + (None,) * (h.ndim - 2) + ("tp",)))
    return jnp.einsum("...f,fd->...d", h, params["w2"])
