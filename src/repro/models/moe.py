"""Mixture-of-Experts FFN: top-k routing with per-row capacity (token dropping).

Formulation chosen for TPU/SPMD friendliness (DESIGN.md Sec. 5):
  * routing + dispatch indices are computed *per batch row* (vmap over B), so they
    never cross the data-parallel sharding;
  * dispatch is a pure gather into an (E, C, D) buffer — the expert dim carries the
    "expert" logical axis (the `model` mesh axis), so the gather materializes the
    all-to-all token exchange under XLA SPMD;
  * expert compute is one batched matmul (E, C, D) x (E, D, F);
  * combine is a gather back in token space + weighted sum over the k slots; the
    sum over experts crosses the `expert` sharding, so XLA emits the combine
    collective (the MoE all-to-all/all-reduce of the paper's alltoall study).

Capacity C = ceil(S * top_k / E * capacity_factor); overflow tokens are dropped
(standard Switch semantics).  The aux output is the load-balancing loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from .sharding import Sharder


def _capacity(seq: int, cfg: ModelConfig) -> int:
    c = int(seq * cfg.top_k / cfg.n_experts * cfg.capacity_factor) + 1
    return min(max(c, cfg.top_k), seq * cfg.top_k)


def route_row(xrow: jnp.ndarray, router: jnp.ndarray, cfg: ModelConfig, capacity: int):
    """xrow: (S, D); router: (D, E).  Returns dispatch/combine indices."""
    S = xrow.shape[0]
    E, k = cfg.n_experts, cfg.top_k
    logits = jnp.einsum("sd,de->se", xrow.astype(jnp.float32), router.astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, k)                     # (S, k)
    w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)  # renormalize over top-k
    e_flat = idx.reshape(-1)                             # (S*k,)
    w_flat = w.reshape(-1)
    order = jnp.argsort(e_flat)                          # stable
    sorted_e = e_flat[order]
    counts = jnp.bincount(e_flat, length=E)              # (E,)
    starts = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(S * k) - starts[sorted_e]      # rank within expert
    # dispatch gather indices: buffer slot (e, c) <- sorted position starts[e]+c
    src = jnp.clip(starts[:, None] + jnp.arange(capacity)[None, :], 0, S * k - 1)  # (E, C)
    tok_slot = order[src]                                # (E, C) token-slot ids
    valid = jnp.arange(capacity)[None, :] < jnp.minimum(counts[:, None], capacity)
    # combine gather indices: token-slot t -> (expert, position) with drop mask
    inv_order = jnp.argsort(order)
    c_of_slot = pos_in_e[inv_order]                      # (S*k,)
    keep = c_of_slot < capacity
    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    f = counts.astype(jnp.float32) / (S * k)
    p = probs.mean(axis=0)
    aux = E * jnp.sum(f * p)
    return dict(tok=tok_slot // cfg.top_k, valid=valid, e_of_slot=e_flat,
                c_of_slot=c_of_slot, keep=keep, w=w_flat, aux=aux)


def moe_ffn(x: jnp.ndarray, lp: dict, cfg: ModelConfig, shd: Sharder) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) normalized hidden states; lp: layer params (router/experts[/shared])."""
    B, S, D = x.shape
    E, k = cfg.n_experts, cfg.top_k
    C = _capacity(S, cfg)
    r = jax.vmap(lambda xr: route_row(xr, lp["router"], cfg, C))(x)

    # ---- dispatch (pure gather; buffer sharded over the expert axis) ----
    xb = jax.vmap(lambda xr, tok: xr[tok])(x, r["tok"])   # (B, E, C, D)
    xb = xb * r["valid"][..., None].astype(x.dtype)
    if shd is not None:
        xb = shd.constrain(xb, "batch", "expert", None, None)

    # ---- expert compute: batched swiglu ----
    h = jnp.einsum("becd,edf->becf", xb, lp["experts"]["w1"])
    g = jnp.einsum("becd,edf->becf", xb, lp["experts"]["w3"])
    h = jax.nn.silu(h) * g
    y = jnp.einsum("becf,efd->becd", h, lp["experts"]["w2"])  # (B, E, C, D)
    if shd is not None:
        y = shd.constrain(y, "batch", "expert", None, None)

    # ---- combine: gather back per token-slot, weighted sum over k ----
    def combine_row(yr, e_of, c_of, keep, w):
        vals = yr[e_of, jnp.clip(c_of, 0, C - 1)]          # (S*k, D)
        vals = vals * (keep & True)[:, None] * w[:, None]
        return vals.reshape(S, k, -1).sum(axis=1)

    out = jax.vmap(combine_row)(y.astype(jnp.float32), r["e_of_slot"], r["c_of_slot"],
                                r["keep"], r["w"])
    if shd is not None:
        out = shd.constrain(out, "batch", None, None)

    if cfg.n_shared_experts:
        sh = lp["shared"]
        hs = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, sh["w1"])) * \
            jnp.einsum("bsd,df->bsf", x, sh["w3"])
        out = out + jnp.einsum("bsf,fd->bsd", hs, sh["w2"]).astype(jnp.float32)

    return out.astype(x.dtype), jnp.mean(r["aux"])
