from . import ops, ref
