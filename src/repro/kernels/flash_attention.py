"""Pallas TPU flash attention (forward): blocked causal attention, online softmax.

TPU mapping (DESIGN.md Sec. 6): grid = (batch*heads, q_blocks, kv_blocks) with
the kv dimension sequential ("arbitrary" semantics); per-(bh, qb) running max /
normalizer / accumulator live in VMEM scratch across kv iterations.  Block shapes
are (q_block, head_dim) / (kv_block, head_dim) — multiples of the (8, 128) TPU
tile; head_dim 64/128 aligns the MXU contraction.

Validated in interpret mode against ref.py (CPU container; Mosaic unavailable).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                  *, causal: bool, q_block: int, kv_block: int, scale: float):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    run = True
    if causal:
        # whole kv block strictly above the diagonal? skip.
        run = (ki * kv_block) <= (qi * q_block + q_block - 1)

    @pl.when(run if causal else True)
    def _body():
        q = q_ref[0, :, :].astype(jnp.float32)            # (qb, hd)
        k = k_ref[0, :, :].astype(jnp.float32)            # (kb, hd)
        v = v_ref[0, :, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * q_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kpos = ki * kv_block + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_scr[...]                                # (qb, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _final():
        o_ref[0, :, :] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


def flash_attention_fwd(q, k, v, *, causal: bool = True, q_block: int = 128,
                        kv_block: int = 128, interpret: bool = True) -> jnp.ndarray:
    """q, k, v: (BH, S, hd) — batch and heads pre-merged, kv pre-repeated to H.
    Returns (BH, S, hd)."""
    bh, s, hd = q.shape
    q_block = min(q_block, s)
    kv_block = min(kv_block, s)
    assert s % q_block == 0 and s % kv_block == 0
    nq, nk = s // q_block, s // kv_block
    scale = 1.0 / math.sqrt(hd)
    kernel = functools.partial(_flash_kernel, causal=causal, q_block=q_block,
                               kv_block=kv_block, scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, nk),
        in_specs=[
            pl.BlockSpec((1, q_block, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, kv_block, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, kv_block, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, q_block, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((q_block, 1), jnp.float32),    # running max
            pltpu.VMEM((q_block, 1), jnp.float32),    # normalizer
            pltpu.VMEM((q_block, hd), jnp.float32),   # output accumulator
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)
