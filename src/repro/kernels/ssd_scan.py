"""Pallas TPU Mamba2 SSD scan: per-(batch, head-block) chunked recurrence.

Grid = (B, H/hb, n_chunks); the chunk dimension is sequential ("arbitrary"):
the (hb, P, N) inter-chunk state lives in VMEM scratch across chunk steps.
Inside a chunk the recurrence is unrolled into the quadratic "dual" form
(matmuls on the MXU) exactly like models/mamba2.ssd_chunked:

  y_diag = (C B^T ∘ L) diag(dt) x      L = exp(segsum(dt*A))
  state  = state * exp(sum dt*A) + B^T (decay ∘ dt ∘ x)
  y_off  = C state_in ∘ exp(cumsum dt*A)

Single B/C group (Mamba2 default).  Validated in interpret mode against
ref.ssd_chunk_ref chained over chunks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, state_scr, *, chunk: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, :, :, :].astype(jnp.float32)        # (l, hb, p)
    dt = dt_ref[0, :, :].astype(jnp.float32)         # (l, hb)
    A = a_ref[...].astype(jnp.float32)               # (hb,)
    Bm = b_ref[0, :, :].astype(jnp.float32)          # (l, n)
    Cm = c_ref[0, :, :].astype(jnp.float32)          # (l, n)

    da = dt * A[None, :]                             # (l, hb)
    da_cs = jnp.cumsum(da, axis=0)                   # inclusive
    # L[i, j] = exp(da_cs[i] - da_cs[j]) for i >= j (per head)
    diff = da_cs[:, None, :] - da_cs[None, :, :]     # (l, l, hb)
    tri = jnp.tril(jnp.ones((chunk, chunk), jnp.bool_))
    L = jnp.where(tri[:, :, None], jnp.exp(diff), 0.0)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (l, l)
    M = CB[:, :, None] * L * dt[None, :, :]          # (i, j, hb)
    y_diag = jnp.einsum("ijh,jhp->ihp", M, x)

    # inter-chunk contribution from the incoming state
    state_in = state_scr[...]                        # (hb, p, n)
    y_off = jnp.einsum("ln,hpn->lhp", Cm, state_in) * jnp.exp(da_cs)[:, :, None]

    # state update
    decay = jnp.exp(da_cs[-1:, :] - da_cs)           # (l, hb)
    upd = jnp.einsum("ln,lh,lhp->hpn", Bm, decay * dt, x)
    state_scr[...] = state_in * jnp.exp(da_cs[-1])[:, None, None] + upd

    y_ref[0, :, :, :] = (y_diag + y_off).astype(y_ref.dtype)


def ssd_scan_fwd(x, dt, A, B, C, *, chunk: int = 128, head_block: int = 0,
                 interpret: bool = True):
    """x: (b, s, h, p); dt: (b, s, h); A: (h,); B, C: (b, s, n) (single group).
    Returns y: (b, s, h, p)."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    assert s % chunk == 0
    nc = s // chunk
    hb = head_block or h
    assert h % hb == 0
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(b, h // hb, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, hb, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
            pl.BlockSpec((1, chunk, hb), lambda bi, hi, ci: (bi, ci, hi)),
            pl.BlockSpec((hb,), lambda bi, hi, ci: (hi,)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, hi, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, hb, p), lambda bi, hi, ci: (bi, ci, hi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, s, h, p), x.dtype),
        scratch_shapes=[pltpu.VMEM((hb, p, n), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(x, dt, A, B, C)
