"""Jit'd public wrappers around the Pallas kernels (shape adaptation + dispatch).

`interpret` defaults to True in this CPU container; on a TPU deployment pass
interpret=False (Mosaic lowering) — the call sites in models/ flip via
cfg.attn_impl == "pallas".
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from . import flash_attention as _fa
from . import rmsnorm as _rn
from . import ssd_scan as _ssd


@partial(jax.jit, static_argnames=("causal", "q_block", "kv_block", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, q_block: int = 128,
                    kv_block: int = 128, interpret: bool = True):
    """q: (B, S, H, hd); k, v: (B, S, H, hd) (kv already repeated to H heads).
    Returns (B, S, H, hd)."""
    b, s, h, hd = q.shape
    fold = lambda t: t.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    out = _fa.flash_attention_fwd(fold(q), fold(k), fold(v), causal=causal,
                                  q_block=q_block, kv_block=kv_block,
                                  interpret=interpret)
    return out.reshape(b, h, s, hd).transpose(0, 2, 1, 3)


@partial(jax.jit, static_argnames=("eps", "interpret"))
def rmsnorm(x, scale, *, eps: float = 1e-5, interpret: bool = True):
    """x: (..., D)."""
    shape = x.shape
    out = _rn.rmsnorm_fwd(x.reshape(-1, shape[-1]), scale, eps=eps,
                          interpret=interpret)
    return out.reshape(shape)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 128, interpret: bool = True):
    """Mamba2 SSD over chunks.  x: (b, s, h, p); B, C: (b, s, 1, n) or (b, s, n)."""
    if B.ndim == 4:
        B = B[:, :, 0, :]
    if C.ndim == 4:
        C = C[:, :, 0, :]
    return _ssd.ssd_scan_fwd(x, dt, A, B, C, chunk=chunk, interpret=interpret)
