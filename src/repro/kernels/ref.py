"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True) -> jnp.ndarray:
    """q, k, v: (BH, S, hd)."""
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        mask = jnp.arange(sq)[:, None] >= jnp.arange(sk)[None, :]
        s = jnp.where(mask[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)


def rmsnorm_ref(x, scale, eps: float = 1e-5) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def ssd_chunk_ref(x, dt, A, B, C, h0):
    """One SSD chunk, sequential recurrence (oracle for the chunk kernel).

    x: (l, h, p); dt: (l, h); A: (h,); B, C: (l, n) (single group);
    h0: (h, p, n) incoming state.  Returns (y, h_out)."""
    l = x.shape[0]

    def step(hstate, t):
        da = jnp.exp(dt[t] * A)                               # (h,)
        upd = jnp.einsum("h,hp,n->hpn", dt[t], x[t].astype(jnp.float32),
                         B[t].astype(jnp.float32))
        hstate = hstate * da[:, None, None] + upd
        y = jnp.einsum("n,hpn->hp", C[t].astype(jnp.float32), hstate)
        return hstate, y

    h_out, ys = jax.lax.scan(step, h0.astype(jnp.float32), jnp.arange(l))
    return ys.astype(x.dtype), h_out
