"""Pallas TPU fused RMSNorm: one row-block per grid step, fp32 accumulation.

Block shape (rows, d) — rows a multiple of 8, d padded to 128 by the caller's
model dims (all assigned archs have d % 128 == 0 except smollm's 576 = 4.5*128;
the kernel only requires the *tile* alignment, handled by Mosaic's implicit
padding on TPU and exact in interpret mode).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, s_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    o_ref[...] = (x * jax.lax.rsqrt(var + eps) * s_ref[...].astype(jnp.float32)
                  ).astype(o_ref.dtype)


def rmsnorm_fwd(x, scale, *, eps: float = 1e-5, block_rows: int = 128,
                interpret: bool = True) -> jnp.ndarray:
    """x: (R, D); scale: (D,)."""
    r, d = x.shape
    block_rows = min(block_rows, r)
    while r % block_rows:
        block_rows //= 2
    block_rows = max(block_rows, 1)
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(r // block_rows,),
        in_specs=[
            pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((r, d), x.dtype),
        interpret=interpret,
    )(x, scale)
