"""Fused bucket wire codec: one-kernel gradient pack/unpack + wire quantization.

The explicit-DP hot path used to materialize the gradient wire format with
O(leaves x buckets) HLO: one `concatenate` per bucket (each slicing spans out
of every overlapping leaf), a `stack` over buckets, and one `concatenate` per
leaf on the way back (`overlap.pack_buckets` / `unpack_buckets`).  The paper's
bottom line (Obs. 1/4/5) is that exactly this kind of software overhead — not
the interconnect — is what leaves bandwidth untapped.

This module replaces that path with a *codec*: a static address table computed
once per tree structure from `overlap.make_buckets`, plus two fused kernels:

  * **pack** — gathers every gradient leaf into the stacked
    `(n_buckets, bucket_elems)` carrier *and quantizes to the wire dtype in the
    same kernel* (fp32 / bf16 / int8 + per-bucket scales).  For int8 the
    error-feedback state (a carrier-shaped fp32 buffer) is added before
    quantization and the new error is emitted by the same kernel, so
    compression composes with the overlap scan schedule instead of excluding
    it.
  * **unpack** — dequantizes the reduced carrier and scatters it back into
    per-leaf fp32 arrays.

Three interchangeable implementations (`impl=`):

  * ``"pallas"`` — the fused Pallas kernels, grid over buckets, span copies
    unrolled from the static table (pattern: `kernels/flash_attention.py`).
    Runs in interpret mode off-TPU so CPU CI exercises the kernel path.  A
    production TPU deployment would move the span table to scalar prefetch
    instead of unrolled `pl.when` branches; block shapes here keep every leaf
    resident, which is fine for the reduced CI configs.
  * ``"xla"`` — pure `dynamic_update_slice` / `dynamic_slice` lowering with
    O(1) `concatenate` ops regardless of leaf count (zero, in fact): the
    address table makes every leaf a single contiguous carrier range, so pack
    is one `dynamic_update_slice` per leaf into a flat buffer and unpack is
    one slice per leaf.  This is the default on CPU hosts.
  * ``"auto"`` — ``"pallas"`` on TPU backends, ``"xla"`` elsewhere.

Numerics: fp32 pack/unpack is exact (validated element-for-element against
`pack_buckets`/`unpack_buckets`); bf16 is a cast on the wire; int8 uses
symmetric per-bucket scales with error feedback (`new_err = packed -
dequant(q)`), the same scheme the per-tensor PR 4 wire used — now per bucket,
so bucketing no longer excludes compression.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ..core.overlap import Bucket, make_buckets

# wire name -> jnp dtype on the wire (byte/sideband accounting lives in
# core.wire.WIRE_FORMATS — the single source of truth the cost model shares)
WIRE_DTYPES = {
    "fp32": jnp.float32,
    "bf16": jnp.bfloat16,
    "int8": jnp.int8,
}


@dataclasses.dataclass(frozen=True)
class CodecTable:
    """Static address table of the fused codec, one per tree structure.

    `spans[k]` lists bucket k's copies as (leaf, src_lo, src_hi, dst_lo):
    carrier row k positions [dst_lo, dst_lo + (src_hi - src_lo)) hold leaf
    elements [src_lo, src_hi).  Because `make_buckets` walks leaves in a fixed
    order and splits them only at bucket boundaries, every leaf also occupies
    one *contiguous* range of the flattened carrier starting at
    `leaf_offsets[i]` — which is what lets the XLA fallback pack with a single
    `dynamic_update_slice` per leaf and unpack with a single slice per leaf.
    Zero-size leaves own no span and `leaf_offsets[i]` is -1.
    """

    sizes: Tuple[int, ...]
    bucket_elems: int
    reverse: bool
    spans: Tuple[Tuple[Tuple[int, int, int, int], ...], ...]
    leaf_offsets: Tuple[int, ...]

    @property
    def n_buckets(self) -> int:
        return len(self.spans)

    @property
    def total_elems(self) -> int:
        return sum(self.sizes)

    @property
    def carrier_elems(self) -> int:
        return self.n_buckets * self.bucket_elems

    def buckets(self) -> List[Bucket]:
        """The `overlap.Bucket` view of the table (for schedule arithmetic)."""
        return [Bucket(tuple((i, lo, hi) for i, lo, hi, _ in row),
                       self.bucket_elems) for row in self.spans]


def make_table(sizes: Sequence[int], bucket_elems: int,
               reverse: bool = True) -> CodecTable:
    """Build the address table from the overlap engine's bucket assignment —
    the codec and `core.overlap` share one boundary algorithm by construction."""
    sizes = tuple(int(s) for s in sizes)
    buckets = make_buckets(sizes, bucket_elems, reverse=reverse)
    cap = buckets[0].elems if buckets else max(int(bucket_elems), 1)
    offsets = [-1] * len(sizes)
    spans: List[Tuple[Tuple[int, int, int, int], ...]] = []
    for k, b in enumerate(buckets):
        dst = 0
        row = []
        for i, lo, hi in b.spans:
            row.append((i, lo, hi, dst))
            if lo == 0:
                offsets[i] = k * cap + dst
            dst += hi - lo
        spans.append(tuple(row))
    return CodecTable(sizes, cap, reverse, tuple(spans), tuple(offsets))


def _resolve_impl(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl not in ("pallas", "xla"):
        raise ValueError(f"impl must be auto/pallas/xla, got {impl!r}")
    return impl


def _quantize_rows(carrier: jnp.ndarray):
    """Symmetric per-bucket int8 quantization of a (n_buckets, cap) fp32
    carrier -> (q int8, scales fp32 (n_buckets,), new_err fp32)."""
    s = jnp.maximum(jnp.max(jnp.abs(carrier), axis=1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(carrier / s[:, None]), -127, 127).astype(jnp.int8)
    new_err = carrier - q.astype(jnp.float32) * s[:, None]
    return q, s, new_err


# ------------------------------------------------------------- XLA fallback
def _pack_xla(table: CodecTable, flat_g, scale: float, wire: str,
              err: Optional[jnp.ndarray]):
    flat = jnp.zeros((table.carrier_elems,), jnp.float32)
    for i, size in enumerate(table.sizes):
        if size == 0:
            continue
        leaf = flat_g[i].reshape(-1).astype(jnp.float32)
        flat = lax.dynamic_update_slice(flat, leaf, (table.leaf_offsets[i],))
    carrier = (flat * scale).reshape(table.n_buckets, table.bucket_elems)
    if wire == "int8":
        if err is not None:
            carrier = carrier + err
        return _quantize_rows(carrier)
    return carrier.astype(WIRE_DTYPES[wire]), None, err


def _unpack_xla(table: CodecTable, carrier, like,
                scales: Optional[jnp.ndarray]) -> List[jnp.ndarray]:
    flat = carrier.astype(jnp.float32)
    if scales is not None:
        flat = flat * scales[:, None]
    flat = flat.reshape(-1)
    out = []
    for i, g in enumerate(like):
        if table.sizes[i] == 0:
            out.append(jnp.zeros(g.shape, jnp.float32))
            continue
        piece = lax.dynamic_slice(flat, (table.leaf_offsets[i],),
                                  (table.sizes[i],))
        out.append(piece.reshape(g.shape))
    return out


# ------------------------------------------------------------ Pallas kernels
def _pack_kernel(*refs, table: CodecTable, scale: float, wire: str,
                 with_err: bool, leaf_pos):
    k = pl.program_id(0)
    n_in = len(leaf_pos) + (1 if with_err else 0)
    n_out = 1 + (2 if wire == "int8" else 0)
    leaf_refs = refs[:len(leaf_pos)]
    err_ref = refs[len(leaf_pos)] if with_err else None
    out_ref = refs[n_in]
    row_scr = refs[n_in + n_out]
    row_scr[...] = jnp.zeros_like(row_scr)  # zero-pad the final partial bucket
    for b, row in enumerate(table.spans):
        @pl.when(k == b)
        def _copy(row=row):
            for i, lo, hi, dst in row:
                row_scr[0, dst:dst + (hi - lo)] = \
                    leaf_refs[leaf_pos[i]][0, lo:hi].astype(jnp.float32) * scale
    if wire == "int8":
        scale_ref, err_out = refs[n_in + 1], refs[n_in + 2]
        r = row_scr[...]
        if with_err:
            r = r + err_ref[...]
        s = jnp.maximum(jnp.max(jnp.abs(r)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(r / s), -127, 127)
        out_ref[...] = q.astype(jnp.int8)
        scale_ref[0, 0] = s
        err_out[...] = r - q * s
    else:
        out_ref[...] = row_scr[...].astype(out_ref.dtype)


def _pack_pallas(table: CodecTable, flat_g, scale: float, wire: str,
                 err: Optional[jnp.ndarray], interpret: bool):
    nb, cap = table.n_buckets, table.bucket_elems
    # zero-size leaves own no span: exclude them from the kernel inputs
    live = [i for i, s in enumerate(table.sizes) if s > 0]
    leaf_pos = {i: p for p, i in enumerate(live)}
    inputs = [flat_g[i].reshape(1, -1) for i in live]
    in_specs = [pl.BlockSpec((1, table.sizes[i]), lambda k: (0, 0))
                for i in live]
    with_err = wire == "int8" and err is not None
    if with_err:
        inputs.append(err)
        in_specs.append(pl.BlockSpec((1, cap), lambda k: (k, 0)))
    out_shape = [jax.ShapeDtypeStruct((nb, cap), WIRE_DTYPES[wire])]
    out_specs = [pl.BlockSpec((1, cap), lambda k: (k, 0))]
    if wire == "int8":
        out_shape += [jax.ShapeDtypeStruct((nb, 1), jnp.float32),
                      jax.ShapeDtypeStruct((nb, cap), jnp.float32)]
        out_specs += [pl.BlockSpec((1, 1), lambda k: (k, 0)),
                      pl.BlockSpec((1, cap), lambda k: (k, 0))]
    kernel = functools.partial(_pack_kernel, table=table, scale=scale,
                               wire=wire, with_err=with_err, leaf_pos=leaf_pos)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=in_specs,
        out_specs=out_specs if len(out_specs) > 1 else out_specs[0],
        out_shape=out_shape if len(out_shape) > 1 else out_shape[0],
        scratch_shapes=[pltpu.VMEM((1, cap), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*inputs)
    if wire == "int8":
        q, s, new_err = out
        return q, s[:, 0], new_err
    return out, None, err


def _unpack_kernel(*refs, table: CodecTable, dequant: bool, leaf_pos):
    k = pl.program_id(0)
    carrier_ref = refs[0]
    scale_ref = refs[1] if dequant else None
    outs = refs[2 if dequant else 1:]
    row = carrier_ref[...].astype(jnp.float32)
    if dequant:
        row = row * scale_ref[0, 0]
    for b, spans in enumerate(table.spans):
        @pl.when(k == b)
        def _scatter(spans=spans, row=row):
            for i, lo, hi, dst in spans:
                outs[leaf_pos[i]][0, lo:hi] = row[0, dst:dst + (hi - lo)]


def _unpack_pallas(table: CodecTable, carrier, like,
                   scales: Optional[jnp.ndarray], interpret: bool):
    nb, cap = table.n_buckets, table.bucket_elems
    live = [i for i, s in enumerate(table.sizes) if s > 0]
    leaf_pos = {i: p for p, i in enumerate(live)}
    inputs = [carrier]
    in_specs = [pl.BlockSpec((1, cap), lambda k: (k, 0))]
    dequant = scales is not None
    if dequant:
        inputs.append(scales.reshape(nb, 1))
        in_specs.append(pl.BlockSpec((1, 1), lambda k: (k, 0)))
    out_shape = [jax.ShapeDtypeStruct((1, table.sizes[i]), jnp.float32)
                 for i in live]
    out_specs = [pl.BlockSpec((1, table.sizes[i]), lambda k: (0, 0))
                 for i in live]
    kernel = functools.partial(_unpack_kernel, table=table, dequant=dequant,
                               leaf_pos=leaf_pos)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=in_specs,
        out_specs=out_specs if len(out_specs) > 1 else out_specs[0],
        out_shape=out_shape if len(out_shape) > 1 else out_shape[0],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(*inputs)
    rows = list(out) if isinstance(out, (tuple, list)) else [out]
    result = []
    it = iter(rows)
    for i, g in enumerate(like):
        if table.sizes[i] == 0:
            result.append(jnp.zeros(g.shape, jnp.float32))
        else:
            result.append(next(it).reshape(g.shape))
    return result


# ------------------------------------------------- fused sharded AdamW update
def _adamw_shard_xla(g, p, m, v, clip, lr, bc1, bc2, b1, b2, eps, wd, wire):
    g = g * clip
    m = b1 * m + (1 - b1) * g
    v = b2 * v + (1 - b2) * g * g
    mhat = m / bc1
    vhat = v / bc2
    delta = mhat / (jnp.sqrt(vhat) + eps) + wd * p
    p_new = p - lr * delta
    if wire == "int8":
        s = jnp.maximum(jnp.max(jnp.abs(p_new), axis=1), 1e-12) / 127.0
        q = jnp.clip(jnp.round(p_new / s[:, None]), -127, 127).astype(jnp.int8)
        return q, s, m, v
    return p_new.astype(WIRE_DTYPES[wire]), None, m, v


def _adamw_shard_kernel(*refs, b1, b2, eps, wd, wire):
    g_ref, p_ref, m_ref, v_ref, sc_ref = refs[:5]
    outs = refs[5:]
    clip, lr = sc_ref[0, 0], sc_ref[0, 1]
    bc1, bc2 = sc_ref[0, 2], sc_ref[0, 3]
    g = g_ref[...] * clip
    m = b1 * m_ref[...] + (1 - b1) * g
    v = b2 * v_ref[...] + (1 - b2) * g * g
    delta = (m / bc1) / (jnp.sqrt(v / bc2) + eps) + wd * p_ref[...]
    p_new = p_ref[...] - lr * delta
    if wire == "int8":
        p_out, s_out, m_out, v_out = outs
        s = jnp.maximum(jnp.max(jnp.abs(p_new)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(p_new / s), -127, 127)
        p_out[...] = q.astype(jnp.int8)
        s_out[0, 0] = s
    else:
        p_out, m_out, v_out = outs
        p_out[...] = p_new.astype(p_out.dtype)
    m_out[...] = m
    v_out[...] = v


def _adamw_shard_pallas(g, p, m, v, scalars, b1, b2, eps, wd, wire,
                        interpret: bool):
    nb, sh = g.shape
    row = pl.BlockSpec((1, sh), lambda k: (k, 0))
    in_specs = [row, row, row, row, pl.BlockSpec((1, 4), lambda k: (0, 0))]
    out_shape = [jax.ShapeDtypeStruct((nb, sh), WIRE_DTYPES[wire])]
    out_specs = [row]
    if wire == "int8":
        out_shape.append(jax.ShapeDtypeStruct((nb, 1), jnp.float32))
        out_specs.append(pl.BlockSpec((1, 1), lambda k: (k, 0)))
    out_shape += [jax.ShapeDtypeStruct((nb, sh), jnp.float32)] * 2
    out_specs += [row, row]
    kernel = functools.partial(_adamw_shard_kernel, b1=b1, b2=b2, eps=eps,
                               wd=wd, wire=wire)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("arbitrary",)),
        interpret=interpret,
    )(g, p, m, v, scalars)
    if wire == "int8":
        q, s, new_m, new_v = out
        return q, s[:, 0], new_m, new_v
    p_wire, new_m, new_v = out
    return p_wire, None, new_m, new_v


def adamw_update_shard(g: jnp.ndarray, p: jnp.ndarray, m: jnp.ndarray,
                       v: jnp.ndarray, *, clip, lr, bc1, bc2,
                       b1: float, b2: float, eps: float, weight_decay: float,
                       wire: str = "fp32", impl: str = "auto"):
    """Fused sharded AdamW: one device's `(n_buckets, shard_elems)` carrier
    shards of (reduced gradient, param, m, v) -> (p_wire, p_scales, new_m,
    new_v) — the ZeRO update between the reduce-scatter and the all-gather.

    Elementwise math is *identical* to `optim.adamw.apply_updates` (same op
    order, so fp32 results are bit-for-bit): `clip` is the global-norm clip
    factor (already psum-combined across shards by the caller), `lr` the
    scheduled rate, `bc1`/`bc2` the bias corrections — all traced scalars;
    `b1`/`b2`/`eps`/`weight_decay` are static.  Zero-padded carrier columns
    are stable: g = p = m = v = 0 gives delta = 0, so pads stay zero through
    any number of steps.

    `wire` is the all-gather leg's format: fp32/bf16 cast `p_new` (scales is
    None); int8 requantizes per bucket-shard with symmetric scales — the
    sideband the gather moves is one fp32 scale per (bucket, device) shard.
    Moments always stay fp32 and carrier-sharded.
    """
    if wire not in WIRE_DTYPES:
        raise ValueError(f"unknown wire format {wire!r}; "
                         f"one of {sorted(WIRE_DTYPES)}")
    if _resolve_impl(impl) == "pallas":
        scalars = jnp.stack([jnp.asarray(clip, jnp.float32),
                             jnp.asarray(lr, jnp.float32),
                             jnp.asarray(bc1, jnp.float32),
                             jnp.asarray(bc2, jnp.float32)]).reshape(1, 4)
        return _adamw_shard_pallas(g, p, m, v, scalars, b1, b2, eps,
                                   weight_decay, wire,
                                   interpret=jax.default_backend() != "tpu")
    return _adamw_shard_xla(g, p, m, v, clip, lr, bc1, bc2, b1, b2, eps,
                            weight_decay, wire)


# ------------------------------------------------------------------- public
def pack(table: CodecTable, flat_g: Sequence[jnp.ndarray], *,
         scale: float = 1.0, wire: str = "fp32",
         err: Optional[jnp.ndarray] = None, impl: str = "auto"):
    """Fused gather + wire-quantize: leaves -> (carrier, scales, new_err).

    `carrier` is `(n_buckets, bucket_elems)` in the wire dtype; the final
    partial bucket is zero-padded (zeros are the reduction identity).  `scale`
    multiplies every element (the 1/n pre-division of a mean-reduce).

    For ``wire="int8"``, `scales` holds the per-bucket symmetric quantization
    scales; `err` — a carrier-shaped fp32 error-feedback buffer — is added
    *after* scaling and before quantization, and `new_err` is the residual
    `packed - dequant(q)`.  For fp32/bf16 wires `scales` is None and `err`
    passes through untouched.
    """
    if wire not in WIRE_DTYPES:
        raise ValueError(f"unknown wire format {wire!r}; "
                         f"one of {sorted(WIRE_DTYPES)}")
    if table.n_buckets == 0:
        raise ValueError("cannot pack an empty table (no gradient elements)")
    if _resolve_impl(impl) == "pallas":
        return _pack_pallas(table, flat_g, scale, wire, err,
                            interpret=jax.default_backend() != "tpu")
    return _pack_xla(table, flat_g, scale, wire, err)


def unpack(table: CodecTable, carrier: jnp.ndarray,
           like: Sequence[jnp.ndarray],
           scales: Optional[jnp.ndarray] = None,
           impl: str = "auto") -> List[jnp.ndarray]:
    """Fused dequantize + scatter: reduced carrier -> per-leaf fp32 arrays
    shaped like `like` (inverse of `pack` up to the wire dtype's rounding).
    Zero-size leaves come back as fp32 zeros.  `carrier` may also be a list of
    1-D rows (the eager reduction path); it is stacked once here."""
    if not isinstance(carrier, jnp.ndarray):
        carrier = jnp.stack(list(carrier))
    if _resolve_impl(impl) == "pallas":
        return _unpack_pallas(table, carrier, like, scales,
                              interpret=jax.default_backend() != "tpu")
    return _unpack_xla(table, carrier, like, scales)


def wire_bytes(table: CodecTable, wire: str) -> int:
    """Bytes the carrier occupies on the wire (payload + int8 scale sideband).
    Delegates to `core.wire.bytes_on_wire` — one source of truth for the
    per-format accounting shared with the cost model."""
    from ..core.wire import bytes_on_wire

    return int(bytes_on_wire(table.carrier_elems * 4, wire, table.n_buckets))
